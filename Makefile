# `make artifacts` AOT-lowers the JAX golden models to HLO text (the
# validation oracle + CPU baseline — python is never on the rust
# request path; see DESIGN.md §1). `make verify` is the tier-1 check.
# `make tune-smoke` is the CI smoke run of the DSE tuner (docs/dse.md).
# `make validate-all` cross-checks the functional engine against the
# cycle-accurate simulator for every primary app (docs/execution.md).
# `make sim-bench` is the CI smoke run of the serving-throughput bench
# (docs/simulator.md, docs/execution.md): it compares the functional
# engine against the cycle-accurate simulator and asserts bit-exactness
# along the way. `make bench-json` refreshes the machine-readable perf
# trajectory (BENCH_serve.json / BENCH_dse.json) in quick mode — the
# CI step future PRs diff req/s and candidates/sec against; it now
# includes the large-image tiled serving numbers (docs/tiling.md).
# `make fuzz-smoke` is the CI smoke run of the seeded three-engine
# differential fuzz suite (rust/tests/exec_fuzz.rs): a small pinned
# case count so failures reproduce exactly; the full 50-case sweep
# runs in `make verify` via `cargo test`.
# `make metrics-smoke` starts a real server, pushes one request through
# the Python client, queries telemetry over the wire (`pushmem stats`)
# and checks the --metrics-json dump (docs/observability.md).
# `make serve-stress-smoke` fires 100 concurrent short-lived clients at
# a real server: every client must end with OK or STATUS_BUSY — never a
# hang — and the final stats must reconcile every rejection and accept
# (docs/serving.md).

.PHONY: artifacts verify tune-smoke validate-all sim-bench bench-json fuzz-smoke metrics-smoke serve-stress-smoke clean

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

verify:
	cargo build --release && cargo test -q

tune-smoke:
	cargo run --release -- tune gaussian --budget 8 --workers 2

validate-all:
	cargo run --release -- validate --all

sim-bench:
	SIM_BENCH_QUICK=1 cargo bench --bench serve_throughput

fuzz-smoke:
	PUSHMEM_FUZZ_CASES=6 PUSHMEM_FUZZ_SEED=7 cargo test -q --test exec_fuzz

metrics-smoke:
	bash scripts/metrics_smoke.sh

serve-stress-smoke:
	bash scripts/serve_stress.sh

bench-json:
	SIM_BENCH_QUICK=1 cargo bench --bench serve_throughput
	DSE_BENCH_QUICK=1 cargo bench --bench dse_harris

clean:
	cargo clean
	rm -rf artifacts dse-cache BENCH_serve.json BENCH_dse.json
