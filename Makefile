# `make artifacts` AOT-lowers the JAX golden models to HLO text (the
# validation oracle + CPU baseline — python is never on the rust
# request path; see DESIGN.md §1). `make verify` is the tier-1 check.

.PHONY: artifacts verify clean

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

verify:
	cargo build --release && cargo test -q

clean:
	cargo clean
	rm -rf artifacts
