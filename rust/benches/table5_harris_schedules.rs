//! Table V: six Halide schedules of the Harris corner detector —
//! pixels/cycle, PEs, MEMs, and runtime cycles.

#[path = "harness.rs"]
mod harness;

use pushmem::apps;
use pushmem::coordinator::report_app;

fn main() {
    harness::rule("Table V: Harris schedule exploration");
    println!(
        "{:<24} {:>8} {:>6} {:>6} {:>10}",
        "schedule", "px/cyc", "PEs", "MEMs", "cycles"
    );
    let rows = [
        ("sch1: recompute all", "harris_sch1"),
        ("sch2: recompute some", "harris_sch2"),
        ("sch3: no recompute", "harris"),
        ("sch4: unroll by 2", "harris_sch4"),
        ("sch5: 4x larger tile", "harris_sch5"),
        ("sch6: last on host", "harris_sch6"),
    ];
    let mut sch1_pes = 0;
    let mut sch3_pes = 0;
    for (label, name) in rows {
        let (p, _) = apps::by_name(name).unwrap();
        let r = report_app(&p, None, None).unwrap();
        if name == "harris_sch1" {
            sch1_pes = r.pes;
        }
        if name == "harris" {
            sch3_pes = r.pes;
        }
        println!(
            "{:<24} {:>8.2} {:>6} {:>6} {:>10}",
            label, r.pixels_per_cycle, r.pes, r.mems, r.completion
        );
    }
    println!(
        "\nrecompute-all / no-recompute PE ratio: {:.1}x (paper: 769/83 = 9.3x)",
        sch1_pes as f64 / sch3_pes as f64
    );
}
