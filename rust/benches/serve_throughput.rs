//! Serving throughput: requests/sec through the simulated CGRA, with
//! and without the per-design SimPlan cache (docs/simulator.md), then
//! through the full TCP + worker-pool stack.
//!
//! §1 isolates the plan/run split: the same requests are simulated
//! with fresh compile-grade setup per request (the pre-split serving
//! cost) versus one cached `SimPlan` and a reused `SimRun`. §2 runs N
//! concurrent clients against the real server, which always serves
//! from the cached plan.
//!
//! Run: `cargo bench --bench serve_throughput` (it is a plain binary:
//! criterion is not vendored in this offline image). Set
//! `SIM_BENCH_QUICK=1` for the CI smoke variant (fewer requests,
//! same code paths — the `make sim-bench` target).

#[path = "harness.rs"]
mod harness;

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use pushmem::cgra::{simulate, SimRun};
use pushmem::coordinator::serve::{self, ServeConfig};
use pushmem::coordinator::CompiledRegistry;
use pushmem::tensor::Tensor;

const APP: &str = "gaussian";
const WORKERS: usize = 8;

fn main() {
    let quick = std::env::var("SIM_BENCH_QUICK")
        .map_or(false, |v| !v.is_empty() && v != "0");
    let requests_per_client: usize = if quick { 4 } else { 12 };
    let direct_reqs: usize = if quick { 4 } else { 16 };
    let client_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };

    harness::rule("serving throughput: plan caching, then N concurrent clients");

    let registry = Arc::new(CompiledRegistry::new());
    let c = registry.get(APP).expect("compile");

    // One deterministic tile reused by every request (we are measuring
    // the serving stack, not input generation).
    let tiles: Vec<Tensor> = c
        .lp
        .inputs
        .iter()
        .map(|name| {
            Tensor::from_fn(c.lp.buffers[name].clone(), |p| {
                let mut h = 23i64;
                for &v in p {
                    h = h.wrapping_mul(31).wrapping_add(v + 7);
                }
                (h.rem_euclid(253)) as i32
            })
        })
        .collect();

    // --- §1 Direct simulation: fresh setup vs cached plan -----------
    let mut inputs = BTreeMap::new();
    for (name, t) in c.lp.inputs.iter().zip(tiles.iter()) {
        inputs.insert(name.clone(), t.clone());
    }
    let baseline = simulate(&c.design, &c.graph, &inputs).expect("fresh simulate");
    let t0 = Instant::now();
    for _ in 0..direct_reqs {
        // The pre-split cost: wire interning, hardware instantiation
        // and event analysis on every request.
        simulate(&c.design, &c.graph, &inputs).expect("fresh simulate");
    }
    let fresh_s = t0.elapsed().as_secs_f64();

    let plan = c.plan().expect("sim plan");
    let mut run = SimRun::new(plan);
    run.run(&inputs).expect("cached simulate"); // warm (instantiation)
    let t0 = Instant::now();
    for _ in 0..direct_reqs {
        run.run(&inputs).expect("cached simulate");
    }
    let cached_s = t0.elapsed().as_secs_f64();
    // Bit-exactness checked outside the timed loops so both measure
    // bare simulation.
    let check = run.run(&inputs).expect("cached simulate");
    assert_eq!(check.output.data, baseline.output.data, "plan reuse must be bit-exact");

    let fresh_rps = direct_reqs as f64 / fresh_s;
    let cached_rps = direct_reqs as f64 / cached_s;
    println!(
        "sim only ({direct_reqs} requests): fresh-setup {fresh_rps:.1} req/s, \
         cached-plan {cached_rps:.1} req/s ({:.2}x)",
        cached_rps / fresh_rps
    );

    // --- §2 Full TCP + worker-pool stack (plan-cached) --------------
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || serve::serve_on(listener, ServeConfig::multi(registry, WORKERS)));
    }
    let tiles = Arc::new(tiles);

    println!(
        "{:<10} {:>10} {:>12} {:>14}",
        "clients", "requests", "req/s", "ms/req (avg)"
    );
    for &clients in client_counts {
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..clients {
                let tiles = Arc::clone(&tiles);
                s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let refs: Vec<&Tensor> = tiles.iter().collect();
                    for _ in 0..requests_per_client {
                        let (words, _, _) =
                            serve::request_app(&mut stream, APP, &refs).unwrap();
                        assert!(!words.is_empty());
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let total = clients * requests_per_client;
        println!(
            "{:<10} {:>10} {:>12.1} {:>14.3}",
            clients,
            total,
            total as f64 / wall,
            wall / total as f64 * 1e3
        );
    }
    println!(
        "\n(app: {APP}, {} cycles/tile simulated per request, {WORKERS} server workers)",
        c.graph.completion
    );
}
