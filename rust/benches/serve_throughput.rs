//! Serving throughput baseline: requests/sec for N concurrent clients
//! against the simulated CGRA through the full TCP + worker-pool
//! stack. Later scaling PRs (batching, sharding, faster simulation)
//! measure against these numbers.
//!
//! Run: `cargo bench --bench serve_throughput` (it is a plain binary:
//! criterion is not vendored in this offline image).

#[path = "harness.rs"]
mod harness;

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use pushmem::coordinator::serve::{self, ServeConfig};
use pushmem::coordinator::CompiledRegistry;
use pushmem::tensor::Tensor;

const APP: &str = "gaussian";
const REQUESTS_PER_CLIENT: usize = 12;
const WORKERS: usize = 8;

fn main() {
    harness::rule("serving throughput: N concurrent clients, one endpoint");

    let registry = Arc::new(CompiledRegistry::new());
    let c = registry.get(APP).expect("compile");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || serve::serve_on(listener, ServeConfig::multi(registry, WORKERS)));
    }

    // One deterministic tile reused by every request (we are measuring
    // the serving stack, not input generation).
    let tiles: Vec<Tensor> = c
        .lp
        .inputs
        .iter()
        .map(|name| {
            Tensor::from_fn(c.lp.buffers[name].clone(), |p| {
                let mut h = 23i64;
                for &v in p {
                    h = h.wrapping_mul(31).wrapping_add(v + 7);
                }
                (h.rem_euclid(253)) as i32
            })
        })
        .collect();
    let tiles = Arc::new(tiles);

    println!(
        "{:<10} {:>10} {:>12} {:>14}",
        "clients", "requests", "req/s", "ms/req (avg)"
    );
    for clients in [1usize, 2, 4, 8] {
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..clients {
                let tiles = Arc::clone(&tiles);
                s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let refs: Vec<&Tensor> = tiles.iter().collect();
                    for _ in 0..REQUESTS_PER_CLIENT {
                        let (words, _, _) =
                            serve::request_app(&mut stream, APP, &refs).unwrap();
                        assert!(!words.is_empty());
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let total = clients * REQUESTS_PER_CLIENT;
        println!(
            "{:<10} {:>10} {:>12.1} {:>14.3}",
            clients,
            total,
            total as f64 / wall,
            wall / total as f64 * 1e3
        );
    }
    println!(
        "\n(app: {APP}, {} cycles/tile simulated per request, {WORKERS} server workers)",
        c.graph.completion
    );
}
