//! Serving throughput: requests/sec through the compiled designs,
//! comparing the functional execution engine against the
//! cycle-accurate simulator (docs/execution.md), then through the
//! full TCP + worker-pool stack.
//!
//! §0 is the engine comparison the ExecPlan work is measured by: for
//! every primary app, the same requests run through a cached-plan
//! `SimRun` and a cached-plan `ExecRun` (bit-exactness asserted
//! outside the timed loops), reporting req/s and the exec-vs-sim
//! speedup. §1 isolates the older plan/run split (fresh sim setup per
//! request vs cached plan). §2 runs N concurrent clients against the
//! real server, which serves from the functional engine by default.
//! §3 measures tiled whole-image serving (docs/tiling.md) and §4 the
//! cross-request scheduler: M concurrent image clients vs the same
//! total issued one-at-a-time (docs/serving.md). §5 isolates the
//! persistent compute pool: dispatch cost vs a per-run
//! `std::thread::scope` spawn over identical work, and the
//! `StorePartition` parallel path on a channel-interleaved store
//! (8-wide vs serial req/s on the same compiled design). §6 measures
//! load-adaptive variant routing (docs/routing.md): whole-image req/s
//! through a multi-variant set built from a persisted `.pareto` front
//! vs the same traffic pinned to the energy-optimal variant — the
//! cost a single-variant deployment pays under light load.
//!
//! Results are also written machine-readably to `BENCH_serve.json`
//! (the perf trajectory file `make bench-json` refreshes in CI).
//!
//! Run: `cargo bench --bench serve_throughput` (a plain binary:
//! criterion is not vendored in this offline image). Set
//! `SIM_BENCH_QUICK=1` for the CI smoke variant (fewer requests and
//! apps, same code paths).

#[path = "harness.rs"]
mod harness;

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use pushmem::cgra::{simulate, SimRun};
use pushmem::coordinator::serve::{self, ServeConfig};
use pushmem::coordinator::{compile, gen_inputs, CompiledRegistry};
use pushmem::exec::{pool, Engine, ExecRun};
use pushmem::tensor::Tensor;
use pushmem::tile::run_tiled;

const APP: &str = "gaussian";
const WORKERS: usize = 8;

/// A channel-unrolled planar-RGB pipeline: each per-lane kernel has a
/// collapsed dim-0 extent of 1 and an interleaved store — the shape
/// only the generalized `StorePartition` proof can parallelize (the
/// §5 strided-parallel measurement; see docs/execution.md).
fn planar_rgb(tile: i64) -> pushmem::halide::Program {
    use pushmem::halide::{Expr, Func, HwSchedule, InputDecl, Program};
    let rgb = Func::pure_fn(
        "rgb",
        &["c", "y", "x"],
        Expr::add(
            Expr::mul(
                Expr::c(3),
                Expr::ld("input", vec![Expr::v("c"), Expr::v("y"), Expr::v("x")]),
            ),
            Expr::v("c"),
        ),
    );
    Program {
        name: "prgb".into(),
        inputs: vec![InputDecl { name: "input".into(), rank: 3 }],
        funcs: vec![rgb],
        schedule: HwSchedule::new([3, tile, tile]).unroll("rgb", "c", 3),
    }
}

fn main() {
    let quick = std::env::var("SIM_BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let requests_per_client: usize = if quick { 4 } else { 12 };
    let direct_reqs: usize = if quick { 4 } else { 16 };
    let exec_reqs: usize = if quick { 50 } else { 400 };
    let client_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    // Quick mode keeps CI latency down with a representative app
    // subset; the full run covers every primary app.
    let bench_apps: &[&str] = if quick {
        &["gaussian", "harris"]
    } else {
        pushmem::apps::PRIMARY
    };

    harness::rule("serving throughput: engines, plan caching, then N concurrent clients");

    let registry = Arc::new(CompiledRegistry::new());

    // --- §0 Engine comparison per primary app -----------------------
    println!(
        "{:<12} {:>12} {:>12} {:>9}  (cached-plan req/s)",
        "app", "sim", "exec", "speedup"
    );
    let mut app_rows: Vec<String> = Vec::new();
    let mut speedups: Vec<f64> = Vec::new();
    for name in bench_apps {
        let c = registry.get(name).expect("compile");
        let inputs = gen_inputs(&c.lp);

        let mut sim_run = SimRun::new(c.plan().expect("sim plan"));
        let mut exec_run = ExecRun::new(c.exec_plan().expect("exec plan"));
        // Bit-exactness and identical stats checked outside the timed
        // loops (the differential test suite proves it exhaustively;
        // the bench must not regress it silently).
        let s = sim_run.run(&inputs).expect("sim");
        let e = exec_run.run(&inputs).expect("exec");
        assert_eq!(s.output.data, e.output.data, "{name}: engine outputs differ");
        assert_eq!(s.stats, e.stats, "{name}: engine stats differ");

        let t0 = Instant::now();
        for _ in 0..direct_reqs {
            sim_run.run(&inputs).expect("sim");
        }
        let sim_rps = direct_reqs as f64 / t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        for _ in 0..exec_reqs {
            exec_run.run(&inputs).expect("exec");
        }
        let exec_rps = exec_reqs as f64 / t0.elapsed().as_secs_f64();

        let speedup = exec_rps / sim_rps;
        speedups.push(speedup);
        println!("{name:<12} {sim_rps:>12.1} {exec_rps:>12.1} {speedup:>8.1}x");
        app_rows.push(
            harness::Json::obj()
                .str_("app", name)
                .num("sim_req_per_s", sim_rps)
                .num("exec_req_per_s", exec_rps)
                .num("exec_vs_sim_speedup", speedup)
                .int("cycles_per_tile", s.stats.cycles)
                .end(),
        );
    }
    let geo = harness::geomean(&speedups);
    println!("geomean exec-vs-sim speedup: {geo:.1}x");

    // --- §1 Plan caching on the sim fallback ------------------------
    let c = registry.get(APP).expect("compile");
    let tiles: Vec<Tensor> = c
        .lp
        .inputs
        .iter()
        .map(|name| {
            Tensor::from_fn(c.lp.buffers[name].clone(), |p| {
                let mut h = 23i64;
                for &v in p {
                    h = h.wrapping_mul(31).wrapping_add(v + 7);
                }
                (h.rem_euclid(253)) as i32
            })
        })
        .collect();
    let mut inputs = BTreeMap::new();
    for (name, t) in c.lp.inputs.iter().zip(tiles.iter()) {
        inputs.insert(name.clone(), t.clone());
    }
    let baseline = simulate(&c.design, &c.graph, &inputs).expect("fresh simulate");
    let t0 = Instant::now();
    for _ in 0..direct_reqs {
        simulate(&c.design, &c.graph, &inputs).expect("fresh simulate");
    }
    let fresh_s = t0.elapsed().as_secs_f64();

    let plan = c.plan().expect("sim plan");
    let mut run = SimRun::new(plan);
    run.run(&inputs).expect("cached simulate"); // warm (instantiation)
    let t0 = Instant::now();
    for _ in 0..direct_reqs {
        run.run(&inputs).expect("cached simulate");
    }
    let cached_s = t0.elapsed().as_secs_f64();
    let check = run.run(&inputs).expect("cached simulate");
    assert_eq!(check.output.data, baseline.output.data, "plan reuse must be bit-exact");

    let fresh_rps = direct_reqs as f64 / fresh_s;
    let cached_rps = direct_reqs as f64 / cached_s;
    println!(
        "\nsim only ({direct_reqs} requests): fresh-setup {fresh_rps:.1} req/s, \
         cached-plan {cached_rps:.1} req/s ({:.2}x)",
        cached_rps / fresh_rps
    );

    // --- §2 Full TCP + worker-pool stack (exec engine) --------------
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || serve::serve_on(listener, ServeConfig::multi(registry, WORKERS)));
    }
    let tiles = Arc::new(tiles);

    println!(
        "{:<10} {:>10} {:>12} {:>14}",
        "clients", "requests", "req/s", "ms/req (avg)"
    );
    let mut tcp_best_rps = 0.0f64;
    for &clients in client_counts {
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..clients {
                let tiles = Arc::clone(&tiles);
                s.spawn(move || {
                    let mut stream = TcpStream::connect(addr).unwrap();
                    let refs: Vec<&Tensor> = tiles.iter().collect();
                    for _ in 0..requests_per_client {
                        let (words, _, _) =
                            serve::request_app(&mut stream, APP, &refs).unwrap();
                        assert!(!words.is_empty());
                    }
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let total = clients * requests_per_client;
        let rps = total as f64 / wall;
        tcp_best_rps = tcp_best_rps.max(rps);
        println!(
            "{:<10} {:>10} {:>12.1} {:>14.3}",
            clients,
            total,
            rps,
            wall / total as f64 * 1e3
        );
    }
    println!(
        "\n(app: {APP}, {} cycles/tile per request, {WORKERS} server workers, engine auto)",
        c.graph.completion
    );

    // --- §3 Large-image tiled serving (docs/tiling.md) --------------
    // One whole-image request is decomposed onto the fixed design by
    // the tile planner: measure tiles/sec and whole-image req/s, both
    // in-process (run_tiled with a local worker fan-out) and over the
    // wire (v3 frames against the running server, whose pool recruits
    // idle workers into the batch).
    let extent: Vec<i64> = if quick { vec![150, 150] } else { vec![250, 250] };
    let plan = c.tile_plan(&extent).expect("tile plan");
    let tiles_per_image = plan.tile_count();
    let mut image_inputs = BTreeMap::new();
    let mut image_tensors: Vec<Tensor> = Vec::new();
    for (name, b) in plan.input_names.iter().zip(&plan.input_boxes) {
        let t = Tensor::from_fn(b.clone(), |p| {
            let mut h = 41i64;
            for &v in p {
                h = h.wrapping_mul(31).wrapping_add(v + 7);
            }
            (h.rem_euclid(253)) as i32
        });
        image_inputs.insert(name.clone(), t.clone());
        image_tensors.push(t);
    }
    let image_reps: usize = if quick { 3 } else { 10 };

    // Bit-exactness of the vectorized + threaded drain against the
    // scalar reference walk, asserted outside the timed loops (the
    // exec_fuzz suite proves it exhaustively; the bench must not
    // regress it silently).
    let vres = run_tiled(&c, Engine::Exec, &extent, image_inputs.clone(), WORKERS)
        .expect("tiled exec");
    let sres = run_tiled(&c, Engine::ExecScalar, &extent, image_inputs.clone(), WORKERS)
        .expect("tiled exec-scalar");
    assert_eq!(vres.output.data, sres.output.data, "scalar vs vectorized outputs differ");
    assert_eq!(vres.stats, sres.stats, "scalar vs vectorized stats differ");

    let t0 = Instant::now();
    for _ in 0..image_reps {
        let res = run_tiled(&c, Engine::Auto, &extent, image_inputs.clone(), WORKERS)
            .expect("tiled run");
        assert_eq!(res.tiles, tiles_per_image);
    }
    let direct_s = t0.elapsed().as_secs_f64();
    let tiles_per_s = (image_reps * tiles_per_image) as f64 / direct_s;
    let image_rps = image_reps as f64 / direct_s;

    // The same drain through the scalar reference path — the
    // denominator of the hot-path (lanes + threads + arena) speedup.
    let t0 = Instant::now();
    for _ in 0..image_reps {
        let res = run_tiled(&c, Engine::ExecScalar, &extent, image_inputs.clone(), WORKERS)
            .expect("tiled scalar run");
        assert_eq!(res.tiles, tiles_per_image);
    }
    let scalar_s = t0.elapsed().as_secs_f64();
    let scalar_tiles_per_s = (image_reps * tiles_per_image) as f64 / scalar_s;
    let hot_path_speedup = tiles_per_s / scalar_tiles_per_s;

    let refs: Vec<&Tensor> = image_tensors.iter().collect();
    let mut stream = TcpStream::connect(addr).unwrap();
    let t0 = Instant::now();
    for _ in 0..image_reps {
        let (words, _, _) =
            serve::request_extent(&mut stream, Some(APP), &extent, &refs).unwrap();
        assert_eq!(words.len() as i64, extent.iter().product::<i64>());
    }
    let tcp_image_rps = image_reps as f64 / t0.elapsed().as_secs_f64();

    println!(
        "\ntiled {APP} {}x{}: {tiles_per_image} tiles/image, \
         {tiles_per_s:.1} tiles/s, {image_rps:.2} image/s direct, \
         {tcp_image_rps:.2} image/s over TCP",
        extent[0], extent[1]
    );
    println!(
        "tiled hot path: vectorized {tiles_per_s:.1} tiles/s vs scalar \
         {scalar_tiles_per_s:.1} tiles/s ({hot_path_speedup:.2}x)"
    );

    // --- §4 Concurrent image clients (docs/serving.md) --------------
    // The traffic-engine scenario: M clients firing the same
    // whole-image request at once. The shared tile scheduler
    // interleaves their batches across one worker pool (and one
    // warmed plan/runner per design), so concurrent aggregate req/s
    // should beat the same total issued one-at-a-time.
    let conc_clients: usize = if quick { 2 } else { 4 };
    let conc_reps: usize = if quick { 2 } else { 5 };
    let total_images = conc_clients * conc_reps;

    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..conc_clients {
            let (refs, extent) = (&image_tensors, &extent);
            s.spawn(move || {
                let refs: Vec<&Tensor> = refs.iter().collect();
                let mut stream = TcpStream::connect(addr).unwrap();
                for _ in 0..conc_reps {
                    let (words, _, _) =
                        serve::request_extent(&mut stream, Some(APP), extent, &refs).unwrap();
                    assert_eq!(words.len() as i64, extent.iter().product::<i64>());
                }
            });
        }
    });
    let conc_image_rps = total_images as f64 / t0.elapsed().as_secs_f64();

    // Isolated baseline: the same total images, one at a time on one
    // connection (no cross-request scheduling possible).
    let t0 = Instant::now();
    {
        let mut stream = TcpStream::connect(addr).unwrap();
        for _ in 0..total_images {
            let (words, _, _) =
                serve::request_extent(&mut stream, Some(APP), &extent, &refs).unwrap();
            assert_eq!(words.len() as i64, extent.iter().product::<i64>());
        }
    }
    let serial_image_rps = total_images as f64 / t0.elapsed().as_secs_f64();
    let coalesced_speedup = conc_image_rps / serial_image_rps;

    println!(
        "concurrent images: {conc_clients} clients x {conc_reps} reqs: \
         {conc_image_rps:.2} image/s concurrent vs {serial_image_rps:.2} image/s \
         isolated ({coalesced_speedup:.2}x coalesced-vs-isolated)"
    );

    // --- §5 Persistent compute pool (docs/execution.md) -------------
    // (a) Dispatch cost: the same partitioned sum fanned out through
    // the warm persistent pool vs a fresh `std::thread::scope` spawn
    // per dispatch — the per-tile overhead the pool removes from the
    // serve drain. (b) The `StorePartition` parallel path: a
    // channel-interleaved store (collapsed dim 0, provable only under
    // the generalized proof) at 8-wide vs serial, bit-exactness
    // asserted outside the timed loops.
    let pool_iters: usize = if quick { 50 } else { 500 };
    let data: Vec<u64> = (0..(1u64 << 16)).collect();
    let parts = std::thread::available_parallelism()
        .map_or(2, |n| n.get())
        .clamp(2, 8);
    let chunks: Vec<&[u64]> = data.chunks((data.len() + parts - 1) / parts).collect();
    let expected: u64 = data.iter().sum();
    let acc = AtomicU64::new(0);

    let dispatch = |acc: &AtomicU64| {
        let mut tasks: Vec<_> = chunks
            .iter()
            .map(|&c| move || {
                acc.fetch_add(c.iter().sum::<u64>(), Ordering::Relaxed);
            })
            .collect();
        pool::run_tasks(&mut tasks);
    };
    dispatch(&acc); // warm: spawns the workers outside the timed loop
    let t0 = Instant::now();
    for _ in 0..pool_iters {
        dispatch(&acc);
    }
    let pool_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    for _ in 0..pool_iters {
        std::thread::scope(|s| {
            for &c in &chunks {
                let acc = &acc;
                s.spawn(move || {
                    acc.fetch_add(c.iter().sum::<u64>(), Ordering::Relaxed);
                });
            }
        });
    }
    let spawn_s = t0.elapsed().as_secs_f64();
    assert_eq!(
        acc.load(Ordering::Relaxed),
        expected * (2 * pool_iters as u64 + 1),
        "pool and spawn dispatches must run every task exactly once"
    );
    let pool_dispatch_per_s = pool_iters as f64 / pool_s;
    let spawn_dispatch_per_s = pool_iters as f64 / spawn_s;
    let pool_vs_spawn_speedup = spawn_s / pool_s;
    println!(
        "\ncompute pool: {pool_dispatch_per_s:.0} dispatch/s warm pool vs \
         {spawn_dispatch_per_s:.0} dispatch/s thread::scope \
         ({pool_vs_spawn_speedup:.2}x, {parts} tasks/dispatch)"
    );

    let pc = compile(&planar_rgb(280)).expect("compile planar rgb");
    assert!(
        pc.exec_plan().expect("exec plan").parallel_kernel_count() >= 1,
        "planar rgb must take the partitioned parallel path"
    );
    let prgb_inputs = gen_inputs(&pc.lp);
    let mut par = ExecRun::with_threads(pc.exec_plan().expect("exec plan"), 8);
    let mut ser = ExecRun::with_threads(pc.exec_plan().expect("exec plan"), 1);
    let a = par.run(&prgb_inputs).expect("parallel exec");
    let b = ser.run(&prgb_inputs).expect("serial exec");
    assert_eq!(a.output.data, b.output.data, "strided parallel outputs differ");
    assert_eq!(a.stats, b.stats, "strided parallel stats differ");

    let strided_reps: usize = if quick { 10 } else { 60 };
    let t0 = Instant::now();
    for _ in 0..strided_reps {
        par.run(&prgb_inputs).expect("parallel exec");
    }
    let strided_parallel_req_per_s = strided_reps as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..strided_reps {
        ser.run(&prgb_inputs).expect("serial exec");
    }
    let strided_serial_req_per_s = strided_reps as f64 / t0.elapsed().as_secs_f64();
    let strided_parallel_speedup = strided_parallel_req_per_s / strided_serial_req_per_s;
    println!(
        "strided-store parallel path (planar rgb 3x280x280): \
         {strided_parallel_req_per_s:.1} req/s 8-wide vs \
         {strided_serial_req_per_s:.1} req/s serial \
         ({strided_parallel_speedup:.2}x)"
    );

    // --- §6 Load-adaptive variant routing (docs/routing.md) ---------
    // A deployment pinned to the energy-optimal variant (picked, say,
    // for power) pays its smaller tile on every request even when the
    // pool is idle. The router serves the latency variant under light
    // load instead, shifting down only as pressure builds — so routed
    // whole-image req/s on an idle pool must beat the pinned
    // single-variant server on identical traffic, with bit-identical
    // responses (every variant is a validated schedule of the same
    // program).
    let (routed_rps, pinned_rps, routing_roles) = {
        use pushmem::coordinator::{compile_variants, VariantSet};
        use pushmem::dse::cache::{candidate_key, encode_schedule, CacheEntry, DseCache};
        use pushmem::halide::HwSchedule;

        let tuned_dir =
            std::env::temp_dir().join(format!("pushmem-bench-routing-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&tuned_dir);
        let entry = |sched: &HwSchedule, cycles: i64, energy: f64, area: f64, pes: usize| CacheEntry {
            key: candidate_key(APP, sched),
            cycles,
            completion: cycles,
            pes,
            mems: 1,
            sram_words: 64,
            energy_per_op_pj: energy,
            pixels_per_cycle: 1.0,
            area_um2: area,
            encoded: encode_schedule(sched),
        };
        // Latency role: the full 62-tile schedule. Energy role: a
        // 31-tile design (fewer PEs, lower synthetic pJ/op) that costs
        // ~4x the tiles per image — the gap routing recovers.
        let lat = HwSchedule::new([62, 62]);
        let eco = HwSchedule::new([31, 31]);
        let mut cache = DseCache::open(&tuned_dir, APP).expect("tuned dir");
        let e_lat = entry(&lat, 100, 9.0, 900.0, 80);
        let e_eco = entry(&eco, 400, 2.0, 300.0, 30);
        let keys = vec![e_lat.key.clone(), e_eco.key.clone()];
        cache.record(e_lat).expect("record");
        cache.record(e_eco).expect("record");
        cache.write_pareto(&keys).expect("write pareto");

        let (prog, _) = pushmem::apps::by_name(APP).expect("app");
        let set =
            Arc::new(compile_variants(&prog, APP, Some(tuned_dir.as_path())).expect("variants"));
        let roles: Vec<String> =
            set.variants().iter().map(|v| v.role.to_string()).collect();
        let pinned = Arc::new(VariantSet::solo(Arc::clone(
            &set.by_role(1).expect("energy variant").compiled,
        )));

        let spawn_variant_server = |set: Arc<VariantSet>| {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            std::thread::spawn(move || {
                let mut cfg = ServeConfig::single_set(APP, set);
                cfg.workers = WORKERS;
                serve::serve_on(listener, cfg)
            });
            addr
        };
        let routed_addr = spawn_variant_server(Arc::clone(&set));
        let pinned_addr = spawn_variant_server(Arc::clone(&pinned));

        // Bit-exactness across servers asserted outside the timed
        // loops; the warm-up also takes compile/plan setup off the
        // clock for both sides equally.
        let mut routed_stream = TcpStream::connect(routed_addr).unwrap();
        let mut pinned_stream = TcpStream::connect(pinned_addr).unwrap();
        let (routed_words, _, _) =
            serve::request_extent(&mut routed_stream, None, &extent, &refs).unwrap();
        let (pinned_words, _, _) =
            serve::request_extent(&mut pinned_stream, None, &extent, &refs).unwrap();
        assert_eq!(routed_words, pinned_words, "variants must answer bit-identically");

        let t0 = Instant::now();
        for _ in 0..image_reps {
            let (words, _, _) =
                serve::request_extent(&mut routed_stream, None, &extent, &refs).unwrap();
            assert_eq!(words.len() as i64, extent.iter().product::<i64>());
        }
        let routed_rps = image_reps as f64 / t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        for _ in 0..image_reps {
            let (words, _, _) =
                serve::request_extent(&mut pinned_stream, None, &extent, &refs).unwrap();
            assert_eq!(words.len() as i64, extent.iter().product::<i64>());
        }
        let pinned_rps = image_reps as f64 / t0.elapsed().as_secs_f64();

        let _ = std::fs::remove_dir_all(&tuned_dir);
        (routed_rps, pinned_rps, roles)
    };
    let routed_vs_single_variant_speedup = routed_rps / pinned_rps;
    println!(
        "\nrouted serving ({APP} {}x{}, variants {}): {routed_rps:.2} image/s routed vs \
         {pinned_rps:.2} image/s pinned-energy ({routed_vs_single_variant_speedup:.2}x)",
        extent[0],
        extent[1],
        routing_roles.join("/")
    );

    harness::write_bench_json(
        "BENCH_serve.json",
        &harness::Json::obj()
            .str_("bench", "serve_throughput")
            .bool_("quick", quick)
            .raw("apps", &harness::json_array(&app_rows))
            .num("geomean_exec_vs_sim_speedup", geo)
            .num("sim_fresh_req_per_s", fresh_rps)
            .num("sim_cached_req_per_s", cached_rps)
            .num("tcp_best_req_per_s", tcp_best_rps)
            .raw(
                "tiled",
                &harness::Json::obj()
                    .str_("app", APP)
                    .str_(
                        "extent",
                        &extent
                            .iter()
                            .map(|e| e.to_string())
                            .collect::<Vec<_>>()
                            .join("x"),
                    )
                    .int("tiles_per_image", tiles_per_image as i64)
                    .num("tiles_per_s", tiles_per_s)
                    .num("scalar_tiles_per_s", scalar_tiles_per_s)
                    .num("vector_vs_scalar_speedup", hot_path_speedup)
                    .num("image_req_per_s", image_rps)
                    .num("tcp_image_req_per_s", tcp_image_rps)
                    .end(),
            )
            .raw(
                "concurrent",
                &harness::Json::obj()
                    .int("clients", conc_clients as i64)
                    .int("reqs_per_client", conc_reps as i64)
                    .num("concurrent_image_req_per_s", conc_image_rps)
                    .num("serial_image_req_per_s", serial_image_rps)
                    .num("coalesced_vs_isolated_speedup", coalesced_speedup)
                    .end(),
            )
            .raw(
                "pool",
                &harness::Json::obj()
                    .num("pool_dispatch_per_s", pool_dispatch_per_s)
                    .num("spawn_dispatch_per_s", spawn_dispatch_per_s)
                    .num("pool_vs_spawn_speedup", pool_vs_spawn_speedup)
                    .num("strided_parallel_req_per_s", strided_parallel_req_per_s)
                    .num("strided_serial_req_per_s", strided_serial_req_per_s)
                    .num("strided_parallel_speedup", strided_parallel_speedup)
                    .int("pool_workers_spawned", pool::spawn_count() as i64)
                    .end(),
            )
            .raw(
                "routing",
                &harness::Json::obj()
                    .str_("variants", &routing_roles.join("/"))
                    .num("routed_image_req_per_s", routed_rps)
                    .num("pinned_image_req_per_s", pinned_rps)
                    .num("routed_vs_single_variant_speedup", routed_vs_single_variant_speedup)
                    .end(),
            )
            // Point-in-time server telemetry (docs/observability.md):
            // the TCP sections above ran through the instrumented
            // serving path, so the snapshot carries request counts,
            // per-stage latency histograms, and exec lane/thread
            // counters for `scripts/bench_diff.py` to compare.
            .raw("telemetry", &pushmem::telemetry::metrics().snapshot().to_json())
            .end(),
    );
}
