//! Fig 14: application runtime on CGRA (900 MHz), FPGA (200 MHz), and
//! CPU (the XLA-compiled golden model on this host — the same role the
//! paper's Xeon plays). The paper's headline: CGRA 4.7x faster than
//! FPGA and faster than the CPU.

#[path = "harness.rs"]
mod harness;

use std::path::PathBuf;

use pushmem::apps;
use pushmem::coordinator::report_app;
use pushmem::runtime::Runtime;

fn main() {
    harness::rule("Fig 14: runtime per tile (ms), CGRA vs FPGA vs CPU");
    let rt = Runtime::cpu().ok();
    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>11}",
        "app", "CGRA ms", "FPGA ms", "CPU ms", "FPGA/CGRA"
    );
    let mut ratios = Vec::new();
    for name in ["gaussian", "harris", "upsample", "unsharp", "camera", "resnet", "mobilenet"] {
        let (p, artifact) = apps::by_name(name).unwrap();
        let path = PathBuf::from("artifacts").join(format!("{artifact}.hlo.txt"));
        let r = report_app(
            &p,
            if path.exists() { Some(path.as_path()) } else { None },
            rt.as_ref(),
        )
        .unwrap();
        let ratio = r.fpga.runtime_s / r.cgra_runtime_s;
        println!(
            "{:<14} {:>10.4} {:>10.4} {:>10} {:>11.2}",
            name,
            r.cgra_runtime_s * 1e3,
            r.fpga.runtime_s * 1e3,
            r.cpu_time_s
                .map(|t| format!("{:.4}", t * 1e3))
                .unwrap_or_else(|| "-".into()),
            ratio
        );
        ratios.push(ratio);
    }
    println!(
        "\ngeomean FPGA/CGRA runtime ratio: {:.2}x (paper: 4.7x)",
        harness::geomean(&ratios)
    );
}
