//! Table II: the three physical unified buffer implementations — area
//! and energy per access for a 3x3 convolution — plus a timing bench of
//! the shipped memory tile's cycle model.

#[path = "harness.rs"]
mod harness;

use pushmem::cost::area::{table2_variants, PubVariant};
use pushmem::hw::{AffineConfig, MemTile, MemTileConfig, PortCtlConfig};
use pushmem::poly::Affine;

fn main() {
    harness::rule("Table II: physical unified buffer variants (model)");
    println!(
        "{:<28} {:>12} {:>8} {:>12} {:>14}",
        "variant", "MEM um^2", "SRAM %", "total um^2", "pJ / access"
    );
    let rows = table2_variants();
    for (v, c) in &rows {
        let name = match v {
            PubVariant::DpSramPes => "DP SRAM + PEs (baseline)",
            PubVariant::DpSramAg => "DP SRAM + AG",
            PubVariant::WideSpSram => "4-wide SP SRAM + AGG/TB/AG",
        };
        println!(
            "{:<28} {:>12.0} {:>8.0} {:>12.0} {:>14.2}",
            name,
            c.mem_tile_um2,
            100.0 * c.sram_fraction,
            c.total_ub_um2,
            c.energy_pj_per_access
        );
    }
    let base = rows[0].1;
    let best = rows[2].1;
    println!(
        "\nimprovement baseline -> shipped: area {:.2}x, energy {:.2}x (paper: ~2x / ~2x)",
        base.total_ub_um2 / best.total_ub_um2,
        base.energy_pj_per_access / best.energy_pj_per_access
    );

    // Timing: one full pass of a 4096-word delay buffer through the
    // behavioral memory tile.
    harness::rule("memtile cycle-model throughput");
    let cfg = |coeffs: Vec<i64>, off: i64| AffineConfig::from_affine(&Affine::new(coeffs, off));
    let tile_cfg = MemTileConfig {
        fetch_width: 4,
        capacity: 2048,
        serial_in: vec![PortCtlConfig::new(vec![1024, 4], cfg(vec![0, 1], 0), cfg(vec![4, 1], 0))
            .with_modulus(4)],
        serial_in_agg: vec![0],
        agg_flush: vec![PortCtlConfig::new(vec![1024], cfg(vec![1], 0), cfg(vec![4], 3))
            .with_modulus(512)],
        sram_read: vec![PortCtlConfig::new(vec![1024], cfg(vec![1], 0), cfg(vec![4], 6))
            .with_modulus(512)],
        tb_out: vec![PortCtlConfig::new(vec![1024, 4], cfg(vec![4, 1], 0), cfg(vec![4, 1], 8))
            .with_modulus(8)],
    };
    harness::time("memtile 4096-word pass", 20, || {
        let mut t = MemTile::new(tile_cfg.clone());
        for cycle in 0..4112 {
            let w = if cycle < 4096 { Some(cycle) } else { None };
            let _ = t.tick(cycle, &[w]).unwrap();
        }
    });
}
