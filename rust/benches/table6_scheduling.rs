//! Table VI: completion time of the optimized hardware-pipeline
//! schedule vs the naïve sequential baseline, per application.

#[path = "harness.rs"]
mod harness;

use pushmem::apps;
use pushmem::coordinator::sequential_comparison;

fn main() {
    harness::rule("Table VI: sequential vs optimized completion time");
    println!(
        "{:<14} {:>12} {:>12} {:>9}",
        "app", "seq cycles", "opt cycles", "speedup"
    );
    let mut speedups = Vec::new();
    for p in apps::all() {
        let s = sequential_comparison(&p).unwrap();
        println!(
            "{:<14} {:>12} {:>12} {:>9.2}",
            s.name, s.seq_completion, s.opt_completion, s.speedup
        );
        speedups.push(s.speedup);
    }
    println!("\ngeomean speedup: {:.2}x (paper: 3x-22x per app)", harness::geomean(&speedups));
}
