//! Fig 13: energy per operation, CGRA vs FPGA, per application. The
//! paper's headline: the CGRA is 4.3x more energy-efficient on average.

#[path = "harness.rs"]
mod harness;

use pushmem::apps;
use pushmem::coordinator::report_app;

fn main() {
    harness::rule("Fig 13: energy per op (pJ), CGRA vs FPGA");
    println!("{:<14} {:>12} {:>12} {:>8}", "app", "CGRA pJ/op", "FPGA pJ/op", "ratio");
    let mut ratios = Vec::new();
    for name in ["gaussian", "harris", "upsample", "unsharp", "camera", "resnet", "mobilenet"] {
        let (p, _) = apps::by_name(name).unwrap();
        let r = report_app(&p, None, None).unwrap();
        let ratio = r.fpga.energy_per_op_pj / r.cgra_energy_per_op_pj;
        println!(
            "{:<14} {:>12.2} {:>12.2} {:>8.2}",
            name, r.cgra_energy_per_op_pj, r.fpga.energy_per_op_pj, ratio
        );
        ratios.push(ratio);
    }
    println!(
        "\ngeomean FPGA/CGRA energy ratio: {:.2}x (paper: 4.3x)",
        harness::geomean(&ratios)
    );
}
