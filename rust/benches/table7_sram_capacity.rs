//! Table VII: required SRAM capacity (live 16-bit words) under the
//! sequential baseline vs the pipelined schedule.

#[path = "harness.rs"]
mod harness;

use pushmem::apps;
use pushmem::coordinator::sequential_comparison;

fn main() {
    harness::rule("Table VII: SRAM words, sequential vs pipelined");
    println!(
        "{:<14} {:>12} {:>12} {:>10}",
        "app", "seq words", "final words", "reduction"
    );
    for p in apps::all() {
        let s = sequential_comparison(&p).unwrap();
        println!(
            "{:<14} {:>12} {:>12} {:>10.2}",
            s.name, s.seq_words, s.opt_words, s.memory_reduction
        );
    }
    println!("\npaper shape: stencils 28-306x, mobilenet ~7x, resnet ~1x");
}
