//! Minimal bench harness (criterion is not vendored in this offline
//! image): measures wall-clock with warmup and repetition, prints
//! mean ± spread, and hosts the table printers the paper-reproduction
//! benches share. Used via `#[path = "harness.rs"] mod harness;`.

#![allow(dead_code)]

use std::time::Instant;

/// Time `f` with one warmup and `iters` measured runs; returns
/// (mean seconds, min, max) and prints a criterion-ish line.
pub fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0, f64::max);
    println!(
        "bench {name:<40} {:>10.3} ms  [{:.3} .. {:.3}]",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
    mean
}

pub fn rule(title: &str) {
    println!("\n==== {title} ====");
}

/// Geometric mean of ratios.
pub fn geomean(v: &[f64]) -> f64 {
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}
