//! Minimal bench harness (criterion is not vendored in this offline
//! image): measures wall-clock with warmup and repetition, prints
//! mean ± spread, and hosts the table printers the paper-reproduction
//! benches share. Used via `#[path = "harness.rs"] mod harness;`.

#![allow(dead_code)]

use std::time::Instant;

/// Time `f` with one warmup and `iters` measured runs; returns
/// (mean seconds, min, max) and prints a criterion-ish line.
pub fn time<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0, f64::max);
    println!(
        "bench {name:<40} {:>10.3} ms  [{:.3} .. {:.3}]",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
    mean
}

pub fn rule(title: &str) {
    println!("\n==== {title} ====");
}

/// Geometric mean of ratios.
pub fn geomean(v: &[f64]) -> f64 {
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

// ---------------------------------------------------------------------
// Machine-readable perf trajectory: a tiny JSON emitter (no serde in
// this offline image). `serve_throughput` writes BENCH_serve.json and
// `dse_harris` writes BENCH_dse.json through it (`make bench-json`),
// so CI and future PRs can diff req/s and candidates/sec numerically
// instead of scraping bench stdout.
// ---------------------------------------------------------------------

/// Builder for one JSON object. Values are formatted directly;
/// strings must not contain `"` or `\` (bench keys and app names
/// never do).
pub struct Json {
    buf: String,
    first: bool,
}

impl Json {
    pub fn obj() -> Json {
        Json { buf: String::from("{"), first: true }
    }

    fn key(&mut self, k: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        self.buf.push('"');
        self.buf.push_str(k);
        self.buf.push_str("\":");
    }

    pub fn num(mut self, k: &str, v: f64) -> Json {
        self.key(k);
        if v.is_finite() {
            self.buf.push_str(&format!("{v:.6}"));
        } else {
            self.buf.push_str("null");
        }
        self
    }

    pub fn int(mut self, k: &str, v: i64) -> Json {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    pub fn bool_(mut self, k: &str, v: bool) -> Json {
        self.key(k);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    pub fn str_(mut self, k: &str, v: &str) -> Json {
        self.key(k);
        self.buf.push('"');
        self.buf.push_str(v);
        self.buf.push('"');
        self
    }

    /// Pre-rendered JSON value (a nested object or array).
    pub fn raw(mut self, k: &str, v: &str) -> Json {
        self.key(k);
        self.buf.push_str(v);
        self
    }

    pub fn end(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

/// Render a JSON array from pre-rendered element strings.
pub fn json_array(items: &[String]) -> String {
    format!("[{}]", items.join(","))
}

/// Write a perf-trajectory file to the repo root and echo its path.
pub fn write_bench_json(path: &str, contents: &str) {
    match std::fs::write(path, format!("{contents}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
