//! Table IV: FPGA (BRAM/DSP/FF/LUT) and CGRA (PEs/MEMs) resources for
//! every application, plus compile-time timing.

#[path = "harness.rs"]
mod harness;

use pushmem::apps;
use pushmem::coordinator::{compile, report_app};

fn main() {
    harness::rule("Table IV: resources per application");
    println!(
        "{:<12} {:>5} {:>5} {:>7} {:>7} | {:>5} {:>5}",
        "app", "BRAM", "DSP", "FF", "LUT", "PEs", "MEMs"
    );
    for name in ["gaussian", "harris", "upsample", "unsharp", "camera", "resnet", "mobilenet"] {
        let (p, _) = apps::by_name(name).unwrap();
        let r = report_app(&p, None, None).unwrap();
        println!(
            "{:<12} {:>5} {:>5} {:>7} {:>7} | {:>5} {:>5}",
            name, r.fpga.bram, r.fpga.dsp, r.fpga.ff, r.fpga.lut, r.pes, r.mems
        );
    }

    harness::rule("compile time per app");
    for name in ["gaussian", "harris", "camera"] {
        let (p, _) = apps::by_name(name).unwrap();
        harness::time(&format!("compile {name}"), 5, || {
            let _ = compile(&p).unwrap();
        });
    }
}
