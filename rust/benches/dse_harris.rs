//! DSE tuner baseline on the paper's schedule-exploration subject
//! (§VI-C, Table V): candidates-evaluated/sec and tuned-best vs the
//! six hand-written Harris schedules, so future PRs can track tuner
//! throughput and search quality.
//!
//! Runs at tile 24 (not the paper's 60) to keep the bench quick; the
//! paper-scale run is `pushmem tune harris`.

#[path = "harness.rs"]
mod harness;

use pushmem::apps::harris::{build, Schedule};
use pushmem::dse::{self, Objective, SpaceConfig, TuneConfig};

fn main() {
    harness::rule("DSE: Harris schedule auto-tuning (tile 24)");

    // Hand-written Table V baselines, simulated with the same scorer
    // the tuner uses. Tiles differ across rows (sch5 is 2x per side),
    // so the comparison metric is cycles per output pixel.
    println!(
        "{:<24} {:>10} {:>5} {:>8} {:>6} {:>6}",
        "hand-written", "cycles", "tile", "cyc/px", "PEs", "MEMs"
    );
    let mut hand_best: Option<(f64, &str)> = None;
    for b in dse::table5_baselines(24) {
        match b.eval {
            Ok(e) => {
                let cpp = dse::cycles_per_pixel(e.cycles, &[b.tile, b.tile]);
                if hand_best.map_or(true, |(c, _)| cpp < c) {
                    hand_best = Some((cpp, b.label));
                }
                println!(
                    "{:<24} {:>10} {:>5} {:>8.3} {:>6} {:>6}",
                    b.label, e.cycles, b.tile, cpp, e.pes, e.mems
                );
            }
            Err(err) => println!("{:<24} failed: {err:#}", b.label),
        }
    }

    let cfg = TuneConfig {
        objective: Objective::Cycles,
        budget: 24,
        workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        seed: 1,
        cache_dir: None,
        space: SpaceConfig::default(),
    };
    let report = dse::tune_program(&build(24, Schedule::NoRecompute), "harris_t24", &cfg)
        .expect("tuner failed");

    println!(
        "\ntuner: {} enumerated, {} pruned, {} simulated (+{} failed) in {:.2} s",
        report.enumerated, report.infeasible, report.evaluated, report.failed,
        report.eval_seconds
    );
    println!(
        "bench {:<40} {:>10.2} candidates/s",
        "dse_harris/evaluation_throughput",
        report.evals_per_sec()
    );
    let best = report.best().expect("no valid candidate");
    let tuned_tile = best.entry.schedule().map(|s| s.tile).unwrap_or_default();
    let tuned_cpp = dse::cycles_per_pixel(best.entry.cycles, &tuned_tile);
    println!(
        "bench {:<40} {:>10.3} cyc/px  (schedule {})",
        "dse_harris/tuned_best", tuned_cpp, best.entry.encoded
    );
    if let Some((cpp, label)) = hand_best {
        println!(
            "bench {:<40} {:>10.3} cyc/px  ({label})",
            "dse_harris/hand_written_best", cpp
        );
        println!(
            "tuned vs hand-written: {:.2}x  ({})",
            cpp / tuned_cpp,
            if tuned_cpp <= cpp { "tuner >= hand-written" } else { "hand-written ahead" }
        );
    }
}
