//! DSE tuner baseline on the paper's schedule-exploration subject
//! (§VI-C, Table V): candidates-evaluated/sec through **both**
//! execution engines (the functional engine is the tuner's default;
//! the cycle-accurate simulator is the baseline it is measured
//! against — docs/execution.md), plus tuned-best vs the six
//! hand-written Harris schedules, so future PRs can track tuner
//! throughput and search quality. Machine-readable results land in
//! `BENCH_dse.json` (`make bench-json`).
//!
//! Runs at tile 24 (not the paper's 60) to keep the bench quick; the
//! paper-scale run is `pushmem tune harris`. `DSE_BENCH_QUICK=1`
//! shrinks the budget for CI.

#[path = "harness.rs"]
mod harness;

use pushmem::apps::harris::{build, Schedule};
use pushmem::dse::{self, Objective, SpaceConfig, TuneConfig};
use pushmem::exec::Engine;

fn main() {
    let quick = std::env::var("DSE_BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0");
    let budget = if quick { 8 } else { 24 };

    harness::rule("DSE: Harris schedule auto-tuning (tile 24)");

    // Hand-written Table V baselines, scored with the same functional
    // engine the tuner defaults to. Tiles differ across rows (sch5 is
    // 2x per side), so the comparison metric is cycles per output
    // pixel.
    println!(
        "{:<24} {:>10} {:>5} {:>8} {:>6} {:>6}",
        "hand-written", "cycles", "tile", "cyc/px", "PEs", "MEMs"
    );
    let mut hand_best: Option<(f64, &str)> = None;
    for b in dse::table5_baselines(24) {
        match b.eval {
            Ok(e) => {
                let cpp = dse::cycles_per_pixel(e.cycles, &[b.tile, b.tile]);
                let better = match hand_best {
                    Some((c, _)) => cpp < c,
                    None => true,
                };
                if better {
                    hand_best = Some((cpp, b.label));
                }
                println!(
                    "{:<24} {:>10} {:>5} {:>8.3} {:>6} {:>6}",
                    b.label, e.cycles, b.tile, cpp, e.pes, e.mems
                );
            }
            Err(err) => println!("{:<24} failed: {err:#}", b.label),
        }
    }

    // Tuner throughput, one run per engine (same space, same seed, so
    // the work is identical and the ratio is pure engine speed).
    let cfg_for = |engine: Engine| TuneConfig {
        objective: Objective::Cycles,
        budget,
        workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        seed: 1,
        cache_dir: None,
        engine,
        space: SpaceConfig::default(),
    };

    let sim_report =
        dse::tune_program(&build(24, Schedule::NoRecompute), "harris_t24", &cfg_for(Engine::Sim))
            .expect("sim-engine tuner failed");
    let report =
        dse::tune_program(&build(24, Schedule::NoRecompute), "harris_t24", &cfg_for(Engine::Auto))
            .expect("tuner failed");

    println!(
        "\ntuner: {} enumerated, {} pruned, {} scored (+{} failed)",
        report.enumerated, report.infeasible, report.evaluated, report.failed,
    );
    let sim_cps = sim_report.evals_per_sec();
    let exec_cps = report.evals_per_sec();
    println!(
        "bench {:<40} {:>10.2} candidates/s",
        "dse_harris/sim_engine_throughput", sim_cps
    );
    println!(
        "bench {:<40} {:>10.2} candidates/s",
        "dse_harris/exec_engine_throughput", exec_cps
    );
    let speedup = if sim_cps > 0.0 { exec_cps / sim_cps } else { 0.0 };
    println!("exec vs sim tuner throughput: {speedup:.1}x");

    // Identical search, identical ranking: the engine must never
    // change what the tuner finds.
    let keys = |r: &dse::TuneReport| -> Vec<&str> {
        r.results.iter().map(|x| x.entry.key.as_str()).collect()
    };
    assert_eq!(keys(&sim_report), keys(&report), "engines ranked differently");

    let best = report.best().expect("no valid candidate");
    let tuned_tile = best.entry.schedule().map(|s| s.tile).unwrap_or_default();
    let tuned_cpp = dse::cycles_per_pixel(best.entry.cycles, &tuned_tile);
    println!(
        "bench {:<40} {:>10.3} cyc/px  (schedule {})",
        "dse_harris/tuned_best", tuned_cpp, best.entry.encoded
    );
    let mut hand_cpp = f64::NAN;
    if let Some((cpp, label)) = hand_best {
        hand_cpp = cpp;
        println!(
            "bench {:<40} {:>10.3} cyc/px  ({label})",
            "dse_harris/hand_written_best", cpp
        );
        println!(
            "tuned vs hand-written: {:.2}x  ({})",
            cpp / tuned_cpp,
            if tuned_cpp <= cpp { "tuner >= hand-written" } else { "hand-written ahead" }
        );
    }

    harness::write_bench_json(
        "BENCH_dse.json",
        &harness::Json::obj()
            .str_("bench", "dse_harris")
            .bool_("quick", quick)
            .int("budget", budget as i64)
            .int("evaluated", report.evaluated as i64)
            .num("sim_candidates_per_s", sim_cps)
            .num("exec_candidates_per_s", exec_cps)
            .num("exec_vs_sim_speedup", speedup)
            .num("tuned_cycles_per_pixel", tuned_cpp)
            .num("hand_written_cycles_per_pixel", hand_cpp)
            .end(),
    );
}
