//! Funcs (pipeline stages) and whole programs.

use super::expr::Expr;
use super::schedule::HwSchedule;

/// An optional reduction update over a reduction domain (Halide RDom).
///
/// `update` may reference the func itself (the running accumulator) plus
/// the reduction iterators. When a reduction loop is *not* fully unrolled
/// the scheduler classifies the pipeline as DNN-style (§V-B).
#[derive(Clone, Debug)]
pub struct Reduction {
    /// Reduction iterators, outermost-first: `(name, min, extent)`.
    pub rdom: Vec<(String, i64, i64)>,
    /// Initial value of the accumulator (usually 0).
    pub init: Expr,
    /// One reduction step; `Load(self_name, pure_vars)` denotes the
    /// running accumulator.
    pub update: Expr,
}

/// A Halide Func: a named stage defined over pure iterators
/// (**outermost-first**, so `vars = ["y", "x"]` means y is the outer
/// loop), with either a pure body or a reduction.
#[derive(Clone, Debug)]
pub struct Func {
    pub name: String,
    pub vars: Vec<String>,
    /// Pure body (for non-reduction funcs), referencing inputs and
    /// earlier funcs through `Expr::Load`.
    pub body: Expr,
    pub reduction: Option<Reduction>,
}

impl Func {
    pub fn pure_fn(name: impl Into<String>, vars: &[&str], body: Expr) -> Func {
        Func {
            name: name.into(),
            vars: vars.iter().map(|s| s.to_string()).collect(),
            body,
            reduction: None,
        }
    }

    pub fn reduce_fn(
        name: impl Into<String>,
        vars: &[&str],
        init: Expr,
        rdom: &[(&str, i64, i64)],
        update: Expr,
    ) -> Func {
        Func {
            name: name.into(),
            vars: vars.iter().map(|s| s.to_string()).collect(),
            body: init.clone(),
            reduction: Some(Reduction {
                rdom: rdom.iter().map(|(n, m, e)| (n.to_string(), *m, *e)).collect(),
                init,
                update,
            }),
        }
    }
}

/// An external input image streamed to the accelerator
/// (`stream_to_accelerator` in the paper's scheduling language).
#[derive(Clone, Debug)]
pub struct InputDecl {
    pub name: String,
    /// Rank only; concrete extents come from bounds inference against the
    /// output tile.
    pub rank: usize,
}

/// A whole Halide pipeline: inputs, funcs in producer-to-consumer
/// (topological) order — the last func is the pipeline output — and the
/// hardware schedule.
#[derive(Clone, Debug)]
pub struct Program {
    pub name: String,
    pub inputs: Vec<InputDecl>,
    pub funcs: Vec<Func>,
    pub schedule: HwSchedule,
}

impl Program {
    pub fn func(&self, name: &str) -> Option<&Func> {
        self.funcs.iter().find(|f| f.name == name)
    }

    pub fn is_input(&self, name: &str) -> bool {
        self.inputs.iter().any(|i| i.name == name)
    }

    /// The accelerator output func (the last one not scheduled onto the
    /// host, §VI-C sch6).
    pub fn accel_output(&self) -> &Func {
        self.funcs
            .iter()
            .rev()
            .find(|f| !self.schedule.host_stages.contains(&f.name))
            .expect("no accelerator funcs")
    }

    /// Sanity checks: topological producer order, known loads, reduction
    /// self-references well-formed.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut defined: Vec<&str> = self.inputs.iter().map(|i| i.name.as_str()).collect();
        for f in &self.funcs {
            let check = |e: &Expr, selfok: bool| -> anyhow::Result<()> {
                for (buf, idx) in e.loads() {
                    let known = defined.contains(&buf.as_str()) || (selfok && buf == f.name);
                    anyhow::ensure!(
                        known,
                        "{}: load of undefined buffer {buf} in func {}",
                        self.name,
                        f.name
                    );
                    if buf == f.name {
                        anyhow::ensure!(
                            idx.len() == f.vars.len(),
                            "self-reference arity mismatch in {}",
                            f.name
                        );
                    }
                }
                Ok(())
            };
            check(&f.body, false)?;
            if let Some(r) = &f.reduction {
                check(&r.init, false)?;
                check(&r.update, true)?;
            }
            defined.push(&f.name);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn brighten_blur() -> Program {
        // The paper's running example (Fig 1): brighten then 2x2 blur.
        let brighten = Func::pure_fn(
            "brighten",
            &["y", "x"],
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
        );
        let blur = Func::pure_fn(
            "blur",
            &["y", "x"],
            Expr::shr(
                Expr::sum(vec![
                    Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                    Expr::ld(
                        "brighten",
                        vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(1)),
                            Expr::add(Expr::v("x"), Expr::c(1)),
                        ],
                    ),
                ]),
                2,
            ),
        );
        Program {
            name: "brighten_blur".into(),
            inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
            funcs: vec![brighten, blur],
            schedule: HwSchedule::new([63, 63]).store_at("brighten"),
        }
    }

    #[test]
    fn validate_ok() {
        brighten_blur().validate().unwrap();
    }

    #[test]
    fn validate_rejects_undefined_buffer() {
        let mut p = brighten_blur();
        p.funcs[1].body = Expr::ld("nonexistent", vec![Expr::v("y"), Expr::v("x")]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn accel_output_respects_host_stages() {
        let mut p = brighten_blur();
        assert_eq!(p.accel_output().name, "blur");
        p.schedule.host_stages.push("blur".into());
        assert_eq!(p.accel_output().name, "brighten");
    }

    #[test]
    fn reduce_fn_shape() {
        let f = Func::reduce_fn(
            "conv",
            &["y", "x"],
            Expr::c(0),
            &[("ry", 0, 3), ("rx", 0, 3)],
            Expr::add(
                Expr::ld("conv", vec![Expr::v("y"), Expr::v("x")]),
                Expr::mul(
                    Expr::ld(
                        "in",
                        vec![
                            Expr::add(Expr::v("y"), Expr::v("ry")),
                            Expr::add(Expr::v("x"), Expr::v("rx")),
                        ],
                    ),
                    Expr::ld("w", vec![Expr::v("ry"), Expr::v("rx")]),
                ),
            ),
        );
        let r = f.reduction.as_ref().unwrap();
        assert_eq!(r.rdom.len(), 2);
        assert_eq!(r.rdom[0], ("ry".to_string(), 0, 3));
    }
}
