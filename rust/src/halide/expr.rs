//! Integer expressions for compute kernels.

use std::collections::BTreeMap;
use std::fmt;

use crate::poly::{Affine, AffineMap};

/// Binary operators available on the CGRA's ALU-based processing
/// elements. Comparison operators produce 0/1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Min,
    Max,
    Shl,
    Shr,
    And,
    Or,
    Xor,
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

/// Unary operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Abs,
}

/// A compute-kernel expression. Loads reference either an input image or
/// another Func's buffer by name; loop iterators appear as [`Expr::Var`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Expr {
    Const(i32),
    Var(String),
    /// `Load(buffer, indices)` — indices listed **outermost-first**, to
    /// match [`crate::poly::BoxSet`] dim order.
    Load(String, Vec<Expr>),
    Binary(BinOp, Box<Expr>, Box<Expr>),
    Unary(UnOp, Box<Expr>),
    /// `Select(cond, then, else)` — cond is any expression, nonzero = true.
    Select(Box<Expr>, Box<Expr>, Box<Expr>),
}

/// Shorthand constructors used by the app definitions.
impl Expr {
    pub fn c(v: i32) -> Expr {
        Expr::Const(v)
    }
    pub fn v(name: impl Into<String>) -> Expr {
        Expr::Var(name.into())
    }
    pub fn ld(buf: impl Into<String>, idx: Vec<Expr>) -> Expr {
        Expr::Load(buf.into(), idx)
    }
    pub fn bin(op: BinOp, a: Expr, b: Expr) -> Expr {
        Expr::Binary(op, Box::new(a), Box::new(b))
    }
    pub fn add(a: Expr, b: Expr) -> Expr {
        Expr::bin(BinOp::Add, a, b)
    }
    pub fn sub(a: Expr, b: Expr) -> Expr {
        Expr::bin(BinOp::Sub, a, b)
    }
    pub fn mul(a: Expr, b: Expr) -> Expr {
        Expr::bin(BinOp::Mul, a, b)
    }
    pub fn min(a: Expr, b: Expr) -> Expr {
        Expr::bin(BinOp::Min, a, b)
    }
    pub fn max(a: Expr, b: Expr) -> Expr {
        Expr::bin(BinOp::Max, a, b)
    }
    /// Arithmetic shift right (used for power-of-two normalization so the
    /// golden models stay division-free).
    pub fn shr(a: Expr, k: i32) -> Expr {
        Expr::bin(BinOp::Shr, a, Expr::c(k))
    }
    pub fn abs(a: Expr) -> Expr {
        Expr::Unary(UnOp::Abs, Box::new(a))
    }
    pub fn neg(a: Expr) -> Expr {
        Expr::Unary(UnOp::Neg, Box::new(a))
    }
    pub fn select(c: Expr, t: Expr, e: Expr) -> Expr {
        Expr::Select(Box::new(c), Box::new(t), Box::new(e))
    }
    pub fn clamp(a: Expr, lo: i32, hi: i32) -> Expr {
        Expr::min(Expr::max(a, Expr::c(lo)), Expr::c(hi))
    }
    /// Sum of a non-empty list of terms (left-assoc).
    pub fn sum(terms: Vec<Expr>) -> Expr {
        let mut it = terms.into_iter();
        let first = it.next().expect("sum of empty list");
        it.fold(first, Expr::add)
    }
}

pub fn eval_binop(op: BinOp, a: i32, b: i32) -> i32 {
    match op {
        BinOp::Add => a.wrapping_add(b),
        BinOp::Sub => a.wrapping_sub(b),
        BinOp::Mul => a.wrapping_mul(b),
        BinOp::Div => {
            // Halide/JAX-style round-toward-negative-infinity division so
            // the golden XLA models (lax.div is trunc; we avoid Div in
            // accelerated kernels anyway) and the simulator agree.
            if b == 0 {
                0
            } else {
                a.div_euclid(b)
            }
        }
        BinOp::Mod => {
            if b == 0 {
                0
            } else {
                a.rem_euclid(b)
            }
        }
        BinOp::Min => a.min(b),
        BinOp::Max => a.max(b),
        BinOp::Shl => a.wrapping_shl(b as u32),
        BinOp::Shr => a.wrapping_shr(b as u32),
        BinOp::And => a & b,
        BinOp::Or => a | b,
        BinOp::Xor => a ^ b,
        BinOp::Lt => (a < b) as i32,
        BinOp::Le => (a <= b) as i32,
        BinOp::Gt => (a > b) as i32,
        BinOp::Ge => (a >= b) as i32,
        BinOp::Eq => (a == b) as i32,
        BinOp::Ne => (a != b) as i32,
    }
}

impl Expr {
    /// Evaluate with loop-iterator bindings and a load callback.
    pub fn eval(
        &self,
        vars: &BTreeMap<String, i64>,
        load: &mut dyn FnMut(&str, &[i64]) -> i32,
    ) -> i32 {
        match self {
            Expr::Const(v) => *v,
            Expr::Var(n) => *vars
                .get(n)
                .unwrap_or_else(|| panic!("unbound iterator {n}"))
                as i32,
            Expr::Load(buf, idx) => {
                let pt: Vec<i64> = idx.iter().map(|e| e.eval(vars, load) as i64).collect();
                load(buf, &pt)
            }
            Expr::Binary(op, a, b) => eval_binop(*op, a.eval(vars, load), b.eval(vars, load)),
            Expr::Unary(op, a) => {
                let v = a.eval(vars, load);
                match op {
                    UnOp::Neg => v.wrapping_neg(),
                    UnOp::Abs => v.wrapping_abs(),
                }
            }
            Expr::Select(c, t, e) => {
                if c.eval(vars, load) != 0 {
                    t.eval(vars, load)
                } else {
                    e.eval(vars, load)
                }
            }
        }
    }

    /// Substitute loop variables with expressions (used by unrolling and
    /// inlining). Variables not in `subst` are left untouched.
    pub fn substitute(&self, subst: &BTreeMap<String, Expr>) -> Expr {
        match self {
            Expr::Const(_) => self.clone(),
            Expr::Var(n) => subst.get(n).cloned().unwrap_or_else(|| self.clone()),
            Expr::Load(buf, idx) => Expr::Load(
                buf.clone(),
                idx.iter().map(|e| e.substitute(subst)).collect(),
            ),
            Expr::Binary(op, a, b) => {
                Expr::bin(*op, a.substitute(subst), b.substitute(subst))
            }
            Expr::Unary(op, a) => Expr::Unary(*op, Box::new(a.substitute(subst))),
            Expr::Select(c, t, e) => Expr::select(
                c.substitute(subst),
                t.substitute(subst),
                e.substitute(subst),
            ),
        }
    }

    /// Replace every `Load(buf, idx)` where `buf == name` with
    /// `body[vars := idx]` — functional inlining (recompute-at-use).
    pub fn inline_calls(&self, name: &str, vars: &[String], body: &Expr) -> Expr {
        match self {
            Expr::Const(_) | Expr::Var(_) => self.clone(),
            Expr::Load(buf, idx) => {
                let idx: Vec<Expr> =
                    idx.iter().map(|e| e.inline_calls(name, vars, body)).collect();
                if buf == name {
                    assert_eq!(idx.len(), vars.len(), "inline arity mismatch for {name}");
                    let subst: BTreeMap<String, Expr> =
                        vars.iter().cloned().zip(idx).collect();
                    body.substitute(&subst)
                } else {
                    Expr::Load(buf.clone(), idx)
                }
            }
            Expr::Binary(op, a, b) => Expr::bin(
                *op,
                a.inline_calls(name, vars, body),
                b.inline_calls(name, vars, body),
            ),
            Expr::Unary(op, a) => Expr::Unary(*op, Box::new(a.inline_calls(name, vars, body))),
            Expr::Select(c, t, e) => Expr::select(
                c.inline_calls(name, vars, body),
                t.inline_calls(name, vars, body),
                e.inline_calls(name, vars, body),
            ),
        }
    }

    /// Collect `(buffer, indices)` of every load, in evaluation order.
    pub fn loads(&self) -> Vec<(String, Vec<Expr>)> {
        let mut out = Vec::new();
        self.visit(&mut |e| {
            if let Expr::Load(buf, idx) = e {
                out.push((buf.clone(), idx.clone()));
            }
        });
        out
    }

    fn visit(&self, f: &mut dyn FnMut(&Expr)) {
        f(self);
        match self {
            Expr::Const(_) | Expr::Var(_) => {}
            Expr::Load(_, idx) => idx.iter().for_each(|e| e.visit(f)),
            Expr::Binary(_, a, b) => {
                a.visit(f);
                b.visit(f);
            }
            Expr::Unary(_, a) => a.visit(f),
            Expr::Select(c, t, e) => {
                c.visit(f);
                t.visit(f);
                e.visit(f);
            }
        }
    }

    /// Number of ALU operations (binary + unary + select nodes),
    /// excluding address arithmetic inside load indices (which maps to
    /// the memory tiles' address generators). This is the PE-count
    /// estimate: each op maps to one 16-bit ALU PE (§VI).
    pub fn op_count(&self) -> usize {
        match self {
            Expr::Const(_) | Expr::Var(_) | Expr::Load(_, _) => 0,
            Expr::Binary(_, a, b) => 1 + a.op_count() + b.op_count(),
            Expr::Unary(_, a) => 1 + a.op_count(),
            Expr::Select(c, t, e) => 1 + c.op_count() + t.op_count() + e.op_count(),
        }
    }

    /// Depth of the ALU-op tree on the critical path: the pipeline
    /// latency (in cycles) of the kernel when each op takes one cycle.
    /// Leaves (constants, vars, loads) contribute 0.
    pub fn depth(&self) -> i64 {
        match self {
            Expr::Const(_) | Expr::Var(_) => 0,
            // Index arithmetic is address generation (the AGs), not the
            // PE datapath: a load is a leaf.
            Expr::Load(_, _) => 0,
            Expr::Binary(_, a, b) => 1 + a.depth().max(b.depth()),
            Expr::Unary(_, a) => 1 + a.depth(),
            Expr::Select(c, t, e) => 1 + c.depth().max(t.depth()).max(e.depth()),
        }
    }

    /// Extract this index expression as an [`Affine`] over the loop
    /// iterators `dims` (outermost-first). Returns `None` for non-affine
    /// indices — which the physical address generators cannot implement,
    /// so lowering rejects them.
    pub fn as_affine(&self, dims: &[String]) -> Option<Affine> {
        let rank = dims.len();
        match self {
            Expr::Const(v) => Some(Affine::constant(rank, *v as i64)),
            Expr::Var(n) => dims
                .iter()
                .position(|d| d == n)
                .map(|k| Affine::var(rank, k)),
            Expr::Binary(BinOp::Add, a, b) => {
                Some(a.as_affine(dims)?.add(&b.as_affine(dims)?))
            }
            Expr::Binary(BinOp::Sub, a, b) => {
                Some(a.as_affine(dims)?.sub(&b.as_affine(dims)?))
            }
            Expr::Binary(BinOp::Mul, a, b) => {
                let (fa, fb) = (a.as_affine(dims)?, b.as_affine(dims)?);
                if fa.is_constant() {
                    Some(fb.scale(fa.offset))
                } else if fb.is_constant() {
                    Some(fa.scale(fb.offset))
                } else {
                    None
                }
            }
            _ => None,
        }
    }

    /// Extract a full access map from `Load` indices.
    pub fn load_affine_map(idx: &[Expr], dims: &[String]) -> Option<AffineMap> {
        let outs: Option<Vec<Affine>> = idx.iter().map(|e| e.as_affine(dims)).collect();
        Some(AffineMap::new(dims.len(), outs?))
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Const(v) => write!(f, "{v}"),
            Expr::Var(n) => write!(f, "{n}"),
            Expr::Load(b, idx) => {
                write!(f, "{b}(")?;
                for (k, e) in idx.iter().enumerate() {
                    if k > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{e}")?;
                }
                write!(f, ")")
            }
            Expr::Binary(op, a, b) => write!(f, "({a} {op:?} {b})"),
            Expr::Unary(op, a) => write!(f, "{op:?}({a})"),
            Expr::Select(c, t, e) => write!(f, "select({c}, {t}, {e})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vars(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn eval_arith() {
        // brighten(x, y) = min(2 * input(x, y), 255)
        let e = Expr::min(
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
            Expr::c(255),
        );
        let mut load = |_: &str, p: &[i64]| (p[0] * 10 + p[1]) as i32;
        assert_eq!(e.eval(&vars(&[("x", 3), ("y", 2)]), &mut load), 46);
        assert_eq!(e.eval(&vars(&[("x", 9), ("y", 20)]), &mut load), 255);
    }

    #[test]
    fn eval_select_and_unary() {
        let e = Expr::select(
            Expr::bin(BinOp::Lt, Expr::v("x"), Expr::c(0)),
            Expr::neg(Expr::v("x")),
            Expr::abs(Expr::sub(Expr::v("x"), Expr::c(10))),
        );
        let mut no_load = |_: &str, _: &[i64]| 0;
        assert_eq!(e.eval(&vars(&[("x", -4)]), &mut no_load), 4);
        assert_eq!(e.eval(&vars(&[("x", 3)]), &mut no_load), 7);
    }

    #[test]
    fn floor_division_semantics() {
        assert_eq!(eval_binop(BinOp::Div, -3, 2), -2);
        assert_eq!(eval_binop(BinOp::Mod, -3, 2), 1);
        assert_eq!(eval_binop(BinOp::Div, 7, 2), 3);
    }

    #[test]
    fn substitute_unroll_style() {
        // x -> 2*xo + 1 (the odd unrolled copy).
        let e = Expr::ld("f", vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))]);
        let subst: BTreeMap<String, Expr> = [(
            "x".to_string(),
            Expr::add(Expr::mul(Expr::c(2), Expr::v("xo")), Expr::c(1)),
        )]
        .into();
        let e2 = e.substitute(&subst);
        let mut last = Vec::new();
        let mut load = |_: &str, p: &[i64]| {
            last = p.to_vec();
            0
        };
        e2.eval(&vars(&[("xo", 5), ("y", 0)]), &mut load);
        assert_eq!(last, vec![0, 12]);
    }

    #[test]
    fn inline_recompute() {
        // g(x) = f(x) + f(x+1) with f(x) = 2*in(x) inlined:
        // g(x) = 2*in(x) + 2*in(x+1).
        let f_body = Expr::mul(Expr::c(2), Expr::ld("in", vec![Expr::v("x")]));
        let g = Expr::add(
            Expr::ld("f", vec![Expr::v("x")]),
            Expr::ld("f", vec![Expr::add(Expr::v("x"), Expr::c(1))]),
        );
        let inlined = g.inline_calls("f", &["x".to_string()], &f_body);
        let mut load = |_: &str, p: &[i64]| p[0] as i32;
        assert_eq!(inlined.eval(&vars(&[("x", 10)]), &mut load), 2 * 10 + 2 * 11);
        // No f loads remain.
        assert!(inlined.loads().iter().all(|(b, _)| b == "in"));
    }

    #[test]
    fn op_count_counts_alus() {
        let e = Expr::min(
            Expr::mul(Expr::c(2), Expr::ld("i", vec![Expr::v("x")])),
            Expr::c(255),
        );
        assert_eq!(e.op_count(), 2); // mul + min
    }

    #[test]
    fn affine_extraction() {
        let dims = vec!["y".to_string(), "x".to_string()];
        // x + 1 over (y, x).
        let e = Expr::add(Expr::v("x"), Expr::c(1));
        assert_eq!(e.as_affine(&dims), Some(Affine::new(vec![0, 1], 1)));
        // 2*y - x.
        let e = Expr::sub(Expr::mul(Expr::c(2), Expr::v("y")), Expr::v("x"));
        assert_eq!(e.as_affine(&dims), Some(Affine::new(vec![2, -1], 0)));
        // x*y is not affine.
        let e = Expr::mul(Expr::v("x"), Expr::v("y"));
        assert_eq!(e.as_affine(&dims), None);
        // An unknown var is not affine over these dims.
        assert_eq!(Expr::v("z").as_affine(&dims), None);
    }

    #[test]
    fn load_affine_map_extraction() {
        let dims = vec!["y".to_string(), "x".to_string()];
        let idx = vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))];
        let m = Expr::load_affine_map(&idx, &dims).unwrap();
        assert_eq!(m.apply(&[3, 7]), vec![3, 8]);
    }

    #[test]
    fn sum_builder() {
        let e = Expr::sum(vec![Expr::c(1), Expr::c(2), Expr::c(3)]);
        let mut no_load = |_: &str, _: &[i64]| 0;
        assert_eq!(e.eval(&BTreeMap::new(), &mut no_load), 6);
        assert_eq!(e.op_count(), 2);
    }
}
