//! The paper's hardware scheduling directives (§V-A).
//!
//! Halide separates algorithm from schedule; the paper extends the
//! scheduling language with `hw_accelerate` / `stream_to_accelerator`
//! and reuses `tile`, `store_at`/`compute_at` and `unroll` to control
//! what becomes a push memory versus what is fused (recomputed), and
//! which loops are parallelized in space.

use std::collections::BTreeMap;

/// The scheduling decisions for one program, mirroring the directives in
/// Fig 1 and §VI-C of the paper:
///
/// * `tile`        — the accelerator output-tile extents (`hw_accelerate`
///   operates on one tile; the global buffer streams tiles, Fig 12).
/// * `store_at`    — funcs materialized as unified buffers; every other
///   intermediate func is **inlined** into its consumers (recomputed),
///   which is how sch1 "recompute all" vs sch3 "no recompute" of
///   Table V arise.
/// * `unroll`      — spatial unrolling of a pure loop by a factor
///   (sch4 "unroll by 2": two output pixels per cycle).
/// * `unroll_reduction` — fully unroll a func's reduction loops; if every
///   reduction is fully unrolled the scheduler uses the *stencil* policy,
///   otherwise the *DNN* policy (§V-B).
/// * `host_stages` — funcs excluded from the accelerator and run on the
///   host CPU (sch6 "last stage on CPU").
#[derive(Clone, Debug, Default)]
pub struct HwSchedule {
    /// Output tile extents, outermost-first, matching the output func's
    /// pure vars.
    pub tile: Vec<i64>,
    /// Funcs given dedicated storage (`store_at` the tile loop).
    pub memories: Vec<String>,
    /// `func -> [(var, factor)]` spatial unrolling.
    pub unroll: BTreeMap<String, Vec<(String, i64)>>,
    /// Funcs whose reduction domain is fully unrolled in space.
    pub unroll_reductions: Vec<String>,
    /// Funcs computed on the host instead of the accelerator.
    pub host_stages: Vec<String>,
}

impl HwSchedule {
    pub fn new(tile: impl Into<Vec<i64>>) -> Self {
        HwSchedule { tile: tile.into(), ..Default::default() }
    }

    /// `f.store_at(output, tile_loop)` — materialize `f` as a unified
    /// buffer rather than recomputing it at each use.
    pub fn store_at(mut self, func: &str) -> Self {
        if !self.memories.iter().any(|m| m == func) {
            self.memories.push(func.to_string());
        }
        self
    }

    /// `f.unroll(var, factor)` — compute `factor` instances of `var`'s
    /// loop body in parallel each cycle.
    pub fn unroll(mut self, func: &str, var: &str, factor: i64) -> Self {
        assert!(factor >= 2, "unroll factor must be >= 2");
        self.unroll
            .entry(func.to_string())
            .or_default()
            .push((var.to_string(), factor));
        self
    }

    /// Fully unroll `func`'s reduction loops (stencil-style conv).
    pub fn unroll_reduction(mut self, func: &str) -> Self {
        if !self.unroll_reductions.iter().any(|m| m == func) {
            self.unroll_reductions.push(func.to_string());
        }
        self
    }

    /// Run `func` on the host processor (outside `hw_accelerate`).
    pub fn on_host(mut self, func: &str) -> Self {
        if !self.host_stages.iter().any(|m| m == func) {
            self.host_stages.push(func.to_string());
        }
        self
    }

    /// Check the schedule against the program's func names before any
    /// directive is consumed: every tile extent positive, every unroll
    /// factor ≥ 2, and every func named by `memories` / `unroll` /
    /// `unroll_reductions` / `host_stages` actually defined. Runs at
    /// the top of lowering (where the `HwSchedule` is still in scope —
    /// `sched::schedule` re-checks the tile it inherits), so an
    /// auto-generated candidate schedule fails with a message instead
    /// of a deep internal error.
    pub fn validate(&self, funcs: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(!self.tile.is_empty(), "schedule has an empty tile");
        for (k, &e) in self.tile.iter().enumerate() {
            anyhow::ensure!(e >= 1, "tile extent {e} at dim {k} must be >= 1");
        }
        let known = |n: &String| funcs.contains(n);
        for m in &self.memories {
            anyhow::ensure!(known(m), "store_at of unknown func {m:?}");
        }
        for h in &self.host_stages {
            anyhow::ensure!(known(h), "host stage is an unknown func {h:?}");
        }
        for r in &self.unroll_reductions {
            anyhow::ensure!(known(r), "unroll_reduction of unknown func {r:?}");
        }
        for (f, entries) in &self.unroll {
            anyhow::ensure!(known(f), "unroll of unknown func {f:?}");
            for (var, factor) in entries {
                anyhow::ensure!(!var.is_empty(), "unroll of {f:?}: empty var name");
                anyhow::ensure!(
                    *factor >= 2,
                    "unroll({f}, {var}, {factor}): factor must be >= 2"
                );
            }
        }
        if !funcs.is_empty() {
            anyhow::ensure!(
                funcs.iter().any(|f| !self.host_stages.contains(f)),
                "every func is scheduled on the host; nothing remains to accelerate"
            );
        }
        Ok(())
    }

    pub fn is_memory(&self, func: &str) -> bool {
        self.memories.iter().any(|m| m == func)
    }

    pub fn is_reduction_unrolled(&self, func: &str) -> bool {
        self.unroll_reductions.iter().any(|m| m == func)
    }

    pub fn unroll_factors(&self, func: &str) -> &[(String, i64)] {
        self.unroll.get(func).map(Vec::as_slice).unwrap_or(&[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains() {
        let s = HwSchedule::new([64, 64])
            .store_at("brighten")
            .unroll("blur", "x", 2)
            .unroll_reduction("conv")
            .on_host("final");
        assert_eq!(s.tile, vec![64, 64]);
        assert!(s.is_memory("brighten"));
        assert!(!s.is_memory("blur"));
        assert_eq!(s.unroll_factors("blur"), &[("x".to_string(), 2)]);
        assert!(s.is_reduction_unrolled("conv"));
        assert!(s.host_stages.contains(&"final".to_string()));
    }

    #[test]
    fn store_at_idempotent() {
        let s = HwSchedule::new([8]).store_at("f").store_at("f");
        assert_eq!(s.memories.len(), 1);
    }

    #[test]
    #[should_panic]
    fn unroll_factor_one_rejected() {
        let _ = HwSchedule::new([8]).unroll("f", "x", 1);
    }

    fn funcs(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn validate_accepts_well_formed() {
        let s = HwSchedule::new([8, 8])
            .store_at("a")
            .unroll("b", "x", 2)
            .unroll_reduction("c")
            .on_host("d");
        s.validate(&funcs(&["a", "b", "c", "d"])).unwrap();
    }

    #[test]
    fn validate_rejects_empty_tile() {
        let s = HwSchedule::default();
        assert!(s.validate(&funcs(&["f"])).is_err());
    }

    #[test]
    fn validate_rejects_nonpositive_tile_extent() {
        for bad in [0, -4] {
            let s = HwSchedule::new([8, bad]);
            let e = s.validate(&funcs(&["f"])).unwrap_err();
            assert!(e.to_string().contains("tile extent"), "{e}");
        }
    }

    #[test]
    fn validate_rejects_unknown_memory_func() {
        let s = HwSchedule::new([8]).store_at("ghost");
        let e = s.validate(&funcs(&["f"])).unwrap_err();
        assert!(e.to_string().contains("store_at"), "{e}");
    }

    #[test]
    fn validate_rejects_unknown_host_func() {
        let s = HwSchedule::new([8]).on_host("ghost");
        assert!(s.validate(&funcs(&["f"])).is_err());
    }

    #[test]
    fn validate_rejects_unknown_unroll_func() {
        let s = HwSchedule::new([8]).unroll("ghost", "x", 2);
        assert!(s.validate(&funcs(&["f"])).is_err());
    }

    #[test]
    fn validate_rejects_unknown_unroll_reduction_func() {
        let s = HwSchedule::new([8]).unroll_reduction("ghost");
        assert!(s.validate(&funcs(&["f"])).is_err());
    }

    #[test]
    fn validate_rejects_unroll_factor_below_two() {
        // The builder panics on factor < 2; a hand-assembled schedule
        // (what a tuner or a deserializer produces) must be caught by
        // validate instead.
        let mut s = HwSchedule::new([8]);
        s.unroll.insert("f".into(), vec![("x".into(), 1)]);
        let e = s.validate(&funcs(&["f"])).unwrap_err();
        assert!(e.to_string().contains("factor"), "{e}");
    }

    #[test]
    fn validate_rejects_everything_on_host() {
        let s = HwSchedule::new([8]).on_host("f").on_host("g");
        let e = s.validate(&funcs(&["f", "g"])).unwrap_err();
        assert!(e.to_string().contains("host"), "{e}");
    }
}
