//! A mini-Halide frontend.
//!
//! The paper's compiler consumes *scheduled Halide IR* — loop nests after
//! Halide's scheduling directives (`tile`, `unroll`, `compute_at`,
//! `store_at`) plus the paper's accelerator extensions (`hw_accelerate`,
//! `stream_to_accelerator`) have been applied (§II, §V-A). This module is
//! a from-scratch embedded DSL producing exactly that IR:
//!
//! * [`expr::Expr`] — 32-bit integer expressions (the CGRA models 16-bit
//!   ALUs for cost purposes; we compute in i32 so the golden JAX models
//!   match bit-exactly without incidental overflow differences).
//! * [`func::Func`] / [`func::Program`] — pure and reduction stages.
//! * [`schedule::HwSchedule`] — the paper's scheduling directives.
//! * [`bounds`] — Halide-style interval bounds inference.
//! * [`lower`] — inlining (recompute), unrolling, and lowering to
//!   [`lower::LoweredStage`]s that buffer extraction consumes.
//!
//! Quasi-affine accesses (upsample's `x/2`, demosaic's `x%2`) are written
//! in pre-strip-mined form (e.g. iterate `(xo, xi)` with `x = 2*xo + xi`)
//! so every access map stays strictly affine, as the physical address
//! generators require (§IV-A).

pub mod bounds;
pub mod expr;
pub mod func;
pub mod lower;
pub mod schedule;

pub use expr::{BinOp, Expr, UnOp};
pub use func::{Func, InputDecl, Program, Reduction};
pub use lower::{LoweredPipeline, LoweredStage, StageInstance};
pub use schedule::HwSchedule;
