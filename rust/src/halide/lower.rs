//! Lowering: inlining, reduction unrolling, spatial unrolling, and
//! production of the scheduled loop IR that buffer extraction consumes.
//!
//! This is the "scheduling" step of Fig 1: after it, every materialized
//! func is a [`LoweredStage`] — a loop nest with affine store/load access
//! maps and a compute-kernel expression — and every non-materialized func
//! has been inlined into its consumers (recomputed per use).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::bounds::{self, StageDef};
use super::expr::Expr;
use super::func::{Func, Program};
use crate::poly::set::{BoxSet, Dim};
use crate::poly::AffineMap;
use crate::tensor::Tensor;

/// One spatial copy of a stage's compute kernel. Unrolling a loop by `u`
/// yields `u` instances issuing in the same cycle; each instance carries
/// its own store map and load ports (§V-A `unroll`).
#[derive(Clone, Debug)]
pub struct StageInstance {
    /// Buffer coordinates written, as an affine map over the stage's
    /// full (pure x reduction) domain.
    pub store: AffineMap,
    /// Distinct `(buffer, access map)` load ports over the full domain.
    pub loads: Vec<(String, AffineMap)>,
    /// The kernel expression (loads still symbolic).
    pub kernel: Expr,
}

/// A materialized func lowered to a loop nest.
#[derive(Clone, Debug)]
pub struct LoweredStage {
    pub name: String,
    /// Pure loop domain (absolute coordinates, outermost-first).
    pub pure_domain: BoxSet,
    /// Reduction loop domain, iterated innermost of the pure loops;
    /// empty rank for non-reduction stages.
    pub rdom: BoxSet,
    pub instances: Vec<StageInstance>,
}

impl LoweredStage {
    /// The full compute domain: pure dims then reduction dims.
    pub fn full_domain(&self) -> BoxSet {
        self.pure_domain.product(&self.rdom)
    }

    pub fn is_reduction(&self) -> bool {
        self.rdom.rank() > 0
    }

    /// ALU-op estimate: each arithmetic node of every instance maps to
    /// one PE (§VI, Table IV/V PE counts).
    pub fn alu_ops(&self) -> usize {
        self.instances.iter().map(|i| i.kernel.op_count()).sum()
    }
}

/// The whole pipeline after lowering.
#[derive(Clone, Debug)]
pub struct LoweredPipeline {
    pub name: String,
    /// Topological order; the last stage produces the accelerator output.
    pub stages: Vec<LoweredStage>,
    /// Realization box of every materialized buffer and streamed input.
    pub buffers: BTreeMap<String, BoxSet>,
    pub inputs: Vec<String>,
    pub output: String,
    pub tile: Vec<i64>,
    /// Funcs scheduled on the host CPU (evaluated by the coordinator).
    pub host_funcs: Vec<Func>,
    /// The post-inlining stage definitions bounds inference ran over —
    /// kept so consumers can re-range the same access structure at a
    /// *different* output box ([`LoweredPipeline::footprint`]; the
    /// tile planner's halo math, docs/tiling.md).
    pub stage_defs: Vec<bounds::StageDef>,
    /// The unroll round-up directives that accompanied inference
    /// (`func -> [(var, factor)]`), so re-ranging reproduces the
    /// exact halos the compiled design was built with.
    pub rounding: BTreeMap<String, Vec<(String, i64)>>,
}

/// Fully unroll a reduction func into a pure expression: repeatedly
/// substitute the reduction step, replacing the accumulator reference
/// with the running expression and reduction iterators with constants.
fn unroll_reduction(f: &Func) -> Result<Expr> {
    let r = f.reduction.as_ref().context("not a reduction")?;
    let rdom_box = BoxSet::new(
        r.rdom
            .iter()
            .map(|(n, m, e)| Dim::new(n.clone(), *m, *e))
            .collect(),
    );
    let mut acc = r.init.clone();
    for pt in rdom_box.points() {
        let mut subst: BTreeMap<String, Expr> = r
            .rdom
            .iter()
            .zip(&pt)
            .map(|((n, _, _), &v)| (n.clone(), Expr::c(v as i32)))
            .collect();
        // Accumulator: self-load at the pure vars.
        let step = r.update.substitute(&subst);
        subst.clear();
        acc = step.inline_calls(&f.name, &f.vars, &acc);
    }
    Ok(acc)
}

/// Extract the distinct load ports of `kernel` over `dims`
/// (outermost-first), skipping accumulator self-references.
fn extract_loads(kernel: &Expr, dims: &[String], self_name: &str) -> Result<Vec<(String, AffineMap)>> {
    let mut out: Vec<(String, AffineMap)> = Vec::new();
    for (buf, idx) in kernel.loads() {
        if buf == self_name {
            continue;
        }
        let map = Expr::load_affine_map(&idx, dims)
            .with_context(|| format!("non-affine access to {buf} in {self_name}"))?;
        if !out.iter().any(|(b, m)| *b == buf && *m == map) {
            out.push((buf, map));
        }
    }
    Ok(out)
}

/// Lower a program to stages (Fig 1 "scheduling" output).
pub fn lower(program: &Program) -> Result<LoweredPipeline> {
    program.validate()?;
    let func_names: Vec<String> = program.funcs.iter().map(|f| f.name.clone()).collect();
    program
        .schedule
        .validate(&func_names)
        .with_context(|| format!("{}: schedule validation", program.name))?;
    let sched = &program.schedule;

    // Partition host stages off the accelerator (sch6 of Table V).
    let host_funcs: Vec<Func> = program
        .funcs
        .iter()
        .filter(|f| sched.host_stages.contains(&f.name))
        .cloned()
        .collect();
    let accel_funcs: Vec<&Func> = program
        .funcs
        .iter()
        .filter(|f| !sched.host_stages.contains(&f.name))
        .collect();
    let output = accel_funcs.last().context("no accelerator funcs")?.name.clone();

    // A func is materialized (gets a unified buffer) iff store_at'd, is
    // the output, or carries a non-unrolled reduction (which cannot be
    // recomputed per use).
    let materialized = |f: &Func| -> bool {
        sched.is_memory(&f.name)
            || f.name == output
            || (f.reduction.is_some() && !sched.is_reduction_unrolled(&f.name))
    };

    // Inline pass: walk in topological order, keeping the current
    // (already fully inlined) body of every non-materialized func and
    // substituting it into each later consumer.
    let mut inlined_bodies: Vec<(String, Vec<String>, Expr)> = Vec::new();
    let mut stage_defs: Vec<StageDef> = Vec::new();
    for f in &accel_funcs {
        // Resolve this func's kernel expression.
        let mut kernel = if let Some(r) = &f.reduction {
            if sched.is_reduction_unrolled(&f.name) {
                unroll_reduction(f)?
            } else {
                r.update.clone()
            }
        } else {
            f.body.clone()
        };
        // Substitute all previously inlined producers (repeat until no
        // producer loads remain — inlined bodies may reference other
        // inlined funcs).
        loop {
            let mut changed = false;
            for (name, vars, body) in &inlined_bodies {
                if kernel.loads().iter().any(|(b, _)| b == name) {
                    kernel = kernel.inline_calls(name, vars, body);
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        if materialized(f) {
            let rdom = if f.reduction.is_some() && !sched.is_reduction_unrolled(&f.name) {
                f.reduction.as_ref().unwrap().rdom.clone()
            } else {
                vec![]
            };
            stage_defs.push(StageDef {
                name: f.name.clone(),
                vars: f.vars.clone(),
                rdom,
                kernel,
            });
        } else {
            inlined_bodies.push((f.name.clone(), f.vars.clone(), kernel));
        }
    }

    // Bounds inference over the materialized graph (unrolled dims are
    // rounded up to a factor multiple, growing producer halos).
    let required = bounds::infer(&stage_defs, &sched.tile, &sched.unroll)?;
    let mut buffers: BTreeMap<String, BoxSet> = BTreeMap::new();
    for s in &stage_defs {
        buffers.insert(
            s.name.clone(),
            bounds::intervals_to_box(&s.vars, &required[&s.name]),
        );
    }
    let mut inputs = Vec::new();
    for inp in &program.inputs {
        if let Some(iv) = required.get(&inp.name) {
            anyhow::ensure!(iv.len() == inp.rank, "input {} rank mismatch", inp.name);
            let names: Vec<String> = (0..inp.rank).map(|k| format!("i{k}")).collect();
            buffers.insert(inp.name.clone(), bounds::intervals_to_box(&names, iv));
            inputs.push(inp.name.clone());
        }
    }

    // Emit lowered stages, applying spatial unrolling.
    let mut stages = Vec::new();
    for def in &stage_defs {
        let mut pure_domain = buffers[&def.name].clone();
        let rdom = BoxSet::new(
            def.rdom
                .iter()
                .map(|(n, m, e)| Dim::new(n.clone(), *m, *e))
                .collect(),
        );
        // Base instance: identity store over pure dims.
        let all_dims: Vec<String> = pure_domain
            .dims
            .iter()
            .map(|d| d.name.clone())
            .chain(rdom.dims.iter().map(|d| d.name.clone()))
            .collect();
        let store_idx: Vec<Expr> = def.vars.iter().map(Expr::v).collect();
        let mut insts: Vec<(Vec<Expr>, Expr)> = vec![(store_idx, def.kernel.clone())];

        // Apply each unroll directive: split var v by factor u.
        for (var, factor) in sched.unroll_factors(&def.name) {
            let k = pure_domain
                .dim_index(var)
                .with_context(|| format!("unroll of unknown var {var} in {}", def.name))?;
            let d = &pure_domain.dims[k];
            anyhow::ensure!(
                d.min == 0,
                "unroll({}, {var}, {factor}): dim must start at 0, starts at {}",
                def.name,
                d.min
            );
            // Bounds inference already rounded the extent up.
            anyhow::ensure!(d.extent % factor == 0, "internal: extent not rounded");
            pure_domain.dims[k] = Dim::new(var.clone(), 0, d.extent / factor);
            let mut next = Vec::with_capacity(insts.len() * *factor as usize);
            for (sidx, kern) in &insts {
                for lane in 0..*factor {
                    let subst: BTreeMap<String, Expr> = [(
                        var.clone(),
                        Expr::add(
                            Expr::mul(Expr::c(*factor as i32), Expr::v(var.clone())),
                            Expr::c(lane as i32),
                        ),
                    )]
                    .into();
                    next.push((
                        sidx.iter().map(|e| e.substitute(&subst)).collect(),
                        kern.substitute(&subst),
                    ));
                }
            }
            insts = next;
        }

        let instances: Result<Vec<StageInstance>> = insts
            .into_iter()
            .map(|(sidx, kern)| {
                let store = Expr::load_affine_map(&sidx, &all_dims)
                    .context("non-affine store index")?
                    // Store coords ignore reduction dims (write-once per
                    // pure point at the final reduction iteration).
                    ;
                let loads = extract_loads(&kern, &all_dims, &def.name)?;
                Ok(StageInstance { store, loads, kernel: kern })
            })
            .collect();

        stages.push(LoweredStage {
            name: def.name.clone(),
            pure_domain,
            rdom,
            instances: instances?,
        });
    }

    Ok(LoweredPipeline {
        name: program.name.clone(),
        stages,
        buffers,
        inputs,
        output,
        tile: sched.tile.clone(),
        host_funcs,
        stage_defs,
        rounding: sched.unroll.clone(),
    })
}

impl LoweredPipeline {
    /// Re-run bounds inference over this pipeline's (post-inlining)
    /// stage graph with the output realized over an arbitrary absolute
    /// box `out` (`(min, max)` inclusive per output pure dim). Returns
    /// the required interval of **every** buffer — materialized stages
    /// and streamed inputs — at that placement; `out == [(0, tile-1)]`
    /// reproduces [`LoweredPipeline::buffers`] exactly. This is the
    /// halo/footprint primitive the tile planner ([`crate::tile`])
    /// uses to slice whole-image inputs per output tile
    /// (docs/tiling.md).
    pub fn footprint(&self, out: &[(i64, i64)]) -> Result<BTreeMap<String, bounds::Intervals>> {
        bounds::infer_boxes(&self.stage_defs, out, &self.rounding)
    }

    /// Reference (functional) execution: evaluate every stage over its
    /// domain in program order. This is the semantics the cycle-accurate
    /// schedule and the CGRA simulator must preserve.
    pub fn execute(&self, inputs: &BTreeMap<String, Tensor>) -> Result<BTreeMap<String, Tensor>> {
        let mut bufs: BTreeMap<String, Tensor> = BTreeMap::new();
        for name in &self.inputs {
            let t = inputs
                .get(name)
                .with_context(|| format!("missing input {name}"))?;
            anyhow::ensure!(
                t.shape == self.buffers[name],
                "input {name} shape {} != required {}",
                t.shape,
                self.buffers[name]
            );
            bufs.insert(name.clone(), t.clone());
        }
        for stage in &self.stages {
            let mut out = Tensor::zeros(self.buffers[&stage.name].clone());
            let pure_names: Vec<String> =
                stage.pure_domain.dims.iter().map(|d| d.name.clone()).collect();
            let rdom_names: Vec<String> =
                stage.rdom.dims.iter().map(|d| d.name.clone()).collect();
            for p in stage.pure_domain.points() {
                for inst in &stage.instances {
                    let mut env: BTreeMap<String, i64> =
                        pure_names.iter().cloned().zip(p.iter().cloned()).collect();
                    let mut acc: i32 = 0;
                    if stage.is_reduction() {
                        for rp in stage.rdom.points() {
                            for (n, v) in rdom_names.iter().zip(&rp) {
                                env.insert(n.clone(), *v);
                            }
                            let acc_in = acc;
                            let mut load = |buf: &str, pt: &[i64]| -> i32 {
                                if buf == stage.name {
                                    acc_in
                                } else {
                                    bufs[buf].get(pt)
                                }
                            };
                            acc = inst.kernel.eval(&env, &mut load);
                        }
                    } else {
                        let mut load = |buf: &str, pt: &[i64]| bufs[buf].get(pt);
                        acc = inst.kernel.eval(&env, &mut load);
                    }
                    // Store at the instance's (possibly unrolled) coords.
                    let full_pt: Vec<i64> = p
                        .iter()
                        .cloned()
                        .chain(stage.rdom.dims.iter().map(|d| d.min + d.extent - 1))
                        .collect();
                    let coords = inst.store.apply(&full_pt);
                    out.set(&coords, acc);
                }
            }
            bufs.insert(stage.name.clone(), out);
        }
        Ok(bufs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::halide::func::InputDecl;
    use crate::halide::schedule::HwSchedule;

    fn brighten_blur(tile: i64) -> Program {
        let brighten = Func::pure_fn(
            "brighten",
            &["y", "x"],
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
        );
        let blur = Func::pure_fn(
            "blur",
            &["y", "x"],
            Expr::shr(
                Expr::sum(vec![
                    Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                    Expr::ld(
                        "brighten",
                        vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(1)),
                            Expr::add(Expr::v("x"), Expr::c(1)),
                        ],
                    ),
                ]),
                2,
            ),
        );
        Program {
            name: "brighten_blur".into(),
            inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
            funcs: vec![brighten, blur],
            schedule: HwSchedule::new([tile, tile]).store_at("brighten"),
        }
    }

    #[test]
    fn lower_brighten_blur_structure() {
        let lp = lower(&brighten_blur(64)).unwrap();
        assert_eq!(lp.stages.len(), 2);
        assert_eq!(lp.stages[0].name, "brighten");
        assert_eq!(lp.stages[1].name, "blur");
        // brighten realization is 65x65 (blur halo).
        assert_eq!(lp.buffers["brighten"].dims[0].extent, 65);
        // blur has 4 loads of brighten (the 2x2 window, Fig 2).
        assert_eq!(lp.stages[1].instances[0].loads.len(), 4);
        assert_eq!(lp.output, "blur");
    }

    #[test]
    fn inlining_recomputes() {
        // Without store_at, brighten is inlined into blur: 1 stage, and
        // the 4 loads go straight to input with brighten's mul recomputed
        // 4 times (more PEs, fewer memories — Table V sch1 vs sch3).
        let mut p = brighten_blur(64);
        p.schedule = HwSchedule::new([64, 64]);
        let lp = lower(&p).unwrap();
        assert_eq!(lp.stages.len(), 1);
        let inst = &lp.stages[0].instances[0];
        assert!(inst.loads.iter().all(|(b, _)| b == "input"));
        assert_eq!(inst.loads.len(), 4);
        // Recompute has more ALU ops than the buffered version's blur.
        let buffered = lower(&brighten_blur(64)).unwrap();
        assert!(lp.stages[0].alu_ops() > buffered.stages[1].alu_ops());
    }

    #[test]
    fn execute_matches_scalar_reference() {
        let lp = lower(&brighten_blur(8)).unwrap();
        let in_box = lp.buffers["input"].clone();
        let input = Tensor::from_fn(in_box, |p| (p[0] * 9 + p[1]) as i32);
        let mut ins = BTreeMap::new();
        ins.insert("input".to_string(), input.clone());
        let out = &lp.execute(&ins).unwrap()["blur"];
        for y in 0..8 {
            for x in 0..8 {
                let b = |yy: i64, xx: i64| 2 * input.get(&[yy, xx]);
                let expect = (b(y, x) + b(y, x + 1) + b(y + 1, x) + b(y + 1, x + 1)) >> 2;
                assert_eq!(out.get(&[y, x]), expect, "at ({y},{x})");
            }
        }
    }

    #[test]
    fn unroll_creates_instances() {
        let mut p = brighten_blur(8);
        p.schedule = HwSchedule::new([8, 8]).store_at("brighten").unroll("blur", "x", 2);
        let lp = lower(&p).unwrap();
        let blur = &lp.stages[1];
        assert_eq!(blur.instances.len(), 2);
        assert_eq!(blur.pure_domain.dims[1].extent, 4);
        // Lane 1 stores to 2x+1.
        assert_eq!(blur.instances[1].store.apply(&[3, 2]), vec![3, 5]);
        // Execution still matches.
        let input = Tensor::from_fn(lp.buffers["input"].clone(), |p| (p[0] + 2 * p[1]) as i32);
        let mut ins = BTreeMap::new();
        ins.insert("input".to_string(), input.clone());
        let out = &lp.execute(&ins).unwrap()["blur"];
        let b = |yy: i64, xx: i64| 2 * input.get(&[yy, xx]);
        for y in 0..8 {
            for x in 0..8 {
                let expect = (b(y, x) + b(y, x + 1) + b(y + 1, x) + b(y + 1, x + 1)) >> 2;
                assert_eq!(out.get(&[y, x]), expect);
            }
        }
    }

    #[test]
    fn footprint_at_compiled_tile_reproduces_buffers() {
        let lp = lower(&brighten_blur(16)).unwrap();
        let out: Vec<(i64, i64)> = lp.tile.iter().map(|&e| (0, e - 1)).collect();
        let fp = lp.footprint(&out).unwrap();
        for (name, b) in &lp.buffers {
            let iv = &fp[name];
            assert_eq!(b.rank(), iv.len(), "{name}");
            for (d, &(lo, hi)) in b.dims.iter().zip(iv) {
                assert_eq!((d.min, d.max()), (lo, hi), "{name}/{}", d.name);
            }
        }
        // A shifted tile translates the input footprint, extent intact.
        let shifted = lp.footprint(&[(16, 31), (32, 47)]).unwrap();
        assert_eq!(shifted["input"], vec![(16, 32), (32, 48)]);
    }

    #[test]
    fn reduction_lowers_and_executes() {
        // 3x3 box filter as a non-unrolled reduction (DNN-style stage).
        let conv = Func::reduce_fn(
            "conv",
            &["y", "x"],
            Expr::c(0),
            &[("ry", 0, 3), ("rx", 0, 3)],
            Expr::add(
                Expr::ld("conv", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld(
                    "in",
                    vec![
                        Expr::add(Expr::v("y"), Expr::v("ry")),
                        Expr::add(Expr::v("x"), Expr::v("rx")),
                    ],
                ),
            ),
        );
        let p = Program {
            name: "boxf".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![conv],
            schedule: HwSchedule::new([4, 4]),
        };
        let lp = lower(&p).unwrap();
        assert!(lp.stages[0].is_reduction());
        assert_eq!(lp.stages[0].full_domain().rank(), 4);
        let input = Tensor::from_fn(lp.buffers["in"].clone(), |p| (p[0] * 6 + p[1]) as i32);
        let mut ins = BTreeMap::new();
        ins.insert("in".to_string(), input.clone());
        let out = &lp.execute(&ins).unwrap()["conv"];
        for y in 0..4 {
            for x in 0..4 {
                let mut s = 0;
                for ry in 0..3 {
                    for rx in 0..3 {
                        s += input.get(&[y + ry, x + rx]);
                    }
                }
                assert_eq!(out.get(&[y, x]), s);
            }
        }
    }

    #[test]
    fn unrolled_reduction_becomes_pure() {
        let conv = Func::reduce_fn(
            "conv",
            &["y", "x"],
            Expr::c(0),
            &[("ry", 0, 2), ("rx", 0, 2)],
            Expr::add(
                Expr::ld("conv", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld(
                    "in",
                    vec![
                        Expr::add(Expr::v("y"), Expr::v("ry")),
                        Expr::add(Expr::v("x"), Expr::v("rx")),
                    ],
                ),
            ),
        );
        let p = Program {
            name: "boxf2".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![conv],
            schedule: HwSchedule::new([4, 4]).unroll_reduction("conv"),
        };
        let lp = lower(&p).unwrap();
        assert!(!lp.stages[0].is_reduction());
        // 4 loads (the 2x2 window), all of `in`.
        assert_eq!(lp.stages[0].instances[0].loads.len(), 4);
    }

    #[test]
    fn lower_rejects_invalid_schedule() {
        // store_at of an unknown func fails in schedule validation, up
        // front, instead of surfacing as a bounds-inference oddity.
        let mut p = brighten_blur(8);
        p.schedule = p.schedule.store_at("ghost");
        let e = lower(&p).unwrap_err();
        assert!(format!("{e:#}").contains("schedule validation"), "{e:#}");
        // Non-positive tile too.
        let mut p = brighten_blur(8);
        p.schedule.tile = vec![8, 0];
        assert!(lower(&p).is_err());
    }

    #[test]
    fn host_stage_excluded() {
        let mut p = brighten_blur(8);
        p.schedule = p.schedule.on_host("blur");
        let lp = lower(&p).unwrap();
        assert_eq!(lp.output, "brighten");
        assert_eq!(lp.host_funcs.len(), 1);
        assert_eq!(lp.host_funcs[0].name, "blur");
    }
}
