//! Halide-style interval bounds inference.
//!
//! Given the accelerator output tile, walk the (post-inlining) stage
//! graph consumer-to-producer and compute the realization box required of
//! every materialized buffer and every streamed input. Because all
//! accesses are affine over box domains, interval analysis is exact here.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::expr::Expr;
use crate::poly::set::{BoxSet, Dim};

/// A func after inlining: pure iterators + optional reduction iterators
/// and the final kernel expression (self-accumulator loads removed).
#[derive(Clone, Debug)]
pub struct StageDef {
    pub name: String,
    pub vars: Vec<String>,
    pub rdom: Vec<(String, i64, i64)>,
    pub kernel: Expr,
}

impl StageDef {
    /// All iterator names, outermost-first: pure then reduction.
    pub fn all_dims(&self) -> Vec<String> {
        let mut d = self.vars.clone();
        d.extend(self.rdom.iter().map(|(n, _, _)| n.clone()));
        d
    }
}

/// `(min, max)` inclusive interval per dimension.
pub type Intervals = Vec<(i64, i64)>;

/// Infer realization intervals for every buffer referenced by `stages`
/// (which are in topological order; the last is the accelerator output
/// realized over `tile`). Returns `buffer name -> intervals`, including
/// entries for external inputs.
///
/// `rounding` maps a stage to `(var, factor)` pairs whose realized
/// extent must be a multiple of `factor` (Halide-style round-up for
/// unrolled loops); the growth propagates to producer halos because it
/// is applied before the stage's loads are ranged.
pub fn infer(
    stages: &[StageDef],
    tile: &[i64],
    rounding: &BTreeMap<String, Vec<(String, i64)>>,
) -> Result<BTreeMap<String, Intervals>> {
    let out: Intervals = tile.iter().map(|&e| (0, e - 1)).collect();
    infer_boxes(stages, &out, rounding)
}

/// [`infer`] generalized to an arbitrary *absolute* output box: the
/// output stage is realized over `out` (`(min, max)` inclusive per
/// pure dim, not necessarily starting at 0), and every producer halo
/// is ranged from there. Because every access is affine, the result
/// is exact at any position — this is the primitive the tile planner
/// ([`crate::tile`]) uses to place a compiled fixed-tile design at
/// every tile origin of an arbitrarily large image and read off each
/// input's shifted footprint (docs/tiling.md).
pub fn infer_boxes(
    stages: &[StageDef],
    out: &[(i64, i64)],
    rounding: &BTreeMap<String, Vec<(String, i64)>>,
) -> Result<BTreeMap<String, Intervals>> {
    let mut required: BTreeMap<String, Intervals> = BTreeMap::new();
    let output = stages.last().context("no stages")?;
    anyhow::ensure!(
        out.len() == output.vars.len(),
        "output box rank {} != output rank {}",
        out.len(),
        output.vars.len()
    );
    for (k, &(lo, hi)) in out.iter().enumerate() {
        anyhow::ensure!(lo <= hi, "empty output interval ({lo}, {hi}) at dim {k}");
    }
    required.insert(output.name.clone(), out.to_vec());

    for stage in stages.iter().rev() {
        // Round up unrolled dims before ranging this stage's loads.
        if let Some(rounds) = rounding.get(&stage.name) {
            let req = required.get_mut(&stage.name).unwrap();
            for (var, factor) in rounds {
                let k = stage
                    .vars
                    .iter()
                    .position(|v| v == var)
                    .with_context(|| format!("unroll of unknown var {var} in {}", stage.name))?;
                let extent = req[k].1 - req[k].0 + 1;
                req[k].1 = req[k].0 + (extent + *factor - 1) / *factor * factor - 1;
            }
        }
        let req = match required.get(&stage.name) {
            Some(r) => r.clone(),
            None => bail!("stage {} is never consumed", stage.name),
        };
        // The stage's compute domain: required pure box x reduction box.
        let mut dim_bounds: Intervals = req.clone();
        for (_, min, extent) in &stage.rdom {
            dim_bounds.push((*min, *min + *extent - 1));
        }
        let dims = stage.all_dims();
        for (buf, idx) in stage.kernel.loads() {
            if buf == stage.name {
                continue; // accumulator self-reference
            }
            let map = Expr::load_affine_map(&idx, &dims).with_context(|| {
                format!("non-affine access to {buf} in stage {}", stage.name)
            })?;
            let ranges: Intervals =
                map.outputs.iter().map(|o| o.bounds(&dim_bounds)).collect();
            match required.get_mut(&buf) {
                Some(cur) => {
                    anyhow::ensure!(
                        cur.len() == ranges.len(),
                        "rank mismatch for buffer {buf}"
                    );
                    for (c, r) in cur.iter_mut().zip(&ranges) {
                        c.0 = c.0.min(r.0);
                        c.1 = c.1.max(r.1);
                    }
                }
                None => {
                    required.insert(buf.clone(), ranges);
                }
            }
        }
    }
    Ok(required)
}

/// Convert inferred intervals into a [`BoxSet`] with the given dim names.
pub fn intervals_to_box(names: &[String], iv: &Intervals) -> BoxSet {
    assert_eq!(names.len(), iv.len());
    BoxSet::new(
        names
            .iter()
            .zip(iv)
            .map(|(n, &(lo, hi))| Dim::new(n.clone(), lo, hi - lo + 1))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(name: &str, vars: &[&str], kernel: Expr) -> StageDef {
        StageDef {
            name: name.into(),
            vars: vars.iter().map(|s| s.to_string()).collect(),
            rdom: vec![],
            kernel,
        }
    }

    #[test]
    fn brighten_blur_halo() {
        // blur reads brighten at (y..y+1, x..x+1); brighten reads input
        // pointwise. 64x64 output tile => brighten/input need 65x65.
        let brighten = stage(
            "brighten",
            &["y", "x"],
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
        );
        let blur = stage(
            "blur",
            &["y", "x"],
            Expr::sum(vec![
                Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld(
                    "brighten",
                    vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))],
                ),
                Expr::ld(
                    "brighten",
                    vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")],
                ),
                Expr::ld(
                    "brighten",
                    vec![
                        Expr::add(Expr::v("y"), Expr::c(1)),
                        Expr::add(Expr::v("x"), Expr::c(1)),
                    ],
                ),
            ]),
        );
        let req = infer(&[brighten, blur], &[64, 64], &BTreeMap::new()).unwrap();
        assert_eq!(req["blur"], vec![(0, 63), (0, 63)]);
        assert_eq!(req["brighten"], vec![(0, 64), (0, 64)]);
        assert_eq!(req["input"], vec![(0, 64), (0, 64)]);
    }

    #[test]
    fn negative_halo() {
        // sobel-style: reads x-1..x+1.
        let s = stage(
            "g",
            &["x"],
            Expr::add(
                Expr::ld("in", vec![Expr::sub(Expr::v("x"), Expr::c(1))]),
                Expr::ld("in", vec![Expr::add(Expr::v("x"), Expr::c(1))]),
            ),
        );
        let req = infer(&[s], &[16], &BTreeMap::new()).unwrap();
        assert_eq!(req["in"], vec![(-1, 16)]);
    }

    #[test]
    fn reduction_dims_extend_domain() {
        let conv = StageDef {
            name: "conv".into(),
            vars: vec!["y".into(), "x".into()],
            rdom: vec![("ry".into(), 0, 3), ("rx".into(), 0, 3)],
            kernel: Expr::mul(
                Expr::ld(
                    "in",
                    vec![
                        Expr::add(Expr::v("y"), Expr::v("ry")),
                        Expr::add(Expr::v("x"), Expr::v("rx")),
                    ],
                ),
                Expr::ld("w", vec![Expr::v("ry"), Expr::v("rx")]),
            ),
        };
        let req = infer(&[conv], &[8, 8], &BTreeMap::new()).unwrap();
        assert_eq!(req["in"], vec![(0, 9), (0, 9)]);
        assert_eq!(req["w"], vec![(0, 2), (0, 2)]);
    }

    #[test]
    fn shifted_output_box_shifts_the_footprint() {
        // The tile-planner invariant: for identity-linear stencil
        // accesses, realizing the output over [o, o+t) instead of
        // [0, t) translates every producer footprint by o without
        // changing its extent.
        let s = stage(
            "g",
            &["x"],
            Expr::add(
                Expr::ld("in", vec![Expr::sub(Expr::v("x"), Expr::c(1))]),
                Expr::ld("in", vec![Expr::add(Expr::v("x"), Expr::c(1))]),
            ),
        );
        let base = infer_boxes(&[s.clone()], &[(0, 15)], &BTreeMap::new()).unwrap();
        let shifted = infer_boxes(&[s], &[(40, 55)], &BTreeMap::new()).unwrap();
        assert_eq!(base["in"], vec![(-1, 16)]);
        assert_eq!(shifted["in"], vec![(39, 56)]);
        assert_eq!(shifted["g"], vec![(40, 55)]);
    }

    #[test]
    fn scaling_access_shifts_by_linear_part() {
        // Strip-mined upsample shape: out(yo, yi) = in(yo). A tile at
        // yo-origin 8 needs in rows starting at 8 — the footprint
        // shift is the access map's linear part applied to the origin.
        let up = stage("up", &["yo", "yi"], Expr::ld("in", vec![Expr::v("yo")]));
        let f = infer_boxes(&[up], &[(8, 15), (0, 1)], &BTreeMap::new()).unwrap();
        assert_eq!(f["in"], vec![(8, 15)]);
    }

    #[test]
    fn empty_output_interval_rejected() {
        let s = stage("g", &["x"], Expr::ld("in", vec![Expr::v("x")]));
        assert!(infer_boxes(&[s], &[(4, 3)], &BTreeMap::new()).is_err());
    }

    #[test]
    fn unconsumed_stage_rejected() {
        let a = stage("a", &["x"], Expr::ld("in", vec![Expr::v("x")]));
        let b = stage("b", &["x"], Expr::ld("in", vec![Expr::v("x")]));
        assert!(infer(&[a, b], &[8], &BTreeMap::new()).is_err());
    }

    #[test]
    fn intervals_to_box_roundtrip() {
        let b = intervals_to_box(&["y".into(), "x".into()], &vec![(-1, 62), (0, 64)]);
        assert_eq!(b.dims[0].min, -1);
        assert_eq!(b.dims[0].extent, 64);
        assert_eq!(b.dims[1].extent, 65);
    }
}
