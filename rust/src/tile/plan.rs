//! [`TilePlan`]: the pure planning half of arbitrary-extent serving.
//!
//! Built once per `(compiled design, requested output extent)` and
//! cached on [`Compiled::tile_plan`], a plan holds everything the
//! execution half needs that does not depend on request payloads: the
//! whole-image input boxes a request must supply, the clamped tile
//! origins covering the requested extent, and — per tile, per input —
//! the translation from the design's declared input box into
//! whole-image coordinates (docs/tiling.md). The input boxes depend
//! only on the extent and the program's stencil halo, never on the
//! design's tile size — which is what lets the load-adaptive router
//! retarget one v3 payload at any compiled variant of the same
//! program (docs/routing.md): each variant's `Compiled` carries its
//! own plan cache, so switching variants never re-plans another's
//! extents.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::coordinator::Compiled;
use crate::halide::bounds::Intervals;
use crate::poly::set::{BoxSet, Dim};
use crate::tensor::Tensor;

/// A whole-image input payload the gather path reads from: either an
/// owned [`Tensor`] (the in-process `run_tiled` shape) or raw
/// little-endian words still sitting in the request frame buffer (the
/// server's zero-copy v3 path — payload bytes are copied exactly once,
/// frame → tile scratch, instead of frame → `Vec<i32>` → scratch).
/// Both variants index the same row-major layout the wire declares
/// (docs/protocol.md), pinned equal by the gather tests below.
#[derive(Clone, Copy)]
pub enum ImageSource<'a> {
    Tensor(&'a Tensor),
    Frame { shape: &'a BoxSet, bytes: &'a [u8] },
}

impl ImageSource<'_> {
    pub fn shape(&self) -> &BoxSet {
        match self {
            ImageSource::Tensor(t) => &t.shape,
            ImageSource::Frame { shape, .. } => shape,
        }
    }

    /// Read one word at image point `q` (must lie inside the shape).
    #[inline]
    fn get(&self, q: &[i64]) -> i32 {
        match self {
            ImageSource::Tensor(t) => t.get(q),
            ImageSource::Frame { shape, bytes } => {
                let mut idx = 0usize;
                let mut mul = 1usize;
                for (i, d) in shape.dims.iter().enumerate().rev() {
                    idx += (q[i] - d.min) as usize * mul;
                    mul *= d.extent as usize;
                }
                let b = &bytes[4 * idx..4 * idx + 4];
                i32::from_le_bytes([b[0], b[1], b[2], b[3]])
            }
        }
    }

    /// Whole-image copy for the aligned fast path (`dst` must have
    /// exactly the source's cardinality).
    fn copy_into(&self, dst: &mut [i32]) {
        match self {
            ImageSource::Tensor(t) => dst.copy_from_slice(&t.data),
            ImageSource::Frame { bytes, .. } => {
                for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
                    *d = i32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
            }
        }
    }
}

/// One accelerator pass of the plan: where its (full-extent) output
/// tile lands in the image, and where each input slice is read from.
#[derive(Clone, Debug)]
pub struct TileSlot {
    /// Output-tile origin per output pure dim (absolute image coords).
    /// Edge tiles are clamped back so `origin + tile <= extent`
    /// whenever the extent allows a full tile.
    pub origin: Vec<i64>,
    /// Per input (in declared order): the per-dim translation from the
    /// design's declared input box into whole-image coordinates
    /// (`image_coord = local_coord + shift`). Derived from the tile's
    /// polyhedral footprint, so it carries the stencil halo exactly.
    pub input_shift: Vec<Vec<i64>>,
}

/// A tiling of one requested output extent onto one compiled design.
#[derive(Clone, Debug)]
pub struct TilePlan {
    /// Requested output extents, per output pure dim.
    pub extent: Vec<i64>,
    /// Stitched output box: zero-based, `extent` per dim (the box the
    /// response words are row-major over).
    pub out_box: BoxSet,
    /// The design's compiled per-tile output extents
    /// ([`crate::halide::LoweredPipeline::tile`]).
    pub tile: Vec<i64>,
    /// Declared input names, in request order.
    pub input_names: Vec<String>,
    /// Whole-image box per input — what a request must supply, halo
    /// included (`footprint` of the full requested extent).
    pub input_boxes: Vec<BoxSet>,
    /// The design's declared per-tile input boxes (what every
    /// accelerator pass consumes).
    pub compiled_input_boxes: Vec<BoxSet>,
    /// The accelerator passes, in scatter order.
    pub tiles: Vec<TileSlot>,
}

/// Clamped 1-D tile origins covering `[0, h)` with stride/width `t`:
/// full tiles at multiples of `t`, and a final origin shifted back to
/// `h - t` when `h` is not a multiple (the overlap is recomputed and
/// restitched bit-identically). `h <= t` degenerates to one tile at 0
/// whose overhang is fed by clamp-to-edge gathering and cropped away.
fn origins_1d(h: i64, t: i64) -> Vec<i64> {
    if h <= t {
        return vec![0];
    }
    let mut v = Vec::new();
    let mut x = 0;
    while x + t < h {
        v.push(x);
        x += t;
    }
    v.push(h - t);
    v
}

impl TilePlan {
    /// Plan the decomposition of `extent` onto `c`'s fixed design.
    ///
    /// Fails when the rank does not match the design's output, when an
    /// extent is non-positive, or when the access structure is not
    /// tileable by translation (a tile's input footprint would need a
    /// different extent than the design's declared box — no registered
    /// app does this; the guard keeps the planner honest if one ever
    /// does).
    pub fn build(c: &Compiled, extent: &[i64]) -> Result<TilePlan> {
        let lp = &c.lp;
        anyhow::ensure!(
            extent.len() == lp.tile.len(),
            "output extent rank {} != design output rank {} (tile {:?})",
            extent.len(),
            lp.tile.len(),
            lp.tile
        );
        for (k, &e) in extent.iter().enumerate() {
            anyhow::ensure!(e >= 1, "output extent {e} at dim {k} must be >= 1");
        }

        // Whole-image inference: the input boxes a request must
        // supply. Identical to lowering the same program at
        // `tile = extent` — the host-side golden model's boxes.
        let full: Intervals = extent.iter().map(|&e| (0, e - 1)).collect();
        let full_fp = lp.footprint(&full).context("whole-image bounds inference")?;
        let mut input_boxes = Vec::with_capacity(lp.inputs.len());
        let mut compiled_input_boxes = Vec::with_capacity(lp.inputs.len());
        for name in &lp.inputs {
            let compiled = &lp.buffers[name];
            let names: Vec<String> = compiled.dims.iter().map(|d| d.name.clone()).collect();
            input_boxes.push(crate::halide::bounds::intervals_to_box(&names, &full_fp[name]));
            compiled_input_boxes.push(compiled.clone());
        }

        // Clamped tile origins, cartesian across dims.
        let per_dim: Vec<Vec<i64>> = extent
            .iter()
            .zip(&lp.tile)
            .map(|(&h, &t)| origins_1d(h, t))
            .collect();
        let mut origin_list: Vec<Vec<i64>> = vec![Vec::new()];
        for dim_origins in &per_dim {
            let mut next = Vec::with_capacity(origin_list.len() * dim_origins.len());
            for prefix in &origin_list {
                for &o in dim_origins {
                    let mut p = prefix.clone();
                    p.push(o);
                    next.push(p);
                }
            }
            origin_list = next;
        }

        // Per tile: range the same access structure at the tile's
        // absolute output box and read off each input's translation.
        // The extents must reproduce the design's declared boxes —
        // every pass runs the unchanged fixed design.
        let mut tiles = Vec::with_capacity(origin_list.len());
        for origin in origin_list {
            let out: Intervals =
                origin.iter().zip(&lp.tile).map(|(&o, &t)| (o, o + t - 1)).collect();
            let fp = lp
                .footprint(&out)
                .with_context(|| format!("tile footprint at origin {origin:?}"))?;
            for (name, compiled) in &lp.buffers {
                let iv = fp
                    .get(name)
                    .with_context(|| format!("buffer {name} missing from tile footprint"))?;
                for (k, (d, &(lo, hi))) in compiled.dims.iter().zip(iv).enumerate() {
                    anyhow::ensure!(
                        hi - lo + 1 == d.extent,
                        "buffer {name} dim {k}: footprint extent {} at tile origin \
                         {origin:?} != compiled extent {} — access structure is not \
                         tileable by translation",
                        hi - lo + 1,
                        d.extent
                    );
                }
            }
            let input_shift = lp
                .inputs
                .iter()
                .map(|name| {
                    lp.buffers[name]
                        .dims
                        .iter()
                        .zip(&fp[name])
                        .map(|(d, &(lo, _))| lo - d.min)
                        .collect()
                })
                .collect();
            tiles.push(TileSlot { origin, input_shift });
        }

        let out_box = BoxSet::new(
            lp.buffers[&lp.output]
                .dims
                .iter()
                .zip(extent)
                .map(|(d, &e)| Dim::new(d.name.clone(), 0, e))
                .collect(),
        );
        Ok(TilePlan {
            extent: extent.to_vec(),
            out_box,
            tile: lp.tile.clone(),
            input_names: lp.inputs.clone(),
            input_boxes,
            compiled_input_boxes,
            tiles,
        })
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    /// Expected whole-image word count per input, in request order —
    /// the numbers the server's diagnostics quote back to clients.
    pub fn expected_words(&self) -> Vec<(&str, i64)> {
        self.input_names
            .iter()
            .map(String::as_str)
            .zip(self.input_boxes.iter().map(BoxSet::cardinality))
            .collect()
    }

    /// Validate a request's whole-image tensors: every declared input
    /// present with exactly the plan's box layout.
    pub fn check_inputs(&self, inputs: &BTreeMap<String, Tensor>) -> Result<()> {
        for (name, b) in self.input_names.iter().zip(&self.input_boxes) {
            let t = inputs
                .get(name)
                .with_context(|| format!("missing input {name}"))?;
            anyhow::ensure!(
                t.shape.same_layout(b),
                "input {name}: tensor box {} does not match the whole-image box {b}",
                t.shape
            );
        }
        Ok(())
    }

    /// Build the input slices one accelerator pass consumes: tensors
    /// over the design's declared boxes, filled from the whole-image
    /// tensors at the tile's shifted footprint. Reads outside the
    /// whole-image box clamp to the image edge — those samples only
    /// ever feed output pixels outside the requested extent (the
    /// overhang of a tile wider than the image), which stitching
    /// discards, so clamping never alters a served word.
    pub fn gather(
        &self,
        slot: &TileSlot,
        inputs: &BTreeMap<String, Tensor>,
    ) -> BTreeMap<String, Tensor> {
        let mut out = BTreeMap::new();
        for (k, name) in self.input_names.iter().enumerate() {
            let full = &inputs[name];
            let compiled = &self.compiled_input_boxes[k];
            let shift = &slot.input_shift[k];
            let slice = if shift.iter().all(|&s| s == 0) && full.shape.same_layout(compiled)
            {
                full.clone()
            } else {
                let mut q = vec![0i64; compiled.rank()];
                Tensor::from_fn(compiled.clone(), |p| {
                    for (qk, (&pk, &sk)) in q.iter_mut().zip(p.iter().zip(shift)) {
                        *qk = pk + sk;
                    }
                    full.get_clamped(&q)
                })
            };
            out.insert(name.clone(), slice);
        }
        out
    }

    /// The allocation-free [`TilePlan::gather`]: fill input `k`'s
    /// pre-shaped slice tensor `dst` (over the design's declared box)
    /// from the whole-image tensor, for one tile. `p` and `q` are
    /// caller-owned coordinate scratch of at least the input's rank —
    /// [`Tensor::get_clamped`] builds a coord `Vec` per call, which is
    /// exactly the per-point allocation the tile hot path must avoid
    /// (docs/tiling.md). Same clamp-to-edge semantics as `gather`.
    pub fn gather_into(
        &self,
        k: usize,
        slot: &TileSlot,
        full: ImageSource<'_>,
        dst: &mut Tensor,
        p: &mut [i64],
        q: &mut [i64],
    ) {
        let compiled = &self.compiled_input_boxes[k];
        let shift = &slot.input_shift[k];
        debug_assert!(dst.shape.same_layout(compiled), "dst not pre-shaped");
        if shift.iter().all(|&s| s == 0) && full.shape().same_layout(compiled) {
            full.copy_into(&mut dst.data);
            return;
        }
        // Manual row-major odometer over the compiled box: `p` is the
        // local point, `q` its clamped whole-image coordinate. `dst`
        // is filled sequentially — local row-major order IS its flat
        // order.
        let rank = compiled.rank();
        let p = &mut p[..rank];
        let q = &mut q[..rank];
        for (v, d) in p.iter_mut().zip(&compiled.dims) {
            *v = d.min;
        }
        let full_shape = full.shape();
        let mut idx = 0usize;
        loop {
            for i in 0..rank {
                let d = &full_shape.dims[i];
                q[i] = (p[i] + shift[i]).clamp(d.min, d.max());
            }
            dst.data[idx] = full.get(q);
            idx += 1;
            let mut done = true;
            for k in (0..rank).rev() {
                p[k] += 1;
                if p[k] < compiled.dims[k].min + compiled.dims[k].extent {
                    done = false;
                    break;
                }
                p[k] = compiled.dims[k].min;
            }
            if done {
                break;
            }
        }
    }

    /// Copy one finished tile into the stitched output, cropped to the
    /// requested extent. Clamped tiles overlap their neighbours; the
    /// overlap re-writes bit-identical words (same design, same input
    /// slice values), so scatter order is irrelevant.
    pub fn scatter(&self, slot: &TileSlot, tile_out: &Tensor, out: &mut Tensor) {
        let clip = BoxSet::new(
            self.out_box
                .dims
                .iter()
                .zip(&slot.origin)
                .zip(&self.tile)
                .map(|((d, &o), &t)| {
                    Dim::new(d.name.clone(), o, (o + t).min(d.min + d.extent) - o)
                })
                .collect(),
        );
        let mut local = vec![0i64; clip.rank()];
        clip.for_each_point(|p| {
            for (lk, (&pk, &ok)) in local.iter_mut().zip(p.iter().zip(&slot.origin)) {
                *lk = pk - ok;
            }
            out.set(p, tile_out.get(&local));
        });
    }

    /// The allocation-free [`TilePlan::scatter`]: same crop-and-copy
    /// with caller-owned coordinate scratch (`local`, `image`, at
    /// least the output rank each) instead of a per-call `Vec` and the
    /// box point iterator.
    pub fn scatter_into(
        &self,
        slot: &TileSlot,
        tile_out: &Tensor,
        out: &mut Tensor,
        local: &mut [i64],
        image: &mut [i64],
    ) {
        let rank = self.out_box.rank();
        let local = &mut local[..rank];
        let image = &mut image[..rank];
        local.iter_mut().for_each(|v| *v = 0);
        loop {
            for i in 0..rank {
                image[i] = slot.origin[i] + local[i];
            }
            out.set(image, tile_out.get(local));
            let mut done = true;
            for k in (0..rank).rev() {
                local[k] += 1;
                // Crop: only [origin, min(origin + tile, extent)) of
                // each dim lands in the stitched image.
                let span = (slot.origin[k] + self.tile[k]).min(self.out_box.dims[k].extent)
                    - slot.origin[k];
                if local[k] < span {
                    done = false;
                    break;
                }
                local[k] = 0;
            }
            if done {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::compile;

    #[test]
    fn origins_clamp_at_the_edge() {
        assert_eq!(origins_1d(28, 14), vec![0, 14]);
        assert_eq!(origins_1d(33, 14), vec![0, 14, 19]);
        assert_eq!(origins_1d(250, 62), vec![0, 62, 124, 186, 188]);
        assert_eq!(origins_1d(14, 14), vec![0]);
        assert_eq!(origins_1d(9, 14), vec![0]);
    }

    #[test]
    fn gaussian_plan_shapes() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        let plan = TilePlan::build(&c, &[33, 20]).unwrap();
        assert_eq!(plan.tile_count(), 6, "origins {:?}", plan.tiles);
        // 3x3 stencil: whole-image input is extent+2 per side.
        assert_eq!(plan.input_boxes[0].dims[0].extent, 35);
        assert_eq!(plan.input_boxes[0].dims[1].extent, 22);
        assert_eq!(plan.expected_words(), vec![("input", 35 * 22)]);
        // Identity access: each tile's input shift is its origin.
        for slot in &plan.tiles {
            assert_eq!(slot.input_shift[0], slot.origin);
        }
        assert_eq!(plan.tiles[0].origin, vec![0, 0]);
        assert_eq!(plan.tiles.last().unwrap().origin, vec![19, 6]);
    }

    #[test]
    fn extent_smaller_than_tile_is_one_clamped_pass() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        let plan = TilePlan::build(&c, &[9, 9]).unwrap();
        assert_eq!(plan.tile_count(), 1);
        assert_eq!(plan.input_boxes[0].dims[0].extent, 11);
        assert_eq!(plan.out_box.cardinality(), 81);
    }

    #[test]
    fn rank_and_extent_validation() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        assert!(TilePlan::build(&c, &[33]).is_err());
        assert!(TilePlan::build(&c, &[33, 0]).is_err());
    }

    #[test]
    fn gather_is_a_pure_translation_inside_the_image() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        let plan = TilePlan::build(&c, &[33, 20]).unwrap();
        let full = Tensor::from_fn(plan.input_boxes[0].clone(), |p| {
            (100 * p[0] + p[1]) as i32
        });
        let mut inputs = BTreeMap::new();
        inputs.insert("input".to_string(), full.clone());
        let slot = &plan.tiles[plan.tile_count() - 1]; // origin [19, 6]
        let slice = &plan.gather(slot, &inputs)["input"];
        assert!(slice.shape.same_layout(&c.lp.buffers["input"]));
        // Local (0,0) reads image (19,6); local (15,15) reads (34,21).
        assert_eq!(slice.get(&[0, 0]), full.get(&[19, 6]));
        assert_eq!(slice.get(&[15, 15]), full.get(&[34, 21]));
    }

    /// The allocation-free gather/scatter variants are bit-identical
    /// to the allocating reference paths, across every tile of a plan
    /// with clamped edge tiles (so the clamp and crop paths both run).
    #[test]
    fn gather_into_and_scatter_into_match_the_allocating_paths() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        let plan = TilePlan::build(&c, &[33, 20]).unwrap();
        let full = Tensor::from_fn(plan.input_boxes[0].clone(), |p| {
            (7 * p[0] + 3 * p[1] + 1) as i32
        });
        let mut inputs = BTreeMap::new();
        inputs.insert("input".to_string(), full.clone());
        let (mut ca, mut cb) = (vec![0i64; 4], vec![0i64; 4]);
        let mut dst = Tensor::zeros(plan.compiled_input_boxes[0].clone());
        for slot in &plan.tiles {
            let want = &plan.gather(slot, &inputs)["input"];
            plan.gather_into(0, slot, ImageSource::Tensor(&full), &mut dst, &mut ca, &mut cb);
            assert_eq!(dst.data, want.data, "origin {:?}", slot.origin);
        }
        let tile_box = BoxSet::from_extents(&plan.tile);
        let mut a = Tensor::zeros(plan.out_box.clone());
        let mut b = Tensor::zeros(plan.out_box.clone());
        for (i, slot) in plan.tiles.iter().enumerate() {
            let t = Tensor::from_fn(tile_box.clone(), |p| {
                (i as i64 * 1000 + 10 * p[0] + p[1]) as i32
            });
            plan.scatter(slot, &t, &mut a);
            plan.scatter_into(slot, &t, &mut b, &mut ca, &mut cb);
        }
        assert_eq!(a.data, b.data);
    }

    /// A Frame source over the tensor's wire bytes gathers exactly
    /// what the Tensor source does, on every tile of a plan whose edge
    /// tiles exercise the clamp path — the zero-copy v3 path can never
    /// change served words. The 33x20 extent exercises the shifted
    /// odometer path; the 14x14 extent (exactly one design tile) is
    /// zero-shift with matching layout, the aligned fast path.
    #[test]
    fn frame_source_matches_tensor_source() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        for extent in [vec![33i64, 20], vec![14, 14]] {
            let plan = TilePlan::build(&c, &extent).unwrap();
            let full = Tensor::from_fn(plan.input_boxes[0].clone(), |p| {
                (13 * p[0] - 5 * p[1] + 2) as i32
            });
            let bytes: Vec<u8> = full.data.iter().flat_map(|w| w.to_le_bytes()).collect();
            let (mut ca, mut cb) = (vec![0i64; 4], vec![0i64; 4]);
            let mut from_tensor = Tensor::zeros(plan.compiled_input_boxes[0].clone());
            let mut from_frame = Tensor::zeros(plan.compiled_input_boxes[0].clone());
            for slot in &plan.tiles {
                plan.gather_into(
                    0,
                    slot,
                    ImageSource::Tensor(&full),
                    &mut from_tensor,
                    &mut ca,
                    &mut cb,
                );
                plan.gather_into(
                    0,
                    slot,
                    ImageSource::Frame { shape: &full.shape, bytes: &bytes },
                    &mut from_frame,
                    &mut ca,
                    &mut cb,
                );
                assert_eq!(from_frame.data, from_tensor.data, "origin {:?}", slot.origin);
            }
        }
    }
}
