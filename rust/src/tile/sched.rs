//! [`TileScheduler`]: cross-request tile scheduling for the serving
//! pool (docs/serving.md).
//!
//! The PR-5 serving design posted each whole-image request to the job
//! queue as an opportunistic `Job::Tiles(Weak<TileBatch>)` fan-out: a
//! worker that picked the job up dedicated itself to that one batch
//! until the batch drained. Two consequences: N concurrent requests
//! each paid their own recruitment round, and one large image could
//! head-of-line-block every small request behind it on the queue.
//!
//! The scheduler replaces that with one shared structure holding the
//! claim cursors of **all** in-flight batches, in admission order.
//! Workers ask it one question — "which batch deserves my next tile
//! claim?" — via [`TileScheduler::claim`], drain one short claim run
//! ([`crate::tile::TileBatch::work_run`] — up to
//! `TileBatch::claim_run_len` adjacent tiles per cursor hit, sized
//! inversely to tile cost; [`crate::tile::TileBatch::work_one`]
//! remains the explicit single-tile unit), and ask again. The answer
//! is a weighted round-robin: the **oldest** live batch gets every
//! other claim (it admitted first, it finishes first), and the
//! remaining claims rotate across the younger batches so none of them
//! starves while the oldest drains. Runs stay short in *work* —
//! paper-scale tiles keep run length 1 — so the fairness granularity
//! the interleaving tests pin is unchanged where it matters.
//!
//! ## Exactness
//!
//! The scheduler only decides *which thread claims which tile next*.
//! Tile execution itself — gather, engine run, scatter — is untouched
//! and order-independent: every tile reads only its own input slice
//! and writes only its own output region (overlapping clamped tiles
//! rewrite bit-identical words, see [`crate::tile::TilePlan`]), so
//! any interleaving of claims across requests stitches exactly the
//! images serial execution would. The coalescing loopback suite pins
//! this over the wire.
//!
//! Batches are held as [`Weak`] references: the submitting connection
//! owns the only strong `Arc`, so a request that fails or disconnects
//! unregisters itself by dropping — dead and fully-claimed entries
//! are pruned on every call.

use std::sync::{Arc, Mutex, Weak};

use super::TileBatch;

/// Shared across all acceptor threads, pool workers, and submitting
/// connections of one server (see module docs).
pub struct TileScheduler {
    state: Mutex<SchedState>,
}

struct SchedState {
    /// Live batches in admission order — index 0 is the oldest.
    entries: Vec<Weak<TileBatch>>,
    /// Claim counter driving the oldest-first weighting.
    tick: u64,
    /// Rotation cursor over the non-oldest entries.
    rr: usize,
}

impl Default for TileScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl TileScheduler {
    pub fn new() -> TileScheduler {
        TileScheduler {
            state: Mutex::new(SchedState { entries: Vec::new(), tick: 0, rr: 0 }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, SchedState> {
        // State is a list of weak refs and two counters — always
        // valid whole, so poisoned-lock recovery is safe.
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Register an in-flight batch. The caller keeps its strong
    /// `Arc`; the scheduler prunes the entry once the batch is fully
    /// claimed or dropped.
    pub fn submit(&self, batch: &Arc<TileBatch>) {
        self.lock().entries.push(Arc::downgrade(batch));
    }

    /// Pick the batch that deserves the caller's next tile claim, or
    /// `None` when no batch has unclaimed tiles. Weighted
    /// round-robin: even ticks go to the oldest live batch, odd ticks
    /// rotate across the rest (with one live batch, every tick is
    /// its). The caller should drain one short claim run
    /// ([`TileBatch::work_run`]; [`TileBatch::work_one`] for the
    /// strict single-tile unit) and ask again, so scheduling
    /// decisions track batch arrivals and completions claim by claim.
    pub fn claim(&self) -> Option<Arc<TileBatch>> {
        let mut st = self.lock();
        let mut live: Vec<Arc<TileBatch>> = Vec::with_capacity(st.entries.len());
        st.entries.retain(|w| match w.upgrade() {
            Some(b) if b.has_unclaimed() => {
                live.push(b);
                true
            }
            _ => false,
        });
        if live.is_empty() {
            return None;
        }
        let idx = if live.len() == 1 || st.tick % 2 == 0 {
            0
        } else {
            let i = 1 + st.rr % (live.len() - 1);
            st.rr += 1;
            i
        };
        st.tick += 1;
        Some(live.swap_remove(idx))
    }

    /// Unclaimed tiles across every live batch — the admission
    /// layer's retry-hint signal and one input of the load-adaptive
    /// variant router's pressure score (docs/routing.md); prunes as
    /// it counts.
    pub fn backlog(&self) -> u64 {
        let mut st = self.lock();
        let mut sum = 0u64;
        st.entries.retain(|w| match w.upgrade() {
            Some(b) if b.has_unclaimed() => {
                sum += b.unclaimed() as u64;
                true
            }
            _ => false,
        });
        sum
    }

    /// Live batches with unclaimed tiles.
    pub fn active(&self) -> usize {
        let mut st = self.lock();
        st.entries.retain(|w| w.upgrade().is_some_and(|b| b.has_unclaimed()));
        st.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;
    use crate::apps;
    use crate::coordinator::{compile, Compiled};
    use crate::exec::Engine;
    use crate::tensor::Tensor;
    use crate::tile::{TileBatch, TileScratch};

    fn four_tile_batch(c: &Arc<Compiled>) -> Arc<TileBatch> {
        let plan = c.tile_plan(&[28, 28]).unwrap();
        let mut inputs = BTreeMap::new();
        for (name, b) in plan.input_names.iter().zip(&plan.input_boxes) {
            inputs.insert(name.clone(), Tensor::from_fn(b.clone(), |p| (p[0] + p[1]) as i32));
        }
        TileBatch::new(Arc::clone(c), Engine::Exec, plan, inputs).unwrap()
    }

    /// Two live batches: a single drainer's claims alternate strictly
    /// between them (oldest on even ticks, the other on odd), so both
    /// claim cursors advance together — the no-starvation property
    /// the coalescing loopback suite observes over the wire.
    #[test]
    fn claims_interleave_across_two_batches() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let sched = TileScheduler::new();
        let a = four_tile_batch(&c);
        let b = four_tile_batch(&c);
        sched.submit(&a);
        sched.submit(&b);
        assert_eq!(sched.active(), 2);
        assert_eq!(sched.backlog(), 8);

        let mut runner = c.runner(Engine::Exec).unwrap();
        let mut scratch = TileScratch::new(a.plan());
        let mut order = Vec::new();
        while let Some(batch) = sched.claim() {
            assert!(batch.work_one(&mut runner, &mut scratch));
            order.push(if Arc::ptr_eq(&batch, &a) { 'a' } else { 'b' });
            // Both cursors advance in lockstep: after any prefix the
            // two claim counts differ by at most one.
            assert!(a.claimed().abs_diff(b.claimed()) <= 1, "order so far {order:?}");
        }
        assert_eq!(order.iter().collect::<String>(), "abababab");
        assert!(a.wait().is_ok());
        assert!(b.wait().is_ok());
        assert_eq!(sched.backlog(), 0);
        assert_eq!(sched.active(), 0);
    }

    /// Three batches: the oldest gets every even tick (half of all
    /// claims) and drains first; the younger two share the odd ticks
    /// evenly — weighted toward the oldest, starving nobody.
    #[test]
    fn oldest_batch_gets_half_of_the_claims() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let sched = TileScheduler::new();
        let batches = [four_tile_batch(&c), four_tile_batch(&c), four_tile_batch(&c)];
        for b in &batches {
            sched.submit(b);
        }
        let mut runner = c.runner(Engine::Exec).unwrap();
        let mut scratch = TileScratch::new(batches[0].plan());
        let mut first_drained = None;
        while let Some(batch) = sched.claim() {
            assert!(batch.work_one(&mut runner, &mut scratch));
            for (i, b) in batches.iter().enumerate() {
                if !b.has_unclaimed() && first_drained.is_none() {
                    first_drained = Some(i);
                    // At the moment the oldest is fully claimed it
                    // has had every even tick — half of all claims —
                    // and the younger two split the odd ticks, both
                    // having progressed.
                    assert_eq!(batches[1].claimed() + batches[2].claimed(), 3);
                    assert!(batches[1].claimed() >= 1, "second batch starved");
                    assert!(batches[2].claimed() >= 1, "third batch starved");
                }
            }
        }
        assert_eq!(first_drained, Some(0), "the oldest batch must drain first");
        for b in &batches {
            assert_eq!(b.claimed(), 4);
            assert!(b.wait().is_ok());
        }
    }

    /// Dropped and fully-claimed batches disappear from the
    /// scheduler's view without any explicit unregister call.
    #[test]
    fn dead_and_drained_batches_are_pruned() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let sched = TileScheduler::new();
        let a = four_tile_batch(&c);
        sched.submit(&a);
        drop(a);
        assert!(sched.claim().is_none());
        assert_eq!(sched.active(), 0);

        let b = four_tile_batch(&c);
        sched.submit(&b);
        b.work(); // drain on this thread
        assert!(sched.claim().is_none(), "fully-claimed batch must be pruned");
        assert_eq!(sched.backlog(), 0);
        assert!(b.wait().is_ok());
    }
}
