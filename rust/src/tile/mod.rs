//! `tile` — the halo-aware tiling planner: serve **any** output extent
//! on the fixed-extent compiled design.
//!
//! The paper's accelerator executes one pass over a fixed output tile
//! (64×64-input scale); everything above it in this repo — apps,
//! protocol, server — historically inherited that limit. This layer
//! removes it with the host/accelerator split the paper assumes and
//! Pu et al. make explicit in *"Programming Heterogeneous Systems
//! from an Image Processing DSL"*: the host decomposes the requested
//! image into compiled-tile-sized pieces, gathers each tile's input
//! slice **with its stencil halo**, replays the unchanged accelerator
//! design per tile, and stitches the results.
//!
//! * [`TilePlan`] is the pure planning half: built once per
//!   `(design, extent)` and cached on
//!   [`crate::coordinator::Compiled::tile_plan`], it uses polyhedral
//!   bounds inference ([`crate::halide::bounds::infer_boxes`] via
//!   [`crate::halide::LoweredPipeline::footprint`]) to derive the
//!   whole-image input boxes a request must supply and, per tile, the
//!   translation from the design's declared input boxes into
//!   whole-image coordinates. Edge tiles are **clamped**: their
//!   origins shift back so every accelerator pass runs at the full
//!   compiled extent, recomputing the overlap (bit-identical by
//!   shift-invariance of the affine access structure, which the
//!   planner verifies per tile).
//! * [`TileBatch`] is the execution half: a cooperative work queue of
//!   per-tile runs over the design's cached engine plan
//!   ([`crate::coordinator::Compiled::runner`], ExecPlan-preferred
//!   with SimRun fallback). Any number of threads may join via
//!   [`TileBatch::work`] — the serving worker pool recruits idle
//!   workers into a large request this way
//!   (`coordinator/serve.rs`) — and [`TileBatch::wait`] stitches the
//!   finished tiles and sums their [`crate::cgra::SimStats`].
//!
//! * [`TileScheduler`] sits between the two halves on the serving
//!   path: it holds the claim cursors of **all** in-flight batches so
//!   pool workers drain tiles in a weighted round-robin across
//!   requests — oldest first, nobody starved — instead of dedicating
//!   themselves to one batch (docs/serving.md).
//!
//! Full halo math, edge-clamping rationale, and the v3 wire frames
//! that carry requested extents: docs/tiling.md.

pub mod plan;
pub mod run;
pub mod sched;

pub use plan::{ImageSource, TilePlan, TileSlot};
pub use run::{run_tiled, BatchPayload, TileBatch, TileScratch, TiledResult};
pub use sched::TileScheduler;
