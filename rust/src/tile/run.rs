//! [`TileBatch`]: the execution half of arbitrary-extent serving — a
//! cooperative work queue of per-tile accelerator passes.
//!
//! One batch is one whole-image request: the plan's tiles are claimed
//! off a shared atomic cursor and executed through the design's cached
//! engine plan ([`crate::coordinator::Compiled::runner`] — fused
//! functional kernels when the design supports them, the
//! cycle-accurate simulator otherwise). **Any** thread may join the
//! drain via [`TileBatch::work`]: the standalone path
//! ([`run_tiled`]) spawns scoped helpers, while the serving worker
//! pool posts the batch to its own job queue so idle connection
//! workers pick tiles up and one large request saturates the pool
//! (`coordinator/serve.rs`). Progress never depends on helpers — the
//! submitting thread drains every unclaimed tile itself, so a fully
//! busy pool degrades to sequential execution, not deadlock.
//!
//! Claims come in short **runs**: one cursor `fetch_add` hands out up
//! to [`TileBatch::claim_run_len`] adjacent tiles, sized inversely to
//! the design's per-tile cost so cheap tiles amortize cursor traffic
//! while expensive (paper-scale) tiles keep the single-tile
//! granularity the scheduler's fairness interleaving relies on
//! ([`super::TileScheduler`]; `work_one` remains the explicit
//! one-tile unit).
//!
//! ## The steady-state drain allocates nothing
//!
//! Each participant drains through a [`TileScratch`]: pre-shaped
//! per-input slice tensors filled by [`TilePlan::gather_into`], a
//! reused tile-output tensor driven by
//! [`crate::exec::EngineRun::run_into`], and coordinate scratch for
//! the non-allocating scatter. Tiles land directly in the batch's
//! preallocated stitched output as they finish (under the state lock
//! — overlapping clamped tiles write bit-identical words, the lock
//! just keeps `Tensor::set` races out of the picture). The scratch is
//! design-level, not extent-level: the serving layer caches one per
//! design next to its cached runner, so a warm connection's
//! whole-image requests perform **zero per-tile heap allocations**
//! with the functional engine — the alloc-counter test pins it.
//!
//! [`TileBatch::wait`] blocks until every claimed tile has landed,
//! then hands over the stitched image and the summed per-tile
//! [`SimStats`] (the sequential-replay totals one accelerator would
//! spend).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Result};

use crate::cgra::SimStats;
use crate::coordinator::Compiled;
use crate::exec::{Engine, EngineRun};
use crate::tensor::Tensor;

use super::plan::{ImageSource, TilePlan};

/// Target output points per claim run: a run of cheap tiles amortizes
/// cursor contention up to roughly this much work, keeping the claim
/// granularity (and the scheduler's fairness) fine-grained in *time*
/// rather than in tiles.
const CLAIM_RUN_TARGET_POINTS: i64 = 2048;

/// Hard cap on tiles per claim run, however cheap the tiles are.
const CLAIM_RUN_MAX: usize = 8;

/// A stitched whole-image result.
pub struct TiledResult {
    /// Row-major over the plan's `out_box` (zero-based, the requested
    /// extents).
    pub output: Tensor,
    /// Field-wise sum of the per-tile runs.
    pub stats: SimStats,
    /// How many accelerator passes the image took.
    pub tiles: usize,
    /// The concrete engine that executed the passes (`Auto` resolved).
    pub engine: Engine,
}

/// One drain participant's reusable buffers, sized by the design (not
/// the extent — every [`TilePlan`] of a design shares the declared
/// per-tile boxes), so serving caches one per design alongside its
/// cached runner.
pub struct TileScratch {
    /// Per-input tile slices over the design's declared boxes.
    inputs: BTreeMap<String, Tensor>,
    /// Reused tile-output tensor ([`EngineRun::run_into`] rebinds it
    /// only when the layout changes).
    out: Option<Tensor>,
    /// Coordinate scratch for the odometer walks (max rank in play).
    ca: Vec<i64>,
    cb: Vec<i64>,
    /// Fresh tile-output bindings observed: the functional engine
    /// binds once and reuses; the simulator rebuilds per tile.
    allocs: u64,
}

impl TileScratch {
    pub fn new(plan: &TilePlan) -> TileScratch {
        let mut inputs = BTreeMap::new();
        let mut rank = plan.out_box.rank();
        for (name, b) in plan.input_names.iter().zip(&plan.compiled_input_boxes) {
            rank = rank.max(b.rank());
            inputs.insert(name.clone(), Tensor::zeros(b.clone()));
        }
        TileScratch { inputs, out: None, ca: vec![0; rank], cb: vec![0; rank], allocs: 0 }
    }

    /// Fresh tile-output bindings so far — frozen across warm drains
    /// with the functional engine (the alloc-counter test asserts it
    /// together with [`crate::exec::ExecRun::alloc_count`]).
    pub fn alloc_count(&self) -> u64 {
        self.allocs
    }
}

struct BatchState {
    /// The stitched image, preallocated at batch creation; tiles land
    /// in it as they finish. Taken (once) by [`TileBatch::wait`].
    output: Option<Tensor>,
    stats: SimStats,
    finished: usize,
    failed: Option<String>,
    engine_used: Option<Engine>,
}

/// The whole-image inputs a batch gathers from: owned tensors (the
/// in-process path), or the raw request frame buffer plus per-input
/// word ranges `(byte_off, words)` in plan order (the server's
/// zero-copy v3 path — the batch owns the frame bytes because any
/// pool worker may gather from them long after the submitting
/// connection's stack frame is gone).
pub enum BatchPayload {
    Tensors(BTreeMap<String, Tensor>),
    Frame { buf: Vec<u8>, ranges: Vec<(usize, usize)> },
}

/// One in-flight whole-image request (see module docs).
pub struct TileBatch {
    c: Arc<Compiled>,
    engine: Engine,
    plan: Arc<TilePlan>,
    payload: BatchPayload,
    /// Next unclaimed tile index; `>= tile_count` once drained (or
    /// poisoned to stop claims after a failure).
    next: AtomicUsize,
    /// Tiles handed out per cursor claim (see [`Self::claim_run_len`]).
    run_len: usize,
    state: Mutex<BatchState>,
    done: Condvar,
}

impl TileBatch {
    /// Validate the whole-image inputs against the plan and wrap the
    /// request for execution.
    pub fn new(
        c: Arc<Compiled>,
        engine: Engine,
        plan: Arc<TilePlan>,
        inputs: BTreeMap<String, Tensor>,
    ) -> Result<Arc<TileBatch>> {
        plan.check_inputs(&inputs)?;
        Self::with_payload(c, engine, plan, BatchPayload::Tensors(inputs))
    }

    /// The zero-copy constructor: whole-image inputs stay as
    /// little-endian words inside the request frame `buf`, one
    /// `(byte_off, word_count)` range per declared input in plan
    /// order. Word counts are validated against the plan's
    /// whole-image boxes (the serving layer has already diagnosed
    /// mismatches client-side; this guard keeps the batch honest for
    /// any other caller).
    pub fn new_frame(
        c: Arc<Compiled>,
        engine: Engine,
        plan: Arc<TilePlan>,
        buf: Vec<u8>,
        ranges: Vec<(usize, usize)>,
    ) -> Result<Arc<TileBatch>> {
        anyhow::ensure!(
            ranges.len() == plan.input_names.len(),
            "frame payload has {} inputs, plan declares {}",
            ranges.len(),
            plan.input_names.len()
        );
        for ((name, b), &(off, words)) in
            plan.input_names.iter().zip(&plan.input_boxes).zip(&ranges)
        {
            anyhow::ensure!(
                words as i64 == b.cardinality(),
                "input {name}: frame range has {words} words, whole-image box {b} needs {}",
                b.cardinality()
            );
            anyhow::ensure!(
                off + 4 * words <= buf.len(),
                "input {name}: frame range [{off}, {}) overruns the {}-byte buffer",
                off + 4 * words,
                buf.len()
            );
        }
        Self::with_payload(c, engine, plan, BatchPayload::Frame { buf, ranges })
    }

    fn with_payload(
        c: Arc<Compiled>,
        engine: Engine,
        plan: Arc<TilePlan>,
        payload: BatchPayload,
    ) -> Result<Arc<TileBatch>> {
        let output = Tensor::zeros(plan.out_box.clone());
        // K adaptive to tile cost: cheap tiles (small compiled tile
        // extents) batch up to CLAIM_RUN_MAX per cursor hit;
        // paper-scale tiles (≥ CLAIM_RUN_TARGET_POINTS output points)
        // keep run length 1, preserving single-tile fairness.
        let pts: i64 = c.tile_extent().iter().product();
        let run_len =
            ((CLAIM_RUN_TARGET_POINTS / pts.max(1)) as usize).clamp(1, CLAIM_RUN_MAX);
        Ok(Arc::new(TileBatch {
            c,
            engine,
            plan,
            payload,
            next: AtomicUsize::new(0),
            run_len,
            state: Mutex::new(BatchState {
                output: Some(output),
                stats: SimStats::default(),
                finished: 0,
                failed: None,
                engine_used: None,
            }),
            done: Condvar::new(),
        }))
    }

    /// The whole-image source for input `k` (named `name`), whichever
    /// payload variant backs it.
    fn source(&self, k: usize, name: &str) -> ImageSource<'_> {
        match &self.payload {
            BatchPayload::Tensors(m) => ImageSource::Tensor(&m[name]),
            BatchPayload::Frame { buf, ranges } => {
                let (off, words) = ranges[k];
                ImageSource::Frame {
                    shape: &self.plan.input_boxes[k],
                    bytes: &buf[off..off + 4 * words],
                }
            }
        }
    }

    pub fn tile_count(&self) -> usize {
        self.plan.tile_count()
    }

    /// The design this batch runs on — the scheduler key worker
    /// threads use to reuse a warmed runner/scratch across batches.
    pub fn compiled(&self) -> &Arc<Compiled> {
        &self.c
    }

    pub fn plan(&self) -> &Arc<TilePlan> {
        &self.plan
    }

    pub fn engine(&self) -> Engine {
        self.engine
    }

    /// Whether any tile is still unclaimed (claims may still be
    /// executing). The scheduler prunes drained batches on this.
    pub fn has_unclaimed(&self) -> bool {
        self.next.load(Ordering::Relaxed) < self.plan.tile_count()
    }

    /// Tiles still unclaimed — the scheduler's backlog contribution.
    pub fn unclaimed(&self) -> usize {
        self.plan.tile_count() - self.claimed()
    }

    /// Tiles claimed so far (capped at the tile count — the cursor
    /// overshoots on concurrent claims and failure poisoning).
    pub fn claimed(&self) -> usize {
        self.next.load(Ordering::Relaxed).min(self.plan.tile_count())
    }

    /// Whether the batch has fully resolved: every tile landed, or
    /// the batch failed. Distinct from [`TileBatch::has_unclaimed`] —
    /// between a claim and its landing the batch has no unclaimed
    /// tiles but is not yet done.
    pub fn is_done(&self) -> bool {
        let st = self.lock();
        st.failed.is_some() || st.finished == self.plan.tile_count()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BatchState> {
        // A panicking claimant already recorded its failure through
        // the catch_unwind in `step`; the state it guards is only
        // counters and tensors written whole, so recovery is safe.
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn fail(&self, msg: String) {
        self.next.store(self.plan.tile_count(), Ordering::Relaxed);
        let mut st = self.lock();
        if st.failed.is_none() {
            st.failed = Some(msg);
        }
        drop(st);
        self.done.notify_all();
    }

    /// Claim and execute tiles until none remain unclaimed; safe to
    /// call from any number of threads, and returns quickly when the
    /// batch is already drained (stale helper wake-ups are free).
    /// Each participant builds one engine runner and one scratch on
    /// its first pass and reuses them for every subsequent claim run.
    pub fn work(&self) {
        if !self.has_unclaimed() {
            return; // stale wake-up: no claims left, nothing to build
        }
        let mut ctx: Option<(EngineRun, TileScratch)> = None;
        loop {
            if ctx.is_none() {
                match self.c.runner(self.engine) {
                    Ok(r) => ctx = Some((r, TileScratch::new(&self.plan))),
                    Err(e) => return self.fail(format!("building engine runner: {e:#}")),
                }
            }
            let (r, scratch) = ctx.as_mut().expect("runner just built");
            if self.work_run(r, scratch) == 0 {
                return;
            }
        }
    }

    /// [`TileBatch::work`] with caller-provided runner and scratch —
    /// the serving path lends its per-design cached [`EngineRun`] and
    /// [`TileScratch`] so a v3 request on a warm connection pays no
    /// setup and no per-tile allocation.
    pub fn work_with(&self, runner: &mut EngineRun, scratch: &mut TileScratch) {
        while self.work_run(runner, scratch) > 0 {}
    }

    /// Tiles handed out per cursor claim for this batch (adaptive to
    /// the design's per-tile cost; `1` for paper-scale tiles).
    pub fn claim_run_len(&self) -> usize {
        self.run_len
    }

    /// Claim and execute one **run** of up to [`Self::claim_run_len`]
    /// adjacent tiles with a single cursor `fetch_add`; returns how
    /// many tiles this call drained (`0` when nothing was left to
    /// claim). The scheduler's drain unit: a worker drains one short
    /// run, then re-asks the scheduler which batch deserves its next
    /// claim, so no single large batch monopolizes a thread other
    /// requests are waiting on — runs stay short in *work* because
    /// `run_len` shrinks to 1 as tiles get expensive. A failed step
    /// still counts as drained — the claim was spent; the failure is
    /// recorded on the batch (and poisons the cursor, ending the run's
    /// remainder along with everyone else's claims).
    pub fn work_run(&self, runner: &mut EngineRun, scratch: &mut TileScratch) -> usize {
        let count = self.plan.tile_count();
        let i = self.next.fetch_add(self.run_len, Ordering::Relaxed);
        if i >= count {
            return 0;
        }
        let mut done = 0;
        for t in i..(i + self.run_len).min(count) {
            done += 1;
            if !self.step(t, runner, scratch) {
                break;
            }
        }
        if crate::telemetry::sampling() {
            crate::telemetry::metrics().sched_claim_runs.inc();
        }
        done
    }

    /// Claim and execute exactly **one** tile; `false` when nothing
    /// was left to claim. The explicit single-tile unit (claim-run
    /// length 1 regardless of tile cost) — the scheduler fairness
    /// tests pin their interleaving with it. A failed step still
    /// returns `true` — a claim was spent; the failure is recorded on
    /// the batch.
    pub fn work_one(&self, runner: &mut EngineRun, scratch: &mut TileScratch) -> bool {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        if i >= self.plan.tile_count() {
            return false;
        }
        self.step(i, runner, scratch);
        true
    }

    /// Execute one claimed tile: gather into the scratch slices, run
    /// into the reused tile output, scatter into the stitched image.
    /// Returns `false` when the batch failed and the claimant should
    /// stop.
    ///
    /// §Telemetry: when serving has sampling on
    /// ([`crate::telemetry::sampling`]), each successful tile bumps
    /// `tiles_executed` and records its wall time (gather + engine run
    /// + scatter) into the `tile_exec` histogram — a handful of atomic
    /// ops, no allocation, so the zero-allocation steady-state
    /// contract above holds with sampling on. Off, the hook is one
    /// relaxed bool load.
    fn step(&self, i: usize, r: &mut EngineRun, scratch: &mut TileScratch) -> bool {
        let sampled_t0 = crate::telemetry::sampling().then(std::time::Instant::now);
        let slot = &self.plan.tiles[i];
        // A panic inside an engine must not strand the batch: the
        // submitter waits on the finished count, so every claimed
        // tile has to resolve to a result or a recorded failure.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for (k, name) in self.plan.input_names.iter().enumerate() {
                let dst = scratch.inputs.get_mut(name).expect("scratch covers inputs");
                self.plan.gather_into(
                    k,
                    slot,
                    self.source(k, name),
                    dst,
                    &mut scratch.ca,
                    &mut scratch.cb,
                );
            }
            r.run_into(&scratch.inputs, &mut scratch.out)
        }));
        match outcome {
            Ok(Ok((stats, fresh))) => {
                if fresh {
                    scratch.allocs += 1;
                }
                let tile_out = scratch.out.as_ref().expect("run_into bound the output");
                let mut st = self.lock();
                st.engine_used.get_or_insert(r.engine());
                st.stats += stats;
                let out = st.output.as_mut().expect("result not yet consumed");
                self.plan.scatter_into(slot, tile_out, out, &mut scratch.ca, &mut scratch.cb);
                st.finished += 1;
                let all = st.finished == self.plan.tile_count();
                drop(st);
                if all {
                    self.done.notify_all();
                }
                if let Some(t0) = sampled_t0 {
                    let m = crate::telemetry::metrics();
                    m.tiles_executed.inc();
                    m.tile_exec.record_ns(t0.elapsed().as_nanos() as u64);
                }
                true
            }
            Ok(Err(e)) => {
                self.fail(format!("tile {i}: {e:#}"));
                false
            }
            Err(_) => {
                self.fail(format!("tile {i}: engine panicked"));
                false
            }
        }
    }

    /// Block until every tile has finished (or the batch failed), then
    /// hand over the stitched result. Callable from the submitting
    /// thread while helpers are still landing their last claims.
    /// Consumes the result: a second call reports an error.
    pub fn wait(&self) -> Result<TiledResult> {
        let mut st = self.lock();
        loop {
            if let Some(e) = &st.failed {
                bail!("tiled execution failed: {e}");
            }
            if st.finished == self.plan.tile_count() {
                break;
            }
            st = self.done.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        let Some(output) = st.output.take() else {
            bail!("tiled result already consumed by an earlier wait()");
        };
        Ok(TiledResult {
            output,
            stats: st.stats,
            tiles: self.plan.tile_count(),
            engine: st.engine_used.unwrap_or(self.engine),
        })
    }

    /// Drain the batch on the calling thread plus up to `workers - 1`
    /// scoped helper threads — the standalone (CLI / test / bench)
    /// path; serving recruits its worker pool instead.
    pub fn run_local(self: &Arc<Self>, workers: usize) -> Result<TiledResult> {
        let helpers = workers
            .saturating_sub(1)
            .min(self.tile_count().saturating_sub(1));
        std::thread::scope(|s| {
            for _ in 0..helpers {
                let b = Arc::clone(self);
                s.spawn(move || b.work());
            }
            self.work();
        });
        self.wait()
    }
}

/// One-call tiled execution: plan (cached on `c`), batch, drain with
/// `workers` threads, stitch.
pub fn run_tiled(
    c: &Arc<Compiled>,
    engine: Engine,
    extent: &[i64],
    inputs: BTreeMap<String, Tensor>,
    workers: usize,
) -> Result<TiledResult> {
    let plan = c.tile_plan(extent)?;
    TileBatch::new(Arc::clone(c), engine, plan, inputs)?.run_local(workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::compile;
    use crate::halide::lower;

    /// Whole-image host golden: the same program lowered at
    /// `tile = extent`, executed functionally.
    fn golden(
        name_tile: i64,
        extent: &[i64],
    ) -> (BTreeMap<String, Tensor>, Tensor) {
        let mut p = apps::gaussian::build(name_tile);
        p.schedule.tile = extent.to_vec();
        let lp = lower::lower(&p).unwrap();
        let inputs = crate::coordinator::gen_inputs(&lp);
        let out = lp.execute(&inputs).unwrap()[&lp.output].clone();
        (inputs, out)
    }

    #[test]
    fn stitched_output_matches_whole_image_golden() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        for extent in [vec![33, 20], vec![9, 9], vec![14, 14], vec![28, 28]] {
            let (inputs, want) = golden(14, &extent);
            for engine in [Engine::Exec, Engine::ExecScalar, Engine::Sim] {
                let res =
                    run_tiled(&c, engine, &extent, inputs.clone(), 3).unwrap();
                assert_eq!(res.engine, engine);
                assert!(res.tiles >= 1);
                res.output.shape.for_each_point(|p| {
                    assert_eq!(
                        res.output.get(p),
                        want.get(p),
                        "{engine:?} {extent:?} at {p:?}"
                    );
                });
            }
        }
    }

    #[test]
    fn stats_aggregate_across_tiles() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let (inputs, _) = golden(14, &[28, 28]);
        let res = run_tiled(&c, Engine::Exec, &[28, 28], inputs, 2).unwrap();
        assert_eq!(res.tiles, 4);
        // Four full passes: exactly four times one pass's cycles.
        let one = c.graph.completion;
        assert_eq!(res.stats.cycles, 4 * one);
        assert_eq!(res.output.shape.cardinality(), 28 * 28);
    }

    #[test]
    fn bad_inputs_rejected_up_front() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let plan = c.tile_plan(&[28, 28]).unwrap();
        let err = TileBatch::new(
            Arc::clone(&c),
            Engine::Exec,
            plan,
            BTreeMap::new(),
        )
        .err()
        .expect("missing inputs must fail");
        assert!(format!("{err:#}").contains("missing input"), "{err:#}");
    }

    /// A frame-payload batch (the server's zero-copy v3 path) stitches
    /// the same image as the tensor-payload batch, and `new_frame`
    /// rejects ranges that do not match the plan's whole-image boxes.
    #[test]
    fn frame_payload_batch_matches_tensor_batch() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let plan = c.tile_plan(&[33, 20]).unwrap();
        let (inputs, want) = golden(14, &[33, 20]);
        // Serialize the inputs the way a v3 frame carries them:
        // concatenated little-endian row-major words, one range each.
        let mut buf = Vec::new();
        let mut ranges = Vec::new();
        for name in &plan.input_names {
            let t = &inputs[name];
            ranges.push((buf.len(), t.data.len()));
            for w in &t.data {
                buf.extend_from_slice(&w.to_le_bytes());
            }
        }
        let b = TileBatch::new_frame(
            Arc::clone(&c),
            Engine::Exec,
            Arc::clone(&plan),
            buf.clone(),
            ranges.clone(),
        )
        .unwrap();
        b.work();
        let res = b.wait().unwrap();
        assert_eq!(res.tiles, plan.tile_count());
        res.output.shape.for_each_point(|p| {
            assert_eq!(res.output.get(p), want.get(p), "at {p:?}");
        });

        // Wrong word count and buffer overrun are rejected up front.
        let mut short = ranges.clone();
        short[0].1 -= 1;
        assert!(TileBatch::new_frame(
            Arc::clone(&c),
            Engine::Exec,
            Arc::clone(&plan),
            buf.clone(),
            short
        )
        .is_err());
        let mut shifted = ranges.clone();
        shifted[0].0 += 8;
        assert!(TileBatch::new_frame(
            Arc::clone(&c),
            Engine::Exec,
            Arc::clone(&plan),
            buf.clone(),
            shifted
        )
        .is_err());
        assert!(TileBatch::new_frame(Arc::clone(&c), Engine::Exec, plan, buf, vec![]).is_err());
    }

    /// `work_one` claims exactly one tile per call and reports when
    /// the batch has nothing left; the bookkeeping accessors the
    /// scheduler relies on track it.
    #[test]
    fn work_one_claims_a_single_tile() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let plan = c.tile_plan(&[28, 28]).unwrap();
        let (inputs, _) = golden(14, &[28, 28]);
        let b = TileBatch::new(Arc::clone(&c), Engine::Exec, plan, inputs).unwrap();
        let mut runner = c.runner(Engine::Exec).unwrap();
        let mut scratch = TileScratch::new(b.plan());
        assert_eq!(b.tile_count(), 4);
        for k in 1..=4 {
            assert!(b.has_unclaimed());
            assert!(!b.is_done());
            assert!(b.work_one(&mut runner, &mut scratch));
            assert_eq!(b.claimed(), k);
            assert_eq!(b.unclaimed(), 4 - k);
        }
        assert!(!b.has_unclaimed());
        assert!(b.is_done());
        assert!(!b.work_one(&mut runner, &mut scratch));
        assert_eq!(b.claimed(), 4);
        assert!(b.wait().is_ok());
    }

    /// Claim runs adapt to tile cost: cheap 14×14 tiles (196 output
    /// points) batch up to 8 per cursor hit — one `work_run` drains
    /// this whole 4-tile batch — while tiles at or above the
    /// 2048-point target keep the single-tile claim unit the
    /// scheduler's fairness granularity relies on.
    #[test]
    fn claim_runs_adapt_to_tile_cost() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let plan = c.tile_plan(&[28, 28]).unwrap();
        let (inputs, _) = golden(14, &[28, 28]);
        let b = TileBatch::new(Arc::clone(&c), Engine::Exec, plan, inputs).unwrap();
        assert_eq!(b.claim_run_len(), 8, "2048 / 196 clamps to the max run");
        let mut runner = c.runner(Engine::Exec).unwrap();
        let mut scratch = TileScratch::new(b.plan());
        assert_eq!(b.tile_count(), 4);
        assert_eq!(b.work_run(&mut runner, &mut scratch), 4);
        assert!(!b.has_unclaimed());
        assert_eq!(b.claimed(), 4);
        assert_eq!(b.work_run(&mut runner, &mut scratch), 0);
        assert!(b.is_done());
        assert!(b.wait().is_ok());

        // Paper-scale tiles: 48×48 = 2304 ≥ 2048 points → runs of 1.
        let big = Arc::new(compile(&apps::gaussian::build(48)).unwrap());
        let plan = big.tile_plan(&[48, 48]).unwrap();
        let (inputs, _) = golden(48, &[48, 48]);
        let b = TileBatch::new(Arc::clone(&big), Engine::Exec, plan, inputs).unwrap();
        assert_eq!(b.claim_run_len(), 1);
    }

    /// The zero-allocation **and zero-spawn** contract of the
    /// steady-state drain: after one warm-up batch, further batches
    /// through the same runner + scratch freeze both allocation
    /// counters (the engine arena's and the tile scratch's) and the
    /// compute pool's spawn counter.
    #[test]
    fn steady_state_tile_drain_does_not_allocate() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let plan = c.tile_plan(&[33, 20]).unwrap();
        let (inputs, _) = golden(14, &[33, 20]);
        let mut runner = c.runner(Engine::Exec).unwrap();
        let mut scratch = TileScratch::new(&plan);
        let exec_allocs = |r: &EngineRun| match r {
            EngineRun::Exec(e) => e.alloc_count(),
            EngineRun::Sim(_) => unreachable!("Engine::Exec requested"),
        };
        let drain = |runner: &mut EngineRun, scratch: &mut TileScratch| {
            let b = TileBatch::new(
                Arc::clone(&c),
                Engine::Exec,
                Arc::clone(&plan),
                inputs.clone(),
            )
            .unwrap();
            b.work_with(runner, scratch);
            b.wait().unwrap()
        };
        let first = drain(&mut runner, &mut scratch);
        let frozen = (exec_allocs(&runner), scratch.alloc_count());
        for _ in 0..2 {
            let warm = drain(&mut runner, &mut scratch);
            assert_eq!(warm.output.data, first.output.data);
        }
        assert_eq!(
            (exec_allocs(&runner), scratch.alloc_count()),
            frozen,
            "steady-state drain allocated"
        );
        // Zero-spawn half of the warm contract: drained tiles never
        // spawn threads. Concurrent tests may grow the pool, so only
        // a spawn on every attempt is a real regression.
        let mut zero_spawn = false;
        for _ in 0..5 {
            let before = crate::exec::pool::spawn_count();
            drain(&mut runner, &mut scratch);
            if crate::exec::pool::spawn_count() == before {
                zero_spawn = true;
                break;
            }
        }
        assert!(zero_spawn, "steady-state drain spawned threads");
    }
}
