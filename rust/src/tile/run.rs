//! [`TileBatch`]: the execution half of arbitrary-extent serving — a
//! cooperative work queue of per-tile accelerator passes.
//!
//! One batch is one whole-image request: the plan's tiles are claimed
//! off a shared atomic cursor and executed through the design's cached
//! engine plan ([`crate::coordinator::Compiled::runner`] — fused
//! functional kernels when the design supports them, the
//! cycle-accurate simulator otherwise). **Any** thread may join the
//! drain via [`TileBatch::work`]: the standalone path
//! ([`run_tiled`]) spawns scoped helpers, while the serving worker
//! pool posts the batch to its own job queue so idle connection
//! workers pick tiles up and one large request saturates the pool
//! (`coordinator/serve.rs`). Progress never depends on helpers — the
//! submitting thread drains every unclaimed tile itself, so a fully
//! busy pool degrades to sequential execution, not deadlock.
//!
//! [`TileBatch::wait`] blocks until every claimed tile has landed,
//! then stitches the clipped tile outputs into the whole image and
//! sums the per-tile [`SimStats`] (the sequential-replay totals one
//! accelerator would spend).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Result};

use crate::cgra::{SimResult, SimStats};
use crate::coordinator::Compiled;
use crate::exec::Engine;
use crate::tensor::Tensor;

use super::plan::TilePlan;

/// A stitched whole-image result.
pub struct TiledResult {
    /// Row-major over the plan's `out_box` (zero-based, the requested
    /// extents).
    pub output: Tensor,
    /// Field-wise sum of the per-tile runs.
    pub stats: SimStats,
    /// How many accelerator passes the image took.
    pub tiles: usize,
    /// The concrete engine that executed the passes (`Auto` resolved).
    pub engine: Engine,
}

struct BatchState {
    results: Vec<Option<SimResult>>,
    finished: usize,
    failed: Option<String>,
    engine_used: Option<Engine>,
}

/// One in-flight whole-image request (see module docs).
pub struct TileBatch {
    c: Arc<Compiled>,
    engine: Engine,
    plan: Arc<TilePlan>,
    inputs: BTreeMap<String, Tensor>,
    /// Next unclaimed tile index; `>= tile_count` once drained (or
    /// poisoned to stop claims after a failure).
    next: AtomicUsize,
    state: Mutex<BatchState>,
    done: Condvar,
}

impl TileBatch {
    /// Validate the whole-image inputs against the plan and wrap the
    /// request for execution.
    pub fn new(
        c: Arc<Compiled>,
        engine: Engine,
        plan: Arc<TilePlan>,
        inputs: BTreeMap<String, Tensor>,
    ) -> Result<Arc<TileBatch>> {
        plan.check_inputs(&inputs)?;
        let tiles = plan.tile_count();
        Ok(Arc::new(TileBatch {
            c,
            engine,
            plan,
            inputs,
            next: AtomicUsize::new(0),
            state: Mutex::new(BatchState {
                results: (0..tiles).map(|_| None).collect(),
                finished: 0,
                failed: None,
                engine_used: None,
            }),
            done: Condvar::new(),
        }))
    }

    pub fn tile_count(&self) -> usize {
        self.plan.tile_count()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BatchState> {
        // A panicking claimant already recorded its failure through
        // the catch_unwind in `work`; the state it guards is only
        // Options and counters, so recovery is safe.
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn fail(&self, msg: String) {
        self.next.store(self.plan.tile_count(), Ordering::Relaxed);
        let mut st = self.lock();
        if st.failed.is_none() {
            st.failed = Some(msg);
        }
        drop(st);
        self.done.notify_all();
    }

    /// Claim and execute tiles until none remain unclaimed; safe to
    /// call from any number of threads, and returns quickly when the
    /// batch is already drained (stale helper wake-ups are free).
    /// Each participant builds one engine runner lazily on its first
    /// claim and reuses it for every subsequent tile.
    pub fn work(&self) {
        let mut runner = None;
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.plan.tile_count() {
                return;
            }
            if runner.is_none() {
                match self.c.runner(self.engine) {
                    Ok(r) => runner = Some(r),
                    Err(e) => return self.fail(format!("building engine runner: {e:#}")),
                }
            }
            if !self.step(i, runner.as_mut().expect("runner just built")) {
                return;
            }
        }
    }

    /// [`TileBatch::work`] with a caller-provided runner — the serving
    /// path lends its per-connection cached [`EngineRun`] so a v3
    /// request on a warm connection pays no runner setup, keeping the
    /// fixed-box path's "no per-request setup" invariant.
    pub fn work_with(&self, runner: &mut crate::exec::EngineRun) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.plan.tile_count() {
                return;
            }
            if !self.step(i, runner) {
                return;
            }
        }
    }

    /// Execute one claimed tile; returns `false` when the batch
    /// failed and the claimant should stop.
    fn step(&self, i: usize, r: &mut crate::exec::EngineRun) -> bool {
        // A panic inside an engine must not strand the batch: the
        // submitter waits on the finished count, so every claimed
        // tile has to resolve to a result or a recorded failure.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let slice = self.plan.gather(&self.plan.tiles[i], &self.inputs);
            r.run(&slice)
        }));
        match outcome {
            Ok(Ok(res)) => {
                let mut st = self.lock();
                st.engine_used.get_or_insert(r.engine());
                st.results[i] = Some(res);
                st.finished += 1;
                let all = st.finished == self.plan.tile_count();
                drop(st);
                if all {
                    self.done.notify_all();
                }
                true
            }
            Ok(Err(e)) => {
                self.fail(format!("tile {i}: {e:#}"));
                false
            }
            Err(_) => {
                self.fail(format!("tile {i}: engine panicked"));
                false
            }
        }
    }

    /// Block until every tile has finished (or the batch failed), then
    /// stitch. Callable from the submitting thread while helpers are
    /// still landing their last claims.
    pub fn wait(&self) -> Result<TiledResult> {
        let mut st = self.lock();
        loop {
            if let Some(e) = &st.failed {
                bail!("tiled execution failed: {e}");
            }
            if st.finished == self.plan.tile_count() {
                break;
            }
            st = self.done.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        let mut output = Tensor::zeros(self.plan.out_box.clone());
        let mut stats = SimStats::default();
        for (slot, res) in self.plan.tiles.iter().zip(&st.results) {
            let res = res.as_ref().expect("finished tile has a result");
            stats += res.stats;
            self.plan.scatter(slot, &res.output, &mut output);
        }
        Ok(TiledResult {
            output,
            stats,
            tiles: self.plan.tile_count(),
            engine: st.engine_used.unwrap_or(self.engine),
        })
    }

    /// Drain the batch on the calling thread plus up to `workers - 1`
    /// scoped helper threads — the standalone (CLI / test / bench)
    /// path; serving recruits its worker pool instead.
    pub fn run_local(self: &Arc<Self>, workers: usize) -> Result<TiledResult> {
        let helpers = workers
            .saturating_sub(1)
            .min(self.tile_count().saturating_sub(1));
        std::thread::scope(|s| {
            for _ in 0..helpers {
                let b = Arc::clone(self);
                s.spawn(move || b.work());
            }
            self.work();
        });
        self.wait()
    }
}

/// One-call tiled execution: plan (cached on `c`), batch, drain with
/// `workers` threads, stitch.
pub fn run_tiled(
    c: &Arc<Compiled>,
    engine: Engine,
    extent: &[i64],
    inputs: BTreeMap<String, Tensor>,
    workers: usize,
) -> Result<TiledResult> {
    let plan = c.tile_plan(extent)?;
    TileBatch::new(Arc::clone(c), engine, plan, inputs)?.run_local(workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::compile;
    use crate::halide::lower;

    /// Whole-image host golden: the same program lowered at
    /// `tile = extent`, executed functionally.
    fn golden(
        name_tile: i64,
        extent: &[i64],
    ) -> (BTreeMap<String, Tensor>, Tensor) {
        let mut p = apps::gaussian::build(name_tile);
        p.schedule.tile = extent.to_vec();
        let lp = lower::lower(&p).unwrap();
        let inputs = crate::coordinator::gen_inputs(&lp);
        let out = lp.execute(&inputs).unwrap()[&lp.output].clone();
        (inputs, out)
    }

    #[test]
    fn stitched_output_matches_whole_image_golden() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        for extent in [vec![33, 20], vec![9, 9], vec![14, 14], vec![28, 28]] {
            let (inputs, want) = golden(14, &extent);
            for engine in [Engine::Exec, Engine::Sim] {
                let res =
                    run_tiled(&c, engine, &extent, inputs.clone(), 3).unwrap();
                assert_eq!(res.engine, engine);
                assert!(res.tiles >= 1);
                res.output.shape.for_each_point(|p| {
                    assert_eq!(
                        res.output.get(p),
                        want.get(p),
                        "{engine:?} {extent:?} at {p:?}"
                    );
                });
            }
        }
    }

    #[test]
    fn stats_aggregate_across_tiles() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let (inputs, _) = golden(14, &[28, 28]);
        let res = run_tiled(&c, Engine::Exec, &[28, 28], inputs, 2).unwrap();
        assert_eq!(res.tiles, 4);
        // Four full passes: exactly four times one pass's cycles.
        let one = c.graph.completion;
        assert_eq!(res.stats.cycles, 4 * one);
        assert_eq!(res.output.shape.cardinality(), 28 * 28);
    }

    #[test]
    fn bad_inputs_rejected_up_front() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let plan = c.tile_plan(&[28, 28]).unwrap();
        let err = TileBatch::new(
            Arc::clone(&c),
            Engine::Exec,
            plan,
            BTreeMap::new(),
        )
        .err()
        .expect("missing inputs must fail");
        assert!(format!("{err:#}").contains("missing input"), "{err:#}");
    }
}
