//! Dense integer tensors over [`BoxSet`] coordinate boxes.
//!
//! Used for host-side reference execution, the CGRA simulator's buffer
//! state, and golden-model comparison. Coordinates are *absolute* (a box
//! may start at a negative min, e.g. a stencil halo).

use crate::poly::set::BoxSet;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: BoxSet,
    strides: Vec<i64>,
    pub data: Vec<i32>,
}

impl Tensor {
    /// Row-major strides over `shape` — THE layout rule for every
    /// tensor: flat index = Σ (coord_k − min_k) · stride_k. The
    /// simulator's flat addressing ([`crate::cgra::SimPlan`]) builds
    /// on this exact function; keep any layout change here.
    pub fn row_major_strides(shape: &BoxSet) -> Vec<i64> {
        let mut strides = vec![0i64; shape.rank()];
        let mut s = 1i64;
        for k in (0..shape.rank()).rev() {
            strides[k] = s;
            s *= shape.dims[k].extent;
        }
        strides
    }

    /// Zero-filled tensor over `shape`.
    pub fn zeros(shape: BoxSet) -> Tensor {
        let strides = Self::row_major_strides(&shape);
        let len = shape.cardinality() as usize;
        Tensor { data: vec![0; len], strides, shape }
    }

    /// Build from row-major data in the box's lexicographic point order.
    pub fn from_data(shape: BoxSet, data: Vec<i32>) -> Tensor {
        let t = Tensor::zeros(shape);
        assert_eq!(data.len(), t.data.len(), "data length mismatch");
        Tensor { data, ..t }
    }

    /// Fill from a coordinate function.
    pub fn from_fn(shape: BoxSet, mut f: impl FnMut(&[i64]) -> i32) -> Tensor {
        let mut t = Tensor::zeros(shape.clone());
        for p in shape.points() {
            let v = f(&p);
            t.set(&p, v);
        }
        t
    }

    fn offset(&self, point: &[i64]) -> usize {
        debug_assert!(
            self.shape.contains(point),
            "point {point:?} outside {}",
            self.shape
        );
        self.shape
            .dims
            .iter()
            .zip(point)
            .zip(&self.strides)
            .map(|((d, &p), &s)| (p - d.min) * s)
            .sum::<i64>() as usize
    }

    pub fn get(&self, point: &[i64]) -> i32 {
        self.data[self.offset(point)]
    }

    pub fn set(&mut self, point: &[i64], v: i32) {
        let o = self.offset(point);
        self.data[o] = v;
    }

    /// Clamp-to-edge read (used when host code samples outside the halo).
    pub fn get_clamped(&self, point: &[i64]) -> i32 {
        let p: Vec<i64> = self
            .shape
            .dims
            .iter()
            .zip(point)
            .map(|(d, &v)| v.clamp(d.min, d.max()))
            .collect();
        self.get(&p)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::set::Dim;

    #[test]
    fn roundtrip_get_set() {
        let mut t = Tensor::zeros(BoxSet::from_extents(&[3, 4]));
        t.set(&[2, 3], 42);
        t.set(&[0, 0], -1);
        assert_eq!(t.get(&[2, 3]), 42);
        assert_eq!(t.get(&[0, 0]), -1);
        assert_eq!(t.get(&[1, 1]), 0);
    }

    #[test]
    fn negative_min_box() {
        let b = BoxSet::new(vec![Dim::new("y", -1, 4), Dim::new("x", -1, 4)]);
        let t = Tensor::from_fn(b, |p| (10 * p[0] + p[1]) as i32);
        assert_eq!(t.get(&[-1, -1]), -11);
        assert_eq!(t.get(&[2, 0]), 20);
    }

    #[test]
    fn from_data_lexicographic() {
        let t = Tensor::from_data(BoxSet::from_extents(&[2, 2]), vec![1, 2, 3, 4]);
        assert_eq!(t.get(&[0, 0]), 1);
        assert_eq!(t.get(&[0, 1]), 2);
        assert_eq!(t.get(&[1, 0]), 3);
        assert_eq!(t.get(&[1, 1]), 4);
    }

    #[test]
    fn clamped_reads() {
        let t = Tensor::from_data(BoxSet::from_extents(&[2, 2]), vec![1, 2, 3, 4]);
        assert_eq!(t.get_clamped(&[-5, 0]), 1);
        assert_eq!(t.get_clamped(&[1, 99]), 4);
    }
}
