//! Process-global serving telemetry: lock-free metrics, request
//! spans, and the snapshot surface behind `pushmem stats`
//! (docs/observability.md).
//!
//! Three layers, std-only:
//!
//! * **Registry** — a fixed set of saturating atomic [`Counter`]s,
//!   [`Gauge`]s, and log-linear latency [`Histogram`]s
//!   ([`hist`]), owned by one process-global [`Metrics`] instance.
//!   Every mutation is a handful of relaxed/acq-rel atomic ops; there
//!   is no lock anywhere on the recording path. Counters saturate at
//!   `u64::MAX` instead of wrapping, mirroring the `SimStats`
//!   saturating-sum semantics the serving stats already use.
//! * **Spans** — the serving path builds one [`RequestRecord`] per
//!   request ([`span`]) and feeds it through [`Metrics::record_request`],
//!   which updates the counters and stage histograms and retains the
//!   most recent records in a bounded ring. The `--stats` `[req]`
//!   line is printed from the *same record*, so the flag and the
//!   metrics snapshot can never disagree.
//! * **Snapshot** — [`Metrics::snapshot`] freezes a consistent
//!   point-in-time [`Snapshot`], serializable to JSON with a tiny
//!   std-only emitter (the same idiom as the bench harness's
//!   `BENCH_*.json` writer). The wire `STATS` frame, the
//!   `--metrics-json` periodic dump, and the bench embedding all
//!   serialize this one type.
//!
//! ## Hot-path hooks cost ~nothing when off
//!
//! The exec/tile hot paths (`exec/run.rs`, `tile/run.rs`) only touch
//! the registry when [`sampling`] is on — a single relaxed
//! `AtomicBool` load per kernel dispatch / per tile otherwise, and
//! never a heap allocation either way (the zero-allocation
//! steady-state contracts from PR 6 hold with sampling on; the
//! alloc-counter tests pin them). Serving turns sampling on; the CLI
//! run/tune/fuzz paths leave it off. See DESIGN.md §8 for the
//! overhead argument.
//!
//! ## Snapshot consistency under concurrent writers
//!
//! Writers publish with release ordering in a fixed field order
//! (`requests_total` before `requests_ok`/`requests_failed`;
//! histogram buckets before the histogram count) and [`Metrics::snapshot`]
//! reads with acquire ordering in the *opposite* order, so every
//! snapshot satisfies `requests_ok + requests_failed <= requests_total`
//! and `sum(buckets) >= count` even while requests are in flight —
//! pinned by a concurrent-writer test.

pub mod hist;
pub mod log;
pub mod span;

pub use hist::{Histogram, HistogramSnapshot};
pub use span::{RecentRing, RequestRecord};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Acceptor-shard ceiling: the per-shard accept counters are a fixed
/// array so the recording path stays lock-free (`PUSHMEM_ACCEPT_SHARDS`
/// is clamped to this in `coordinator/serve.rs`).
pub const MAX_ACCEPT_SHARDS: usize = 8;

/// Variant-set ceiling for load-adaptive serving (docs/routing.md):
/// at most the three tuned frontier roles plus the hand-written
/// fallback. Fixed so the per-variant request counters are a plain
/// array and the recording path stays lock-free, exactly like
/// [`MAX_ACCEPT_SHARDS`].
pub const MAX_VARIANTS: usize = 4;

/// The closed set of variant roles, in counter-index order. These are
/// the only values a served request's `variant` field takes —
/// `coordinator/route.rs` names its roles from this array, so the
/// telemetry names and the routing policy cannot drift apart.
pub const VARIANT_ROLES: [&str; MAX_VARIANTS] = ["latency", "energy", "area", "fallback"];

/// Index of a variant-role name in [`VARIANT_ROLES`] (`None` for
/// anything outside the closed set, e.g. the `"?"` placeholder on
/// failed requests).
pub fn variant_role_index(name: &str) -> Option<usize> {
    VARIANT_ROLES.iter().position(|r| *r == name)
}

/// Global sampling switch for the hot-path hooks. Off by default so
/// standalone CLI runs, the tuner, and the fuzz suites pay one
/// relaxed bool load per kernel dispatch and nothing else; the
/// serving loop turns it on.
static SAMPLING: AtomicBool = AtomicBool::new(false);

#[inline]
pub fn sampling() -> bool {
    SAMPLING.load(Ordering::Relaxed)
}

pub fn set_sampling(on: bool) {
    SAMPLING.store(on, Ordering::Relaxed);
}

/// A monotone saturating counter. `add` is an acq-rel RMW (so
/// cross-counter snapshot invariants hold — see the module docs);
/// overflow pins at `u64::MAX` instead of wrapping, mirroring
/// `SimStats`' saturating `AddAssign`.
pub struct Counter(AtomicU64);

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn add(&self, n: u64) {
        let prev = self.0.fetch_add(n, Ordering::AcqRel);
        if prev.checked_add(n).is_none() {
            // Wrapped: pin to the ceiling. Racing adders may observe
            // a transiently wrapped value, but the counter converges
            // to MAX and never reports a small value again.
            self.0.store(u64::MAX, Ordering::Release);
        }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Acquire)
    }
}

/// An instantaneous level (queue depth, busy workers). Decrements
/// saturate at zero so a racing teardown can never underflow to
/// 2^64-1.
pub struct Gauge(AtomicU64);

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::AcqRel);
    }

    pub fn dec(&self) {
        let _ = self
            .0
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| Some(v.saturating_sub(1)));
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Release);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Acquire)
    }
}

/// The process-global metrics registry: every field is a named metric
/// surfaced verbatim in the snapshot (docs/observability.md lists
/// them all). A fixed struct, not a dynamic map: registration is a
/// field, lookup is a load, and the recording path stays lock-free.
pub struct Metrics {
    start: Instant,

    // -- serving counters ------------------------------------------
    pub connections_opened: Counter,
    pub connections_closed: Counter,
    pub requests_total: Counter,
    pub requests_ok: Counter,
    pub requests_failed: Counter,
    pub requests_v1: Counter,
    pub requests_v2: Counter,
    pub requests_v3: Counter,
    pub stats_requests: Counter,
    pub accept_errors: Counter,
    pub queue_full: Counter,
    /// Connections rejected at admission with `STATUS_BUSY` + a
    /// retry-after hint (docs/serving.md). On the serving path every
    /// `queue_full` event becomes exactly one `requests_busy`
    /// rejection — the reconciliation the loopback suite pins.
    pub requests_busy: Counter,
    /// Accepted connections per acceptor shard
    /// (`PUSHMEM_ACCEPT_SHARDS`); shards beyond the configured count
    /// stay zero.
    pub accepts_by_shard: [Counter; MAX_ACCEPT_SHARDS],
    pub words_in: Counter,
    pub words_out: Counter,
    /// Accelerator passes behind served OK responses (1 per fixed-box
    /// request, the plan's tile count per v3 request).
    pub tiles_served: Counter,
    /// OK responses served by each variant role ([`VARIANT_ROLES`]
    /// order: latency, energy, area, fallback). Fed from the request
    /// record, so at quiescence the four counters sum to exactly
    /// `requests_ok` — the reconciliation the stress smoke pins.
    pub requests_by_variant: [Counter; MAX_VARIANTS],
    /// Tuned records that failed to load, verify, or compile and fell
    /// back to the hand-written schedule (`coordinator/driver.rs`) —
    /// the previously-silent failure mode now also logged via
    /// [`log::warn`].
    pub tuned_fallbacks: Counter,

    // -- worker pool ------------------------------------------------
    pub jobs_conn: Counter,
    pub jobs_tiles: Counter,
    /// Tile plans actually built (cache misses on
    /// `Compiled::tile_plan`); coalesced same-extent requests share
    /// one build, so M concurrent identical v3 requests move this by
    /// exactly 1.
    pub tile_plan_builds: Counter,
    /// Batches admitted to the shared tile scheduler.
    pub sched_batches: Counter,
    /// Tiles a worker executed for a batch it did **not** submit —
    /// the cross-request work-stealing the scheduler exists for.
    pub sched_cross_tiles: Counter,
    /// Claim runs drained from tile batches (`TileBatch::work_run`);
    /// mean run length = tiles_executed / sched_claim_runs.
    pub sched_claim_runs: Counter,
    /// Summed wall time workers spent inside jobs; utilization =
    /// worker_busy_ns / (uptime * workers_total).
    pub worker_busy_ns: Counter,
    pub queue_depth: Gauge,
    pub workers_busy: Gauge,
    pub workers_total: Gauge,
    /// Distinct (app, variant-role) pairs the routing policy has
    /// activated — the co-residency footprint on the array
    /// (docs/routing.md).
    pub active_variants: Gauge,

    // -- hot-path hooks (recorded only while `sampling()` is on) ----
    /// Tiles executed by the tile drain (`tile/run.rs`), whoever
    /// drained them; tiles/s = tiles_executed / uptime.
    pub tiles_executed: Counter,
    pub exec_kernels: Counter,
    /// Kernel dispatches that took the row-parallel path.
    pub exec_kernels_parallel: Counter,
    /// Summed thread fan-out actually used (vs the
    /// `PUSHMEM_EXEC_THREADS` cap in `exec_threads_cap`); mean
    /// fan-out = exec_threads_used / exec_kernels.
    pub exec_threads_used: Counter,
    /// Output points computed through the 8-wide lane path vs the
    /// scalar tail/reference walk: lane engagement =
    /// vector / (vector + scalar).
    pub exec_points_vector: Counter,
    pub exec_points_scalar: Counter,
    pub exec_threads_cap: Gauge,

    // -- compute pool (exec/pool.rs) --------------------------------
    /// Worker threads ever spawned by the persistent compute pool —
    /// flat once warm (the zero-spawn steady-state invariant; always
    /// recorded, spawning is never a sampled-only event).
    pub pool_spawns: Counter,
    /// Parallel kernel dispatches routed through the pool.
    pub pool_dispatches: Counter,
    /// Pool tasks run on claimed workers vs inline on the dispatcher
    /// (inline counts the dispatcher's own share plus saturation
    /// fallbacks); mean fan-out =
    /// (pool_tasks + pool_tasks_inline) / pool_dispatches.
    pub pool_tasks: Counter,
    pub pool_tasks_inline: Counter,
    /// Live pool workers (parked between dispatches).
    pub pool_workers: Gauge,

    // -- stage histograms (nanoseconds) -----------------------------
    pub accept_wait: Histogram,
    pub stage_decode: Histogram,
    pub stage_lookup: Histogram,
    pub stage_execute: Histogram,
    pub stage_stitch: Histogram,
    pub stage_respond: Histogram,
    pub request_total: Histogram,
    pub tile_exec: Histogram,

    recent: RecentRing,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    /// A fresh registry. Production code uses the process-global
    /// [`metrics`]; tests build private instances.
    pub fn new() -> Metrics {
        Metrics {
            start: Instant::now(),
            connections_opened: Counter::new(),
            connections_closed: Counter::new(),
            requests_total: Counter::new(),
            requests_ok: Counter::new(),
            requests_failed: Counter::new(),
            requests_v1: Counter::new(),
            requests_v2: Counter::new(),
            requests_v3: Counter::new(),
            stats_requests: Counter::new(),
            accept_errors: Counter::new(),
            queue_full: Counter::new(),
            requests_busy: Counter::new(),
            accepts_by_shard: std::array::from_fn(|_| Counter::new()),
            words_in: Counter::new(),
            words_out: Counter::new(),
            tiles_served: Counter::new(),
            requests_by_variant: std::array::from_fn(|_| Counter::new()),
            tuned_fallbacks: Counter::new(),
            jobs_conn: Counter::new(),
            jobs_tiles: Counter::new(),
            tile_plan_builds: Counter::new(),
            sched_batches: Counter::new(),
            sched_cross_tiles: Counter::new(),
            sched_claim_runs: Counter::new(),
            worker_busy_ns: Counter::new(),
            queue_depth: Gauge::new(),
            workers_busy: Gauge::new(),
            workers_total: Gauge::new(),
            active_variants: Gauge::new(),
            tiles_executed: Counter::new(),
            exec_kernels: Counter::new(),
            exec_kernels_parallel: Counter::new(),
            exec_threads_used: Counter::new(),
            exec_points_vector: Counter::new(),
            exec_points_scalar: Counter::new(),
            exec_threads_cap: Gauge::new(),
            pool_spawns: Counter::new(),
            pool_dispatches: Counter::new(),
            pool_tasks: Counter::new(),
            pool_tasks_inline: Counter::new(),
            pool_workers: Gauge::new(),
            accept_wait: Histogram::new(),
            stage_decode: Histogram::new(),
            stage_lookup: Histogram::new(),
            stage_execute: Histogram::new(),
            stage_stitch: Histogram::new(),
            stage_respond: Histogram::new(),
            request_total: Histogram::new(),
            tile_exec: Histogram::new(),
            recent: RecentRing::new(),
        }
    }

    /// Fold one served request into the registry: counters, stage
    /// histograms (OK requests only, so every stage histogram's count
    /// equals `requests_ok`), and the recent-request ring. This is
    /// the single entry point the serving path uses — the `--stats`
    /// `[req]` line is printed from the same record afterwards, so
    /// the two surfaces cannot diverge.
    ///
    /// Write order matters: `requests_total` is incremented *before*
    /// the ok/failed split, and `requests_ok` before the per-variant
    /// counter (see the module docs on snapshot consistency).
    pub fn record_request(&self, rec: RequestRecord) {
        self.requests_total.inc();
        match rec.version {
            1 => self.requests_v1.inc(),
            2 => self.requests_v2.inc(),
            3 => self.requests_v3.inc(),
            // 0 = the request failed before its generation was known
            // (framing error); counted in total/failed only.
            _ => {}
        }
        self.words_in.add(rec.in_words);
        if rec.ok {
            self.words_out.add(rec.out_words);
            self.tiles_served.add(rec.tiles);
            self.stage_decode.record_ns(rec.decode_ns);
            self.stage_lookup.record_ns(rec.lookup_ns);
            self.stage_execute.record_ns(rec.execute_ns);
            self.stage_stitch.record_ns(rec.stitch_ns);
            self.stage_respond.record_ns(rec.respond_ns);
            self.request_total.record_ns(rec.total_ns);
            self.requests_ok.inc();
            // After requests_ok (the snapshot reads variants first),
            // so sum(requests_by_variant) <= requests_ok in every
            // snapshot and == at quiescence. Every served OK response
            // carries a role from the closed set; anything else would
            // break the stress smoke's exact reconciliation.
            if let Some(i) = variant_role_index(rec.variant) {
                self.requests_by_variant[i].inc();
            }
        } else {
            self.requests_failed.inc();
        }
        self.recent.push(rec);
    }

    /// Freeze a point-in-time snapshot. Reads the ok/failed split
    /// *before* `requests_total` (the reverse of the write order), so
    /// `ok + failed <= total` holds in every snapshot.
    pub fn snapshot(&self) -> Snapshot {
        // Variants before requests_ok (the reverse of the write
        // order), so sum(requests_by_variant) <= requests_ok holds in
        // every snapshot.
        let by_variant: [u64; MAX_VARIANTS] =
            std::array::from_fn(|i| self.requests_by_variant[i].get());
        let requests_ok = self.requests_ok.get();
        let requests_failed = self.requests_failed.get();
        let requests_total = self.requests_total.get();
        let counters = vec![
            ("connections_opened", self.connections_opened.get()),
            ("connections_closed", self.connections_closed.get()),
            ("requests_total", requests_total),
            ("requests_ok", requests_ok),
            ("requests_failed", requests_failed),
            ("requests_v1", self.requests_v1.get()),
            ("requests_v2", self.requests_v2.get()),
            ("requests_v3", self.requests_v3.get()),
            ("stats_requests", self.stats_requests.get()),
            ("accept_errors", self.accept_errors.get()),
            ("queue_full", self.queue_full.get()),
            ("requests_busy", self.requests_busy.get()),
            ("accepts_shard0", self.accepts_by_shard[0].get()),
            ("accepts_shard1", self.accepts_by_shard[1].get()),
            ("accepts_shard2", self.accepts_by_shard[2].get()),
            ("accepts_shard3", self.accepts_by_shard[3].get()),
            ("accepts_shard4", self.accepts_by_shard[4].get()),
            ("accepts_shard5", self.accepts_by_shard[5].get()),
            ("accepts_shard6", self.accepts_by_shard[6].get()),
            ("accepts_shard7", self.accepts_by_shard[7].get()),
            ("words_in", self.words_in.get()),
            ("words_out", self.words_out.get()),
            ("tiles_served", self.tiles_served.get()),
            ("requests_variant_latency", by_variant[0]),
            ("requests_variant_energy", by_variant[1]),
            ("requests_variant_area", by_variant[2]),
            ("requests_variant_fallback", by_variant[3]),
            ("tuned_fallbacks", self.tuned_fallbacks.get()),
            ("jobs_conn", self.jobs_conn.get()),
            ("jobs_tiles", self.jobs_tiles.get()),
            ("tile_plan_builds", self.tile_plan_builds.get()),
            ("sched_batches", self.sched_batches.get()),
            ("sched_cross_tiles", self.sched_cross_tiles.get()),
            ("sched_claim_runs", self.sched_claim_runs.get()),
            ("worker_busy_ns", self.worker_busy_ns.get()),
            ("tiles_executed", self.tiles_executed.get()),
            ("exec_kernels", self.exec_kernels.get()),
            ("exec_kernels_parallel", self.exec_kernels_parallel.get()),
            ("exec_threads_used", self.exec_threads_used.get()),
            ("exec_points_vector", self.exec_points_vector.get()),
            ("exec_points_scalar", self.exec_points_scalar.get()),
            ("pool_spawns", self.pool_spawns.get()),
            ("pool_dispatches", self.pool_dispatches.get()),
            ("pool_tasks", self.pool_tasks.get()),
            ("pool_tasks_inline", self.pool_tasks_inline.get()),
        ];
        let gauges = vec![
            ("queue_depth", self.queue_depth.get()),
            ("workers_busy", self.workers_busy.get()),
            ("workers_total", self.workers_total.get()),
            ("active_variants", self.active_variants.get()),
            ("exec_threads_cap", self.exec_threads_cap.get()),
            ("pool_workers", self.pool_workers.get()),
        ];
        let histograms = vec![
            ("accept_wait", self.accept_wait.snapshot()),
            ("stage_decode", self.stage_decode.snapshot()),
            ("stage_lookup", self.stage_lookup.snapshot()),
            ("stage_execute", self.stage_execute.snapshot()),
            ("stage_stitch", self.stage_stitch.snapshot()),
            ("stage_respond", self.stage_respond.snapshot()),
            ("request_total", self.request_total.snapshot()),
            ("tile_exec", self.tile_exec.snapshot()),
        ];
        Snapshot {
            uptime_s: self.start.elapsed().as_secs_f64(),
            counters,
            gauges,
            histograms,
            recent: self.recent.to_vec(),
        }
    }
}

/// The process-global registry (one per process, like the exec thread
/// cap). Lazy so library users who never serve pay nothing.
pub fn metrics() -> &'static Metrics {
    static METRICS: OnceLock<Metrics> = OnceLock::new();
    METRICS.get_or_init(Metrics::new)
}

/// A consistent point-in-time copy of the registry, the one type
/// every stats surface serializes: the wire `STATS` reply, the
/// `--metrics-json` dump, and the bench embedding.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub uptime_s: f64,
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub histograms: Vec<(&'static str, HistogramSnapshot)>,
    pub recent: Vec<RequestRecord>,
}

impl Snapshot {
    /// Named counter value (0 if absent — snapshots are forward
    /// compatible: readers must tolerate missing names).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .chain(self.gauges.iter())
            .find(|(n, _)| *n == name)
            .map_or(0, |&(_, v)| v)
    }

    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|(n, _)| *n == name).map(|(_, h)| h)
    }

    /// Serialize to one JSON object (docs/observability.md pins the
    /// shape). Std-only, same idiom as the bench harness emitter.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(2048);
        out.push_str("{\"schema\":\"pushmem-stats-v1\"");
        out.push_str(&format!(",\"uptime_s\":{:.6}", self.uptime_s));
        out.push_str(",\"counters\":{");
        for (i, (name, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{name}\":{v}"));
        }
        out.push_str("},\"gauges\":{");
        for (i, (name, v)) in self.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{name}\":{v}"));
        }
        out.push_str("},\"histograms\":{");
        for (i, (name, h)) in self.histograms.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\"{name}\":{{\"count\":{},\"sum_ns\":{},\"max_ns\":{},\"mean_ns\":{},\
                 \"p50_ns\":{},\"p90_ns\":{},\"p99_ns\":{},\"buckets\":[",
                h.count,
                h.sum_ns,
                h.max_ns,
                h.mean_ns(),
                h.quantile_ns(0.50),
                h.quantile_ns(0.90),
                h.quantile_ns(0.99),
            ));
            for (j, (b, n)) in h.buckets.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push_str(&format!("[{b},{n}]"));
            }
            out.push_str("]}");
        }
        out.push_str("},\"recent\":[");
        for (i, rec) in self.recent.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&rec.to_json());
        }
        out.push_str("]}");
        out
    }
}

/// Escape a string for inclusion in a JSON string literal (quotes,
/// backslashes, and control characters; everything else verbatim).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(ok: bool) -> RequestRecord {
        RequestRecord {
            app: "gaussian".into(),
            engine: "exec",
            variant: if ok { "latency" } else { "?" },
            version: 3,
            ok,
            tiles: 4,
            in_words: 770,
            out_words: 700,
            cycles: 100,
            queue_depth: 0,
            decode_ns: 10,
            lookup_ns: 20,
            execute_ns: 30,
            stitch_ns: 5,
            respond_ns: 15,
            total_ns: 80,
        }
    }

    /// Counter saturation mirrors `SimStats`' saturating `AddAssign`:
    /// once at the ceiling the counter stays there.
    #[test]
    fn counter_saturates_at_max() {
        let c = Counter::new();
        c.add(u64::MAX - 1);
        c.add(5); // would wrap
        assert_eq!(c.get(), u64::MAX);
        c.add(17); // stays pinned
        assert_eq!(c.get(), u64::MAX);
        c.inc();
        assert_eq!(c.get(), u64::MAX);
    }

    #[test]
    fn gauge_never_underflows() {
        let g = Gauge::new();
        g.inc();
        g.dec();
        g.dec(); // extra decrement: clamps at 0, no wraparound
        assert_eq!(g.get(), 0);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    /// The documented snapshot invariants hold while writers race:
    /// `ok + failed <= total`, and each stage histogram's bucket sum
    /// covers its count.
    #[test]
    fn snapshot_consistent_under_concurrent_writers() {
        let m = Metrics::new();
        const PER_THREAD: u64 = 500;
        std::thread::scope(|s| {
            for t in 0..4 {
                let m = &m;
                s.spawn(move || {
                    for i in 0..PER_THREAD {
                        m.record_request(rec((i + t) % 3 != 0));
                    }
                });
            }
            // Snapshot continuously while writers run.
            let m = &m;
            s.spawn(move || {
                let mut last_total = 0;
                for _ in 0..200 {
                    let snap = m.snapshot();
                    let total = snap.counter("requests_total");
                    let ok = snap.counter("requests_ok");
                    let failed = snap.counter("requests_failed");
                    assert!(
                        ok + failed <= total,
                        "ok {ok} + failed {failed} > total {total}"
                    );
                    let by_variant: u64 = VARIANT_ROLES
                        .iter()
                        .map(|r| snap.counter(&format!("requests_variant_{r}")))
                        .sum();
                    assert!(
                        by_variant <= ok,
                        "variants {by_variant} > ok {ok} mid-flight"
                    );
                    assert!(total >= last_total, "requests_total went backwards");
                    last_total = total;
                    for (name, h) in &snap.histograms {
                        let bucket_sum: u64 =
                            h.buckets.iter().map(|&(_, n)| n).sum();
                        assert!(
                            bucket_sum >= h.count,
                            "{name}: buckets {bucket_sum} < count {}",
                            h.count
                        );
                    }
                }
            });
        });
        let end = m.snapshot();
        assert_eq!(end.counter("requests_total"), 4 * PER_THREAD);
        assert_eq!(
            end.counter("requests_ok") + end.counter("requests_failed"),
            4 * PER_THREAD
        );
        // Quiescent reconciliation: variants sum to exactly ok.
        let by_variant: u64 = VARIANT_ROLES
            .iter()
            .map(|r| end.counter(&format!("requests_variant_{r}")))
            .sum();
        assert_eq!(by_variant, end.counter("requests_ok"));
        // OK-only histogram feeding: every stage histogram count
        // equals requests_ok exactly.
        for (name, h) in &end.histograms {
            if name.starts_with("stage_") || *name == "request_total" {
                assert_eq!(h.count, end.counter("requests_ok"), "{name}");
            }
        }
    }

    #[test]
    fn snapshot_json_is_well_formed_and_complete() {
        let m = Metrics::new();
        m.record_request(rec(true));
        m.record_request(rec(false));
        let snap = m.snapshot();
        let json = snap.to_json();
        assert!(json.starts_with("{\"schema\":\"pushmem-stats-v1\""), "{json}");
        for key in [
            "\"uptime_s\":",
            "\"counters\":{",
            "\"requests_total\":2",
            "\"requests_ok\":1",
            "\"requests_failed\":1",
            "\"requests_busy\":",
            "\"accepts_shard0\":",
            "\"accepts_shard7\":",
            "\"requests_variant_latency\":1",
            "\"requests_variant_energy\":0",
            "\"requests_variant_area\":0",
            "\"requests_variant_fallback\":0",
            "\"tuned_fallbacks\":",
            "\"tile_plan_builds\":",
            "\"sched_batches\":",
            "\"sched_cross_tiles\":",
            "\"gauges\":{",
            "\"queue_depth\":",
            "\"active_variants\":",
            "\"variant\":\"latency\"",
            "\"histograms\":{",
            "\"stage_decode\":{\"count\":1",
            "\"buckets\":[",
            "\"recent\":[{",
            "\"app\":\"gaussian\"",
        ] {
            assert!(json.contains(key), "{key} missing from {json}");
        }
        // Balanced braces/brackets (cheap well-formedness check; the
        // Python side parses the same JSON with a real parser).
        let opens = json.matches(['{', '[']).count();
        let closes = json.matches(['}', ']']).count();
        assert_eq!(opens, closes, "{json}");
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("x\ny"), "x\\ny");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    /// Every OK request carries a role from the closed set and the
    /// per-variant counters reconcile exactly with `requests_ok`;
    /// failed requests (variant `"?"`) count nowhere.
    #[test]
    fn variant_counters_reconcile_with_requests_ok() {
        assert_eq!(variant_role_index("latency"), Some(0));
        assert_eq!(variant_role_index("energy"), Some(1));
        assert_eq!(variant_role_index("area"), Some(2));
        assert_eq!(variant_role_index("fallback"), Some(3));
        assert_eq!(variant_role_index("?"), None);
        assert_eq!(variant_role_index("Latency"), None);

        let m = Metrics::new();
        for (i, role) in VARIANT_ROLES.iter().enumerate() {
            for _ in 0..=i {
                let mut r = rec(true);
                r.variant = role;
                m.record_request(r);
            }
        }
        m.record_request(rec(false)); // variant "?": failed, uncounted
        let snap = m.snapshot();
        assert_eq!(snap.counter("requests_variant_latency"), 1);
        assert_eq!(snap.counter("requests_variant_energy"), 2);
        assert_eq!(snap.counter("requests_variant_area"), 3);
        assert_eq!(snap.counter("requests_variant_fallback"), 4);
        let sum: u64 =
            VARIANT_ROLES.iter().map(|r| snap.counter(&format!("requests_variant_{r}"))).sum();
        assert_eq!(sum, snap.counter("requests_ok"));
        assert_eq!(snap.counter("requests_failed"), 1);
    }

    #[test]
    fn snapshot_counter_lookup_covers_gauges() {
        let m = Metrics::new();
        m.workers_total.set(8);
        let snap = m.snapshot();
        assert_eq!(snap.counter("workers_total"), 8);
        assert_eq!(snap.counter("no_such_metric"), 0);
    }
}
