//! Minimal leveled stderr logger for the serving path
//! (docs/observability.md).
//!
//! One line per event, structured as `key=value` pairs so operators
//! can grep and cut without a parser:
//!
//! ```text
//! log level=warn target=serve event=accept_error err=... suppressed=12
//! ```
//!
//! The level comes from `PUSHMEM_LOG` (`error|warn|info|debug`,
//! default `info`), read once per process. There is deliberately no
//! timestamp machinery or formatting framework — the serving stack is
//! std-only, and anything heavier belongs in the metrics registry,
//! not stderr. The `[req]` per-request line printed under `--stats`
//! does NOT route through here: its format is a stable script
//! interface (see `coordinator/serve.rs`) and it prints regardless of
//! level.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Severity, ordered: a configured level admits itself and everything
/// more severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `PUSHMEM_LOG` value; unknown strings fall back to
    /// `Info` (a typo must not silence error reporting — erring
    /// toward chatty is the safe direction).
    pub fn parse(s: &str) -> Level {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" | "0" => Level::Error,
            "warn" | "warning" | "1" => Level::Warn,
            "debug" | "3" => Level::Debug,
            _ => Level::Info,
        }
    }
}

fn configured() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(|| match std::env::var("PUSHMEM_LOG") {
        Ok(v) => Level::parse(&v),
        Err(_) => Level::Info,
    })
}

/// Is `level` admitted by the configured threshold? Callers use this
/// to skip formatting entirely on the fast path.
pub fn enabled(level: Level) -> bool {
    level <= configured()
}

/// Emit one structured line to stderr (no-op above the configured
/// level). `msg` should be `key=value` pairs.
pub fn write(level: Level, target: &str, msg: &str) {
    if enabled(level) {
        eprintln!("log level={} target={target} {msg}", level.name());
    }
}

pub fn error(target: &str, msg: &str) {
    write(Level::Error, target, msg);
}

pub fn warn(target: &str, msg: &str) {
    write(Level::Warn, target, msg);
}

pub fn info(target: &str, msg: &str) {
    write(Level::Info, target, msg);
}

pub fn debug(target: &str, msg: &str) {
    write(Level::Debug, target, msg);
}

/// Token-bucket-of-one rate limiter for repetitive failure paths
/// (e.g. a listener stuck on EMFILE returning accept errors in a
/// tight loop): admits at most one log line per interval and counts
/// what it suppressed, so the operator sees both the error and its
/// rate without stderr flooding.
pub struct RateLimited {
    interval: Duration,
    last: Mutex<Option<Instant>>,
    suppressed: AtomicU64,
}

impl RateLimited {
    pub fn new(interval: Duration) -> RateLimited {
        RateLimited { interval, last: Mutex::new(None), suppressed: AtomicU64::new(0) }
    }

    /// `Some(suppressed_since_last)` when the caller should log now,
    /// `None` when the event should be counted silently.
    pub fn admit(&self) -> Option<u64> {
        let mut last = self.last.lock().unwrap_or_else(|p| p.into_inner());
        let now = Instant::now();
        match *last {
            Some(t) if now.duration_since(t) < self.interval => {
                self.suppressed.fetch_add(1, Ordering::Relaxed);
                None
            }
            _ => {
                *last = Some(now);
                Some(self.suppressed.swap(0, Ordering::Relaxed))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_ordering() {
        assert_eq!(Level::parse("error"), Level::Error);
        assert_eq!(Level::parse("WARN"), Level::Warn);
        assert_eq!(Level::parse(" debug "), Level::Debug);
        // Unknown values fall back to Info, never to silence.
        assert_eq!(Level::parse("verbose"), Level::Info);
        assert_eq!(Level::parse(""), Level::Info);
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn rate_limiter_admits_once_per_interval() {
        let rl = RateLimited::new(Duration::from_secs(3600));
        assert_eq!(rl.admit(), Some(0)); // first event always logs
        for _ in 0..5 {
            assert_eq!(rl.admit(), None); // within the interval: counted
        }
        // A zero-interval limiter admits every event and reports the
        // backlog exactly once.
        let rl = RateLimited::new(Duration::from_secs(0));
        assert_eq!(rl.admit(), Some(0));
        assert_eq!(rl.admit(), Some(0));
    }

    #[test]
    fn rate_limiter_reports_suppressed_count() {
        let rl = RateLimited::new(Duration::from_secs(3600));
        assert_eq!(rl.admit(), Some(0));
        for _ in 0..7 {
            assert_eq!(rl.admit(), None);
        }
        // Force the window open and check the backlog is surfaced.
        *rl.last.lock().unwrap() = Some(Instant::now() - Duration::from_secs(7200));
        assert_eq!(rl.admit(), Some(7));
    }
}
