//! Lock-free log-linear latency histograms (docs/observability.md).
//!
//! The bucket layout is **fixed** — every histogram in the process
//! (and in any snapshot ever serialized) uses the same boundaries, so
//! snapshots are mergeable across threads, processes, and PRs without
//! coordination: merging is element-wise saturating addition, which is
//! associative and commutative.
//!
//! Layout (values are nanoseconds, but nothing here assumes a unit):
//! buckets `0..4` are exact (`v < 4` lands in bucket `v`); past that,
//! each power-of-two octave is split into 4 linear sub-buckets, so
//! bucket width tracks magnitude at a constant ~25% relative error.
//! The top octave of `u64` maps to the last bucket — recording can
//! never index out of range, and overflow saturates instead of
//! wrapping (mirroring the `SimStats` saturating-sum semantics).

use std::sync::atomic::{AtomicU64, Ordering};

use super::Counter;

/// 4 exact buckets + 62 octaves x 4 sub-buckets covers all of `u64`.
pub const N_BUCKETS: usize = 252;

/// Bucket index for a recorded value (total over `u64`).
pub fn bucket_index(v: u64) -> usize {
    if v < 4 {
        return v as usize;
    }
    let exp = 63 - v.leading_zeros() as usize; // 2..=63
    let sub = ((v >> (exp - 2)) & 3) as usize;
    (exp - 2) * 4 + 4 + sub
}

/// Inclusive lower bound of bucket `i` — the value reported for any
/// sample in the bucket (quantiles are therefore lower-bound
/// estimates with ~25% relative error).
pub fn bucket_floor(i: usize) -> u64 {
    if i < 4 {
        return i as u64;
    }
    let oct = (i - 4) / 4;
    let sub = ((i - 4) % 4) as u64;
    (4 + sub) << oct
}

/// A lock-free histogram: relaxed per-bucket counters plus a total
/// count, a saturating sum, and a running max. `record_ns` is a few
/// relaxed atomic RMWs — safe to call from any thread, never blocks.
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    /// Release-ordered so a snapshot that reads `count` first is
    /// guaranteed to see at least that many bucket increments.
    count: Counter,
    sum: Counter,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: Counter::new(),
            sum: Counter::new(),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample (nanoseconds by convention). Bucket first,
    /// count last: `count` is the release-publish, so any reader that
    /// observes `count >= n` also observes `>= n` bucket increments.
    pub fn record_ns(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.add(v);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.count.add(1);
    }

    pub fn count(&self) -> u64 {
        self.count.get()
    }

    /// Point-in-time copy. Reads `count` (acquire) before the
    /// buckets, so `snapshot.buckets` always sums to **at least**
    /// `snapshot.count` even while writers are racing.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let count = self.count.get();
        let sum_ns = self.sum.get();
        let max_ns = self.max.load(Ordering::Acquire);
        let mut buckets = Vec::new();
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                buckets.push((i as u32, n));
            }
        }
        HistogramSnapshot { count, sum_ns, max_ns, buckets }
    }
}

/// A frozen histogram: sparse `(bucket index, count)` pairs in index
/// order, plus the scalar aggregates. Mergeable (fixed layout) and
/// serializable (docs/observability.md gives the JSON shape).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum_ns: u64,
    pub max_ns: u64,
    /// `(bucket, count)` with `bucket < N_BUCKETS`, strictly
    /// increasing, zero-count buckets omitted.
    pub buckets: Vec<(u32, u64)>,
}

impl HistogramSnapshot {
    pub fn empty() -> HistogramSnapshot {
        HistogramSnapshot { count: 0, sum_ns: 0, max_ns: 0, buckets: Vec::new() }
    }

    /// Element-wise saturating merge. Saturating addition over a
    /// fixed bucket layout is associative, so merging snapshots in
    /// any grouping or order yields identical results (pinned by
    /// test).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        self.count = self.count.saturating_add(other.count);
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
        let mut merged: Vec<(u32, u64)> =
            Vec::with_capacity(self.buckets.len() + other.buckets.len());
        let (mut a, mut b) = (self.buckets.iter().peekable(), other.buckets.iter().peekable());
        loop {
            match (a.peek(), b.peek()) {
                (Some(&&(ia, na)), Some(&&(ib, nb))) => {
                    if ia == ib {
                        merged.push((ia, na.saturating_add(nb)));
                        a.next();
                        b.next();
                    } else if ia < ib {
                        merged.push((ia, na));
                        a.next();
                    } else {
                        merged.push((ib, nb));
                        b.next();
                    }
                }
                (Some(_), None) => {
                    merged.extend(a.by_ref().copied());
                }
                (None, Some(_)) => {
                    merged.extend(b.by_ref().copied());
                }
                (None, None) => break,
            }
        }
        self.buckets = merged;
    }

    /// Lower-bound quantile estimate (`q` in `[0, 1]`): the floor of
    /// the bucket holding the `ceil(q * count)`-th sample.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for &(i, n) in &self.buckets {
            cum = cum.saturating_add(n);
            if cum >= target {
                return bucket_floor(i as usize);
            }
        }
        self.max_ns
    }

    /// Mean in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum_ns / self.count
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exact bucket boundaries are part of the snapshot format:
    /// 0..4 exact, then 4 linear sub-buckets per octave, floors
    /// `(4 + sub) << octave`. Pinned so serialized snapshots stay
    /// comparable across versions.
    #[test]
    fn bucket_boundaries_pinned() {
        // Exact region.
        for v in 0..4u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_floor(v as usize), v);
        }
        // First octave [4, 8): one bucket per value.
        for v in 4..8u64 {
            assert_eq!(bucket_index(v), v as usize);
        }
        // Octave starts land on sub-bucket 0.
        for (v, want) in [(8u64, 8usize), (16, 12), (32, 16), (1 << 20, 4 + 18 * 4)] {
            assert_eq!(bucket_index(v), want, "v={v}");
            assert_eq!(bucket_floor(want), v, "v={v}");
        }
        // A value one below an octave lands in the top sub-bucket of
        // the previous octave.
        assert_eq!(bucket_index(15), 11);
        assert_eq!(bucket_floor(11), 14);
        // Full range: u64::MAX maps to the last bucket, in range.
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
        assert_eq!(bucket_floor(N_BUCKETS - 1), 7u64 << 61);
        // Floors are monotone and index/floor are mutually consistent
        // over every bucket.
        for i in 0..N_BUCKETS {
            let f = bucket_floor(i);
            assert_eq!(bucket_index(f), i, "floor of bucket {i} maps back");
            if i > 0 {
                assert!(f > bucket_floor(i - 1), "floors monotone at {i}");
            }
        }
    }

    #[test]
    fn record_and_quantiles() {
        let h = Histogram::new();
        for v in [10u64, 20, 30, 40, 1000] {
            h.record_ns(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum_ns, 1100);
        assert_eq!(s.max_ns, 1000);
        assert_eq!(s.buckets.iter().map(|&(_, n)| n).sum::<u64>(), 5);
        // Lower-bound estimates: within one bucket of the truth.
        assert_eq!(s.quantile_ns(0.5), bucket_floor(bucket_index(30)));
        assert_eq!(s.quantile_ns(1.0), bucket_floor(bucket_index(1000)));
        assert_eq!(s.quantile_ns(0.0), bucket_floor(bucket_index(10)));
        assert_eq!(s.mean_ns(), 220);
    }

    /// Merging is associative: (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c), including
    /// under saturation — the property that lets per-thread or
    /// per-process snapshots be combined in any order.
    #[test]
    fn merge_is_associative() {
        let mk = |vals: &[u64]| {
            let h = Histogram::new();
            for &v in vals {
                h.record_ns(v);
            }
            h.snapshot()
        };
        let a = mk(&[1, 5, 9, 1 << 30]);
        let b = mk(&[5, 5, 7]);
        let c = mk(&[0, u64::MAX, 1 << 30]);

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ab_c = ab.clone();
        ab_c.merge(&c);

        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);

        assert_eq!(ab_c, a_bc);
        // Commutative too.
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        // Counts and bucket totals agree after merging.
        assert_eq!(ab_c.count, 10);
        assert_eq!(ab_c.buckets.iter().map(|&(_, n)| n).sum::<u64>(), 10);
    }

    /// Saturation: sums pin at u64::MAX instead of wrapping, exactly
    /// like `SimStats`' saturating `AddAssign`.
    #[test]
    fn merge_saturates_instead_of_wrapping() {
        let mut a = HistogramSnapshot {
            count: u64::MAX - 1,
            sum_ns: u64::MAX,
            max_ns: 1,
            buckets: vec![(0, u64::MAX)],
        };
        let b = a.clone();
        a.merge(&b);
        assert_eq!(a.count, u64::MAX);
        assert_eq!(a.sum_ns, u64::MAX);
        assert_eq!(a.buckets, vec![(0, u64::MAX)]);
    }

    /// Concurrent recording loses nothing: bucket totals, count, and
    /// sum all land exactly.
    #[test]
    fn concurrent_recording_is_lossless() {
        let h = Histogram::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = &h;
                s.spawn(move || {
                    for i in 0..1000u64 {
                        h.record_ns(t * 1000 + i);
                    }
                });
            }
        });
        let s = h.snapshot();
        assert_eq!(s.count, 4000);
        assert_eq!(s.buckets.iter().map(|&(_, n)| n).sum::<u64>(), 4000);
        assert_eq!(s.sum_ns, (0..4000u64).sum::<u64>());
        assert_eq!(s.max_ns, 3999);
    }
}
