//! Request spans: one record per served request, carrying the
//! per-stage timing breakdown of the serving path
//! (docs/observability.md).
//!
//! The stages mirror the lifecycle of a request inside
//! `coordinator/serve.rs`:
//!
//! ```text
//! accept-wait -> decode -> lookup -> execute -> stitch -> respond
//! ```
//!
//! `accept-wait` (time queued before a worker picked the connection
//! up) is a per-connection quantity and feeds its own histogram;
//! the rest are per-request and are recorded both into the stage
//! histograms and — for the most recent requests — into a bounded
//! in-memory ring surfaced verbatim in the `STATS` reply, so an
//! operator can see the last few concrete requests, not just
//! aggregates.

use std::collections::VecDeque;
use std::sync::Mutex;

use super::json_escape;

/// How many recent requests the ring retains. Small on purpose: the
/// ring is a debugging window, not a log — aggregates live in the
/// histograms.
pub const RING_CAP: usize = 32;

/// One served request, as recorded by the serving path. Stage
/// durations are nanoseconds; a stage the request never entered
/// (e.g. `stitch` on a fixed-box request) records 0.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestRecord {
    /// Resolved app name ("?" when the request failed before
    /// resolution).
    pub app: String,
    /// Concrete engine that executed ("?" before resolution).
    pub engine: &'static str,
    /// Variant role that served the request — one of
    /// [`super::VARIANT_ROLES`] on OK responses ("?" when the request
    /// failed before a variant was chosen). See docs/routing.md.
    pub variant: &'static str,
    /// Protocol generation: 1, 2, or 3.
    pub version: u8,
    pub ok: bool,
    /// Accelerator passes (1 for fixed-box, the plan's tile count for
    /// v3 whole-image requests).
    pub tiles: u64,
    pub in_words: u64,
    pub out_words: u64,
    pub cycles: u64,
    /// Pool queue depth sampled at admission.
    pub queue_depth: u64,
    pub decode_ns: u64,
    pub lookup_ns: u64,
    pub execute_ns: u64,
    pub stitch_ns: u64,
    pub respond_ns: u64,
    pub total_ns: u64,
}

impl RequestRecord {
    /// Serialize as a JSON object (the element shape of the
    /// snapshot's `recent` array).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"app\":\"{}\",\"engine\":\"{}\",\"variant\":\"{}\",\
             \"version\":{},\"ok\":{},\
             \"tiles\":{},\"in_words\":{},\"out_words\":{},\"cycles\":{},\
             \"queue_depth\":{},\"decode_ns\":{},\"lookup_ns\":{},\
             \"execute_ns\":{},\"stitch_ns\":{},\"respond_ns\":{},\"total_ns\":{}}}",
            json_escape(&self.app),
            json_escape(self.engine),
            json_escape(self.variant),
            self.version,
            self.ok,
            self.tiles,
            self.in_words,
            self.out_words,
            self.cycles,
            self.queue_depth,
            self.decode_ns,
            self.lookup_ns,
            self.execute_ns,
            self.stitch_ns,
            self.respond_ns,
            self.total_ns,
        )
    }
}

/// Bounded ring of recent [`RequestRecord`]s. A mutex is fine here:
/// it is taken once per request (never on the tile/exec hot path) and
/// holds only a push/pop.
pub struct RecentRing {
    ring: Mutex<VecDeque<RequestRecord>>,
}

impl Default for RecentRing {
    fn default() -> RecentRing {
        RecentRing::new()
    }
}

impl RecentRing {
    pub fn new() -> RecentRing {
        RecentRing { ring: Mutex::new(VecDeque::with_capacity(RING_CAP)) }
    }

    pub fn push(&self, rec: RequestRecord) {
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        if ring.len() == RING_CAP {
            ring.pop_front();
        }
        ring.push_back(rec);
    }

    /// Oldest-first copy of the retained records.
    pub fn to_vec(&self) -> Vec<RequestRecord> {
        self.ring
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .iter()
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: u64) -> RequestRecord {
        RequestRecord {
            app: format!("app{i}"),
            engine: "exec",
            variant: "latency",
            version: 3,
            ok: true,
            tiles: i,
            in_words: 0,
            out_words: 0,
            cycles: 0,
            queue_depth: 0,
            decode_ns: 1,
            lookup_ns: 2,
            execute_ns: 3,
            stitch_ns: 4,
            respond_ns: 5,
            total_ns: 15,
        }
    }

    #[test]
    fn ring_is_bounded_and_keeps_newest() {
        let ring = RecentRing::new();
        for i in 0..(RING_CAP as u64 + 10) {
            ring.push(rec(i));
        }
        let v = ring.to_vec();
        assert_eq!(v.len(), RING_CAP);
        assert_eq!(v[0].tiles, 10); // oldest retained
        assert_eq!(v.last().unwrap().tiles, RING_CAP as u64 + 9);
    }

    #[test]
    fn record_json_shape() {
        let j = rec(7).to_json();
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        for key in [
            "\"app\":\"app7\"",
            "\"engine\":\"exec\"",
            "\"variant\":\"latency\"",
            "\"version\":3",
            "\"ok\":true",
            "\"tiles\":7",
            "\"decode_ns\":1",
            "\"stitch_ns\":4",
            "\"total_ns\":15",
        ] {
            assert!(j.contains(key), "{key} missing from {j}");
        }
    }
}
