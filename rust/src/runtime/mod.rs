//! PJRT golden-model runtime: load the AOT-lowered HLO artifacts and
//! execute them on the CPU client. This is both the validation oracle
//! (§VI-B "we validate the output images against each other") and the
//! CPU baseline of Fig 14 (the same XLA executable *is* the optimized
//! CPU implementation of the app).
//!
//! HLO **text** is the interchange format — see gen_hlo notes in
//! /opt/xla-example: jax ≥ 0.5 emits 64-bit instruction ids that this
//! xla_extension rejects in proto form; the text parser reassigns ids.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::tensor::Tensor;

pub struct Runtime {
    client: xla::PjRtClient,
}

pub struct GoldenModel {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<GoldenModel> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("path utf8")?)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(GoldenModel {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// Convert a [`Tensor`] to an XLA literal (row-major over its box).
fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.dims.iter().map(|d| d.extent).collect();
    xla::Literal::vec1(&t.data)
        .reshape(&dims)
        .context("reshaping input literal")
}

impl GoldenModel {
    /// Execute with the inputs in artifact parameter order; returns the
    /// flattened row-major output and the wall-clock execute time (the
    /// Fig 14 CPU measurement).
    pub fn run(&self, inputs: &[&Tensor]) -> Result<(Vec<i32>, f64)> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| to_literal(t))
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let dt = t0.elapsed().as_secs_f64();
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        Ok((out.to_vec::<i32>().context("reading output literal")?, dt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::BoxSet;

    fn artifact(name: &str) -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .join(format!("{name}.hlo.txt"))
    }

    #[test]
    fn gaussian_artifact_roundtrip() {
        let path = artifact("gaussian");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let m = rt.load(&path).unwrap();
        // Constant image: binomial blur is the identity.
        let img = Tensor::from_fn(BoxSet::from_extents(&[64, 64]), |_| 100);
        let (out, dt) = m.run(&[&img]).unwrap();
        assert_eq!(out.len(), 62 * 62);
        assert!(out.iter().all(|&v| v == 100));
        assert!(dt > 0.0);
    }

    #[test]
    fn upsample_artifact_roundtrip() {
        let path = artifact("upsample");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let m = rt.load(&path).unwrap();
        let img = Tensor::from_fn(BoxSet::from_extents(&[64, 64]), |p| (p[0] * 64 + p[1]) as i32);
        let (out, _) = m.run(&[&img]).unwrap();
        assert_eq!(out.len(), 64 * 2 * 64 * 2);
        // out[yo,yi,xo,xi] = in[yo,xo]; check a few.
        let idx = |yo: usize, yi: usize, xo: usize, xi: usize| ((yo * 2 + yi) * 64 + xo) * 2 + xi;
        assert_eq!(out[idx(3, 0, 5, 1)], (3 * 64 + 5) as i32);
        assert_eq!(out[idx(3, 1, 5, 0)], (3 * 64 + 5) as i32);
    }
}
