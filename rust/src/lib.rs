//! # pushmem — compiling Halide programs to push-memory accelerators
//!
//! A from-scratch reproduction of *"Compiling Halide Programs to
//! Push-Memory Accelerators"* (Liu et al., 2021): a compiler from a
//! mini-Halide DSL to configurations of *physical unified buffers* on a
//! CGRA, plus the cycle-accurate CGRA simulator, FPGA/CPU baselines, and
//! area/energy models used to regenerate every table and figure in the
//! paper's evaluation.
//!
//! Pipeline (Fig 1 of the paper):
//!
//! ```text
//! halide::*  --lower-->  scheduled loop IR
//!   --extraction-->      unified buffer graph (ub::*)
//!   --sched-->           cycle-accurate schedules (stencil | dnn)
//!   --mapping-->         physical unified buffer configs (hw::*)
//!   --cgra-->            place & route -> bitstream -> simulate
//!   --coordinator-->     validate vs XLA golden model (runtime::*)
//! ```
//!
//! Layered on top, [`dse`] searches the schedule space itself: it
//! enumerates `HwSchedule` candidates, prunes them analytically, and
//! scores the survivors through the full compile + simulate path on a
//! worker pool (§VI-C automated; see docs/dse.md).
//!
//! Serving and tuning default to the [`exec`] functional engine — the
//! design executed as fused affine tensor kernels with an analytic
//! timing model, bit-identical to the simulator but orders of
//! magnitude faster (docs/execution.md, DESIGN.md §6); the
//! cycle-accurate [`cgra::sim`] remains the fallback and the oracle.

pub mod apps;
pub mod cgra;
pub mod coordinator;
pub mod cost;
pub mod dse;
pub mod exec;
pub mod extraction;
pub mod halide;
pub mod hw;
pub mod mapping;
pub mod poly;
pub mod runtime;
pub mod sched;
pub mod telemetry;
pub mod tensor;
pub mod tile;
pub mod ub;
