//! Physical unified buffer hardware models (§IV).
//!
//! Cycle-level behavioral models of the hardware primitives the mapper
//! configures, mirroring the paper's generator:
//!
//! * [`affine_fn`] — the three affine-function implementations of Fig 5:
//!   (a) explicit multipliers, (b) per-dimension stride accumulators,
//!   (c) the single-adder delta recurrence. All are bit-equivalent; (c)
//!   is what ships in the memory tile.
//! * [`id`] — the IterationDomain counter module.
//! * [`controller`] — an ID + AddressGenerator + ScheduleGenerator
//!   triple: fires at scheduled cycles, producing addresses (Fig 3/4).
//! * [`agg`] / [`tb`] / [`sram`] — aggregator (serial→parallel),
//!   transpose buffer (parallel→serial), and single/dual-port SRAM
//!   macros with wide fetch.
//! * [`memtile`] — the complete physical unified buffer: AGG + wide
//!   single-port SRAM + TB with shared-schedule optimizations (Fig 11),
//!   plus shift-register chains and chaining support (Fig 10).
//! * [`petile`] — the CGRA processing element: one 16-bit ALU op with
//!   programmable operand delays and an accumulate mode.

pub mod affine_fn;
pub mod agg;
pub mod controller;
pub mod id;
pub mod memtile;
pub mod petile;
pub mod sram;
pub mod tb;

pub use affine_fn::{AffineConfig, AffineHw, DeltaImpl, IncrImpl, MultImpl};
pub use controller::PortController;
pub use id::IterationDomain;
pub use memtile::{DpMemTile, DpTileConfig, MemTile, MemTileConfig, PortCtlConfig};
pub use petile::{PeConfig, PeOp, PeTile};
