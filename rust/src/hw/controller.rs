//! A port controller: IterationDomain + AddressGenerator +
//! ScheduleGenerator (the ID/AG/SG triple at every port of Fig 3/4).
//!
//! Each cycle the SG's current value is compared against the global
//! cycle counter; on a match the port *fires*, the AG's current value is
//! the address, and all three recurrences advance. Both AG and SG use
//! the optimized single-adder delta implementation (Fig 5c).

use super::affine_fn::{AffineConfig, AffineHw, DeltaImpl};
use super::id::IterationDomain;

#[derive(Clone, Debug)]
pub struct PortController {
    id: IterationDomain,
    ag: DeltaImpl,
    sg: DeltaImpl,
    fired: i64,
}

impl PortController {
    /// `extents` — iteration domain (outermost-first); `addr`/`sched` —
    /// affine configs over that domain (schedule must be monotone
    /// increasing in iteration order).
    pub fn new(extents: Vec<i64>, addr: &AffineConfig, sched: &AffineConfig) -> Self {
        let ag = DeltaImpl::new(addr, &extents);
        let sg = DeltaImpl::new(sched, &extents);
        PortController { id: IterationDomain::new(extents), ag, sg, fired: 0 }
    }

    pub fn is_done(&self) -> bool {
        self.id.is_done()
    }

    /// Cycle the SG will fire next (meaningless once done).
    pub fn next_fire(&self) -> i64 {
        self.sg.value()
    }

    pub fn ops_fired(&self) -> i64 {
        self.fired
    }

    /// Advance one global cycle; returns the address if the port fires.
    pub fn tick(&mut self, cycle: i64) -> Option<i64> {
        if self.id.is_done() || cycle != self.sg.value() {
            return None;
        }
        debug_assert!(cycle == self.sg.value());
        let addr = self.ag.value();
        self.fired += 1;
        if let Some((inc, clr)) = self.id.step() {
            self.ag.step(&inc, &clr);
            self.sg.step(&inc, &clr);
        }
        Some(addr)
    }

    pub fn reset(&mut self) {
        self.id.reset();
        self.ag.reset();
        self.sg.reset();
        self.fired = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::Affine;

    fn cfg(coeffs: Vec<i64>, offset: i64) -> AffineConfig {
        AffineConfig::from_affine(&Affine::new(coeffs, offset))
    }

    #[test]
    fn fires_per_schedule_with_addresses() {
        // 2x3 domain; schedule t = 4y + x + 2 (gaps in each row);
        // address a = 3y + x (row-major linear).
        let mut pc = PortController::new(vec![2, 3], &cfg(vec![3, 1], 0), &cfg(vec![4, 1], 2));
        let mut fires = Vec::new();
        for cycle in 0..12 {
            if let Some(addr) = pc.tick(cycle) {
                fires.push((cycle, addr));
            }
        }
        assert_eq!(
            fires,
            vec![(2, 0), (3, 1), (4, 2), (6, 3), (7, 4), (8, 5)]
        );
        assert!(pc.is_done());
        assert_eq!(pc.ops_fired(), 6);
    }

    #[test]
    fn no_fire_before_offset_or_after_done() {
        let mut pc = PortController::new(vec![2], &cfg(vec![1], 0), &cfg(vec![1], 5));
        assert_eq!(pc.tick(4), None);
        assert_eq!(pc.tick(5), Some(0));
        assert_eq!(pc.tick(6), Some(1));
        assert_eq!(pc.tick(7), None);
        assert!(pc.is_done());
    }

    #[test]
    fn reset_replays() {
        let mut pc = PortController::new(vec![2], &cfg(vec![2], 7), &cfg(vec![1], 0));
        assert_eq!(pc.tick(0), Some(7));
        assert_eq!(pc.tick(1), Some(9));
        pc.reset();
        assert_eq!(pc.tick(0), Some(7));
    }
}
