//! The IterationDomain module: a chain of loop counters (Fig 3).

/// Nested loop counters, outermost-first, each running `0..extent`.
/// Stepping advances the innermost counter with carry, producing the
/// `inc`/`clr` event flags the affine-function hardware consumes.
#[derive(Clone, Debug)]
pub struct IterationDomain {
    extents: Vec<i64>,
    counters: Vec<i64>,
    done: bool,
}

impl IterationDomain {
    pub fn new(extents: Vec<i64>) -> Self {
        assert!(extents.iter().all(|&e| e > 0), "empty iteration domain");
        let n = extents.len();
        IterationDomain { extents, counters: vec![0; n], done: false }
    }

    pub fn rank(&self) -> usize {
        self.extents.len()
    }

    /// Current point (zero-based; callers add domain mins).
    pub fn point(&self) -> &[i64] {
        &self.counters
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Total number of points.
    pub fn trip_count(&self) -> i64 {
        self.extents.iter().product()
    }

    pub fn reset(&mut self) {
        self.counters.iter_mut().for_each(|c| *c = 0);
        self.done = false;
    }

    /// Advance one step. Returns the `(inc, clr)` flag vectors, or `None`
    /// when the domain is exhausted (all counters wrapped).
    pub fn step(&mut self) -> Option<(Vec<bool>, Vec<bool>)> {
        if self.done {
            return None;
        }
        let n = self.rank();
        let mut inc = vec![false; n];
        let mut clr = vec![false; n];
        for k in (0..n).rev() {
            inc[k] = true;
            self.counters[k] += 1;
            if self.counters[k] < self.extents[k] {
                return Some((inc, clr));
            }
            self.counters[k] = 0;
            clr[k] = true;
        }
        self.done = true;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn visits_all_points_in_order() {
        let mut id = IterationDomain::new(vec![2, 3]);
        let mut seen = vec![id.point().to_vec()];
        while id.step().is_some() {
            seen.push(id.point().to_vec());
        }
        assert_eq!(
            seen,
            vec![
                vec![0, 0],
                vec![0, 1],
                vec![0, 2],
                vec![1, 0],
                vec![1, 1],
                vec![1, 2]
            ]
        );
        assert!(id.is_done());
        assert!(id.step().is_none());
    }

    #[test]
    fn inc_clr_flags() {
        let mut id = IterationDomain::new(vec![2, 2]);
        // (0,0) -> (0,1): inner inc only.
        let (inc, clr) = id.step().unwrap();
        assert_eq!((inc, clr), (vec![false, true], vec![false, false]));
        // (0,1) -> (1,0): inner wraps (inc+clr), outer incs.
        let (inc, clr) = id.step().unwrap();
        assert_eq!((inc, clr), (vec![true, true], vec![false, true]));
    }

    #[test]
    fn trip_count_and_reset() {
        let mut id = IterationDomain::new(vec![3, 4]);
        assert_eq!(id.trip_count(), 12);
        let mut n = 1;
        while id.step().is_some() {
            n += 1;
        }
        assert_eq!(n, 12);
        id.reset();
        assert_eq!(id.point(), &[0, 0]);
        assert!(!id.is_done());
    }
}
