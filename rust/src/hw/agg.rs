//! The aggregator (AGG): a fetch-width register file that converts the
//! serial input stream into SRAM-wide vectors (§IV-B). Slot addressing
//! comes from the port controller's AG (the `x mod FW` dimension the
//! vectorization transform introduces, Eq. 2).

#[derive(Clone, Debug)]
pub struct Aggregator {
    regs: Vec<i64>,
    pub writes: u64,
}

impl Aggregator {
    pub fn new(fetch_width: usize) -> Self {
        Aggregator { regs: vec![0; fetch_width], writes: 0 }
    }

    pub fn fetch_width(&self) -> usize {
        self.regs.len()
    }

    /// Zero the register file and write counter (per-run reuse).
    pub fn reset(&mut self) {
        self.regs.iter_mut().for_each(|r| *r = 0);
        self.writes = 0;
    }

    /// Serial write into one slot.
    pub fn write(&mut self, slot: i64, word: i64) {
        assert!(
            (0..self.regs.len() as i64).contains(&slot),
            "AGG slot {slot} out of range"
        );
        self.regs[slot as usize] = word;
        self.writes += 1;
    }

    /// Parallel read of the whole vector (the SRAM-write side).
    pub fn read_all(&self) -> Vec<i64> {
        self.regs.clone()
    }

    /// Borrow the register file directly — the allocation-free flush
    /// path ([`crate::hw::MemTile::tick_into`] copies straight from
    /// here into the SRAM write port).
    pub fn regs(&self) -> &[i64] {
        &self.regs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_a_vector() {
        let mut a = Aggregator::new(4);
        for k in 0..4 {
            a.write(k, 10 + k);
        }
        assert_eq!(a.read_all(), vec![10, 11, 12, 13]);
        assert_eq!(a.writes, 4);
    }

    #[test]
    fn partial_overwrite() {
        let mut a = Aggregator::new(2);
        a.write(0, 5);
        a.write(1, 6);
        a.write(0, 7);
        assert_eq!(a.read_all(), vec![7, 6]);
    }

    #[test]
    #[should_panic]
    fn oob_slot_panics() {
        Aggregator::new(2).write(2, 0);
    }
}
