//! The complete physical unified buffer memory tile (Fig 4 / Fig 11).
//!
//! Per input port: a serial-in controller filling an aggregator, and an
//! AGG→SRAM flush controller issuing wide writes. Per output port: an
//! SRAM→TB controller issuing wide reads (1-cycle latency), and a
//! TB→out controller serializing words onto the port. One wide-fetch
//! single-port SRAM is shared by all flush/read controllers — the
//! scheduler must avoid conflicts, and the model faults on any.
//!
//! Shift-register chains ([`DelayLine`]) implement the ports the mapper
//! converted away from memory (Fig 8a).

use std::collections::VecDeque;

use anyhow::{Context, Result};

use super::affine_fn::AffineConfig;
use super::agg::Aggregator;
use super::controller::PortController;
use super::sram::WideSram;
use super::tb::TransposeBuffer;

/// Configuration of one port controller (ID extents + AG + SG).
/// `modulus` wraps the generated address — the circular-buffer layout of
/// the paper's address linearization (§V-C, `{1,64} mod 64 = {1,0}`
/// example generalized to a hardware wrap).
#[derive(Clone, Debug)]
pub struct PortCtlConfig {
    pub extents: Vec<i64>,
    pub addr: AffineConfig,
    pub sched: AffineConfig,
    pub modulus: Option<i64>,
}

impl PortCtlConfig {
    pub fn new(extents: Vec<i64>, addr: AffineConfig, sched: AffineConfig) -> Self {
        PortCtlConfig { extents, addr, sched, modulus: None }
    }

    pub fn with_modulus(mut self, m: i64) -> Self {
        self.modulus = Some(m);
        self
    }

    pub fn controller(&self) -> PortController {
        PortController::new(self.extents.clone(), &self.addr, &self.sched)
    }

    fn wrap(&self, addr: i64) -> i64 {
        match self.modulus {
            Some(m) => addr.rem_euclid(m),
            None => addr,
        }
    }
}

/// Full memory-tile configuration (the "configuration bits" the
/// compiler produces for a MEM tile, §V-C).
#[derive(Clone, Debug)]
pub struct MemTileConfig {
    pub fetch_width: usize,
    /// SRAM capacity in words.
    pub capacity: usize,
    /// Serial input controllers; `addr` selects the AGG slot.
    pub serial_in: Vec<PortCtlConfig>,
    /// Which aggregator each serial input fills (unrolled write lanes
    /// interleave into a shared AGG).
    pub serial_in_agg: Vec<usize>,
    /// AGG→SRAM flush controllers (one per aggregator); `addr` is the
    /// SRAM *vector* address.
    pub agg_flush: Vec<PortCtlConfig>,
    /// SRAM→TB read controllers (one per output port); vector address.
    pub sram_read: Vec<PortCtlConfig>,
    /// TB→out serializers; `addr` selects the TB slot.
    pub tb_out: Vec<PortCtlConfig>,
}

/// Behavioral model of a configured memory tile.
#[derive(Clone, Debug)]
pub struct MemTile {
    pub cfg: MemTileConfig,
    aggs: Vec<Aggregator>,
    tbs: Vec<TransposeBuffer>,
    pub sram: WideSram,
    ctl_in: Vec<PortController>,
    ctl_flush: Vec<PortController>,
    ctl_read: Vec<PortController>,
    ctl_out: Vec<PortController>,
    /// Which TB (and which ping-pong half) the in-flight read targets.
    inflight: Option<(usize, usize)>,
}

impl MemTile {
    pub fn new(cfg: MemTileConfig) -> Self {
        assert_eq!(cfg.serial_in.len(), cfg.serial_in_agg.len());
        assert_eq!(cfg.sram_read.len(), cfg.tb_out.len());
        assert!(cfg.serial_in_agg.iter().all(|&a| a < cfg.agg_flush.len()));
        MemTile {
            aggs: cfg.agg_flush.iter().map(|_| Aggregator::new(cfg.fetch_width)).collect(),
            tbs: cfg.sram_read.iter().map(|_| TransposeBuffer::new(cfg.fetch_width)).collect(),
            sram: WideSram::new(cfg.capacity, cfg.fetch_width),
            ctl_in: cfg.serial_in.iter().map(|c| c.controller()).collect(),
            ctl_flush: cfg.agg_flush.iter().map(|c| c.controller()).collect(),
            ctl_read: cfg.sram_read.iter().map(|c| c.controller()).collect(),
            ctl_out: cfg.tb_out.iter().map(|c| c.controller()).collect(),
            inflight: None,
            cfg,
        }
    }

    pub fn n_inputs(&self) -> usize {
        self.ctl_in.len()
    }

    pub fn n_outputs(&self) -> usize {
        self.ctl_out.len()
    }

    pub fn is_done(&self) -> bool {
        self.ctl_out.iter().all(|c| c.is_done())
    }

    /// Return the tile to its just-configured state: every controller
    /// replays from its first iteration, all storage is zeroed, access
    /// statistics restart. This is the cheap per-request path of the
    /// simulator's plan/run split (docs/simulator.md): a `SimRun` keeps
    /// one instantiated tile per bank and resets it instead of
    /// re-instantiating the whole design per request.
    pub fn reset(&mut self) {
        for c in self
            .ctl_in
            .iter_mut()
            .chain(self.ctl_flush.iter_mut())
            .chain(self.ctl_read.iter_mut())
            .chain(self.ctl_out.iter_mut())
        {
            c.reset();
        }
        for a in &mut self.aggs {
            a.reset();
        }
        for tb in &mut self.tbs {
            tb.reset();
        }
        self.sram.reset();
        self.inflight = None;
    }

    /// Earliest future cycle any controller of this tile fires, or
    /// `None` when every controller is done — the simulator's
    /// idle-cycle skip must never jump past this.
    pub fn next_event(&self) -> Option<i64> {
        self.ctl_in
            .iter()
            .chain(&self.ctl_flush)
            .chain(&self.ctl_read)
            .chain(&self.ctl_out)
            .filter(|c| !c.is_done())
            .map(|c| c.next_fire())
            .min()
    }

    /// A wide read is in flight (must land on the very next tick), so
    /// the tile cannot be skipped over even with no scheduled fire.
    pub fn busy(&self) -> bool {
        self.inflight.is_some()
    }

    /// Advance one cycle. `inputs[i]` must carry a word whenever input
    /// port `i`'s schedule fires. Returns one optional word per output
    /// port. Convenience over [`MemTile::tick_into`] — steady-state
    /// callers (the simulator's bank loop) pass a reusable scratch
    /// slice instead of allocating a fresh `Vec` per cycle.
    pub fn tick(&mut self, cycle: i64, inputs: &[Option<i64>]) -> Result<Vec<Option<i64>>> {
        let mut out = vec![None; self.ctl_out.len()];
        self.tick_into(cycle, inputs, &mut out)?;
        Ok(out)
    }

    /// [`MemTile::tick`] writing into caller-owned scratch (cleared to
    /// `None` first). The whole cycle is allocation-free: aggregator
    /// flushes borrow the register file ([`Aggregator::regs`]) and the
    /// landing read borrows the SRAM's double-buffered read register
    /// ([`WideSram::take_read_ref`]).
    pub fn tick_into(
        &mut self,
        cycle: i64,
        inputs: &[Option<i64>],
        out: &mut [Option<i64>],
    ) -> Result<()> {
        assert_eq!(inputs.len(), self.ctl_in.len(), "input arity mismatch");
        assert_eq!(out.len(), self.ctl_out.len(), "output arity mismatch");
        out.iter_mut().for_each(|v| *v = None);

        // 1. Serial input -> aggregator slots.
        for (i, ctl) in self.ctl_in.iter_mut().enumerate() {
            if let Some(slot) = ctl.tick(cycle) {
                let slot = self.cfg.serial_in[i].wrap(slot);
                let word = inputs[i]
                    .with_context(|| format!("input port {i} fired at {cycle} with no data"))?;
                self.aggs[self.cfg.serial_in_agg[i]].write(slot, word);
            }
        }

        // 2. Aggregator flush -> wide SRAM write.
        for (i, ctl) in self.ctl_flush.iter_mut().enumerate() {
            if let Some(vaddr) = ctl.tick(cycle) {
                let vaddr = self.cfg.agg_flush[i].wrap(vaddr);
                self.sram
                    .write_vec(vaddr, self.aggs[i].regs())
                    .with_context(|| format!("flush {i} at cycle {cycle}"))?;
            }
        }

        // 3. Serialize TB slots onto the output ports (the TB register
        // file still holds last cycle's contents — loads land below).
        for (o, ctl) in self.ctl_out.iter_mut().enumerate() {
            if let Some(slot) = ctl.tick(cycle) {
                out[o] = Some(self.tbs[o].read(self.cfg.tb_out[o].wrap(slot)));
            }
        }

        // 4. Land the read issued last cycle into its transpose buffer
        // half (ping-pong selected by vector-address parity; registers
        // latch at end of cycle: data issued at cycle t is readable from
        // t+2).
        if let Some((tbi, half)) = self.inflight.take() {
            let data = self
                .sram
                .take_read_ref()
                .context("SRAM read did not complete")?;
            self.tbs[tbi].load(half, data);
        }

        // 5. Issue this cycle's wide SRAM read.
        for (o, ctl) in self.ctl_read.iter_mut().enumerate() {
            if let Some(vaddr) = ctl.tick(cycle) {
                let vaddr = self.cfg.sram_read[o].wrap(vaddr);
                self.sram
                    .read_vec(vaddr)
                    .with_context(|| format!("read {o} at cycle {cycle}"))?;
                anyhow::ensure!(self.inflight.is_none(), "two SRAM reads in flight");
                self.inflight = Some((o, (vaddr & 1) as usize));
            }
        }

        self.sram.end_cycle();
        Ok(())
    }
}

/// Configuration of a dual-port (1R + 1W per cycle) word-granular
/// memory tile — the Fig 3 baseline variant. The mapper falls back to
/// it for ports whose access pattern cannot be vectorized onto the
/// wide-fetch single-port SRAM (e.g. a DNN ifmap read that walks
/// channels and windows); it costs more area/energy (Table II row 2).
#[derive(Clone, Debug)]
pub struct DpTileConfig {
    pub capacity: usize,
    /// Serial write controllers (addr = linear address, mod capacity).
    pub writes: Vec<PortCtlConfig>,
    /// Read controller (at most one): `sched` is the cycle the word must
    /// appear on the output port; the SRAM read issues one cycle prior.
    pub reads: Vec<PortCtlConfig>,
}

/// Behavioral model of a configured dual-port memory tile.
#[derive(Clone, Debug)]
pub struct DpMemTile {
    pub cfg: DpTileConfig,
    sram: super::sram::DualPortSram,
    ctl_w: Vec<PortController>,
    ctl_r: Vec<PortController>,
    pending_port: Option<usize>,
}

impl DpMemTile {
    pub fn new(cfg: DpTileConfig) -> Self {
        assert!(cfg.reads.len() <= 1, "dual-port tile has one read port");
        DpMemTile {
            sram: super::sram::DualPortSram::new(cfg.capacity),
            ctl_w: cfg.writes.iter().map(|c| c.controller()).collect(),
            ctl_r: cfg
                .reads
                .iter()
                .map(|c| {
                    // Issue one cycle before the scheduled output.
                    let mut early = c.clone();
                    early.sched.offset -= 1;
                    early.controller()
                })
                .collect(),
            pending_port: None,
            cfg,
        }
    }

    pub fn is_done(&self) -> bool {
        self.ctl_r.iter().all(|c| c.is_done())
    }

    pub fn n_outputs(&self) -> usize {
        self.ctl_r.len()
    }

    /// Just-configured state; see [`MemTile::reset`].
    pub fn reset(&mut self) {
        for c in self.ctl_w.iter_mut().chain(self.ctl_r.iter_mut()) {
            c.reset();
        }
        self.sram.reset();
        self.pending_port = None;
    }

    /// Earliest future controller fire; see [`MemTile::next_event`].
    pub fn next_event(&self) -> Option<i64> {
        self.ctl_w
            .iter()
            .chain(&self.ctl_r)
            .filter(|c| !c.is_done())
            .map(|c| c.next_fire())
            .min()
    }

    /// A read is pending delivery on the next tick.
    pub fn busy(&self) -> bool {
        self.pending_port.is_some()
    }

    /// See [`MemTile::tick`] / [`MemTile::tick_into`].
    pub fn tick(&mut self, cycle: i64, inputs: &[Option<i64>]) -> Result<Vec<Option<i64>>> {
        let mut out = vec![None; self.ctl_r.len()];
        self.tick_into(cycle, inputs, &mut out)?;
        Ok(out)
    }

    pub fn tick_into(
        &mut self,
        cycle: i64,
        inputs: &[Option<i64>],
        out: &mut [Option<i64>],
    ) -> Result<()> {
        assert_eq!(inputs.len(), self.ctl_w.len());
        assert_eq!(out.len(), self.ctl_r.len());
        out.iter_mut().for_each(|v| *v = None);
        // 1. Data from last cycle's read issue appears on the port.
        if let Some(o) = self.pending_port.take() {
            out[o] = Some(self.sram.take_read().context("DP read did not complete")?);
        }
        // 2. Writes (commit at end of cycle).
        for (i, ctl) in self.ctl_w.iter_mut().enumerate() {
            if let Some(addr) = ctl.tick(cycle) {
                let addr = self.cfg.writes[i].wrap(addr);
                let w = inputs[i]
                    .with_context(|| format!("DP write port {i} fired at {cycle} with no data"))?;
                self.sram.write(addr, w)?;
            }
        }
        // 3. Issue reads for next cycle's output.
        for (o, ctl) in self.ctl_r.iter_mut().enumerate() {
            if let Some(addr) = ctl.tick(cycle) {
                let addr = self.cfg.reads[o].wrap(addr);
                self.sram.read(addr)?;
                self.pending_port = Some(o);
            }
        }
        self.sram.end_cycle();
        Ok(())
    }
}

/// A shift-register delay line of fixed depth: the hardware for ports
/// the mapper peeled off as constant-distance dependences (Fig 8a).
/// Depth 0 is a wire.
#[derive(Clone, Debug)]
pub struct DelayLine {
    buf: VecDeque<i64>,
    depth: usize,
}

impl DelayLine {
    pub fn new(depth: usize) -> Self {
        DelayLine { buf: VecDeque::from(vec![0; depth]), depth }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Flush the line back to all zeros (the reset state).
    pub fn reset(&mut self) {
        self.buf.iter_mut().for_each(|v| *v = 0);
    }

    /// Push a word, pop the word from `depth` cycles ago.
    pub fn push(&mut self, v: i64) -> i64 {
        if self.depth == 0 {
            return v;
        }
        self.buf.push_back(v);
        self.buf.pop_front().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::Affine;

    fn cfg(coeffs: Vec<i64>, offset: i64) -> AffineConfig {
        AffineConfig::from_affine(&Affine::new(coeffs, offset))
    }

    /// The Fig 9 vectorized delay buffer: 16 words through a FW=4
    /// single-port SRAM, output delayed 8 cycles after input.
    fn delay8_tile() -> MemTile {
        MemTile::new(MemTileConfig {
            fetch_width: 4,
            capacity: 16,
            serial_in: vec![PortCtlConfig::new(
                vec![4, 4],               // (xo, xi)
                cfg(vec![0, 1], 0),       // AGG slot = xi
                cfg(vec![4, 1], 0),       // t = x
            )],
            serial_in_agg: vec![0],
            agg_flush: vec![PortCtlConfig::new(
                vec![4],
                cfg(vec![1], 0),          // vector addr = xo
                cfg(vec![4], 3),          // as the 4th word lands
            )],
            sram_read: vec![PortCtlConfig::new(
                vec![4],
                cfg(vec![1], 0),
                cfg(vec![4], 6),          // lands at t+7, first use t+8
            )],
            tb_out: vec![PortCtlConfig::new(
                vec![4, 4],
                cfg(vec![4, 1], 0),       // slot = x mod 8 (ping-pong)
                cfg(vec![4, 1], 8),       // t = x + 8
            )
            .with_modulus(8)],
        })
    }

    #[test]
    fn delay_buffer_delays_by_8() {
        let mut tile = delay8_tile();
        let mut outs: Vec<(i64, i64)> = Vec::new();
        for cycle in 0..30 {
            let inw = if cycle < 16 { Some(100 + cycle) } else { None };
            let out = tile.tick(cycle, &[inw]).unwrap();
            if let Some(v) = out[0] {
                outs.push((cycle, v));
            }
        }
        assert_eq!(outs.len(), 16);
        for (t, v) in outs {
            assert_eq!(v, 100 + (t - 8), "wrong word at cycle {t}");
        }
        assert!(tile.is_done());
        // SRAM saw 4 wide writes + 4 wide reads, no conflicts.
        assert_eq!(tile.sram.stats.writes, 4);
        assert_eq!(tile.sram.stats.reads, 4);
        assert_eq!(tile.sram.stats.conflicts, 0);
    }

    #[test]
    fn reset_replays_bit_identically() {
        let mut tile = delay8_tile();
        assert_eq!(tile.next_event(), Some(0));
        let run = |tile: &mut MemTile| -> Vec<(i64, i64)> {
            let mut outs = Vec::new();
            for cycle in 0..30 {
                let inw = if cycle < 16 { Some(100 + cycle) } else { None };
                if let Some(v) = tile.tick(cycle, &[inw]).unwrap()[0] {
                    outs.push((cycle, v));
                }
            }
            outs
        };
        let first = run(&mut tile);
        assert!(tile.is_done());
        tile.reset();
        assert!(!tile.is_done());
        assert_eq!(tile.next_event(), Some(0));
        assert_eq!(tile.sram.stats.reads, 0, "stats must restart");
        let second = run(&mut tile);
        assert_eq!(first, second);
        assert_eq!(tile.sram.stats.reads, 4);
    }

    #[test]
    fn missing_input_word_faults() {
        let mut tile = delay8_tile();
        assert!(tile.tick(0, &[None]).is_err());
    }

    #[test]
    fn delay_line_behaviour() {
        let mut d = DelayLine::new(3);
        let mut outs = Vec::new();
        for k in 0..6 {
            outs.push(d.push(k));
        }
        assert_eq!(outs, vec![0, 0, 0, 0, 1, 2]);
        let mut wire = DelayLine::new(0);
        assert_eq!(wire.push(7), 7);
    }
}
