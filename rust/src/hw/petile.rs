//! The CGRA processing element tile: one 16-bit integer ALU operation
//! with a registered output (latency 1), programmable operand delay
//! lines (retiming of unbalanced expression trees), optional constant
//! operands, and an accumulate mode for reduction loops (§VI, Fig 11).

use crate::halide::expr::{eval_binop, BinOp, UnOp};

use super::memtile::DelayLine;

/// The operation a PE performs each cycle.
#[derive(Clone, Debug)]
pub enum PeOp {
    Bin(BinOp),
    Un(UnOp),
    /// `out = c != 0 ? a : b` (three operands).
    Select,
    /// `acc = op(acc, a)`, with `acc` cleared to `init` every `period`
    /// firings (the reduction-loop accumulator).
    Acc { op: BinOp, init: i32, period: i64 },
}

/// PE configuration: the op plus per-operand constant/delay settings.
#[derive(Clone, Debug)]
pub struct PeConfig {
    pub op: PeOp,
    /// Constant operand values; `None` means the operand comes from the
    /// routed input.
    pub consts: [Option<i32>; 3],
    /// Retiming delay (cycles) on each routed operand.
    pub delays: [usize; 3],
}

impl PeConfig {
    pub fn bin(op: BinOp) -> Self {
        PeConfig { op: PeOp::Bin(op), consts: [None; 3], delays: [0; 3] }
    }

    pub fn with_const(mut self, k: usize, v: i32) -> Self {
        self.consts[k] = Some(v);
        self
    }

    pub fn with_delay(mut self, k: usize, d: usize) -> Self {
        self.delays[k] = d;
        self
    }
}

/// Behavioral PE model.
#[derive(Clone, Debug)]
pub struct PeTile {
    cfg: PeConfig,
    delay_lines: [DelayLine; 3],
    out_reg: i32,
    acc: i32,
    fire_count: i64,
    pub ops_executed: u64,
}

impl PeTile {
    pub fn new(cfg: PeConfig) -> Self {
        let delay_lines = [
            DelayLine::new(cfg.delays[0]),
            DelayLine::new(cfg.delays[1]),
            DelayLine::new(cfg.delays[2]),
        ];
        let acc = match cfg.op {
            PeOp::Acc { init, .. } => init,
            _ => 0,
        };
        PeTile { cfg, delay_lines, out_reg: 0, acc, fire_count: 0, ops_executed: 0 }
    }

    /// Registered output from the previous cycle's computation.
    pub fn output(&self) -> i32 {
        self.out_reg
    }

    /// Return to the just-configured state (operand delay lines
    /// flushed, output register and accumulator cleared) so one
    /// instantiated PE can be reused across simulation runs
    /// (docs/simulator.md).
    pub fn reset(&mut self) {
        for d in &mut self.delay_lines {
            d.reset();
        }
        self.out_reg = 0;
        self.acc = match self.cfg.op {
            PeOp::Acc { init, .. } => init,
            _ => 0,
        };
        self.fire_count = 0;
        self.ops_executed = 0;
    }

    /// Compute one cycle with routed operand values (ignored where a
    /// constant is configured). The result appears on
    /// [`PeTile::output`] after this call (1-cycle latency).
    pub fn tick(&mut self, inputs: [i32; 3]) {
        let mut ops = [0i32; 3];
        for k in 0..3 {
            let routed = self.delay_lines[k].push(inputs[k] as i64) as i32;
            ops[k] = self.cfg.consts[k].unwrap_or(routed);
        }
        self.ops_executed += 1;
        self.out_reg = match &self.cfg.op {
            PeOp::Bin(op) => eval_binop(*op, ops[0], ops[1]),
            PeOp::Un(op) => match op {
                UnOp::Neg => ops[0].wrapping_neg(),
                UnOp::Abs => ops[0].wrapping_abs(),
            },
            PeOp::Select => {
                if ops[0] != 0 {
                    ops[1]
                } else {
                    ops[2]
                }
            }
            PeOp::Acc { op, init, period } => {
                if self.fire_count % period == 0 {
                    self.acc = *init;
                }
                self.fire_count += 1;
                self.acc = eval_binop(*op, self.acc, ops[0]);
                self.acc
            }
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_with_const() {
        let mut pe = PeTile::new(PeConfig::bin(BinOp::Mul).with_const(1, 2));
        pe.tick([21, 0, 0]);
        assert_eq!(pe.output(), 42);
    }

    #[test]
    fn latency_is_one_cycle() {
        let mut pe = PeTile::new(PeConfig::bin(BinOp::Add));
        assert_eq!(pe.output(), 0);
        pe.tick([3, 4, 0]);
        assert_eq!(pe.output(), 7);
        pe.tick([10, 20, 0]);
        assert_eq!(pe.output(), 30);
    }

    #[test]
    fn operand_delay_retimes() {
        // Operand 0 delayed 2 cycles: out(t) = in0(t-2) + in1(t).
        let mut pe = PeTile::new(PeConfig::bin(BinOp::Add).with_delay(0, 2));
        let a = [1, 2, 3, 4, 5];
        let b = [10, 20, 30, 40, 50];
        let mut outs = Vec::new();
        for k in 0..5 {
            pe.tick([a[k], b[k], 0]);
            outs.push(pe.output());
        }
        assert_eq!(outs, vec![10, 20, 31, 42, 53]);
    }

    #[test]
    fn accumulator_clears_each_period() {
        // Sum groups of 3.
        let mut pe = PeTile::new(PeConfig {
            op: PeOp::Acc { op: BinOp::Add, init: 0, period: 3 },
            consts: [None; 3],
            delays: [0; 3],
        });
        let vals = [1, 2, 3, 10, 20, 30];
        let mut outs = Vec::new();
        for v in vals {
            pe.tick([v, 0, 0]);
            outs.push(pe.output());
        }
        assert_eq!(outs, vec![1, 3, 6, 10, 30, 60]);
    }

    #[test]
    fn reset_restores_accumulator_and_delays() {
        let mut pe = PeTile::new(PeConfig {
            op: PeOp::Acc { op: BinOp::Add, init: 0, period: 3 },
            consts: [None; 3],
            delays: [2, 0, 0],
        });
        let run = |pe: &mut PeTile| -> Vec<i32> {
            (1..=4).map(|v| {
                pe.tick([v, 0, 0]);
                pe.output()
            }).collect()
        };
        let first = run(&mut pe);
        pe.reset();
        assert_eq!(pe.output(), 0);
        assert_eq!(pe.ops_executed, 0);
        assert_eq!(run(&mut pe), first);
    }

    #[test]
    fn select_op() {
        let mut pe = PeTile::new(PeConfig {
            op: PeOp::Select,
            consts: [None; 3],
            delays: [0; 3],
        });
        pe.tick([1, 42, 7]);
        assert_eq!(pe.output(), 42);
        pe.tick([0, 42, 7]);
        assert_eq!(pe.output(), 7);
    }
}
