//! The transpose buffer (TB): a **double-buffered** (two-vector,
//! "four to eight words per port", §IV-B) register file that receives
//! SRAM vectors and serializes them onto the output port. The ping-pong
//! halves let the next vector land while the previous one is still
//! being drained — without it, delayed streams whose distance is
//! ≡ 1 (mod fetch width) could never share the single SRAM port.
//! Named for the iteration-space transpose between the vector dimension
//! and the serial output order.

#[derive(Clone, Debug)]
pub struct TransposeBuffer {
    regs: Vec<i64>,
    fetch_width: usize,
    pub loads: u64,
}

impl TransposeBuffer {
    pub fn new(fetch_width: usize) -> Self {
        TransposeBuffer { regs: vec![0; 2 * fetch_width], fetch_width, loads: 0 }
    }

    pub fn fetch_width(&self) -> usize {
        self.fetch_width
    }

    /// Zero both halves and the load counter (per-run reuse).
    pub fn reset(&mut self) {
        self.regs.iter_mut().for_each(|r| *r = 0);
        self.loads = 0;
    }

    /// Parallel load of one vector into half 0 or 1.
    pub fn load(&mut self, half: usize, words: &[i64]) {
        assert_eq!(words.len(), self.fetch_width, "TB width mismatch");
        assert!(half < 2);
        let base = half * self.fetch_width;
        self.regs[base..base + self.fetch_width].copy_from_slice(words);
        self.loads += 1;
    }

    /// Serial read of one slot (0..2*fetch_width).
    pub fn read(&self, slot: i64) -> i64 {
        assert!(
            (0..self.regs.len() as i64).contains(&slot),
            "TB slot {slot} out of range"
        );
        self.regs[slot as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_then_serialize_both_halves() {
        let mut tb = TransposeBuffer::new(4);
        tb.load(0, &[1, 2, 3, 4]);
        tb.load(1, &[5, 6, 7, 8]);
        assert_eq!((0..8).map(|k| tb.read(k)).collect::<Vec<_>>(), vec![1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(tb.loads, 2);
        // Reloading one half leaves the other intact.
        tb.load(0, &[9, 9, 9, 9]);
        assert_eq!(tb.read(5), 6);
        assert_eq!(tb.read(0), 9);
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        TransposeBuffer::new(4).load(0, &[1, 2]);
    }
}
