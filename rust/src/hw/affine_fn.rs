//! The three affine-function hardware implementations of Fig 5.
//!
//! An address/schedule generator computes `Σ s_k·i_k + offset` as the
//! iteration domain steps. The paper optimizes the implementation in two
//! steps: replace multipliers with per-dimension stride accumulators
//! (Fig 5b), then collapse to a single adder using the delta recurrence
//! (Fig 5c):
//!
//! ```text
//! d_outer = s_outer − Σ_{i inner} s_i · (r_i − 1)
//! ```
//!
//! All three are bit-equivalent; the tests sweep full domains to prove
//! it. Each reports its resource usage for the Table II cost model.

/// Configuration of an affine function over an iteration domain:
/// strides are listed **outermost-first**, matching
/// [`crate::poly::Affine`] coefficients.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AffineConfig {
    pub strides: Vec<i64>,
    pub offset: i64,
}

impl AffineConfig {
    pub fn from_affine(a: &crate::poly::Affine) -> Self {
        AffineConfig { strides: a.coeffs.clone(), offset: a.offset }
    }

    /// Loop-boundary deltas for the Fig 5c recurrence, given the domain
    /// extents (`r_k`): `d_k = s_k − Σ_{i>k} s_i (r_i − 1)` (dims inner
    /// to `k` rewind to their start when `k` increments).
    pub fn deltas(&self, extents: &[i64]) -> Vec<i64> {
        assert_eq!(self.strides.len(), extents.len());
        let n = self.strides.len();
        (0..n)
            .map(|k| {
                let rewind: i64 = (k + 1..n)
                    .map(|i| self.strides[i] * (extents[i] - 1))
                    .sum();
                self.strides[k] - rewind
            })
            .collect()
    }
}

/// Hardware resource usage of an affine-function implementation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AffineCost {
    pub multipliers: usize,
    pub adders: usize,
    pub registers: usize,
}

/// Step events from the iteration domain: which dims incremented and
/// which wrapped (cleared) this step. At most one dim increments without
/// wrapping; all dims inner to it wrap.
pub trait AffineHw {
    fn reset(&mut self);
    /// Current function value (combinational output).
    fn value(&self) -> i64;
    /// Advance after the ID steps: `inc[k]`/`clr[k]` as in Fig 5b.
    fn step(&mut self, inc: &[bool], clr: &[bool]);
    fn cost(&self) -> AffineCost;
}

/// Fig 5a: explicit multipliers over the raw counter values.
#[derive(Clone, Debug)]
pub struct MultImpl {
    cfg: AffineConfig,
    counters: Vec<i64>,
}

impl MultImpl {
    pub fn new(cfg: AffineConfig) -> Self {
        let n = cfg.strides.len();
        MultImpl { cfg, counters: vec![0; n] }
    }
}

impl AffineHw for MultImpl {
    fn reset(&mut self) {
        self.counters.iter_mut().for_each(|c| *c = 0);
    }

    fn value(&self) -> i64 {
        self.cfg
            .strides
            .iter()
            .zip(&self.counters)
            .map(|(s, c)| s * c)
            .sum::<i64>()
            + self.cfg.offset
    }

    fn step(&mut self, inc: &[bool], clr: &[bool]) {
        for k in 0..self.counters.len() {
            if clr[k] {
                self.counters[k] = 0;
            } else if inc[k] {
                self.counters[k] += 1;
            }
        }
    }

    fn cost(&self) -> AffineCost {
        let n = self.cfg.strides.len();
        // n multipliers, n adders (the reduction tree + offset), n counters.
        AffineCost { multipliers: n, adders: n, registers: n }
    }
}

/// Fig 5b: one stride accumulator per dimension — no multipliers.
#[derive(Clone, Debug)]
pub struct IncrImpl {
    cfg: AffineConfig,
    partial: Vec<i64>,
}

impl IncrImpl {
    pub fn new(cfg: AffineConfig) -> Self {
        let n = cfg.strides.len();
        IncrImpl { cfg, partial: vec![0; n] }
    }
}

impl AffineHw for IncrImpl {
    fn reset(&mut self) {
        self.partial.iter_mut().for_each(|c| *c = 0);
    }

    fn value(&self) -> i64 {
        self.partial.iter().sum::<i64>() + self.cfg.offset
    }

    fn step(&mut self, inc: &[bool], clr: &[bool]) {
        for k in 0..self.partial.len() {
            if clr[k] {
                self.partial[k] = 0;
            } else if inc[k] {
                self.partial[k] += self.cfg.strides[k];
            }
        }
    }

    fn cost(&self) -> AffineCost {
        let n = self.cfg.strides.len();
        // One increment adder per dim plus the summation tree.
        AffineCost { multipliers: 0, adders: 2 * n, registers: n }
    }
}

/// Fig 5c: single running register + one adder; the increment is the
/// delta of the outermost dimension that stepped.
#[derive(Clone, Debug)]
pub struct DeltaImpl {
    deltas: Vec<i64>,
    offset: i64,
    value: i64,
}

impl DeltaImpl {
    pub fn new(cfg: &AffineConfig, extents: &[i64]) -> Self {
        DeltaImpl { deltas: cfg.deltas(extents), offset: cfg.offset, value: cfg.offset }
    }
}

impl AffineHw for DeltaImpl {
    fn reset(&mut self) {
        self.value = self.offset;
    }

    fn value(&self) -> i64 {
        self.value
    }

    fn step(&mut self, inc: &[bool], clr: &[bool]) {
        // The outermost dim that incremented (not wrapped) owns the step.
        for k in 0..self.deltas.len() {
            if inc[k] && !clr[k] {
                self.value += self.deltas[k];
                return;
            }
        }
        // Full wrap of every dim: the ID finished; value is stale.
    }

    fn cost(&self) -> AffineCost {
        AffineCost { multipliers: 0, adders: 1, registers: 1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::id::IterationDomain;
    use crate::poly::Affine;

    /// Sweep a full iteration domain and check an implementation tracks
    /// the explicit affine function exactly.
    fn check_impl(mut hw: impl AffineHw, expr: &Affine, extents: &[i64]) {
        let mut id = IterationDomain::new(extents.to_vec());
        loop {
            let pt = id.point().to_vec();
            assert_eq!(
                hw.value(),
                expr.eval(&pt),
                "mismatch at {pt:?} for extents {extents:?}"
            );
            let Some((inc, clr)) = id.step() else { break };
            hw.step(&inc, &clr);
        }
    }

    fn downsample2_cfg() -> (AffineConfig, Affine, Vec<i64>) {
        // Fig 6: downsample-by-2 of an 8x8 image: addr = 16y + 2x over
        // a 4x4 iteration domain.
        let a = Affine::new(vec![16, 2], 0);
        (AffineConfig::from_affine(&a), a, vec![4, 4])
    }

    #[test]
    fn deltas_match_fig6() {
        // Fig 6: d_x = 2, d_y = 16 - 2*(4-1) = 10.
        let (cfg, _, ext) = downsample2_cfg();
        assert_eq!(cfg.deltas(&ext), vec![10, 2]);
    }

    #[test]
    fn all_three_impls_agree_fig6() {
        let (cfg, a, ext) = downsample2_cfg();
        check_impl(MultImpl::new(cfg.clone()), &a, &ext);
        check_impl(IncrImpl::new(cfg.clone()), &a, &ext);
        check_impl(DeltaImpl::new(&cfg, &ext), &a, &ext);
    }

    #[test]
    fn impls_agree_on_3d_with_offset_and_negative_strides() {
        let a = Affine::new(vec![-7, 5, 3], 100);
        let cfg = AffineConfig::from_affine(&a);
        let ext = vec![3, 4, 5];
        check_impl(MultImpl::new(cfg.clone()), &a, &ext);
        check_impl(IncrImpl::new(cfg.clone()), &a, &ext);
        check_impl(DeltaImpl::new(&cfg, &ext), &a, &ext);
    }

    #[test]
    fn impls_agree_on_1d() {
        let a = Affine::new(vec![4], -3);
        let cfg = AffineConfig::from_affine(&a);
        check_impl(DeltaImpl::new(&cfg, &[17]), &a, &[17]);
        check_impl(IncrImpl::new(cfg.clone()), &a, &[17]);
        check_impl(MultImpl::new(cfg), &a, &[17]);
    }

    #[test]
    fn cost_ordering_matches_paper() {
        let (cfg, _, ext) = downsample2_cfg();
        let m = MultImpl::new(cfg.clone()).cost();
        let i = IncrImpl::new(cfg.clone()).cost();
        let d = DeltaImpl::new(&cfg, &ext).cost();
        assert!(m.multipliers > 0);
        assert_eq!(i.multipliers, 0);
        assert_eq!(d.multipliers, 0);
        assert_eq!(d.adders, 1);
        assert!(d.registers < i.registers || i.registers == 1);
    }
}
