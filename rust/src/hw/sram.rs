//! SRAM macro models: wide-fetch single-port (the shipped design) and
//! dual-port word-granular (the Table II baseline).

use anyhow::{bail, Result};

/// Access statistics, consumed by the energy model (§VI-A).
#[derive(Clone, Copy, Debug, Default)]
pub struct SramStats {
    pub reads: u64,
    pub writes: u64,
    pub conflicts: u64,
}

/// A single-port SRAM fetching `fetch_width` words per access, with a
/// one-cycle read latency. At most one access (read *or* write) per
/// cycle; concurrent requests are conflicts (the mapper must schedule
/// port sharing, §IV-B).
#[derive(Clone, Debug)]
pub struct WideSram {
    pub fetch_width: usize,
    /// Capacity in *words*.
    pub capacity: usize,
    data: Vec<i64>,
    accessed_this_cycle: bool,
    /// Double-buffered read register: `read_vec` fills `pending_buf`,
    /// `end_cycle` swaps it into `ready_buf`. Fixed buffers instead of
    /// per-read `Vec`s — the simulator's steady state must not
    /// allocate per SRAM access.
    pending: bool,
    ready: bool,
    pending_buf: Vec<i64>,
    ready_buf: Vec<i64>,
    pub stats: SramStats,
}

impl WideSram {
    pub fn new(capacity: usize, fetch_width: usize) -> Self {
        assert!(capacity % fetch_width == 0, "capacity not a vector multiple");
        WideSram {
            fetch_width,
            capacity,
            data: vec![0; capacity],
            accessed_this_cycle: false,
            pending: false,
            ready: false,
            pending_buf: vec![0; fetch_width],
            ready_buf: vec![0; fetch_width],
            stats: SramStats::default(),
        }
    }

    pub fn vector_count(&self) -> usize {
        self.capacity / self.fetch_width
    }

    /// Zero all storage and statistics (the simulator's per-run reuse
    /// path — a reset run must be bit-identical to a fresh instance).
    pub fn reset(&mut self) {
        self.data.iter_mut().for_each(|w| *w = 0);
        self.accessed_this_cycle = false;
        self.pending = false;
        self.ready = false;
        self.stats = SramStats::default();
    }

    fn claim_port(&mut self) -> Result<()> {
        if self.accessed_this_cycle {
            self.stats.conflicts += 1;
            bail!("single-port SRAM access conflict");
        }
        self.accessed_this_cycle = true;
        Ok(())
    }

    /// Write one vector at vector-address `vaddr`.
    pub fn write_vec(&mut self, vaddr: i64, words: &[i64]) -> Result<()> {
        assert_eq!(words.len(), self.fetch_width);
        self.claim_port()?;
        let base = self.word_base(vaddr)?;
        self.data[base..base + self.fetch_width].copy_from_slice(words);
        self.stats.writes += 1;
        Ok(())
    }

    /// Issue a vector read; data is available via [`WideSram::take_read`]
    /// (or the allocation-free [`WideSram::take_read_ref`]) after the
    /// next [`WideSram::end_cycle`].
    pub fn read_vec(&mut self, vaddr: i64) -> Result<()> {
        self.claim_port()?;
        let base = self.word_base(vaddr)?;
        self.pending_buf
            .copy_from_slice(&self.data[base..base + self.fetch_width]);
        self.pending = true;
        self.stats.reads += 1;
        Ok(())
    }

    fn word_base(&self, vaddr: i64) -> Result<usize> {
        let n = self.vector_count() as i64;
        if vaddr < 0 || vaddr >= n {
            bail!("vector address {vaddr} out of range 0..{n}");
        }
        Ok(vaddr as usize * self.fetch_width)
    }

    /// Retire the cycle: pending read data becomes ready.
    pub fn end_cycle(&mut self) {
        std::mem::swap(&mut self.pending_buf, &mut self.ready_buf);
        self.ready = self.pending;
        self.pending = false;
        self.accessed_this_cycle = false;
    }

    /// Data from the read issued last cycle.
    pub fn take_read(&mut self) -> Option<Vec<i64>> {
        self.take_read_ref().map(|d| d.to_vec())
    }

    /// [`WideSram::take_read`] without the copy: borrows the read
    /// register directly (the memory tile loads it straight into a
    /// transpose buffer).
    pub fn take_read_ref(&mut self) -> Option<&[i64]> {
        if self.ready {
            self.ready = false;
            Some(&self.ready_buf)
        } else {
            None
        }
    }
}

/// A dual-port word-granular SRAM (one read port + one write port per
/// cycle), the naïve Fig 3 implementation.
#[derive(Clone, Debug)]
pub struct DualPortSram {
    pub capacity: usize,
    data: Vec<i64>,
    pending_write: Option<(usize, i64)>,
    read_this_cycle: bool,
    pending_read: Option<i64>,
    ready_read: Option<i64>,
    pub stats: SramStats,
}

impl DualPortSram {
    pub fn new(capacity: usize) -> Self {
        DualPortSram {
            capacity,
            data: vec![0; capacity],
            pending_write: None,
            read_this_cycle: false,
            pending_read: None,
            ready_read: None,
            stats: SramStats::default(),
        }
    }

    /// Zero all storage and statistics; see [`WideSram::reset`].
    pub fn reset(&mut self) {
        self.data.iter_mut().for_each(|w| *w = 0);
        self.pending_write = None;
        self.read_this_cycle = false;
        self.pending_read = None;
        self.ready_read = None;
        self.stats = SramStats::default();
    }

    /// Write commits at end of cycle: a same-cycle read of the same
    /// address returns the old data.
    pub fn write(&mut self, addr: i64, word: i64) -> Result<()> {
        if self.pending_write.is_some() {
            self.stats.conflicts += 1;
            bail!("dual-port SRAM: second write in one cycle");
        }
        if addr < 0 || addr as usize >= self.capacity {
            bail!("address {addr} out of range");
        }
        self.pending_write = Some((addr as usize, word));
        self.stats.writes += 1;
        Ok(())
    }

    pub fn read(&mut self, addr: i64) -> Result<()> {
        if self.read_this_cycle {
            self.stats.conflicts += 1;
            bail!("dual-port SRAM: second read in one cycle");
        }
        if addr < 0 || addr as usize >= self.capacity {
            bail!("address {addr} out of range");
        }
        self.read_this_cycle = true;
        self.pending_read = Some(self.data[addr as usize]);
        self.stats.reads += 1;
        Ok(())
    }

    pub fn end_cycle(&mut self) {
        self.ready_read = self.pending_read.take();
        if let Some((addr, word)) = self.pending_write.take() {
            self.data[addr] = word;
        }
        self.read_this_cycle = false;
    }

    pub fn take_read(&mut self) -> Option<i64> {
        self.ready_read.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_write_then_read_with_latency() {
        let mut s = WideSram::new(32, 4);
        s.write_vec(2, &[10, 11, 12, 13]).unwrap();
        s.end_cycle();
        s.read_vec(2).unwrap();
        assert_eq!(s.take_read(), None, "read data not ready same cycle");
        s.end_cycle();
        assert_eq!(s.take_read(), Some(vec![10, 11, 12, 13]));
        assert_eq!(s.stats.reads, 1);
        assert_eq!(s.stats.writes, 1);
    }

    #[test]
    fn single_port_conflict_detected() {
        let mut s = WideSram::new(16, 4);
        s.write_vec(0, &[1, 2, 3, 4]).unwrap();
        assert!(s.read_vec(1).is_err());
        assert_eq!(s.stats.conflicts, 1);
        s.end_cycle();
        s.read_vec(0).unwrap(); // fine next cycle
    }

    #[test]
    fn wide_oob_rejected() {
        let mut s = WideSram::new(16, 4);
        assert!(s.write_vec(4, &[0; 4]).is_err());
        assert!(s.write_vec(-1, &[0; 4]).is_err());
    }

    #[test]
    fn dual_port_parallel_read_write() {
        let mut s = DualPortSram::new(8);
        s.write(3, 42).unwrap();
        s.read(3).unwrap(); // old value, same cycle: reads 0
        s.end_cycle();
        assert_eq!(s.take_read(), Some(0));
        s.read(3).unwrap();
        s.end_cycle();
        assert_eq!(s.take_read(), Some(42));
    }

    #[test]
    fn dual_port_double_access_conflicts() {
        let mut s = DualPortSram::new(8);
        s.read(0).unwrap();
        assert!(s.read(1).is_err());
        s.write(0, 1).unwrap();
        assert!(s.write(1, 2).is_err());
    }
}
