//! Analytic prune: reject or rank candidates *before* any simulation.
//!
//! Lowering a candidate is symbolic and cheap (inlining + exact
//! interval bounds inference); cycle-accurate simulation is the
//! expensive step. So the tuner lowers every enumerated candidate,
//! rejects the ones that can never work, and ranks the survivors by
//! an analytic proxy of the objective so the simulation budget is
//! spent on the most promising points first.
//!
//! Feasibility checks (all conservative — a rejected candidate could
//! never have produced a deployable design):
//!
//! * lowering itself fails (e.g. an unroll of a dim that does not
//!   start at 0, or a schedule validation error);
//! * ALU-op estimate exceeds the array's PE tiles (384 on the paper's
//!   16x32 array) — recompute-heavy schedules like Table V sch1 at
//!   769 PEs die here;
//! * more materialized buffers than MEM tiles, or a realization-box
//!   footprint beyond total SRAM capacity. The footprint is an upper
//!   bound — the mapper's storage minimization only shrinks it — so
//!   exceeding capacity here is a safe reject.
//!
//! Cost proxies (used for ranking only, never for rejection): an
//! issue-slot lower bound on cycles, a Table II-calibrated area sum
//! ([`crate::cost::area`]), and a per-output-pixel energy figure from
//! the [`crate::cost::energy`] constants.

use crate::cgra::CgraSpec;
use crate::cost::area::{table2_variants, PE_UM2};
use crate::cost::energy::{AGG_TB_PJ, CTL_PJ, PE_OP_PJ, SP_WORD_PJ};
use crate::halide::{lower, Program};
use crate::mapping::TILE_CAPACITY_WORDS;

/// Analytic pre-simulation estimates for one feasible candidate.
#[derive(Clone, Debug)]
pub struct Analysis {
    /// One PE per ALU op (the Table IV/V estimate).
    pub pe_estimate: usize,
    /// Materialized buffers (each needs at least one MEM bank).
    pub buffer_count: usize,
    /// Sum of realization-box footprints in words (upper bound on the
    /// mapped SRAM allocation).
    pub words_estimate: i64,
    /// Issue-slot lower bound on completion: the output stage's full
    /// domain cardinality (its lanes issue one point per cycle at
    /// best).
    pub cycles_lb: i64,
    /// Analytic silicon area (µm², Table II constants).
    pub area_um2: f64,
    /// Analytic energy per output pixel (pJ) — a ranking proxy, much
    /// cruder than the simulated [`crate::cost::energy_per_op_pj`].
    pub energy_per_pixel_pj: f64,
}

/// The prune verdict for one candidate.
#[derive(Clone, Debug)]
pub enum Verdict {
    Feasible(Analysis),
    /// Why the candidate can never produce a deployable design.
    Infeasible(String),
}

impl Verdict {
    pub fn is_feasible(&self) -> bool {
        matches!(self, Verdict::Feasible(_))
    }
}

/// Analyze `program` (whose schedule is the candidate under test)
/// against `spec`'s capacity.
pub fn prune(program: &Program, spec: &CgraSpec) -> Verdict {
    let lp = match lower::lower(program) {
        Ok(lp) => lp,
        Err(e) => return Verdict::Infeasible(format!("lowering: {e:#}")),
    };

    let pe_estimate: usize = lp.stages.iter().map(|s| s.alu_ops()).sum();
    if pe_estimate > spec.pe_tiles() {
        return Verdict::Infeasible(format!(
            "needs {pe_estimate} PEs > the array's {}",
            spec.pe_tiles()
        ));
    }

    let buffer_count = lp.buffers.len();
    if buffer_count > spec.mem_tiles() {
        return Verdict::Infeasible(format!(
            "{buffer_count} buffers > the array's {} MEM tiles",
            spec.mem_tiles()
        ));
    }

    let words_estimate: i64 = lp.buffers.values().map(|b| b.cardinality()).sum();
    let sram_budget = (spec.mem_tiles() * TILE_CAPACITY_WORDS) as i64;
    if words_estimate > sram_budget {
        return Verdict::Infeasible(format!(
            "footprint {words_estimate} words > total SRAM {sram_budget}"
        ));
    }

    // The output stage issues one full-domain point per lane-set per
    // cycle at II=1; completion can never beat that.
    let out_stage = lp.stages.last().expect("lowering yields >= 1 stage");
    let cycles_lb = out_stage.full_domain().cardinality().max(1);

    // Total ALU firings per tile: every instance of a stage fires once
    // per full-domain point (alu_ops already sums over instances).
    let ops_per_tile: i64 = lp
        .stages
        .iter()
        .map(|s| s.alu_ops() as i64 * s.full_domain().cardinality())
        .sum();
    let out_pixels = lp.buffers[&lp.output].cardinality().max(1);

    let mem_tile_um2 = table2_variants()[2].1.mem_tile_um2;
    let tiles_needed = (buffer_count as i64)
        .max((words_estimate + TILE_CAPACITY_WORDS as i64 - 1) / TILE_CAPACITY_WORDS as i64);
    let area_um2 = pe_estimate as f64 * PE_UM2 + tiles_needed as f64 * mem_tile_um2;

    // Per pixel: every op costs one PE firing; every materialized word
    // is written once and read at least once through the wide-fetch
    // SRAM path.
    let access_pj = SP_WORD_PJ + AGG_TB_PJ + CTL_PJ;
    let energy_per_pixel_pj = (ops_per_tile as f64 * PE_OP_PJ
        + 2.0 * words_estimate as f64 * access_pj)
        / out_pixels as f64;

    Verdict::Feasible(Analysis {
        pe_estimate,
        buffer_count,
        words_estimate,
        cycles_lb,
        area_um2,
        energy_per_pixel_pj,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{gaussian, harris};
    use crate::halide::HwSchedule;

    #[test]
    fn gaussian_default_is_feasible() {
        let a = match prune(&gaussian::build(14), &CgraSpec::default()) {
            Verdict::Feasible(a) => a,
            v => panic!("{v:?}"),
        };
        assert!(a.pe_estimate > 0);
        assert!(a.cycles_lb >= 14 * 14);
        assert!(a.area_um2 > 0.0);
        assert!(a.energy_per_pixel_pj > 0.0);
    }

    #[test]
    fn recompute_all_unrolled_is_pruned_for_pes() {
        // sch1 ("recompute all") is already several hundred PEs;
        // unrolling it by 4 puts it far over the 384-PE array.
        let mut p = harris::build(20, harris::Schedule::RecomputeAll);
        p.schedule = p.schedule.unroll("corners", "x", 4);
        let why = match prune(&p, &CgraSpec::default()) {
            Verdict::Infeasible(why) => why,
            v => panic!("expected infeasible, got {v:?}"),
        };
        assert!(why.contains("PEs"), "{why}");
    }

    #[test]
    fn invalid_schedule_is_pruned_not_panicking() {
        let mut p = gaussian::build(14);
        p.schedule = HwSchedule::new([14, 0]);
        assert!(!prune(&p, &CgraSpec::default()).is_feasible());
    }

    #[test]
    fn lower_bound_tracks_unrolling() {
        // Unrolling by 2 halves the issue-slot lower bound.
        let base = prune(&gaussian::build(16), &CgraSpec::default());
        let mut p = gaussian::build(16);
        p.schedule = p.schedule.unroll("gaussian", "x", 2);
        let unrolled = prune(&p, &CgraSpec::default());
        match (base, unrolled) {
            (Verdict::Feasible(a), Verdict::Feasible(b)) => {
                assert_eq!(a.cycles_lb, 2 * b.cycles_lb);
                assert!(b.pe_estimate > a.pe_estimate);
            }
            other => panic!("{other:?}"),
        }
    }
}
