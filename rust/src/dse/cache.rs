//! Content-addressed result cache for the schedule tuner.
//!
//! Every evaluated candidate is keyed by a hash of its *canonical
//! schedule encoding* (plus the app name), so re-running the tuner —
//! with a different budget, seed, or objective — never re-simulates a
//! schedule it has already scored, and `pushmem serve --tuned-dir`
//! can pick up the winner without recompiling the search.
//!
//! On-disk format (specified in docs/dse.md): one TSV file per app,
//! `<dir>/<app>.tsv`, each line
//!
//! ```text
//! key  cycles  completion  pes  mems  sram_words  energy_per_op_pj \
//!      pixels_per_cycle  area_um2  schedule-encoding
//! ```
//!
//! plus `<dir>/<app>.best` holding the single winning line and — when
//! the tuner ran with `--objective pareto` — `<dir>/<app>.pareto`
//! holding one line per member of the cycles-vs-PEs Pareto front
//! (best-cycles first), the record variant-aware serving loads (see
//! docs/routing.md). Lines starting with `#` and lines that fail to
//! parse are skipped on load (forward compatibility), and a corrupt
//! `.best` simply means "no tuned schedule" — serving falls back to
//! the hand-written default. `.pareto` lines are additionally
//! *verified* on load ([`load_pareto`]): the key is recomputed from
//! the decoded schedule exactly as [`lookup_verified`]
//! (DseCache::lookup_verified) re-checks encodings, so a corrupt or
//! forged line can never smuggle a different schedule into serving.
//!
//! No serde is vendored in this offline image, so the schedule
//! encoding is a hand-rolled `field=value|...` string with set-valued
//! fields sorted, making it canonical: two `HwSchedule`s that differ
//! only in directive order hash identically.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::halide::HwSchedule;

/// FNV-1a 64-bit: tiny, dependency-free, stable across platforms —
/// exactly what a content address needs here (not cryptographic; the
/// cache is a local performance artifact, not a trust boundary).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The cache key of one candidate: hex FNV-1a over app name + the
/// canonical schedule encoding.
pub fn candidate_key(app: &str, sched: &HwSchedule) -> String {
    format!("{:016x}", fnv1a64(format!("{app}\n{}", encode_schedule(sched)).as_bytes()))
}

fn sorted_join(v: &[String]) -> String {
    let mut v = v.to_vec();
    v.sort();
    v.dedup();
    v.join(",")
}

/// Canonical text encoding of a schedule. Set-valued directives
/// (`mem`, `runroll`, `host`) are sorted and deduped; `unroll` keeps
/// per-func split order (successive splits of one var are not
/// commutative) but iterates funcs in `BTreeMap` order. Empty
/// sections are omitted; `tile` is always present.
pub fn encode_schedule(s: &HwSchedule) -> String {
    let tile: Vec<String> = s.tile.iter().map(|e| e.to_string()).collect();
    let mut parts = vec![format!("tile={}", tile.join("x"))];
    if !s.memories.is_empty() {
        parts.push(format!("mem={}", sorted_join(&s.memories)));
    }
    if !s.unroll.is_empty() {
        let entries: Vec<String> = s
            .unroll
            .iter()
            .flat_map(|(f, es)| es.iter().map(move |(v, u)| format!("{f}:{v}:{u}")))
            .collect();
        parts.push(format!("unroll={}", entries.join(",")));
    }
    if !s.unroll_reductions.is_empty() {
        parts.push(format!("runroll={}", sorted_join(&s.unroll_reductions)));
    }
    if !s.host_stages.is_empty() {
        parts.push(format!("host={}", sorted_join(&s.host_stages)));
    }
    parts.join("|")
}

fn name_list(v: &str) -> Vec<String> {
    v.split(',').filter(|s| !s.is_empty()).map(|s| s.to_string()).collect()
}

/// Inverse of [`encode_schedule`]. The decoded schedule is structural
/// only — run [`HwSchedule::validate`] against the target program's
/// funcs before compiling with it.
pub fn decode_schedule(enc: &str) -> Result<HwSchedule> {
    let mut s = HwSchedule::default();
    for part in enc.split('|') {
        let (k, v) = part
            .split_once('=')
            .with_context(|| format!("bad schedule field {part:?}"))?;
        match k {
            "tile" => {
                s.tile = v
                    .split('x')
                    .map(|t| t.parse::<i64>().with_context(|| format!("bad tile extent {t:?}")))
                    .collect::<Result<Vec<i64>>>()?;
            }
            "mem" => s.memories = name_list(v),
            "unroll" => {
                for e in v.split(',').filter(|e| !e.is_empty()) {
                    let fields: Vec<&str> = e.split(':').collect();
                    let &[f, var, u] = fields.as_slice() else {
                        bail!("bad unroll entry {e:?} (want func:var:factor)");
                    };
                    let factor: i64 =
                        u.parse().with_context(|| format!("bad unroll factor {u:?}"))?;
                    s.unroll
                        .entry(f.to_string())
                        .or_default()
                        .push((var.to_string(), factor));
                }
            }
            "runroll" => s.unroll_reductions = name_list(v),
            "host" => s.host_stages = name_list(v),
            other => bail!("unknown schedule field {other:?}"),
        }
    }
    anyhow::ensure!(!s.tile.is_empty(), "schedule encoding {enc:?} has no tile");
    Ok(s)
}

/// One scored candidate as persisted in the cache.
#[derive(Clone, Debug)]
pub struct CacheEntry {
    pub key: String,
    pub cycles: i64,
    pub completion: i64,
    pub pes: usize,
    pub mems: usize,
    pub sram_words: i64,
    pub energy_per_op_pj: f64,
    pub pixels_per_cycle: f64,
    pub area_um2: f64,
    /// Canonical schedule encoding ([`encode_schedule`]).
    pub encoded: String,
}

impl CacheEntry {
    pub fn schedule(&self) -> Result<HwSchedule> {
        decode_schedule(&self.encoded)
    }

    fn to_line(&self) -> String {
        format!(
            "{}\t{}\t{}\t{}\t{}\t{}\t{:.6}\t{:.6}\t{:.1}\t{}",
            self.key,
            self.cycles,
            self.completion,
            self.pes,
            self.mems,
            self.sram_words,
            self.energy_per_op_pj,
            self.pixels_per_cycle,
            self.area_um2,
            self.encoded
        )
    }

    fn parse_line(line: &str) -> Result<CacheEntry> {
        let f: Vec<&str> = line.split('\t').collect();
        let &[key, cycles, completion, pes, mems, sram, energy, ppc, area, encoded] =
            f.as_slice()
        else {
            bail!("cache line has {} fields, want 10", f.len());
        };
        Ok(CacheEntry {
            key: key.to_string(),
            cycles: cycles.parse().context("cycles")?,
            completion: completion.parse().context("completion")?,
            pes: pes.parse().context("pes")?,
            mems: mems.parse().context("mems")?,
            sram_words: sram.parse().context("sram_words")?,
            energy_per_op_pj: energy.parse().context("energy_per_op_pj")?,
            pixels_per_cycle: ppc.parse().context("pixels_per_cycle")?,
            area_um2: area.parse().context("area_um2")?,
            encoded: encoded.to_string(),
        })
    }
}

const HEADER: &str = "# pushmem dse cache v1: key cycles completion pes mems \
sram_words energy_per_op_pj pixels_per_cycle area_um2 schedule";

/// The per-app result cache: an in-memory index over `<dir>/<app>.tsv`,
/// appended on every [`record`](DseCache::record).
pub struct DseCache {
    path: PathBuf,
    best_path: PathBuf,
    pareto_path: PathBuf,
    entries: BTreeMap<String, CacheEntry>,
}

/// `<dir>/<app>.best` — exposed so callers (the tuned-serving loader)
/// can distinguish "no record" from "unreadable record" without
/// duplicating the naming convention.
pub fn best_path(dir: &Path, app: &str) -> PathBuf {
    dir.join(format!("{app}.best"))
}

/// `<dir>/<app>.pareto` — the persisted Pareto front.
pub fn pareto_path(dir: &Path, app: &str) -> PathBuf {
    dir.join(format!("{app}.pareto"))
}

impl DseCache {
    /// Open (creating `dir` if needed) and load the cache for `app`.
    /// Malformed lines are skipped, not fatal.
    pub fn open(dir: &Path, app: &str) -> Result<DseCache> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        let path = dir.join(format!("{app}.tsv"));
        let best = best_path(dir, app);
        let pareto = pareto_path(dir, app);
        let mut entries = BTreeMap::new();
        if path.exists() {
            let text = fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            for line in text.lines() {
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                if let Ok(e) = CacheEntry::parse_line(line) {
                    entries.insert(e.key.clone(), e);
                }
            }
        }
        Ok(DseCache { path, best_path: best, pareto_path: pareto, entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn lookup(&self, key: &str) -> Option<&CacheEntry> {
        self.entries.get(key)
    }

    /// Collision-safe lookup: a hit must carry the *same canonical
    /// schedule encoding* as the query, not just the same 64-bit
    /// FNV-1a key. FNV is not collision-resistant, and a colliding hit
    /// would silently return another candidate's score (and could even
    /// crown it `.best`); an encoding mismatch is therefore treated as
    /// a miss, and the candidate goes back to the simulator.
    pub fn lookup_verified(&self, key: &str, encoded: &str) -> Option<&CacheEntry> {
        match self.entries.get(key) {
            Some(e) if e.encoded == encoded => Some(e),
            Some(e) => {
                eprintln!(
                    "[dse] cache key {key} collides: stored {:?} != queried {encoded:?}; \
                     treating as a miss",
                    e.encoded
                );
                None
            }
            None => None,
        }
    }

    /// Persist one scored candidate (append + index). Re-recording an
    /// existing key overwrites the index entry; the duplicate line is
    /// harmless (last one wins on reload).
    pub fn record(&mut self, entry: CacheEntry) -> Result<()> {
        let fresh = !self.path.exists();
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening {}", self.path.display()))?;
        if fresh {
            writeln!(f, "{HEADER}")?;
        }
        writeln!(f, "{}", entry.to_line())?;
        self.entries.insert(entry.key.clone(), entry);
        Ok(())
    }

    /// Mark `key` as the tuned-best schedule (`<app>.best`), the record
    /// `pushmem serve --tuned-dir` loads.
    pub fn write_best(&self, key: &str) -> Result<()> {
        let e = self
            .entries
            .get(key)
            .with_context(|| format!("best key {key} not in cache"))?;
        fs::write(&self.best_path, format!("{}\n", e.to_line()))
            .with_context(|| format!("writing {}", self.best_path.display()))
    }

    /// Persist the Pareto front (`<app>.pareto`): one cached line per
    /// key, in the order given (best-cycles first by convention of the
    /// caller). Every key must already be in the cache — the front is
    /// always a subset of scored candidates.
    pub fn write_pareto(&self, keys: &[String]) -> Result<()> {
        let mut out = String::from(HEADER);
        out.push('\n');
        for key in keys {
            let e = self
                .entries
                .get(key)
                .with_context(|| format!("pareto key {key} not in cache"))?;
            out.push_str(&e.to_line());
            out.push('\n');
        }
        fs::write(&self.pareto_path, out)
            .with_context(|| format!("writing {}", self.pareto_path.display()))
    }
}

/// Load the tuned-best schedule for `app`, if one was recorded — the
/// coordinator hook behind `--tuned-dir`. Any missing or malformed
/// file is `None`: serving falls back to the hand-written schedule.
pub fn load_best(dir: &Path, app: &str) -> Option<(HwSchedule, CacheEntry)> {
    let text = fs::read_to_string(best_path(dir, app)).ok()?;
    let entry = CacheEntry::parse_line(text.lines().next()?.trim()).ok()?;
    let sched = entry.schedule().ok()?;
    Some((sched, entry))
}

/// Load the persisted Pareto front for `app`, *verified*: each line's
/// schedule is decoded and its [`candidate_key`] recomputed — a line
/// whose stored key does not match the schedule it carries (disk
/// corruption, a hand-edited record, or an FNV collision smuggled
/// into the file) is dropped, exactly mirroring the
/// `lookup_verified` collision rule. Malformed lines and duplicate
/// keys are skipped; a missing file is simply the empty front (the
/// caller falls back to `.best` or the hand-written schedule). Order
/// is preserved from the file (best-cycles first as written by
/// [`DseCache::write_pareto`]).
pub fn load_pareto(dir: &Path, app: &str) -> Vec<(HwSchedule, CacheEntry)> {
    let Ok(text) = fs::read_to_string(pareto_path(dir, app)) else {
        return Vec::new();
    };
    let mut seen = std::collections::BTreeSet::new();
    let mut front = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Ok(entry) = CacheEntry::parse_line(line) else { continue };
        let Ok(sched) = entry.schedule() else { continue };
        if candidate_key(app, &sched) != entry.key
            || encode_schedule(&sched) != entry.encoded
        {
            eprintln!(
                "[dse] {}: dropping unverifiable pareto line (key {} does not \
                 match its schedule {:?})",
                pareto_path(dir, app).display(),
                entry.key,
                entry.encoded
            );
            continue;
        }
        if seen.insert(entry.key.clone()) {
            front.push((sched, entry));
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_schedule() -> HwSchedule {
        HwSchedule::new([60, 60])
            .store_at("iy")
            .store_at("ix")
            .unroll("resp", "x", 2)
            .unroll_reduction("conv")
            .on_host("corners")
    }

    #[test]
    fn encoding_roundtrips() {
        let s = sample_schedule();
        let enc = encode_schedule(&s);
        let d = decode_schedule(&enc).unwrap();
        assert_eq!(encode_schedule(&d), enc);
        assert_eq!(d.tile, vec![60, 60]);
        assert_eq!(d.memories, vec!["ix".to_string(), "iy".to_string()]);
        assert_eq!(d.unroll_factors("resp"), &[("x".to_string(), 2)]);
        assert!(d.is_reduction_unrolled("conv"));
        assert_eq!(d.host_stages, vec!["corners".to_string()]);
    }

    #[test]
    fn encoding_is_canonical_under_directive_order() {
        let a = HwSchedule::new([8, 8]).store_at("p").store_at("q");
        let b = HwSchedule::new([8, 8]).store_at("q").store_at("p");
        assert_eq!(encode_schedule(&a), encode_schedule(&b));
        assert_eq!(candidate_key("app", &a), candidate_key("app", &b));
    }

    #[test]
    fn key_depends_on_app_and_schedule() {
        let s = HwSchedule::new([8, 8]);
        assert_ne!(candidate_key("a", &s), candidate_key("b", &s));
        assert_ne!(
            candidate_key("a", &s),
            candidate_key("a", &HwSchedule::new([16, 8]))
        );
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode_schedule("").is_err());
        assert!(decode_schedule("mem=a").is_err()); // no tile
        assert!(decode_schedule("tile=4x4|wat=1").is_err());
        assert!(decode_schedule("tile=4xfour").is_err());
        assert!(decode_schedule("tile=4|unroll=f:x").is_err());
    }

    #[test]
    fn colliding_key_is_a_miss_not_a_wrong_hit() {
        let dir = std::env::temp_dir()
            .join(format!("pushmem-dse-collision-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        // Forge the collision FNV-1a could produce: an entry recorded
        // under candidate A's key but carrying candidate B's schedule.
        let a = HwSchedule::new([8, 8]);
        let b = HwSchedule::new([16, 16]).store_at("p");
        let key = candidate_key("toy", &a);
        let mut c = DseCache::open(&dir, "toy").unwrap();
        c.record(CacheEntry {
            key: key.clone(),
            cycles: 64,
            completion: 64,
            pes: 1,
            mems: 1,
            sram_words: 1,
            energy_per_op_pj: 1.0,
            pixels_per_cycle: 1.0,
            area_um2: 1.0,
            encoded: encode_schedule(&b),
        })
        .unwrap();
        // The unverified index still finds it; the verified lookup
        // rejects the mismatched encoding and only accepts the real
        // owner of the stored line.
        assert!(c.lookup(&key).is_some());
        assert!(c.lookup_verified(&key, &encode_schedule(&a)).is_none());
        assert!(c.lookup_verified(&key, &encode_schedule(&b)).is_some());
        assert!(c.lookup_verified("unknown-key", &encode_schedule(&a)).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn cache_roundtrips_on_disk() {
        let dir = std::env::temp_dir()
            .join(format!("pushmem-dse-cache-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let s = sample_schedule();
        let entry = CacheEntry {
            key: candidate_key("toy", &s),
            cycles: 1234,
            completion: 1200,
            pes: 42,
            mems: 7,
            sram_words: 4096,
            energy_per_op_pj: 2.25,
            pixels_per_cycle: 1.0,
            area_um2: 123456.7,
            encoded: encode_schedule(&s),
        };
        {
            let mut c = DseCache::open(&dir, "toy").unwrap();
            assert!(c.is_empty());
            c.record(entry.clone()).unwrap();
            c.write_best(&entry.key).unwrap();
        }
        // Fresh open sees the entry; load_best round-trips the schedule.
        let c = DseCache::open(&dir, "toy").unwrap();
        assert_eq!(c.len(), 1);
        let got = c.lookup(&entry.key).unwrap();
        assert_eq!(got.cycles, 1234);
        assert_eq!(got.encoded, entry.encoded);
        let (sched, best) = load_best(&dir, "toy").unwrap();
        assert_eq!(encode_schedule(&sched), entry.encoded);
        assert_eq!(best.key, entry.key);
        // Unknown app: no best.
        assert!(load_best(&dir, "nope").is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    fn entry_for(app: &str, sched: &HwSchedule, cycles: i64) -> CacheEntry {
        CacheEntry {
            key: candidate_key(app, sched),
            cycles,
            completion: cycles,
            pes: 10,
            mems: 2,
            sram_words: 256,
            energy_per_op_pj: 1.5,
            pixels_per_cycle: 0.5,
            area_um2: 1000.0,
            encoded: encode_schedule(sched),
        }
    }

    #[test]
    fn pareto_record_roundtrips_in_order() {
        let dir = std::env::temp_dir()
            .join(format!("pushmem-dse-pareto-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let a = HwSchedule::new([60, 60]);
        let b = HwSchedule::new([30, 30]).store_at("p");
        let (ea, eb) = (entry_for("toy", &a, 100), entry_for("toy", &b, 200));
        {
            let mut c = DseCache::open(&dir, "toy").unwrap();
            c.record(ea.clone()).unwrap();
            c.record(eb.clone()).unwrap();
            c.write_pareto(&[ea.key.clone(), eb.key.clone()]).unwrap();
            // A key the cache never scored cannot be crowned; the
            // failed call leaves the previous record untouched.
            assert!(c.write_pareto(&["feedfacefeedface".into()]).is_err());
        }
        let front = load_pareto(&dir, "toy");
        assert_eq!(front.len(), 2);
        assert_eq!(front[0].1.key, ea.key, "file order (best-cycles first) preserved");
        assert_eq!(front[0].0.tile, vec![60, 60]);
        assert_eq!(front[1].0.tile, vec![30, 30]);
        assert_eq!(front[1].1.cycles, 200);
        // Missing file: empty front, not an error.
        assert!(load_pareto(&dir, "nope").is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn pareto_load_verifies_keys_and_skips_garbage() {
        let dir = std::env::temp_dir()
            .join(format!("pushmem-dse-pareto-verify-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let good = entry_for("toy", &HwSchedule::new([60, 60]), 100);
        // A forged line: candidate A's key, candidate B's schedule —
        // the collision shape lookup_verified guards against.
        let mut forged = entry_for("toy", &HwSchedule::new([60, 60]), 50);
        forged.encoded = encode_schedule(&HwSchedule::new([16, 16]));
        let text = format!(
            "{HEADER}\nnot a cache line\n{}\n{}\n{}\n",
            forged.to_line(),
            good.to_line(),
            good.to_line(), // duplicate key: kept once
        );
        fs::write(pareto_path(&dir, "toy"), text).unwrap();
        let front = load_pareto(&dir, "toy");
        assert_eq!(front.len(), 1, "only the verifiable line survives");
        assert_eq!(front[0].1.key, good.key);
        assert_eq!(front[0].0.tile, vec![60, 60]);
        let _ = fs::remove_dir_all(&dir);
    }
}
