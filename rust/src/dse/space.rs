//! Candidate enumeration: the schedule dimensions of §V-A crossed into
//! a concrete, deterministic design space.
//!
//! For a program with funcs `f1 … fn` (last = output) the space is
//!
//! * **tile**        — the hand-written tile scaled by each configured
//!   multiplier (Table V sch5 is the 2x point);
//! * **memories**    — subsets of the *pure intermediate* funcs
//!   (`store_at` vs recompute; sch1/sch2/sch3). Funcs carrying a
//!   rolled reduction are materialized by lowering regardless, so
//!   listing them would only duplicate candidates. Canonical subsets
//!   (all, none, each single, each leave-one-out) come first; seeded
//!   xorshift sampling fills the remaining budget;
//! * **unroll**      — a uniform spatial factor on every accelerator
//!   func's innermost pure var (sch4 is factor 2);
//! * **host_stages** — the last func offloaded to the host or not
//!   (sch6).
//!
//! The hand-written schedule itself is always candidate zero, so the
//! tuner's best is never worse than the default. `unroll_reductions`
//! is carried over from the hand schedule unchanged: it encodes
//! stencil-vs-DNN policy intent (§V-B), not a free knob — flipping it
//! is future work tracked in docs/dse.md.
//!
//! Enumeration is fully deterministic given a seed; candidates are
//! deduped by their canonical encoding (see [`super::cache`]).

use std::collections::BTreeSet;

use crate::halide::{HwSchedule, Program};

use super::cache::{candidate_key, encode_schedule};

/// xorshift64* — the same tiny PRNG the property tests use.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }
}

/// Shape of the enumerated space. Defaults reproduce the Table V axes.
#[derive(Clone, Debug)]
pub struct SpaceConfig {
    /// Per-axis scalings of the hand-written tile (`1` = as written).
    pub tile_multipliers: Vec<i64>,
    /// Uniform spatial unroll factors (`1` = no unrolling).
    pub unroll_factors: Vec<i64>,
    /// Also try the last stage on the host CPU (sch6).
    pub explore_host_offload: bool,
    /// Max `store_at` subsets per (tile, host) point — canonical
    /// subsets first, then seeded random ones.
    pub max_memory_subsets: usize,
    /// Sampling seed (overridden by `TuneConfig::seed`).
    pub seed: u64,
}

impl Default for SpaceConfig {
    fn default() -> Self {
        SpaceConfig {
            tile_multipliers: vec![1, 2],
            unroll_factors: vec![1, 2, 4],
            explore_host_offload: true,
            max_memory_subsets: 24,
            seed: 1,
        }
    }
}

/// One enumerated point: a complete `HwSchedule` plus its identity.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// Content-address ([`super::cache::candidate_key`]).
    pub key: String,
    /// Canonical encoding ([`super::cache::encode_schedule`]).
    pub encoded: String,
    pub schedule: HwSchedule,
    /// Provenance: `"default"` (the hand-written schedule),
    /// `"canonical"` (a named corner of the space), or `"sampled"`.
    pub origin: &'static str,
}

/// The `store_at` subsets for one (tile, host) point: canonical corners
/// first — buffer-everything, recompute-everything, singles,
/// leave-one-outs — then random fills, truncated to `max`. The `bool`
/// marks canonical subsets.
fn memory_subsets(interm: &[String], max: usize, rng: &mut Rng) -> Vec<(Vec<String>, bool)> {
    let mut subs: Vec<(Vec<String>, bool)> = Vec::new();
    subs.push((interm.to_vec(), true));
    subs.push((Vec::new(), true));
    for f in interm {
        subs.push((vec![f.clone()], true));
    }
    if interm.len() > 2 {
        for f in interm {
            subs.push((interm.iter().filter(|g| *g != f).cloned().collect(), true));
        }
    }
    while subs.len() < max {
        let sub: Vec<String> =
            interm.iter().filter(|_| rng.next() & 1 == 1).cloned().collect();
        subs.push((sub, false));
    }
    subs.truncate(max);
    subs
}

/// Enumerate the candidate schedules for `program`. `app_key` salts
/// the content addresses (the same schedule means a different design
/// on a different app).
pub fn enumerate(program: &Program, app_key: &str, cfg: &SpaceConfig) -> Vec<Candidate> {
    let base = &program.schedule;
    let mut out: Vec<Candidate> = Vec::new();
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut push = |schedule: HwSchedule, origin: &'static str, out: &mut Vec<Candidate>| {
        let encoded = encode_schedule(&schedule);
        if seen.insert(encoded.clone()) {
            out.push(Candidate {
                key: candidate_key(app_key, &schedule),
                encoded,
                schedule,
                origin,
            });
        }
    };

    // Candidate zero: the schedule the app shipped with.
    push(base.clone(), "default", &mut out);

    let last_func = match program.funcs.last() {
        Some(f) => f.name.clone(),
        None => return out,
    };
    let host_options: Vec<Vec<String>> =
        if cfg.explore_host_offload && program.funcs.len() >= 2 {
            vec![Vec::new(), vec![last_func]]
        } else {
            vec![Vec::new()]
        };

    let mut rng = Rng::new(cfg.seed);
    for &m in &cfg.tile_multipliers {
        if m < 1 {
            continue;
        }
        let tile: Vec<i64> = base.tile.iter().map(|e| e * m).collect();
        for host in &host_options {
            let accel: Vec<&crate::halide::Func> = program
                .funcs
                .iter()
                .filter(|f| !host.contains(&f.name))
                .collect();
            let Some((_output, producers)) = accel.split_last() else { continue };
            let interm: Vec<String> = producers
                .iter()
                .filter(|f| {
                    !(f.reduction.is_some() && !base.unroll_reductions.contains(&f.name))
                })
                .map(|f| f.name.clone())
                .collect();
            let carried: Vec<String> = base
                .unroll_reductions
                .iter()
                .filter(|r| accel.iter().any(|f| f.name == **r))
                .cloned()
                .collect();
            for (subset, canonical) in
                memory_subsets(&interm, cfg.max_memory_subsets, &mut rng)
            {
                for &u in &cfg.unroll_factors {
                    if u < 1 {
                        continue;
                    }
                    let mut s = HwSchedule::new(tile.clone());
                    s.memories = subset.clone();
                    s.unroll_reductions = carried.clone();
                    s.host_stages = host.clone();
                    if u >= 2 {
                        for f in &accel {
                            if let Some(var) = f.vars.last() {
                                s.unroll
                                    .entry(f.name.clone())
                                    .or_default()
                                    .push((var.clone(), u));
                            }
                        }
                    }
                    push(
                        s,
                        if canonical { "canonical" } else { "sampled" },
                        &mut out,
                    );
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{gaussian, harris};
    use crate::dse::cache::decode_schedule;

    #[test]
    fn enumeration_is_deterministic() {
        let p = harris::build(12, harris::Schedule::NoRecompute);
        let cfg = SpaceConfig { seed: 7, ..Default::default() };
        let a: Vec<String> = enumerate(&p, "harris", &cfg).iter().map(|c| c.key.clone()).collect();
        let b: Vec<String> = enumerate(&p, "harris", &cfg).iter().map(|c| c.key.clone()).collect();
        assert_eq!(a, b);
        assert!(a.len() > 20, "only {} candidates", a.len());
    }

    #[test]
    fn default_schedule_is_candidate_zero() {
        let p = harris::build(12, harris::Schedule::UnrollBy2);
        let cands = enumerate(&p, "harris_sch4", &SpaceConfig::default());
        assert_eq!(cands[0].origin, "default");
        assert_eq!(cands[0].encoded, encode_schedule(&p.schedule));
    }

    #[test]
    fn space_contains_the_table5_corners() {
        // The enumerated harris space must cover schedules shaped like
        // sch1 (no memories), sch3 (all memories), sch4 (all + unroll
        // 2), and sch6 (all + last on host).
        let p = harris::build(12, harris::Schedule::NoRecompute);
        let cands = enumerate(&p, "harris", &SpaceConfig::default());
        let has = |pred: &dyn Fn(&HwSchedule) -> bool| cands.iter().any(|c| pred(&c.schedule));
        let n_interm = 9; // ix iy ixx ixy iyy sxx sxy syy resp
        assert!(has(&|s| s.memories.is_empty() && s.unroll.is_empty() && s.host_stages.is_empty()));
        assert!(has(&|s| s.memories.len() == n_interm && s.unroll.is_empty() && s.host_stages.is_empty() && s.tile == vec![12, 12]));
        assert!(has(&|s| s.memories.len() == n_interm
            && s.unroll.values().flatten().all(|(v, u)| v == "x" && *u == 2)
            && !s.unroll.is_empty()));
        assert!(has(&|s| s.host_stages == vec!["corners".to_string()]));
        assert!(has(&|s| s.tile == vec![24, 24]));
    }

    #[test]
    fn candidates_dedupe_and_roundtrip() {
        let p = gaussian::build(10);
        let cands = enumerate(&p, "gaussian", &SpaceConfig::default());
        let keys: BTreeSet<&str> = cands.iter().map(|c| c.key.as_str()).collect();
        assert_eq!(keys.len(), cands.len(), "duplicate candidates");
        for c in &cands {
            let d = decode_schedule(&c.encoded).unwrap();
            assert_eq!(encode_schedule(&d), c.encoded, "{}", c.encoded);
        }
    }

    #[test]
    fn single_func_space_has_no_memory_or_host_axes() {
        // gaussian is one func: intermediates are empty and host
        // offload would leave nothing to accelerate.
        let p = gaussian::build(10);
        for c in enumerate(&p, "gaussian", &SpaceConfig::default()) {
            assert!(c.schedule.memories.is_empty());
            assert!(c.schedule.host_stages.is_empty());
        }
    }
}
