//! `dse` — design-space exploration: a parallel schedule auto-tuner
//! over the unified-buffer mapper.
//!
//! The paper's central claim is programmability *with* performance:
//! §VI-C and Table V show one Halide algorithm spanning a 6x PE /
//! pixels-per-cycle range purely through schedule choice. This
//! subsystem searches that space automatically:
//!
//! ```text
//! space::enumerate      tile x store_at-subset x unroll x host axes
//!   --prune::prune-->   analytic feasibility + cost filter (no sim)
//!   --evaluate-->       map + cycle-accurate sim on a worker pool,
//!                       every survivor validated bit-exact
//!   --cache-->          content-addressed TSV cache + `.best` record
//! ```
//!
//! Entry points: [`tune_app`] (a registered CLI app) and
//! [`tune_program`] (any [`Program`], e.g. small tiles in tests). The
//! CLI front end is `pushmem tune`; `pushmem serve --tuned-dir` loads
//! a tuned winner through [`cache::load_best`]. Full walkthrough:
//! docs/dse.md (design rationale: DESIGN.md §4).

pub mod cache;
pub mod evaluate;
pub mod prune;
pub mod space;

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::cgra::CgraSpec;
use crate::exec::Engine;
use crate::halide::Program;

pub use cache::{load_best, CacheEntry, DseCache};
pub use evaluate::{
    cycles_per_pixel, evaluate, evaluate_with, table5_baselines, table5_baselines_with,
    Baseline, Evaluation,
};
pub use prune::{prune, Analysis, Verdict};
pub use space::{enumerate, Candidate, SpaceConfig};

/// What the tuner minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Simulated cycles per tile (throughput).
    Cycles,
    /// Simulated energy per compute op (the Fig 13 metric).
    EnergyPerOp,
    /// PE count.
    Pes,
    /// Analytic silicon area.
    Area,
    /// Rank by cycles but report the cycles-vs-PEs Pareto front.
    Pareto,
}

impl Objective {
    pub fn parse(s: &str) -> Result<Objective> {
        Ok(match s {
            "cycles" => Objective::Cycles,
            "energy" => Objective::EnergyPerOp,
            "pes" => Objective::Pes,
            "area" => Objective::Area,
            "pareto" => Objective::Pareto,
            other => bail!("unknown objective {other:?} (want cycles|energy|pes|area|pareto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Cycles => "cycles",
            Objective::EnergyPerOp => "energy",
            Objective::Pes => "pes",
            Objective::Area => "area",
            Objective::Pareto => "pareto",
        }
    }

    /// Simulated score (lower is better).
    pub fn score(&self, e: &CacheEntry) -> f64 {
        match self {
            Objective::Cycles | Objective::Pareto => e.cycles as f64,
            Objective::EnergyPerOp => e.energy_per_op_pj,
            Objective::Pes => e.pes as f64,
            Objective::Area => e.area_um2,
        }
    }

    /// Analytic proxy used to rank prune survivors for the simulation
    /// budget (lower is better).
    fn analytic_score(&self, a: &Analysis) -> f64 {
        match self {
            Objective::Cycles | Objective::Pareto => a.cycles_lb as f64,
            Objective::EnergyPerOp => a.energy_per_pixel_pj,
            Objective::Pes => a.pe_estimate as f64,
            Objective::Area => a.area_um2,
        }
    }
}

/// Tuner knobs. `Default` matches the `pushmem tune` CLI defaults.
#[derive(Clone, Debug)]
pub struct TuneConfig {
    pub objective: Objective,
    /// Max candidates to *simulate* (cache hits don't count against
    /// it; analytic pruning is unbounded).
    pub budget: usize,
    /// Evaluation worker threads.
    pub workers: usize,
    /// Enumeration seed (overrides `space.seed`).
    pub seed: u64,
    /// Result cache directory; `None` disables caching.
    pub cache_dir: Option<PathBuf>,
    /// Candidate execution engine (docs/execution.md). `Auto` scores
    /// through the functional engine when possible — an order of
    /// magnitude more candidates/sec at identical scores — with the
    /// cycle-accurate simulator as fallback.
    pub engine: Engine,
    pub space: SpaceConfig,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            objective: Objective::Cycles,
            budget: 24,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            seed: 1,
            cache_dir: None,
            engine: Engine::Auto,
            space: SpaceConfig::default(),
        }
    }
}

/// One scored candidate in the final ranking.
#[derive(Clone, Debug)]
pub struct Ranked {
    pub candidate: Candidate,
    pub entry: CacheEntry,
    pub from_cache: bool,
}

/// What a tuning run did and found. `results` is sorted best-first by
/// the objective (ties broken by key, so ranking is deterministic).
#[derive(Debug)]
pub struct TuneReport {
    pub app: String,
    pub objective: Objective,
    pub enumerated: usize,
    pub infeasible: usize,
    pub feasible: usize,
    /// Candidates actually simulated this run.
    pub evaluated: usize,
    pub cache_hits: usize,
    /// Post-prune candidates whose compile/simulate still failed.
    pub failed: usize,
    /// Wall-clock seconds of the parallel evaluation phase.
    pub eval_seconds: f64,
    pub results: Vec<Ranked>,
}

impl TuneReport {
    pub fn best(&self) -> Option<&Ranked> {
        self.results.first()
    }

    /// Simulated candidates per second of evaluation wall-clock (the
    /// tuner-throughput figure benches track).
    pub fn evals_per_sec(&self) -> f64 {
        if self.eval_seconds > 0.0 {
            self.evaluated as f64 / self.eval_seconds
        } else {
            0.0
        }
    }

    /// The cycles-vs-PEs Pareto front, sorted by cycles.
    pub fn pareto_front(&self) -> Vec<&Ranked> {
        let dominated = |a: &CacheEntry| {
            self.results.iter().any(|o| {
                o.entry.cycles <= a.cycles
                    && o.entry.pes <= a.pes
                    && (o.entry.cycles < a.cycles || o.entry.pes < a.pes)
            })
        };
        let mut front: Vec<&Ranked> =
            self.results.iter().filter(|r| !dominated(&r.entry)).collect();
        front.sort_by_key(|r| (r.entry.cycles, r.entry.pes, r.entry.key.clone()));
        front.dedup_by(|a, b| a.entry.key == b.entry.key);
        front
    }
}

/// Tune a registered app (a `pushmem list` name).
pub fn tune_app(name: &str, cfg: &TuneConfig) -> Result<TuneReport> {
    let (program, _) =
        crate::apps::by_name(name).with_context(|| format!("unknown app {name}"))?;
    tune_program(&program, name, cfg)
}

/// Tune any program. `app_key` names the cache bucket (and salts
/// candidate content addresses).
pub fn tune_program(program: &Program, app_key: &str, cfg: &TuneConfig) -> Result<TuneReport> {
    anyhow::ensure!(cfg.budget >= 1, "budget must be >= 1");
    anyhow::ensure!(cfg.workers >= 1, "workers must be >= 1");

    // Phase 1: enumerate.
    let mut scfg = cfg.space.clone();
    scfg.seed = cfg.seed;
    let candidates = space::enumerate(program, app_key, &scfg);
    let enumerated = candidates.len();

    // Phase 2: analytic prune + proxy ranking. The hand-written
    // default and the canonical Table-V-shaped corners keep priority
    // over sampled points so a tiny budget still covers the known
    // landmarks.
    let spec = CgraSpec::default();
    let mut survivors: Vec<(Candidate, Analysis)> = Vec::new();
    let mut infeasible = 0;
    for cand in candidates {
        let mut p = program.clone();
        p.schedule = cand.schedule.clone();
        match prune::prune(&p, &spec) {
            Verdict::Feasible(a) => survivors.push((cand, a)),
            Verdict::Infeasible(_) => infeasible += 1,
        }
    }
    let feasible = survivors.len();
    // Budget priority: the hand-written default is always simulated
    // (so "tuned is never worse than default" holds whenever it is
    // feasible), then canonical corners, then sampled points — each
    // class ordered by the objective's analytic proxy.
    let class = |c: &Candidate| match c.origin {
        "default" => 0u8,
        "canonical" => 1,
        _ => 2,
    };
    survivors.sort_by(|(ca, aa), (cb, ab)| {
        class(ca)
            .cmp(&class(cb))
            .then(
                cfg.objective
                    .analytic_score(aa)
                    .total_cmp(&cfg.objective.analytic_score(ab)),
            )
            .then(ca.key.cmp(&cb.key))
    });
    // Phase 3: cache lookup, then parallel evaluation of the misses.
    // Cache hits are free — they never consume a budget slot — so a
    // warm re-run keeps exploring deeper into the ranked survivors
    // instead of re-treading scored ground.
    let mut dse_cache = match &cfg.cache_dir {
        Some(dir) => Some(DseCache::open(dir, app_key)?),
        None => None,
    };
    let mut results: Vec<Ranked> = Vec::new();
    let mut jobs: VecDeque<Candidate> = VecDeque::new();
    let mut cache_hits = 0;
    for (cand, _) in survivors {
        // Verified hits only: the stored canonical encoding must match
        // the queried one, so a 64-bit key collision can never return
        // another candidate's score (cache::lookup_verified).
        match dse_cache
            .as_ref()
            .and_then(|c| c.lookup_verified(&cand.key, &cand.encoded))
        {
            Some(hit) => {
                cache_hits += 1;
                results.push(Ranked { entry: hit.clone(), candidate: cand, from_cache: true });
            }
            None if jobs.len() < cfg.budget => jobs.push_back(cand),
            None => {}
        }
    }

    let t0 = Instant::now();
    let queue = Mutex::new(jobs);
    let done: Mutex<Vec<(Candidate, Result<Evaluation>)>> = Mutex::new(Vec::new());
    let n_threads = cfg.workers.min(queue.lock().unwrap().len()).max(1);
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            s.spawn(|| loop {
                let Some(cand) = queue.lock().unwrap().pop_front() else { break };
                let mut p = program.clone();
                p.schedule = cand.schedule.clone();
                let res = evaluate::evaluate_with(&p, cfg.engine);
                done.lock().unwrap().push((cand, res));
            });
        }
    });
    let eval_seconds = t0.elapsed().as_secs_f64();

    let mut evaluated = 0;
    let mut failed = 0;
    for (cand, res) in done.into_inner().unwrap() {
        match res {
            Ok(ev) => {
                evaluated += 1;
                let entry = CacheEntry {
                    key: cand.key.clone(),
                    cycles: ev.cycles,
                    completion: ev.completion,
                    pes: ev.pes,
                    mems: ev.mems,
                    sram_words: ev.sram_words,
                    energy_per_op_pj: ev.energy_per_op_pj,
                    pixels_per_cycle: ev.pixels_per_cycle,
                    area_um2: ev.area_um2,
                    encoded: cand.encoded.clone(),
                };
                if let Some(c) = dse_cache.as_mut() {
                    c.record(entry.clone())?;
                }
                results.push(Ranked { candidate: cand, entry, from_cache: false });
            }
            Err(e) => {
                // Post-prune failures are possible (the prune is
                // analytic, not a full mapper dry-run) and must never
                // kill the tuner — that is the whole point of the
                // Result-returning compile path.
                failed += 1;
                eprintln!("[dse] {app_key}: candidate {} failed: {e:#}", cand.key);
            }
        }
    }

    // Phase 4: rank (deterministically) and persist the winner — and,
    // under the pareto objective, the whole front (`<app>.pareto`),
    // which variant-aware serving loads through
    // [`cache::load_pareto`] (docs/routing.md).
    results.sort_by(|a, b| {
        cfg.objective
            .score(&a.entry)
            .total_cmp(&cfg.objective.score(&b.entry))
            .then(a.entry.key.cmp(&b.entry.key))
    });
    let report = TuneReport {
        app: app_key.to_string(),
        objective: cfg.objective,
        enumerated,
        infeasible,
        feasible,
        evaluated,
        cache_hits,
        failed,
        eval_seconds,
        results,
    };
    if let Some(c) = &dse_cache {
        if let Some(best) = report.best() {
            c.write_best(&best.entry.key)?;
        }
        if cfg.objective == Objective::Pareto {
            let keys: Vec<String> =
                report.pareto_front().iter().map(|r| r.entry.key.clone()).collect();
            if !keys.is_empty() {
                c.write_pareto(&keys)?;
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_parse_roundtrips() {
        for o in [
            Objective::Cycles,
            Objective::EnergyPerOp,
            Objective::Pes,
            Objective::Area,
            Objective::Pareto,
        ] {
            assert_eq!(Objective::parse(o.name()).unwrap(), o);
        }
        assert!(Objective::parse("speed").is_err());
    }

    #[test]
    fn zero_budget_rejected() {
        let cfg = TuneConfig { budget: 0, ..Default::default() };
        assert!(tune_app("gaussian", &cfg).is_err());
    }

    #[test]
    fn unknown_app_rejected() {
        assert!(tune_app("no_such_app", &TuneConfig::default()).is_err());
    }

    /// A pareto-objective run writes `<app>.pareto` and the verified
    /// loader round-trips exactly the front the report computed, in
    /// best-cycles-first order.
    #[test]
    fn pareto_objective_persists_a_verified_front() {
        let dir = std::env::temp_dir()
            .join(format!("pushmem-dse-front-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let program = crate::apps::gaussian::build(14);
        let cfg = TuneConfig {
            objective: Objective::Pareto,
            budget: 4,
            workers: 2,
            cache_dir: Some(dir.clone()),
            ..Default::default()
        };
        let report = tune_program(&program, "g14front", &cfg).unwrap();
        let front = report.pareto_front();
        assert!(!front.is_empty(), "no front from {} results", report.results.len());
        let loaded = cache::load_pareto(&dir, "g14front");
        assert_eq!(loaded.len(), front.len());
        for ((sched, entry), r) in loaded.iter().zip(&front) {
            assert_eq!(entry.key, r.entry.key);
            assert_eq!(cache::encode_schedule(sched), r.entry.encoded);
        }
        assert!(
            loaded.windows(2).all(|w| w[0].1.cycles <= w[1].1.cycles),
            "front must be best-cycles first"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
