//! Candidate scoring: full compile + cycle-accurate simulation +
//! bit-exact validation, wrapped in one `Result`.
//!
//! This is the expensive stage the analytic prune protects. It rides
//! the same [`crate::apps::compile_checked`] path the test suite uses,
//! so a candidate that scores here has *already* been validated
//! bit-exact against the functional reference — an unvalidated design
//! can never enter the ranking or the cache. That path simulates
//! through the per-design [`crate::cgra::SimPlan`] (docs/simulator.md),
//! so per-candidate simulation pays setup exactly once and every
//! additional input a caller streams through `CheckedRun::plan` is
//! setup-free.

use std::time::Instant;

use anyhow::Result;

use crate::apps::compile_checked;
use crate::cost::{design_area_um2, energy_per_op_pj};
use crate::halide::Program;

/// The simulated metrics of one validated candidate.
#[derive(Clone, Debug)]
pub struct Evaluation {
    /// Simulated cycles for one tile, including output drain.
    pub cycles: i64,
    /// Scheduled completion (the Table V/VI figure).
    pub completion: i64,
    pub coarse_ii: i64,
    pub pes: usize,
    pub mems: usize,
    pub sram_words: i64,
    pub sr_words: i64,
    pub pixels_per_cycle: f64,
    pub energy_per_op_pj: f64,
    pub area_um2: f64,
    /// Wall-clock seconds this evaluation took (tuner throughput).
    pub eval_seconds: f64,
}

/// Compile, simulate, and validate `program`; score the run. Any
/// failure — including an output mismatch — is `Err`.
pub fn evaluate(program: &Program) -> Result<Evaluation> {
    let t0 = Instant::now();
    let run = compile_checked(program)?;
    Ok(Evaluation {
        cycles: run.stats.cycles,
        completion: run.graph.completion,
        coarse_ii: run.graph.coarse_ii,
        pes: run.design.pe_count(),
        mems: run.design.mem_tiles(),
        sram_words: run.design.sram_words(),
        sr_words: run.design.sr_words(),
        pixels_per_cycle: run.graph.output_pixels_per_cycle(),
        energy_per_op_pj: energy_per_op_pj(&run.design, &run.stats),
        area_um2: design_area_um2(&run.design),
        eval_seconds: t0.elapsed().as_secs_f64(),
    })
}

/// One simulated hand-written Table V baseline.
pub struct Baseline {
    pub label: &'static str,
    /// Realized output-tile side (sch5 doubles the base tile, so raw
    /// cycle counts are not comparable across rows — normalize with
    /// [`cycles_per_pixel`]).
    pub tile: i64,
    pub eval: Result<Evaluation>,
}

/// Cycles per output pixel — the tile-size-independent throughput
/// figure used to compare schedules realized at different tiles
/// (Table V sch5 runs a 2x-per-side tile).
pub fn cycles_per_pixel(cycles: i64, tile: &[i64]) -> f64 {
    cycles as f64 / tile.iter().product::<i64>().max(1) as f64
}

/// Simulate the six hand-written Table V Harris schedules (base tile
/// `tile`; sch5 realizes at `2*tile`) with the tuner's own scorer —
/// the comparison baseline that both `pushmem tune harris` and
/// `benches/dse_harris.rs` print, defined once so the label table
/// cannot drift between them.
pub fn table5_baselines(tile: i64) -> Vec<Baseline> {
    use crate::apps::harris::{build, Schedule};
    [
        ("sch1: recompute all", Schedule::RecomputeAll),
        ("sch2: recompute some", Schedule::RecomputeSome),
        ("sch3: no recompute", Schedule::NoRecompute),
        ("sch4: unroll by 2", Schedule::UnrollBy2),
        ("sch5: 4x larger tile", Schedule::BiggerTile),
        ("sch6: last on host", Schedule::LastOnHost),
    ]
    .into_iter()
    .map(|(label, s)| Baseline {
        label,
        tile: if s == Schedule::BiggerTile { tile * 2 } else { tile },
        eval: evaluate(&build(tile, s)),
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::gaussian;

    #[test]
    fn evaluates_gaussian_small() {
        let e = evaluate(&gaussian::build(12)).unwrap();
        assert!(e.cycles >= 12 * 12);
        assert!(e.pes > 0 && e.mems > 0);
        assert!(e.energy_per_op_pj > 0.0 && e.area_um2 > 0.0);
        assert!((e.pixels_per_cycle - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cycles_per_pixel_normalizes_tiles() {
        assert!((cycles_per_pixel(3600, &[60, 60]) - 1.0).abs() < 1e-9);
        assert!((cycles_per_pixel(14400, &[120, 120]) - 1.0).abs() < 1e-9);
        // Degenerate tile never divides by zero.
        assert!((cycles_per_pixel(5, &[]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn infeasible_candidate_is_err_not_panic() {
        let mut p = gaussian::build(12);
        p.schedule.tile = vec![12, -1];
        assert!(evaluate(&p).is_err());
    }
}
