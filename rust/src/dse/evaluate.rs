//! Candidate scoring: full compile + execution + bit-exact
//! validation, wrapped in one `Result`.
//!
//! This is the expensive stage the analytic prune protects. It rides
//! the same [`crate::apps::compile_checked_with`] path the test suite
//! uses, so a candidate that scores here has *already* been validated
//! bit-exact against the functional reference — an unvalidated design
//! can never enter the ranking or the cache. Under the default `Auto`
//! engine the run goes through the functional engine
//! ([`crate::exec`]) — analytic cycle counts, no cycle loop — which
//! is what lifted tuner throughput by an order of magnitude
//! (`benches/dse_harris.rs` tracks both engines); `--engine sim`
//! keeps the cycle-accurate scorer.

use std::time::Instant;

use anyhow::Result;

use crate::apps::compile_checked_with;
use crate::cost::{design_area_um2, energy_per_op_pj};
use crate::exec::Engine;
use crate::halide::Program;

/// The simulated metrics of one validated candidate.
#[derive(Clone, Debug)]
pub struct Evaluation {
    /// Simulated cycles for one tile, including output drain.
    pub cycles: i64,
    /// Scheduled completion (the Table V/VI figure).
    pub completion: i64,
    pub coarse_ii: i64,
    pub pes: usize,
    pub mems: usize,
    pub sram_words: i64,
    pub sr_words: i64,
    pub pixels_per_cycle: f64,
    pub energy_per_op_pj: f64,
    pub area_um2: f64,
    /// Wall-clock seconds this evaluation took (tuner throughput).
    pub eval_seconds: f64,
}

/// Compile, execute, and validate `program` with the default (`Auto`)
/// engine; score the run. Any failure — including an output mismatch
/// — is `Err`.
pub fn evaluate(program: &Program) -> Result<Evaluation> {
    evaluate_with(program, Engine::Auto)
}

/// [`evaluate`] with an explicit engine (the tuner's `--engine` flag).
/// Scores are engine-independent — the functional engine's analytic
/// cycle/energy counts are bit-identical to simulated ones — so a
/// cache populated by one engine is valid for the other.
pub fn evaluate_with(program: &Program, engine: Engine) -> Result<Evaluation> {
    let t0 = Instant::now();
    let run = compile_checked_with(program, engine)?;
    Ok(Evaluation {
        cycles: run.stats.cycles,
        completion: run.graph.completion,
        coarse_ii: run.graph.coarse_ii,
        pes: run.design.pe_count(),
        mems: run.design.mem_tiles(),
        sram_words: run.design.sram_words(),
        sr_words: run.design.sr_words(),
        pixels_per_cycle: run.graph.output_pixels_per_cycle(),
        energy_per_op_pj: energy_per_op_pj(&run.design, &run.stats),
        area_um2: design_area_um2(&run.design),
        eval_seconds: t0.elapsed().as_secs_f64(),
    })
}

/// One simulated hand-written Table V baseline.
pub struct Baseline {
    pub label: &'static str,
    /// Realized output-tile side (sch5 doubles the base tile, so raw
    /// cycle counts are not comparable across rows — normalize with
    /// [`cycles_per_pixel`]).
    pub tile: i64,
    pub eval: Result<Evaluation>,
}

/// Cycles per output pixel — the tile-size-independent throughput
/// figure used to compare schedules realized at different tiles
/// (Table V sch5 runs a 2x-per-side tile).
pub fn cycles_per_pixel(cycles: i64, tile: &[i64]) -> f64 {
    cycles as f64 / tile.iter().product::<i64>().max(1) as f64
}

/// Score the six hand-written Table V Harris schedules (base tile
/// `tile`; sch5 realizes at `2*tile`) with the tuner's own scorer —
/// the comparison baseline that both `pushmem tune harris` and
/// `benches/dse_harris.rs` print, defined once so the label table
/// cannot drift between them.
pub fn table5_baselines(tile: i64) -> Vec<Baseline> {
    table5_baselines_with(tile, Engine::Auto)
}

/// [`table5_baselines`] with an explicit engine (the bench measures
/// both to report the exec-vs-sim speedup).
pub fn table5_baselines_with(tile: i64, engine: Engine) -> Vec<Baseline> {
    use crate::apps::harris::{build, Schedule};
    [
        ("sch1: recompute all", Schedule::RecomputeAll),
        ("sch2: recompute some", Schedule::RecomputeSome),
        ("sch3: no recompute", Schedule::NoRecompute),
        ("sch4: unroll by 2", Schedule::UnrollBy2),
        ("sch5: 4x larger tile", Schedule::BiggerTile),
        ("sch6: last on host", Schedule::LastOnHost),
    ]
    .into_iter()
    .map(|(label, s)| Baseline {
        label,
        tile: if s == Schedule::BiggerTile { tile * 2 } else { tile },
        eval: evaluate_with(&build(tile, s), engine),
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::gaussian;

    #[test]
    fn evaluates_gaussian_small() {
        let e = evaluate(&gaussian::build(12)).unwrap();
        assert!(e.cycles >= 12 * 12);
        assert!(e.pes > 0 && e.mems > 0);
        assert!(e.energy_per_op_pj > 0.0 && e.area_um2 > 0.0);
        assert!((e.pixels_per_cycle - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cycles_per_pixel_normalizes_tiles() {
        assert!((cycles_per_pixel(3600, &[60, 60]) - 1.0).abs() < 1e-9);
        assert!((cycles_per_pixel(14400, &[120, 120]) - 1.0).abs() < 1e-9);
        // Degenerate tile never divides by zero.
        assert!((cycles_per_pixel(5, &[]) - 5.0).abs() < 1e-9);
    }

    /// A cache populated by one engine must be valid for the other:
    /// every scored metric is engine-independent.
    #[test]
    fn scores_are_engine_independent() {
        let p = gaussian::build(12);
        let e = evaluate_with(&p, Engine::Exec).unwrap();
        let s = evaluate_with(&p, Engine::Sim).unwrap();
        assert_eq!(e.cycles, s.cycles);
        assert_eq!(e.completion, s.completion);
        assert_eq!(
            (e.pes, e.mems, e.sram_words, e.sr_words),
            (s.pes, s.mems, s.sram_words, s.sr_words)
        );
        assert!((e.energy_per_op_pj - s.energy_per_op_pj).abs() < 1e-12);
        assert!((e.area_um2 - s.area_um2).abs() < 1e-12);
    }

    #[test]
    fn infeasible_candidate_is_err_not_panic() {
        let mut p = gaussian::build(12);
        p.schedule.tile = vec![12, -1];
        assert!(evaluate(&p).is_err());
    }
}
