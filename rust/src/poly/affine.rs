//! Affine expressions over named loop iterators.

use std::fmt;

/// An affine expression `coeffs[0]*i0 + ... + coeffs[n-1]*i(n-1) + offset`
/// over `n` integer input dimensions.
///
/// This is the only expression form the paper's unified buffers allow for
/// access maps and schedules ("we limit address maps and schedules to
/// affine functions in keeping with the polyhedral model", §IV-A).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Affine {
    pub coeffs: Vec<i64>,
    pub offset: i64,
}

impl Affine {
    /// The zero expression over `rank` dims.
    pub fn zero(rank: usize) -> Self {
        Affine { coeffs: vec![0; rank], offset: 0 }
    }

    /// A constant expression over `rank` dims.
    pub fn constant(rank: usize, c: i64) -> Self {
        Affine { coeffs: vec![0; rank], offset: c }
    }

    /// The expression selecting input dimension `dim`.
    pub fn var(rank: usize, dim: usize) -> Self {
        assert!(dim < rank, "var {dim} out of rank {rank}");
        let mut coeffs = vec![0; rank];
        coeffs[dim] = 1;
        Affine { coeffs, offset: 0 }
    }

    /// Build from explicit coefficients.
    pub fn new(coeffs: Vec<i64>, offset: i64) -> Self {
        Affine { coeffs, offset }
    }

    pub fn rank(&self) -> usize {
        self.coeffs.len()
    }

    /// Evaluate at an integer point.
    pub fn eval(&self, point: &[i64]) -> i64 {
        assert_eq!(point.len(), self.rank(), "point rank mismatch");
        self.coeffs.iter().zip(point).map(|(c, p)| c * p).sum::<i64>() + self.offset
    }

    pub fn add(&self, other: &Affine) -> Affine {
        assert_eq!(self.rank(), other.rank());
        Affine {
            coeffs: self.coeffs.iter().zip(&other.coeffs).map(|(a, b)| a + b).collect(),
            offset: self.offset + other.offset,
        }
    }

    pub fn sub(&self, other: &Affine) -> Affine {
        assert_eq!(self.rank(), other.rank());
        Affine {
            coeffs: self.coeffs.iter().zip(&other.coeffs).map(|(a, b)| a - b).collect(),
            offset: self.offset - other.offset,
        }
    }

    pub fn scale(&self, s: i64) -> Affine {
        Affine {
            coeffs: self.coeffs.iter().map(|c| c * s).collect(),
            offset: self.offset * s,
        }
    }

    /// Add a constant to the offset.
    pub fn shift(&self, delta: i64) -> Affine {
        Affine { coeffs: self.coeffs.clone(), offset: self.offset + delta }
    }

    /// Substitute each input dimension `k` with the affine expression
    /// `inner[k]` (all over a common inner rank), yielding `self ∘ inner`.
    pub fn compose(&self, inner: &[Affine]) -> Affine {
        assert_eq!(inner.len(), self.rank(), "compose rank mismatch");
        let inner_rank = inner.first().map_or(0, |a| a.rank());
        let mut out = Affine::constant(inner_rank, self.offset);
        for (c, expr) in self.coeffs.iter().zip(inner) {
            assert_eq!(expr.rank(), inner_rank);
            out = out.add(&expr.scale(*c));
        }
        out
    }

    /// True if no input dimension contributes.
    pub fn is_constant(&self) -> bool {
        self.coeffs.iter().all(|&c| c == 0)
    }

    /// Interval of values over a box `[lo_k, hi_k]` per dim (inclusive).
    pub fn bounds(&self, dims: &[(i64, i64)]) -> (i64, i64) {
        assert_eq!(dims.len(), self.rank());
        let mut lo = self.offset;
        let mut hi = self.offset;
        for (&c, &(dlo, dhi)) in self.coeffs.iter().zip(dims) {
            assert!(dlo <= dhi, "empty dim in bounds");
            if c >= 0 {
                lo += c * dlo;
                hi += c * dhi;
            } else {
                lo += c * dhi;
                hi += c * dlo;
            }
        }
        (lo, hi)
    }

    /// Bind the trailing `values.len()` input dims to constants,
    /// yielding an expression over the leading dims (used to turn a
    /// full-domain schedule into a per-pure-point write schedule by
    /// fixing the reduction iterators at their final values).
    pub fn bind_tail(&self, values: &[i64]) -> Affine {
        assert!(values.len() <= self.rank());
        let keep = self.rank() - values.len();
        let mut offset = self.offset;
        for (c, v) in self.coeffs[keep..].iter().zip(values) {
            offset += c * v;
        }
        Affine { coeffs: self.coeffs[..keep].to_vec(), offset }
    }

    /// Insert `count` new zero-coefficient dims at position `at`
    /// (used when strip-mining adds an iteration dimension).
    pub fn insert_dims(&self, at: usize, count: usize) -> Affine {
        assert!(at <= self.rank());
        let mut coeffs = self.coeffs.clone();
        for _ in 0..count {
            coeffs.insert(at, 0);
        }
        Affine { coeffs, offset: self.offset }
    }
}

/// Fit an affine function to `f` over `domain`, exactly: coefficients
/// from unit steps at the domain origin, then verified at every point.
/// Returns `None` if `f` is not affine on the domain (or `f` returns
/// `None` anywhere). Used by the mapper to turn exact event lists into
/// AG/SG hardware configurations.
pub fn fit_affine(
    domain: &crate::poly::BoxSet,
    f: &mut dyn FnMut(&[i64]) -> Option<i64>,
) -> Option<Affine> {
    let rank = domain.rank();
    if domain.is_empty() {
        return Some(Affine::zero(rank));
    }
    let mins: Vec<i64> = domain.dims.iter().map(|d| d.min).collect();
    let base = f(&mins)?;
    let mut coeffs = vec![0i64; rank];
    for k in 0..rank {
        if domain.dims[k].extent > 1 {
            let mut p = mins.clone();
            p[k] += 1;
            coeffs[k] = f(&p)? - base;
        }
    }
    let cand = Affine::new(coeffs, 0);
    let offset = base - cand.eval(&mins);
    let cand = cand.shift(offset);
    for p in domain.points() {
        if f(&p)? != cand.eval(&p) {
            return None;
        }
    }
    Some(cand)
}

impl fmt::Display for Affine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for (k, &c) in self.coeffs.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if !first {
                write!(f, " {} ", if c < 0 { "-" } else { "+" })?;
            } else if c < 0 {
                write!(f, "-")?;
            }
            let a = c.abs();
            if a == 1 {
                write!(f, "i{k}")?;
            } else {
                write!(f, "{a}*i{k}")?;
            }
            first = false;
        }
        if first {
            write!(f, "{}", self.offset)?;
        } else if self.offset != 0 {
            write!(
                f,
                " {} {}",
                if self.offset < 0 { "-" } else { "+" },
                self.offset.abs()
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_basic() {
        // 64y + x over (y, x) — the paper's Eq. 1 schedule (outermost first).
        let sched = Affine::new(vec![64, 1], 0);
        assert_eq!(sched.eval(&[0, 0]), 0);
        assert_eq!(sched.eval(&[0, 1]), 1);
        assert_eq!(sched.eval(&[1, 0]), 64);
        assert_eq!(sched.eval(&[2, 5]), 133);
    }

    #[test]
    fn arith_ops() {
        let a = Affine::new(vec![2, 3], 1);
        let b = Affine::new(vec![1, -1], 4);
        assert_eq!(a.add(&b), Affine::new(vec![3, 2], 5));
        assert_eq!(a.sub(&b), Affine::new(vec![1, 4], -3));
        assert_eq!(a.scale(-2), Affine::new(vec![-4, -6], -2));
        assert_eq!(a.shift(7), Affine::new(vec![2, 3], 8));
    }

    #[test]
    fn compose_substitutes() {
        // f(u, v) = 3u + 2v + 1; u = x + 1, v = 2x + y.
        let f = Affine::new(vec![3, 2], 1);
        let u = Affine::new(vec![1, 0], 1);
        let v = Affine::new(vec![2, 1], 0);
        let g = f.compose(&[u, v]);
        // g(x, y) = 3(x+1) + 2(2x+y) + 1 = 7x + 2y + 4
        assert_eq!(g, Affine::new(vec![7, 2], 4));
        for x in -3..3 {
            for y in -3..3 {
                assert_eq!(g.eval(&[x, y]), f.eval(&[x + 1, 2 * x + y]));
            }
        }
    }

    #[test]
    fn bounds_interval() {
        let a = Affine::new(vec![2, -3], 5);
        let (lo, hi) = a.bounds(&[(0, 4), (1, 3)]);
        assert_eq!(lo, 2 * 0 - 3 * 3 + 5);
        assert_eq!(hi, 2 * 4 - 3 * 1 + 5);
    }

    #[test]
    fn constant_detection() {
        assert!(Affine::constant(3, 9).is_constant());
        assert!(!Affine::var(3, 1).is_constant());
    }

    #[test]
    fn insert_dims_keeps_semantics() {
        let a = Affine::new(vec![4, 7], 2);
        let b = a.insert_dims(1, 1);
        assert_eq!(b.rank(), 3);
        assert_eq!(b.eval(&[3, 99, 5]), a.eval(&[3, 5]));
    }

    #[test]
    fn display_pretty() {
        assert_eq!(Affine::new(vec![64, 1], 0).to_string(), "64*i0 + i1");
        assert_eq!(Affine::new(vec![0, 0], 7).to_string(), "7");
        assert_eq!(Affine::new(vec![-1, 2], -3).to_string(), "-i0 + 2*i1 - 3");
    }
}
