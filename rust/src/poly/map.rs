//! Multi-output affine maps (access maps).

use std::fmt;

use super::{Affine, BoxSet};

/// An affine map `Z^in_rank -> Z^out_rank`, one [`Affine`] per output.
///
/// Unified-buffer access maps — `(x, y) -> brighten(x+1, y)` and friends —
/// are exactly this shape (§III).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct AffineMap {
    pub in_rank: usize,
    pub outputs: Vec<Affine>,
}

impl AffineMap {
    pub fn new(in_rank: usize, outputs: Vec<Affine>) -> Self {
        for o in &outputs {
            assert_eq!(o.rank(), in_rank, "output rank mismatch");
        }
        AffineMap { in_rank, outputs }
    }

    /// The identity map on `rank` dims.
    pub fn identity(rank: usize) -> Self {
        AffineMap {
            in_rank: rank,
            outputs: (0..rank).map(|k| Affine::var(rank, k)).collect(),
        }
    }

    /// A map whose every output is constant (rank-0 style access).
    pub fn constant(in_rank: usize, values: &[i64]) -> Self {
        AffineMap {
            in_rank,
            outputs: values.iter().map(|&v| Affine::constant(in_rank, v)).collect(),
        }
    }

    pub fn out_rank(&self) -> usize {
        self.outputs.len()
    }

    pub fn apply(&self, point: &[i64]) -> Vec<i64> {
        self.outputs.iter().map(|o| o.eval(point)).collect()
    }

    /// `self ∘ inner`: first apply `inner`, then `self`.
    pub fn compose(&self, inner: &AffineMap) -> AffineMap {
        assert_eq!(self.in_rank, inner.out_rank(), "compose rank mismatch");
        AffineMap {
            in_rank: inner.in_rank,
            outputs: self.outputs.iter().map(|o| o.compose(&inner.outputs)).collect(),
        }
    }

    /// If `self - other` is a constant vector, return it.
    ///
    /// This is the shift-register legality test (§V-C): output port B can
    /// be a shift register fed from port A when their access maps differ
    /// by a constant offset on a common iteration space.
    pub fn constant_difference(&self, other: &AffineMap) -> Option<Vec<i64>> {
        if self.in_rank != other.in_rank || self.out_rank() != other.out_rank() {
            return None;
        }
        let mut diff = Vec::with_capacity(self.out_rank());
        for (a, b) in self.outputs.iter().zip(&other.outputs) {
            let d = a.sub(b);
            if !d.is_constant() {
                return None;
            }
            diff.push(d.offset);
        }
        Some(diff)
    }

    /// Inclusive `(min, max)` bounds of each output over `domain`.
    pub fn range_bounds(&self, domain: &BoxSet) -> Vec<(i64, i64)> {
        assert_eq!(domain.rank(), self.in_rank);
        let b = domain.bounds();
        self.outputs.iter().map(|o| o.bounds(&b)).collect()
    }

    /// Exact injectivity check on a (small) domain by enumeration.
    pub fn is_injective_on(&self, domain: &BoxSet) -> bool {
        let mut seen = std::collections::HashSet::new();
        for p in domain.points() {
            if !seen.insert(self.apply(&p)) {
                return false;
            }
        }
        true
    }

    /// Bind the trailing `values.len()` input dims to constants.
    pub fn bind_tail(&self, values: &[i64]) -> AffineMap {
        AffineMap {
            in_rank: self.in_rank - values.len(),
            outputs: self.outputs.iter().map(|o| o.bind_tail(values)).collect(),
        }
    }

    /// Insert `count` unused input dims at `at` (strip-mining support).
    pub fn insert_in_dims(&self, at: usize, count: usize) -> AffineMap {
        AffineMap {
            in_rank: self.in_rank + count,
            outputs: self.outputs.iter().map(|o| o.insert_dims(at, count)).collect(),
        }
    }
}

impl fmt::Display for AffineMap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(")?;
        for (k, o) in self.outputs.iter().enumerate() {
            if k > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{o}")?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::set::Dim;

    /// The paper's Fig 2 access maps over (y, x) — y outermost.
    fn stencil_port(dy: i64, dx: i64) -> AffineMap {
        AffineMap::new(
            2,
            vec![Affine::new(vec![1, 0], dy), Affine::new(vec![0, 1], dx)],
        )
    }

    #[test]
    fn apply_access_map() {
        // (x,y) -> brighten(x+1, y): stored (y, x) order.
        let m = stencil_port(0, 1);
        assert_eq!(m.apply(&[3, 5]), vec![3, 6]);
    }

    #[test]
    fn identity_map() {
        let id = AffineMap::identity(3);
        assert_eq!(id.apply(&[7, -2, 4]), vec![7, -2, 4]);
    }

    #[test]
    fn compose_order() {
        // f(y, x) = (y, x + 1); g(t) = (t, 2t). (f ∘ g)(t) = (t, 2t + 1).
        let f = stencil_port(0, 1);
        let g = AffineMap::new(1, vec![Affine::var(1, 0), Affine::new(vec![2], 0)]);
        let fg = f.compose(&g);
        assert_eq!(fg.apply(&[5]), vec![5, 11]);
    }

    #[test]
    fn constant_difference_detects_shift_register() {
        // Fig 2 / Fig 8a: the 2x2 stencil ports differ from the write port
        // by constant offsets (0,0), (0,1), (1,0), (1,1).
        let write = stencil_port(0, 0);
        for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
            let read = stencil_port(dy, dx);
            assert_eq!(read.constant_difference(&write), Some(vec![dy, dx]));
        }
        // A transposed access is not a constant shift.
        let transpose = AffineMap::new(2, vec![Affine::var(2, 1), Affine::var(2, 0)]);
        assert_eq!(transpose.constant_difference(&write), None);
    }

    #[test]
    fn range_bounds_interval() {
        let dom = BoxSet::new(vec![Dim::new("y", 0, 8), Dim::new("x", 0, 8)]);
        // Downsample-by-2 access (Fig 6): (y, x) -> (2y, 2x).
        let m = AffineMap::new(2, vec![Affine::new(vec![2, 0], 0), Affine::new(vec![0, 2], 0)]);
        assert_eq!(m.range_bounds(&dom), vec![(0, 14), (0, 14)]);
    }

    #[test]
    fn injectivity() {
        let dom = BoxSet::from_extents(&[4, 4]);
        assert!(AffineMap::identity(2).is_injective_on(&dom));
        // Project to one output dim: not injective.
        let proj = AffineMap::new(2, vec![Affine::var(2, 0)]);
        assert!(!proj.is_injective_on(&dom));
        // Linearized (4y + x) is injective on a 4-wide box...
        let lin = AffineMap::new(2, vec![Affine::new(vec![4, 1], 0)]);
        assert!(lin.is_injective_on(&dom));
        // ...but not on a wider one.
        let dom8 = BoxSet::from_extents(&[4, 8]);
        assert!(!lin.is_injective_on(&dom8));
    }

    #[test]
    fn insert_in_dims_preserves() {
        let m = stencil_port(1, 1);
        let m2 = m.insert_in_dims(1, 1);
        assert_eq!(m2.in_rank, 3);
        assert_eq!(m2.apply(&[3, 42, 5]), m.apply(&[3, 5]));
    }

    #[test]
    fn display() {
        let m = stencil_port(0, 1);
        assert_eq!(m.to_string(), "(i0, i1 + 1)");
    }
}
