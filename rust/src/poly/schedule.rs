//! One-dimensional cycle-accurate affine schedules.

use std::fmt;

use super::{Affine, BoxSet};

/// A cycle-accurate schedule: an affine function from an iteration domain
/// to *cycles after reset* (Eq. 1 in the paper, e.g. `(x,y) -> 64y + x`).
///
/// Unlike classical multidimensional polyhedral schedules (Feautrier,
/// PLUTO), these map loop nests directly to scalar hardware time; several
/// operations may share a timestamp only across *different* ports (the
/// design is pipelined), but a single port issues at most one operation
/// per cycle — checked by [`CycleSchedule::is_injective_on`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CycleSchedule {
    pub expr: Affine,
}

impl CycleSchedule {
    pub fn new(expr: Affine) -> Self {
        CycleSchedule { expr }
    }

    /// The canonical dense row-major schedule of a loop nest with the
    /// given extents and initiation interval `ii`, starting at `offset`:
    /// innermost dim advances by `ii` each iteration.
    pub fn row_major(extents: &[i64], ii: i64, offset: i64) -> Self {
        let rank = extents.len();
        let mut coeffs = vec![0i64; rank];
        let mut stride = ii;
        for k in (0..rank).rev() {
            coeffs[k] = stride;
            stride *= extents[k];
        }
        CycleSchedule { expr: Affine::new(coeffs, offset) }
    }

    pub fn rank(&self) -> usize {
        self.expr.rank()
    }

    /// Cycle at which the operation at `point` begins.
    pub fn cycle(&self, point: &[i64]) -> i64 {
        self.expr.eval(point)
    }

    /// Shift the whole schedule later by `delay` cycles.
    pub fn delayed(&self, delay: i64) -> CycleSchedule {
        CycleSchedule { expr: self.expr.shift(delay) }
    }

    /// Earliest and latest issue cycle over `domain` (inclusive).
    pub fn span(&self, domain: &BoxSet) -> (i64, i64) {
        self.expr.bounds(&domain.bounds())
    }

    /// One operation per cycle per port: exact check by enumeration.
    pub fn is_injective_on(&self, domain: &BoxSet) -> bool {
        let mut seen = std::collections::HashSet::new();
        domain.points().all(|p| seen.insert(self.cycle(&p)))
    }

    /// True if the schedule visits `domain` in lexicographic program
    /// order (monotone over the point iterator). Row-major schedules
    /// with positive II always satisfy this.
    pub fn is_monotone_on(&self, domain: &BoxSet) -> bool {
        let mut last = i64::MIN;
        for p in domain.points() {
            let c = self.cycle(&p);
            if c < last {
                return false;
            }
            last = c;
        }
        true
    }
}

impl fmt::Display for CycleSchedule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t = {}", self.expr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_major_matches_paper_eq1() {
        // 64x64 tile, II=1: (y, x) -> 64y + x.
        let s = CycleSchedule::row_major(&[64, 64], 1, 0);
        assert_eq!(s.expr, Affine::new(vec![64, 1], 0));
        assert_eq!(s.cycle(&[0, 0]), 0);
        assert_eq!(s.cycle(&[0, 1]), 1);
        assert_eq!(s.cycle(&[1, 0]), 64);
    }

    #[test]
    fn row_major_with_ii() {
        let s = CycleSchedule::row_major(&[4, 8], 2, 10);
        assert_eq!(s.cycle(&[0, 0]), 10);
        assert_eq!(s.cycle(&[0, 1]), 12);
        assert_eq!(s.cycle(&[1, 0]), 10 + 16);
    }

    #[test]
    fn delayed_shifts_offset() {
        // Paper: output ports emit first value after 65 cycles.
        let s = CycleSchedule::row_major(&[64, 64], 1, 0).delayed(65);
        assert_eq!(s.cycle(&[0, 0]), 65);
    }

    #[test]
    fn span_over_domain() {
        let dom = BoxSet::from_extents(&[64, 64]);
        let s = CycleSchedule::row_major(&[64, 64], 1, 0);
        assert_eq!(s.span(&dom), (0, 4095));
    }

    #[test]
    fn injective_and_monotone() {
        let dom = BoxSet::from_extents(&[8, 8]);
        let s = CycleSchedule::row_major(&[8, 8], 1, 0);
        assert!(s.is_injective_on(&dom));
        assert!(s.is_monotone_on(&dom));
        // A schedule ignoring x is not injective per-port.
        let bad = CycleSchedule::new(Affine::new(vec![8, 0], 0));
        assert!(!bad.is_injective_on(&dom));
    }
}
