//! ISL-lite polyhedral substrate.
//!
//! The paper uses ISL [Verdoolaege 2010] to represent iteration domains,
//! access maps and cycle-accurate schedules. All maps and schedules it
//! actually constructs are *affine functions over rectangular (hyper-box)
//! Halide loop domains* (§III, §V-B), so this module implements exactly
//! that fragment from scratch:
//!
//! * [`Affine`] — an affine expression `c0*i0 + ... + ck*ik + offset`.
//! * [`BoxSet`] — a dense hyper-rectangular integer set (an iteration
//!   domain); dimension 0 is the *outermost* loop.
//! * [`AffineMap`] — a multi-output affine function (an access map).
//! * [`CycleSchedule`] — a one-dimensional affine schedule mapping
//!   iteration points to cycles-after-reset (Eq. 1 in the paper).
//!
//! Everything is exact: where a closed form is awkward (e.g. injectivity
//! on a domain, live-value counting) we enumerate the domain, which is
//! cheap for the tile-sized domains the accelerator operates on.

pub mod affine;
pub mod map;
pub mod set;
pub mod schedule;

pub use affine::{fit_affine, Affine};
pub use map::AffineMap;
pub use set::BoxSet;
pub use schedule::CycleSchedule;
