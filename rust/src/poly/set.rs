//! Dense hyper-rectangular integer sets (iteration domains).

use std::fmt;

/// One dimension of a [`BoxSet`]: a named loop iterator with an inclusive
/// integer range `[min, min + extent)`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Dim {
    pub name: String,
    pub min: i64,
    pub extent: i64,
}

impl Dim {
    pub fn new(name: impl Into<String>, min: i64, extent: i64) -> Self {
        assert!(extent >= 0, "negative extent");
        Dim { name: name.into(), min, extent }
    }

    /// Inclusive upper bound (`min + extent - 1`). Panics on empty dims.
    pub fn max(&self) -> i64 {
        assert!(self.extent > 0, "max() of empty dim {}", self.name);
        self.min + self.extent - 1
    }
}

/// A dense box iteration domain. `dims[0]` is the **outermost** loop;
/// `dims.last()` is the innermost. Points are vectors in the same order,
/// and [`BoxSet::points`] yields them in lexicographic (= program) order.
///
/// Halide loop nests over rectangular bounds lower exactly to this shape,
/// which is why the paper's polyhedral fragment never needs general
/// Presburger sets (§V-B: "The iteration domain is the Cartesian product
/// of the bounds of the loops surrounding the memory reference").
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BoxSet {
    pub dims: Vec<Dim>,
}

impl BoxSet {
    pub fn new(dims: Vec<Dim>) -> Self {
        BoxSet { dims }
    }

    /// Zero-based box from extents only, with synthesized names `d0..`.
    pub fn from_extents(extents: &[i64]) -> Self {
        BoxSet {
            dims: extents
                .iter()
                .enumerate()
                .map(|(k, &e)| Dim::new(format!("d{k}"), 0, e))
                .collect(),
        }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dims.iter().any(|d| d.extent == 0)
    }

    /// Number of integer points.
    pub fn cardinality(&self) -> i64 {
        self.dims.iter().map(|d| d.extent).product()
    }

    pub fn contains(&self, point: &[i64]) -> bool {
        point.len() == self.rank()
            && self
                .dims
                .iter()
                .zip(point)
                .all(|(d, &p)| p >= d.min && p < d.min + d.extent)
    }

    /// `(min, max)` inclusive bounds per dim, for interval arithmetic.
    pub fn bounds(&self) -> Vec<(i64, i64)> {
        self.dims.iter().map(|d| (d.min, d.max())).collect()
    }

    /// Visit all points in lexicographic order without allocating a
    /// vector per point (§Perf hot path for event enumeration).
    pub fn for_each_point(&self, mut f: impl FnMut(&[i64])) {
        if self.is_empty() {
            return;
        }
        let mut p: Vec<i64> = self.dims.iter().map(|d| d.min).collect();
        loop {
            f(&p);
            let mut k = self.rank();
            loop {
                if k == 0 {
                    return;
                }
                k -= 1;
                p[k] += 1;
                if p[k] <= self.dims[k].max() {
                    break;
                }
                p[k] = self.dims[k].min;
            }
        }
    }

    /// Iterate all points in lexicographic order (outermost dim slowest).
    pub fn points(&self) -> PointIter<'_> {
        PointIter {
            set: self,
            cur: if self.is_empty() {
                None
            } else {
                Some(self.dims.iter().map(|d| d.min).collect())
            },
        }
    }

    /// Cartesian product `self × other` (other's dims become innermost).
    pub fn product(&self, other: &BoxSet) -> BoxSet {
        let mut dims = self.dims.clone();
        dims.extend(other.dims.iter().cloned());
        BoxSet { dims }
    }

    /// Drop dimension `at`.
    pub fn project_out(&self, at: usize) -> BoxSet {
        let mut dims = self.dims.clone();
        dims.remove(at);
        BoxSet { dims }
    }

    /// Insert a dim at position `at`.
    pub fn insert_dim(&self, at: usize, dim: Dim) -> BoxSet {
        let mut dims = self.dims.clone();
        dims.insert(at, dim);
        BoxSet { dims }
    }

    /// Index of a named dim.
    pub fn dim_index(&self, name: &str) -> Option<usize> {
        self.dims.iter().position(|d| d.name == name)
    }

    /// Layout equality: same rank, mins, and extents — dim names are
    /// irrelevant to layout. The one rule every flat-addressing
    /// consumer (`SimRun`, `ExecRun`, `ExecPlan`) checks request
    /// tensors and port domains by, defined once so the engines can
    /// never drift on which boxes they accept.
    pub fn same_layout(&self, other: &BoxSet) -> bool {
        self.rank() == other.rank()
            && self
                .dims
                .iter()
                .zip(&other.dims)
                .all(|(a, b)| a.min == b.min && a.extent == b.extent)
    }
}

/// Lexicographic point iterator over a [`BoxSet`].
pub struct PointIter<'a> {
    set: &'a BoxSet,
    cur: Option<Vec<i64>>,
}

impl Iterator for PointIter<'_> {
    type Item = Vec<i64>;

    fn next(&mut self) -> Option<Vec<i64>> {
        let cur = self.cur.take()?;
        let mut next = cur.clone();
        // Increment innermost-first with carry.
        let mut k = self.set.rank();
        loop {
            if k == 0 {
                // Full carry-out: iteration finished.
                self.cur = None;
                break;
            }
            k -= 1;
            next[k] += 1;
            if next[k] <= self.set.dims[k].max() {
                self.cur = Some(next);
                break;
            }
            next[k] = self.set.dims[k].min;
        }
        Some(cur)
    }
}

impl fmt::Display for BoxSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{ (")?;
        for (k, d) in self.dims.iter().enumerate() {
            if k > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{}", d.name)?;
        }
        write!(f, ") | ")?;
        for (k, d) in self.dims.iter().enumerate() {
            if k > 0 {
                write!(f, " and ")?;
            }
            if d.extent == 0 {
                write!(f, "{} in empty", d.name)?;
            } else {
                write!(f, "{} <= {} <= {}", d.min, d.name, d.max())?;
            }
        }
        write!(f, " }}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xy64() -> BoxSet {
        // Paper §III input-port domain: 0 <= x,y <= 63, y outermost.
        BoxSet::new(vec![Dim::new("y", 0, 64), Dim::new("x", 0, 64)])
    }

    #[test]
    fn cardinality_and_contains() {
        let s = xy64();
        assert_eq!(s.cardinality(), 4096);
        assert!(s.contains(&[0, 0]));
        assert!(s.contains(&[63, 63]));
        assert!(!s.contains(&[64, 0]));
        assert!(!s.contains(&[0, -1]));
    }

    #[test]
    fn points_lexicographic() {
        let s = BoxSet::from_extents(&[2, 3]);
        let pts: Vec<_> = s.points().collect();
        assert_eq!(
            pts,
            vec![
                vec![0, 0],
                vec![0, 1],
                vec![0, 2],
                vec![1, 0],
                vec![1, 1],
                vec![1, 2]
            ]
        );
    }

    #[test]
    fn points_count_matches_cardinality() {
        let s = BoxSet::from_extents(&[3, 4, 5]);
        assert_eq!(s.points().count() as i64, s.cardinality());
    }

    #[test]
    fn empty_set() {
        let s = BoxSet::from_extents(&[4, 0]);
        assert!(s.is_empty());
        assert_eq!(s.cardinality(), 0);
        assert_eq!(s.points().count(), 0);
    }

    #[test]
    fn nonzero_min() {
        let s = BoxSet::new(vec![Dim::new("i", -2, 3)]);
        let pts: Vec<_> = s.points().collect();
        assert_eq!(pts, vec![vec![-2], vec![-1], vec![0]]);
    }

    #[test]
    fn product_and_project() {
        let a = BoxSet::from_extents(&[2]);
        let b = BoxSet::from_extents(&[3]);
        let p = a.product(&b);
        assert_eq!(p.rank(), 2);
        assert_eq!(p.cardinality(), 6);
        assert_eq!(p.project_out(0), BoxSet::from_extents(&[3]));
    }

    #[test]
    fn insert_dim_for_stripmine() {
        // (x, y) -> (x mod FW, x/FW, y): vectorization adds a dim (Eq. 2).
        let s = BoxSet::new(vec![Dim::new("y", 0, 8), Dim::new("x", 0, 16)]);
        let v = s.insert_dim(2, Dim::new("xv", 0, 4));
        assert_eq!(v.rank(), 3);
        assert_eq!(v.dims[2].extent, 4);
    }

    #[test]
    fn dim_index_lookup() {
        let s = xy64();
        assert_eq!(s.dim_index("y"), Some(0));
        assert_eq!(s.dim_index("x"), Some(1));
        assert_eq!(s.dim_index("z"), None);
    }

    #[test]
    fn display_matches_paper_notation() {
        let s = xy64();
        assert_eq!(s.to_string(), "{ (y, x) | 0 <= y <= 63 and 0 <= x <= 63 }");
    }
}
