//! Report generation: the per-app numbers behind Tables IV-VII and
//! Figs 13/14.

use std::path::Path;

use anyhow::{Context, Result};

use super::driver::{compile, gen_inputs, Compiled};
use super::validate::validate_with;
use crate::cgra::SimStats;
use crate::cost::{energy_per_op_pj, estimate_fpga, FpgaReport, CGRA_CLOCK_HZ};
use crate::exec::Engine;
use crate::extraction::extract;
use crate::halide::{lower, Program};
use crate::runtime::Runtime;
use crate::sched::{self, PipelineKind};

/// One row of the evaluation tables.
pub struct AppReport {
    pub name: String,
    pub kind: PipelineKind,
    pub completion: i64,
    pub coarse_ii: i64,
    pub pes: usize,
    pub mems: usize,
    pub sram_words: i64,
    pub sr_words: i64,
    pub pixels_per_cycle: f64,
    pub fits: bool,
    pub wirelength: Option<usize>,
    pub cgra_runtime_s: f64,
    pub cgra_energy_per_op_pj: f64,
    pub fpga: FpgaReport,
    /// XLA wall-clock (the CPU baseline), when an artifact was given.
    pub cpu_time_s: Option<f64>,
    pub validated: Option<bool>,
    pub stats: SimStats,
    /// Which engine produced the activity stats.
    pub engine: Engine,
}

/// Compile, execute, cost-model, and (optionally) validate one app
/// with the default (`Auto`) engine selection.
pub fn report_app(
    program: &Program,
    artifact: Option<&Path>,
    rt: Option<&Runtime>,
) -> Result<AppReport> {
    report_app_with(program, artifact, rt, Engine::Auto)
}

/// [`report_app`] with an explicit engine (`pushmem report --engine`).
/// Engine choice can never change a reported number — the functional
/// engine's analytic stats are bit-identical to the simulator's — it
/// only changes how long the report takes to produce.
pub fn report_app_with(
    program: &Program,
    artifact: Option<&Path>,
    rt: Option<&Runtime>,
    engine: Engine,
) -> Result<AppReport> {
    let c: Compiled = compile(program)?;
    let inputs = gen_inputs(&c.lp);
    // Execute through the design's cached plan, the same setup-once
    // path serving uses.
    let mut runner = c.runner(engine)?;
    let engine_used = runner.engine();
    let res = runner.run(&inputs).context("execution")?;

    let (cpu_time_s, validated) = match (artifact, rt) {
        (Some(a), Some(rt)) if a.exists() => {
            let v = validate_with(&c, a, rt, engine)?;
            (Some(v.cpu_time_s), Some(v.matched))
        }
        _ => (None, None),
    };

    Ok(AppReport {
        name: program.name.clone(),
        kind: c.schedule.kind,
        completion: c.graph.completion,
        coarse_ii: c.graph.coarse_ii,
        pes: c.design.pe_count(),
        mems: c.design.mem_tiles(),
        sram_words: c.design.sram_words(),
        sr_words: c.design.sr_words(),
        pixels_per_cycle: c.graph.output_pixels_per_cycle(),
        fits: c.fits(),
        wirelength: c.routing.as_ref().map(|r| r.total_wirelength),
        cgra_runtime_s: c.graph.completion as f64 / CGRA_CLOCK_HZ,
        cgra_energy_per_op_pj: energy_per_op_pj(&c.design, &res.stats),
        fpga: estimate_fpga(&c.design, &res.stats),
        cpu_time_s,
        validated,
        stats: res.stats,
        engine: engine_used,
    })
}

/// Table VI/VII: optimized pipeline schedule vs the naïve sequential
/// baseline, in completion cycles and live SRAM words.
pub struct SequentialComparison {
    pub name: String,
    pub seq_completion: i64,
    pub opt_completion: i64,
    pub speedup: f64,
    pub seq_words: i64,
    pub opt_words: i64,
    pub memory_reduction: f64,
}

pub fn sequential_comparison(program: &Program) -> Result<SequentialComparison> {
    let lp = lower::lower(program)?;
    let opt = sched::schedule(&lp)?;
    let seq = sched::sequential::schedule(&lp)?;
    let g_opt = extract(&lp, &opt)?;
    let g_seq = extract(&lp, &seq)?;
    let opt_words = g_opt.total_live_words()?;
    let seq_words = g_seq.total_live_words()?;
    Ok(SequentialComparison {
        name: program.name.clone(),
        seq_completion: seq.completion,
        opt_completion: opt.completion,
        speedup: seq.completion as f64 / opt.completion as f64,
        seq_words,
        opt_words,
        memory_reduction: seq_words as f64 / opt_words.max(1) as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn stencil_speedup_shape_table6() {
        // Table VI: gaussian ~6.6x, multi-stage stencils 10-22x.
        let g = sequential_comparison(&apps::gaussian::build(30)).unwrap();
        assert!(g.speedup > 3.0, "gaussian speedup {}", g.speedup);
        let h = sequential_comparison(&apps::harris::build(
            24,
            apps::harris::Schedule::NoRecompute,
        ))
        .unwrap();
        assert!(h.speedup > g.speedup, "harris {} vs gaussian {}", h.speedup, g.speedup);
    }

    #[test]
    fn memory_reduction_shape_table7() {
        // Stencils see large reductions; resnet sees none (ratio ~1).
        let g = sequential_comparison(&apps::gaussian::build(30)).unwrap();
        assert!(g.memory_reduction > 5.0, "gaussian reduction {}", g.memory_reduction);
        let r = sequential_comparison(&apps::resnet::build(
            apps::resnet::Size::small(),
        ))
        .unwrap();
        assert!(r.memory_reduction < 2.0, "resnet reduction {}", r.memory_reduction);
    }

    #[test]
    fn report_without_artifact() {
        let (p, _) = apps::by_name("gaussian").unwrap();
        let r = report_app(&p, None, None).unwrap();
        assert!(r.pes > 0 && r.mems > 0);
        assert!(r.fits);
        assert!(r.cgra_runtime_s > 0.0);
        assert!(r.fpga.runtime_s > r.cgra_runtime_s);
        assert!(r.validated.is_none());
        assert_eq!(r.engine, Engine::Exec, "Auto must resolve to exec");
    }

    /// Engine choice must not change a single reported number.
    #[test]
    fn report_numbers_are_engine_independent() {
        let p = apps::gaussian::build(14);
        let e = report_app_with(&p, None, None, Engine::Exec).unwrap();
        let s = report_app_with(&p, None, None, Engine::Sim).unwrap();
        assert_eq!(e.stats, s.stats);
        assert_eq!(e.completion, s.completion);
        assert!((e.cgra_energy_per_op_pj - s.cgra_energy_per_op_pj).abs() < 1e-12);
    }
}
