//! End-to-end validation: run the cycle-accurate CGRA simulation and
//! the AOT-compiled XLA golden model on identical inputs and compare
//! the output images pixel-exactly (§VI-B), evaluating any host-side
//! stages (sch6-style) on the simulator's output first.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::driver::{gen_inputs, Compiled};
use crate::cgra::SimStats;
use crate::halide::Func;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub struct Validation {
    pub app: String,
    pub words_compared: usize,
    pub matched: bool,
    /// Wall-clock of the XLA execution — the Fig 14 CPU point.
    pub cpu_time_s: f64,
    pub stats: SimStats,
}

/// Evaluate host-scheduled funcs (pointwise stages moved off the
/// accelerator) over the accelerator output.
pub fn eval_host_funcs(
    host: &[Func],
    accel_out: &str,
    bufs: &mut BTreeMap<String, Tensor>,
) -> Result<String> {
    let mut last = accel_out.to_string();
    for f in host {
        let src_box = bufs[&last].shape.clone();
        let names: Vec<String> = f.vars.clone();
        let mut out = Tensor::zeros(src_box.clone());
        for p in src_box.points() {
            let env: BTreeMap<String, i64> =
                names.iter().cloned().zip(p.iter().cloned()).collect();
            let mut load = |buf: &str, pt: &[i64]| bufs[buf].get(pt);
            let v = f.body.eval(&env, &mut load);
            out.set(&p, v);
        }
        bufs.insert(f.name.clone(), out);
        last = f.name.clone();
    }
    Ok(last)
}

/// Validate one compiled app against a golden HLO artifact.
pub fn validate(c: &Compiled, artifact: &Path, rt: &Runtime) -> Result<Validation> {
    let inputs = gen_inputs(&c.lp);
    // Simulate through the design's cached plan (Compiled::plan), the
    // same setup-once path serving uses.
    let res = crate::cgra::SimRun::new(c.plan()?)
        .run(&inputs)
        .context("CGRA simulation")?;

    // Host stages (if any) run on the simulator output.
    let mut bufs: BTreeMap<String, Tensor> = inputs.clone();
    bufs.insert(c.lp.output.clone(), res.output.clone());
    let final_name = eval_host_funcs(&c.lp.host_funcs, &c.lp.output, &mut bufs)?;
    let final_out = &bufs[&final_name];

    // Golden: XLA executes the artifact on the same inputs, in the
    // program's declared input order.
    let model = rt.load(artifact)?;
    let ordered: Vec<&Tensor> = c.lp.inputs.iter().map(|n| &inputs[n]).collect();
    let (golden, cpu_time_s) = model.run(&ordered)?;

    // Compare row-major over the golden's length: the simulator's box
    // may be halo-rounded larger; the golden shape is the reference.
    anyhow::ensure!(
        golden.len() <= final_out.len(),
        "golden output larger than simulated ({} vs {})",
        golden.len(),
        final_out.len()
    );
    let mut matched = true;
    if golden.len() == final_out.len() {
        matched = golden == final_out.data;
    } else {
        // Rounded realization: compare point-by-point over the golden
        // box (leading sub-box of each dimension).
        let mut gshape = final_out.shape.clone();
        // Infer the golden box by shrinking the rounded dims.
        let total: i64 = golden.len() as i64;
        let mut prod: i64 = gshape.dims.iter().map(|d| d.extent).product();
        for k in (0..gshape.rank()).rev() {
            while prod > total && gshape.dims[k].extent > 1 {
                let e = gshape.dims[k].extent;
                gshape.dims[k] = crate::poly::set::Dim::new(
                    gshape.dims[k].name.clone(),
                    gshape.dims[k].min,
                    e - 1,
                );
                prod = gshape.dims.iter().map(|d| d.extent).product();
            }
        }
        anyhow::ensure!(prod == total, "cannot infer golden box");
        let gt = Tensor::from_data(gshape.clone(), golden.clone());
        for p in gshape.points() {
            if gt.get(&p) != final_out.get(&p) {
                matched = false;
                break;
            }
        }
    }

    Ok(Validation {
        app: c.program.name.clone(),
        words_compared: golden.len(),
        matched,
        cpu_time_s,
        stats: res.stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::driver::compile;

    fn artifact(name: &str) -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .join(format!("{name}.hlo.txt"))
    }

    #[test]
    fn gaussian_sim_matches_xla_golden() {
        let path = artifact("gaussian");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let (p, _) = apps::by_name("gaussian").unwrap();
        let c = compile(&p).unwrap();
        let rt = Runtime::cpu().unwrap();
        let v = validate(&c, &path, &rt).unwrap();
        assert!(v.matched, "CGRA simulation diverges from XLA golden");
        assert_eq!(v.words_compared, 62 * 62);
        assert!(v.cpu_time_s > 0.0);
    }

    #[test]
    fn host_stage_validation_sch6() {
        let path = artifact("harris");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let (p, _) = apps::by_name("harris_sch6").unwrap();
        let c = compile(&p).unwrap();
        assert_eq!(c.lp.host_funcs.len(), 1);
        let rt = Runtime::cpu().unwrap();
        let v = validate(&c, &path, &rt).unwrap();
        assert!(v.matched, "host-stage pipeline diverges from golden");
    }
}
