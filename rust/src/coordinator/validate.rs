//! End-to-end validation: run the accelerator model and the
//! AOT-compiled XLA golden model on identical inputs and compare the
//! output images pixel-exactly (§VI-B), evaluating any host-side
//! stages (sch6-style) on the accelerator's output first. Also home
//! of the engine cross-check ([`cross_check`]): the functional engine
//! vs the cycle-accurate simulator, with first-divergence reporting
//! (port, coordinate, cycle) instead of a bare boolean — the
//! `pushmem validate` subcommand.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::driver::{gen_inputs, Compiled};
use crate::cgra::{SimRun, SimStats};
use crate::exec::{Engine, ExecRun};
use crate::halide::Func;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub struct Validation {
    pub app: String,
    pub words_compared: usize,
    pub matched: bool,
    /// Wall-clock of the XLA execution — the Fig 14 CPU point.
    pub cpu_time_s: f64,
    pub stats: SimStats,
    /// Which engine produced the accelerator output.
    pub engine: Engine,
}

/// The first point where the two engines disagree, located on the
/// output stream: which drain port, which output coordinate, and the
/// cycle that word leaves the accelerator.
#[derive(Clone, Debug)]
pub struct EngineDivergence {
    pub port: String,
    pub coord: Vec<i64>,
    pub cycle: i64,
    pub sim: i32,
    pub exec: i32,
}

/// Result of the exec-vs-sim differential run ([`cross_check`]).
pub struct CrossCheck {
    pub app: String,
    pub words: usize,
    pub sim_cycles: i64,
    pub exec_cycles: i64,
    pub sim_stats: SimStats,
    pub exec_stats: SimStats,
    /// `None` when outputs are bit-exact.
    pub divergence: Option<EngineDivergence>,
}

impl CrossCheck {
    /// Bit-exact outputs AND identical reported stats.
    pub fn matched(&self) -> bool {
        self.divergence.is_none() && self.sim_stats == self.exec_stats
    }
}

/// Run one design through both engines on the deterministic input
/// stream and compare outputs word-for-word. On divergence, report
/// the *first* mismatching output event in cycle order — the drain
/// port, output coordinate, and cycle — so a broken engine points at
/// the exact event to replay, not a bare boolean.
pub fn cross_check(c: &Compiled) -> Result<CrossCheck> {
    let inputs = gen_inputs(&c.lp);
    let sim = SimRun::new(c.plan()?)
        .run(&inputs)
        .context("cycle-accurate simulation")?;
    let ex = ExecRun::new(c.exec_plan().context("functional engine unavailable")?)
        .run(&inputs)
        .context("functional execution")?;
    // Third leg: the scalar reference walk. The vectorized + threaded
    // hot path is *defined* to be bit-identical to it (DESIGN.md §6);
    // any daylight here is an engine bug, never a design property, so
    // it is a hard internal failure rather than a CrossCheck verdict.
    let sc = ExecRun::new_scalar(c.exec_plan().context("functional engine unavailable")?)
        .run(&inputs)
        .context("scalar functional execution")?;
    anyhow::ensure!(
        sc.output.data == ex.output.data && sc.stats == ex.stats,
        "vectorized functional engine diverges from its scalar reference \
         (this is an exec-engine bug; run `cargo test --test exec_fuzz` to localize)"
    );
    anyhow::ensure!(
        sim.output.shape == ex.output.shape,
        "engines produced different output boxes: {} vs {}",
        sim.output.shape,
        ex.output.shape
    );

    let mut divergence: Option<EngineDivergence> = None;
    if sim.output.data != ex.output.data {
        // Locate the earliest differing output event in cycle order.
        for ep in &c.graph.output_streams {
            let port = &c.graph.buffers[&ep.buffer].outputs[ep.port];
            port.visit_events(|cycle, coords| {
                let (s, e) = (sim.output.get(coords), ex.output.get(coords));
                let earlier = match &divergence {
                    Some(d) => cycle < d.cycle,
                    None => true,
                };
                if s != e && earlier {
                    divergence = Some(EngineDivergence {
                        port: port.name.clone(),
                        coord: coords.to_vec(),
                        cycle,
                        sim: s,
                        exec: e,
                    });
                }
            });
        }
        if divergence.is_none() {
            // The outputs differ at a coordinate no drain event covers
            // (a never-streamed word). This must still be reported as
            // a divergence — never let the data-differs case fall
            // through to a MATCH verdict.
            for (idx, p) in sim.output.shape.points().enumerate() {
                let (s, e) = (sim.output.data[idx], ex.output.data[idx]);
                if s != e {
                    divergence = Some(EngineDivergence {
                        port: "(no drain event covers this coordinate)".to_string(),
                        coord: p,
                        cycle: -1,
                        sim: s,
                        exec: e,
                    });
                    break;
                }
            }
        }
    }

    Ok(CrossCheck {
        app: c.program.name.clone(),
        words: sim.output.data.len(),
        sim_cycles: sim.stats.cycles,
        exec_cycles: ex.stats.cycles,
        sim_stats: sim.stats,
        exec_stats: ex.stats,
        divergence,
    })
}

/// Evaluate host-scheduled funcs (pointwise stages moved off the
/// accelerator) over the accelerator output.
pub fn eval_host_funcs(
    host: &[Func],
    accel_out: &str,
    bufs: &mut BTreeMap<String, Tensor>,
) -> Result<String> {
    let mut last = accel_out.to_string();
    for f in host {
        let src_box = bufs[&last].shape.clone();
        let names: Vec<String> = f.vars.clone();
        let mut out = Tensor::zeros(src_box.clone());
        for p in src_box.points() {
            let env: BTreeMap<String, i64> =
                names.iter().cloned().zip(p.iter().cloned()).collect();
            let mut load = |buf: &str, pt: &[i64]| bufs[buf].get(pt);
            let v = f.body.eval(&env, &mut load);
            out.set(&p, v);
        }
        bufs.insert(f.name.clone(), out);
        last = f.name.clone();
    }
    Ok(last)
}

/// Validate one compiled app against a golden HLO artifact, using the
/// default (`Auto`) engine selection.
pub fn validate(c: &Compiled, artifact: &Path, rt: &Runtime) -> Result<Validation> {
    validate_with(c, artifact, rt, Engine::Auto)
}

/// [`validate`] with an explicit engine choice (`pushmem run --engine`).
pub fn validate_with(
    c: &Compiled,
    artifact: &Path,
    rt: &Runtime,
    engine: Engine,
) -> Result<Validation> {
    let inputs = gen_inputs(&c.lp);
    // Execute through the design's cached plan, the same setup-once
    // path serving uses.
    let mut runner = c.runner(engine)?;
    let engine = runner.engine();
    let res = runner.run(&inputs).context("accelerator execution")?;

    // Host stages (if any) run on the simulator output.
    let mut bufs: BTreeMap<String, Tensor> = inputs.clone();
    bufs.insert(c.lp.output.clone(), res.output.clone());
    let final_name = eval_host_funcs(&c.lp.host_funcs, &c.lp.output, &mut bufs)?;
    let final_out = &bufs[&final_name];

    // Golden: XLA executes the artifact on the same inputs, in the
    // program's declared input order.
    let model = rt.load(artifact)?;
    let ordered: Vec<&Tensor> = c.lp.inputs.iter().map(|n| &inputs[n]).collect();
    let (golden, cpu_time_s) = model.run(&ordered)?;

    // Compare row-major over the golden's length: the simulator's box
    // may be halo-rounded larger; the golden shape is the reference.
    anyhow::ensure!(
        golden.len() <= final_out.len(),
        "golden output larger than simulated ({} vs {})",
        golden.len(),
        final_out.len()
    );
    let mut matched = true;
    if golden.len() == final_out.len() {
        matched = golden == final_out.data;
    } else {
        // Rounded realization: compare point-by-point over the golden
        // box (leading sub-box of each dimension).
        let mut gshape = final_out.shape.clone();
        // Infer the golden box by shrinking the rounded dims.
        let total: i64 = golden.len() as i64;
        let mut prod: i64 = gshape.dims.iter().map(|d| d.extent).product();
        for k in (0..gshape.rank()).rev() {
            while prod > total && gshape.dims[k].extent > 1 {
                let e = gshape.dims[k].extent;
                gshape.dims[k] = crate::poly::set::Dim::new(
                    gshape.dims[k].name.clone(),
                    gshape.dims[k].min,
                    e - 1,
                );
                prod = gshape.dims.iter().map(|d| d.extent).product();
            }
        }
        anyhow::ensure!(prod == total, "cannot infer golden box");
        let gt = Tensor::from_data(gshape.clone(), golden.clone());
        for p in gshape.points() {
            if gt.get(&p) != final_out.get(&p) {
                matched = false;
                break;
            }
        }
    }

    Ok(Validation {
        app: c.program.name.clone(),
        words_compared: golden.len(),
        matched,
        cpu_time_s,
        stats: res.stats,
        engine,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::driver::compile;

    fn artifact(name: &str) -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .join(format!("{name}.hlo.txt"))
    }

    #[test]
    fn cross_check_engines_match_on_small_apps() {
        for p in [
            apps::gaussian::build(14),
            apps::harris::build(12, apps::harris::Schedule::NoRecompute),
        ] {
            let c = compile(&p).unwrap();
            let cc = cross_check(&c).unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
            assert!(cc.matched(), "{}: {:?}", p.name, cc.divergence);
            assert_eq!(cc.sim_cycles, cc.exec_cycles, "{}", p.name);
            assert_eq!(cc.sim_stats, cc.exec_stats, "{}", p.name);
            assert!(cc.words > 0);
        }
    }

    #[test]
    fn gaussian_sim_matches_xla_golden() {
        let path = artifact("gaussian");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let (p, _) = apps::by_name("gaussian").unwrap();
        let c = compile(&p).unwrap();
        let rt = Runtime::cpu().unwrap();
        let v = validate(&c, &path, &rt).unwrap();
        assert!(v.matched, "CGRA simulation diverges from XLA golden");
        assert_eq!(v.words_compared, 62 * 62);
        assert!(v.cpu_time_s > 0.0);
    }

    #[test]
    fn host_stage_validation_sch6() {
        let path = artifact("harris");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let (p, _) = apps::by_name("harris_sch6").unwrap();
        let c = compile(&p).unwrap();
        assert_eq!(c.lp.host_funcs.len(), 1);
        let rt = Runtime::cpu().unwrap();
        let v = validate(&c, &path, &rt).unwrap();
        assert!(v.matched, "host-stage pipeline diverges from golden");
    }
}
