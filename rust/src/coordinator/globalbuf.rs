//! The global buffer (Fig 12): a multi-banked, double-buffered staging
//! memory between main memory and the CGRA. It gives the array a
//! deterministic access latency — tiles are fully staged before the
//! statically-scheduled computation starts, and the *next* tile loads
//! while the current one computes. If compute finishes first, the whole
//! CGRA stalls until the tile is staged (coarse-grained stalling, §VI).

/// Double-buffered tile streaming model.
#[derive(Clone, Copy, Debug)]
pub struct GlobalBuffer {
    /// Words per cycle from main memory into the global buffer.
    pub fill_bandwidth: f64,
    /// Words per cycle from the global buffer back to main memory.
    pub drain_bandwidth: f64,
}

impl Default for GlobalBuffer {
    fn default() -> Self {
        // A 64-bit DDR-ish channel at the CGRA clock: 4 16-bit words
        // per cycle each way.
        GlobalBuffer { fill_bandwidth: 4.0, drain_bandwidth: 4.0 }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct StreamPlan {
    /// Cycles to stage one input tile.
    pub fill_cycles: i64,
    /// Cycles to drain one output tile.
    pub drain_cycles: i64,
    /// Steady-state interval between tiles (the larger of compute II
    /// and staging time).
    pub interval: i64,
    /// Total cycles for `tiles` tiles.
    pub total_cycles: i64,
    /// Fraction of intervals in which the CGRA is compute-bound
    /// (1.0 = never stalls on memory).
    pub compute_bound: bool,
}

impl GlobalBuffer {
    /// Plan streaming `tiles` tiles through a kernel with the given
    /// per-tile word counts and schedule.
    pub fn plan(
        &self,
        input_words: i64,
        output_words: i64,
        completion: i64,
        coarse_ii: i64,
        tiles: i64,
    ) -> StreamPlan {
        let fill = (input_words as f64 / self.fill_bandwidth).ceil() as i64;
        let drain = (output_words as f64 / self.drain_bandwidth).ceil() as i64;
        let interval = coarse_ii.max(fill).max(drain);
        let total = fill + completion + (tiles - 1).max(0) * interval + drain;
        StreamPlan {
            fill_cycles: fill,
            drain_cycles: drain,
            interval,
            total_cycles: total,
            compute_bound: coarse_ii >= fill.max(drain),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_bound_stencil() {
        // 64x64 input tile (4096 words) at 4 words/cycle = 1024 fill
        // cycles; a 4102-cycle stencil is compute-bound.
        let gb = GlobalBuffer::default();
        let plan = gb.plan(4096, 3844, 4102, 4102, 8);
        assert_eq!(plan.fill_cycles, 1024);
        assert!(plan.compute_bound);
        assert_eq!(plan.interval, 4102);
        assert_eq!(plan.total_cycles, 1024 + 4102 + 7 * 4102 + 961);
    }

    #[test]
    fn memory_bound_when_compute_is_tiny() {
        let gb = GlobalBuffer { fill_bandwidth: 1.0, drain_bandwidth: 1.0 };
        let plan = gb.plan(4096, 4096, 100, 100, 4);
        assert!(!plan.compute_bound);
        assert_eq!(plan.interval, 4096);
    }

    #[test]
    fn single_tile_has_no_interval_term() {
        let gb = GlobalBuffer::default();
        let plan = gb.plan(400, 400, 500, 500, 1);
        assert_eq!(plan.total_cycles, 100 + 500 + 100);
    }
}
