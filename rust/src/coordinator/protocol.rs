//! Wire protocol for the tile server: pure frame encode/decode over
//! byte slices, unit-testable without sockets. The wire format is
//! specified in docs/protocol.md; the constants here are the Rust
//! source of truth (the Python client mirrors them in
//! python/pushmem_client.py).
//!
//! Two request generations share one port:
//!
//! * **v1** (the original `pushmem serve <app>` shape): the word after
//!   the magic is the input count, and the target app is implicit (the
//!   server's default app).
//! * **v2**: the word after the magic is the [`VERSION2`] sentinel —
//!   a value no valid v1 input count can reach, since v1 counts are
//!   capped at [`MAX_INPUTS`] — followed by an app-name field, so one
//!   endpoint serves every registered app.
//!
//! Responses are identical for both generations.
//!
//! All decode functions are *total* over `&[u8]`: on a short buffer
//! they return [`FrameError::Truncated`] carrying the exact number of
//! bytes the frame needs so far, which is what lets the socket layer
//! in [`super::serve`] read frames incrementally without duplicating
//! any parsing logic.

use std::fmt;

/// Frame magic ("PUB\"" — push-memory unified buffer).
pub const MAGIC: u32 = 0x5055_4222;

/// v2 discriminator: occupies the word where v1 puts `n_inputs`.
/// Deliberately far above [`MAX_INPUTS`] so the two generations can
/// never be confused.
pub const VERSION2: u32 = 0xFFFF_0002;

/// Request handled; payload words follow.
pub const STATUS_OK: u32 = 0;
/// v2 app name (or v1 with no default app) did not resolve.
pub const STATUS_UNKNOWN_APP: u32 = 1;
/// Structurally or semantically malformed request (bad magic, input
/// count or word count not matching the app's declared input boxes).
pub const STATUS_BAD_REQUEST: u32 = 2;
/// Simulation failed server-side.
pub const STATUS_INTERNAL: u32 = 3;

/// Caps that keep one malformed length word from allocating
/// gigabytes. Generous: the paper-scale apps use ≤ 5 inputs and
/// ≤ 2^17 words per tensor.
pub const MAX_INPUTS: u32 = 64;
pub const MAX_APP_NAME: u32 = 64;
pub const MAX_WORDS: u32 = 1 << 24;
/// Aggregate cap on payload words in one frame (all inputs summed) —
/// without it a frame could legally declare `MAX_INPUTS × MAX_WORDS`
/// (≈ 4 GiB) and OOM a worker before the app's declared boxes ever
/// reject it.
pub const MAX_FRAME_WORDS: u32 = 1 << 24;

/// A decoded request frame. `app` is `None` for v1 frames (implicit
/// default app) and `Some(name)` for v2. Inputs are row-major word
/// vectors in the app's declared input order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub app: Option<String>,
    pub inputs: Vec<Vec<i32>>,
}

/// A decoded response frame (shared by v1 and v2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub status: u32,
    pub words: Vec<i32>,
    pub cycles: u64,
    pub micros: u64,
}

/// Structural framing errors. [`FrameError::Truncated`] is
/// recoverable: `need` is the total frame length known so far, so a
/// stream reader can fetch exactly the missing bytes and retry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    Truncated { have: usize, need: usize },
    BadMagic(u32),
    TooLarge { what: &'static str, got: u32, max: u32 },
    BadAppName,
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Truncated { have, need } => {
                write!(f, "truncated frame: have {have} bytes, need {need}")
            }
            FrameError::BadMagic(m) => write!(f, "bad magic {m:#010x}"),
            FrameError::TooLarge { what, got, max } => {
                write!(f, "{what} {got} exceeds protocol cap {max}")
            }
            FrameError::BadAppName => write!(f, "app name is not valid UTF-8"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Byte-slice cursor; every read reports the exact prefix length the
/// frame needs when the buffer is short.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        let need = self.pos + n;
        if need > self.buf.len() {
            return Err(FrameError::Truncated { have: self.buf.len(), need });
        }
        let s = &self.buf[self.pos..need];
        self.pos = need;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    fn words(&mut self, n: usize) -> Result<Vec<i32>, FrameError> {
        let b = self.take(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_words(out: &mut Vec<u8>, words: &[i32]) {
    put_u32(out, words.len() as u32);
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

/// Encode a v1 request: `magic | n_inputs | (word_count | words)*`.
pub fn encode_request_v1(inputs: &[&[i32]]) -> Vec<u8> {
    let total: usize = inputs.iter().map(|w| w.len()).sum();
    let mut out = Vec::with_capacity(8 + 4 * inputs.len() + 4 * total);
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, inputs.len() as u32);
    for words in inputs {
        put_words(&mut out, words);
    }
    out
}

/// Encode a v2 request:
/// `magic | VERSION2 | name_len | name bytes | n_inputs | (word_count | words)*`.
pub fn encode_request_v2(app: &str, inputs: &[&[i32]]) -> Vec<u8> {
    let total: usize = inputs.iter().map(|w| w.len()).sum();
    let mut out = Vec::with_capacity(16 + app.len() + 4 * inputs.len() + 4 * total);
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, VERSION2);
    put_u32(&mut out, app.len() as u32);
    out.extend_from_slice(app.as_bytes());
    put_u32(&mut out, inputs.len() as u32);
    for words in inputs {
        put_words(&mut out, words);
    }
    out
}

/// Encode a [`Request`], choosing v1 or v2 framing by `app` presence.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let refs: Vec<&[i32]> = req.inputs.iter().map(|v| v.as_slice()).collect();
    match &req.app {
        Some(name) => encode_request_v2(name, &refs),
        None => encode_request_v1(&refs),
    }
}

/// Decode one request frame from the front of `buf`; returns the
/// request and the number of bytes consumed.
pub fn decode_request(buf: &[u8]) -> Result<(Request, usize), FrameError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let word2 = c.u32()?;
    let (app, n_inputs) = if word2 == VERSION2 {
        let name_len = c.u32()?;
        if name_len > MAX_APP_NAME {
            return Err(FrameError::TooLarge { what: "app name length", got: name_len, max: MAX_APP_NAME });
        }
        let name = std::str::from_utf8(c.take(name_len as usize)?)
            .map_err(|_| FrameError::BadAppName)?
            .to_string();
        (Some(name), c.u32()?)
    } else {
        (None, word2)
    };
    if n_inputs > MAX_INPUTS {
        return Err(FrameError::TooLarge { what: "input count", got: n_inputs, max: MAX_INPUTS });
    }
    let mut inputs = Vec::with_capacity(n_inputs as usize);
    let mut total: u64 = 0;
    for _ in 0..n_inputs {
        let wc = c.u32()?;
        if wc > MAX_WORDS {
            return Err(FrameError::TooLarge { what: "input word count", got: wc, max: MAX_WORDS });
        }
        total += wc as u64;
        if total > MAX_FRAME_WORDS as u64 {
            return Err(FrameError::TooLarge { what: "frame word total", got: total.min(u32::MAX as u64) as u32, max: MAX_FRAME_WORDS });
        }
        inputs.push(c.words(wc as usize)?);
    }
    Ok((Request { app, inputs }, c.pos))
}

/// Total byte length of the request frame at the front of `buf`,
/// computed from the length fields alone — no payload allocation or
/// word conversion. Returns `Truncated { need }` while more bytes are
/// required to know. Stream readers use this to size their reads so
/// [`decode_request`] runs exactly once per frame (re-decoding after
/// every partial read would re-convert all completed inputs, an
/// amplification a hostile client gets for free).
pub fn request_frame_len(buf: &[u8]) -> Result<usize, FrameError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let word2 = c.u32()?;
    let n_inputs = if word2 == VERSION2 {
        let name_len = c.u32()?;
        if name_len > MAX_APP_NAME {
            return Err(FrameError::TooLarge { what: "app name length", got: name_len, max: MAX_APP_NAME });
        }
        c.take(name_len as usize)?;
        c.u32()?
    } else {
        word2
    };
    if n_inputs > MAX_INPUTS {
        return Err(FrameError::TooLarge { what: "input count", got: n_inputs, max: MAX_INPUTS });
    }
    let mut total: u64 = 0;
    for _ in 0..n_inputs {
        let wc = c.u32()?;
        if wc > MAX_WORDS {
            return Err(FrameError::TooLarge { what: "input word count", got: wc, max: MAX_WORDS });
        }
        total += wc as u64;
        if total > MAX_FRAME_WORDS as u64 {
            return Err(FrameError::TooLarge { what: "frame word total", got: total.min(u32::MAX as u64) as u32, max: MAX_FRAME_WORDS });
        }
        c.take(wc as usize * 4)?;
    }
    Ok(c.pos)
}

/// Total byte length of the response frame at the front of `buf`
/// (same contract as [`request_frame_len`]).
pub fn response_frame_len(buf: &[u8]) -> Result<usize, FrameError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    c.u32()?; // status
    let wc = c.u32()?;
    if wc > MAX_WORDS {
        return Err(FrameError::TooLarge { what: "response word count", got: wc, max: MAX_WORDS });
    }
    Ok(28 + 4 * wc as usize)
}

/// Encode a response frame:
/// `magic | status | word_count | words | cycles u64 | micros u64`.
pub fn encode_response(r: &Response) -> Vec<u8> {
    let mut out = Vec::with_capacity(28 + 4 * r.words.len());
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, r.status);
    put_words(&mut out, &r.words);
    out.extend_from_slice(&r.cycles.to_le_bytes());
    out.extend_from_slice(&r.micros.to_le_bytes());
    out
}

/// An error response carries no payload words and zeroed timings.
pub fn encode_error(status: u32) -> Vec<u8> {
    encode_response(&Response { status, words: Vec::new(), cycles: 0, micros: 0 })
}

/// Decode one response frame from the front of `buf`; returns the
/// response and the number of bytes consumed.
pub fn decode_response(buf: &[u8]) -> Result<(Response, usize), FrameError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let status = c.u32()?;
    let wc = c.u32()?;
    if wc > MAX_WORDS {
        return Err(FrameError::TooLarge { what: "response word count", got: wc, max: MAX_WORDS });
    }
    let words = c.words(wc as usize)?;
    let cycles = c.u64()?;
    let micros = c.u64()?;
    Ok((Response { status, words, cycles, micros }, c.pos))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_v1() -> Request {
        Request { app: None, inputs: vec![vec![1, -2, 3], vec![0; 5]] }
    }

    fn req_v2() -> Request {
        Request {
            app: Some("gaussian".to_string()),
            inputs: vec![vec![i32::MIN, -1, 0, 1, i32::MAX]],
        }
    }

    #[test]
    fn sentinel_cannot_collide_with_v1_counts() {
        assert!(VERSION2 > MAX_INPUTS);
    }

    #[test]
    fn v1_request_round_trip() {
        let req = req_v1();
        let bytes = encode_request(&req);
        let (back, used) = decode_request(&bytes).unwrap();
        assert_eq!(back, req);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn v2_request_round_trip() {
        let req = req_v2();
        let bytes = encode_request(&req);
        let (back, used) = decode_request(&bytes).unwrap();
        assert_eq!(back, req);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn v2_empty_inputs_round_trip() {
        let req = Request { app: Some("x".into()), inputs: vec![] };
        let (back, _) = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(back, req);
    }

    /// Every strict prefix must report Truncated with a `need` that
    /// (a) exceeds the prefix and (b) never overshoots the full frame
    /// — the invariant the socket reader in serve.rs relies on.
    #[test]
    fn request_truncation_sweep() {
        for req in [req_v1(), req_v2()] {
            let bytes = encode_request(&req);
            for cut in 0..bytes.len() {
                match decode_request(&bytes[..cut]) {
                    Err(FrameError::Truncated { have, need }) => {
                        assert_eq!(have, cut);
                        assert!(need > cut, "need {need} at cut {cut}");
                        assert!(need <= bytes.len(), "overshoot {need} at cut {cut}");
                    }
                    other => panic!("cut {cut}: expected Truncated, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn response_truncation_sweep() {
        let resp = Response { status: 0, words: vec![7, 8, 9], cycles: 42, micros: 17 };
        let bytes = encode_response(&resp);
        for cut in 0..bytes.len() {
            match decode_response(&bytes[..cut]) {
                Err(FrameError::Truncated { need, .. }) => {
                    assert!(need > cut && need <= bytes.len());
                }
                other => panic!("cut {cut}: expected Truncated, got {other:?}"),
            }
        }
        let (back, used) = decode_response(&bytes).unwrap();
        assert_eq!(back, resp);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode_request(&req_v1());
        bytes[0] ^= 0xFF;
        let got = decode_request(&bytes).unwrap_err();
        assert!(matches!(got, FrameError::BadMagic(_)));
    }

    #[test]
    fn oversized_counts_rejected() {
        // Input count above the cap (and not the v2 sentinel).
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, MAX_INPUTS + 1);
        assert!(matches!(
            decode_request(&out).unwrap_err(),
            FrameError::TooLarge { what: "input count", .. }
        ));

        // Word count above the cap.
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, 1);
        super::put_u32(&mut out, MAX_WORDS + 1);
        assert!(matches!(
            decode_request(&out).unwrap_err(),
            FrameError::TooLarge { what: "input word count", .. }
        ));

        // Aggregate words above the cap even though each input is
        // individually legal — caught from the header alone, before
        // any payload byte would need buffering.
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, 3);
        super::put_u32(&mut out, MAX_WORDS / 2);
        out.extend_from_slice(&vec![0u8; (MAX_WORDS / 2) as usize * 4]);
        super::put_u32(&mut out, MAX_WORDS / 2);
        out.extend_from_slice(&vec![0u8; (MAX_WORDS / 2) as usize * 4]);
        super::put_u32(&mut out, MAX_WORDS / 2);
        assert!(matches!(
            request_frame_len(&out).unwrap_err(),
            FrameError::TooLarge { what: "frame word total", .. }
        ));
        assert!(matches!(
            decode_request(&out).unwrap_err(),
            FrameError::TooLarge { what: "frame word total", .. }
        ));

        // App name above the cap.
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, VERSION2);
        super::put_u32(&mut out, MAX_APP_NAME + 1);
        assert!(matches!(
            decode_request(&out).unwrap_err(),
            FrameError::TooLarge { what: "app name length", .. }
        ));
    }

    #[test]
    fn non_utf8_app_name_rejected() {
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, VERSION2);
        super::put_u32(&mut out, 2);
        out.extend_from_slice(&[0xFF, 0xFE]);
        super::put_u32(&mut out, 0);
        assert_eq!(decode_request(&out).unwrap_err(), FrameError::BadAppName);
    }

    #[test]
    fn error_frame_shape() {
        let bytes = encode_error(STATUS_UNKNOWN_APP);
        let (resp, used) = decode_response(&bytes).unwrap();
        assert_eq!(used, 28);
        assert_eq!(resp.status, STATUS_UNKNOWN_APP);
        assert!(resp.words.is_empty());
        assert_eq!((resp.cycles, resp.micros), (0, 0));
    }

    /// The frame-length pre-scan must agree exactly with the decoder
    /// (full length on a complete frame, recoverable Truncated on any
    /// strict prefix, never overshooting the frame).
    #[test]
    fn frame_len_matches_decode() {
        for req in [req_v1(), req_v2()] {
            let bytes = encode_request(&req);
            assert_eq!(request_frame_len(&bytes).unwrap(), bytes.len());
            for cut in 0..bytes.len() {
                match request_frame_len(&bytes[..cut]) {
                    Err(FrameError::Truncated { need, .. }) => {
                        assert!(need > cut && need <= bytes.len(), "cut {cut}");
                    }
                    other => panic!("cut {cut}: expected Truncated, got {other:?}"),
                }
            }
        }
        let resp = Response { status: 0, words: vec![1, 2], cycles: 3, micros: 4 };
        let bytes = encode_response(&resp);
        assert_eq!(response_frame_len(&bytes).unwrap(), bytes.len());
        assert_eq!(response_frame_len(&bytes[..12]).unwrap(), bytes.len());
    }

    /// Back-to-back frames in one buffer decode independently via the
    /// consumed-byte count (pipelined clients).
    #[test]
    fn consumed_supports_pipelining() {
        let a = encode_request(&req_v2());
        let b = encode_request(&req_v1());
        let mut buf = a.clone();
        buf.extend_from_slice(&b);
        let (first, used) = decode_request(&buf).unwrap();
        assert_eq!(first, req_v2());
        let (second, used2) = decode_request(&buf[used..]).unwrap();
        assert_eq!(second, req_v1());
        assert_eq!(used + used2, buf.len());
    }
}
