//! Wire protocol for the tile server: pure frame encode/decode over
//! byte slices, unit-testable without sockets. The wire format is
//! specified in docs/protocol.md; the constants here are the Rust
//! source of truth (the Python client mirrors them in
//! python/pushmem_client.py).
//!
//! Three request generations share one port:
//!
//! * **v1** (the original `pushmem serve <app>` shape): the word after
//!   the magic is the input count, and the target app is implicit (the
//!   server's default app).
//! * **v2**: the word after the magic is the [`VERSION2`] sentinel —
//!   a value no valid v1 input count can reach, since v1 counts are
//!   capped at [`MAX_INPUTS`] — followed by an app-name field, so one
//!   endpoint serves every registered app.
//! * **v3**: the [`VERSION3`] sentinel, then an app-name field (length
//!   0 targets the default app) and a **requested output extent**
//!   (rank + per-dim extents), so a request may carry a whole image of
//!   any size: the server decomposes it onto the fixed compiled design
//!   through the tile planner ([`crate::tile`], docs/tiling.md) and
//!   answers the stitched output.
//!
//! Responses are identical for all generations. Non-OK responses may
//! carry a UTF-8 **diagnostic** packed into the payload words
//! ([`detail_words`] / [`detail_from_words`]) — e.g. the expected vs
//! received word count per input on `STATUS_BAD_REQUEST` — which
//! pre-diagnostic clients simply ignore.
//!
//! All decode functions are *total* over `&[u8]`: on a short buffer
//! they return [`FrameError::Truncated`] carrying the exact number of
//! bytes the frame needs so far, which is what lets the socket layer
//! in [`super::serve`] read frames incrementally without duplicating
//! any parsing logic.

use std::fmt;

/// Frame magic ("PUB\"" — push-memory unified buffer).
pub const MAGIC: u32 = 0x5055_4222;

/// v2 discriminator: occupies the word where v1 puts `n_inputs`.
/// Deliberately far above [`MAX_INPUTS`] so the two generations can
/// never be confused.
pub const VERSION2: u32 = 0xFFFF_0002;

/// v3 discriminator (arbitrary-extent requests), same collision rule
/// as [`VERSION2`].
pub const VERSION3: u32 = 0xFFFF_0003;

/// Admin discriminator: a `STATS` request (docs/observability.md).
/// Same collision rule as [`VERSION2`] — the sentinel occupies the
/// word where v1 puts `n_inputs`, far above [`MAX_INPUTS`]. The frame
/// is exactly 8 bytes (`magic | ADMIN_STATS`); the server answers
/// with an ordinary OK response whose payload words pack a JSON
/// telemetry snapshot ([`stats_words`] / [`detail_from_words`]). The
/// v1–v3 data frames are untouched: old clients never see this
/// sentinel unless they send it.
pub const ADMIN_STATS: u32 = 0xFFFF_0004;

/// Request handled; payload words follow.
pub const STATUS_OK: u32 = 0;
/// v2 app name (or v1 with no default app) did not resolve.
pub const STATUS_UNKNOWN_APP: u32 = 1;
/// Structurally or semantically malformed request (bad magic, input
/// count or word count not matching the app's declared input boxes).
pub const STATUS_BAD_REQUEST: u32 = 2;
/// Simulation failed server-side.
pub const STATUS_INTERNAL: u32 = 3;
/// The server declined admission: every worker is busy and the job
/// queue is full. The packed detail carries a machine-parseable
/// `retry_after_ms` hint ([`encode_busy`] / [`busy_retry_after_ms`])
/// sized from the live queue depth and tile backlog, so clients can
/// back off instead of hanging (docs/serving.md). Like every non-OK
/// status, the server closes the connection after sending it.
pub const STATUS_BUSY: u32 = 4;

/// Caps that keep one malformed length word from allocating
/// gigabytes. Generous: the paper-scale apps use ≤ 5 inputs and
/// ≤ 2^17 words per tensor.
pub const MAX_INPUTS: u32 = 64;
pub const MAX_APP_NAME: u32 = 64;
pub const MAX_WORDS: u32 = 1 << 24;
/// Aggregate cap on payload words in one frame (all inputs summed) —
/// without it a frame could legally declare `MAX_INPUTS × MAX_WORDS`
/// (≈ 4 GiB) and OOM a worker before the app's declared boxes ever
/// reject it.
pub const MAX_FRAME_WORDS: u32 = 1 << 24;
/// Cap on a v3 request's output rank (the registered apps top out at
/// rank 4).
pub const MAX_RANK: u32 = 8;
/// Cap on non-OK responses' packed diagnostic, so the detail channel
/// can never amplify (128 words = 512 bytes of UTF-8).
pub const MAX_DETAIL_BYTES: usize = 512;
/// Cap on a `STATS` reply's packed JSON payload. Separate from
/// [`MAX_DETAIL_BYTES`]: a snapshot with full histograms and the
/// recent-request ring is a few KiB, far above the diagnostic cap,
/// but still must not amplify unboundedly.
pub const MAX_STATS_BYTES: usize = 1 << 20;

/// A decoded request frame. `app` is `None` for v1 frames (implicit
/// default app) and `Some(name)` for v2/v3; `extent` is `Some` only
/// for v3 frames (requested whole-image output extents, outermost
/// dim first). Inputs are row-major word vectors in the app's
/// declared input order — over the declared per-tile boxes for v1/v2,
/// over the whole-image boxes (halo included) for v3.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub app: Option<String>,
    pub extent: Option<Vec<i64>>,
    pub inputs: Vec<Vec<i32>>,
}

/// A borrowed view of a request frame: the same structural decode as
/// [`decode_request`] — identical validation, caps, and consumed-byte
/// count — but input payloads stay in the frame buffer as byte ranges
/// instead of being converted into owned `Vec<i32>`s. The tile path
/// gathers straight from these ranges into per-tile scratch
/// ([`crate::tile::ImageSource`]), so a whole-image payload is copied
/// once (frame → scratch) instead of twice (frame → Vec → scratch).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestView<'a> {
    pub app: Option<&'a str>,
    pub extent: Option<Vec<i64>>,
    pub inputs: Vec<WordsRange>,
}

/// One input payload inside a request frame: `words` little-endian
/// i32 words starting at byte offset `byte_off`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WordsRange {
    pub byte_off: usize,
    pub words: usize,
}

impl WordsRange {
    /// The payload bytes within `frame` (the buffer the view was
    /// decoded from).
    pub fn bytes<'a>(&self, frame: &'a [u8]) -> &'a [u8] {
        &frame[self.byte_off..self.byte_off + 4 * self.words]
    }

    /// Materialize the words — the bridge back to the owned
    /// [`Request`] shape where zero-copy doesn't apply.
    pub fn to_vec(&self, frame: &[u8]) -> Vec<i32> {
        self.bytes(frame)
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

/// Any inbound frame: a data request (v1/v2/v3) or an admin `STATS`
/// query. [`decode_frame`] is the server-side entry point;
/// [`decode_request`] keeps its original signature for data-only
/// callers (and all the frozen byte-level tests).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Frame {
    Request(Request),
    Stats,
}

/// A decoded response frame (shared by v1 and v2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub status: u32,
    pub words: Vec<i32>,
    pub cycles: u64,
    pub micros: u64,
}

/// Structural framing errors. [`FrameError::Truncated`] is
/// recoverable: `need` is the total frame length known so far, so a
/// stream reader can fetch exactly the missing bytes and retry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    Truncated { have: usize, need: usize },
    BadMagic(u32),
    TooLarge { what: &'static str, got: u32, max: u32 },
    BadAppName,
    /// A v3 extent field is structurally invalid (zero rank or a zero
    /// per-dim extent) — size overruns are [`FrameError::TooLarge`].
    BadExtent { what: &'static str, got: u32 },
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Truncated { have, need } => {
                write!(f, "truncated frame: have {have} bytes, need {need}")
            }
            FrameError::BadMagic(m) => write!(f, "bad magic {m:#010x}"),
            FrameError::TooLarge { what, got, max } => {
                write!(f, "{what} {got} exceeds protocol cap {max}")
            }
            FrameError::BadAppName => write!(f, "app name is not valid UTF-8"),
            FrameError::BadExtent { what, got } => {
                write!(f, "output extent {what} {got} is invalid (must be >= 1)")
            }
        }
    }
}

impl std::error::Error for FrameError {}

/// Byte-slice cursor; every read reports the exact prefix length the
/// frame needs when the buffer is short.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        let need = self.pos + n;
        if need > self.buf.len() {
            return Err(FrameError::Truncated { have: self.buf.len(), need });
        }
        let s = &self.buf[self.pos..need];
        self.pos = need;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    fn words(&mut self, n: usize) -> Result<Vec<i32>, FrameError> {
        let b = self.take(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Skip a v2/v3 app-name field (`name_len | bytes`), enforcing the
/// length cap — the non-allocating half shared by [`read_name`] and
/// the frame-length pre-scan, so the cap can never diverge between
/// them. Returns the name bytes.
fn skip_name<'a>(c: &mut Cur<'a>) -> Result<&'a [u8], FrameError> {
    let name_len = c.u32()?;
    if name_len > MAX_APP_NAME {
        return Err(FrameError::TooLarge {
            what: "app name length",
            got: name_len,
            max: MAX_APP_NAME,
        });
    }
    c.take(name_len as usize)
}

/// Read a v2/v3 app-name field, enforcing the length cap and UTF-8.
fn read_name(c: &mut Cur<'_>) -> Result<String, FrameError> {
    Ok(std::str::from_utf8(skip_name(c)?)
        .map_err(|_| FrameError::BadAppName)?
        .to_string())
}

/// Read a v3 extent field (`rank | extent[rank]`). The product of the
/// extents is the response's output word count, so it is capped at
/// [`MAX_WORDS`] from the header alone — a hostile extent cannot make
/// the server plan (or allocate) a gigaword image.
fn read_extent(c: &mut Cur<'_>) -> Result<Vec<i64>, FrameError> {
    let rank = c.u32()?;
    if rank == 0 {
        return Err(FrameError::BadExtent { what: "rank", got: 0 });
    }
    if rank > MAX_RANK {
        return Err(FrameError::TooLarge { what: "extent rank", got: rank, max: MAX_RANK });
    }
    let mut extent = Vec::with_capacity(rank as usize);
    let mut words: u64 = 1;
    for _ in 0..rank {
        let e = c.u32()?;
        if e == 0 {
            return Err(FrameError::BadExtent { what: "dim extent", got: 0 });
        }
        words = words.saturating_mul(e as u64);
        if words > MAX_WORDS as u64 {
            return Err(FrameError::TooLarge {
                what: "output extent words",
                got: words.min(u32::MAX as u64) as u32,
                max: MAX_WORDS,
            });
        }
        extent.push(e as i64);
    }
    Ok(extent)
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_words(out: &mut Vec<u8>, words: &[i32]) {
    put_u32(out, words.len() as u32);
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

/// Encode a v1 request: `magic | n_inputs | (word_count | words)*`.
pub fn encode_request_v1(inputs: &[&[i32]]) -> Vec<u8> {
    let total: usize = inputs.iter().map(|w| w.len()).sum();
    let mut out = Vec::with_capacity(8 + 4 * inputs.len() + 4 * total);
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, inputs.len() as u32);
    for words in inputs {
        put_words(&mut out, words);
    }
    out
}

/// Encode a v2 request:
/// `magic | VERSION2 | name_len | name bytes | n_inputs | (word_count | words)*`.
pub fn encode_request_v2(app: &str, inputs: &[&[i32]]) -> Vec<u8> {
    let total: usize = inputs.iter().map(|w| w.len()).sum();
    let mut out = Vec::with_capacity(16 + app.len() + 4 * inputs.len() + 4 * total);
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, VERSION2);
    put_u32(&mut out, app.len() as u32);
    out.extend_from_slice(app.as_bytes());
    put_u32(&mut out, inputs.len() as u32);
    for words in inputs {
        put_words(&mut out, words);
    }
    out
}

/// Encode a v3 request:
/// `magic | VERSION3 | name_len | name | rank | extent[rank] | n_inputs | (word_count | words)*`.
/// `app = None` encodes a zero-length name and targets the server's
/// default app; inputs are whole-image tensors over the boxes the
/// tile planner derives for `extent` (docs/tiling.md).
///
/// Panics on an extent outside `1..=u32::MAX` per dim — the wire
/// field is u32, and silently truncating would frame a *different*
/// extent (the mirrored Python encoder rejects these too).
pub fn encode_request_v3(app: Option<&str>, extent: &[i64], inputs: &[&[i32]]) -> Vec<u8> {
    for &e in extent {
        assert!(
            e >= 1 && e <= u32::MAX as i64,
            "extent dim {e} outside the encodable range 1..=u32::MAX"
        );
    }
    let name = app.unwrap_or("");
    let total: usize = inputs.iter().map(|w| w.len()).sum();
    let mut out =
        Vec::with_capacity(24 + name.len() + 4 * (extent.len() + inputs.len()) + 4 * total);
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, VERSION3);
    put_u32(&mut out, name.len() as u32);
    out.extend_from_slice(name.as_bytes());
    put_u32(&mut out, extent.len() as u32);
    for &e in extent {
        put_u32(&mut out, e as u32);
    }
    put_u32(&mut out, inputs.len() as u32);
    for words in inputs {
        put_words(&mut out, words);
    }
    out
}

/// Encode an admin `STATS` request: `magic | ADMIN_STATS`, 8 bytes.
pub fn encode_stats_request() -> Vec<u8> {
    let mut out = Vec::with_capacity(8);
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, ADMIN_STATS);
    out
}

/// Encode a [`Request`], choosing framing by field presence: an
/// extent forces v3, else an app name selects v2, else v1.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let refs: Vec<&[i32]> = req.inputs.iter().map(|v| v.as_slice()).collect();
    match (&req.extent, &req.app) {
        (Some(extent), app) => encode_request_v3(app.as_deref(), extent, &refs),
        (None, Some(name)) => encode_request_v2(name, &refs),
        (None, None) => encode_request_v1(&refs),
    }
}

/// Decode one request frame from the front of `buf`; returns the
/// request and the number of bytes consumed.
pub fn decode_request(buf: &[u8]) -> Result<(Request, usize), FrameError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let word2 = c.u32()?;
    let (app, extent, n_inputs) = if word2 == VERSION2 {
        (Some(read_name(&mut c)?), None, c.u32()?)
    } else if word2 == VERSION3 {
        let name = read_name(&mut c)?;
        let app = (!name.is_empty()).then_some(name);
        (app, Some(read_extent(&mut c)?), c.u32()?)
    } else {
        (None, None, word2)
    };
    if n_inputs > MAX_INPUTS {
        return Err(FrameError::TooLarge { what: "input count", got: n_inputs, max: MAX_INPUTS });
    }
    let mut inputs = Vec::with_capacity(n_inputs as usize);
    let mut total: u64 = 0;
    for _ in 0..n_inputs {
        let wc = c.u32()?;
        if wc > MAX_WORDS {
            return Err(FrameError::TooLarge { what: "input word count", got: wc, max: MAX_WORDS });
        }
        total += wc as u64;
        if total > MAX_FRAME_WORDS as u64 {
            return Err(FrameError::TooLarge { what: "frame word total", got: total.min(u32::MAX as u64) as u32, max: MAX_FRAME_WORDS });
        }
        inputs.push(c.words(wc as usize)?);
    }
    Ok((Request { app, extent, inputs }, c.pos))
}

/// Decode one request frame from the front of `buf` without copying
/// input payloads: the borrowing counterpart of [`decode_request`].
/// Identical header validation and caps; each input is returned as a
/// [`WordsRange`] into `buf`. Pinned against [`decode_request`] by
/// `view_agrees_with_owned_decode` below.
pub fn decode_request_view(buf: &[u8]) -> Result<(RequestView<'_>, usize), FrameError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let word2 = c.u32()?;
    let (app, extent, n_inputs) = if word2 == VERSION2 {
        let name = std::str::from_utf8(skip_name(&mut c)?).map_err(|_| FrameError::BadAppName)?;
        (Some(name), None, c.u32()?)
    } else if word2 == VERSION3 {
        let name = std::str::from_utf8(skip_name(&mut c)?).map_err(|_| FrameError::BadAppName)?;
        let app = (!name.is_empty()).then_some(name);
        (app, Some(read_extent(&mut c)?), c.u32()?)
    } else {
        (None, None, word2)
    };
    if n_inputs > MAX_INPUTS {
        return Err(FrameError::TooLarge { what: "input count", got: n_inputs, max: MAX_INPUTS });
    }
    let mut inputs = Vec::with_capacity(n_inputs as usize);
    let mut total: u64 = 0;
    for _ in 0..n_inputs {
        let wc = c.u32()?;
        if wc > MAX_WORDS {
            return Err(FrameError::TooLarge { what: "input word count", got: wc, max: MAX_WORDS });
        }
        total += wc as u64;
        if total > MAX_FRAME_WORDS as u64 {
            return Err(FrameError::TooLarge { what: "frame word total", got: total.min(u32::MAX as u64) as u32, max: MAX_FRAME_WORDS });
        }
        let byte_off = c.pos;
        c.take(wc as usize * 4)?;
        inputs.push(WordsRange { byte_off, words: wc as usize });
    }
    Ok((RequestView { app, extent, inputs }, c.pos))
}

/// Decode one inbound frame — data request or admin `STATS` — from
/// the front of `buf`; returns the frame and the bytes consumed.
/// Same totality contract as [`decode_request`]: short buffers yield
/// [`FrameError::Truncated`].
pub fn decode_frame(buf: &[u8]) -> Result<(Frame, usize), FrameError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    if c.u32()? == ADMIN_STATS {
        return Ok((Frame::Stats, 8));
    }
    let (req, used) = decode_request(buf)?;
    Ok((Frame::Request(req), used))
}

/// Total byte length of the request frame at the front of `buf`,
/// computed from the length fields alone — no payload allocation or
/// word conversion. Returns `Truncated { need }` while more bytes are
/// required to know. Stream readers use this to size their reads so
/// [`decode_request`] runs exactly once per frame (re-decoding after
/// every partial read would re-convert all completed inputs, an
/// amplification a hostile client gets for free).
pub fn request_frame_len(buf: &[u8]) -> Result<usize, FrameError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let word2 = c.u32()?;
    if word2 == ADMIN_STATS {
        return Ok(8);
    }
    let n_inputs = if word2 == VERSION2 {
        skip_name(&mut c)?;
        c.u32()?
    } else if word2 == VERSION3 {
        skip_name(&mut c)?;
        read_extent(&mut c)?;
        c.u32()?
    } else {
        word2
    };
    if n_inputs > MAX_INPUTS {
        return Err(FrameError::TooLarge { what: "input count", got: n_inputs, max: MAX_INPUTS });
    }
    let mut total: u64 = 0;
    for _ in 0..n_inputs {
        let wc = c.u32()?;
        if wc > MAX_WORDS {
            return Err(FrameError::TooLarge { what: "input word count", got: wc, max: MAX_WORDS });
        }
        total += wc as u64;
        if total > MAX_FRAME_WORDS as u64 {
            return Err(FrameError::TooLarge { what: "frame word total", got: total.min(u32::MAX as u64) as u32, max: MAX_FRAME_WORDS });
        }
        c.take(wc as usize * 4)?;
    }
    Ok(c.pos)
}

/// Total byte length of the response frame at the front of `buf`
/// (same contract as [`request_frame_len`]).
pub fn response_frame_len(buf: &[u8]) -> Result<usize, FrameError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    c.u32()?; // status
    let wc = c.u32()?;
    if wc > MAX_WORDS {
        return Err(FrameError::TooLarge { what: "response word count", got: wc, max: MAX_WORDS });
    }
    Ok(28 + 4 * wc as usize)
}

/// Encode a response frame:
/// `magic | status | word_count | words | cycles u64 | micros u64`.
pub fn encode_response(r: &Response) -> Vec<u8> {
    let mut out = Vec::with_capacity(28 + 4 * r.words.len());
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, r.status);
    put_words(&mut out, &r.words);
    out.extend_from_slice(&r.cycles.to_le_bytes());
    out.extend_from_slice(&r.micros.to_le_bytes());
    out
}

/// An error response carries no payload words and zeroed timings.
pub fn encode_error(status: u32) -> Vec<u8> {
    encode_response(&Response { status, words: Vec::new(), cycles: 0, micros: 0 })
}

/// Pack a UTF-8 diagnostic into response payload words (4 bytes per
/// word, little-endian, the last word zero-padded), truncated to
/// [`MAX_DETAIL_BYTES`]. Non-OK responses use this channel to say
/// *what* was wrong — e.g. the expected vs received word count per
/// input on `STATUS_BAD_REQUEST` — instead of a bare status word.
pub fn detail_words(msg: &str) -> Vec<i32> {
    pack_utf8_words(msg, MAX_DETAIL_BYTES)
}

/// Pack a `STATS` reply's JSON snapshot into response payload words —
/// same packing as [`detail_words`] (so [`detail_from_words`] decodes
/// both) under the larger [`MAX_STATS_BYTES`] cap.
pub fn stats_words(json: &str) -> Vec<i32> {
    pack_utf8_words(json, MAX_STATS_BYTES)
}

/// The shared UTF-8-to-words packer behind [`detail_words`] and
/// [`stats_words`]: one cap parameter, one packing, so the two
/// channels can never diverge in layout.
fn pack_utf8_words(msg: &str, cap: usize) -> Vec<i32> {
    let bytes = &msg.as_bytes()[..msg.len().min(cap)];
    bytes
        .chunks(4)
        .map(|c| {
            let mut b = [0u8; 4];
            b[..c.len()].copy_from_slice(c);
            i32::from_le_bytes(b)
        })
        .collect()
}

/// Recover a [`detail_words`] diagnostic from an error frame's
/// payload (trailing padding stripped; invalid UTF-8 — possible only
/// on a truncation boundary — is replaced, never an error).
pub fn detail_from_words(words: &[i32]) -> String {
    let mut bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
    while bytes.last() == Some(&0) {
        bytes.pop();
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

/// An error response with a packed diagnostic (see [`detail_words`]).
pub fn encode_error_detail(status: u32, detail: &str) -> Vec<u8> {
    encode_response(&Response {
        status,
        words: detail_words(detail),
        cycles: 0,
        micros: 0,
    })
}

/// Encode a [`STATUS_BUSY`] admission rejection. The retry hint rides
/// in the packed-detail words in the fixed machine-parseable form
/// `busy: retry_after_ms=<N>` ([`busy_retry_after_ms`] is the
/// matching parser; the Python client mirrors it in `ServerBusy`).
pub fn encode_busy(retry_after_ms: u64) -> Vec<u8> {
    encode_error_detail(STATUS_BUSY, &format!("busy: retry_after_ms={retry_after_ms}"))
}

/// Parse the `retry_after_ms` hint out of a [`STATUS_BUSY`] detail
/// string. `None` if the marker is absent or malformed — a client
/// should then fall back to its own backoff.
pub fn busy_retry_after_ms(detail: &str) -> Option<u64> {
    let rest = detail.split("retry_after_ms=").nth(1)?;
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// Decode one response frame from the front of `buf`; returns the
/// response and the number of bytes consumed.
pub fn decode_response(buf: &[u8]) -> Result<(Response, usize), FrameError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let status = c.u32()?;
    let wc = c.u32()?;
    if wc > MAX_WORDS {
        return Err(FrameError::TooLarge { what: "response word count", got: wc, max: MAX_WORDS });
    }
    let words = c.words(wc as usize)?;
    let cycles = c.u64()?;
    let micros = c.u64()?;
    Ok((Response { status, words, cycles, micros }, c.pos))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_v1() -> Request {
        Request { app: None, extent: None, inputs: vec![vec![1, -2, 3], vec![0; 5]] }
    }

    fn req_v2() -> Request {
        Request {
            app: Some("gaussian".to_string()),
            extent: None,
            inputs: vec![vec![i32::MIN, -1, 0, 1, i32::MAX]],
        }
    }

    fn req_v3() -> Request {
        Request {
            app: Some("gaussian".to_string()),
            extent: Some(vec![250, 131]),
            inputs: vec![vec![9, -8, 7]],
        }
    }

    #[test]
    fn sentinel_cannot_collide_with_v1_counts() {
        assert!(VERSION2 > MAX_INPUTS);
        assert!(VERSION3 > MAX_INPUTS);
        assert!(ADMIN_STATS > MAX_INPUTS);
        assert_ne!(VERSION2, VERSION3);
        assert_ne!(ADMIN_STATS, VERSION2);
        assert_ne!(ADMIN_STATS, VERSION3);
    }

    /// The admin STATS frame is exactly 8 bytes, pinned as literals
    /// (mirroring python/tests/test_protocol.py and docs/protocol.md).
    #[test]
    fn stats_frame_golden_bytes() {
        let frame = encode_stats_request();
        assert_eq!(frame, [0x22, 0x42, 0x55, 0x50, 0x04, 0x00, 0xFF, 0xFF]);
        assert_eq!(request_frame_len(&frame).unwrap(), 8);
        let (decoded, used) = decode_frame(&frame).unwrap();
        assert_eq!(decoded, Frame::Stats);
        assert_eq!(used, 8);
        // Every strict prefix is recoverable Truncated, like any
        // other frame.
        for cut in 0..frame.len() {
            match decode_frame(&frame[..cut]) {
                Err(FrameError::Truncated { have, need }) => {
                    assert_eq!(have, cut);
                    assert!(need > cut && need <= frame.len(), "cut {cut}");
                }
                other => panic!("cut {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    /// decode_frame passes data frames through to decode_request
    /// unchanged, including the consumed-byte count for pipelining.
    #[test]
    fn decode_frame_passes_data_requests_through() {
        for req in [req_v1(), req_v2(), req_v3()] {
            let bytes = encode_request(&req);
            let (frame, used) = decode_frame(&bytes).unwrap();
            assert_eq!(frame, Frame::Request(req));
            assert_eq!(used, bytes.len());
        }
        // A STATS frame followed by a data frame in one buffer.
        let mut buf = encode_stats_request();
        let data = encode_request(&req_v1());
        buf.extend_from_slice(&data);
        let (first, used) = decode_frame(&buf).unwrap();
        assert_eq!(first, Frame::Stats);
        let (second, used2) = decode_frame(&buf[used..]).unwrap();
        assert_eq!(second, Frame::Request(req_v1()));
        assert_eq!(used + used2, buf.len());
    }

    /// Stats payload packing: same layout as detail_words (one
    /// decoder serves both), but under the larger cap.
    #[test]
    fn stats_words_round_trip_and_cap() {
        let json = "{\"counters\":{\"requests_total\":7}}";
        let words = stats_words(json);
        assert_eq!(detail_from_words(&words), json);
        assert_eq!(words, detail_words(json)); // same packing below both caps
        // Beyond the detail cap but within the stats cap: intact.
        let big = "y".repeat(4 * MAX_DETAIL_BYTES);
        assert_eq!(detail_from_words(&stats_words(&big)), big);
        // The stats cap truncates instead of amplifying.
        let huge = "z".repeat(MAX_STATS_BYTES + 9);
        let words = stats_words(&huge);
        assert_eq!(words.len() * 4, MAX_STATS_BYTES);
        assert_eq!(detail_from_words(&words).len(), MAX_STATS_BYTES);
    }

    /// The v1/v2 wire bytes are **frozen**: any refactor that changes
    /// them breaks deployed clients. Pinned as literal byte vectors
    /// (mirroring python/tests/test_protocol.py and docs/protocol.md).
    #[test]
    fn v1_v2_frames_are_byte_frozen() {
        let v1 = encode_request_v1(&[&[1, -2, 3]]);
        let mut expect = Vec::new();
        for w in [MAGIC, 1, 3, 1i32 as u32, -2i32 as u32, 3] {
            expect.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(v1, expect);

        let v2 = encode_request_v2("gaussian", &[&[1, -2, 3]]);
        let mut expect = Vec::new();
        for w in [MAGIC, VERSION2, 8] {
            expect.extend_from_slice(&w.to_le_bytes());
        }
        expect.extend_from_slice(b"gaussian");
        for w in [1u32, 3, 1i32 as u32, -2i32 as u32, 3] {
            expect.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(v2, expect);
    }

    /// The v3 layout as specified in docs/protocol.md, pinned.
    #[test]
    fn v3_frame_golden_bytes() {
        let frame = encode_request_v3(Some("gaussian"), &[250, 131], &[&[9, -8, 7]]);
        let mut expect = Vec::new();
        for w in [MAGIC, VERSION3, 8] {
            expect.extend_from_slice(&w.to_le_bytes());
        }
        expect.extend_from_slice(b"gaussian");
        for w in [2u32, 250, 131, 1, 3, 9, (-8i32) as u32, 7] {
            expect.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(frame, expect);
    }

    #[test]
    fn v1_request_round_trip() {
        let req = req_v1();
        let bytes = encode_request(&req);
        let (back, used) = decode_request(&bytes).unwrap();
        assert_eq!(back, req);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn v2_request_round_trip() {
        let req = req_v2();
        let bytes = encode_request(&req);
        let (back, used) = decode_request(&bytes).unwrap();
        assert_eq!(back, req);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn v2_empty_inputs_round_trip() {
        let req = Request { app: Some("x".into()), extent: None, inputs: vec![] };
        let (back, _) = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn v3_request_round_trip() {
        let req = req_v3();
        let bytes = encode_request(&req);
        let (back, used) = decode_request(&bytes).unwrap();
        assert_eq!(back, req);
        assert_eq!(used, bytes.len());
    }

    /// A zero-length v3 name decodes as the implicit default app —
    /// the single-app `pushmem serve <app>` shape.
    #[test]
    fn v3_default_app_round_trip() {
        let req = Request { app: None, extent: Some(vec![33, 20]), inputs: vec![vec![5]] };
        let bytes = encode_request(&req);
        let (back, used) = decode_request(&bytes).unwrap();
        assert_eq!(back, req);
        assert_eq!(used, bytes.len());
    }

    /// Every strict prefix must report Truncated with a `need` that
    /// (a) exceeds the prefix and (b) never overshoots the full frame
    /// — the invariant the socket reader in serve.rs relies on.
    #[test]
    fn request_truncation_sweep() {
        for req in [req_v1(), req_v2(), req_v3()] {
            let bytes = encode_request(&req);
            for cut in 0..bytes.len() {
                match decode_request(&bytes[..cut]) {
                    Err(FrameError::Truncated { have, need }) => {
                        assert_eq!(have, cut);
                        assert!(need > cut, "need {need} at cut {cut}");
                        assert!(need <= bytes.len(), "overshoot {need} at cut {cut}");
                    }
                    other => panic!("cut {cut}: expected Truncated, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn response_truncation_sweep() {
        let resp = Response { status: 0, words: vec![7, 8, 9], cycles: 42, micros: 17 };
        let bytes = encode_response(&resp);
        for cut in 0..bytes.len() {
            match decode_response(&bytes[..cut]) {
                Err(FrameError::Truncated { need, .. }) => {
                    assert!(need > cut && need <= bytes.len());
                }
                other => panic!("cut {cut}: expected Truncated, got {other:?}"),
            }
        }
        let (back, used) = decode_response(&bytes).unwrap();
        assert_eq!(back, resp);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode_request(&req_v1());
        bytes[0] ^= 0xFF;
        let got = decode_request(&bytes).unwrap_err();
        assert!(matches!(got, FrameError::BadMagic(_)));
    }

    #[test]
    fn oversized_counts_rejected() {
        // Input count above the cap (and not the v2 sentinel).
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, MAX_INPUTS + 1);
        assert!(matches!(
            decode_request(&out).unwrap_err(),
            FrameError::TooLarge { what: "input count", .. }
        ));

        // Word count above the cap.
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, 1);
        super::put_u32(&mut out, MAX_WORDS + 1);
        assert!(matches!(
            decode_request(&out).unwrap_err(),
            FrameError::TooLarge { what: "input word count", .. }
        ));

        // Aggregate words above the cap even though each input is
        // individually legal — caught from the header alone, before
        // any payload byte would need buffering.
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, 3);
        super::put_u32(&mut out, MAX_WORDS / 2);
        out.extend_from_slice(&vec![0u8; (MAX_WORDS / 2) as usize * 4]);
        super::put_u32(&mut out, MAX_WORDS / 2);
        out.extend_from_slice(&vec![0u8; (MAX_WORDS / 2) as usize * 4]);
        super::put_u32(&mut out, MAX_WORDS / 2);
        assert!(matches!(
            request_frame_len(&out).unwrap_err(),
            FrameError::TooLarge { what: "frame word total", .. }
        ));
        assert!(matches!(
            decode_request(&out).unwrap_err(),
            FrameError::TooLarge { what: "frame word total", .. }
        ));

        // App name above the cap.
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, VERSION2);
        super::put_u32(&mut out, MAX_APP_NAME + 1);
        assert!(matches!(
            decode_request(&out).unwrap_err(),
            FrameError::TooLarge { what: "app name length", .. }
        ));
    }

    /// v3 extent fields: rank and per-dim extents are validated from
    /// the header alone, including the output-word product cap.
    #[test]
    fn v3_extent_validation() {
        let v3_header = |rank: u32, extents: &[u32]| {
            let mut out = Vec::new();
            super::put_u32(&mut out, MAGIC);
            super::put_u32(&mut out, VERSION3);
            super::put_u32(&mut out, 0); // empty name -> default app
            super::put_u32(&mut out, rank);
            for &e in extents {
                super::put_u32(&mut out, e);
            }
            out
        };
        assert!(matches!(
            decode_request(&v3_header(0, &[])).unwrap_err(),
            FrameError::BadExtent { what: "rank", .. }
        ));
        assert!(matches!(
            decode_request(&v3_header(MAX_RANK + 1, &[1; 9])).unwrap_err(),
            FrameError::TooLarge { what: "extent rank", .. }
        ));
        assert!(matches!(
            decode_request(&v3_header(2, &[4, 0])).unwrap_err(),
            FrameError::BadExtent { what: "dim extent", .. }
        ));
        // Product cap: 2^13 x 2^13 = 2^26 output words > MAX_WORDS.
        let too_big = v3_header(2, &[1 << 13, 1 << 13]);
        assert!(matches!(
            decode_request(&too_big).unwrap_err(),
            FrameError::TooLarge { what: "output extent words", .. }
        ));
        assert!(matches!(
            request_frame_len(&too_big).unwrap_err(),
            FrameError::TooLarge { what: "output extent words", .. }
        ));
    }

    /// The exact boundary values are part of the wire contract
    /// (mirrored in python/tests/test_protocol.py): a 1x1 whole-image
    /// request, a rank of exactly [`MAX_RANK`], and an output product
    /// of exactly [`MAX_WORDS`] must all decode; one past each must
    /// not (the one-past-rank case is in [`v3_extent_validation`]).
    #[test]
    fn v3_boundary_extents_decode() {
        // The smallest legal whole image: 1x1.
        let req = Request {
            app: Some("gaussian".into()),
            extent: Some(vec![1, 1]),
            inputs: vec![vec![42]],
        };
        let bytes = encode_request(&req);
        let (back, used) = decode_request(&bytes).unwrap();
        assert_eq!(back, req);
        assert_eq!(used, bytes.len());

        // Rank exactly MAX_RANK decodes.
        let req = Request { app: None, extent: Some(vec![1; MAX_RANK as usize]), inputs: vec![] };
        let (back, _) = decode_request(&encode_request(&req)).unwrap();
        assert_eq!(back, req);

        // Output product exactly MAX_WORDS (2^12 x 2^12 = 2^24)
        // decodes; the next extent up does not.
        let at_cap = Request { app: None, extent: Some(vec![1 << 12, 1 << 12]), inputs: vec![] };
        let (back, _) = decode_request(&encode_request(&at_cap)).unwrap();
        assert_eq!(back, at_cap);
        let over = Request { app: None, extent: Some(vec![1 << 12, (1 << 12) + 1]), inputs: vec![] };
        assert!(matches!(
            decode_request(&encode_request(&over)).unwrap_err(),
            FrameError::TooLarge { what: "output extent words", .. }
        ));
    }

    /// Diagnostic payloads: pack, round-trip, cap, and the frame
    /// shape old clients see (non-empty words on a non-OK status).
    #[test]
    fn error_detail_round_trip() {
        let msg = "input gradient: got 100 words, expected 4096";
        let frame = encode_error_detail(STATUS_BAD_REQUEST, msg);
        let (resp, _) = decode_response(&frame).unwrap();
        assert_eq!(resp.status, STATUS_BAD_REQUEST);
        assert_eq!(detail_from_words(&resp.words), msg);
        assert_eq!((resp.cycles, resp.micros), (0, 0));

        // Length not a multiple of 4 pads the last word with zeros.
        assert_eq!(detail_from_words(&detail_words("abcde")), "abcde");
        // The cap truncates instead of amplifying.
        let long = "x".repeat(4 * MAX_DETAIL_BYTES);
        let words = detail_words(&long);
        assert_eq!(words.len() * 4, MAX_DETAIL_BYTES);
        assert_eq!(detail_from_words(&words).len(), MAX_DETAIL_BYTES);
        // Empty detail is the legacy 28-byte error frame.
        assert_eq!(encode_error_detail(STATUS_INTERNAL, ""), encode_error(STATUS_INTERNAL));
    }

    #[test]
    fn non_utf8_app_name_rejected() {
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, VERSION2);
        super::put_u32(&mut out, 2);
        out.extend_from_slice(&[0xFF, 0xFE]);
        super::put_u32(&mut out, 0);
        assert_eq!(decode_request(&out).unwrap_err(), FrameError::BadAppName);
    }

    #[test]
    fn error_frame_shape() {
        let bytes = encode_error(STATUS_UNKNOWN_APP);
        let (resp, used) = decode_response(&bytes).unwrap();
        assert_eq!(used, 28);
        assert_eq!(resp.status, STATUS_UNKNOWN_APP);
        assert!(resp.words.is_empty());
        assert_eq!((resp.cycles, resp.micros), (0, 0));
    }

    /// The frame-length pre-scan must agree exactly with the decoder
    /// (full length on a complete frame, recoverable Truncated on any
    /// strict prefix, never overshooting the frame).
    #[test]
    fn frame_len_matches_decode() {
        for req in [req_v1(), req_v2(), req_v3()] {
            let bytes = encode_request(&req);
            assert_eq!(request_frame_len(&bytes).unwrap(), bytes.len());
            for cut in 0..bytes.len() {
                match request_frame_len(&bytes[..cut]) {
                    Err(FrameError::Truncated { need, .. }) => {
                        assert!(need > cut && need <= bytes.len(), "cut {cut}");
                    }
                    other => panic!("cut {cut}: expected Truncated, got {other:?}"),
                }
            }
        }
        let resp = Response { status: 0, words: vec![1, 2], cycles: 3, micros: 4 };
        let bytes = encode_response(&resp);
        assert_eq!(response_frame_len(&bytes).unwrap(), bytes.len());
        assert_eq!(response_frame_len(&bytes[..12]).unwrap(), bytes.len());
    }

    /// The borrowing decode must agree with the owned decode on every
    /// generation: same app/extent, same consumed count, and ranges
    /// that materialize to the same words. Truncation behaviour is
    /// identical too.
    #[test]
    fn view_agrees_with_owned_decode() {
        for req in [req_v1(), req_v2(), req_v3()] {
            let bytes = encode_request(&req);
            let (owned, used) = decode_request(&bytes).unwrap();
            let (view, vused) = decode_request_view(&bytes).unwrap();
            assert_eq!(vused, used);
            assert_eq!(view.app.map(str::to_string), owned.app);
            assert_eq!(view.extent, owned.extent);
            assert_eq!(view.inputs.len(), owned.inputs.len());
            for (r, w) in view.inputs.iter().zip(&owned.inputs) {
                assert_eq!(&r.to_vec(&bytes), w);
                assert_eq!(r.bytes(&bytes).len(), 4 * w.len());
            }
            for cut in 0..bytes.len() {
                assert_eq!(
                    decode_request_view(&bytes[..cut]).unwrap_err(),
                    decode_request(&bytes[..cut]).unwrap_err(),
                    "cut {cut}"
                );
            }
        }
        // Cap violations surface identically.
        let mut out = Vec::new();
        super::put_u32(&mut out, MAGIC);
        super::put_u32(&mut out, 1);
        super::put_u32(&mut out, MAX_WORDS + 1);
        assert_eq!(decode_request_view(&out).unwrap_err(), decode_request(&out).unwrap_err());
    }

    /// STATUS_BUSY admission rejections: frame shape, the packed
    /// retry hint round-trip, and the parser's failure modes.
    #[test]
    fn busy_frame_round_trip() {
        let frame = encode_busy(250);
        let (resp, used) = decode_response(&frame).unwrap();
        assert_eq!(used, frame.len());
        assert_eq!(resp.status, STATUS_BUSY);
        assert_eq!((resp.cycles, resp.micros), (0, 0));
        let detail = detail_from_words(&resp.words);
        assert_eq!(detail, "busy: retry_after_ms=250");
        assert_eq!(busy_retry_after_ms(&detail), Some(250));

        assert_eq!(busy_retry_after_ms("busy: retry_after_ms=0"), Some(0));
        assert_eq!(busy_retry_after_ms("retry_after_ms=17 trailing"), Some(17));
        assert_eq!(busy_retry_after_ms("busy"), None);
        assert_eq!(busy_retry_after_ms("retry_after_ms="), None);
        assert_eq!(busy_retry_after_ms("retry_after_ms=x9"), None);
    }

    /// Back-to-back frames in one buffer decode independently via the
    /// consumed-byte count (pipelined clients).
    #[test]
    fn consumed_supports_pipelining() {
        let a = encode_request(&req_v2());
        let b = encode_request(&req_v1());
        let c = encode_request(&req_v3());
        let mut buf = a.clone();
        buf.extend_from_slice(&b);
        buf.extend_from_slice(&c);
        let (first, used) = decode_request(&buf).unwrap();
        assert_eq!(first, req_v2());
        let (second, used2) = decode_request(&buf[used..]).unwrap();
        assert_eq!(second, req_v1());
        let (third, used3) = decode_request(&buf[used + used2..]).unwrap();
        assert_eq!(third, req_v3());
        assert_eq!(used + used2 + used3, buf.len());
    }
}
