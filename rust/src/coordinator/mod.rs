//! The Layer-3 coordinator: process lifecycle, tile streaming through
//! the global buffer, validation against the XLA golden models, report
//! generation, and the request-serving subsystem (wire framing in
//! [`protocol`], lazy compile cache in
//! [`driver::CompiledRegistry`], load-adaptive variant routing in
//! [`route`], bounded worker-pool server in [`serve`] — see DESIGN.md
//! §2, docs/protocol.md, and docs/routing.md).
//!
//! Python never appears here — the HLO artifacts were lowered once at
//! build time (`make artifacts`) and are loaded through the PJRT C API
//! ([`crate::runtime`]).

pub mod driver;
pub mod globalbuf;
pub mod protocol;
pub mod report;
pub mod route;
pub mod serve;
pub mod validate;

pub use driver::{
    apply_tuned_schedule, compile, compile_maybe_tuned, compile_variants, gen_inputs, Compiled,
    CompiledRegistry, Variant, VariantSet,
};
pub use route::{LoadSignals, RoutePolicy};
pub use globalbuf::GlobalBuffer;
pub use report::{
    report_app, report_app_with, sequential_comparison, AppReport, SequentialComparison,
};
pub use validate::{
    cross_check, validate, validate_with, CrossCheck, EngineDivergence, Validation,
};
