//! The Layer-3 coordinator: process lifecycle, tile streaming through
//! the global buffer, validation against the XLA golden models, report
//! generation, and the request-serving subsystem (wire framing in
//! [`protocol`], lazy compile cache in
//! [`driver::CompiledRegistry`], bounded worker-pool server in
//! [`serve`] — see DESIGN.md §2 and docs/protocol.md).
//!
//! Python never appears here — the HLO artifacts were lowered once at
//! build time (`make artifacts`) and are loaded through the PJRT C API
//! ([`crate::runtime`]).

pub mod driver;
pub mod globalbuf;
pub mod protocol;
pub mod report;
pub mod serve;
pub mod validate;

pub use driver::{
    apply_tuned_schedule, compile, compile_maybe_tuned, gen_inputs, Compiled, CompiledRegistry,
};
pub use globalbuf::GlobalBuffer;
pub use report::{
    report_app, report_app_with, sequential_comparison, AppReport, SequentialComparison,
};
pub use validate::{
    cross_check, validate, validate_with, CrossCheck, EngineDivergence, Validation,
};
