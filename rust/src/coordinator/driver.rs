//! Compilation driver: run the full pipeline of Fig 1 and bundle every
//! intermediate for inspection, simulation, and reporting.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::cgra::{place, route, CgraSpec, Placement, RoutingResult, SimPlan, SimRun};
use crate::exec::{Engine, EngineRun, ExecPlan, ExecRun};
use crate::extraction::extract;
use crate::halide::{lower, LoweredPipeline, Program};
use crate::mapping::{map_design, MappedDesign};
use crate::sched::{self, PipelineSchedule};
use crate::tensor::Tensor;
use crate::tile::TilePlan;
use crate::ub::UbGraph;

/// Everything the compiler produced for one program.
pub struct Compiled {
    pub program: Program,
    pub lp: LoweredPipeline,
    pub schedule: PipelineSchedule,
    pub graph: UbGraph,
    pub design: MappedDesign,
    /// `None` when the design does not fit the array (the paper's
    /// camera footnote) — simulation still works; placement-derived
    /// numbers are reported as unavailable.
    pub placement: Option<Placement>,
    pub routing: Option<RoutingResult>,
    /// Lazily-built simulation plan (interned wires, hardware
    /// templates, event schedules — docs/simulator.md). Private:
    /// everything simulation-shaped goes through [`Compiled::plan`],
    /// which is what lets `serve` pay setup once per app instead of
    /// once per request.
    sim_plan: OnceLock<Result<Arc<SimPlan>, String>>,
    /// Lazily-built functional execution plan (fused affine kernels +
    /// analytic timing — docs/execution.md). A cached `Err` marks the
    /// design as needing the cycle-accurate fallback; `Auto` engine
    /// selection consults it once, not per request.
    exec_plan: OnceLock<Result<Arc<ExecPlan>, String>>,
    /// Tiling plans by requested output extent (docs/tiling.md):
    /// planning an extent costs a handful of bounds-inference runs,
    /// so repeated whole-image requests at the same size — the
    /// production shape — reuse one plan. Only successes are cached,
    /// and the cache is **bounded** ([`TILE_PLAN_CACHE_CAP`]): a
    /// client cycling through distinct extents evicts old plans
    /// instead of growing server memory without limit.
    tile_plans: Mutex<BTreeMap<Vec<i64>, Arc<TilePlan>>>,
}

/// Cap on cached tiling plans per design. Production traffic uses a
/// handful of image sizes; anything past the cap evicts the
/// smallest-key entry (cheap, deterministic — a re-planned extent
/// costs only bounds inference, while an unbounded map is a remote
/// memory-growth vector).
const TILE_PLAN_CACHE_CAP: usize = 16;

impl Compiled {
    pub fn fits(&self) -> bool {
        self.placement.is_some()
    }

    /// The design's compiled output-tile extents — the fixed box one
    /// accelerator pass produces. Requests at any other extent go
    /// through [`Compiled::tile_plan`].
    pub fn tile_extent(&self) -> &[i64] {
        &self.lp.tile
    }

    /// The tiling plan decomposing `extent` onto this fixed design,
    /// built on first use and cached per extent (docs/tiling.md).
    /// **Single-flight**: the build runs under the cache lock, so
    /// racing first calls for one extent build exactly once — the
    /// losers block for the few bounds-inference runs a build costs
    /// and then share the winner's `Arc`. That is what makes
    /// `tile_plan_builds` an exact coalescing observable: M
    /// concurrent same-extent requests move it by 1. The cache is
    /// bounded ([`TILE_PLAN_CACHE_CAP`]) so hostile extent-cycling
    /// cannot grow server memory.
    pub fn tile_plan(&self, extent: &[i64]) -> Result<Arc<TilePlan>> {
        let mut plans = self.tile_plans.lock().unwrap();
        if let Some(p) = plans.get(extent) {
            return Ok(Arc::clone(p));
        }
        let built = Arc::new(TilePlan::build(self, extent)?);
        crate::telemetry::metrics().tile_plan_builds.inc();
        while plans.len() >= TILE_PLAN_CACHE_CAP {
            let first = plans.keys().next().cloned().expect("non-empty map");
            plans.remove(&first);
        }
        plans.insert(extent.to_vec(), Arc::clone(&built));
        Ok(built)
    }

    /// The design's [`SimPlan`], built once on first use and shared by
    /// every caller as an `Arc` (concurrent first calls race benignly:
    /// `OnceLock` keeps exactly one winner). A build failure is cached
    /// too, so a broken design cannot trigger rebuild storms.
    pub fn plan(&self) -> Result<Arc<SimPlan>> {
        match self.sim_plan.get_or_init(|| {
            SimPlan::build(&self.design, &self.graph)
                .map(Arc::new)
                .map_err(|e| format!("{e:#}"))
        }) {
            Ok(p) => Ok(Arc::clone(p)),
            Err(e) => bail!("building simulation plan: {e}"),
        }
    }

    /// The design's [`ExecPlan`], built once on first use; same
    /// caching contract as [`Compiled::plan`]. `Err` means the design
    /// is outside the functional engine's proven fragment and must be
    /// served by the simulator.
    pub fn exec_plan(&self) -> Result<Arc<ExecPlan>> {
        match self.exec_plan.get_or_init(|| {
            ExecPlan::build(&self.design, &self.graph)
                .map(Arc::new)
                .map_err(|e| format!("{e:#}"))
        }) {
            Ok(p) => Ok(Arc::clone(p)),
            Err(e) => bail!("building functional execution plan: {e}"),
        }
    }

    /// Resolve `engine` into a concrete per-thread request executor
    /// over this design's cached plans. `Auto` prefers the functional
    /// engine and silently falls back to the cycle-accurate simulator
    /// when [`Compiled::exec_plan`] fails — tuned to the serving path,
    /// where exec availability must never cost availability.
    pub fn runner(&self, engine: Engine) -> Result<EngineRun> {
        match engine {
            Engine::Exec => Ok(EngineRun::Exec(ExecRun::new(self.exec_plan()?))),
            Engine::ExecScalar => {
                Ok(EngineRun::Exec(ExecRun::new_scalar(self.exec_plan()?)))
            }
            Engine::Sim => Ok(EngineRun::Sim(SimRun::new(self.plan()?))),
            Engine::Auto => match self.exec_plan() {
                Ok(p) => Ok(EngineRun::Exec(ExecRun::new(p))),
                Err(_) => Ok(EngineRun::Sim(SimRun::new(self.plan()?))),
            },
        }
    }
}

/// Full compile: lower → schedule → extract → map → place & route.
pub fn compile(program: &Program) -> Result<Compiled> {
    let lp = lower::lower(program).context("lowering")?;
    let schedule = sched::schedule(&lp).context("scheduling")?;
    let graph = extract(&lp, &schedule).context("buffer extraction")?;
    let design = map_design(&graph).context("buffer mapping")?;
    let placement = place(&design, CgraSpec::default()).ok();
    let routing = placement.as_ref().and_then(|p| route(p).ok());
    Ok(Compiled {
        program: program.clone(),
        lp,
        schedule,
        graph,
        design,
        placement,
        routing,
        sim_plan: OnceLock::new(),
        exec_plan: OnceLock::new(),
        tile_plans: Mutex::new(BTreeMap::new()),
    })
}

/// Lazily-compiled, shared cache of [`Compiled`] designs keyed by
/// registered app name (the names [`crate::apps::by_name`] accepts).
///
/// The first `get` for an app runs the full compile exactly once even
/// under concurrent requests — each app owns a [`OnceLock`] slot, so
/// racing callers block on the winner instead of recompiling.
/// Failures are cached too: a bad app name cannot trigger a
/// recompilation storm. Designs are handed out as `Arc<Compiled>` so
/// every connection shares one copy (see DESIGN.md §2).
///
/// A registry built [`with_tuned_dir`](Self::with_tuned_dir) consults
/// the [`crate::dse`] result cache before compiling: when the tuner
/// recorded a best schedule for an app (`<dir>/<app>.best`), that
/// schedule replaces the hand-written default. A missing, malformed,
/// or invalid record — or a tuned schedule that fails to compile —
/// falls back to the hand-written schedule
/// ([`compile_maybe_tuned`]): tuned serving must never be less
/// available than untuned serving.
pub struct CompiledRegistry {
    slots: Mutex<BTreeMap<String, Arc<OnceLock<Result<Arc<Compiled>, String>>>>>,
    tuned_dir: Option<PathBuf>,
}

impl CompiledRegistry {
    pub fn new() -> CompiledRegistry {
        CompiledRegistry { slots: Mutex::new(BTreeMap::new()), tuned_dir: None }
    }

    /// A registry that serves tuner-recorded schedules from `dir`
    /// (the `pushmem serve --tuned-dir` path).
    pub fn with_tuned_dir(dir: impl Into<PathBuf>) -> CompiledRegistry {
        CompiledRegistry { slots: Mutex::new(BTreeMap::new()), tuned_dir: Some(dir.into()) }
    }

    fn slot(&self, name: &str) -> Arc<OnceLock<Result<Arc<Compiled>, String>>> {
        let mut slots = self.slots.lock().unwrap();
        slots
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(OnceLock::new()))
            .clone()
    }

    /// Fetch the compiled design for `name`, compiling on first use.
    /// Concurrent first-`get`s for the same app compile once; the
    /// losers block until the winner's result lands in the slot.
    pub fn get(&self, name: &str) -> Result<Arc<Compiled>> {
        let slot = self.slot(name);
        let entry = slot.get_or_init(|| match crate::apps::by_name(name) {
            None => Err(format!("unknown app {name:?} (see `pushmem list`)")),
            Some((program, _)) => {
                compile_maybe_tuned(&program, name, self.tuned_dir.as_deref())
                    .map(Arc::new)
                    .map_err(|e| format!("{e:#}"))
            }
        });
        match entry {
            Ok(c) => Ok(Arc::clone(c)),
            Err(e) => bail!("{e}"),
        }
    }

    /// Seed the cache with an already-compiled design (the
    /// `pushmem serve <app>` path compiles before binding the port).
    pub fn insert(&self, name: &str, c: Arc<Compiled>) {
        let _ = self.slot(name).set(Ok(c));
    }

    /// Eagerly compile `names` on parallel threads (server warm-up);
    /// returns how many compiled successfully.
    pub fn warm(&self, names: &[&str]) -> usize {
        std::thread::scope(|s| {
            let handles: Vec<_> = names
                .iter()
                .map(|name| s.spawn(move || self.get(name).is_ok()))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or(false))
                .filter(|&ok| ok)
                .count()
        })
    }

    /// Names whose compile finished **successfully** (cached failures
    /// are not "compiled" — banners must not advertise them).
    pub fn compiled_names(&self) -> Vec<String> {
        let slots = self.slots.lock().unwrap();
        slots
            .iter()
            .filter(|(_, slot)| matches!(slot.get(), Some(Ok(_))))
            .map(|(name, _)| name.clone())
            .collect()
    }
}

impl Default for CompiledRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// Compile `program`, preferring the [`crate::dse`] tuner's recorded
/// schedule from `dir` when one exists — the policy behind
/// `serve --tuned-dir`, shared by the registry and the CLI. A tuned
/// schedule that is missing, malformed, fails validation, **or fails
/// to compile** (e.g. a stale record from before the app changed)
/// falls back to the hand-written schedule: tuned serving must never
/// be less available than untuned serving.
pub fn compile_maybe_tuned(
    program: &Program,
    name: &str,
    tuned_dir: Option<&Path>,
) -> Result<Compiled> {
    if let Some(dir) = tuned_dir {
        let mut tuned = program.clone();
        if apply_tuned_schedule(&mut tuned, name, dir) {
            match compile(&tuned) {
                Ok(c) => return Ok(c),
                Err(e) => eprintln!(
                    "[tuned] {name}: tuned schedule failed to compile ({e:#}); \
                     falling back to the hand-written schedule"
                ),
            }
        }
    }
    compile(program)
}

/// Swap in the tuner's recorded best schedule for `name` when `dir`
/// holds a structurally valid record; keep the hand-written one
/// otherwise. Returns whether a tuned schedule was applied. (Compile
/// failures are the caller's concern — [`compile_maybe_tuned`] adds
/// that fallback.)
pub fn apply_tuned_schedule(program: &mut Program, name: &str, dir: &Path) -> bool {
    match crate::dse::cache::load_best(dir, name) {
        Some((sched, entry)) => {
            let funcs: Vec<String> = program.funcs.iter().map(|f| f.name.clone()).collect();
            match sched.validate(&funcs) {
                Ok(()) => {
                    eprintln!(
                        "[tuned] {name}: schedule {} ({} cycles) from {}",
                        entry.key,
                        entry.cycles,
                        dir.display()
                    );
                    program.schedule = sched;
                    true
                }
                Err(e) => {
                    eprintln!(
                        "[tuned] {name}: ignoring invalid tuned schedule {}: {e:#}",
                        entry.key
                    );
                    false
                }
            }
        }
        None => {
            eprintln!(
                "[tuned] {name}: no record in {}; using the hand-written schedule",
                dir.display()
            );
            false
        }
    }
}

/// Deterministic pseudo-random inputs (the same stream the tests use):
/// identical values feed the CGRA simulator and the XLA golden model.
pub fn gen_inputs(lp: &LoweredPipeline) -> BTreeMap<String, Tensor> {
    let mut ins = BTreeMap::new();
    for (i, name) in lp.inputs.iter().enumerate() {
        let seed = 17 + 11 * i as i64;
        ins.insert(
            name.clone(),
            Tensor::from_fn(lp.buffers[name].clone(), |pt| {
                let mut h = seed;
                for &v in pt {
                    h = h.wrapping_mul(31).wrapping_add(v + 7);
                }
                (h.rem_euclid(253)) as i32
            }),
        );
    }
    ins
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn compile_every_registered_app_small() {
        for p in apps::all_small() {
            let c = compile(&p).unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
            assert!(c.design.pe_count() > 0, "{}", p.name);
            assert!(c.fits(), "{} should fit at small scale", p.name);
        }
    }

    #[test]
    fn registry_compiles_once_and_shares() {
        let reg = CompiledRegistry::new();
        // Seed with a small build so the test stays fast; concurrent
        // gets must all resolve to the very same Arc.
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        reg.insert("gaussian", Arc::clone(&c));
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| s.spawn(|| reg.get("gaussian").unwrap()))
                .collect();
            for h in handles {
                assert!(Arc::ptr_eq(&h.join().unwrap(), &c));
            }
        });
        assert_eq!(reg.compiled_names(), vec!["gaussian".to_string()]);
    }

    #[test]
    fn registry_caches_unknown_app_failure() {
        let reg = CompiledRegistry::new();
        assert!(reg.get("no_such_app").is_err());
        assert!(reg.get("no_such_app").is_err());
        // Failed slots are cached but never advertised as compiled.
        assert!(reg.compiled_names().is_empty());
    }

    #[test]
    fn registry_warm_reports_successes() {
        let reg = CompiledRegistry::new();
        reg.insert("g14", Arc::new(compile(&apps::gaussian::build(14)).unwrap()));
        let ok = reg.warm(&["g14", "no_such_app"]);
        assert_eq!(ok, 1);
    }

    #[test]
    fn registry_applies_tuned_schedule() {
        use crate::dse::cache::{candidate_key, encode_schedule, CacheEntry, DseCache};
        use crate::halide::HwSchedule;

        let dir = std::env::temp_dir()
            .join(format!("pushmem-tuned-registry-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Record a "tuned" gaussian schedule with a small tile (fast to
        // compile) and mark it best.
        let sched = HwSchedule::new([14, 14]);
        let entry = CacheEntry {
            key: candidate_key("gaussian", &sched),
            cycles: 999,
            completion: 999,
            pes: 19,
            mems: 1,
            sram_words: 64,
            energy_per_op_pj: 1.0,
            pixels_per_cycle: 1.0,
            area_um2: 1.0,
            encoded: encode_schedule(&sched),
        };
        let key = entry.key.clone();
        let mut c = DseCache::open(&dir, "gaussian").unwrap();
        c.record(entry).unwrap();
        c.write_best(&key).unwrap();

        let reg = CompiledRegistry::with_tuned_dir(&dir);
        let compiled = reg.get("gaussian").unwrap();
        assert_eq!(compiled.lp.tile, vec![14, 14], "tuned tile not applied");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn registry_falls_back_on_malformed_tuned_record() {
        let dir = std::env::temp_dir()
            .join(format!("pushmem-tuned-bad-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("gaussian.best"), "not a cache line\n").unwrap();
        // The full get() path must fall back to the hand-written
        // schedule (tile 62) when the record cannot be parsed.
        let reg = CompiledRegistry::with_tuned_dir(&dir);
        let c = reg.get("gaussian").unwrap();
        assert_eq!(c.lp.tile, vec![62, 62]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn registry_falls_back_when_tuned_schedule_fails_to_compile() {
        use crate::dse::cache::{candidate_key, encode_schedule, CacheEntry, DseCache};
        use crate::halide::HwSchedule;

        let dir = std::env::temp_dir()
            .join(format!("pushmem-tuned-stale-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Rank-3 tile: structurally valid (positive extents, no func
        // names to miss), but lowering rejects it against gaussian's
        // rank-2 output — the stale-record shape.
        let sched = HwSchedule::new([14, 14, 14]);
        let entry = CacheEntry {
            key: candidate_key("gaussian", &sched),
            cycles: 1,
            completion: 1,
            pes: 1,
            mems: 1,
            sram_words: 1,
            energy_per_op_pj: 1.0,
            pixels_per_cycle: 1.0,
            area_um2: 1.0,
            encoded: encode_schedule(&sched),
        };
        let key = entry.key.clone();
        let mut cache = DseCache::open(&dir, "gaussian").unwrap();
        cache.record(entry).unwrap();
        cache.write_best(&key).unwrap();

        let reg = CompiledRegistry::with_tuned_dir(&dir);
        let c = reg.get("gaussian").unwrap();
        assert_eq!(c.lp.tile, vec![62, 62], "hand-written fallback not used");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_is_built_once_and_shared() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        let a = c.plan().unwrap();
        let b = c.plan().unwrap();
        assert!(Arc::ptr_eq(&a, &b), "plan must be cached, not rebuilt");
        let ea = c.exec_plan().unwrap();
        let eb = c.exec_plan().unwrap();
        assert!(Arc::ptr_eq(&ea, &eb), "exec plan must be cached too");
    }

    #[test]
    fn auto_runner_prefers_the_functional_engine() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        assert_eq!(c.runner(Engine::Auto).unwrap().engine(), Engine::Exec);
        // Both engines are bit-identical through the runner seam —
        // output and reported stats.
        let ins = gen_inputs(&c.lp);
        let e = c.runner(Engine::Exec).unwrap().run(&ins).unwrap();
        let s = c.runner(Engine::Sim).unwrap().run(&ins).unwrap();
        assert_eq!(e.output.data, s.output.data);
        assert_eq!(e.stats, s.stats);
    }

    #[test]
    fn tile_plans_are_cached_per_extent() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        assert_eq!(c.tile_extent(), &[14, 14]);
        let a = c.tile_plan(&[33, 20]).unwrap();
        let b = c.tile_plan(&[33, 20]).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same extent must share one plan");
        let other = c.tile_plan(&[20, 33]).unwrap();
        assert!(!Arc::ptr_eq(&a, &other));
        // Failures are not cached — and keep failing.
        assert!(c.tile_plan(&[33]).is_err());
        assert!(c.tile_plan(&[33]).is_err());
    }

    #[test]
    fn tile_plan_cache_is_bounded() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        for k in 0..(2 * TILE_PLAN_CACHE_CAP as i64) {
            c.tile_plan(&[14 + k, 14]).unwrap();
        }
        let cached = c.tile_plans.lock().unwrap().len();
        assert!(cached <= TILE_PLAN_CACHE_CAP, "{cached} plans cached");
        // A capped cache still serves: the newest extent hits.
        let last = 14 + 2 * TILE_PLAN_CACHE_CAP as i64 - 1;
        let a = c.tile_plan(&[last, 14]).unwrap();
        let b = c.tile_plan(&[last, 14]).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    /// Eviction is oldest-key-first (BTreeMap order): filling exactly
    /// to cap keeps everything; one more insert evicts the smallest
    /// extent and only it.
    #[test]
    fn tile_plan_cache_evicts_smallest_key_first() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        for k in 0..TILE_PLAN_CACHE_CAP as i64 {
            c.tile_plan(&[20 + k, 14]).unwrap();
        }
        {
            let plans = c.tile_plans.lock().unwrap();
            assert_eq!(plans.len(), TILE_PLAN_CACHE_CAP);
            assert!(plans.contains_key([20, 14].as_slice()));
        }
        // Cap + 1: exactly one eviction, and it is the smallest key.
        c.tile_plan(&[200, 14]).unwrap();
        let plans = c.tile_plans.lock().unwrap();
        assert_eq!(plans.len(), TILE_PLAN_CACHE_CAP);
        assert!(
            !plans.contains_key([20, 14].as_slice()),
            "smallest key should have been evicted"
        );
        assert!(plans.contains_key([21, 14].as_slice()));
        assert!(plans.contains_key([200, 14].as_slice()));
    }

    /// A re-requested evicted extent rebuilds a bit-identical plan and
    /// serves bit-identical results — eviction is purely a memory
    /// policy, never a behavior change.
    #[test]
    fn evicted_tile_plan_rebuilds_bit_identically() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let before = c.tile_plan(&[33, 20]).unwrap();
        let snapshot = format!("{before:?}");
        let inputs = {
            let mut p = apps::gaussian::build(14);
            p.schedule.tile = vec![33, 20];
            gen_inputs(&lower::lower(&p).unwrap())
        };
        let first =
            crate::tile::run_tiled(&c, Engine::Exec, &[33, 20], inputs.clone(), 2).unwrap();
        // Cycle enough distinct extents to evict [33, 20]...
        for k in 0..(2 * TILE_PLAN_CACHE_CAP as i64) {
            c.tile_plan(&[40 + k, 14]).unwrap();
        }
        assert!(
            !c.tile_plans.lock().unwrap().contains_key([33, 20].as_slice()),
            "extent should have been evicted"
        );
        // ...then re-request it: a fresh Arc, an identical plan, and
        // identical served words.
        let rebuilt = c.tile_plan(&[33, 20]).unwrap();
        assert!(!Arc::ptr_eq(&before, &rebuilt), "must be a rebuild");
        assert_eq!(snapshot, format!("{rebuilt:?}"), "rebuilt plan differs");
        let again =
            crate::tile::run_tiled(&c, Engine::Exec, &[33, 20], inputs, 2).unwrap();
        assert_eq!(first.output.data, again.output.data);
        assert_eq!(first.stats, again.stats);
    }

    #[test]
    fn inputs_are_deterministic() {
        let p = apps::gaussian::build(14);
        let lp = lower::lower(&p).unwrap();
        let a = gen_inputs(&lp);
        let b = gen_inputs(&lp);
        assert_eq!(a["input"].data, b["input"].data);
    }
}
