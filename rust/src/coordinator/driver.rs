//! Compilation driver: run the full pipeline of Fig 1 and bundle every
//! intermediate for inspection, simulation, and reporting.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::cgra::{place, route, CgraSpec, Placement, RoutingResult};
use crate::extraction::extract;
use crate::halide::{lower, LoweredPipeline, Program};
use crate::mapping::{map_design, MappedDesign};
use crate::sched::{self, PipelineSchedule};
use crate::tensor::Tensor;
use crate::ub::UbGraph;

/// Everything the compiler produced for one program.
pub struct Compiled {
    pub program: Program,
    pub lp: LoweredPipeline,
    pub schedule: PipelineSchedule,
    pub graph: UbGraph,
    pub design: MappedDesign,
    /// `None` when the design does not fit the array (the paper's
    /// camera footnote) — simulation still works; placement-derived
    /// numbers are reported as unavailable.
    pub placement: Option<Placement>,
    pub routing: Option<RoutingResult>,
}

impl Compiled {
    pub fn fits(&self) -> bool {
        self.placement.is_some()
    }
}

/// Full compile: lower → schedule → extract → map → place & route.
pub fn compile(program: &Program) -> Result<Compiled> {
    let lp = lower::lower(program).context("lowering")?;
    let schedule = sched::schedule(&lp).context("scheduling")?;
    let graph = extract(&lp, &schedule).context("buffer extraction")?;
    let design = map_design(&graph).context("buffer mapping")?;
    let placement = place(&design, CgraSpec::default()).ok();
    let routing = placement.as_ref().and_then(|p| route(p).ok());
    Ok(Compiled {
        program: program.clone(),
        lp,
        schedule,
        graph,
        design,
        placement,
        routing,
    })
}

/// Deterministic pseudo-random inputs (the same stream the tests use):
/// identical values feed the CGRA simulator and the XLA golden model.
pub fn gen_inputs(lp: &LoweredPipeline) -> BTreeMap<String, Tensor> {
    let mut ins = BTreeMap::new();
    for (i, name) in lp.inputs.iter().enumerate() {
        let seed = 17 + 11 * i as i64;
        ins.insert(
            name.clone(),
            Tensor::from_fn(lp.buffers[name].clone(), |pt| {
                let mut h = seed;
                for &v in pt {
                    h = h.wrapping_mul(31).wrapping_add(v + 7);
                }
                (h.rem_euclid(253)) as i32
            }),
        );
    }
    ins
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn compile_every_registered_app_small() {
        for p in apps::all_small() {
            let c = compile(&p).unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
            assert!(c.design.pe_count() > 0, "{}", p.name);
            assert!(c.fits(), "{} should fit at small scale", p.name);
        }
    }

    #[test]
    fn inputs_are_deterministic() {
        let p = apps::gaussian::build(14);
        let lp = lower::lower(&p).unwrap();
        let a = gen_inputs(&lp);
        let b = gen_inputs(&lp);
        assert_eq!(a["input"].data, b["input"].data);
    }
}
