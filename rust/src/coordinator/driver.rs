//! Compilation driver: run the full pipeline of Fig 1 and bundle every
//! intermediate for inspection, simulation, and reporting. Also home
//! of the serving-side variant machinery ([`VariantSet`],
//! [`compile_variants`]) that turns a tuner-persisted Pareto front
//! into a bounded set of co-resident compiled designs per app
//! (docs/routing.md).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::cgra::{place, route as route_nets, CgraSpec, Placement, RoutingResult, SimPlan, SimRun};
use crate::dse::cache::CacheEntry;
use crate::exec::{Engine, EngineRun, ExecPlan, ExecRun};
use crate::extraction::extract;
use crate::halide::{lower, LoweredPipeline, Program};
use crate::mapping::{map_design, MappedDesign};
use crate::sched::{self, PipelineSchedule};
use crate::telemetry::{self, log, MAX_VARIANTS, VARIANT_ROLES};
use crate::tensor::Tensor;
use crate::tile::TilePlan;
use crate::ub::UbGraph;

/// Everything the compiler produced for one program.
pub struct Compiled {
    pub program: Program,
    pub lp: LoweredPipeline,
    pub schedule: PipelineSchedule,
    pub graph: UbGraph,
    pub design: MappedDesign,
    /// `None` when the design does not fit the array (the paper's
    /// camera footnote) — simulation still works; placement-derived
    /// numbers are reported as unavailable.
    pub placement: Option<Placement>,
    pub routing: Option<RoutingResult>,
    /// Lazily-built simulation plan (interned wires, hardware
    /// templates, event schedules — docs/simulator.md). Private:
    /// everything simulation-shaped goes through [`Compiled::plan`],
    /// which is what lets `serve` pay setup once per app instead of
    /// once per request.
    sim_plan: OnceLock<Result<Arc<SimPlan>, String>>,
    /// Lazily-built functional execution plan (fused affine kernels +
    /// analytic timing — docs/execution.md). A cached `Err` marks the
    /// design as needing the cycle-accurate fallback; `Auto` engine
    /// selection consults it once, not per request.
    exec_plan: OnceLock<Result<Arc<ExecPlan>, String>>,
    /// Tiling plans by requested output extent (docs/tiling.md):
    /// planning an extent costs a handful of bounds-inference runs,
    /// so repeated whole-image requests at the same size — the
    /// production shape — reuse one plan. Only successes are cached,
    /// and the cache is **bounded** ([`TILE_PLAN_CACHE_CAP`]): a
    /// client cycling through distinct extents evicts old plans
    /// instead of growing server memory without limit.
    tile_plans: Mutex<BTreeMap<Vec<i64>, Arc<TilePlan>>>,
}

/// Cap on cached tiling plans per design. Production traffic uses a
/// handful of image sizes; anything past the cap evicts the
/// smallest-key entry (cheap, deterministic — a re-planned extent
/// costs only bounds inference, while an unbounded map is a remote
/// memory-growth vector).
const TILE_PLAN_CACHE_CAP: usize = 16;

impl Compiled {
    pub fn fits(&self) -> bool {
        self.placement.is_some()
    }

    /// The design's compiled output-tile extents — the fixed box one
    /// accelerator pass produces. Requests at any other extent go
    /// through [`Compiled::tile_plan`].
    pub fn tile_extent(&self) -> &[i64] {
        &self.lp.tile
    }

    /// The tiling plan decomposing `extent` onto this fixed design,
    /// built on first use and cached per extent (docs/tiling.md).
    /// **Single-flight**: the build runs under the cache lock, so
    /// racing first calls for one extent build exactly once — the
    /// losers block for the few bounds-inference runs a build costs
    /// and then share the winner's `Arc`. That is what makes
    /// `tile_plan_builds` an exact coalescing observable: M
    /// concurrent same-extent requests move it by 1. The cache is
    /// bounded ([`TILE_PLAN_CACHE_CAP`]) so hostile extent-cycling
    /// cannot grow server memory.
    pub fn tile_plan(&self, extent: &[i64]) -> Result<Arc<TilePlan>> {
        let mut plans = self.tile_plans.lock().unwrap();
        if let Some(p) = plans.get(extent) {
            return Ok(Arc::clone(p));
        }
        let built = Arc::new(TilePlan::build(self, extent)?);
        crate::telemetry::metrics().tile_plan_builds.inc();
        while plans.len() >= TILE_PLAN_CACHE_CAP {
            let first = plans.keys().next().cloned().expect("non-empty map");
            plans.remove(&first);
        }
        plans.insert(extent.to_vec(), Arc::clone(&built));
        Ok(built)
    }

    /// The design's [`SimPlan`], built once on first use and shared by
    /// every caller as an `Arc` (concurrent first calls race benignly:
    /// `OnceLock` keeps exactly one winner). A build failure is cached
    /// too, so a broken design cannot trigger rebuild storms.
    pub fn plan(&self) -> Result<Arc<SimPlan>> {
        match self.sim_plan.get_or_init(|| {
            SimPlan::build(&self.design, &self.graph)
                .map(Arc::new)
                .map_err(|e| format!("{e:#}"))
        }) {
            Ok(p) => Ok(Arc::clone(p)),
            Err(e) => bail!("building simulation plan: {e}"),
        }
    }

    /// The design's [`ExecPlan`], built once on first use; same
    /// caching contract as [`Compiled::plan`]. `Err` means the design
    /// is outside the functional engine's proven fragment and must be
    /// served by the simulator.
    pub fn exec_plan(&self) -> Result<Arc<ExecPlan>> {
        match self.exec_plan.get_or_init(|| {
            ExecPlan::build(&self.design, &self.graph)
                .map(Arc::new)
                .map_err(|e| format!("{e:#}"))
        }) {
            Ok(p) => Ok(Arc::clone(p)),
            Err(e) => bail!("building functional execution plan: {e}"),
        }
    }

    /// Resolve `engine` into a concrete per-thread request executor
    /// over this design's cached plans. `Auto` prefers the functional
    /// engine and silently falls back to the cycle-accurate simulator
    /// when [`Compiled::exec_plan`] fails — tuned to the serving path,
    /// where exec availability must never cost availability.
    pub fn runner(&self, engine: Engine) -> Result<EngineRun> {
        match engine {
            Engine::Exec => Ok(EngineRun::Exec(ExecRun::new(self.exec_plan()?))),
            Engine::ExecScalar => {
                Ok(EngineRun::Exec(ExecRun::new_scalar(self.exec_plan()?)))
            }
            Engine::Sim => Ok(EngineRun::Sim(SimRun::new(self.plan()?))),
            Engine::Auto => match self.exec_plan() {
                Ok(p) => Ok(EngineRun::Exec(ExecRun::new(p))),
                Err(_) => Ok(EngineRun::Sim(SimRun::new(self.plan()?))),
            },
        }
    }
}

/// Full compile: lower → schedule → extract → map → place & route.
pub fn compile(program: &Program) -> Result<Compiled> {
    let lp = lower::lower(program).context("lowering")?;
    let schedule = sched::schedule(&lp).context("scheduling")?;
    let graph = extract(&lp, &schedule).context("buffer extraction")?;
    let design = map_design(&graph).context("buffer mapping")?;
    let placement = place(&design, CgraSpec::default()).ok();
    let routing = placement.as_ref().and_then(|p| route_nets(p).ok());
    Ok(Compiled {
        program: program.clone(),
        lp,
        schedule,
        graph,
        design,
        placement,
        routing,
        sim_plan: OnceLock::new(),
        exec_plan: OnceLock::new(),
        tile_plans: Mutex::new(BTreeMap::new()),
    })
}

/// One member of a [`VariantSet`]: a compiled design playing a named
/// serving role. Role names come from
/// [`crate::telemetry::VARIANT_ROLES`], so the routing policy, the
/// per-variant request counters, and the request records all speak
/// the same closed vocabulary.
pub struct Variant {
    /// `"latency"`, `"energy"`, `"area"`, or `"fallback"`.
    pub role: &'static str,
    /// Index of `role` in [`VARIANT_ROLES`] (and in the
    /// `requests_by_variant` counter array).
    pub role_index: usize,
    pub compiled: Arc<Compiled>,
    /// The tuner-recorded score this variant was selected by (`None`
    /// for the hand-written fallback, which the tuner never scored).
    pub entry: Option<CacheEntry>,
}

impl Variant {
    /// PE footprint for co-residency budgeting: the tuner's recorded
    /// count when available, the mapped design's otherwise.
    pub fn pes(&self) -> u64 {
        match &self.entry {
            Some(e) => e.pes as u64,
            None => self.compiled.design.pe_count() as u64,
        }
    }
}

/// The bounded set of compiled variants serving one app: up to three
/// tuned frontier roles (latency-, energy-, and area-optimal picks
/// off the persisted `.pareto` front) plus the hand-written fallback,
/// in that order. Every variant is a validated bit-exact schedule of
/// the *same program*, so routing between them can never change
/// response bytes (docs/routing.md) — and each owns its own
/// `Compiled`, hence its own exec/sim plans and bounded tile-plan
/// cache, so variants never thrash each other's caches.
pub struct VariantSet {
    variants: Vec<Variant>,
}

impl VariantSet {
    /// A single-variant set around an already-compiled design (the
    /// test-seeding and untuned-serving shape): one `"fallback"`.
    pub fn solo(c: Arc<Compiled>) -> VariantSet {
        VariantSet {
            variants: vec![Variant {
                role: VARIANT_ROLES[3],
                role_index: 3,
                compiled: c,
                entry: None,
            }],
        }
    }

    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    pub fn len(&self) -> usize {
        self.variants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.variants.is_empty()
    }

    /// More than one variant to route between.
    pub fn is_multi(&self) -> bool {
        self.variants.len() > 1
    }

    /// The default variant: the best tuned one when the set is tuned
    /// (first in role order — latency-optimal), the hand-written
    /// fallback otherwise. Fixed-box (v1/v2) requests always use this
    /// one — their payload is shaped by the compiled tile box, so
    /// they must see a stable variant (docs/routing.md).
    pub fn primary(&self) -> &Variant {
        &self.variants[0]
    }

    /// The variant playing `role_index`, if present.
    pub fn by_role(&self, role_index: usize) -> Option<&Variant> {
        self.variants.iter().find(|v| v.role_index == role_index)
    }

    /// Test-only assembly of an arbitrary set (routing tests need
    /// synthetic PE footprints without running the tuner).
    #[cfg(test)]
    pub(crate) fn from_variants(variants: Vec<Variant>) -> VariantSet {
        VariantSet { variants }
    }

    /// Index of the smallest-PE-footprint variant — the co-residency
    /// escape hatch when the array budget is exhausted.
    pub fn min_pes_index(&self) -> usize {
        let mut best = 0;
        for (i, v) in self.variants.iter().enumerate() {
            if v.pes() < self.variants[best].pes() {
                best = i;
            }
        }
        best
    }
}

/// Pick the serving roles off a Pareto front: `(role_index,
/// entry_index)` pairs in role order — latency-optimal (min cycles),
/// energy-optimal (min energy/op), area-optimal (min area), each
/// deduped so an entry that wins several roles appears once under its
/// highest-priority role. Ties break on key, so selection is
/// deterministic.
pub fn select_variant_roles(entries: &[CacheEntry]) -> Vec<(usize, usize)> {
    if entries.is_empty() {
        return Vec::new();
    }
    let argmin = |score: &dyn Fn(&CacheEntry) -> f64| -> usize {
        let mut best = 0;
        for (i, e) in entries.iter().enumerate() {
            let (s, b) = (score(e), score(&entries[best]));
            if s < b || (s == b && e.key < entries[best].key) {
                best = i;
            }
        }
        best
    };
    let picks = [
        argmin(&|e: &CacheEntry| e.cycles as f64),
        argmin(&|e: &CacheEntry| e.energy_per_op_pj),
        argmin(&|e: &CacheEntry| e.area_um2),
    ];
    let mut out: Vec<(usize, usize)> = Vec::new();
    for (role, &idx) in picks.iter().enumerate() {
        if !out.iter().any(|&(_, i)| i == idx) {
            out.push((role, idx));
        }
    }
    out
}

/// `PUSHMEM_VARIANTS`: cap on the total variants compiled per app
/// (tuned roles + fallback), clamped to `1..=MAX_VARIANTS`. `1`
/// disables multi-variant routing (fallback only); unset or invalid
/// means the full set (invalid values warn, mirroring the
/// `PUSHMEM_EXEC_THREADS` convention).
fn env_variant_cap() -> usize {
    match std::env::var("PUSHMEM_VARIANTS") {
        Err(_) => MAX_VARIANTS,
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(n) if (1..=MAX_VARIANTS).contains(&n) => n,
            _ => {
                log::warn(
                    "route",
                    &format!(
                        "invalid PUSHMEM_VARIANTS={s:?} (want 1..={MAX_VARIANTS}); \
                         using {MAX_VARIANTS}"
                    ),
                );
                MAX_VARIANTS
            }
        },
    }
}

/// Compile the full serving set for `program`: tuned frontier
/// variants from `tuned_dir` (the verified `.pareto` record, or the
/// single `.best` when no front was persisted) plus the hand-written
/// fallback. Tuned records that fail verification, validation, or
/// compilation are skipped with a `log::warn` + `tuned_fallbacks`
/// count — variant serving must never be less available than plain
/// serving. Honors `PUSHMEM_VARIANTS`.
pub fn compile_variants(
    program: &Program,
    name: &str,
    tuned_dir: Option<&Path>,
) -> Result<VariantSet> {
    compile_variants_capped(program, name, tuned_dir, env_variant_cap())
}

pub(crate) fn compile_variants_capped(
    program: &Program,
    name: &str,
    tuned_dir: Option<&Path>,
    cap: usize,
) -> Result<VariantSet> {
    let cap = cap.clamp(1, MAX_VARIANTS);
    let mut variants: Vec<Variant> = Vec::new();
    if let Some(dir) = tuned_dir {
        let front = crate::dse::cache::load_pareto(dir, name);
        // (role_index, schedule, entry) picks, in role order.
        let picks: Vec<(usize, crate::halide::HwSchedule, CacheEntry)> = if front.is_empty()
        {
            if crate::dse::cache::pareto_path(dir, name).exists() {
                tuned_fallback(name, "pareto record exists but no line verified");
            }
            // No front: `.best` serves as the single latency variant,
            // preserving the pre-variant tuned-serving behavior.
            match crate::dse::cache::load_best(dir, name) {
                Some((sched, entry)) => vec![(0, sched, entry)],
                None => {
                    if crate::dse::cache::best_path(dir, name).exists() {
                        tuned_fallback(name, "best record exists but is unreadable");
                    } else {
                        log::info(
                            "tuned",
                            &format!(
                                "{name}: no record in {}; serving the hand-written \
                                 schedule only",
                                dir.display()
                            ),
                        );
                    }
                    Vec::new()
                }
            }
        } else {
            let entries: Vec<CacheEntry> = front.iter().map(|(_, e)| e.clone()).collect();
            select_variant_roles(&entries)
                .into_iter()
                .map(|(role, i)| (role, front[i].0.clone(), front[i].1.clone()))
                .collect()
        };
        let funcs: Vec<String> = program.funcs.iter().map(|f| f.name.clone()).collect();
        for (role_index, sched, entry) in picks {
            if variants.len() + 1 >= cap {
                break; // keep one slot for the fallback
            }
            if let Err(e) = sched.validate(&funcs) {
                tuned_fallback(
                    name,
                    &format!("invalid tuned schedule {}: {e:#}", entry.key),
                );
                continue;
            }
            let mut tuned = program.clone();
            tuned.schedule = sched;
            match compile(&tuned) {
                Ok(c) => {
                    log::info(
                        "tuned",
                        &format!(
                            "{name}: variant {} = schedule {} ({} cycles, {} PEs) \
                             from {}",
                            VARIANT_ROLES[role_index],
                            entry.key,
                            entry.cycles,
                            entry.pes,
                            dir.display()
                        ),
                    );
                    variants.push(Variant {
                        role: VARIANT_ROLES[role_index],
                        role_index,
                        compiled: Arc::new(c),
                        entry: Some(entry),
                    });
                }
                Err(e) => tuned_fallback(
                    name,
                    &format!("tuned schedule {} failed to compile: {e:#}", entry.key),
                ),
            }
        }
    }
    // The hand-written fallback is always last — unless it fails to
    // compile while tuned variants succeeded, in which case the set
    // stays tuned-only rather than losing the app entirely.
    match compile(program) {
        Ok(c) => variants.push(Variant {
            role: VARIANT_ROLES[3],
            role_index: 3,
            compiled: Arc::new(c),
            entry: None,
        }),
        Err(e) if variants.is_empty() => return Err(e),
        Err(e) => log::warn(
            "tuned",
            &format!(
                "{name}: hand-written schedule failed to compile ({e:#}); serving \
                 tuned variants only"
            ),
        ),
    }
    Ok(VariantSet { variants })
}

/// One tuned-record fallback event: previously a silent `eprintln` +
/// bare bool, now a leveled warning plus the `tuned_fallbacks`
/// counter so operators can see (and alert on) stale tuned dirs.
fn tuned_fallback(name: &str, why: &str) {
    telemetry::metrics().tuned_fallbacks.inc();
    log::warn("tuned", &format!("{name}: {why}; falling back to the hand-written schedule"));
}

/// Lazily-compiled, shared cache of per-app [`VariantSet`]s keyed by
/// registered app name (the names [`crate::apps::by_name`] accepts).
///
/// The first `get` for an app runs the full compile exactly once even
/// under concurrent requests — each app owns a [`OnceLock`] slot, so
/// racing callers block on the winner instead of recompiling.
/// Failures are cached too: a bad app name cannot trigger a
/// recompilation storm. Designs are handed out as `Arc`s so every
/// connection shares one copy (see DESIGN.md §2).
///
/// A registry built [`with_tuned_dir`](Self::with_tuned_dir) consults
/// the [`crate::dse`] result cache before compiling
/// ([`compile_variants`]): the persisted `.pareto` front becomes up
/// to three tuned variants, `.best` alone becomes one, and the
/// hand-written schedule is always compiled as the fallback. Missing,
/// malformed, or invalid records — or tuned schedules that fail to
/// compile — fall back with a warning + `tuned_fallbacks` count:
/// tuned serving must never be less available than untuned serving.
pub struct CompiledRegistry {
    slots: Mutex<BTreeMap<String, Arc<OnceLock<Result<Arc<VariantSet>, String>>>>>,
    tuned_dir: Option<PathBuf>,
}

impl CompiledRegistry {
    pub fn new() -> CompiledRegistry {
        CompiledRegistry { slots: Mutex::new(BTreeMap::new()), tuned_dir: None }
    }

    /// A registry that serves tuner-recorded schedules from `dir`
    /// (the `pushmem serve --tuned-dir` path).
    pub fn with_tuned_dir(dir: impl Into<PathBuf>) -> CompiledRegistry {
        CompiledRegistry { slots: Mutex::new(BTreeMap::new()), tuned_dir: Some(dir.into()) }
    }

    fn slot(&self, name: &str) -> Arc<OnceLock<Result<Arc<VariantSet>, String>>> {
        let mut slots = self.slots.lock().unwrap();
        slots
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(OnceLock::new()))
            .clone()
    }

    /// Fetch the variant set for `name`, compiling on first use.
    /// Concurrent first-gets for the same app compile once; the
    /// losers block until the winner's result lands in the slot.
    pub fn get_variants(&self, name: &str) -> Result<Arc<VariantSet>> {
        let slot = self.slot(name);
        let entry = slot.get_or_init(|| match crate::apps::by_name(name) {
            None => Err(format!("unknown app {name:?} (see `pushmem list`)")),
            Some((program, _)) => {
                compile_variants(&program, name, self.tuned_dir.as_deref())
                    .map(Arc::new)
                    .map_err(|e| format!("{e:#}"))
            }
        });
        match entry {
            Ok(set) => Ok(Arc::clone(set)),
            Err(e) => bail!("{e}"),
        }
    }

    /// The primary compiled design for `name` (the pre-variant API):
    /// the best tuned variant when one loaded, the hand-written
    /// design otherwise.
    pub fn get(&self, name: &str) -> Result<Arc<Compiled>> {
        Ok(Arc::clone(&self.get_variants(name)?.primary().compiled))
    }

    /// Seed the cache with an already-compiled design (the
    /// `pushmem serve <app>` path compiles before binding the port);
    /// it becomes a single-variant set.
    pub fn insert(&self, name: &str, c: Arc<Compiled>) {
        let _ = self.slot(name).set(Ok(Arc::new(VariantSet::solo(c))));
    }

    /// Seed the cache with a pre-built variant set.
    pub fn insert_set(&self, name: &str, set: Arc<VariantSet>) {
        let _ = self.slot(name).set(Ok(set));
    }

    /// Eagerly compile `names` on parallel threads (server warm-up);
    /// returns how many compiled successfully.
    pub fn warm(&self, names: &[&str]) -> usize {
        std::thread::scope(|s| {
            let handles: Vec<_> = names
                .iter()
                .map(|name| s.spawn(move || self.get(name).is_ok()))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or(false))
                .filter(|&ok| ok)
                .count()
        })
    }

    /// Names whose compile finished **successfully** (cached failures
    /// are not "compiled" — banners must not advertise them).
    pub fn compiled_names(&self) -> Vec<String> {
        let slots = self.slots.lock().unwrap();
        slots
            .iter()
            .filter(|(_, slot)| matches!(slot.get(), Some(Ok(_))))
            .map(|(name, _)| name.clone())
            .collect()
    }
}

impl Default for CompiledRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// Compile `program`, preferring the [`crate::dse`] tuner's recorded
/// schedule from `dir` when one exists — the policy behind
/// `serve --tuned-dir`, shared by the registry and the CLI. A tuned
/// schedule that is missing, malformed, fails validation, **or fails
/// to compile** (e.g. a stale record from before the app changed)
/// falls back to the hand-written schedule: tuned serving must never
/// be less available than untuned serving.
pub fn compile_maybe_tuned(
    program: &Program,
    name: &str,
    tuned_dir: Option<&Path>,
) -> Result<Compiled> {
    if let Some(dir) = tuned_dir {
        let mut tuned = program.clone();
        if apply_tuned_schedule(&mut tuned, name, dir) {
            match compile(&tuned) {
                Ok(c) => return Ok(c),
                Err(e) => tuned_fallback(
                    name,
                    &format!("tuned schedule failed to compile: {e:#}"),
                ),
            }
        }
    }
    compile(program)
}

/// Swap in the tuner's recorded best schedule for `name` when `dir`
/// holds a structurally valid record; keep the hand-written one
/// otherwise. Returns whether a tuned schedule was applied. (Compile
/// failures are the caller's concern — [`compile_maybe_tuned`] adds
/// that fallback.)
///
/// Fallbacks used to be a silent `eprintln` + bare `false`, which
/// made a stale or corrupt tuned dir indistinguishable from an
/// intentionally untuned one. Now every *failure* fallback (record
/// present but unusable) is a `log::warn` plus a `tuned_fallbacks`
/// count; a genuinely missing record stays informational.
pub fn apply_tuned_schedule(program: &mut Program, name: &str, dir: &Path) -> bool {
    match crate::dse::cache::load_best(dir, name) {
        Some((sched, entry)) => {
            let funcs: Vec<String> = program.funcs.iter().map(|f| f.name.clone()).collect();
            match sched.validate(&funcs) {
                Ok(()) => {
                    log::info(
                        "tuned",
                        &format!(
                            "{name}: schedule {} ({} cycles) from {}",
                            entry.key,
                            entry.cycles,
                            dir.display()
                        ),
                    );
                    program.schedule = sched;
                    true
                }
                Err(e) => {
                    tuned_fallback(
                        name,
                        &format!("invalid tuned schedule {}: {e:#}", entry.key),
                    );
                    false
                }
            }
        }
        None => {
            if crate::dse::cache::best_path(dir, name).exists() {
                // A record exists but did not load: corrupt or
                // key-mismatched — an operator problem, not a choice.
                tuned_fallback(name, "best record exists but is unreadable");
            } else {
                log::info(
                    "tuned",
                    &format!(
                        "{name}: no record in {}; using the hand-written schedule",
                        dir.display()
                    ),
                );
            }
            false
        }
    }
}

/// Deterministic pseudo-random inputs (the same stream the tests use):
/// identical values feed the CGRA simulator and the XLA golden model.
pub fn gen_inputs(lp: &LoweredPipeline) -> BTreeMap<String, Tensor> {
    let mut ins = BTreeMap::new();
    for (i, name) in lp.inputs.iter().enumerate() {
        let seed = 17 + 11 * i as i64;
        ins.insert(
            name.clone(),
            Tensor::from_fn(lp.buffers[name].clone(), |pt| {
                let mut h = seed;
                for &v in pt {
                    h = h.wrapping_mul(31).wrapping_add(v + 7);
                }
                (h.rem_euclid(253)) as i32
            }),
        );
    }
    ins
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn compile_every_registered_app_small() {
        for p in apps::all_small() {
            let c = compile(&p).unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
            assert!(c.design.pe_count() > 0, "{}", p.name);
            assert!(c.fits(), "{} should fit at small scale", p.name);
        }
    }

    #[test]
    fn registry_compiles_once_and_shares() {
        let reg = CompiledRegistry::new();
        // Seed with a small build so the test stays fast; concurrent
        // gets must all resolve to the very same Arc.
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        reg.insert("gaussian", Arc::clone(&c));
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| s.spawn(|| reg.get("gaussian").unwrap()))
                .collect();
            for h in handles {
                assert!(Arc::ptr_eq(&h.join().unwrap(), &c));
            }
        });
        assert_eq!(reg.compiled_names(), vec!["gaussian".to_string()]);
    }

    #[test]
    fn registry_caches_unknown_app_failure() {
        let reg = CompiledRegistry::new();
        assert!(reg.get("no_such_app").is_err());
        assert!(reg.get("no_such_app").is_err());
        // Failed slots are cached but never advertised as compiled.
        assert!(reg.compiled_names().is_empty());
    }

    #[test]
    fn registry_warm_reports_successes() {
        let reg = CompiledRegistry::new();
        reg.insert("g14", Arc::new(compile(&apps::gaussian::build(14)).unwrap()));
        let ok = reg.warm(&["g14", "no_such_app"]);
        assert_eq!(ok, 1);
    }

    #[test]
    fn registry_applies_tuned_schedule() {
        use crate::dse::cache::{candidate_key, encode_schedule, CacheEntry, DseCache};
        use crate::halide::HwSchedule;

        let dir = std::env::temp_dir()
            .join(format!("pushmem-tuned-registry-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Record a "tuned" gaussian schedule with a small tile (fast to
        // compile) and mark it best.
        let sched = HwSchedule::new([14, 14]);
        let entry = CacheEntry {
            key: candidate_key("gaussian", &sched),
            cycles: 999,
            completion: 999,
            pes: 19,
            mems: 1,
            sram_words: 64,
            energy_per_op_pj: 1.0,
            pixels_per_cycle: 1.0,
            area_um2: 1.0,
            encoded: encode_schedule(&sched),
        };
        let key = entry.key.clone();
        let mut c = DseCache::open(&dir, "gaussian").unwrap();
        c.record(entry).unwrap();
        c.write_best(&key).unwrap();

        let reg = CompiledRegistry::with_tuned_dir(&dir);
        let compiled = reg.get("gaussian").unwrap();
        assert_eq!(compiled.lp.tile, vec![14, 14], "tuned tile not applied");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn registry_falls_back_on_malformed_tuned_record() {
        let dir = std::env::temp_dir()
            .join(format!("pushmem-tuned-bad-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("gaussian.best"), "not a cache line\n").unwrap();
        // The full get() path must fall back to the hand-written
        // schedule (tile 62) when the record cannot be parsed.
        let reg = CompiledRegistry::with_tuned_dir(&dir);
        let c = reg.get("gaussian").unwrap();
        assert_eq!(c.lp.tile, vec![62, 62]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn registry_falls_back_when_tuned_schedule_fails_to_compile() {
        use crate::dse::cache::{candidate_key, encode_schedule, CacheEntry, DseCache};
        use crate::halide::HwSchedule;

        let dir = std::env::temp_dir()
            .join(format!("pushmem-tuned-stale-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Rank-3 tile: structurally valid (positive extents, no func
        // names to miss), but lowering rejects it against gaussian's
        // rank-2 output — the stale-record shape.
        let sched = HwSchedule::new([14, 14, 14]);
        let entry = CacheEntry {
            key: candidate_key("gaussian", &sched),
            cycles: 1,
            completion: 1,
            pes: 1,
            mems: 1,
            sram_words: 1,
            energy_per_op_pj: 1.0,
            pixels_per_cycle: 1.0,
            area_um2: 1.0,
            encoded: encode_schedule(&sched),
        };
        let key = entry.key.clone();
        let mut cache = DseCache::open(&dir, "gaussian").unwrap();
        cache.record(entry).unwrap();
        cache.write_best(&key).unwrap();

        let reg = CompiledRegistry::with_tuned_dir(&dir);
        let c = reg.get("gaussian").unwrap();
        assert_eq!(c.lp.tile, vec![62, 62], "hand-written fallback not used");
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn front_entry(
        app: &str,
        sched: &crate::halide::HwSchedule,
        cycles: i64,
        energy_per_op_pj: f64,
        area_um2: f64,
        pes: usize,
    ) -> crate::dse::cache::CacheEntry {
        use crate::dse::cache::{candidate_key, encode_schedule, CacheEntry};
        CacheEntry {
            key: candidate_key(app, sched),
            cycles,
            completion: cycles,
            pes,
            mems: 1,
            sram_words: 64,
            energy_per_op_pj,
            pixels_per_cycle: 1.0,
            area_um2,
            encoded: encode_schedule(sched),
        }
    }

    #[test]
    fn select_variant_roles_dedups_and_orders() {
        use crate::halide::HwSchedule;
        let a = front_entry("x", &HwSchedule::new([62, 62]), 100, 9.0, 900.0, 80);
        let b = front_entry("x", &HwSchedule::new([31, 31]), 400, 2.0, 300.0, 30);
        // a wins latency; b wins both energy and area → deduped under
        // energy (its highest-priority role).
        let roles = select_variant_roles(&[a.clone(), b.clone()]);
        assert_eq!(roles, vec![(0, 0), (1, 1)]);
        // One entry winning everything collapses to a single latency
        // variant; an empty front selects nothing.
        assert_eq!(select_variant_roles(&[a]), vec![(0, 0)]);
        assert!(select_variant_roles(&[]).is_empty());
        // Three distinct winners fill all three roles.
        let l = front_entry("x", &HwSchedule::new([62, 62]), 100, 9.0, 900.0, 80);
        let e = front_entry("x", &HwSchedule::new([31, 31]), 400, 2.0, 800.0, 30);
        let r = front_entry("x", &HwSchedule::new([14, 14]), 900, 8.0, 100.0, 10);
        assert_eq!(select_variant_roles(&[l, e, r]), vec![(0, 0), (1, 1), (2, 2)]);
    }

    /// A persisted `.pareto` front becomes one compiled variant per
    /// distinct role winner, plus the hand-written fallback last; the
    /// primary is the tuned latency pick.
    #[test]
    fn compile_variants_builds_role_set_from_pareto_front() {
        use crate::dse::cache::DseCache;
        use crate::halide::HwSchedule;

        let app = "g14front-variants";
        let dir = std::env::temp_dir()
            .join(format!("pushmem-variants-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let lat_sched = HwSchedule::new([14, 14]);
        let eco_sched = HwSchedule::new([7, 7]);
        let lat = front_entry(app, &lat_sched, 100, 9.0, 900.0, 80);
        let eco = front_entry(app, &eco_sched, 400, 2.0, 300.0, 30);
        let keys = vec![lat.key.clone(), eco.key.clone()];
        let mut c = DseCache::open(&dir, app).unwrap();
        c.record(lat).unwrap();
        c.record(eco).unwrap();
        c.write_pareto(&keys).unwrap();

        let program = apps::gaussian::build(14);
        let set = compile_variants_capped(&program, app, Some(&dir), 4).unwrap();
        assert!(set.is_multi());
        assert_eq!(set.len(), 3, "latency + energy (area deduped) + fallback");
        assert_eq!(set.primary().role, "latency");
        assert_eq!(set.primary().compiled.lp.tile, vec![14, 14]);
        let eco_v = set.by_role(1).expect("energy variant");
        assert_eq!(eco_v.compiled.lp.tile, vec![7, 7]);
        assert_eq!(eco_v.pes(), 30, "tuner-recorded PEs drive budgeting");
        assert!(set.by_role(2).is_none(), "area role deduped into energy");
        let fb = set.by_role(3).expect("hand-written fallback");
        assert_eq!(fb.compiled.lp.tile, vec![14, 14]);
        assert!(fb.entry.is_none());
        // Smallest footprint is the tuned energy variant, not the
        // fallback (whose PEs come from its mapped design).
        assert_eq!(set.min_pes_index(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `PUSHMEM_VARIANTS`-style caps always reserve one slot for the
    /// fallback; cap 1 disables tuned variants entirely. (Tested via
    /// the capped entry point — mutating the env var would race
    /// parallel tests.)
    #[test]
    fn compile_variants_cap_reserves_the_fallback_slot() {
        use crate::dse::cache::DseCache;
        use crate::halide::HwSchedule;

        let app = "g14cap-variants";
        let dir = std::env::temp_dir()
            .join(format!("pushmem-variants-cap-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let lat_sched = HwSchedule::new([14, 14]);
        let eco_sched = HwSchedule::new([7, 7]);
        let lat = front_entry(app, &lat_sched, 100, 9.0, 900.0, 80);
        let eco = front_entry(app, &eco_sched, 400, 2.0, 300.0, 30);
        let keys = vec![lat.key.clone(), eco.key.clone()];
        let mut c = DseCache::open(&dir, app).unwrap();
        c.record(lat).unwrap();
        c.record(eco).unwrap();
        c.write_pareto(&keys).unwrap();

        let program = apps::gaussian::build(14);
        let two = compile_variants_capped(&program, app, Some(&dir), 2).unwrap();
        assert_eq!(two.len(), 2, "one tuned + the fallback");
        assert_eq!(two.primary().role, "latency");
        assert_eq!(two.variants().last().unwrap().role, "fallback");
        let one = compile_variants_capped(&program, app, Some(&dir), 1).unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one.primary().role, "fallback", "cap 1 = routing disabled");
        // No tuned dir at all: a solo fallback set, still servable.
        let untuned = compile_variants_capped(&program, app, None, 4).unwrap();
        assert_eq!(untuned.len(), 1);
        assert_eq!(untuned.primary().role, "fallback");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A front whose lines all fail verification falls back to
    /// `.best`, and a bad `.best` falls back to the hand-written
    /// schedule — tuned serving is never less available than untuned.
    #[test]
    fn compile_variants_survives_corrupt_records() {
        use crate::dse::cache::DseCache;
        use crate::halide::HwSchedule;

        let app = "g14bad-variants";
        let dir = std::env::temp_dir()
            .join(format!("pushmem-variants-bad-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Corrupt front + a good `.best`: the best record becomes the
        // single tuned (latency) variant.
        std::fs::write(dir.join(format!("{app}.pareto")), "garbage\n").unwrap();
        let sched = HwSchedule::new([14, 14]);
        let entry = front_entry(app, &sched, 100, 9.0, 900.0, 80);
        let key = entry.key.clone();
        let mut c = DseCache::open(&dir, app).unwrap();
        c.record(entry).unwrap();
        c.write_best(&key).unwrap();
        let program = apps::gaussian::build(14);
        let set = compile_variants_capped(&program, app, Some(&dir), 4).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.primary().role, "latency");
        assert_eq!(set.primary().compiled.lp.tile, vec![14, 14]);

        // Corrupt both: only the fallback remains.
        std::fs::write(dir.join(format!("{app}.best")), "also garbage\n").unwrap();
        let set = compile_variants_capped(&program, app, Some(&dir), 4).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.primary().role, "fallback");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn registry_get_variants_shares_one_set() {
        let reg = CompiledRegistry::new();
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        reg.insert("gaussian", Arc::clone(&c));
        let a = reg.get_variants("gaussian").unwrap();
        let b = reg.get_variants("gaussian").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), 1);
        assert!(Arc::ptr_eq(&a.primary().compiled, &c));
    }

    #[test]
    fn plan_is_built_once_and_shared() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        let a = c.plan().unwrap();
        let b = c.plan().unwrap();
        assert!(Arc::ptr_eq(&a, &b), "plan must be cached, not rebuilt");
        let ea = c.exec_plan().unwrap();
        let eb = c.exec_plan().unwrap();
        assert!(Arc::ptr_eq(&ea, &eb), "exec plan must be cached too");
    }

    #[test]
    fn auto_runner_prefers_the_functional_engine() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        assert_eq!(c.runner(Engine::Auto).unwrap().engine(), Engine::Exec);
        // Both engines are bit-identical through the runner seam —
        // output and reported stats.
        let ins = gen_inputs(&c.lp);
        let e = c.runner(Engine::Exec).unwrap().run(&ins).unwrap();
        let s = c.runner(Engine::Sim).unwrap().run(&ins).unwrap();
        assert_eq!(e.output.data, s.output.data);
        assert_eq!(e.stats, s.stats);
    }

    #[test]
    fn tile_plans_are_cached_per_extent() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        assert_eq!(c.tile_extent(), &[14, 14]);
        let a = c.tile_plan(&[33, 20]).unwrap();
        let b = c.tile_plan(&[33, 20]).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same extent must share one plan");
        let other = c.tile_plan(&[20, 33]).unwrap();
        assert!(!Arc::ptr_eq(&a, &other));
        // Failures are not cached — and keep failing.
        assert!(c.tile_plan(&[33]).is_err());
        assert!(c.tile_plan(&[33]).is_err());
    }

    #[test]
    fn tile_plan_cache_is_bounded() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        for k in 0..(2 * TILE_PLAN_CACHE_CAP as i64) {
            c.tile_plan(&[14 + k, 14]).unwrap();
        }
        let cached = c.tile_plans.lock().unwrap().len();
        assert!(cached <= TILE_PLAN_CACHE_CAP, "{cached} plans cached");
        // A capped cache still serves: the newest extent hits.
        let last = 14 + 2 * TILE_PLAN_CACHE_CAP as i64 - 1;
        let a = c.tile_plan(&[last, 14]).unwrap();
        let b = c.tile_plan(&[last, 14]).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    /// Eviction is oldest-key-first (BTreeMap order): filling exactly
    /// to cap keeps everything; one more insert evicts the smallest
    /// extent and only it.
    #[test]
    fn tile_plan_cache_evicts_smallest_key_first() {
        let c = compile(&apps::gaussian::build(14)).unwrap();
        for k in 0..TILE_PLAN_CACHE_CAP as i64 {
            c.tile_plan(&[20 + k, 14]).unwrap();
        }
        {
            let plans = c.tile_plans.lock().unwrap();
            assert_eq!(plans.len(), TILE_PLAN_CACHE_CAP);
            assert!(plans.contains_key([20, 14].as_slice()));
        }
        // Cap + 1: exactly one eviction, and it is the smallest key.
        c.tile_plan(&[200, 14]).unwrap();
        let plans = c.tile_plans.lock().unwrap();
        assert_eq!(plans.len(), TILE_PLAN_CACHE_CAP);
        assert!(
            !plans.contains_key([20, 14].as_slice()),
            "smallest key should have been evicted"
        );
        assert!(plans.contains_key([21, 14].as_slice()));
        assert!(plans.contains_key([200, 14].as_slice()));
    }

    /// A re-requested evicted extent rebuilds a bit-identical plan and
    /// serves bit-identical results — eviction is purely a memory
    /// policy, never a behavior change.
    #[test]
    fn evicted_tile_plan_rebuilds_bit_identically() {
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        let before = c.tile_plan(&[33, 20]).unwrap();
        let snapshot = format!("{before:?}");
        let inputs = {
            let mut p = apps::gaussian::build(14);
            p.schedule.tile = vec![33, 20];
            gen_inputs(&lower::lower(&p).unwrap())
        };
        let first =
            crate::tile::run_tiled(&c, Engine::Exec, &[33, 20], inputs.clone(), 2).unwrap();
        // Cycle enough distinct extents to evict [33, 20]...
        for k in 0..(2 * TILE_PLAN_CACHE_CAP as i64) {
            c.tile_plan(&[40 + k, 14]).unwrap();
        }
        assert!(
            !c.tile_plans.lock().unwrap().contains_key([33, 20].as_slice()),
            "extent should have been evicted"
        );
        // ...then re-request it: a fresh Arc, an identical plan, and
        // identical served words.
        let rebuilt = c.tile_plan(&[33, 20]).unwrap();
        assert!(!Arc::ptr_eq(&before, &rebuilt), "must be a rebuild");
        assert_eq!(snapshot, format!("{rebuilt:?}"), "rebuilt plan differs");
        let again =
            crate::tile::run_tiled(&c, Engine::Exec, &[33, 20], inputs, 2).unwrap();
        assert_eq!(first.output.data, again.output.data);
        assert_eq!(first.stats, again.stats);
    }

    #[test]
    fn inputs_are_deterministic() {
        let p = apps::gaussian::build(14);
        let lp = lower::lower(&p).unwrap();
        let a = gen_inputs(&lp);
        let b = gen_inputs(&lp);
        assert_eq!(a["input"].data, b["input"].data);
    }
}
