//! Compilation driver: run the full pipeline of Fig 1 and bundle every
//! intermediate for inspection, simulation, and reporting.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::cgra::{place, route, CgraSpec, Placement, RoutingResult};
use crate::extraction::extract;
use crate::halide::{lower, LoweredPipeline, Program};
use crate::mapping::{map_design, MappedDesign};
use crate::sched::{self, PipelineSchedule};
use crate::tensor::Tensor;
use crate::ub::UbGraph;

/// Everything the compiler produced for one program.
pub struct Compiled {
    pub program: Program,
    pub lp: LoweredPipeline,
    pub schedule: PipelineSchedule,
    pub graph: UbGraph,
    pub design: MappedDesign,
    /// `None` when the design does not fit the array (the paper's
    /// camera footnote) — simulation still works; placement-derived
    /// numbers are reported as unavailable.
    pub placement: Option<Placement>,
    pub routing: Option<RoutingResult>,
}

impl Compiled {
    pub fn fits(&self) -> bool {
        self.placement.is_some()
    }
}

/// Full compile: lower → schedule → extract → map → place & route.
pub fn compile(program: &Program) -> Result<Compiled> {
    let lp = lower::lower(program).context("lowering")?;
    let schedule = sched::schedule(&lp).context("scheduling")?;
    let graph = extract(&lp, &schedule).context("buffer extraction")?;
    let design = map_design(&graph).context("buffer mapping")?;
    let placement = place(&design, CgraSpec::default()).ok();
    let routing = placement.as_ref().and_then(|p| route(p).ok());
    Ok(Compiled {
        program: program.clone(),
        lp,
        schedule,
        graph,
        design,
        placement,
        routing,
    })
}

/// Lazily-compiled, shared cache of [`Compiled`] designs keyed by
/// registered app name (the names [`crate::apps::by_name`] accepts).
///
/// The first `get` for an app runs the full compile exactly once even
/// under concurrent requests — each app owns a [`OnceLock`] slot, so
/// racing callers block on the winner instead of recompiling.
/// Failures are cached too: a bad app name cannot trigger a
/// recompilation storm. Designs are handed out as `Arc<Compiled>` so
/// every connection shares one copy (see DESIGN.md §2).
pub struct CompiledRegistry {
    slots: Mutex<BTreeMap<String, Arc<OnceLock<Result<Arc<Compiled>, String>>>>>,
}

impl CompiledRegistry {
    pub fn new() -> CompiledRegistry {
        CompiledRegistry { slots: Mutex::new(BTreeMap::new()) }
    }

    fn slot(&self, name: &str) -> Arc<OnceLock<Result<Arc<Compiled>, String>>> {
        let mut slots = self.slots.lock().unwrap();
        slots
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(OnceLock::new()))
            .clone()
    }

    /// Fetch the compiled design for `name`, compiling on first use.
    /// Concurrent first-`get`s for the same app compile once; the
    /// losers block until the winner's result lands in the slot.
    pub fn get(&self, name: &str) -> Result<Arc<Compiled>> {
        let slot = self.slot(name);
        let entry = slot.get_or_init(|| match crate::apps::by_name(name) {
            None => Err(format!("unknown app {name:?} (see `pushmem list`)")),
            Some((program, _)) => {
                compile(&program).map(Arc::new).map_err(|e| format!("{e:#}"))
            }
        });
        match entry {
            Ok(c) => Ok(Arc::clone(c)),
            Err(e) => bail!("{e}"),
        }
    }

    /// Seed the cache with an already-compiled design (the
    /// `pushmem serve <app>` path compiles before binding the port).
    pub fn insert(&self, name: &str, c: Arc<Compiled>) {
        let _ = self.slot(name).set(Ok(c));
    }

    /// Eagerly compile `names` on parallel threads (server warm-up);
    /// returns how many compiled successfully.
    pub fn warm(&self, names: &[&str]) -> usize {
        std::thread::scope(|s| {
            let handles: Vec<_> = names
                .iter()
                .map(|name| s.spawn(move || self.get(name).is_ok()))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or(false))
                .filter(|&ok| ok)
                .count()
        })
    }

    /// Names whose compile finished **successfully** (cached failures
    /// are not "compiled" — banners must not advertise them).
    pub fn compiled_names(&self) -> Vec<String> {
        let slots = self.slots.lock().unwrap();
        slots
            .iter()
            .filter(|(_, slot)| matches!(slot.get(), Some(Ok(_))))
            .map(|(name, _)| name.clone())
            .collect()
    }
}

impl Default for CompiledRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// Deterministic pseudo-random inputs (the same stream the tests use):
/// identical values feed the CGRA simulator and the XLA golden model.
pub fn gen_inputs(lp: &LoweredPipeline) -> BTreeMap<String, Tensor> {
    let mut ins = BTreeMap::new();
    for (i, name) in lp.inputs.iter().enumerate() {
        let seed = 17 + 11 * i as i64;
        ins.insert(
            name.clone(),
            Tensor::from_fn(lp.buffers[name].clone(), |pt| {
                let mut h = seed;
                for &v in pt {
                    h = h.wrapping_mul(31).wrapping_add(v + 7);
                }
                (h.rem_euclid(253)) as i32
            }),
        );
    }
    ins
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn compile_every_registered_app_small() {
        for p in apps::all_small() {
            let c = compile(&p).unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
            assert!(c.design.pe_count() > 0, "{}", p.name);
            assert!(c.fits(), "{} should fit at small scale", p.name);
        }
    }

    #[test]
    fn registry_compiles_once_and_shares() {
        let reg = CompiledRegistry::new();
        // Seed with a small build so the test stays fast; concurrent
        // gets must all resolve to the very same Arc.
        let c = Arc::new(compile(&apps::gaussian::build(14)).unwrap());
        reg.insert("gaussian", Arc::clone(&c));
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| s.spawn(|| reg.get("gaussian").unwrap()))
                .collect();
            for h in handles {
                assert!(Arc::ptr_eq(&h.join().unwrap(), &c));
            }
        });
        assert_eq!(reg.compiled_names(), vec!["gaussian".to_string()]);
    }

    #[test]
    fn registry_caches_unknown_app_failure() {
        let reg = CompiledRegistry::new();
        assert!(reg.get("no_such_app").is_err());
        assert!(reg.get("no_such_app").is_err());
        // Failed slots are cached but never advertised as compiled.
        assert!(reg.compiled_names().is_empty());
    }

    #[test]
    fn registry_warm_reports_successes() {
        let reg = CompiledRegistry::new();
        reg.insert("g14", Arc::new(compile(&apps::gaussian::build(14)).unwrap()));
        let ok = reg.warm(&["g14", "no_such_app"]);
        assert_eq!(ok, 1);
    }

    #[test]
    fn inputs_are_deterministic() {
        let p = apps::gaussian::build(14);
        let lp = lower::lower(&p).unwrap();
        let a = gen_inputs(&lp);
        let b = gen_inputs(&lp);
        assert_eq!(a["input"].data, b["input"].data);
    }
}
