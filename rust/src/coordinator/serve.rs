//! Tile-serving loop: a minimal framed TCP protocol that streams image
//! tiles through the (simulated) accelerator — the deployment shape of
//! Fig 12, with the global buffer fed over the wire. Implemented on
//! std::net + threads (this image vendors no async runtime; see
//! DESIGN.md §2).
//!
//! Frame format (little-endian):
//!   request:  u32 magic (0x50554222) | u32 n_inputs |
//!             per input: u32 word_count | i32 words...
//!   response: u32 magic | u32 status (0=ok) | u32 word_count |
//!             i32 words... | u64 sim_cycles | u64 micros
//!
//! Input word counts must match the app's declared input boxes
//! (row-major).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::driver::Compiled;
use crate::cgra::simulate;
use crate::tensor::Tensor;

pub const MAGIC: u32 = 0x5055_4222; // "PUB\"" — push-memory unified buffer

fn read_u32(s: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    s.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_words(s: &mut impl Read, n: usize) -> Result<Vec<i32>> {
    let mut buf = vec![0u8; n * 4];
    s.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Handle one client connection (public so drivers can embed the
/// server with their own accept loop).
pub fn handle_connection(c: &Compiled, stream: &mut TcpStream) -> Result<()> {
    loop {
        let magic = match read_u32(stream) {
            Ok(m) => m,
            Err(_) => return Ok(()), // connection closed
        };
        if magic != MAGIC {
            bail!("bad magic {magic:#x}");
        }
        let n_inputs = read_u32(stream)? as usize;
        anyhow::ensure!(
            n_inputs == c.lp.inputs.len(),
            "expected {} inputs, got {n_inputs}",
            c.lp.inputs.len()
        );
        let mut inputs = std::collections::BTreeMap::new();
        for name in &c.lp.inputs {
            let words = read_u32(stream)? as usize;
            let shape = c.lp.buffers[name].clone();
            anyhow::ensure!(
                words as i64 == shape.cardinality(),
                "input {name}: {words} words != box {}",
                shape.cardinality()
            );
            let data = read_words(stream, words)?;
            inputs.insert(name.clone(), Tensor::from_data(shape, data));
        }
        let t0 = Instant::now();
        let res = simulate(&c.design, &c.graph, &inputs).context("simulation")?;
        let micros = t0.elapsed().as_micros() as u64;

        // One buffered frame (word-at-a-time writes are syscall-bound).
        let mut frame = Vec::with_capacity(20 + 4 * res.output.data.len());
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.extend_from_slice(&0u32.to_le_bytes());
        frame.extend_from_slice(&(res.output.data.len() as u32).to_le_bytes());
        for w in &res.output.data {
            frame.extend_from_slice(&w.to_le_bytes());
        }
        frame.extend_from_slice(&(res.stats.cycles as u64).to_le_bytes());
        frame.extend_from_slice(&micros.to_le_bytes());
        stream.write_all(&frame)?;
        stream.flush()?;
    }
}

/// Serve tiles forever (one thread per connection).
pub fn serve(c: Compiled, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "serving {} on {addr} ({} PEs, {} MEM tiles, {} cycles/tile)",
        c.program.name,
        c.design.pe_count(),
        c.design.mem_tiles(),
        c.graph.completion
    );
    let shared = Arc::new(c);
    for stream in listener.incoming() {
        let mut stream = stream?;
        let c = Arc::clone(&shared);
        std::thread::spawn(move || {
            if let Err(e) = handle_connection(&c, &mut stream) {
                eprintln!("connection error: {e:#}");
            }
        });
    }
    Ok(())
}

/// Client helper: send one request, get `(output words, cycles, µs)`.
pub fn request(
    stream: &mut TcpStream,
    inputs: &[&Tensor],
) -> Result<(Vec<i32>, u64, u64)> {
    let total: usize = inputs.iter().map(|t| t.data.len()).sum();
    let mut frame = Vec::with_capacity(8 + 4 * inputs.len() + 4 * total);
    frame.extend_from_slice(&MAGIC.to_le_bytes());
    frame.extend_from_slice(&(inputs.len() as u32).to_le_bytes());
    for t in inputs {
        frame.extend_from_slice(&(t.data.len() as u32).to_le_bytes());
        for w in &t.data {
            frame.extend_from_slice(&w.to_le_bytes());
        }
    }
    stream.write_all(&frame)?;
    stream.flush()?;
    let magic = read_u32(stream)?;
    anyhow::ensure!(magic == MAGIC, "bad response magic");
    let status = read_u32(stream)?;
    anyhow::ensure!(status == 0, "server error status {status}");
    let n = read_u32(stream)? as usize;
    let words = read_words(stream, n)?;
    let mut b = [0u8; 8];
    stream.read_exact(&mut b)?;
    let cycles = u64::from_le_bytes(b);
    stream.read_exact(&mut b)?;
    let micros = u64::from_le_bytes(b);
    Ok((words, cycles, micros))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::driver::{compile, gen_inputs};

    #[test]
    fn serve_roundtrip_over_localhost() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let inputs = gen_inputs(&c.lp);
        let expect = simulate_expect(&c, &inputs);

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let shared = Arc::new(c);
        let c2 = Arc::clone(&shared);
        std::thread::spawn(move || {
            if let Ok((mut s, _)) = listener.accept() {
                let _ = handle_connection(&c2, &mut s);
            }
        });

        let mut stream = TcpStream::connect(addr).unwrap();
        let ordered: Vec<&Tensor> =
            shared.lp.inputs.iter().map(|n| &inputs[n]).collect();
        let (words, cycles, _) = request(&mut stream, &ordered).unwrap();
        assert_eq!(words, expect);
        assert!(cycles > 0);
    }

    fn simulate_expect(
        c: &Compiled,
        inputs: &std::collections::BTreeMap<String, Tensor>,
    ) -> Vec<i32> {
        simulate(&c.design, &c.graph, inputs).unwrap().output.data
    }
}
