//! Tile-serving loop: a framed TCP protocol that streams image tiles
//! through the (simulated) accelerator — the deployment shape of
//! Fig 12, with the global buffer fed over the wire. Implemented on
//! std::net + a bounded worker pool of OS threads (this image vendors
//! no async runtime; the rationale is DESIGN.md §2).
//!
//! The wire format lives in [`super::protocol`] (spec: docs/protocol.md).
//! Three generations share one port: v1 frames target the server's
//! default app (`pushmem serve <app>`), v2 frames carry an app name so
//! a single endpoint serves every design in the
//! [`CompiledRegistry`](super::driver::CompiledRegistry)
//! (`pushmem serve-all`), and v3 frames additionally carry a requested
//! **output extent** — whole images of any size, decomposed onto the
//! fixed compiled design by the tile planner ([`crate::tile`],
//! docs/tiling.md) and answered stitched.
//!
//! The worker pool drains a queue of [`Job`]s, not raw connections: a
//! connection occupies one worker for its lifetime as before, but a
//! v3 request also posts its [`TileBatch`] back onto the queue, so
//! **idle** workers join the tile drain and one large request
//! saturates the pool. Progress never depends on recruitment — the
//! posting worker drains unclaimed tiles itself (see
//! [`crate::tile::run`]), so a pool full of busy connections degrades
//! to in-connection execution, never deadlock.
//!
//! Every request is measured: the serving path records one
//! [`RequestRecord`] span per request — stage timings (accept-wait →
//! decode → lookup → execute → stitch → respond), engine, tile count,
//! queue depth at admission — into the process-global
//! [`crate::telemetry`] registry, queryable over the wire via the
//! admin `STATS` frame ([`protocol::ADMIN_STATS`], `pushmem stats`)
//! and dumpable periodically with `--metrics-json`
//! (docs/observability.md). The per-request `[req]` line printed
//! under `--stats` is derived from the same record, so the flag and
//! the snapshot can never disagree; its format is a stable script
//! interface and bypasses the leveled [`telemetry::log`] logger the
//! rest of the module's stderr output goes through.
//!
//! This module owns only the socket I/O and the pool; framing is pure
//! byte-slice code in [`super::protocol`], app-to-design resolution is
//! the registry's job, and tiling is [`crate::tile`]'s. That split
//! keeps every layer unit-testable without the others.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::driver::{Compiled, CompiledRegistry};
use super::protocol::{self, FrameError, Request, Response};
use crate::exec::{Engine, EngineRun};
use crate::telemetry::{self, log, RequestRecord};
use crate::tensor::Tensor;
use crate::tile::{TileBatch, TileScratch};

pub use super::protocol::MAGIC;

/// What the pool's workers drain: whole connections (held until the
/// peer disconnects) and tile batches posted by v3 requests in flight
/// on *other* workers (drained cooperatively, returning the worker to
/// the queue when the batch's claims run out). Batch jobs hold a
/// `Weak` handle: a job that sits queued past its request's lifetime
/// (every worker was busy) must not pin the request's whole-image
/// inputs and per-tile outputs in memory — the submitting connection
/// owns the only strong reference, and a stale job upgrades to
/// nothing. Connection jobs carry their enqueue time so the pool can
/// histogram accept-wait (time queued before a worker picked the
/// connection up).
enum Job {
    Conn(TcpStream, Instant),
    Tiles(std::sync::Weak<TileBatch>),
}

/// How connections resolve apps and report, plus the pool size used
/// by [`serve_on`].
pub struct ServeConfig {
    pub registry: Arc<CompiledRegistry>,
    /// Target of v1 frames (which carry no app name). `None` makes
    /// v1 frames an error — multi-app endpoints may choose that.
    pub default_app: Option<Arc<Compiled>>,
    /// Worker threads handling connections; accepted connections
    /// beyond this queue on a bounded channel (backpressure instead
    /// of unbounded thread spawn).
    pub workers: usize,
    /// Print one `[req]` line per served request to stderr.
    pub stats: bool,
    /// Execution engine policy (docs/execution.md): `Auto` serves
    /// from the functional engine whenever the design supports it and
    /// falls back to the cycle-accurate simulator otherwise.
    pub engine: Engine,
    /// Periodically dump the telemetry snapshot JSON to this path
    /// (atomic overwrite, ~5 s cadence, plus a final dump at
    /// shutdown). `None` disables the dump thread entirely.
    pub metrics_json: Option<std::path::PathBuf>,
    /// Set by [`serve_on_with`] once the pool's queue exists (and
    /// cleared at shutdown so workers see the channel disconnect); v3
    /// handling uses it to recruit idle workers into a tile batch.
    /// `None` (embedders calling [`handle_connection`] directly, unit
    /// tests) means tiles drain on the connection's own thread.
    helpers: Mutex<Option<mpsc::SyncSender<Job>>>,
}

impl ServeConfig {
    /// Single-app v1-style serving (`pushmem serve <app>`); v2 frames
    /// naming other registered apps still work via the registry, and
    /// the default app is seeded into it **under its CLI name** (which
    /// differs from `program.name` for the Harris schedule variants)
    /// so a v2 frame naming it shares the design instead of
    /// recompiling.
    pub fn single(cli_name: &str, c: Compiled) -> ServeConfig {
        let registry = Arc::new(CompiledRegistry::new());
        let c = Arc::new(c);
        registry.insert(cli_name, Arc::clone(&c));
        ServeConfig {
            registry,
            default_app: Some(c),
            workers: 4,
            stats: false,
            engine: Engine::Auto,
            metrics_json: None,
            helpers: Mutex::new(None),
        }
    }

    /// Multi-app serving over a shared registry (`pushmem serve-all`).
    /// Stats default off so embedders (benches, examples, tests) get a
    /// quiet timed path; the CLI opts in.
    pub fn multi(registry: Arc<CompiledRegistry>, workers: usize) -> ServeConfig {
        ServeConfig {
            registry,
            default_app: None,
            workers,
            stats: false,
            engine: Engine::Auto,
            metrics_json: None,
            helpers: Mutex::new(None),
        }
    }
}

/// Grow `buf` to `need` bytes by reading exactly the missing amount.
fn fill_to(stream: &mut impl Read, buf: &mut Vec<u8>, need: usize) -> Result<()> {
    let have = buf.len();
    buf.resize(need, 0);
    stream.read_exact(&mut buf[have..]).context("reading frame body")
}

/// Read one request frame from a stream. `Ok(None)` is a clean
/// disconnect (EOF between frames). All parsing is delegated to
/// [`protocol`]: the length pre-scan ([`protocol::request_frame_len`])
/// sizes the reads, so the full decode — which allocates the input
/// payloads — runs exactly once per frame.
pub fn read_request(stream: &mut impl Read) -> Result<Option<Request>> {
    let mut buf = vec![0u8; 4];
    match stream.read_exact(&mut buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("reading frame header"),
    }
    loop {
        match protocol::request_frame_len(&buf) {
            Ok(total) => {
                if buf.len() < total {
                    fill_to(stream, &mut buf, total)?;
                }
                let (req, _) = protocol::decode_request(&buf)?;
                return Ok(Some(req));
            }
            Err(FrameError::Truncated { need, .. }) => fill_to(stream, &mut buf, need)?,
            Err(e) => return Err(e.into()),
        }
    }
}

/// Read one inbound frame — data request or admin `STATS` — plus the
/// span anchors the serving loop needs: the instant the frame's first
/// header bytes arrived (the request's start-of-span) and the decode
/// stage duration (from that instant until the frame is fully read
/// and decoded, i.e. wire transfer of the body + parsing).
/// `Ok(None)` is a clean disconnect.
fn read_frame(stream: &mut impl Read) -> Result<Option<(protocol::Frame, Instant, u64)>> {
    let mut buf = vec![0u8; 4];
    match stream.read_exact(&mut buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("reading frame header"),
    }
    let started = Instant::now();
    loop {
        match protocol::request_frame_len(&buf) {
            Ok(total) => {
                if buf.len() < total {
                    fill_to(stream, &mut buf, total)?;
                }
                let (frame, _) = protocol::decode_frame(&buf)?;
                let decode_ns = started.elapsed().as_nanos() as u64;
                return Ok(Some((frame, started, decode_ns)));
            }
            Err(FrameError::Truncated { need, .. }) => fill_to(stream, &mut buf, need)?,
            Err(e) => return Err(e.into()),
        }
    }
}

/// Read one response frame (client side), same single-decode
/// discipline as [`read_request`].
pub fn read_response(stream: &mut impl Read) -> Result<Response> {
    let mut buf = vec![0u8; 4];
    stream.read_exact(&mut buf).context("reading response header")?;
    loop {
        match protocol::response_frame_len(&buf) {
            Ok(total) => {
                if buf.len() < total {
                    fill_to(stream, &mut buf, total)?;
                }
                let (resp, _) = protocol::decode_response(&buf)?;
                return Ok(resp);
            }
            Err(FrameError::Truncated { need, .. }) => fill_to(stream, &mut buf, need)?,
            Err(e) => return Err(e.into()),
        }
    }
}

fn write_error(stream: &mut TcpStream, status: u32) {
    // Best-effort: the connection is being dropped anyway.
    let _ = stream.write_all(&protocol::encode_error(status));
    let _ = stream.flush();
}

/// Best-effort error frame with a packed diagnostic (docs/protocol.md)
/// so the peer learns *what* was wrong, not just a status word.
fn write_error_detail(stream: &mut TcpStream, status: u32, detail: &str) {
    let _ = stream.write_all(&protocol::encode_error_detail(status, detail));
    let _ = stream.flush();
}

/// Write one complete frame (the success-path counterpart of
/// [`write_error`], but fallible — a failed OK response must be
/// reported, and recorded as a failed request).
fn send_frame(stream: &mut TcpStream, frame: &[u8]) -> std::io::Result<()> {
    stream.write_all(frame)?;
    stream.flush()
}

/// Record a failed request into the telemetry registry. Stage timings
/// beyond decode are zero — a failure span documents *that* and
/// *where* a request died, not a latency profile (stage histograms are
/// fed by OK requests only, so their counts equal `requests_ok`).
fn fail_rec(version: u8, app: &str, ctx: &ReqCtx<'_>) {
    telemetry::metrics().record_request(RequestRecord {
        app: app.to_string(),
        engine: "?",
        version,
        ok: false,
        tiles: 0,
        in_words: ctx.in_words,
        out_words: 0,
        cycles: 0,
        queue_depth: ctx.queue_depth,
        decode_ns: ctx.decode_ns,
        lookup_ns: 0,
        execute_ns: 0,
        stitch_ns: 0,
        respond_ns: 0,
        total_ns: ctx.started.elapsed().as_nanos() as u64,
    });
}

/// Per-request span context threaded from the frame reader into the
/// fixed-box and tiled handlers.
struct ReqCtx<'a> {
    peer: &'a str,
    /// First header bytes on the wire — the span's zero point.
    started: Instant,
    /// Start of the lookup stage (app resolution + validation +
    /// tensor/plan build).
    lookup_t0: Instant,
    decode_ns: u64,
    /// Pool queue depth sampled at admission.
    queue_depth: u64,
    in_words: u64,
}

/// Answer an admin `STATS` frame: freeze a snapshot, pack its JSON
/// into payload words, reply `STATUS_OK` with zeroed timing fields.
fn handle_stats(stream: &mut TcpStream) -> Result<()> {
    let m = telemetry::metrics();
    m.stats_requests.inc();
    let json = m.snapshot().to_json();
    let frame = protocol::encode_response(&Response {
        status: protocol::STATUS_OK,
        words: protocol::stats_words(&json),
        cycles: 0,
        micros: 0,
    });
    send_frame(stream, &frame).context("responding to stats query")
}

/// Check request payloads against the expected per-input word counts
/// before any tensor is built (`Tensor::from_data` asserts lengths).
/// The error text enumerates expected vs received counts per input —
/// it travels back to the client as the `STATUS_BAD_REQUEST` detail
/// payload, replacing the old opaque status word.
fn check_input_words(app: &str, expect: &[(&str, i64)], inputs: &[Vec<i32>]) -> Result<()> {
    if inputs.len() != expect.len() {
        let decl: Vec<String> = expect
            .iter()
            .map(|(name, want)| format!("{name}={want} words"))
            .collect();
        bail!(
            "app {app}: expected {} inputs ({}), got {}",
            expect.len(),
            decl.join(", "),
            inputs.len()
        );
    }
    let bad: Vec<String> = expect
        .iter()
        .zip(inputs)
        .filter(|((_, want), words)| words.len() as i64 != *want)
        .map(|((name, want), words)| {
            format!("input {name}: got {} words, expected {want}", words.len())
        })
        .collect();
    anyhow::ensure!(bad.is_empty(), "app {app}: {}", bad.join("; "));
    Ok(())
}

/// Expected word counts for the fixed-box (v1/v2) path: the app's
/// declared per-tile input boxes.
fn declared_words(c: &Compiled) -> Vec<(&str, i64)> {
    c.lp
        .inputs
        .iter()
        .map(|name| (name.as_str(), c.lp.buffers[name].cardinality()))
        .collect()
}

/// One connection-cached slot per design: the reusable engine run plus
/// the tiled path's gather/output scratch. The scratch is built lazily
/// (the fixed-box path never pays for it) and is keyed per *design*,
/// not per extent — every tile plan of a design gathers into the same
/// compiled input boxes, so one scratch serves all requested extents.
struct RunSlot {
    key: usize,
    run: EngineRun,
    scratch: Option<TileScratch>,
}

/// The connection's cached per-design runner, built on first use —
/// shared by the fixed-box and tiled paths so neither pays
/// per-request engine setup (`runs` is keyed by design identity; a
/// connection may interleave apps).
fn runner_for<'a>(
    runs: &'a mut Vec<RunSlot>,
    c: &Arc<Compiled>,
    engine: Engine,
) -> Result<&'a mut RunSlot> {
    let key = Arc::as_ptr(c) as usize;
    if let Some(i) = runs.iter().position(|s| s.key == key) {
        return Ok(&mut runs[i]);
    }
    runs.push(RunSlot { key, run: c.runner(engine)?, scratch: None });
    Ok(runs.last_mut().expect("just pushed"))
}

/// Handle one client connection: frames in, simulated tiles out,
/// until the peer disconnects. Errors are reported to the client as a
/// status frame before the connection drops (public so drivers can
/// embed the server with their own accept loop).
///
/// §Perf: request handling performs **no per-request setup** — the
/// compile-grade half lives in the design's cached [`crate::exec::ExecPlan`]
/// / [`crate::cgra::SimPlan`] (built once per app), and the connection
/// keeps one reusable [`EngineRun`] per app it has served, so a
/// request pays only the execution itself plus decoding its own
/// payload (docs/execution.md, docs/simulator.md). Under the default
/// `Auto` engine that execution is the functional engine's fused
/// kernels — microseconds, not a cycle loop.
pub fn handle_connection(cfg: &ServeConfig, stream: &mut TcpStream) -> Result<()> {
    let m = telemetry::metrics();
    m.connections_opened.inc();
    // Count the close however the connection ends — clean EOF, error
    // return, or a panic unwinding out through the pool's
    // catch_unwind.
    struct CloseGuard;
    impl Drop for CloseGuard {
        fn drop(&mut self) {
            telemetry::metrics().connections_closed.inc();
        }
    }
    let _close = CloseGuard;
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string());
    // Reusable per-app run state, keyed by design identity (a
    // connection may interleave v2 requests for different apps).
    let mut runs: Vec<RunSlot> = Vec::new();
    loop {
        let (frame, started, decode_ns) = match read_frame(stream) {
            Ok(Some(f)) => f,
            Ok(None) => return Ok(()),
            Err(e) => {
                // Framing errors carry precise, client-safe messages
                // (cap overruns name the field and the cap) — send
                // them as the diagnostic like every semantic error.
                fail_rec(
                    0,
                    "?",
                    &ReqCtx {
                        peer: &peer,
                        started: Instant::now(),
                        lookup_t0: Instant::now(),
                        decode_ns: 0,
                        queue_depth: m.queue_depth.get(),
                        in_words: 0,
                    },
                );
                write_error_detail(stream, protocol::STATUS_BAD_REQUEST, &format!("{e:#}"));
                return Err(e.context(format!("client {peer}")));
            }
        };
        let req = match frame {
            protocol::Frame::Stats => {
                handle_stats(stream)?;
                continue;
            }
            protocol::Frame::Request(req) => req,
        };
        let version: u8 = match (&req.extent, &req.app) {
            (Some(_), _) => 3,
            (None, Some(_)) => 2,
            (None, None) => 1,
        };
        let ctx = ReqCtx {
            peer: &peer,
            started,
            lookup_t0: Instant::now(),
            decode_ns,
            queue_depth: m.queue_depth.get(),
            in_words: req.inputs.iter().map(|w| w.len() as u64).sum(),
        };
        let c: Arc<Compiled> = match &req.app {
            Some(name) => match cfg.registry.get(name) {
                Ok(c) => c,
                Err(e) => {
                    fail_rec(version, name, &ctx);
                    write_error(stream, protocol::STATUS_UNKNOWN_APP);
                    bail!("client {peer}: {e:#}");
                }
            },
            None => match &cfg.default_app {
                Some(c) => Arc::clone(c),
                None => {
                    fail_rec(version, "?", &ctx);
                    write_error(stream, protocol::STATUS_UNKNOWN_APP);
                    bail!("client {peer}: v1 frame on a server with no default app (send v2 frames with an app name)");
                }
            },
        };
        let Request { extent, inputs: payloads, .. } = req;
        // v3: arbitrary-extent requests take the tiling path — plan,
        // fan tiles out across idle pool workers, stitch, respond.
        if let Some(extent) = extent {
            match handle_tiled(cfg, stream, &c, &extent, payloads, &mut runs, &ctx) {
                Ok(()) => continue,
                Err(e) => return Err(e),
            }
        }
        if let Err(e) = check_input_words(&c.program.name, &declared_words(&c), &payloads) {
            fail_rec(version, &c.program.name, &ctx);
            write_error_detail(stream, protocol::STATUS_BAD_REQUEST, &format!("{e:#}"));
            return Err(e.context(format!("client {peer}")));
        }
        let mut inputs = BTreeMap::new();
        for (name, words) in c.lp.inputs.iter().zip(payloads) {
            inputs.insert(name.clone(), Tensor::from_data(c.lp.buffers[name].clone(), words));
        }
        let run = match runner_for(&mut runs, &c, cfg.engine) {
            Ok(slot) => &mut slot.run,
            Err(e) => {
                fail_rec(version, &c.program.name, &ctx);
                write_error(stream, protocol::STATUS_INTERNAL);
                return Err(e.context(format!("planning {} for {peer}", c.program.name)));
            }
        };
        let engine_name = run.engine().name();
        let lookup_ns = ctx.lookup_t0.elapsed().as_nanos() as u64;
        let t0 = Instant::now();
        let res = match run.run(&inputs) {
            Ok(res) => res,
            Err(e) => {
                fail_rec(version, &c.program.name, &ctx);
                write_error(stream, protocol::STATUS_INTERNAL);
                return Err(e.context(format!("executing {} for {peer}", c.program.name)));
            }
        };
        let execute_ns = t0.elapsed().as_nanos() as u64;
        let micros = execute_ns / 1000;
        let cycles = res.stats.cycles as u64;
        let words = res.output.data;
        let out_words = words.len() as u64;
        let respond_t0 = Instant::now();
        let frame = protocol::encode_response(&Response {
            status: protocol::STATUS_OK,
            words,
            cycles,
            micros,
        });
        if let Err(e) = send_frame(stream, &frame) {
            fail_rec(version, &c.program.name, &ctx);
            return Err(e).context(format!("responding to {peer}"));
        }
        let rec = RequestRecord {
            app: c.program.name.clone(),
            engine: engine_name,
            version,
            ok: true,
            tiles: 1,
            in_words: ctx.in_words,
            out_words,
            cycles,
            queue_depth: ctx.queue_depth,
            decode_ns,
            lookup_ns,
            execute_ns,
            stitch_ns: 0,
            respond_ns: respond_t0.elapsed().as_nanos() as u64,
            total_ns: started.elapsed().as_nanos() as u64,
        };
        // The `[req]` line is a stable script interface (format
        // frozen); it is printed from the same record the registry
        // keeps, so the two can never disagree.
        if cfg.stats {
            eprintln!(
                "[req] client={peer} app={} engine={} in_words={} out_words={} cycles={} exec_us={}",
                rec.app,
                rec.engine,
                rec.in_words,
                rec.out_words,
                rec.cycles,
                rec.execute_ns / 1000
            );
        }
        m.record_request(rec);
    }
}

/// Serve one v3 (whole-image) request on an open connection: plan the
/// tiling (cached per extent on the design), validate the whole-image
/// inputs, recruit idle pool workers into the [`TileBatch`], drain,
/// stitch, respond. Client-caused failures answer
/// `STATUS_BAD_REQUEST` with a packed diagnostic; like every non-OK
/// path, the connection closes afterwards (`Err` return).
fn handle_tiled(
    cfg: &ServeConfig,
    stream: &mut TcpStream,
    c: &Arc<Compiled>,
    extent: &[i64],
    payloads: Vec<Vec<i32>>,
    runs: &mut Vec<RunSlot>,
    ctx: &ReqCtx<'_>,
) -> Result<()> {
    let peer = ctx.peer;
    let app = c.program.name.clone();
    let plan = match c.tile_plan(extent) {
        Ok(p) => p,
        Err(e) => {
            fail_rec(3, &app, ctx);
            let msg = format!("app {app}: cannot tile output extent {extent:?}: {e:#}");
            write_error_detail(stream, protocol::STATUS_BAD_REQUEST, &msg);
            bail!("client {peer}: {msg}");
        }
    };
    if let Err(e) = check_input_words(&app, &plan.expected_words(), &payloads) {
        fail_rec(3, &app, ctx);
        write_error_detail(stream, protocol::STATUS_BAD_REQUEST, &format!("{e:#}"));
        return Err(e.context(format!("client {peer} (extent {extent:?})")));
    }
    let mut inputs = BTreeMap::new();
    for ((name, b), words) in plan.input_names.iter().zip(&plan.input_boxes).zip(payloads) {
        inputs.insert(name.clone(), Tensor::from_data(b.clone(), words));
    }
    let lookup_ns = ctx.lookup_t0.elapsed().as_nanos() as u64;
    let exec_t0 = Instant::now();
    let batch = match TileBatch::new(Arc::clone(c), cfg.engine, Arc::clone(&plan), inputs) {
        Ok(b) => b,
        Err(e) => {
            fail_rec(3, &app, ctx);
            write_error_detail(stream, protocol::STATUS_INTERNAL, &format!("{e:#}"));
            return Err(e.context(format!("batching {app} for {peer}")));
        }
    };
    // Opportunistic recruitment: idle workers pick the batch off the
    // pool queue and join the drain; a saturated pool (try_send
    // fails, or the jobs sit queued until the batch is over) just
    // leaves the whole drain to this thread. Stale pickups are free —
    // `work` returns immediately once all tiles are claimed.
    let recruit = cfg
        .helpers
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .clone();
    if let Some(tx) = recruit {
        let extra = cfg
            .workers
            .saturating_sub(1)
            .min(batch.tile_count().saturating_sub(1));
        for _ in 0..extra {
            match tx.try_send(Job::Tiles(Arc::downgrade(&batch))) {
                Ok(()) => telemetry::metrics().queue_depth.inc(),
                Err(_) => break,
            }
        }
    }
    // The connection's cached runner drains tiles — a v3 request on a
    // warm connection pays no engine setup, like the fixed-box path —
    // and its cached scratch makes the warm drain allocation-free
    // (gathers, per-tile output, and stitch coordinates all reuse the
    // slot's buffers; see `crate::tile::run`).
    match runner_for(runs, c, cfg.engine) {
        Ok(slot) => {
            let scratch = slot.scratch.get_or_insert_with(|| TileScratch::new(&plan));
            batch.work_with(&mut slot.run, scratch);
        }
        Err(e) => {
            fail_rec(3, &app, ctx);
            write_error_detail(stream, protocol::STATUS_INTERNAL, &format!("{e:#}"));
            return Err(e.context(format!("planning {app} for {peer}")));
        }
    }
    let execute_ns = exec_t0.elapsed().as_nanos() as u64;
    let stitch_t0 = Instant::now();
    let res = match batch.wait() {
        Ok(r) => r,
        Err(e) => {
            fail_rec(3, &app, ctx);
            write_error_detail(stream, protocol::STATUS_INTERNAL, &format!("{e:#}"));
            return Err(e.context(format!("tiled execution of {app} for {peer}")));
        }
    };
    let stitch_ns = stitch_t0.elapsed().as_nanos() as u64;
    let micros = (execute_ns + stitch_ns) / 1000;
    let cycles = res.stats.cycles as u64;
    let out_words = res.output.data.len() as u64;
    let respond_t0 = Instant::now();
    let frame = protocol::encode_response(&Response {
        status: protocol::STATUS_OK,
        words: res.output.data,
        cycles,
        micros,
    });
    if let Err(e) = send_frame(stream, &frame) {
        fail_rec(3, &app, ctx);
        return Err(e).context(format!("responding to {peer}"));
    }
    let rec = RequestRecord {
        app,
        engine: res.engine.name(),
        version: 3,
        ok: true,
        tiles: res.tiles as u64,
        in_words: ctx.in_words,
        out_words,
        cycles,
        queue_depth: ctx.queue_depth,
        decode_ns: ctx.decode_ns,
        lookup_ns,
        execute_ns,
        stitch_ns,
        respond_ns: respond_t0.elapsed().as_nanos() as u64,
        total_ns: ctx.started.elapsed().as_nanos() as u64,
    };
    // Same stable `[req]` interface as the fixed-box path, derived
    // from the record.
    if cfg.stats {
        eprintln!(
            "[req] client={peer} app={} engine={} extent={extent:?} tiles={} \
             out_words={} cycles={} exec_us={micros}",
            rec.app, rec.engine, rec.tiles, rec.out_words, rec.cycles
        );
    }
    telemetry::metrics().record_request(rec);
    Ok(())
}

/// A connection handler, as [`serve_on_with`] accepts it. Production
/// serving always uses [`handle_connection`]; tests inject faulting
/// handlers to exercise the pool's isolation guarantees.
pub type Handler = dyn Fn(&ServeConfig, &mut TcpStream) -> Result<()> + Send + Sync;

/// Run the accept loop on an already-bound listener with a bounded
/// pool of `cfg.workers` connection-handler threads. Accepted
/// connections queue on a bounded channel when every worker is busy —
/// load sheds into the kernel backlog instead of unbounded spawning.
/// Embeddable: tests and examples bind an ephemeral port themselves.
pub fn serve_on(listener: TcpListener, cfg: ServeConfig) -> Result<()> {
    serve_on_with(listener, cfg, Arc::new(handle_connection))
}

/// [`serve_on`] with an injectable per-connection handler (the test
/// seam for pool-isolation tests; everything else should call
/// [`serve_on`]).
///
/// Fault isolation: one connection must never take the pool down.
/// A panicking handler is caught (`catch_unwind`), answered with
/// `STATUS_INTERNAL` best-effort, and its worker keeps serving; a
/// panic elsewhere that poisons the queue mutex is recovered
/// (`PoisonError::into_inner` — the queue holds only streams and
/// batch handles, so there is no invariant a poisoner could have
/// broken mid-update). Tile-batch jobs contain their own panics (see
/// [`crate::tile::run`]), so a worker surviving them needs no extra
/// guard here.
///
/// Serving turns telemetry sampling on ([`telemetry::set_sampling`])
/// so the exec/tile hot-path hooks record; standalone CLI runs leave
/// it off and pay one relaxed bool load per dispatch (DESIGN.md §8).
pub fn serve_on_with(
    listener: TcpListener,
    cfg: ServeConfig,
    handler: Arc<Handler>,
) -> Result<()> {
    telemetry::set_sampling(true);
    let workers = cfg.workers.max(1);
    telemetry::metrics().workers_total.set(workers as u64);
    let (tx, rx) = mpsc::sync_channel::<Job>(2 * workers);
    // Hand the queue to v3 tile fan-out before any connection can
    // arrive; cleared again at shutdown so the channel can disconnect
    // and the workers exit.
    *cfg.helpers.lock().unwrap_or_else(|p| p.into_inner()) = Some(tx.clone());
    let cfg = Arc::new(cfg);
    // Periodic snapshot dumps (--metrics-json): a side thread, never
    // the serving path. Stops (after one final dump) when the accept
    // loop ends.
    let dump_stop = Arc::new(AtomicBool::new(false));
    let dump_handle = cfg.metrics_json.clone().map(|path| {
        let stop = Arc::clone(&dump_stop);
        std::thread::spawn(move || {
            let mut ticks = 0u32;
            loop {
                std::thread::sleep(Duration::from_millis(250));
                let stopping = stop.load(Ordering::Relaxed);
                ticks += 1;
                if stopping || ticks >= 20 {
                    ticks = 0;
                    let json = telemetry::metrics().snapshot().to_json();
                    if let Err(e) = std::fs::write(&path, json) {
                        log::warn(
                            "serve",
                            &format!("event=metrics_dump_failed path={} err={e}", path.display()),
                        );
                    }
                }
                if stopping {
                    return;
                }
            }
        })
    });
    let rx = Arc::new(Mutex::new(rx));
    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let rx = Arc::clone(&rx);
        let cfg = Arc::clone(&cfg);
        let handler = Arc::clone(&handler);
        handles.push(std::thread::spawn(move || loop {
            // The guard is a temporary: the lock is released as soon
            // as recv returns, before the job is handled. A poisoned
            // lock is recovered, not propagated — one dead peer must
            // not cascade the whole pool down.
            let next = rx
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .recv();
            let job = match next {
                Ok(job) => job,
                Err(_) => return, // accept loop gone
            };
            let m = telemetry::metrics();
            m.queue_depth.dec();
            m.workers_busy.inc();
            let busy_t0 = Instant::now();
            match job {
                Job::Conn(mut stream, queued) => {
                    m.jobs_conn.inc();
                    m.accept_wait.record_ns(queued.elapsed().as_nanos() as u64);
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        handler(&cfg, &mut stream)
                    }));
                    match outcome {
                        Ok(Ok(())) => {}
                        Ok(Err(e)) => {
                            log::warn("serve", &format!("event=connection_error err={e:#}"))
                        }
                        Err(_) => {
                            // The handler panicked mid-connection:
                            // report an internal error to the peer
                            // (best-effort) and keep this worker alive
                            // for the next connection.
                            write_error(&mut stream, protocol::STATUS_INTERNAL);
                            log::error(
                                "serve",
                                "event=handler_panic msg=\"worker recovered\"",
                            );
                        }
                    }
                }
                Job::Tiles(batch) => {
                    // Join an in-flight whole-image request; `work`
                    // panics are contained inside the batch, a
                    // drained batch returns immediately, and a batch
                    // whose request already completed upgrades to
                    // nothing (its connection dropped the only
                    // strong handle).
                    m.jobs_tiles.inc();
                    if let Some(batch) = batch.upgrade() {
                        batch.work();
                    }
                }
            }
            m.workers_busy.dec();
            m.worker_busy_ns.add(busy_t0.elapsed().as_nanos() as u64);
        }));
    }
    // One log line per interval on the accept-error path — a listener
    // stuck on EMFILE returns errors in a tight loop and must not
    // flood stderr (the `accept_errors` counter keeps the true rate).
    let accept_rl = log::RateLimited::new(Duration::from_secs(5));
    for stream in listener.incoming() {
        match stream {
            // try_send first so pool saturation is visible to the
            // operator (a queued client hangs silently otherwise).
            Ok(s) => match tx.try_send(Job::Conn(s, Instant::now())) {
                Ok(()) => telemetry::metrics().queue_depth.inc(),
                Err(mpsc::TrySendError::Full(job)) => {
                    telemetry::metrics().queue_full.inc();
                    log::warn(
                        "serve",
                        &format!(
                            "event=queue_full workers={workers} \
                             msg=\"connection waits; raise --workers if this persists\""
                        ),
                    );
                    if tx.send(job).is_err() {
                        break;
                    }
                    telemetry::metrics().queue_depth.inc();
                }
                Err(mpsc::TrySendError::Disconnected(_)) => break,
            },
            Err(e) => {
                // Persistent accept failures (e.g. EMFILE under fd
                // exhaustion) must shed load, not busy-spin.
                telemetry::metrics().accept_errors.inc();
                if let Some(suppressed) = accept_rl.admit() {
                    log::error(
                        "serve",
                        &format!("event=accept_error err={e} suppressed={suppressed}"),
                    );
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    cfg.helpers.lock().unwrap_or_else(|p| p.into_inner()).take();
    drop(tx);
    for h in handles {
        let _ = h.join();
    }
    dump_stop.store(true, Ordering::Relaxed);
    if let Some(h) = dump_handle {
        let _ = h.join();
    }
    Ok(())
}

/// Serve one pre-compiled app forever (the `pushmem serve <app>`
/// path; v1 frames hit this app, v2 frames may name any other
/// registered app). `cli_name` is the `pushmem list` name the design
/// is cached under; `workers` bounds concurrent connections (a
/// connection holds its worker until disconnect — DESIGN.md §2);
/// `metrics_json` enables periodic telemetry snapshot dumps.
pub fn serve(
    cli_name: &str,
    c: Compiled,
    addr: &str,
    workers: usize,
    stats: bool,
    engine: Engine,
    metrics_json: Option<std::path::PathBuf>,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    log::info(
        "serve",
        &format!(
            "event=listening app={} addr={addr} pes={} mem_tiles={} cycles_per_tile={} workers={workers} engine={}",
            c.program.name,
            c.design.pe_count(),
            c.design.mem_tiles(),
            c.graph.completion,
            engine.name()
        ),
    );
    let mut cfg = ServeConfig::single(cli_name, c);
    cfg.workers = workers;
    cfg.stats = stats;
    cfg.engine = engine;
    cfg.metrics_json = metrics_json;
    serve_on(listener, cfg)
}

/// Serve every app in `registry` on one endpoint forever (the
/// `pushmem serve-all` path). Designs compile lazily on first
/// request unless the registry was warmed. `stats` prints one
/// `[req]` line per served request; `metrics_json` enables periodic
/// telemetry snapshot dumps.
pub fn serve_all(
    registry: Arc<CompiledRegistry>,
    addr: &str,
    workers: usize,
    stats: bool,
    engine: Engine,
    metrics_json: Option<std::path::PathBuf>,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let warmed = registry.compiled_names();
    log::info(
        "serve",
        &format!(
            "event=listening_all addr={addr} workers={workers} engine={} precompiled={}",
            engine.name(),
            if warmed.is_empty() { "none(lazy)".to_string() } else { warmed.join(",") }
        ),
    );
    let mut cfg = ServeConfig::multi(registry, workers);
    cfg.stats = stats;
    cfg.engine = engine;
    cfg.metrics_json = metrics_json;
    serve_on(listener, cfg)
}

/// Client helper: send one v1 request (implicit default app), get
/// `(output words, cycles, µs)`.
pub fn request(stream: &mut TcpStream, inputs: &[&Tensor]) -> Result<(Vec<i32>, u64, u64)> {
    let refs: Vec<&[i32]> = inputs.iter().map(|t| t.data.as_slice()).collect();
    roundtrip(stream, protocol::encode_request_v1(&refs))
}

/// Client helper: send one v2 request naming `app`.
pub fn request_app(
    stream: &mut TcpStream,
    app: &str,
    inputs: &[&Tensor],
) -> Result<(Vec<i32>, u64, u64)> {
    let refs: Vec<&[i32]> = inputs.iter().map(|t| t.data.as_slice()).collect();
    roundtrip(stream, protocol::encode_request_v2(app, &refs))
}

/// Client helper: send one v3 whole-image request at `extent`
/// (`app = None` targets the server's default app); inputs are the
/// whole-image tensors over the tile planner's boxes
/// ([`crate::coordinator::Compiled::tile_plan`], docs/tiling.md).
pub fn request_extent(
    stream: &mut TcpStream,
    app: Option<&str>,
    extent: &[i64],
    inputs: &[&Tensor],
) -> Result<(Vec<i32>, u64, u64)> {
    let refs: Vec<&[i32]> = inputs.iter().map(|t| t.data.as_slice()).collect();
    roundtrip(stream, protocol::encode_request_v3(app, extent, &refs))
}

/// Client helper: query the server's telemetry snapshot over the wire
/// (the admin `STATS` frame, docs/observability.md). Returns the raw
/// JSON string.
pub fn request_stats(stream: &mut TcpStream) -> Result<String> {
    stream.write_all(&protocol::encode_stats_request())?;
    stream.flush()?;
    let resp = read_response(stream)?;
    if resp.status != protocol::STATUS_OK {
        bail!("server error status {}", resp.status);
    }
    Ok(protocol::detail_from_words(&resp.words))
}

fn roundtrip(stream: &mut TcpStream, frame: Vec<u8>) -> Result<(Vec<i32>, u64, u64)> {
    stream.write_all(&frame)?;
    stream.flush()?;
    let resp = read_response(stream)?;
    if resp.status != protocol::STATUS_OK {
        let detail = protocol::detail_from_words(&resp.words);
        if detail.is_empty() {
            bail!("server error status {}", resp.status);
        }
        bail!("server error status {}: {detail}", resp.status);
    }
    Ok((resp.words, resp.cycles, resp.micros))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::cgra::simulate;
    use crate::coordinator::driver::{compile, gen_inputs};

    fn spawn_server(cfg: ServeConfig) -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || serve_on(listener, cfg));
        addr
    }

    #[test]
    fn serve_roundtrip_over_localhost_v1() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let inputs = gen_inputs(&c.lp);
        let expect = simulate(&c.design, &c.graph, &inputs).unwrap().output.data;
        let ordered: Vec<Tensor> =
            c.lp.inputs.iter().map(|n| inputs[n].clone()).collect();

        let addr = spawn_server(ServeConfig::single("g14", c));
        let mut stream = TcpStream::connect(addr).unwrap();
        let refs: Vec<&Tensor> = ordered.iter().collect();
        // Two requests on one connection: the loop must persist.
        for _ in 0..2 {
            let (words, cycles, _) = request(&mut stream, &refs).unwrap();
            assert_eq!(words, expect);
            assert!(cycles > 0);
        }
    }

    #[test]
    fn v2_frame_shares_the_seeded_default_design() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let inputs = gen_inputs(&c.lp);
        let expect = simulate(&c.design, &c.graph, &inputs).unwrap().output.data;
        let ordered: Vec<Tensor> =
            c.lp.inputs.iter().map(|n| inputs[n].clone()).collect();

        // single() must seed the registry under the CLI name ("g14" is
        // not a by_name app, so any hit proves it came from the seed,
        // not a recompile).
        let cfg = ServeConfig::single("g14", c);
        let addr = spawn_server(cfg);
        let mut stream = TcpStream::connect(addr).unwrap();
        let refs: Vec<&Tensor> = ordered.iter().collect();
        let (words, _, _) = request_app(&mut stream, "g14", &refs).unwrap();
        assert_eq!(words, expect);
    }

    /// The engine flag changes the execution path, never the bytes on
    /// the wire: exec- and sim-served responses are identical, words
    /// and reported cycles both.
    #[test]
    fn engines_agree_over_the_wire() {
        let prog = apps::gaussian::build(14);
        let inputs = gen_inputs(&compile(&prog).unwrap().lp);
        let ordered: Vec<Tensor> = inputs.values().cloned().collect();
        let refs: Vec<&Tensor> = ordered.iter().collect();

        let mut answers = Vec::new();
        for engine in [Engine::Exec, Engine::Sim] {
            let mut cfg = ServeConfig::single("g14", compile(&prog).unwrap());
            cfg.engine = engine;
            let addr = spawn_server(cfg);
            let mut stream = TcpStream::connect(addr).unwrap();
            answers.push(request(&mut stream, &refs).unwrap());
        }
        let (ew, ec, _) = &answers[0];
        let (sw, sc, _) = &answers[1];
        assert_eq!(ew, sw, "exec and sim served different words");
        assert_eq!(ec, sc, "exec and sim served different cycle counts");
    }

    #[test]
    fn unknown_app_gets_status_frame() {
        let cfg = ServeConfig::multi(Arc::new(CompiledRegistry::new()), 1);
        let addr = spawn_server(cfg);
        let mut stream = TcpStream::connect(addr).unwrap();
        let t = Tensor::from_data(crate::poly::BoxSet::from_extents(&[1]), vec![0]);
        let err = request_app(&mut stream, "definitely_not_an_app", &[&t]).unwrap_err();
        assert!(
            err.to_string().contains(&format!("status {}", protocol::STATUS_UNKNOWN_APP)),
            "{err:#}"
        );
    }

    #[test]
    fn word_count_mismatch_gets_bad_request() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let addr = spawn_server(ServeConfig::single("g14", c));
        let mut stream = TcpStream::connect(addr).unwrap();
        // One input with a wrong word count vs the declared box.
        let t = Tensor::from_data(crate::poly::BoxSet::from_extents(&[3]), vec![1, 2, 3]);
        let err = request(&mut stream, &[&t]).unwrap_err();
        assert!(
            err.to_string().contains(&format!("status {}", protocol::STATUS_BAD_REQUEST)),
            "{err:#}"
        );
    }

    /// v3 whole-image request over the real pool: stitched output is
    /// bit-exact vs the host-side whole-image golden, the plan is
    /// reused across requests, and both the default-app (empty name)
    /// and named forms work.
    #[test]
    fn v3_whole_image_request_stitches() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let extent = vec![33i64, 20];
        let mut full = prog.clone();
        full.schedule.tile = extent.clone();
        let lp = crate::halide::lower::lower(&full).unwrap();
        let inputs = gen_inputs(&lp);
        let want = lp.execute(&inputs).unwrap()[&lp.output].clone();
        let ordered: Vec<Tensor> = lp.inputs.iter().map(|n| inputs[n].clone()).collect();

        let addr = spawn_server(ServeConfig::single("g14", c));
        let mut stream = TcpStream::connect(addr).unwrap();
        let refs: Vec<&Tensor> = ordered.iter().collect();
        for _ in 0..2 {
            let (words, cycles, _) =
                request_extent(&mut stream, None, &extent, &refs).unwrap();
            assert_eq!(words, want.data, "stitched output != whole-image golden");
            assert!(cycles > 0);
        }
        let (words, _, _) =
            request_extent(&mut stream, Some("g14"), &extent, &refs).unwrap();
        assert_eq!(words, want.data);
        // The same connection still serves fixed-box v1 frames after.
        let tile_inputs = gen_inputs(&crate::halide::lower::lower(&prog).unwrap());
        let ordered: Vec<Tensor> =
            prog_inputs_in_order(&prog, &tile_inputs);
        let refs: Vec<&Tensor> = ordered.iter().collect();
        let (words, _, _) = request(&mut stream, &refs).unwrap();
        assert_eq!(words.len(), 14 * 14);
    }

    fn prog_inputs_in_order(
        prog: &crate::halide::Program,
        inputs: &BTreeMap<String, Tensor>,
    ) -> Vec<Tensor> {
        prog.inputs.iter().map(|i| inputs[&i.name].clone()).collect()
    }

    /// The bad-request diagnostic channel: wrong whole-image word
    /// counts come back naming the input with expected vs received.
    #[test]
    fn v3_wrong_word_count_reports_expected_counts() {
        let prog = apps::gaussian::build(14);
        let addr = spawn_server(ServeConfig::single("g14", compile(&prog).unwrap()));
        let mut stream = TcpStream::connect(addr).unwrap();
        let t = Tensor::from_data(crate::poly::BoxSet::from_extents(&[3]), vec![1, 2, 3]);
        let err =
            request_extent(&mut stream, None, &[33, 20], &[&t]).unwrap_err();
        let msg = err.to_string();
        // 33x20 gaussian needs a (33+2)x(20+2) input image.
        assert!(msg.contains("got 3 words, expected 770"), "{msg}");
        assert!(msg.contains("input"), "{msg}");
    }

    /// The fixed-box path gained the same diagnostics: the old opaque
    /// status word now carries expected vs received per input.
    #[test]
    fn v1_word_count_mismatch_detail_names_expected() {
        let prog = apps::gaussian::build(14);
        let addr = spawn_server(ServeConfig::single("g14", compile(&prog).unwrap()));
        let mut stream = TcpStream::connect(addr).unwrap();
        let t = Tensor::from_data(crate::poly::BoxSet::from_extents(&[3]), vec![1, 2, 3]);
        let err = request(&mut stream, &[&t]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&format!("status {}", protocol::STATUS_BAD_REQUEST)), "{msg}");
        assert!(msg.contains("got 3 words, expected 256"), "{msg}");
    }

    /// An untileable extent (wrong rank) earns a diagnostic
    /// BAD_REQUEST, not a dropped connection.
    #[test]
    fn v3_bad_rank_gets_diagnostic_bad_request() {
        let prog = apps::gaussian::build(14);
        let addr = spawn_server(ServeConfig::single("g14", compile(&prog).unwrap()));
        let mut stream = TcpStream::connect(addr).unwrap();
        let t = Tensor::from_data(crate::poly::BoxSet::from_extents(&[3]), vec![1, 2, 3]);
        let err = request_extent(&mut stream, None, &[33], &[&t]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&format!("status {}", protocol::STATUS_BAD_REQUEST)), "{msg}");
        assert!(msg.contains("cannot tile output extent"), "{msg}");
    }

    #[test]
    fn bad_magic_gets_bad_request_then_close() {
        let prog = apps::gaussian::build(14);
        let addr = spawn_server(ServeConfig::single("g14", compile(&prog).unwrap()));
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(&0xDEAD_BEEFu32.to_le_bytes()).unwrap();
        stream.flush().unwrap();
        let resp = read_response(&mut stream).unwrap();
        assert_eq!(resp.status, protocol::STATUS_BAD_REQUEST);
        // Server closed the connection afterwards.
        let mut rest = Vec::new();
        assert_eq!(stream.read_to_end(&mut rest).unwrap(), 0);
    }

    /// A STATS frame answered on a connection interleaved with data
    /// frames: OK status, parseable JSON payload, zeroed timings.
    #[test]
    fn stats_frame_answers_json_on_data_connection() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let inputs = gen_inputs(&c.lp);
        let ordered: Vec<Tensor> =
            c.lp.inputs.iter().map(|n| inputs[n].clone()).collect();
        let addr = spawn_server(ServeConfig::single("g14", c));
        let mut stream = TcpStream::connect(addr).unwrap();
        let refs: Vec<&Tensor> = ordered.iter().collect();
        let (words, _, _) = request(&mut stream, &refs).unwrap();
        assert_eq!(words.len(), 14 * 14);
        let json = request_stats(&mut stream).unwrap();
        assert!(json.starts_with("{\"schema\":\"pushmem-stats-v1\""), "{json}");
        assert!(json.contains("\"requests_total\":"), "{json}");
        // The connection still serves data frames after the admin
        // frame.
        let (words, _, _) = request(&mut stream, &refs).unwrap();
        assert_eq!(words.len(), 14 * 14);
    }
}
