//! Tile-serving loop: a framed TCP protocol that streams image tiles
//! through the (simulated) accelerator — the deployment shape of
//! Fig 12, with the global buffer fed over the wire. Implemented on
//! std::net + a bounded worker pool of OS threads (this image vendors
//! no async runtime; the rationale is DESIGN.md §2).
//!
//! The wire format lives in [`super::protocol`] (spec: docs/protocol.md).
//! Three generations share one port: v1 frames target the server's
//! default app (`pushmem serve <app>`), v2 frames carry an app name so
//! a single endpoint serves every design in the
//! [`CompiledRegistry`](super::driver::CompiledRegistry)
//! (`pushmem serve-all`), and v3 frames additionally carry a requested
//! **output extent** — whole images of any size, decomposed onto the
//! fixed compiled design by the tile planner ([`crate::tile`],
//! docs/tiling.md) and answered stitched.
//!
//! The worker pool drains a queue of [`Job`]s, not raw connections: a
//! connection occupies one worker for its lifetime as before, but a
//! v3 request also registers its [`TileBatch`] with the server's
//! shared [`TileScheduler`] and posts wake-up tokens, so **idle**
//! workers join a *cross-request* tile drain: claims are weighted
//! round-robin across every in-flight batch (oldest first), so N
//! concurrent whole-image requests interleave fairly instead of
//! serializing behind the largest one. Progress never depends on
//! recruitment — the submitting worker drains through the same
//! scheduler until its own batch completes (see [`crate::tile::run`]),
//! so a pool full of busy connections degrades to in-connection
//! execution, never deadlock.
//!
//! Admission is bounded end to end: the listener is shared across K
//! acceptor shards (`PUSHMEM_ACCEPT_SHARDS`, default 2), and when the
//! job queue is full an acceptor answers [`protocol::STATUS_BUSY`]
//! with a `retry_after_ms` hint derived from the live queue depth and
//! tile backlog, then closes — a saturated server is loud and fast,
//! never a silent hang (docs/serving.md, DESIGN.md §2).
//!
//! Every request is measured: the serving path records one
//! [`RequestRecord`] span per request — stage timings (accept-wait →
//! decode → lookup → execute → stitch → respond), engine, tile count,
//! queue depth at admission — into the process-global
//! [`crate::telemetry`] registry, queryable over the wire via the
//! admin `STATS` frame ([`protocol::ADMIN_STATS`], `pushmem stats`)
//! and dumpable periodically with `--metrics-json`
//! (docs/observability.md). The per-request `[req]` line printed
//! under `--stats` is derived from the same record, so the flag and
//! the snapshot can never disagree; its format is a stable script
//! interface and bypasses the leveled [`telemetry::log`] logger the
//! rest of the module's stderr output goes through.
//!
//! Apps resolve to [`VariantSet`]s, not single designs: a tuned
//! registry carries up to four compiled variants per app (latency-,
//! energy-, area-optimal picks off the DSE Pareto front, plus the
//! hand-written fallback), and each v3 request picks its variant
//! through the server's [`RoutePolicy`] from load sampled at
//! admission — bit-exact by construction, since every variant is a
//! validated schedule of the same program and v3 responses are
//! extent-addressed (docs/routing.md). Fixed-box v1/v2 requests
//! always use the set's primary variant.
//!
//! This module owns only the socket I/O and the pool; framing is pure
//! byte-slice code in [`super::protocol`], app-to-design resolution is
//! the registry's job, and tiling is [`crate::tile`]'s. That split
//! keeps every layer unit-testable without the others.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::driver::{Compiled, CompiledRegistry, VariantSet};
use super::protocol::{self, FrameError, Request, Response};
use super::route::{LoadSignals, RoutePolicy};
use crate::exec::{Engine, EngineRun};
use crate::telemetry::{self, log, RequestRecord, MAX_ACCEPT_SHARDS};
use crate::tensor::Tensor;
use crate::tile::{TileBatch, TileScheduler, TileScratch};

pub use super::protocol::MAGIC;

/// What the pool's workers drain: whole connections (held until the
/// peer disconnects) and `Drain` wake-up tokens posted by v3 requests
/// in flight on *other* workers. A token carries no batch handle —
/// the woken worker pulls tiles from the server's shared
/// [`TileScheduler`], which weights claims across **every** in-flight
/// request (oldest first), so a token posted for one request ends up
/// helping whichever requests need work most, and a stale token (the
/// batch drained before any worker came free) is a cheap no-op. Not
/// pinning the batch also keeps the old `Weak`-handle property: a
/// queued token never holds a finished request's whole-image inputs
/// in memory. Connection jobs carry their enqueue time so the pool
/// can histogram accept-wait (time queued before a worker picked the
/// connection up).
enum Job {
    Conn(TcpStream, Instant),
    Drain,
}

/// How connections resolve apps and report, plus the pool size used
/// by [`serve_on`].
pub struct ServeConfig {
    pub registry: Arc<CompiledRegistry>,
    /// Target of v1 frames (which carry no app name) and of default-
    /// app v3 frames. `None` makes v1 frames an error — multi-app
    /// endpoints may choose that. A multi-variant set here is what
    /// load-adaptive routing routes over (docs/routing.md).
    pub default_set: Option<Arc<VariantSet>>,
    /// Per-request variant routing policy for v3 (whole-image)
    /// requests — fixed-box requests always use the set's primary
    /// variant (docs/routing.md).
    pub route: RoutePolicy,
    /// Worker threads handling connections; accepted connections
    /// beyond this queue on a bounded channel (backpressure instead
    /// of unbounded thread spawn).
    pub workers: usize,
    /// Print one `[req]` line per served request to stderr.
    pub stats: bool,
    /// Execution engine policy (docs/execution.md): `Auto` serves
    /// from the functional engine whenever the design supports it and
    /// falls back to the cycle-accurate simulator otherwise.
    pub engine: Engine,
    /// Periodically dump the telemetry snapshot JSON to this path
    /// (atomic overwrite, ~5 s cadence, plus a final dump at
    /// shutdown). `None` disables the dump thread entirely.
    pub metrics_json: Option<std::path::PathBuf>,
    /// Capacity of the pool's bounded job queue (`None`: `2 *
    /// workers`). When the queue is full the acceptor answers
    /// `STATUS_BUSY` with a retry hint instead of parking — tests pin
    /// the rejection path with a cap of 1.
    pub queue_cap: Option<usize>,
    /// Acceptor threads sharing the listener (`None`: the
    /// `PUSHMEM_ACCEPT_SHARDS` env var, default 2; always clamped to
    /// `1..=MAX_ACCEPT_SHARDS`). Accepting is cheap but serial: under
    /// a connection flood a single acceptor is the choke point, every
    /// handoff *and* every busy rejection queueing behind one thread
    /// (DESIGN.md §2).
    pub accept_shards: Option<usize>,
    /// The cross-request tile scheduler shared by every pool worker
    /// and v3 submitter of this server (docs/serving.md).
    sched: Arc<TileScheduler>,
    /// Set by [`serve_on_with`] once the pool's queue exists (and
    /// cleared at shutdown so workers see the channel disconnect); v3
    /// handling uses it to recruit idle workers into a tile batch.
    /// `None` (embedders calling [`handle_connection`] directly, unit
    /// tests) means tiles drain on the connection's own thread.
    helpers: Mutex<Option<mpsc::SyncSender<Job>>>,
}

impl ServeConfig {
    /// Single-app v1-style serving (`pushmem serve <app>`); v2 frames
    /// naming other registered apps still work via the registry, and
    /// the default app is seeded into it **under its CLI name** (which
    /// differs from `program.name` for the Harris schedule variants)
    /// so a v2 frame naming it shares the design instead of
    /// recompiling.
    pub fn single(cli_name: &str, c: Compiled) -> ServeConfig {
        ServeConfig::single_set(cli_name, Arc::new(VariantSet::solo(Arc::new(c))))
    }

    /// Single-app serving over a pre-built variant set (the
    /// `pushmem serve <app> --tuned-dir` path, where the tuner's
    /// persisted Pareto front yields multiple routable variants).
    pub fn single_set(cli_name: &str, set: Arc<VariantSet>) -> ServeConfig {
        let registry = Arc::new(CompiledRegistry::new());
        registry.insert_set(cli_name, Arc::clone(&set));
        ServeConfig {
            registry,
            default_set: Some(set),
            route: RoutePolicy::new(),
            workers: 4,
            stats: false,
            engine: Engine::Auto,
            metrics_json: None,
            queue_cap: None,
            accept_shards: None,
            sched: Arc::new(TileScheduler::new()),
            helpers: Mutex::new(None),
        }
    }

    /// Multi-app serving over a shared registry (`pushmem serve-all`).
    /// Stats default off so embedders (benches, examples, tests) get a
    /// quiet timed path; the CLI opts in.
    pub fn multi(registry: Arc<CompiledRegistry>, workers: usize) -> ServeConfig {
        ServeConfig {
            registry,
            default_set: None,
            route: RoutePolicy::new(),
            workers,
            stats: false,
            engine: Engine::Auto,
            metrics_json: None,
            queue_cap: None,
            accept_shards: None,
            sched: Arc::new(TileScheduler::new()),
            helpers: Mutex::new(None),
        }
    }
}

/// Grow `buf` to `need` bytes by reading exactly the missing amount.
fn fill_to(stream: &mut impl Read, buf: &mut Vec<u8>, need: usize) -> Result<()> {
    let have = buf.len();
    buf.resize(need, 0);
    stream.read_exact(&mut buf[have..]).context("reading frame body")
}

/// Read one request frame from a stream. `Ok(None)` is a clean
/// disconnect (EOF between frames). All parsing is delegated to
/// [`protocol`]: the length pre-scan ([`protocol::request_frame_len`])
/// sizes the reads, so the full decode — which allocates the input
/// payloads — runs exactly once per frame.
pub fn read_request(stream: &mut impl Read) -> Result<Option<Request>> {
    let mut buf = vec![0u8; 4];
    match stream.read_exact(&mut buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("reading frame header"),
    }
    loop {
        match protocol::request_frame_len(&buf) {
            Ok(total) => {
                if buf.len() < total {
                    fill_to(stream, &mut buf, total)?;
                }
                let (req, _) = protocol::decode_request(&buf)?;
                return Ok(Some(req));
            }
            Err(FrameError::Truncated { need, .. }) => fill_to(stream, &mut buf, need)?,
            Err(e) => return Err(e.into()),
        }
    }
}

/// Read one inbound frame's raw bytes — data request or admin
/// `STATS` — plus the instant its first header bytes arrived (the
/// request's start-of-span). The length pre-scan
/// ([`protocol::request_frame_len`]) enforces every structural cap
/// before a byte is buffered, but the frame is *not* decoded here:
/// the caller decodes a borrowing [`protocol::RequestView`] over the
/// returned buffer, so a v3 whole-image payload travels frame →
/// gather scratch with no intermediate `Vec<i32>` copy. `Ok(None)`
/// is a clean disconnect.
fn read_frame_bytes(stream: &mut impl Read) -> Result<Option<(Vec<u8>, Instant)>> {
    let mut buf = vec![0u8; 4];
    match stream.read_exact(&mut buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("reading frame header"),
    }
    let started = Instant::now();
    loop {
        match protocol::request_frame_len(&buf) {
            Ok(total) => {
                if buf.len() < total {
                    fill_to(stream, &mut buf, total)?;
                }
                return Ok(Some((buf, started)));
            }
            Err(FrameError::Truncated { need, .. }) => fill_to(stream, &mut buf, need)?,
            Err(e) => return Err(e.into()),
        }
    }
}

/// Read one response frame (client side), same single-decode
/// discipline as [`read_request`].
pub fn read_response(stream: &mut impl Read) -> Result<Response> {
    let mut buf = vec![0u8; 4];
    stream.read_exact(&mut buf).context("reading response header")?;
    loop {
        match protocol::response_frame_len(&buf) {
            Ok(total) => {
                if buf.len() < total {
                    fill_to(stream, &mut buf, total)?;
                }
                let (resp, _) = protocol::decode_response(&buf)?;
                return Ok(resp);
            }
            Err(FrameError::Truncated { need, .. }) => fill_to(stream, &mut buf, need)?,
            Err(e) => return Err(e.into()),
        }
    }
}

fn write_error(stream: &mut TcpStream, status: u32) {
    // Best-effort: the connection is being dropped anyway.
    let _ = stream.write_all(&protocol::encode_error(status));
    let _ = stream.flush();
}

/// Best-effort error frame with a packed diagnostic (docs/protocol.md)
/// so the peer learns *what* was wrong, not just a status word.
fn write_error_detail(stream: &mut TcpStream, status: u32, detail: &str) {
    let _ = stream.write_all(&protocol::encode_error_detail(status, detail));
    let _ = stream.flush();
}

/// Write one complete frame (the success-path counterpart of
/// [`write_error`], but fallible — a failed OK response must be
/// reported, and recorded as a failed request).
fn send_frame(stream: &mut TcpStream, frame: &[u8]) -> std::io::Result<()> {
    stream.write_all(frame)?;
    stream.flush()
}

/// Record a failed request into the telemetry registry. Stage timings
/// beyond decode are zero — a failure span documents *that* and
/// *where* a request died, not a latency profile (stage histograms are
/// fed by OK requests only, so their counts equal `requests_ok`).
fn fail_rec(version: u8, app: &str, ctx: &ReqCtx<'_>) {
    telemetry::metrics().record_request(RequestRecord {
        app: app.to_string(),
        engine: "?",
        // Failures never count toward `requests_by_variant` — the
        // reconciliation invariant is over OK requests only.
        variant: "?",
        version,
        ok: false,
        tiles: 0,
        in_words: ctx.in_words,
        out_words: 0,
        cycles: 0,
        queue_depth: ctx.queue_depth,
        decode_ns: ctx.decode_ns,
        lookup_ns: 0,
        execute_ns: 0,
        stitch_ns: 0,
        respond_ns: 0,
        total_ns: ctx.started.elapsed().as_nanos() as u64,
    });
}

/// Per-request span context threaded from the frame reader into the
/// fixed-box and tiled handlers.
struct ReqCtx<'a> {
    peer: &'a str,
    /// First header bytes on the wire — the span's zero point.
    started: Instant,
    /// Start of the lookup stage (app resolution + validation +
    /// tensor/plan build).
    lookup_t0: Instant,
    decode_ns: u64,
    /// Pool queue depth sampled at admission.
    queue_depth: u64,
    in_words: u64,
}

/// Answer an admin `STATS` frame: freeze a snapshot, pack its JSON
/// into payload words, reply `STATUS_OK` with zeroed timing fields.
fn handle_stats(stream: &mut TcpStream) -> Result<()> {
    let m = telemetry::metrics();
    m.stats_requests.inc();
    let json = m.snapshot().to_json();
    let frame = protocol::encode_response(&Response {
        status: protocol::STATUS_OK,
        words: protocol::stats_words(&json),
        cycles: 0,
        micros: 0,
    });
    send_frame(stream, &frame).context("responding to stats query")
}

/// Check request payloads against the expected per-input word counts
/// before any tensor is built (`Tensor::from_data` asserts lengths).
/// The error text enumerates expected vs received counts per input —
/// it travels back to the client as the `STATUS_BAD_REQUEST` detail
/// payload, replacing the old opaque status word.
fn check_input_words(app: &str, expect: &[(&str, i64)], inputs: &[Vec<i32>]) -> Result<()> {
    let got: Vec<usize> = inputs.iter().map(|w| w.len()).collect();
    check_input_counts(app, expect, &got)
}

/// The count-only core of [`check_input_words`]: the zero-copy tiled
/// path validates its [`protocol::WordsRange`] lengths here without
/// ever materializing the payload words.
fn check_input_counts(app: &str, expect: &[(&str, i64)], got: &[usize]) -> Result<()> {
    if got.len() != expect.len() {
        let decl: Vec<String> = expect
            .iter()
            .map(|(name, want)| format!("{name}={want} words"))
            .collect();
        bail!(
            "app {app}: expected {} inputs ({}), got {}",
            expect.len(),
            decl.join(", "),
            got.len()
        );
    }
    let mut bad = Vec::new();
    for ((name, want), &got) in expect.iter().zip(got) {
        if got as i64 != *want {
            bad.push(format!("input {name}: got {got} words, expected {want}"));
        }
    }
    anyhow::ensure!(bad.is_empty(), "app {app}: {}", bad.join("; "));
    Ok(())
}

/// Expected word counts for the fixed-box (v1/v2) path: the app's
/// declared per-tile input boxes.
fn declared_words(c: &Compiled) -> Vec<(&str, i64)> {
    c.lp
        .inputs
        .iter()
        .map(|name| (name.as_str(), c.lp.buffers[name].cardinality()))
        .collect()
}

/// One connection-cached slot per design: the reusable engine run plus
/// the tiled path's gather/output scratch. The scratch is built lazily
/// (the fixed-box path never pays for it) and is keyed per *design*,
/// not per extent — every tile plan of a design gathers into the same
/// compiled input boxes, so one scratch serves all requested extents.
struct RunSlot {
    key: usize,
    run: EngineRun,
    scratch: Option<TileScratch>,
}

/// The connection's cached per-design runner, built on first use —
/// shared by the fixed-box and tiled paths so neither pays
/// per-request engine setup (`runs` is keyed by design identity; a
/// connection may interleave apps).
fn runner_for<'a>(
    runs: &'a mut Vec<RunSlot>,
    c: &Arc<Compiled>,
    engine: Engine,
) -> Result<&'a mut RunSlot> {
    let key = Arc::as_ptr(c) as usize;
    if let Some(i) = runs.iter().position(|s| s.key == key) {
        return Ok(&mut runs[i]);
    }
    runs.push(RunSlot { key, run: c.runner(engine)?, scratch: None });
    Ok(runs.last_mut().expect("just pushed"))
}

/// Handle one client connection: frames in, simulated tiles out,
/// until the peer disconnects. Errors are reported to the client as a
/// status frame before the connection drops (public so drivers can
/// embed the server with their own accept loop).
///
/// §Perf: request handling performs **no per-request setup** — the
/// compile-grade half lives in the design's cached [`crate::exec::ExecPlan`]
/// / [`crate::cgra::SimPlan`] (built once per app), and the connection
/// keeps one reusable [`EngineRun`] per app it has served, so a
/// request pays only the execution itself plus decoding its own
/// payload (docs/execution.md, docs/simulator.md). Under the default
/// `Auto` engine that execution is the functional engine's fused
/// kernels — microseconds, not a cycle loop.
pub fn handle_connection(cfg: &ServeConfig, stream: &mut TcpStream) -> Result<()> {
    let m = telemetry::metrics();
    m.connections_opened.inc();
    // Count the close however the connection ends — clean EOF, error
    // return, or a panic unwinding out through the pool's
    // catch_unwind.
    struct CloseGuard;
    impl Drop for CloseGuard {
        fn drop(&mut self) {
            telemetry::metrics().connections_closed.inc();
        }
    }
    let _close = CloseGuard;
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".to_string());
    // Reusable per-app run state, keyed by design identity (a
    // connection may interleave v2 requests for different apps).
    let mut runs: Vec<RunSlot> = Vec::new();
    loop {
        let (buf, started) = match read_frame_bytes(stream) {
            Ok(Some(f)) => f,
            Ok(None) => return Ok(()),
            Err(e) => {
                // Framing errors carry precise, client-safe messages
                // (cap overruns name the field and the cap) — send
                // them as the diagnostic like every semantic error.
                fail_rec(
                    0,
                    "?",
                    &ReqCtx {
                        peer: &peer,
                        started: Instant::now(),
                        lookup_t0: Instant::now(),
                        decode_ns: 0,
                        queue_depth: m.queue_depth.get(),
                        in_words: 0,
                    },
                );
                write_error_detail(stream, protocol::STATUS_BAD_REQUEST, &format!("{e:#}"));
                return Err(e.context(format!("client {peer}")));
            }
        };
        // Admin STATS frames are exactly `magic | ADMIN_STATS` (the
        // only 8-byte frame whose second word is the stats sentinel).
        if buf.len() == 8 && buf[4..8] == protocol::ADMIN_STATS.to_le_bytes() {
            handle_stats(stream)?;
            continue;
        }
        // Borrowing decode: payload words stay in `buf` as ranges, so
        // the v3 path hands the frame itself to the tile batch.
        let view = match protocol::decode_request_view(&buf) {
            Ok((view, _)) => view,
            Err(e) => {
                fail_rec(
                    0,
                    "?",
                    &ReqCtx {
                        peer: &peer,
                        started,
                        lookup_t0: Instant::now(),
                        decode_ns: started.elapsed().as_nanos() as u64,
                        queue_depth: m.queue_depth.get(),
                        in_words: 0,
                    },
                );
                write_error_detail(stream, protocol::STATUS_BAD_REQUEST, &format!("{e}"));
                return Err(anyhow::Error::new(e).context(format!("client {peer}")));
            }
        };
        let decode_ns = started.elapsed().as_nanos() as u64;
        let version: u8 = match (&view.extent, &view.app) {
            (Some(_), _) => 3,
            (None, Some(_)) => 2,
            (None, None) => 1,
        };
        let ctx = ReqCtx {
            peer: &peer,
            started,
            lookup_t0: Instant::now(),
            decode_ns,
            queue_depth: m.queue_depth.get(),
            in_words: view.inputs.iter().map(|r| r.words as u64).sum(),
        };
        let set: Arc<VariantSet> = match view.app {
            Some(name) => match cfg.registry.get_variants(name) {
                Ok(s) => s,
                Err(e) => {
                    fail_rec(version, name, &ctx);
                    write_error(stream, protocol::STATUS_UNKNOWN_APP);
                    bail!("client {peer}: {e:#}");
                }
            },
            None => match &cfg.default_set {
                Some(s) => Arc::clone(s),
                None => {
                    fail_rec(version, "?", &ctx);
                    write_error(stream, protocol::STATUS_UNKNOWN_APP);
                    bail!("client {peer}: v1 frame on a server with no default app (send v2 frames with an app name)");
                }
            },
        };
        // The extent and input ranges own no part of `buf`; moving
        // them out ends the view's borrow so the v3 path can take the
        // frame buffer itself.
        let extent = view.extent;
        let ranges = view.inputs;
        // Variant selection (docs/routing.md): v3 requests are
        // extent-addressed, so any variant serves identical bytes —
        // route them by live load. Fixed-box v1/v2 payloads are
        // shaped by the compiled tile box, so they always see the
        // set's primary variant. Every variant is its own `Compiled`,
        // so `runner_for`'s design-identity key gives each variant
        // its own warmed per-connection slot automatically.
        let chosen = if extent.is_some() {
            let sig = LoadSignals {
                queue_depth: ctx.queue_depth,
                backlog: cfg.sched.backlog(),
                workers: cfg.workers.max(1) as u64,
                workers_busy: m.workers_busy.get(),
            };
            cfg.route.decide(&set.primary().compiled.program.name, &set, &sig)
        } else {
            0
        };
        let variant = set.variants()[chosen].role;
        let c: Arc<Compiled> = Arc::clone(&set.variants()[chosen].compiled);
        drop(set);
        // v3: arbitrary-extent requests take the tiling path — plan,
        // fan tiles out across idle pool workers, stitch, respond.
        if let Some(extent) = extent {
            match handle_tiled(cfg, stream, &c, variant, &extent, buf, ranges, &mut runs, &ctx)
            {
                Ok(()) => continue,
                Err(e) => return Err(e),
            }
        }
        // Fixed-box (v1/v2) path: materialize the owned payload words
        // the tensor build needs — the same single frame→Vec copy as
        // before the view decode existed.
        let payloads: Vec<Vec<i32>> = ranges.iter().map(|r| r.to_vec(&buf)).collect();
        if let Err(e) = check_input_words(&c.program.name, &declared_words(&c), &payloads) {
            fail_rec(version, &c.program.name, &ctx);
            write_error_detail(stream, protocol::STATUS_BAD_REQUEST, &format!("{e:#}"));
            return Err(e.context(format!("client {peer}")));
        }
        let mut inputs = BTreeMap::new();
        for (name, words) in c.lp.inputs.iter().zip(payloads) {
            inputs.insert(name.clone(), Tensor::from_data(c.lp.buffers[name].clone(), words));
        }
        let run = match runner_for(&mut runs, &c, cfg.engine) {
            Ok(slot) => &mut slot.run,
            Err(e) => {
                fail_rec(version, &c.program.name, &ctx);
                write_error(stream, protocol::STATUS_INTERNAL);
                return Err(e.context(format!("planning {} for {peer}", c.program.name)));
            }
        };
        let engine_name = run.engine().name();
        let lookup_ns = ctx.lookup_t0.elapsed().as_nanos() as u64;
        let t0 = Instant::now();
        let res = match run.run(&inputs) {
            Ok(res) => res,
            Err(e) => {
                fail_rec(version, &c.program.name, &ctx);
                write_error(stream, protocol::STATUS_INTERNAL);
                return Err(e.context(format!("executing {} for {peer}", c.program.name)));
            }
        };
        let execute_ns = t0.elapsed().as_nanos() as u64;
        let micros = execute_ns / 1000;
        let cycles = res.stats.cycles as u64;
        let words = res.output.data;
        let out_words = words.len() as u64;
        let respond_t0 = Instant::now();
        let frame = protocol::encode_response(&Response {
            status: protocol::STATUS_OK,
            words,
            cycles,
            micros,
        });
        if let Err(e) = send_frame(stream, &frame) {
            fail_rec(version, &c.program.name, &ctx);
            return Err(e).context(format!("responding to {peer}"));
        }
        let rec = RequestRecord {
            app: c.program.name.clone(),
            engine: engine_name,
            variant,
            version,
            ok: true,
            tiles: 1,
            in_words: ctx.in_words,
            out_words,
            cycles,
            queue_depth: ctx.queue_depth,
            decode_ns,
            lookup_ns,
            execute_ns,
            stitch_ns: 0,
            respond_ns: respond_t0.elapsed().as_nanos() as u64,
            total_ns: started.elapsed().as_nanos() as u64,
        };
        // The `[req]` line is a stable script interface (format
        // frozen); it is printed from the same record the registry
        // keeps, so the two can never disagree.
        if cfg.stats {
            eprintln!(
                "[req] client={peer} app={} engine={} in_words={} out_words={} cycles={} exec_us={}",
                rec.app,
                rec.engine,
                rec.in_words,
                rec.out_words,
                rec.cycles,
                rec.execute_ns / 1000
            );
        }
        m.record_request(rec);
    }
}

/// Serve one v3 (whole-image) request on an open connection: plan the
/// tiling (cached per extent on the design, built single-flight),
/// validate the whole-image inputs, register the [`TileBatch`] with
/// the shared [`TileScheduler`], wake idle pool workers, drain
/// through the scheduler, stitch, respond. Client-caused failures
/// answer `STATUS_BAD_REQUEST` with a packed diagnostic; like every
/// non-OK path, the connection closes afterwards (`Err` return).
///
/// §Perf: the whole-image payload is **zero-copy** — it stays as
/// little-endian words inside the request frame (`frame_buf` +
/// `ranges`, from [`protocol::decode_request_view`]), owned by the
/// batch and gathered directly into per-tile scratch
/// ([`crate::tile::ImageSource`]). The old path copied every payload
/// frame → `Vec<i32>` → scratch.
#[allow(clippy::too_many_arguments)]
fn handle_tiled(
    cfg: &ServeConfig,
    stream: &mut TcpStream,
    c: &Arc<Compiled>,
    variant: &'static str,
    extent: &[i64],
    frame_buf: Vec<u8>,
    ranges: Vec<protocol::WordsRange>,
    runs: &mut Vec<RunSlot>,
    ctx: &ReqCtx<'_>,
) -> Result<()> {
    let peer = ctx.peer;
    let app = c.program.name.clone();
    let plan = match c.tile_plan(extent) {
        Ok(p) => p,
        Err(e) => {
            fail_rec(3, &app, ctx);
            let msg = format!("app {app}: cannot tile output extent {extent:?}: {e:#}");
            write_error_detail(stream, protocol::STATUS_BAD_REQUEST, &msg);
            bail!("client {peer}: {msg}");
        }
    };
    let got: Vec<usize> = ranges.iter().map(|r| r.words).collect();
    if let Err(e) = check_input_counts(&app, &plan.expected_words(), &got) {
        fail_rec(3, &app, ctx);
        write_error_detail(stream, protocol::STATUS_BAD_REQUEST, &format!("{e:#}"));
        return Err(e.context(format!("client {peer} (extent {extent:?})")));
    }
    let lookup_ns = ctx.lookup_t0.elapsed().as_nanos() as u64;
    let exec_t0 = Instant::now();
    let batch = match TileBatch::new_frame(
        Arc::clone(c),
        cfg.engine,
        Arc::clone(&plan),
        frame_buf,
        ranges.iter().map(|r| (r.byte_off, r.words)).collect(),
    ) {
        Ok(b) => b,
        Err(e) => {
            fail_rec(3, &app, ctx);
            write_error_detail(stream, protocol::STATUS_INTERNAL, &format!("{e:#}"));
            return Err(e.context(format!("batching {app} for {peer}")));
        }
    };
    let m = telemetry::metrics();
    // Register with the shared scheduler, then wake idle workers with
    // Drain tokens. A saturated pool (try_send fails, or the tokens
    // sit queued until the batch is over) just leaves the drain to
    // this thread and its sibling submitters; stale tokens are free.
    cfg.sched.submit(&batch);
    m.sched_batches.inc();
    let recruit = cfg
        .helpers
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .clone();
    if let Some(tx) = recruit {
        let extra = cfg
            .workers
            .saturating_sub(1)
            .min(batch.tile_count().saturating_sub(1));
        for _ in 0..extra {
            match tx.try_send(Job::Drain) {
                Ok(()) => m.queue_depth.inc(),
                Err(_) => break,
            }
        }
    }
    // Fail fast if this connection cannot run the app at all — the
    // drain loop below treats a runner error as "skip", which is only
    // sound for *foreign* batches (whose own submitter hits this same
    // deterministic error and fails their request).
    if let Err(e) = runner_for(runs, c, cfg.engine) {
        fail_rec(3, &app, ctx);
        write_error_detail(stream, protocol::STATUS_INTERNAL, &format!("{e:#}"));
        return Err(e.context(format!("planning {app} for {peer}")));
    }
    // Drain through the shared scheduler until this request's batch
    // completes. Most claims land on our own batch (oldest-first
    // weighting), but claims for sibling requests are taken too —
    // that cross-service is what keeps N concurrent images advancing
    // together instead of serializing. Progress never depends on
    // recruitment: with no siblings and no idle workers this loop is
    // exactly the old drain-it-yourself path. The per-design
    // [`RunSlot`] cache makes the warm drain allocation-free
    // (gathers, per-tile output, and stitch coordinates all reuse the
    // slot's buffers; see `crate::tile::run`).
    loop {
        if batch.is_done() {
            break;
        }
        let Some(b) = cfg.sched.claim() else {
            // No unclaimed tiles anywhere: ours are all claimed,
            // possibly still executing on other workers — wait()
            // below blocks until they land.
            break;
        };
        let mine = Arc::ptr_eq(&b, &batch);
        let slot = match runner_for(runs, b.compiled(), b.engine()) {
            Ok(s) => s,
            Err(_) => {
                // A foreign design this connection cannot plan. Its
                // own submitter hits the same deterministic error,
                // fails the request, and drops the batch (pruning
                // it); yield instead of spinning until then.
                std::thread::yield_now();
                continue;
            }
        };
        let scratch = slot.scratch.get_or_insert_with(|| TileScratch::new(b.plan()));
        let done = b.work_run(&mut slot.run, scratch);
        if done > 0 && !mine {
            m.sched_cross_tiles.add(done as u64);
        }
    }
    let execute_ns = exec_t0.elapsed().as_nanos() as u64;
    let stitch_t0 = Instant::now();
    let res = match batch.wait() {
        Ok(r) => r,
        Err(e) => {
            fail_rec(3, &app, ctx);
            write_error_detail(stream, protocol::STATUS_INTERNAL, &format!("{e:#}"));
            return Err(e.context(format!("tiled execution of {app} for {peer}")));
        }
    };
    let stitch_ns = stitch_t0.elapsed().as_nanos() as u64;
    let micros = (execute_ns + stitch_ns) / 1000;
    let cycles = res.stats.cycles as u64;
    let out_words = res.output.data.len() as u64;
    let respond_t0 = Instant::now();
    let frame = protocol::encode_response(&Response {
        status: protocol::STATUS_OK,
        words: res.output.data,
        cycles,
        micros,
    });
    if let Err(e) = send_frame(stream, &frame) {
        fail_rec(3, &app, ctx);
        return Err(e).context(format!("responding to {peer}"));
    }
    let rec = RequestRecord {
        app,
        engine: res.engine.name(),
        variant,
        version: 3,
        ok: true,
        tiles: res.tiles as u64,
        in_words: ctx.in_words,
        out_words,
        cycles,
        queue_depth: ctx.queue_depth,
        decode_ns: ctx.decode_ns,
        lookup_ns,
        execute_ns,
        stitch_ns,
        respond_ns: respond_t0.elapsed().as_nanos() as u64,
        total_ns: ctx.started.elapsed().as_nanos() as u64,
    };
    // Same stable `[req]` interface as the fixed-box path, derived
    // from the record.
    if cfg.stats {
        eprintln!(
            "[req] client={peer} app={} engine={} extent={extent:?} tiles={} \
             out_words={} cycles={} exec_us={micros}",
            rec.app, rec.engine, rec.tiles, rec.out_words, rec.cycles
        );
    }
    telemetry::metrics().record_request(rec);
    Ok(())
}

/// A connection handler, as [`serve_on_with`] accepts it. Production
/// serving always uses [`handle_connection`]; tests inject faulting
/// handlers to exercise the pool's isolation guarantees.
pub type Handler = dyn Fn(&ServeConfig, &mut TcpStream) -> Result<()> + Send + Sync;

/// `PUSHMEM_ACCEPT_SHARDS`: acceptor threads sharing the listener.
/// Default 2; the caller clamps to `1..=MAX_ACCEPT_SHARDS`.
fn env_accept_shards() -> usize {
    std::env::var("PUSHMEM_ACCEPT_SHARDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2)
}

/// The admission rejection's backpressure hint: scale with what a
/// queued client would actually wait behind — the jobs already queued
/// plus the in-flight tile backlog spread across the pool — and clamp
/// to `[1, 1000]` ms so a pathological backlog can never tell clients
/// to sleep for minutes.
fn retry_hint_ms(cfg: &ServeConfig, workers: u64) -> u64 {
    let m = telemetry::metrics();
    (1 + 2 * m.queue_depth.get() + cfg.sched.backlog() / workers.max(1)).clamp(1, 1000)
}

/// Refuse admission: answer `STATUS_BUSY` with a retry hint, then
/// close. Order matters — the busy frame is written **first**, and
/// the peer's already-sent request bytes are drained afterwards:
/// closing a socket with unread inbound data makes the kernel send
/// RST, which can discard the peer's unread busy frame in flight.
/// Every step is bounded (short timeouts, a byte budget) so a hostile
/// peer cannot pin the acceptor.
fn reject_busy(mut stream: TcpStream, retry_after_ms: u64) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
    let _ = stream.write_all(&protocol::encode_busy(retry_after_ms));
    let _ = stream.flush();
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut sink = [0u8; 4096];
    let mut budget: usize = 1 << 20;
    loop {
        match stream.read(&mut sink) {
            Ok(0) | Err(_) => break, // EOF, timeout, or reset
            Ok(n) => {
                budget = budget.saturating_sub(n);
                if budget == 0 {
                    break;
                }
            }
        }
    }
}

/// One acceptor shard's loop: accept, try to enqueue, and on a full
/// queue answer `STATUS_BUSY` + retry hint instead of parking (the
/// pre-scheduler fallback blocked the lone acceptor on `tx.send`, so
/// a saturated pool silently hung every later client). Returns when
/// the pool's queue disconnects. Counters account exactly: every
/// accept lands in `accepts_shard<i>`, and every rejection bumps both
/// `queue_full` and `requests_busy`.
fn accept_loop(
    listener: &TcpListener,
    shard: usize,
    tx: &mpsc::SyncSender<Job>,
    cfg: &ServeConfig,
    workers: usize,
) {
    let m = telemetry::metrics();
    // One log line per interval on the accept-error path — a listener
    // stuck on EMFILE returns errors in a tight loop and must not
    // flood stderr (the `accept_errors` counter keeps the true rate).
    let accept_rl = log::RateLimited::new(Duration::from_secs(5));
    for stream in listener.incoming() {
        match stream {
            // try_send first so pool saturation is visible to the
            // operator and the client both (a silently queued-forever
            // client hangs otherwise).
            Ok(s) => {
                m.accepts_by_shard[shard].inc();
                match tx.try_send(Job::Conn(s, Instant::now())) {
                    Ok(()) => m.queue_depth.inc(),
                    Err(mpsc::TrySendError::Full(Job::Conn(s, _))) => {
                        m.queue_full.inc();
                        m.requests_busy.inc();
                        let retry = retry_hint_ms(cfg, workers as u64);
                        log::warn(
                            "serve",
                            &format!(
                                "event=admission_reject shard={shard} workers={workers} \
                                 retry_after_ms={retry} msg=\"pool saturated; client told to retry\""
                            ),
                        );
                        reject_busy(s, retry);
                    }
                    // Only Conn jobs originate here.
                    Err(mpsc::TrySendError::Full(Job::Drain)) => {}
                    Err(mpsc::TrySendError::Disconnected(_)) => return,
                }
            }
            Err(e) => {
                // Persistent accept failures (e.g. EMFILE under fd
                // exhaustion) must shed load, not busy-spin.
                m.accept_errors.inc();
                if let Some(suppressed) = accept_rl.admit() {
                    log::error(
                        "serve",
                        &format!("event=accept_error shard={shard} err={e} suppressed={suppressed}"),
                    );
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Run the accept loop on an already-bound listener with a bounded
/// pool of `cfg.workers` connection-handler threads. Accepted
/// connections queue on a bounded channel when every worker is busy,
/// and queue overflow is answered `STATUS_BUSY` + retry hint — bounded
/// admission instead of unbounded spawning or silent parking.
/// Embeddable: tests and examples bind an ephemeral port themselves.
pub fn serve_on(listener: TcpListener, cfg: ServeConfig) -> Result<()> {
    serve_on_with(listener, cfg, Arc::new(handle_connection))
}

/// [`serve_on`] with an injectable per-connection handler (the test
/// seam for pool-isolation tests; everything else should call
/// [`serve_on`]).
///
/// Fault isolation: one connection must never take the pool down.
/// A panicking handler is caught (`catch_unwind`), answered with
/// `STATUS_INTERNAL` best-effort, and its worker keeps serving; a
/// panic elsewhere that poisons the queue mutex is recovered
/// (`PoisonError::into_inner` — the queue holds only streams and
/// batch handles, so there is no invariant a poisoner could have
/// broken mid-update). Tile-batch jobs contain their own panics (see
/// [`crate::tile::run`]), so a worker surviving them needs no extra
/// guard here.
///
/// Serving turns telemetry sampling on ([`telemetry::set_sampling`])
/// so the exec/tile hot-path hooks record; standalone CLI runs leave
/// it off and pay one relaxed bool load per dispatch (DESIGN.md §8).
pub fn serve_on_with(
    listener: TcpListener,
    cfg: ServeConfig,
    handler: Arc<Handler>,
) -> Result<()> {
    telemetry::set_sampling(true);
    let workers = cfg.workers.max(1);
    telemetry::metrics().workers_total.set(workers as u64);
    let queue_cap = cfg.queue_cap.unwrap_or(2 * workers).max(1);
    let shards = cfg
        .accept_shards
        .unwrap_or_else(env_accept_shards)
        .clamp(1, MAX_ACCEPT_SHARDS);
    let (tx, rx) = mpsc::sync_channel::<Job>(queue_cap);
    // Hand the queue to v3 tile fan-out before any connection can
    // arrive; cleared again at shutdown so the channel can disconnect
    // and the workers exit.
    *cfg.helpers.lock().unwrap_or_else(|p| p.into_inner()) = Some(tx.clone());
    let cfg = Arc::new(cfg);
    // Periodic snapshot dumps (--metrics-json): a side thread, never
    // the serving path. Stops (after one final dump) when the accept
    // loop ends.
    let dump_stop = Arc::new(AtomicBool::new(false));
    let dump_handle = cfg.metrics_json.clone().map(|path| {
        let stop = Arc::clone(&dump_stop);
        std::thread::spawn(move || {
            let mut ticks = 0u32;
            loop {
                std::thread::sleep(Duration::from_millis(250));
                let stopping = stop.load(Ordering::Relaxed);
                ticks += 1;
                if stopping || ticks >= 20 {
                    ticks = 0;
                    let json = telemetry::metrics().snapshot().to_json();
                    if let Err(e) = std::fs::write(&path, json) {
                        log::warn(
                            "serve",
                            &format!("event=metrics_dump_failed path={} err={e}", path.display()),
                        );
                    }
                }
                if stopping {
                    return;
                }
            }
        })
    });
    let rx = Arc::new(Mutex::new(rx));
    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let rx = Arc::clone(&rx);
        let cfg = Arc::clone(&cfg);
        let handler = Arc::clone(&handler);
        handles.push(std::thread::spawn(move || {
            // Per-worker engine runners, persistent across jobs: the
            // pool serves many requests for the same few apps, and
            // this warmed cache is what makes the Nth concurrent
            // request pay no engine setup (it coalesces onto slots
            // built by earlier drains).
            let mut runs: Vec<RunSlot> = Vec::new();
            loop {
                // The guard is a temporary: the lock is released as
                // soon as recv returns, before the job is handled. A
                // poisoned lock is recovered, not propagated — one
                // dead peer must not cascade the whole pool down.
                let next = rx
                    .lock()
                    .unwrap_or_else(|poisoned| poisoned.into_inner())
                    .recv();
                let job = match next {
                    Ok(job) => job,
                    Err(_) => return, // accept loop gone
                };
                let m = telemetry::metrics();
                m.queue_depth.dec();
                m.workers_busy.inc();
                let busy_t0 = Instant::now();
                match job {
                    Job::Conn(mut stream, queued) => {
                        m.jobs_conn.inc();
                        m.accept_wait.record_ns(queued.elapsed().as_nanos() as u64);
                        let outcome =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                handler(&cfg, &mut stream)
                            }));
                        match outcome {
                            Ok(Ok(())) => {}
                            Ok(Err(e)) => {
                                log::warn("serve", &format!("event=connection_error err={e:#}"))
                            }
                            Err(_) => {
                                // The handler panicked mid-connection:
                                // report an internal error to the peer
                                // (best-effort) and keep this worker
                                // alive for the next connection.
                                write_error(&mut stream, protocol::STATUS_INTERNAL);
                                log::error(
                                    "serve",
                                    "event=handler_panic msg=\"worker recovered\"",
                                );
                            }
                        }
                    }
                    Job::Drain => {
                        // Join the cross-request tile drain: claim one
                        // short run of tiles at a time from the shared
                        // scheduler — which batch each claim serves is
                        // its call — until no batch has unclaimed
                        // tiles. Tile
                        // panics are contained inside the batch, and a
                        // stale token (the batch drained or its
                        // request died before this worker came free)
                        // falls straight through.
                        m.jobs_tiles.inc();
                        while let Some(b) = cfg.sched.claim() {
                            let slot = match runner_for(&mut runs, b.compiled(), b.engine()) {
                                Ok(s) => s,
                                Err(_) => {
                                    // The batch's own submitter hits
                                    // this same deterministic planning
                                    // error and drops it; don't spin.
                                    std::thread::yield_now();
                                    continue;
                                }
                            };
                            let scratch =
                                slot.scratch.get_or_insert_with(|| TileScratch::new(b.plan()));
                            // Pool workers never submit batches, so
                            // every tile they drain is cross-request
                            // service.
                            let done = b.work_run(&mut slot.run, scratch);
                            m.sched_cross_tiles.add(done as u64);
                        }
                    }
                }
                m.workers_busy.dec();
                m.worker_busy_ns.add(busy_t0.elapsed().as_nanos() as u64);
            }
        }));
    }
    // Sharded accept: shards 1..K run on their own threads over
    // `try_clone`d handles of the same listener (the kernel load-
    // balances accepts across blocked acceptors); shard 0 runs here.
    // The extra acceptors are detached — they hold only the listener
    // and a queue sender, and exit when the queue disconnects under
    // them (joining them would block shutdown on one more accept).
    for shard in 1..shards {
        match listener.try_clone() {
            Ok(l) => {
                let tx = tx.clone();
                let cfg = Arc::clone(&cfg);
                std::thread::spawn(move || accept_loop(&l, shard, &tx, &cfg, workers));
            }
            Err(e) => {
                // Fewer shards is a performance regression, not a
                // correctness one; shard 0 still accepts everything.
                log::warn(
                    "serve",
                    &format!("event=accept_shard_clone_failed shard={shard} err={e}"),
                );
            }
        }
    }
    accept_loop(&listener, 0, &tx, &cfg, workers);
    cfg.helpers.lock().unwrap_or_else(|p| p.into_inner()).take();
    drop(tx);
    for h in handles {
        let _ = h.join();
    }
    dump_stop.store(true, Ordering::Relaxed);
    if let Some(h) = dump_handle {
        let _ = h.join();
    }
    Ok(())
}

/// Serve one pre-compiled app forever (the `pushmem serve <app>`
/// path; v1 frames hit this app, v2 frames may name any other
/// registered app). `cli_name` is the `pushmem list` name the design
/// is cached under; `workers` bounds concurrent connections (a
/// connection holds its worker until disconnect — DESIGN.md §2);
/// `metrics_json` enables periodic telemetry snapshot dumps.
pub fn serve(
    cli_name: &str,
    c: Compiled,
    addr: &str,
    workers: usize,
    stats: bool,
    engine: Engine,
    metrics_json: Option<std::path::PathBuf>,
) -> Result<()> {
    let set = Arc::new(VariantSet::solo(Arc::new(c)));
    serve_set(cli_name, set, addr, workers, stats, engine, metrics_json)
}

/// [`serve`] over a pre-built [`VariantSet`] — the
/// `pushmem serve <app> --tuned-dir` path, where the tuner's
/// persisted Pareto front yields multiple variants and v3 requests
/// are routed between them by live load (docs/routing.md).
pub fn serve_set(
    cli_name: &str,
    set: Arc<VariantSet>,
    addr: &str,
    workers: usize,
    stats: bool,
    engine: Engine,
    metrics_json: Option<std::path::PathBuf>,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let roles: Vec<&str> = set.variants().iter().map(|v| v.role).collect();
    let c = &set.primary().compiled;
    log::info(
        "serve",
        &format!(
            "event=listening app={} addr={addr} pes={} mem_tiles={} cycles_per_tile={} workers={workers} engine={} variants={}",
            c.program.name,
            c.design.pe_count(),
            c.design.mem_tiles(),
            c.graph.completion,
            engine.name(),
            roles.join(",")
        ),
    );
    let mut cfg = ServeConfig::single_set(cli_name, set);
    cfg.workers = workers;
    cfg.stats = stats;
    cfg.engine = engine;
    cfg.metrics_json = metrics_json;
    serve_on(listener, cfg)
}

/// Serve every app in `registry` on one endpoint forever (the
/// `pushmem serve-all` path). Designs compile lazily on first
/// request unless the registry was warmed. `stats` prints one
/// `[req]` line per served request; `metrics_json` enables periodic
/// telemetry snapshot dumps.
pub fn serve_all(
    registry: Arc<CompiledRegistry>,
    addr: &str,
    workers: usize,
    stats: bool,
    engine: Engine,
    metrics_json: Option<std::path::PathBuf>,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let warmed = registry.compiled_names();
    log::info(
        "serve",
        &format!(
            "event=listening_all addr={addr} workers={workers} engine={} precompiled={}",
            engine.name(),
            if warmed.is_empty() { "none(lazy)".to_string() } else { warmed.join(",") }
        ),
    );
    let mut cfg = ServeConfig::multi(registry, workers);
    cfg.stats = stats;
    cfg.engine = engine;
    cfg.metrics_json = metrics_json;
    serve_on(listener, cfg)
}

/// Client helper: send one v1 request (implicit default app), get
/// `(output words, cycles, µs)`.
pub fn request(stream: &mut TcpStream, inputs: &[&Tensor]) -> Result<(Vec<i32>, u64, u64)> {
    let refs: Vec<&[i32]> = inputs.iter().map(|t| t.data.as_slice()).collect();
    roundtrip(stream, protocol::encode_request_v1(&refs))
}

/// Client helper: send one v2 request naming `app`.
pub fn request_app(
    stream: &mut TcpStream,
    app: &str,
    inputs: &[&Tensor],
) -> Result<(Vec<i32>, u64, u64)> {
    let refs: Vec<&[i32]> = inputs.iter().map(|t| t.data.as_slice()).collect();
    roundtrip(stream, protocol::encode_request_v2(app, &refs))
}

/// Client helper: send one v3 whole-image request at `extent`
/// (`app = None` targets the server's default app); inputs are the
/// whole-image tensors over the tile planner's boxes
/// ([`crate::coordinator::Compiled::tile_plan`], docs/tiling.md).
pub fn request_extent(
    stream: &mut TcpStream,
    app: Option<&str>,
    extent: &[i64],
    inputs: &[&Tensor],
) -> Result<(Vec<i32>, u64, u64)> {
    let refs: Vec<&[i32]> = inputs.iter().map(|t| t.data.as_slice()).collect();
    roundtrip(stream, protocol::encode_request_v3(app, extent, &refs))
}

/// Client helper: query the server's telemetry snapshot over the wire
/// (the admin `STATS` frame, docs/observability.md). Returns the raw
/// JSON string.
pub fn request_stats(stream: &mut TcpStream) -> Result<String> {
    stream.write_all(&protocol::encode_stats_request())?;
    stream.flush()?;
    let resp = read_response(stream)?;
    if resp.status != protocol::STATUS_OK {
        bail!("server error status {}", resp.status);
    }
    Ok(protocol::detail_from_words(&resp.words))
}

fn roundtrip(stream: &mut TcpStream, frame: Vec<u8>) -> Result<(Vec<i32>, u64, u64)> {
    stream.write_all(&frame)?;
    stream.flush()?;
    let resp = read_response(stream)?;
    if resp.status != protocol::STATUS_OK {
        let detail = protocol::detail_from_words(&resp.words);
        if detail.is_empty() {
            bail!("server error status {}", resp.status);
        }
        bail!("server error status {}: {detail}", resp.status);
    }
    Ok((resp.words, resp.cycles, resp.micros))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::cgra::simulate;
    use crate::coordinator::driver::{compile, gen_inputs};

    fn spawn_server(cfg: ServeConfig) -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || serve_on(listener, cfg));
        addr
    }

    #[test]
    fn serve_roundtrip_over_localhost_v1() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let inputs = gen_inputs(&c.lp);
        let expect = simulate(&c.design, &c.graph, &inputs).unwrap().output.data;
        let ordered: Vec<Tensor> =
            c.lp.inputs.iter().map(|n| inputs[n].clone()).collect();

        let addr = spawn_server(ServeConfig::single("g14", c));
        let mut stream = TcpStream::connect(addr).unwrap();
        let refs: Vec<&Tensor> = ordered.iter().collect();
        // Two requests on one connection: the loop must persist.
        for _ in 0..2 {
            let (words, cycles, _) = request(&mut stream, &refs).unwrap();
            assert_eq!(words, expect);
            assert!(cycles > 0);
        }
    }

    #[test]
    fn v2_frame_shares_the_seeded_default_design() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let inputs = gen_inputs(&c.lp);
        let expect = simulate(&c.design, &c.graph, &inputs).unwrap().output.data;
        let ordered: Vec<Tensor> =
            c.lp.inputs.iter().map(|n| inputs[n].clone()).collect();

        // single() must seed the registry under the CLI name ("g14" is
        // not a by_name app, so any hit proves it came from the seed,
        // not a recompile).
        let cfg = ServeConfig::single("g14", c);
        let addr = spawn_server(cfg);
        let mut stream = TcpStream::connect(addr).unwrap();
        let refs: Vec<&Tensor> = ordered.iter().collect();
        let (words, _, _) = request_app(&mut stream, "g14", &refs).unwrap();
        assert_eq!(words, expect);
    }

    /// The engine flag changes the execution path, never the bytes on
    /// the wire: exec- and sim-served responses are identical, words
    /// and reported cycles both.
    #[test]
    fn engines_agree_over_the_wire() {
        let prog = apps::gaussian::build(14);
        let inputs = gen_inputs(&compile(&prog).unwrap().lp);
        let ordered: Vec<Tensor> = inputs.values().cloned().collect();
        let refs: Vec<&Tensor> = ordered.iter().collect();

        let mut answers = Vec::new();
        for engine in [Engine::Exec, Engine::Sim] {
            let mut cfg = ServeConfig::single("g14", compile(&prog).unwrap());
            cfg.engine = engine;
            let addr = spawn_server(cfg);
            let mut stream = TcpStream::connect(addr).unwrap();
            answers.push(request(&mut stream, &refs).unwrap());
        }
        let (ew, ec, _) = &answers[0];
        let (sw, sc, _) = &answers[1];
        assert_eq!(ew, sw, "exec and sim served different words");
        assert_eq!(ec, sc, "exec and sim served different cycle counts");
    }

    #[test]
    fn unknown_app_gets_status_frame() {
        let cfg = ServeConfig::multi(Arc::new(CompiledRegistry::new()), 1);
        let addr = spawn_server(cfg);
        let mut stream = TcpStream::connect(addr).unwrap();
        let t = Tensor::from_data(crate::poly::BoxSet::from_extents(&[1]), vec![0]);
        let err = request_app(&mut stream, "definitely_not_an_app", &[&t]).unwrap_err();
        assert!(
            err.to_string().contains(&format!("status {}", protocol::STATUS_UNKNOWN_APP)),
            "{err:#}"
        );
    }

    #[test]
    fn word_count_mismatch_gets_bad_request() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let addr = spawn_server(ServeConfig::single("g14", c));
        let mut stream = TcpStream::connect(addr).unwrap();
        // One input with a wrong word count vs the declared box.
        let t = Tensor::from_data(crate::poly::BoxSet::from_extents(&[3]), vec![1, 2, 3]);
        let err = request(&mut stream, &[&t]).unwrap_err();
        assert!(
            err.to_string().contains(&format!("status {}", protocol::STATUS_BAD_REQUEST)),
            "{err:#}"
        );
    }

    /// v3 whole-image request over the real pool: stitched output is
    /// bit-exact vs the host-side whole-image golden, the plan is
    /// reused across requests, and both the default-app (empty name)
    /// and named forms work.
    #[test]
    fn v3_whole_image_request_stitches() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let extent = vec![33i64, 20];
        let mut full = prog.clone();
        full.schedule.tile = extent.clone();
        let lp = crate::halide::lower::lower(&full).unwrap();
        let inputs = gen_inputs(&lp);
        let want = lp.execute(&inputs).unwrap()[&lp.output].clone();
        let ordered: Vec<Tensor> = lp.inputs.iter().map(|n| inputs[n].clone()).collect();

        let addr = spawn_server(ServeConfig::single("g14", c));
        let mut stream = TcpStream::connect(addr).unwrap();
        let refs: Vec<&Tensor> = ordered.iter().collect();
        for _ in 0..2 {
            let (words, cycles, _) =
                request_extent(&mut stream, None, &extent, &refs).unwrap();
            assert_eq!(words, want.data, "stitched output != whole-image golden");
            assert!(cycles > 0);
        }
        let (words, _, _) =
            request_extent(&mut stream, Some("g14"), &extent, &refs).unwrap();
        assert_eq!(words, want.data);
        // The same connection still serves fixed-box v1 frames after.
        let tile_inputs = gen_inputs(&crate::halide::lower::lower(&prog).unwrap());
        let ordered: Vec<Tensor> =
            prog_inputs_in_order(&prog, &tile_inputs);
        let refs: Vec<&Tensor> = ordered.iter().collect();
        let (words, _, _) = request(&mut stream, &refs).unwrap();
        assert_eq!(words.len(), 14 * 14);
    }

    fn prog_inputs_in_order(
        prog: &crate::halide::Program,
        inputs: &BTreeMap<String, Tensor>,
    ) -> Vec<Tensor> {
        prog.inputs.iter().map(|i| inputs[&i.name].clone()).collect()
    }

    /// The bad-request diagnostic channel: wrong whole-image word
    /// counts come back naming the input with expected vs received.
    #[test]
    fn v3_wrong_word_count_reports_expected_counts() {
        let prog = apps::gaussian::build(14);
        let addr = spawn_server(ServeConfig::single("g14", compile(&prog).unwrap()));
        let mut stream = TcpStream::connect(addr).unwrap();
        let t = Tensor::from_data(crate::poly::BoxSet::from_extents(&[3]), vec![1, 2, 3]);
        let err =
            request_extent(&mut stream, None, &[33, 20], &[&t]).unwrap_err();
        let msg = err.to_string();
        // 33x20 gaussian needs a (33+2)x(20+2) input image.
        assert!(msg.contains("got 3 words, expected 770"), "{msg}");
        assert!(msg.contains("input"), "{msg}");
    }

    /// The fixed-box path gained the same diagnostics: the old opaque
    /// status word now carries expected vs received per input.
    #[test]
    fn v1_word_count_mismatch_detail_names_expected() {
        let prog = apps::gaussian::build(14);
        let addr = spawn_server(ServeConfig::single("g14", compile(&prog).unwrap()));
        let mut stream = TcpStream::connect(addr).unwrap();
        let t = Tensor::from_data(crate::poly::BoxSet::from_extents(&[3]), vec![1, 2, 3]);
        let err = request(&mut stream, &[&t]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&format!("status {}", protocol::STATUS_BAD_REQUEST)), "{msg}");
        assert!(msg.contains("got 3 words, expected 256"), "{msg}");
    }

    /// An untileable extent (wrong rank) earns a diagnostic
    /// BAD_REQUEST, not a dropped connection.
    #[test]
    fn v3_bad_rank_gets_diagnostic_bad_request() {
        let prog = apps::gaussian::build(14);
        let addr = spawn_server(ServeConfig::single("g14", compile(&prog).unwrap()));
        let mut stream = TcpStream::connect(addr).unwrap();
        let t = Tensor::from_data(crate::poly::BoxSet::from_extents(&[3]), vec![1, 2, 3]);
        let err = request_extent(&mut stream, None, &[33], &[&t]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(&format!("status {}", protocol::STATUS_BAD_REQUEST)), "{msg}");
        assert!(msg.contains("cannot tile output extent"), "{msg}");
    }

    #[test]
    fn bad_magic_gets_bad_request_then_close() {
        let prog = apps::gaussian::build(14);
        let addr = spawn_server(ServeConfig::single("g14", compile(&prog).unwrap()));
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(&0xDEAD_BEEFu32.to_le_bytes()).unwrap();
        stream.flush().unwrap();
        let resp = read_response(&mut stream).unwrap();
        assert_eq!(resp.status, protocol::STATUS_BAD_REQUEST);
        // Server closed the connection afterwards.
        let mut rest = Vec::new();
        assert_eq!(stream.read_to_end(&mut rest).unwrap(), 0);
    }

    /// Satellite regression for the old accept-loop saturation
    /// fallback (which parked the acceptor on a blocking `send`, so a
    /// saturated pool silently hung every later client): with
    /// workers=1 and queue_cap=1 there is room for exactly two
    /// connections — one held by the worker, one queued — and a third
    /// concurrent connection must receive `STATUS_BUSY` with a
    /// parseable retry hint and a clean close, never a hang.
    #[test]
    fn saturated_pool_answers_busy_not_hang() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut cfg = ServeConfig::multi(Arc::new(CompiledRegistry::new()), 1);
        cfg.workers = 1;
        cfg.queue_cap = Some(1);
        cfg.accept_shards = Some(1);
        // The injected handler parks until its peer closes, pinning
        // the single worker without any app compilation.
        std::thread::spawn(move || {
            serve_on_with(
                listener,
                cfg,
                Arc::new(|_cfg: &ServeConfig, stream: &mut TcpStream| {
                    let mut b = [0u8; 1];
                    let _ = stream.read(&mut b);
                    Ok(())
                }),
            )
        });
        let conns: Vec<TcpStream> = (0..3)
            .map(|_| {
                let s = TcpStream::connect(addr).unwrap();
                s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
                s
            })
            .collect();
        // Whichever interleaving the accept/dequeue race picks, at
        // least one of the three must be refused; admitted
        // connections just time out their reads (the handler never
        // responds) and hang up, freeing the worker for the next.
        let mut busy = 0;
        for mut s in conns {
            if let Ok(resp) = read_response(&mut s) {
                assert_eq!(resp.status, protocol::STATUS_BUSY);
                let detail = protocol::detail_from_words(&resp.words);
                let hint = protocol::busy_retry_after_ms(&detail)
                    .unwrap_or_else(|| panic!("unparseable busy detail: {detail:?}"));
                assert!((1..=1000).contains(&hint), "retry hint {hint} out of range");
                // The server closes after any non-OK status.
                let mut rest = Vec::new();
                assert_eq!(s.read_to_end(&mut rest).unwrap(), 0, "data after busy frame");
                busy += 1;
            }
        }
        assert!(busy >= 1, "no connection was refused admission");
    }

    /// Multiple acceptor shards serve plain request traffic exactly
    /// like one acceptor: every connection lands on some shard and
    /// round-trips bit-exactly.
    #[test]
    fn sharded_accept_serves_requests() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let inputs = gen_inputs(&c.lp);
        let expect = simulate(&c.design, &c.graph, &inputs).unwrap().output.data;
        let ordered: Vec<Tensor> =
            c.lp.inputs.iter().map(|n| inputs[n].clone()).collect();
        let mut cfg = ServeConfig::single("g14", c);
        cfg.accept_shards = Some(3);
        let addr = spawn_server(cfg);
        let refs: Vec<&Tensor> = ordered.iter().collect();
        for _ in 0..6 {
            let mut stream = TcpStream::connect(addr).unwrap();
            let (words, _, _) = request(&mut stream, &refs).unwrap();
            assert_eq!(words, expect);
        }
    }

    /// A STATS frame answered on a connection interleaved with data
    /// frames: OK status, parseable JSON payload, zeroed timings.
    #[test]
    fn stats_frame_answers_json_on_data_connection() {
        let prog = apps::gaussian::build(14);
        let c = compile(&prog).unwrap();
        let inputs = gen_inputs(&c.lp);
        let ordered: Vec<Tensor> =
            c.lp.inputs.iter().map(|n| inputs[n].clone()).collect();
        let addr = spawn_server(ServeConfig::single("g14", c));
        let mut stream = TcpStream::connect(addr).unwrap();
        let refs: Vec<&Tensor> = ordered.iter().collect();
        let (words, _, _) = request(&mut stream, &refs).unwrap();
        assert_eq!(words.len(), 14 * 14);
        let json = request_stats(&mut stream).unwrap();
        assert!(json.starts_with("{\"schema\":\"pushmem-stats-v1\""), "{json}");
        assert!(json.contains("\"requests_total\":"), "{json}");
        // The connection still serves data frames after the admin
        // frame.
        let (words, _, _) = request(&mut stream, &refs).unwrap();
        assert_eq!(words.len(), 14 * 14);
    }
}
