//! Load-adaptive variant routing: pick which compiled variant of an
//! app serves each whole-image (v3) request, from load signals
//! sampled at admission (docs/routing.md).
//!
//! The policy is a three-level Schmitt trigger over a scalar
//! *pressure* derived from queue depth, tile-scheduler backlog, and
//! worker saturation:
//!
//! ```text
//! level 0 (light)    -> latency-optimal variant
//! level 1 (elevated) -> energy-optimal variant
//! level 2 (heavy)    -> area-optimal variant
//! ```
//!
//! Escalation is immediate (one overloaded sample is enough to start
//! shedding); de-escalation requires pressure to fall strictly below
//! *half* the escalation threshold, so the router cannot flap on a
//! load oscillating around a threshold.
//!
//! Routing never changes results: every variant is a validated
//! bit-exact schedule of the same program, and v3 responses are
//! extent-addressed, so any variant produces identical bytes — the
//! choice affects only cycles, energy, and array footprint. Fixed-box
//! v1/v2 requests are *not* routed (their payload is shaped by the
//! compiled tile box); they always see [`VariantSet::primary`].
//!
//! A co-residency budget models the 16x32 array: the set of variants
//! the policy has routed to ("resident") may not exceed
//! [`PE_BUDGET`] PEs in total, so serve-all deployments cannot
//! configure more simultaneous designs than the fabric holds. When
//! the preferred variant does not fit, the policy degrades along a
//! per-level preference order, and as a last resort serves the
//! smallest-footprint variant of the set.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::coordinator::driver::VariantSet;
use crate::telemetry;

/// PE tiles available on the default 16x32 array (every 4th column is
/// a memory column: 512 * 3/4). Co-resident variants must fit here.
pub const PE_BUDGET: u64 = 384;

/// Pressure at which the router escalates to the energy-optimal
/// variant (level 1). De-escalates at half this.
pub const T_ENERGY: u64 = 2;

/// Pressure at which the router escalates to the area-optimal
/// variant (level 2). De-escalates at half this.
pub const T_AREA: u64 = 8;

/// Per-level variant preference, by role index into
/// [`telemetry::VARIANT_ROLES`] (`0` latency, `1` energy, `2` area,
/// `3` fallback). Earlier entries are tried first; a role absent from
/// the set or over budget falls through to the next.
const PREFS: [[usize; 4]; 3] = [
    [0, 3, 1, 2], // light: fastest first; the hand-written design next
    [1, 2, 0, 3], // elevated: cheapest joules/op first
    [2, 1, 3, 0], // heavy: smallest footprint first
];

/// Instantaneous load sampled at request admission. All fields come
/// from values the serve path already tracks — sampling a signal
/// costs three atomic loads and one scheduler lock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoadSignals {
    /// Connections waiting in the bounded accept queue.
    pub queue_depth: u64,
    /// Unclaimed tiles across in-flight batches
    /// ([`crate::tile::TileScheduler::backlog`]).
    pub backlog: u64,
    /// Worker threads serving the pool (≥ 1).
    pub workers: u64,
    /// Workers currently executing a request.
    pub workers_busy: u64,
}

impl LoadSignals {
    /// Scalar pressure: queued connections dominate (each is a whole
    /// request someone is waiting on), backlog is normalized per
    /// worker (N workers drain N tiles concurrently), and full worker
    /// saturation adds one — so "all workers busy, nothing queued"
    /// registers above idle but below any real queueing.
    pub fn pressure(&self) -> u64 {
        let w = self.workers.max(1);
        let saturated = u64::from(self.workers_busy >= w);
        2 * self.queue_depth + self.backlog / w + saturated
    }
}

/// Hysteresis step: escalate any number of levels at once, come down
/// one level at a time and only once pressure falls strictly below
/// half the threshold that raised it (`2p < T`, so the band
/// `[T/2, T)` holds the level even at the smallest thresholds).
fn next_level(level: usize, pressure: u64) -> usize {
    match level {
        0 => {
            if pressure >= T_AREA {
                2
            } else if pressure >= T_ENERGY {
                1
            } else {
                0
            }
        }
        1 => {
            if pressure >= T_AREA {
                2
            } else if 2 * pressure < T_ENERGY {
                0
            } else {
                1
            }
        }
        _ => {
            if 2 * pressure < T_AREA {
                1
            } else {
                2
            }
        }
    }
}

struct RouteState {
    /// Current Schmitt-trigger level (0, 1, or 2).
    level: usize,
    /// Variants the policy has routed to, keyed `(app, role_index)`,
    /// valued at their PE footprint — the model of what is configured
    /// on the array. Never exceeds [`PE_BUDGET`] in sum except via
    /// the smallest-footprint escape hatch.
    resident: BTreeMap<(String, usize), u64>,
}

/// The routing policy: one per server, shared by every worker. A
/// mutex is fine here — `decide` runs once per v3 request (never on
/// the tile hot path) and holds only integer work.
pub struct RoutePolicy {
    state: Mutex<RouteState>,
}

impl Default for RoutePolicy {
    fn default() -> RoutePolicy {
        RoutePolicy::new()
    }
}

impl RoutePolicy {
    pub fn new() -> RoutePolicy {
        RoutePolicy {
            state: Mutex::new(RouteState { level: 0, resident: BTreeMap::new() }),
        }
    }

    /// Current trigger level (for banners and tests).
    pub fn level(&self) -> usize {
        self.lock().level
    }

    /// Distinct `(app, variant)` pairs routed to so far — the value
    /// the `active_variants` gauge mirrors.
    pub fn resident_count(&self) -> usize {
        self.lock().resident.len()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RouteState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Pick the variant of `set` that should serve one v3 request for
    /// `app` under `sig`, returning its index into
    /// [`VariantSet::variants`]. Updates the trigger level, the
    /// residency model, and the `active_variants` gauge.
    pub fn decide(&self, app: &str, set: &VariantSet, sig: &LoadSignals) -> usize {
        let mut st = self.lock();
        st.level = next_level(st.level, sig.pressure());
        let pick = if set.is_multi() {
            Self::pick_within_budget(&mut st, app, set)
        } else {
            0
        };
        let v = &set.variants()[pick];
        let key = (app.to_string(), v.role_index);
        st.resident.entry(key).or_insert_with(|| v.pes());
        telemetry::metrics().active_variants.set(st.resident.len() as u64);
        pick
    }

    /// Walk the level's preference order: an already-resident variant
    /// costs nothing; a new one must fit the remaining PE budget.
    /// When nothing preferred fits, serve the smallest variant in the
    /// set — availability beats the budget model.
    fn pick_within_budget(st: &mut RouteState, app: &str, set: &VariantSet) -> usize {
        let total: u64 = st.resident.values().sum();
        for role in PREFS[st.level] {
            let Some(v) = set.by_role(role) else { continue };
            let idx = set
                .variants()
                .iter()
                .position(|w| w.role_index == role)
                .expect("by_role hit");
            if st.resident.contains_key(&(app.to_string(), role)) {
                return idx;
            }
            if total + v.pes() <= PE_BUDGET {
                return idx;
            }
        }
        set.min_pes_index()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::{compile, Variant, VariantSet};
    use crate::dse::cache::{candidate_key, encode_schedule, CacheEntry};
    use crate::halide::HwSchedule;
    use crate::telemetry::VARIANT_ROLES;
    use std::sync::Arc;

    fn sig(queue_depth: u64, backlog: u64, workers: u64, busy: u64) -> LoadSignals {
        LoadSignals { queue_depth, backlog, workers, workers_busy: busy }
    }

    /// A variant with a synthetic PE footprint (the compiled design is
    /// shared — routing only reads `pes()` and `role_index`).
    fn variant(role_index: usize, pes: usize, c: &Arc<crate::coordinator::Compiled>) -> Variant {
        let sched = HwSchedule::new([14, 14]);
        Variant {
            role: VARIANT_ROLES[role_index],
            role_index,
            compiled: Arc::clone(c),
            entry: Some(CacheEntry {
                key: candidate_key("route-test", &sched),
                cycles: 1,
                completion: 1,
                pes,
                mems: 1,
                sram_words: 1,
                energy_per_op_pj: 1.0,
                pixels_per_cycle: 1.0,
                area_um2: 1.0,
                encoded: encode_schedule(&sched),
            }),
        }
    }

    fn set_with_pes(latency: usize, energy: usize, fallback: usize) -> VariantSet {
        let c = Arc::new(compile(&crate::apps::gaussian::build(14)).unwrap());
        VariantSet::from_variants(vec![
            variant(0, latency, &c),
            variant(1, energy, &c),
            variant(3, fallback, &c),
        ])
    }

    #[test]
    fn pressure_weighs_queue_backlog_and_saturation() {
        assert_eq!(sig(0, 0, 4, 0).pressure(), 0);
        assert_eq!(sig(0, 0, 4, 4).pressure(), 1, "saturation alone adds one");
        assert_eq!(sig(1, 0, 4, 0).pressure(), 2, "each queued conn counts double");
        assert_eq!(sig(0, 8, 4, 0).pressure(), 2, "backlog is per-worker");
        assert_eq!(sig(2, 8, 4, 4).pressure(), 7);
        assert_eq!(sig(0, 3, 0, 0).pressure(), 3, "zero workers must not divide");
    }

    #[test]
    fn trigger_escalates_immediately_and_descends_at_half() {
        // Idle stays light.
        assert_eq!(next_level(0, 0), 0);
        assert_eq!(next_level(0, T_ENERGY - 1), 0);
        // One hot sample escalates; heavy load can jump both levels.
        assert_eq!(next_level(0, T_ENERGY), 1);
        assert_eq!(next_level(0, T_AREA), 2);
        // Inside the hysteresis band the level holds — including at
        // exactly half the threshold.
        assert_eq!(next_level(1, T_ENERGY - 1), 1);
        assert_eq!(next_level(2, T_AREA - 1), 2);
        assert_eq!(next_level(2, T_AREA / 2), 2);
        // Descent needs sub-half pressure, one level at a time.
        assert_eq!(next_level(1, 0), 0);
        assert_eq!(next_level(2, T_AREA / 2 - 1), 1);
        assert_eq!(next_level(2, 0), 1, "never 2 -> 0 in one step");
    }

    #[test]
    fn routes_by_level_and_does_not_flap() {
        let set = set_with_pes(80, 30, 50);
        let policy = RoutePolicy::new();
        // Light load: latency-optimal.
        let i = policy.decide("g", &set, &sig(0, 0, 2, 0));
        assert_eq!(set.variants()[i].role, "latency");
        // A queued connection escalates to the energy variant.
        let i = policy.decide("g", &set, &sig(1, 0, 2, 2));
        assert_eq!(set.variants()[i].role, "energy");
        // Pressure falling into the band (1) keeps serving energy —
        // no flapping — and only a calm sample (0) de-escalates.
        let i = policy.decide("g", &set, &sig(0, 0, 2, 2));
        assert_eq!(set.variants()[i].role, "energy");
        let i = policy.decide("g", &set, &sig(0, 0, 2, 0));
        assert_eq!(set.variants()[i].role, "latency");
        // Saturating backlog jumps straight to heavy; this set has no
        // area variant, so preference falls through to energy.
        let i = policy.decide("g", &set, &sig(4, 20, 2, 2));
        assert_eq!(policy.level(), 2);
        assert_eq!(set.variants()[i].role, "energy");
    }

    #[test]
    fn coresidency_respects_the_pe_budget() {
        let set = set_with_pes(300, 100, 50);
        let policy = RoutePolicy::new();
        let calm = sig(0, 0, 2, 0);
        // App a takes the 300-PE latency variant (300/384 used).
        let i = policy.decide("a", &set, &calm);
        assert_eq!(set.variants()[i].role, "latency");
        // App b's latency variant no longer fits; the level-0
        // preference order degrades to its 50-PE fallback (350/384).
        let i = policy.decide("b", &set, &calm);
        assert_eq!(set.variants()[i].role, "fallback");
        // App c: nothing preferred fits (350 + 50 > 384 fails only
        // for 100 and 300; 50 fits) — fallback again at 400... which
        // exceeds the budget, so c gets the escape hatch: its
        // smallest variant.
        let i = policy.decide("c", &set, &calm);
        assert_eq!(set.variants()[i].role, "fallback");
        // Residents are sticky: app a keeps its latency variant even
        // though a fresh 300-PE grant would not fit now.
        let i = policy.decide("a", &set, &calm);
        assert_eq!(set.variants()[i].role, "latency");
        // Distinct resident (app, variant) pairs: a/latency,
        // b/fallback, c/fallback. (The global `active_variants` gauge
        // mirrors this but is shared across parallel tests, so assert
        // on the policy's own count.)
        assert_eq!(policy.resident_count(), 3);
    }

    #[test]
    fn solo_sets_bypass_routing() {
        let c = Arc::new(compile(&crate::apps::gaussian::build(14)).unwrap());
        let set = VariantSet::solo(c);
        let policy = RoutePolicy::new();
        // Even under heavy pressure a single-variant set routes to it.
        assert_eq!(policy.decide("solo", &set, &sig(9, 90, 1, 1)), 0);
        assert_eq!(policy.level(), 2, "the trigger still tracks load");
    }
}
