//! Unified buffer extraction (§V-B, Fig 1 third stage).
//!
//! Converts every materialized Halide buffer of a scheduled
//! [`crate::halide::LoweredPipeline`] into a [`crate::ub::UnifiedBuffer`]:
//! each memory reference becomes a dedicated port carrying its iteration
//! domain, access map, and cycle-accurate schedule. Compute kernels are
//! separated from the memory IR as [`crate::ub::KernelNode`]s, to be
//! mapped to PEs later.

pub mod extract;

pub use extract::extract;
