//! Scheduled loop IR -> unified buffer graph.

use anyhow::{Context, Result};
use std::collections::BTreeMap;

use crate::halide::LoweredPipeline;
use crate::poly::set::{BoxSet, Dim};
use crate::poly::CycleSchedule;
use crate::sched::PipelineSchedule;
use crate::ub::{KernelNode, Port, PortDir, StreamEndpoint, UbGraph, UnifiedBuffer};

/// Clip an input-arrival lane to the part of the domain whose lane
/// coordinates stay inside the buffer box (a partial final iteration
/// arises when the innermost extent is not a lane multiple).
fn clip_lane_domain(
    arr_domain: &BoxSet,
    lane_map: &crate::poly::AffineMap,
    data_box: &BoxSet,
) -> BoxSet {
    let mut dom = arr_domain.clone();
    let last = dom.rank() - 1;
    // The lane map is affine and strictly increasing in the innermost
    // dim; shrink the innermost extent until the max point maps inside.
    while dom.dims[last].extent > 0 {
        let mut maxpt: Vec<i64> = dom.dims.iter().map(|d| d.max()).collect();
        maxpt[last] = dom.dims[last].max();
        if data_box.contains(&lane_map.apply(&maxpt)) {
            break;
        }
        dom.dims[last] = Dim::new(
            dom.dims[last].name.clone(),
            dom.dims[last].min,
            dom.dims[last].extent - 1,
        );
    }
    dom
}

/// Extract the unified buffer graph from a scheduled pipeline.
pub fn extract(lp: &LoweredPipeline, ps: &PipelineSchedule) -> Result<UbGraph> {
    let mut buffers: BTreeMap<String, UnifiedBuffer> = BTreeMap::new();
    for (name, data_box) in &lp.buffers {
        buffers.insert(name.clone(), UnifiedBuffer::new(name.clone(), data_box.clone()));
    }

    // Input buffers: one write port per stream lane.
    let mut input_streams = Vec::new();
    for name in &lp.inputs {
        let arr = ps
            .arrivals
            .get(name)
            .with_context(|| format!("no arrival schedule for input {name}"))?;
        let data_box = lp.buffers[name].clone();
        let ub = buffers.get_mut(name).unwrap();
        for (lane, map) in arr.lane_maps.iter().enumerate() {
            let dom = clip_lane_domain(&arr.domain, map, &data_box);
            let port = Port::new(
                format!("{name}.w{lane}"),
                PortDir::In,
                dom,
                map.clone(),
                arr.schedule.clone(),
            );
            input_streams.push(StreamEndpoint { buffer: name.clone(), port: ub.inputs.len() });
            ub.add_input(port);
        }
    }

    // Stage writes (buffer input ports) and reads (buffer output ports),
    // plus the kernel nodes tying them together.
    let mut kernels = Vec::new();
    for (stage, ss) in lp.stages.iter().zip(&ps.stages) {
        debug_assert_eq!(stage.name, ss.stage);
        let rdom_last: Vec<i64> = stage
            .rdom
            .dims
            .iter()
            .map(|d| d.min + d.extent - 1)
            .collect();
        let full = stage.full_domain();
        for (lane, inst) in stage.instances.iter().enumerate() {
            // Load ports.
            let mut load_refs = Vec::new();
            for (buf, map) in &inst.loads {
                let ub = buffers.get_mut(buf).unwrap();
                let idx = ub.outputs.len();
                ub.add_output(Port::new(
                    format!("{buf}.r.{}({lane})#{idx}", stage.name),
                    PortDir::Out,
                    full.clone(),
                    map.clone(),
                    ss.issue.clone(),
                ));
                load_refs.push((buf.clone(), idx));
            }
            // Store port: one write per pure point, at the cycle the
            // final reduction iteration's result lands.
            let write_sched = CycleSchedule::new(
                ss.issue.expr.bind_tail(&rdom_last).shift(ss.latency),
            );
            let store_map = inst.store.bind_tail(&rdom_last);
            let ub = buffers.get_mut(&stage.name).unwrap();
            let sidx = ub.inputs.len();
            ub.add_input(Port::new(
                format!("{}.w{lane}", stage.name),
                PortDir::In,
                stage.pure_domain.clone(),
                store_map,
                write_sched,
            ));
            kernels.push(KernelNode {
                stage: stage.name.clone(),
                lane,
                kernel: inst.kernel.clone(),
                loads: load_refs,
                store: (stage.name.clone(), sidx),
                domain: full.clone(),
                schedule: ss.issue.clone(),
                latency: ss.latency,
                is_reduction: stage.is_reduction(),
            });
        }
    }

    // Output drain: one read port per write port of the output buffer,
    // one cycle after each value lands.
    let mut output_streams = Vec::new();
    {
        let ub = buffers.get_mut(&lp.output).unwrap();
        let writes: Vec<Port> = ub.inputs.clone();
        for (lane, w) in writes.iter().enumerate() {
            let idx = ub.outputs.len();
            ub.add_output(Port::new(
                format!("{}.drain{lane}", lp.output),
                PortDir::Out,
                w.domain.clone(),
                w.access.clone(),
                w.schedule.delayed(1),
            ));
            output_streams.push(StreamEndpoint { buffer: lp.output.clone(), port: idx });
        }
    }

    let graph = UbGraph {
        name: lp.name.clone(),
        buffers,
        kernels,
        input_streams,
        output_streams,
        completion: ps.completion,
        coarse_ii: ps.coarse_ii,
    };
    // The port specification must be realizable before mapping proceeds.
    graph.verify(1)?;
    Ok(graph)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::Expr;
    use crate::sched;

    fn brighten_blur(tile: i64, unroll: Option<i64>) -> (LoweredPipeline, PipelineSchedule) {
        let brighten = Func::pure_fn(
            "brighten",
            &["y", "x"],
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
        );
        let blur = Func::pure_fn(
            "blur",
            &["y", "x"],
            Expr::shr(
                Expr::sum(vec![
                    Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                    Expr::ld(
                        "brighten",
                        vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(1)),
                            Expr::add(Expr::v("x"), Expr::c(1)),
                        ],
                    ),
                ]),
                2,
            ),
        );
        let mut schedule = HwSchedule::new([tile, tile]).store_at("brighten");
        if let Some(u) = unroll {
            schedule = schedule.unroll("brighten", "x", u).unroll("blur", "x", u);
        }
        let p = Program {
            name: "bb".into(),
            inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
            funcs: vec![brighten, blur],
            schedule,
        };
        let lp = lower(&p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        (lp, ps)
    }

    #[test]
    fn brighten_buffer_has_five_ports() {
        // The paper's Fig 2: 1 input port + 4 output ports.
        let (lp, ps) = brighten_blur(63, None);
        let g = extract(&lp, &ps).unwrap();
        let b = &g.buffers["brighten"];
        assert_eq!(b.inputs.len(), 1);
        assert_eq!(b.outputs.len(), 4);
        assert_eq!(b.port_count(), 5);
    }

    #[test]
    fn graph_verifies_and_counts() {
        let (lp, ps) = brighten_blur(31, None);
        let g = extract(&lp, &ps).unwrap();
        // verify() ran inside extract; double-check stronger latency.
        g.verify(1).unwrap();
        assert_eq!(g.kernels.len(), 2);
        assert_eq!(g.input_streams.len(), 1);
        assert_eq!(g.output_streams.len(), 1);
        assert!((g.output_pixels_per_cycle() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn brighten_max_live_is_line_sized() {
        // §V-C: "a maximum of 64 live pixels" for the brighten buffer
        // (plus the 2x2 window corner values) on a 64-wide tile.
        let (lp, ps) = brighten_blur(63, None);
        let g = extract(&lp, &ps).unwrap();
        let live = g.buffers["brighten"].max_live().unwrap();
        assert!((64..=74).contains(&live), "live {live}");
    }

    #[test]
    fn unrolled_extraction_doubles_ports() {
        let (lp, ps) = brighten_blur(62, Some(2));
        let g = extract(&lp, &ps).unwrap();
        // Two blur lanes x 4 loads = 8 read ports; 2 write lanes.
        let b = &g.buffers["brighten"];
        assert_eq!(b.inputs.len(), 2);
        assert_eq!(b.outputs.len(), 8);
        // Output drains two pixels per cycle.
        assert_eq!(g.output_streams.len(), 2);
        assert!((g.output_pixels_per_cycle() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn input_lanes_clipped_to_box() {
        // Unroll only blur: the input box stays 63x63 (odd innermost)
        // with 2 arrival lanes, so lane 1's final iteration of each row
        // would exceed the box and must be clipped.
        let (lp, ps) = {
            let brighten = Func::pure_fn(
                "brighten",
                &["y", "x"],
                Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
            );
            let blur = Func::pure_fn(
                "blur",
                &["y", "x"],
                Expr::add(
                    Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                    Expr::ld(
                        "brighten",
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(1)),
                            Expr::add(Expr::v("x"), Expr::c(1)),
                        ],
                    ),
                ),
            );
            let prog = Program {
                name: "bb_clip".into(),
                inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
                funcs: vec![brighten, blur],
                schedule: HwSchedule::new([62, 62]).store_at("brighten").unroll("blur", "x", 2),
            };
            let lp = lower(&prog).unwrap();
            let ps = sched::schedule(&lp).unwrap();
            (lp, ps)
        };
        let g = extract(&lp, &ps).unwrap();
        let inb = &g.buffers["input"];
        assert_eq!(inb.inputs.len(), 2);
        let n0 = inb.inputs[0].op_count();
        let n1 = inb.inputs[1].op_count();
        assert_eq!(
            n0 + n1,
            inb.data_box.cardinality(),
            "lanes must cover the box exactly"
        );
        assert_eq!(n0 - n1, 63, "lane 0 covers the odd final column");
    }

    #[test]
    fn dnn_reduction_write_port_once_per_pure_point() {
        let conv = Func::reduce_fn(
            "conv",
            &["y", "x"],
            Expr::c(0),
            &[("ry", 0, 3), ("rx", 0, 3)],
            Expr::add(
                Expr::ld("conv", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld(
                    "in",
                    vec![
                        Expr::add(Expr::v("y"), Expr::v("ry")),
                        Expr::add(Expr::v("x"), Expr::v("rx")),
                    ],
                ),
            ),
        );
        let p = Program {
            name: "boxf".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![conv],
            schedule: HwSchedule::new([6, 6]),
        };
        let lp = lower(&p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        let g = extract(&lp, &ps).unwrap();
        let conv_ub = &g.buffers["conv"];
        assert_eq!(conv_ub.inputs[0].op_count(), 36); // 6x6 pure points
        // The read port on `in` fires once per MAC: 6*6*9.
        assert_eq!(g.buffers["in"].outputs[0].op_count(), 324);
    }
}
