//! Area model, calibrated to Table II (TSMC 16nm, µm²).
//!
//! Component constants are solved from the paper's three rows:
//!
//! | variant                    | MEM area | SRAM % | total UB |
//! |----------------------------|----------|--------|----------|
//! | DP SRAM + PEs (baseline)   | 19k      | 82     | 34k      |
//! | DP SRAM + AG               | 23k      | 70     | 23k      |
//! | 4-wide SP SRAM + AGG/TB/AG | 17k      | 32     | 17k      |
//!
//! From row 1: dual-port 2048x16 SRAM macro = 19k * 0.82 ≈ 15.6k, and
//! PE-based addressing adds 34k − 19k = 15k (≈ 10 PEs → 1.5k per PE).
//! From row 2: integrated AG/SG/ID logic for two dual ports ≈
//! 23k − 15.6k ≈ 7.4k. From row 3: the single-port 512x64 macro is
//! ≈ 2.5x smaller (≈ 5.5k ≈ 17k * 0.32), leaving ≈ 11.5k for AGG + TB
//! register files and the four controller sets.

use crate::mapping::MappedDesign;

/// Dual-port 2048x16b SRAM macro.
pub const DP_SRAM_UM2: f64 = 15_600.0;
/// Single-port 512x64b SRAM macro (same 2048 words; ~2.5x smaller).
pub const SP_SRAM_UM2: f64 = 5_500.0;
/// One 16-bit ALU PE tile (datapath + routing mux share).
pub const PE_UM2: f64 = 1_500.0;
/// Integrated ID+AG+SG controller set for one port (Fig 5c).
pub const CTL_UM2: f64 = 1_850.0;
/// AGG or TB register file (fetch-width words) incl. its controllers.
pub const AGG_TB_UM2: f64 = 2_875.0;
/// One 16-bit shift register word.
pub const SR_WORD_UM2: f64 = 18.0;

/// The three physical unified buffer implementations of Table II.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PubVariant {
    /// Dual-port SRAM, addressing on CGRA PEs (baseline).
    DpSramPes,
    /// Dual-port SRAM with integrated address generators.
    DpSramAg,
    /// Wide-fetch single-port SRAM + AGG + TB + AGs (shipped).
    WideSpSram,
}

#[derive(Clone, Copy, Debug)]
pub struct VariantCost {
    pub mem_tile_um2: f64,
    pub sram_fraction: f64,
    pub total_ub_um2: f64,
    pub energy_pj_per_access: f64,
}

/// Reproduce Table II: cost of one physical unified buffer servicing a
/// 3x3 convolution (1 write + the line-buffer read traffic).
pub fn table2_variants() -> [(PubVariant, VariantCost); 3] {
    // Baseline: DP SRAM tile + 10 PEs doing addressing & sequencing.
    let dp_pes_mem = DP_SRAM_UM2 + 0.18 / 0.82 * DP_SRAM_UM2; // mux/wiring overhead
    let dp_pes = VariantCost {
        mem_tile_um2: dp_pes_mem,
        sram_fraction: DP_SRAM_UM2 / dp_pes_mem,
        total_ub_um2: dp_pes_mem + 10.0 * PE_UM2,
        energy_pj_per_access: super::energy::DP_ACCESS_PJ + super::energy::PE_ADDR_PJ,
    };
    // Integrated AGs: 4 controller sets on the dual-port tile.
    let dp_ag_mem = DP_SRAM_UM2 + 4.0 * CTL_UM2;
    let dp_ag = VariantCost {
        mem_tile_um2: dp_ag_mem,
        sram_fraction: DP_SRAM_UM2 / dp_ag_mem,
        total_ub_um2: dp_ag_mem,
        energy_pj_per_access: super::energy::DP_ACCESS_PJ + super::energy::CTL_PJ,
    };
    // Shipped: SP wide SRAM + AGG + TB + 4 controller sets.
    let sp_mem = SP_SRAM_UM2 + 2.0 * AGG_TB_UM2 + 3.2 * CTL_UM2;
    let sp = VariantCost {
        mem_tile_um2: sp_mem,
        sram_fraction: SP_SRAM_UM2 / sp_mem,
        total_ub_um2: sp_mem,
        energy_pj_per_access: super::energy::SP_WORD_PJ
            + super::energy::AGG_TB_PJ
            + super::energy::CTL_PJ,
    };
    [
        (PubVariant::DpSramPes, dp_pes),
        (PubVariant::DpSramAg, dp_ag),
        (PubVariant::WideSpSram, sp),
    ]
}

/// Silicon area of a mapped design (µm²): memory tiles (by variant),
/// PEs, and shift-register words.
pub fn design_area_um2(d: &MappedDesign) -> f64 {
    let variants = table2_variants();
    let wide = variants[2].1.mem_tile_um2;
    let dual = variants[1].1.mem_tile_um2;
    let mut area = 0.0;
    for b in d.buffers.values() {
        for bank in &b.banks {
            let tile = if bank.is_dual_port() { dual } else { wide };
            area += tile * bank.tiles as f64;
        }
        area += b.sr_words as f64 * SR_WORD_UM2;
    }
    area + d.pe_count() as f64 * PE_UM2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shape_holds() {
        let v = table2_variants();
        let (base, ag, sp) = (v[0].1, v[1].1, v[2].1);
        // Total UB area strictly improves down the table.
        assert!(base.total_ub_um2 > ag.total_ub_um2);
        assert!(ag.total_ub_um2 > sp.total_ub_um2);
        // Energy strictly improves too.
        assert!(base.energy_pj_per_access > ag.energy_pj_per_access);
        assert!(ag.energy_pj_per_access > sp.energy_pj_per_access);
        // Paper magnitudes: 34k / 23k / 17k within 15%.
        assert!((base.total_ub_um2 - 34_000.0).abs() / 34_000.0 < 0.15);
        assert!((ag.total_ub_um2 - 23_000.0).abs() / 23_000.0 < 0.15);
        assert!((sp.total_ub_um2 - 17_000.0).abs() / 17_000.0 < 0.15);
        // SRAM fraction drops from ~82% to ~32%.
        assert!(base.sram_fraction > 0.75);
        assert!(sp.sram_fraction < 0.40);
        // Final design is about half the area and energy of the baseline
        // ("half the area and energy of the original design", §VI-A).
        assert!(base.total_ub_um2 / sp.total_ub_um2 > 1.8);
        assert!(base.energy_pj_per_access / sp.energy_pj_per_access > 1.8);
    }

    #[test]
    fn dp_sram_ratio_matches_paper() {
        // "around 2.5x larger than the single-port" (§VI-A).
        let r = DP_SRAM_UM2 / SP_SRAM_UM2;
        assert!((2.2..=3.2).contains(&r), "ratio {r}");
    }
}
