//! Area, energy, and baseline cost models (§VI).
//!
//! The paper's numbers come from a TSMC-16nm Genus/Innovus flow; we
//! cannot tape out here, so [`area`] and [`energy`] are analytical
//! models **calibrated to the paper's own Table II** (the three
//! physical-unified-buffer variants) and standard 16-nm energy/op
//! figures. [`fpga`] estimates the Zynq UltraScale+ resources and
//! timing of the synthesizable-C path (Table IV, Figs 13/14): II=1
//! pipelined designs at 200 MHz vs the CGRA's 900 MHz.

pub mod area;
pub mod energy;
pub mod fpga;

pub use area::{design_area_um2, table2_variants, PubVariant, VariantCost};
pub use energy::{design_energy, energy_per_op_pj, EnergyBreakdown};
pub use fpga::{estimate_fpga, FpgaReport};

/// Clock frequencies (§VI-B): the CGRA dominates the FPGA "due to its
/// higher clock frequency (900 MHz)" vs Vivado's 200 MHz closure.
pub const CGRA_CLOCK_HZ: f64 = 900.0e6;
pub const FPGA_CLOCK_HZ: f64 = 200.0e6;
