//! Energy model (pJ), calibrated to Table II's per-access column and
//! standard 16-nm dynamic-energy figures, consumed with the activity
//! counts the cycle-accurate simulator reports.

use crate::cgra::SimStats;
use crate::mapping::MappedDesign;

/// Dual-port SRAM word access.
pub const DP_ACCESS_PJ: f64 = 3.2;
/// Single-port wide-fetch SRAM, amortized per word (wide fetches are
/// cheaper per byte, §IV-A).
pub const SP_WORD_PJ: f64 = 1.7;
/// AGG/TB register-file traffic per word.
pub const AGG_TB_PJ: f64 = 0.4;
/// Integrated controller (ID+AG+SG delta recurrence) per operation.
pub const CTL_PJ: f64 = 0.4;
/// Addressing done on general PEs (baseline variant) per access.
pub const PE_ADDR_PJ: f64 = 1.6;
/// One 16-bit PE ALU operation.
pub const PE_OP_PJ: f64 = 0.5;
/// One shift-register word shift.
pub const SR_SHIFT_PJ: f64 = 0.05;

/// FPGA-side constants (Figs 13/14): LUT-mapped 16-bit logic and BRAM
/// accesses cost several times their ASIC counterparts.
pub const FPGA_OP_PJ: f64 = 2.6;
pub const FPGA_BRAM_WORD_PJ: f64 = 5.5;
pub const FPGA_REG_PJ: f64 = 0.25;

#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    pub sram_pj: f64,
    pub ctl_pj: f64,
    pub pe_pj: f64,
    pub sr_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.sram_pj + self.ctl_pj + self.pe_pj + self.sr_pj
    }
}

/// Total CGRA energy of one simulated run.
pub fn design_energy(d: &MappedDesign, stats: &SimStats) -> EnergyBreakdown {
    // Wide accesses move fetch_width words each.
    let fw = d.fetch_width as f64;
    let sram_words = (stats.sram_reads + stats.sram_writes) as f64 * fw;
    EnergyBreakdown {
        sram_pj: sram_words * SP_WORD_PJ + sram_words * AGG_TB_PJ,
        ctl_pj: (stats.sram_reads + stats.sram_writes) as f64 * CTL_PJ * 2.0
            + (stats.words_in + stats.words_out) as f64 * CTL_PJ,
        pe_pj: stats.pe_ops as f64 * PE_OP_PJ,
        sr_pj: stats.sr_shifts as f64 * SR_SHIFT_PJ,
    }
}

/// Energy per compute operation (the Fig 13 metric).
pub fn energy_per_op_pj(d: &MappedDesign, stats: &SimStats) -> f64 {
    design_energy(d, stats).total_pj() / stats.pe_ops.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> SimStats {
        SimStats {
            cycles: 4096,
            sram_reads: 1000,
            sram_writes: 1000,
            pe_ops: 40_000,
            sr_shifts: 16_000,
            words_in: 4096,
            words_out: 4096,
        }
    }

    #[test]
    fn breakdown_sums() {
        let d = dummy_design();
        let e = design_energy(&d, &stats());
        let t = e.total_pj();
        assert!(t > 0.0);
        assert!((e.sram_pj + e.ctl_pj + e.pe_pj + e.sr_pj - t).abs() < 1e-9);
    }

    #[test]
    fn per_access_magnitude_matches_table2() {
        // SP word + AGG/TB + controller ≈ 2.5 pJ (Table II row 3).
        let per_access = SP_WORD_PJ + AGG_TB_PJ + CTL_PJ;
        assert!((per_access - 2.5).abs() < 0.15, "{per_access}");
        // DP + AG ≈ 3.6; DP + PEs ≈ 4.8.
        assert!((DP_ACCESS_PJ + CTL_PJ - 3.6).abs() < 0.1);
        assert!((DP_ACCESS_PJ + PE_ADDR_PJ - 4.8).abs() < 0.1);
    }

    fn dummy_design() -> MappedDesign {
        MappedDesign {
            name: "t".into(),
            buffers: Default::default(),
            kernels: vec![],
            completion: 4096,
            coarse_ii: 4096,
            fetch_width: 4,
        }
    }
}
