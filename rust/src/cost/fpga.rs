//! FPGA baseline model (Zynq UltraScale+ 7EV through Vivado HLS).
//!
//! The paper's FPGA path emits synthesizable C that Vivado schedules at
//! II=1 and 200 MHz (§VI). The *cycle count* of an II=1 pipelined design
//! equals the CGRA's static schedule; runtime differs by the clock
//! ratio, and resources/energy by the LUT/BRAM fabric costs. This
//! module estimates Table IV's BRAM/DSP/FF/LUT columns and the Fig
//! 13/14 energy and runtime series from the same mapped design.

use super::energy::{FPGA_BRAM_WORD_PJ, FPGA_OP_PJ, FPGA_REG_PJ};
use super::FPGA_CLOCK_HZ;
use crate::cgra::SimStats;
use crate::halide::expr::BinOp;
use crate::hw::PeOp;
use crate::mapping::{MappedDesign, OperandSrc};

/// One BRAM18 holds 1024 16-bit words; buffers at or below half that
/// are placed in distributed LUTRAM/FF instead (Vivado's default).
const BRAM_WORDS: i64 = 1024;
const BRAM_THRESHOLD: i64 = 512;

#[derive(Clone, Copy, Debug, Default)]
pub struct FpgaReport {
    pub bram: usize,
    pub dsp: usize,
    pub ff: usize,
    pub lut: usize,
    pub runtime_s: f64,
    pub energy_per_op_pj: f64,
}

pub fn estimate_fpga(d: &MappedDesign, stats: &SimStats) -> FpgaReport {
    let mut bram = 0usize;
    let mut dist_words = 0i64;
    for b in d.buffers.values() {
        for bank in &b.banks {
            if bank.capacity_words > BRAM_THRESHOLD {
                bram += ((bank.capacity_words + BRAM_WORDS - 1) / BRAM_WORDS) as usize;
            } else {
                dist_words += bank.capacity_words;
            }
        }
        dist_words += b.sr_words;
    }

    // DSPs: general multiplies map to DSP48s; constant multiplies are
    // strength-reduced into LUT shift-add trees, packed 8-to-a-DSP by
    // Vivado's resource sharing when any remain.
    let mut dyn_mul = 0usize;
    let mut const_mul = 0usize;
    for k in &d.kernels {
        for n in &k.nodes {
            let is_mul = matches!(n.cfg.op, PeOp::Bin(BinOp::Mul))
                || matches!(n.cfg.op, PeOp::Acc { op: BinOp::Mul, .. });
            if is_mul {
                let has_const = n.cfg.consts.iter().any(|c| c.is_some());
                let dynamic_srcs = n
                    .srcs
                    .iter()
                    .filter(|s| !matches!(s, OperandSrc::None))
                    .count();
                if has_const || dynamic_srcs < 2 {
                    const_mul += 1;
                } else {
                    dyn_mul += 1;
                }
            }
        }
    }
    let dsp = dyn_mul + const_mul.div_ceil(8).max(usize::from(const_mul > 0));

    // FFs: pipeline registers per op stage, operand retiming, SR words,
    // and the HLS loop counters per buffer port.
    let pe_ops = d.pe_count();
    let ctl_regs: usize = d
        .buffers
        .values()
        .map(|b| b.banks.len() * 3 * 16 + (b.sr_words as usize) * 16)
        .sum();
    let ff = pe_ops * 18 + ctl_regs + dist_words as usize * 16 / 4;

    // LUTs: ~2 LUT6 per 16-bit adder bit-pair plus control and muxing.
    let lut = pe_ops * 34 + d.mem_tiles() * 160 + dist_words as usize * 2;

    // Runtime: same II=1 cycle count at the FPGA clock.
    let runtime_s = d.completion as f64 / FPGA_CLOCK_HZ;

    // Energy/op: LUT-fabric op energy plus BRAM traffic amortized over
    // compute ops.
    let fw = d.fetch_width as f64;
    let mem_words = (stats.sram_reads + stats.sram_writes) as f64 * fw;
    let e_mem = mem_words * FPGA_BRAM_WORD_PJ;
    let e_ops = stats.pe_ops as f64 * FPGA_OP_PJ;
    let e_reg = stats.sr_shifts as f64 * FPGA_REG_PJ;
    let energy_per_op_pj = (e_mem + e_ops + e_reg) / stats.pe_ops.max(1) as f64;

    FpgaReport { bram, dsp, ff, lut, runtime_s, energy_per_op_pj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::energy::energy_per_op_pj;
    use crate::extraction::extract;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::Expr;
    use crate::mapping::map_design;
    use crate::sched;
    use crate::tensor::Tensor;
    use std::collections::BTreeMap;

    fn small_stencil() -> (MappedDesign, SimStats) {
        let a = Func::pure_fn(
            "a",
            &["y", "x"],
            Expr::mul(Expr::c(3), Expr::ld("in", vec![Expr::v("y"), Expr::v("x")])),
        );
        let b = Func::pure_fn(
            "b",
            &["y", "x"],
            Expr::add(
                Expr::ld("a", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld("a", vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")]),
            ),
        );
        let p = Program {
            name: "p".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![a, b],
            schedule: HwSchedule::new([24, 24]).store_at("a"),
        };
        let lp = lower(&p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        let g = extract(&lp, &ps).unwrap();
        let d = map_design(&g).unwrap();
        let input = Tensor::from_fn(lp.buffers["in"].clone(), |pt| (pt[0] + pt[1]) as i32);
        let mut ins = BTreeMap::new();
        ins.insert("in".to_string(), input);
        let res = crate::cgra::simulate(&d, &g, &ins).unwrap();
        (d, res.stats)
    }

    #[test]
    fn small_buffers_avoid_bram() {
        let (d, stats) = small_stencil();
        let r = estimate_fpga(&d, &stats);
        // A one-line buffer lives in distributed RAM (Table IV gaussian
        // row: 0 BRAM).
        assert_eq!(r.bram, 0);
        assert!(r.ff > 0);
        assert!(r.lut > 0);
    }

    #[test]
    fn fpga_slower_and_hungrier_than_cgra(){
        let (d, stats) = small_stencil();
        let r = estimate_fpga(&d, &stats);
        let cgra_runtime = d.completion as f64 / crate::cost::CGRA_CLOCK_HZ;
        let ratio = r.runtime_s / cgra_runtime;
        assert!((4.0..5.0).contains(&ratio), "runtime ratio {ratio}");
        let cgra_e = energy_per_op_pj(&d, &stats);
        let eratio = r.energy_per_op_pj / cgra_e;
        assert!(eratio > 2.0, "energy ratio {eratio}");
    }
}
