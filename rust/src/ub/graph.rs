//! The application graph: unified buffers wired to compute kernels.
//!
//! This is the output of buffer extraction (Fig 1, third stage): every
//! materialized Halide buffer has become a [`UnifiedBuffer`], every stage
//! instance a [`KernelNode`], and the tile boundary I/O is expressed as
//! stream endpoints fed/drained by the global buffer (Fig 12).

use std::collections::BTreeMap;

use anyhow::Result;

use super::buffer::UnifiedBuffer;
use crate::halide::Expr;
use crate::poly::{BoxSet, CycleSchedule};

/// One spatial compute kernel (a stage instance): reads from buffer
/// output ports, computes, writes one buffer input port.
#[derive(Clone, Debug)]
pub struct KernelNode {
    pub stage: String,
    /// Unroll lane index within the stage.
    pub lane: usize,
    pub kernel: Expr,
    /// `(buffer, output-port index)` feeding each load, in the order the
    /// loads appear in `kernel`.
    pub loads: Vec<(String, usize)>,
    /// `(buffer, input-port index)` receiving the result.
    pub store: (String, usize),
    /// Full compute domain (pure x reduction dims).
    pub domain: BoxSet,
    /// Issue schedule over `domain`.
    pub schedule: CycleSchedule,
    /// Pipeline latency from operand arrival to result write.
    pub latency: i64,
    pub is_reduction: bool,
}

/// External stream endpoint: which buffer port the global buffer feeds
/// (input images) or drains (the output).
#[derive(Clone, Debug)]
pub struct StreamEndpoint {
    pub buffer: String,
    pub port: usize,
}

/// The full extracted application.
#[derive(Clone, Debug)]
pub struct UbGraph {
    pub name: String,
    pub buffers: BTreeMap<String, UnifiedBuffer>,
    pub kernels: Vec<KernelNode>,
    pub input_streams: Vec<StreamEndpoint>,
    /// One endpoint per output lane (unrolled outputs drain several
    /// pixels per cycle).
    pub output_streams: Vec<StreamEndpoint>,
    /// Cycles to complete one tile (last output-stream event + 1).
    pub completion: i64,
    /// Coarse-grained initiation interval between successive tiles
    /// (= `completion` when not double-buffered).
    pub coarse_ii: i64,
}

impl UbGraph {
    /// Verify every unified buffer's port specification (causality with
    /// at least `min_latency` cycles write-to-read).
    pub fn verify(&self, min_latency: i64) -> Result<()> {
        for ub in self.buffers.values() {
            ub.verify(min_latency)?;
        }
        Ok(())
    }

    /// Total storage requirement in words across all buffers after
    /// storage minimization — the "SRAM Words" column of Table VII.
    pub fn total_live_words(&self) -> Result<i64> {
        let mut total = 0;
        for ub in self.buffers.values() {
            total += ub.max_live()?;
        }
        Ok(total)
    }

    /// Total ALU operation count across kernels — the PE estimate.
    pub fn total_alu_ops(&self) -> usize {
        self.kernels.iter().map(|k| k.kernel.op_count()).sum()
    }

    /// Output pixels produced per steady-state cycle (Table V column):
    /// output-writing kernel instances divided by their issue II.
    pub fn output_pixels_per_cycle(&self) -> f64 {
        let out_buf = &self.output_streams[0].buffer;
        let writers: Vec<&KernelNode> =
            self.kernels.iter().filter(|k| k.store.0 == *out_buf).collect();
        if writers.is_empty() {
            return 0.0;
        }
        // II of a row-major schedule = innermost coefficient.
        let ii = writers[0]
            .schedule
            .expr
            .coeffs
            .last()
            .copied()
            .unwrap_or(1)
            .max(1);
        writers.len() as f64 / ii as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::{Affine, AffineMap};
    use crate::ub::port::{Port, PortDir};

    fn tiny_graph() -> UbGraph {
        // input --(brighten kernel)--> bbuf --(blur kernel)--> out
        let mut input = UnifiedBuffer::new("input", BoxSet::from_extents(&[4, 4]));
        input.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[4, 4]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[4, 4], 1, 0),
        ));
        input.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[4, 4]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[4, 4], 1, 1),
        ));
        let mut out = UnifiedBuffer::new("out", BoxSet::from_extents(&[4, 4]));
        out.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[4, 4]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[4, 4], 1, 3),
        ));
        out.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[4, 4]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[4, 4], 1, 4),
        ));
        let kern = KernelNode {
            stage: "bright".into(),
            lane: 0,
            kernel: Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
            loads: vec![("input".into(), 0)],
            store: ("out".into(), 0),
            domain: BoxSet::from_extents(&[4, 4]),
            schedule: CycleSchedule::row_major(&[4, 4], 1, 1),
            latency: 2,
            is_reduction: false,
        };
        let mut buffers = BTreeMap::new();
        buffers.insert("input".to_string(), input);
        buffers.insert("out".to_string(), out);
        UbGraph {
            name: "tiny".into(),
            buffers,
            kernels: vec![kern],
            input_streams: vec![StreamEndpoint { buffer: "input".into(), port: 0 }],
            output_streams: vec![StreamEndpoint { buffer: "out".into(), port: 0 }],
            completion: 20,
            coarse_ii: 20,
        }
    }

    #[test]
    fn graph_verifies() {
        tiny_graph().verify(1).unwrap();
    }

    #[test]
    fn totals() {
        let g = tiny_graph();
        assert_eq!(g.total_alu_ops(), 1);
        assert!(g.total_live_words().unwrap() >= 2);
        assert!((g.output_pixels_per_cycle() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn pixels_per_cycle_with_ii2() {
        let mut g = tiny_graph();
        let k = &mut g.kernels[0];
        k.schedule = CycleSchedule::new(Affine::new(vec![8, 2], 1));
        assert!((g.output_pixels_per_cycle() - 0.5).abs() < 1e-9);
    }
}
