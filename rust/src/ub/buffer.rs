//! The unified buffer: a bundle of ports plus derived analyses
//! (causality verification, storage minimization, dependence distances).

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::port::{Port, PortDir};
use crate::poly::BoxSet;

/// A unified buffer for one logical array (one materialized Halide
/// buffer). `data_box` is the realization box; it bounds the coordinate
/// space but — per the abstraction — implies nothing about physical
/// capacity, which comes from [`UnifiedBuffer::max_live`].
#[derive(Clone, Debug)]
pub struct UnifiedBuffer {
    pub name: String,
    pub data_box: BoxSet,
    pub inputs: Vec<Port>,
    pub outputs: Vec<Port>,
}

impl UnifiedBuffer {
    pub fn new(name: impl Into<String>, data_box: BoxSet) -> Self {
        UnifiedBuffer { name: name.into(), data_box, inputs: vec![], outputs: vec![] }
    }

    pub fn add_input(&mut self, p: Port) {
        assert_eq!(p.dir, PortDir::In);
        self.inputs.push(p);
    }

    pub fn add_output(&mut self, p: Port) {
        assert_eq!(p.dir, PortDir::Out);
        self.outputs.push(p);
    }

    /// Total ports — memory operations per cycle in steady state if all
    /// ports are concurrently active (the bandwidth the mapper must
    /// service, §V-C).
    pub fn port_count(&self) -> usize {
        self.inputs.len() + self.outputs.len()
    }

    /// Row-major flattener over the data box (flat i64 hash keys are
    /// far cheaper than Vec<i64> keys on these hot analyses, §Perf).
    fn flat_key(&self) -> impl Fn(&[i64]) -> i64 + '_ {
        let dims = &self.data_box.dims;
        let rank = dims.len();
        let mut strides = vec![1i64; rank];
        for k in (0..rank.saturating_sub(1)).rev() {
            strides[k] = strides[k + 1] * dims[k + 1].extent;
        }
        move |coords: &[i64]| {
            coords
                .iter()
                .zip(dims)
                .zip(&strides)
                .map(|((&c, d), &s)| (c - d.min) * s)
                .sum()
        }
    }

    /// Map buffer coordinates (flattened) -> cycle of the (unique) write.
    fn write_times(&self) -> Result<HashMap<i64, i64>> {
        let key = self.flat_key();
        let mut wt: HashMap<i64, i64> = HashMap::new();
        let mut dup: Option<(i64, i64)> = None;
        for p in &self.inputs {
            p.visit_events(|t, coords| {
                if let Some(prev) = wt.insert(key(coords), t) {
                    dup.get_or_insert((prev, t));
                }
            });
        }
        if let Some((prev, t)) = dup {
            bail!(
                "buffer {}: a coordinate is written twice (cycles {prev} and {t})",
                self.name
            );
        }
        Ok(wt)
    }

    /// Verify the port specification is realizable:
    /// * every port schedule issues at most one op per cycle,
    /// * every read is of a coordinate previously written (causality,
    ///   with `min_latency` cycles between a write and the earliest
    ///   dependent read — the time a value needs to travel through the
    ///   buffer, cf. the 65-cycle startup delay in Fig 2),
    /// * no coordinate is written twice (SSA per tile).
    pub fn verify(&self, min_latency: i64) -> Result<()> {
        for p in self.inputs.iter().chain(&self.outputs) {
            if !p.schedule_is_valid() {
                bail!("buffer {}: port {} issues >1 op per cycle", self.name, p.name);
            }
            for (_, coords) in p.events() {
                if !self.data_box.contains(&coords) {
                    bail!(
                        "buffer {}: port {} accesses {coords:?} outside {}",
                        self.name,
                        p.name,
                        self.data_box
                    );
                }
            }
        }
        let wt = self.write_times()?;
        let key = self.flat_key();
        for p in &self.outputs {
            let mut bad: Option<String> = None;
            p.visit_events(|t, coords| {
                if bad.is_some() {
                    return;
                }
                match wt.get(&key(coords)) {
                    None => {
                        bad = Some(format!(
                            "buffer {}: port {} reads never-written {coords:?}",
                            self.name, p.name
                        ))
                    }
                    Some(&w) if t < w + min_latency => {
                        bad = Some(format!(
                            "buffer {}: port {} reads {coords:?} at {t}, written at {w} \
                             (needs {min_latency} cycles)",
                            self.name, p.name
                        ))
                    }
                    _ => {}
                }
            });
            if let Some(msg) = bad {
                bail!(msg);
            }
        }
        Ok(())
    }

    /// Storage minimization (§V-C "Address Linearization" example): the
    /// maximum number of simultaneously-live values. A value is live from
    /// its write until its last read; values never read die immediately.
    ///
    /// This is the capacity an optimal circular-buffer implementation
    /// needs (the paper's "maximum of 64 live pixels" for the brighten
    /// buffer).
    pub fn max_live(&self) -> Result<i64> {
        let wt = self.write_times()?;
        let key = self.flat_key();
        let mut last_read: HashMap<i64, i64> = HashMap::new();
        for p in &self.outputs {
            p.visit_events(|t, coords| {
                let e = last_read.entry(key(coords)).or_insert(t);
                *e = (*e).max(t);
            });
        }
        // Sweep events: +1 at write, -1 after last read.
        let mut events: Vec<(i64, i64)> = Vec::with_capacity(2 * wt.len());
        for (coords, &w) in &wt {
            if let Some(&r) = last_read.get(coords) {
                events.push((w, 1));
                events.push((r + 1, -1));
            }
        }
        // At equal cycle, process frees before allocations? A value read
        // in the same cycle another is written must coexist (the write
        // lands while the old value is still being drained), so process
        // allocations first: sort by (cycle, delta descending).
        events.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
        let mut live = 0i64;
        let mut max = 0i64;
        for (_, d) in events {
            live += d;
            max = max.max(live);
        }
        Ok(max)
    }

    /// Constant dependence distance in *cycles* from input port `inp` to
    /// output port `out`, if one exists: the shift-register legality test
    /// (§V-C). Returns `Some(d)` iff every value emitted by `out` was
    /// written by `inp` exactly `d` cycles earlier.
    pub fn dependence_distance(&self, inp: &Port, out: &Port) -> Option<i64> {
        let wt = self.event_time_map(inp);
        self.distance_against(&wt, out)
    }

    /// Coordinate -> event-time map for one port (flat-keyed against
    /// this buffer's box; built once per source, probed per port, §Perf).
    pub fn event_time_map(&self, port: &Port) -> HashMap<i64, i64> {
        let key = self.flat_key();
        let mut wt: HashMap<i64, i64> = HashMap::new();
        port.visit_events(|t, coords| {
            wt.insert(key(coords), t);
        });
        wt
    }

    /// [`UnifiedBuffer::dependence_distance`] against a prebuilt map.
    pub fn distance_against(&self, wt: &HashMap<i64, i64>, out: &Port) -> Option<i64> {
        let key = self.flat_key();
        let mut dist: Option<i64> = None;
        let mut bad = false;
        out.visit_events(|t, coords| {
            if bad {
                return;
            }
            match wt.get(&key(coords)) {
                None => bad = true,
                Some(&w) => {
                    let d = t - w;
                    match dist {
                        None => dist = Some(d),
                        Some(prev) if prev != d => bad = true,
                        _ => {}
                    }
                }
            }
        });
        if bad {
            None
        } else {
            dist
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::{Affine, AffineMap, CycleSchedule};

    /// Build the paper's Fig 2 brighten buffer: one write port (identity,
    /// t = 64y + x) and four read ports for the 2x2 blur stencil
    /// ((y+dy, x+dx), t = 64y + x + 66), over a 64x64 read domain.
    ///
    /// The read schedule offset 66 makes the tightest read — of
    /// brighten(y+1, x+1), written at 64(y+1) + (x+1) = t_w — happen at
    /// 64y + x + 66 = t_w + 1, i.e. one cycle after its write.
    fn brighten_buffer() -> UnifiedBuffer {
        let mut ub = UnifiedBuffer::new(
            "brighten",
            BoxSet::from_extents(&[65, 65]),
        );
        ub.add_input(Port::new(
            "w0",
            PortDir::In,
            BoxSet::from_extents(&[65, 65]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[65, 65], 1, 0),
        ));
        for (k, (dy, dx)) in [(0i64, 0i64), (0, 1), (1, 0), (1, 1)].iter().enumerate() {
            ub.add_output(Port::new(
                format!("r{k}"),
                PortDir::Out,
                BoxSet::from_extents(&[64, 64]),
                AffineMap::new(
                    2,
                    vec![Affine::new(vec![1, 0], *dy), Affine::new(vec![0, 1], *dx)],
                ),
                // Writer traverses 65-wide rows: row stride is 65.
                CycleSchedule::new(Affine::new(vec![65, 1], 67)),
            ));
        }
        ub
    }

    #[test]
    fn verify_passes_for_fig2() {
        let ub = brighten_buffer();
        ub.verify(1).unwrap();
        assert_eq!(ub.port_count(), 5);
    }

    #[test]
    fn verify_catches_too_early_read() {
        let mut ub = brighten_buffer();
        // Shift reads 80 cycles earlier: now reads precede writes.
        for p in &mut ub.outputs {
            p.schedule = p.schedule.delayed(-80);
        }
        assert!(ub.verify(1).is_err());
    }

    #[test]
    fn verify_catches_out_of_box() {
        let mut ub = brighten_buffer();
        ub.data_box = BoxSet::from_extents(&[64, 64]); // too small for halo
        assert!(ub.verify(1).is_err());
    }

    #[test]
    fn max_live_is_one_line_plus_window() {
        let ub = brighten_buffer();
        // A 2x2 stencil over 65-wide rows keeps ~one row + a bit live.
        // Paper §V-C: "polyhedral analysis identifies that there are a
        // maximum of 64 live pixels" for the delay-64 part; with the
        // 2 extra shift-register values the full buffer holds ~66-67.
        let live = ub.max_live().unwrap();
        assert!(
            (64..=70).contains(&live),
            "expected about one row live, got {live}"
        );
    }

    #[test]
    fn dependence_distances_match_fig8a() {
        let ub = brighten_buffer();
        // Fig 8a: the four read ports' distances from the write port
        // differ by the spatial offsets 0/1/65/66 (rows are 65 wide
        // here). The port reading the *newest* value, (y+1, x+1), has the
        // smallest distance; the (y, x) port the largest.
        let d: Vec<i64> = ub
            .outputs
            .iter()
            .map(|o| ub.dependence_distance(&ub.inputs[0], o).unwrap())
            .collect();
        assert_eq!(d[0] - d[1], 1);
        assert_eq!(d[0] - d[2], 65);
        assert_eq!(d[0] - d[3], 66);
        assert!(d[3] >= 1, "tightest dependence must be causal");
    }

    #[test]
    fn dependence_distance_none_for_transpose() {
        // A transposed read has no constant cycle distance.
        let mut ub = UnifiedBuffer::new("t", BoxSet::from_extents(&[8, 8]));
        ub.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[8, 8]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[8, 8], 1, 0),
        ));
        let transpose = AffineMap::new(2, vec![Affine::var(2, 1), Affine::var(2, 0)]);
        ub.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[8, 8]),
            transpose,
            CycleSchedule::row_major(&[8, 8], 1, 64),
        ));
        assert_eq!(ub.dependence_distance(&ub.inputs[0], &ub.outputs[0]), None);
        // But it still verifies (all reads after writes).
        ub.verify(1).unwrap();
    }

    #[test]
    fn max_live_full_buffer_when_sequential() {
        // Sequential schedules (consumer starts after producer finishes)
        // keep the whole 8x8 buffer live — the Table VII effect.
        let mut ub = UnifiedBuffer::new("s", BoxSet::from_extents(&[8, 8]));
        ub.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[8, 8]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[8, 8], 1, 0),
        ));
        ub.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[8, 8]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[8, 8], 1, 64),
        ));
        assert_eq!(ub.max_live().unwrap(), 64);
    }

    #[test]
    fn double_write_rejected() {
        let mut ub = UnifiedBuffer::new("d", BoxSet::from_extents(&[4]));
        for k in 0..2 {
            ub.add_input(Port::new(
                format!("w{k}"),
                PortDir::In,
                BoxSet::from_extents(&[4]),
                AffineMap::identity(1),
                CycleSchedule::row_major(&[4], 1, k * 10),
            ));
        }
        assert!(ub.verify(0).is_err());
        assert!(ub.max_live().is_err());
    }
}
