//! The unified buffer abstraction (§III).
//!
//! A unified buffer is a push memory described *only* by its ports. Each
//! port carries three pieces of polyhedral information:
//!
//! 1. the **iteration domain** of the operations using the port,
//! 2. the **access map** from iteration points to buffer coordinates,
//! 3. the cycle-accurate **schedule** mapping iteration points to the
//!    cycle (after reset) when the operation occurs.
//!
//! Physical capacity and data placement are deliberately *not* part of
//! the abstraction — they are derived by buffer mapping (§V-C), which
//! gives the hardware side freedom to implement the interface with shift
//! registers, banked wide-fetch SRAMs, or chains thereof.

pub mod buffer;
pub mod graph;
pub mod port;

pub use buffer::UnifiedBuffer;
pub use graph::{KernelNode, StreamEndpoint, UbGraph};
pub use port::{Port, PortDir};
