//! Unified buffer ports.

use std::fmt;

use crate::poly::{AffineMap, BoxSet, CycleSchedule};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PortDir {
    /// Data flows *into* the buffer (a producer writes here).
    In,
    /// Data is *pushed out* of the buffer to a consumer.
    Out,
}

/// One port of a unified buffer: the polyhedral triple from Fig 2.
#[derive(Clone, Debug)]
pub struct Port {
    pub name: String,
    pub dir: PortDir,
    /// Iteration domain of the operations using this port.
    pub domain: BoxSet,
    /// Access map: iteration point -> buffer coordinates.
    pub access: AffineMap,
    /// Cycle-accurate schedule: iteration point -> cycles after reset.
    pub schedule: CycleSchedule,
}

impl Port {
    pub fn new(
        name: impl Into<String>,
        dir: PortDir,
        domain: BoxSet,
        access: AffineMap,
        schedule: CycleSchedule,
    ) -> Self {
        let p = Port { name: name.into(), dir, domain, access, schedule };
        assert_eq!(p.access.in_rank, p.domain.rank(), "access rank mismatch on {}", p.name);
        assert_eq!(p.schedule.rank(), p.domain.rank(), "schedule rank mismatch on {}", p.name);
        p
    }

    /// Number of operations this port performs.
    pub fn op_count(&self) -> i64 {
        self.domain.cardinality()
    }

    /// First and last cycle the port is active (inclusive).
    pub fn active_span(&self) -> (i64, i64) {
        self.schedule.span(&self.domain)
    }

    /// A port must not issue two operations in the same cycle.
    pub fn schedule_is_valid(&self) -> bool {
        self.schedule.is_injective_on(&self.domain)
    }

    /// Visit `(cycle, coordinates)` events in iteration order without
    /// allocating per event (schedules are monotone on row-major
    /// domains, so iteration order is schedule order for all ports the
    /// compiler builds).
    pub fn visit_events(&self, mut f: impl FnMut(i64, &[i64])) {
        let mut coords: Vec<i64> = vec![0; self.access.out_rank()];
        self.domain.for_each_point(|p| {
            for (c, o) in coords.iter_mut().zip(&self.access.outputs) {
                *c = o.eval(p);
            }
            f(self.schedule.cycle(p), &coords);
        });
    }

    /// Enumerate `(cycle, buffer coordinates)` events, in schedule order.
    pub fn events(&self) -> Vec<(i64, Vec<i64>)> {
        let mut ev: Vec<(i64, Vec<i64>)> = self
            .domain
            .points()
            .map(|p| (self.schedule.cycle(&p), self.access.apply(&p)))
            .collect();
        ev.sort_by_key(|(t, _)| *t);
        ev
    }
}

impl fmt::Display for Port {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} {:?}: dom {} access {} sched {}",
            self.name, self.dir, self.domain, self.access, self.schedule
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::Affine;

    /// The paper's Fig 2 input port: 64x64 domain, identity access,
    /// schedule 64y + x.
    fn fig2_input() -> Port {
        Port::new(
            "in0",
            PortDir::In,
            BoxSet::from_extents(&[64, 64]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[64, 64], 1, 0),
        )
    }

    #[test]
    fn op_count_and_span() {
        let p = fig2_input();
        assert_eq!(p.op_count(), 4096);
        assert_eq!(p.active_span(), (0, 4095));
        assert!(p.schedule_is_valid());
    }

    #[test]
    fn events_sorted_by_cycle() {
        let p = fig2_input();
        let ev = p.events();
        assert_eq!(ev[0], (0, vec![0, 0]));
        assert_eq!(ev[1], (1, vec![0, 1]));
        assert_eq!(ev[64], (64, vec![1, 0]));
        assert!(ev.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn output_port_with_offset_access() {
        // Fig 2 output port 2: access (x+1, y), first emit at cycle 65.
        let p = Port::new(
            "out1",
            PortDir::Out,
            BoxSet::from_extents(&[64, 64]),
            AffineMap::new(
                2,
                vec![Affine::var(2, 0), Affine::new(vec![0, 1], 1)],
            ),
            CycleSchedule::row_major(&[64, 64], 1, 65),
        );
        assert_eq!(p.active_span().0, 65);
        assert_eq!(p.events()[0], (65, vec![0, 1]));
    }
}
