//! Vectorization (§V-C "Vectorization", Eq 2/3, Fig 9): derive the
//! AGG / wide-SRAM / TB controller configurations for one memory bank.
//!
//! The external serial ports keep their (already affine) UB schedules;
//! their AGG/TB slot addresses are the linear layout address wrapped
//! `mod fetch_width` — expressible directly in the AG hardware's
//! modulus wrap, so no re-fitting is needed. The internal AGG→SRAM and
//! SRAM→TB controllers are derived from exact event lists (grouping the
//! write stream into fetch-width generations; deduplicating consecutive
//! vector uses of each read stream), fitted back to affine AG/SG
//! configurations, conflict-resolved on the single SRAM port, and
//! finally re-verified event-by-event.

use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};

use super::linearize::Layout;
use crate::hw::{AffineConfig, MemTileConfig, PortCtlConfig};
use crate::poly::{fit_affine, Affine, BoxSet};
use crate::ub::{Port, UnifiedBuffer};

/// Fit `(time, addr)` event sequences to an affine controller over some
/// reshape of the sequence index. Candidate shapes: 1-D, plus 2-D splits
/// by each divisor prefix from `hint_extents` (the port's loop
/// structure, so row-gap schedules fit as (row, group) domains).
fn fit_events(
    events: &[(i64, i64)],
    hint_extents: &[i64],
) -> Option<(Vec<i64>, Affine, Affine)> {
    let n = events.len() as i64;
    if n == 0 {
        return None;
    }
    let mut shapes: Vec<Vec<i64>> = vec![vec![n]];
    let mut prefix = 1i64;
    for &e in &hint_extents[..hint_extents.len().saturating_sub(1)] {
        prefix *= e;
        if prefix > 1 && prefix < n && n % prefix == 0 {
            shapes.push(vec![prefix, n / prefix]);
        }
    }
    for shape in shapes {
        let dom = BoxSet::from_extents(&shape);
        let lex = |p: &[i64]| -> usize {
            let mut idx = 0i64;
            for (k, v) in p.iter().enumerate() {
                idx = idx * shape[k] + v;
            }
            idx as usize
        };
        let t = fit_affine(&dom, &mut |p| Some(events[lex(p)].0));
        let a = fit_affine(&dom, &mut |p| Some(events[lex(p)].1));
        if let (Some(t), Some(a)) = (t, a) {
            return Some((shape, t, a));
        }
    }
    None
}

/// The write stream of a bank, merged across input ports and sorted by
/// flat address (generation order). Returns `(flush_time, generation)`
/// per fetch-width group, plus per-generation flush times for checks.
fn flush_events(
    ub: &UnifiedBuffer,
    in_ports: &[usize],
    layout: &Layout,
    fw: i64,
) -> Result<Vec<(i64, i64)>> {
    let mut writes: Vec<(i64, i64)> = Vec::new(); // (flat, t)
    for &i in in_ports {
        for (t, coords) in ub.inputs[i].events() {
            writes.push((layout.flat(&coords), t));
        }
    }
    writes.sort();
    // Writes must be contiguous *within each generation* (row-pitch
    // padding leaves unwritten slots only at generation tails, which
    // are never read). Check: consecutive flats either increment by 1
    // or jump to the start of a later generation.
    for w in writes.windows(2) {
        let (a, b) = (w[0].0, w[1].0);
        let ok = b == a + 1 || (b > a && b % fw == 0);
        anyhow::ensure!(
            ok,
            "buffer {}: write stream not generation-contiguous ({a} -> {b})",
            ub.name
        );
    }
    // Group by generation = floor(flat / fw) and flush when the last
    // slot *would* land if the generation were dense: tail-missing
    // generations (row-pitch padding, final partials) flush padded by
    // their missing-slot count, keeping the SG affine — but never at or
    // after the next generation's first write, which starts overwriting
    // the shared aggregator slots (the pitch wrap aliases slot indices).
    struct Gen {
        gen: i64,
        last_flat: i64,
        first_t: i64,
        last_t: i64,
    }
    let mut gens: Vec<Gen> = Vec::new();
    for &(flat, t) in &writes {
        let g = flat.div_euclid(fw);
        match gens.last_mut() {
            Some(cur) if cur.gen == g => {
                cur.last_t = cur.last_t.max(t);
                cur.last_flat = flat;
            }
            _ => gens.push(Gen { gen: g, last_flat: flat, first_t: t, last_t: t }),
        }
    }
    let lanes = in_ports.len().max(1) as i64;
    let mut out: Vec<(i64, i64)> = Vec::new();
    for (k, gi) in gens.iter().enumerate() {
        let missing_tail = (gi.gen + 1) * fw - 1 - gi.last_flat;
        // Pad in *cycles*: `lanes` slots land per cycle.
        let mut t = gi.last_t + (missing_tail + lanes - 1) / lanes;
        if let Some(next) = gens.get(k + 1) {
            t = t.min(next.first_t - 1).max(gi.last_t);
        }
        out.push((t, gi.gen));
    }
    // Flush times must follow generation order for the SG recurrence.
    for w in out.windows(2) {
        anyhow::ensure!(
            w[0].0 < w[1].0,
            "buffer {}: flush times not increasing",
            ub.name
        );
    }
    Ok(out)
}

/// Vector-use runs of one output port: `(first_use, gen)` per maximal
/// run of consecutive uses of the same generation.
fn use_runs(port: &Port, layout: &Layout, fw: i64) -> Vec<(i64, i64)> {
    let mut out: Vec<(i64, i64)> = Vec::new();
    for (t, coords) in port.events() {
        let gen = layout.flat(&coords).div_euclid(fw);
        match out.last() {
            Some(&(_, g)) if g == gen => {}
            _ => out.push((t, gen)),
        }
    }
    out
}

/// Read plan: issue each vector read at `first_use - 2 - extra_lead`.
fn read_events_from(runs: &[(i64, i64)], extra_lead: i64) -> Vec<(i64, i64)> {
    runs.iter().map(|&(t, g)| (t - 2 - extra_lead, g)).collect()
}

/// Regular-cadence fallbacks for ports whose vector uses straddle
/// generation boundaries (offset accesses): issue reads on an even II,
/// starting as late as every per-run deadline allows. Several candidate
/// IIs are produced (observed run gaps plus the fetch width); the
/// caller's event-level verifier decides which (if any) is hazard-free.
/// Returns nothing when the generation sequence itself is not affine in
/// the run index.
fn regular_read_events(
    runs: &[(i64, i64)],
    fw: i64,
    extra_lead: i64,
) -> Vec<Vec<(i64, i64)>> {
    let n = runs.len() as i64;
    if n == 0 {
        return vec![];
    }
    if n == 1 {
        return vec![vec![(runs[0].0 - 2 - extra_lead, runs[0].1)]];
    }
    let gstep = runs[1].1 - runs[0].1;
    if runs
        .iter()
        .enumerate()
        .any(|(i, &(_, g))| g != runs[0].1 + gstep * i as i64)
    {
        return vec![];
    }
    // Candidate IIs: distinct consecutive run gaps, the fetch width,
    // and the tightest deadline slope.
    let mut iis: Vec<i64> = runs.windows(2).map(|w| w[1].0 - w[0].0).collect();
    iis.push(fw);
    iis.push(
        (1..n)
            .map(|i| (runs[i as usize].0 - runs[0].0) / i)
            .min()
            .unwrap()
            .max(1),
    );
    iis.sort();
    iis.dedup();
    iis.retain(|&ii| ii >= 1);
    iis.iter()
        .map(|&ii| {
            let t0 = (0..n)
                .map(|i| runs[i as usize].0 - 2 - ii * i)
                .min()
                .unwrap()
                - extra_lead;
            (0..n)
                .map(|i| (t0 + ii * i, runs[0].1 + gstep * i))
                .collect()
        })
        .collect()
}

/// Exact event-level verification of a bank: every serialized output
/// word must come from a vector that was flushed, read after its flush,
/// landed before first use, not overwritten in SRAM before its read,
/// and not clobbered in the TB before its last use.
fn verify_bank(
    ub: &UnifiedBuffer,
    out_ports: &[usize],
    layout: &Layout,
    fw: i64,
    flushes: &[(i64, i64)],
    reads: &[Vec<(i64, i64)>],
    port_uses: &[Vec<(i64, i64)>], // per out port: (t_use, gen), precomputed
) -> Result<()> {
    let vecs = layout.capacity / fw;
    let flush_t: HashMap<i64, i64> = flushes.iter().map(|&(t, g)| (g, t)).collect();
    // Next flush to the same vector address (staleness horizon).
    let mut next_alias: HashMap<i64, i64> = HashMap::new();
    for &(t, g) in flushes.iter().rev() {
        let v = g.rem_euclid(vecs);
        if let Some(&nt) = next_alias.get(&v) {
            anyhow::ensure!(t < nt, "flush order violation");
        }
        next_alias.insert(v, t);
    }
    // Rebuild per-vaddr alias chains for staleness checks.
    let mut alias_chains: HashMap<i64, Vec<(i64, i64)>> = HashMap::new(); // vaddr -> [(flush_t, gen)]
    for &(t, g) in flushes {
        alias_chains.entry(g.rem_euclid(vecs)).or_default().push((t, g));
    }

    for (k, &o) in out_ports.iter().enumerate() {
        let port = &ub.outputs[o];
        let rd = &reads[k];
        for w in rd.windows(2) {
            anyhow::ensure!(w[0].0 < w[1].0, "port {}: read times not increasing", port.name);
        }
        for &(t_use, gen) in &port_uses[k] {
            // The read whose data occupies this value's ping-pong half
            // during t_use's output phase: loads land *after* the output
            // phase of issue+1, so data issued at ti is visible from
            // ti+2 (and the previous occupant of the half through
            // ti+1). Halves alternate with generation parity (even
            // vector count).
            let occ = rd
                .iter()
                .rev()
                .find(|&&(ti, g)| ti + 2 <= t_use && (g - gen).rem_euclid(2) == 0)
                .with_context(|| format!("port {}: no read lands by {t_use}", port.name))?;
            anyhow::ensure!(
                occ.1 == gen,
                "port {}: TB half holds gen {} at cycle {t_use}, value needs gen {gen}",
                port.name,
                occ.1
            );
            let tf = *flush_t
                .get(&gen)
                .with_context(|| format!("gen {gen} never flushed"))?;
            anyhow::ensure!(
                occ.0 > tf,
                "port {}: read of gen {gen} at {} before flush at {tf}",
                port.name,
                occ.0
            );
            // The vector must not be overwritten in SRAM before the read.
            let chain = &alias_chains[&gen.rem_euclid(vecs)];
            if let Some(&(nt, _)) = chain.iter().find(|&&(t, g)| g > gen && t <= occ.0) {
                bail!(
                    "port {}: gen {gen} overwritten at {nt} before read at {}",
                    port.name,
                    occ.0
                );
            }
        }
    }
    Ok(())
}

/// SRAM single-port conflict scan across flush + read controllers.
fn conflicts(flushes: &[(i64, i64)], reads: &[Vec<(i64, i64)>]) -> HashSet<i64> {
    let mut used: HashSet<i64> = HashSet::new();
    let mut bad = HashSet::new();
    for &(t, _) in flushes {
        if !used.insert(t) {
            bad.insert(t);
        }
    }
    for rd in reads {
        for &(t, _) in rd {
            if !used.insert(t) {
                bad.insert(t);
            }
        }
    }
    bad
}

/// Build the memory-tile configuration for one bank.
pub fn build_bank(
    ub: &UnifiedBuffer,
    layout: &Layout,
    in_ports: &[usize],
    out_ports: &[usize],
    fw: usize,
) -> Result<MemTileConfig> {
    let fwi = fw as i64;
    anyhow::ensure!(layout.capacity % fwi == 0, "capacity not a vector multiple");
    let vecs = layout.capacity / fwi;

    // External serial controllers: UB schedules + layout addresses with
    // a fetch-width modulus (slot) — affine by construction.
    let mut serial_in = Vec::new();
    for &i in in_ports {
        let p = &ub.inputs[i];
        let flat = layout.linear.compose(&p.access.outputs);
        serial_in.push(
            PortCtlConfig::new(
                p.domain.dims.iter().map(|d| d.extent).collect(),
                AffineConfig::from_affine(&zero_base(&flat, &p.domain)),
                AffineConfig::from_affine(&zero_base(&p.schedule.expr, &p.domain)),
            )
            .with_modulus(fwi),
        );
    }
    // TB slots span two ping-pong vectors: slot = flat mod 2*fw, with
    // the landing half chosen by vector-address parity (requires an
    // even vector count, i.e. capacity a multiple of 2*fw).
    anyhow::ensure!(vecs % 2 == 0, "capacity {} gives odd vector count", layout.capacity);
    let mut tb_out = Vec::new();
    for &o in out_ports {
        let p = &ub.outputs[o];
        let flat = layout.linear.compose(&p.access.outputs);
        tb_out.push(
            PortCtlConfig::new(
                p.domain.dims.iter().map(|d| d.extent).collect(),
                AffineConfig::from_affine(&zero_base(&flat, &p.domain)),
                AffineConfig::from_affine(&zero_base(&p.schedule.expr, &p.domain)),
            )
            .with_modulus(2 * fwi),
        );
    }

    // AGG flush controller (one shared AGG across write lanes).
    let fl_events = flush_events(ub, in_ports, layout, fwi)?;
    let hint: Vec<i64> = in_ports
        .first()
        .map(|&i| ub.inputs[i].domain.dims.iter().map(|d| d.extent).collect())
        .unwrap_or_default();

    // Read controllers: per-port candidate plans (run-based with
    // increasing leads, then regular-cadence fallbacks), searched
    // greedily for a combination that is conflict-free, hazard-free,
    // and affine-fittable.
    let out_hints: Vec<Vec<i64>> = out_ports
        .iter()
        .map(|&o| ub.outputs[o].domain.dims.iter().map(|d| d.extent).collect())
        .collect();
    // Precompute each port's (use time, generation) stream once — the
    // verifier runs inside the candidate product search (§Perf).
    let port_uses: Vec<Vec<(i64, i64)>> = out_ports
        .iter()
        .map(|&o| {
            ub.outputs[o]
                .events()
                .into_iter()
                .map(|(t, coords)| (t, layout.flat(&coords).div_euclid(fwi)))
                .collect()
        })
        .collect();
    let candidates: Vec<Vec<Vec<(i64, i64)>>> = out_ports
        .iter()
        .enumerate()
        .map(|(k, &o)| {
            // The vector-use runs are computed once; every lead variant
            // is a constant time shift, and affinity is shift-invariant,
            // so each candidate family is fitted exactly once (§Perf).
            let runs = use_runs(&ub.outputs[o], layout, fwi);
            let mut c = Vec::new();
            let base = read_events_from(&runs, 0);
            // Only keep plans the AG/SG hardware can hold.
            if fit_events(&base, &out_hints[k]).is_some() {
                c.push(base);
                for lead in 1..2 * fwi {
                    c.push(read_events_from(&runs, lead));
                }
            }
            for ev0 in regular_read_events(&runs, fwi, 0) {
                if fit_events(&ev0, &out_hints[k]).is_some() {
                    for lead in 1..2 * fwi {
                        c.push(ev0.iter().map(|&(t, g)| (t - lead, g)).collect());
                    }
                    c.push(ev0);
                }
            }
            c
        })
        .collect();
    for (k, c) in candidates.iter().enumerate() {
        anyhow::ensure!(
            !c.is_empty(),
            "buffer {}: no affine read schedule for port {}",
            ub.name,
            out_ports[k]
        );
    }
    // Exhaustive (bounded) product search over per-port candidates: the
    // space is tiny (≤ 3 ports × ~16 candidates) and the event-level
    // verifier is the only trustworthy judge.
    let mut pick = vec![0usize; out_ports.len()];
    let mut found: Option<Vec<Vec<(i64, i64)>>> = None;
    let mut budget = 50_000usize;
    'product: loop {
        let reads: Vec<Vec<(i64, i64)>> = pick
            .iter()
            .enumerate()
            .map(|(k, &c)| candidates[k][c].clone())
            .collect();
        if conflicts(&fl_events, &reads).is_empty()
            && verify_bank(ub, out_ports, layout, fwi, &fl_events, &reads, &port_uses).is_ok()
        {
            found = Some(reads);
            break 'product;
        }
        budget -= 1;
        if budget == 0 {
            break 'product;
        }
        // Odometer increment.
        let mut k = 0;
        loop {
            if k == pick.len() {
                break 'product;
            }
            pick[k] += 1;
            if pick[k] < candidates[k].len() {
                break;
            }
            pick[k] = 0;
            k += 1;
        }
    }
    if found.is_none() && std::env::var("PUSHMEM_DEBUG_MAP").is_ok() {
        eprintln!(
            "[map] {}: flushes {:?}...",
            ub.name,
            &fl_events[..fl_events.len().min(6)]
        );
        for (k, c) in candidates.iter().enumerate() {
            eprintln!("[map] port {} has {} candidates", out_ports[k], c.len());
            if let Some(first) = c.first() {
                eprintln!("[map]   first: {:?}...", &first[..first.len().min(6)]);
                let bad = conflicts(&fl_events, &[first.clone()]);
                eprintln!(
                    "[map]   conflicts {:?} verify {:?}",
                    bad.iter().take(4).collect::<Vec<_>>(),
                    verify_bank(ub, &out_ports[k..=k], layout, fwi, &fl_events, &[first.clone()], &port_uses[k..=k])
                        .err()
                        .map(|e| e.to_string())
                );
            }
        }
    }
    let reads = found.with_context(|| {
        format!("buffer {}: cannot find conflict-free vectorized schedule", ub.name)
    })?;

    // Fit the internal controllers to affine hardware.
    let (fsh, ft, fa) = fit_events(&fl_events, &hint)
        .with_context(|| format!("buffer {}: flush schedule not affine", ub.name))?;
    let agg_flush = vec![PortCtlConfig::new(
        fsh,
        AffineConfig::from_affine(&fa),
        AffineConfig::from_affine(&ft),
    )
    .with_modulus(vecs)];

    let mut sram_read = Vec::new();
    for (k, rd) in reads.iter().enumerate() {
        let (rsh, rt, ra) = fit_events(rd, &out_hints[k]).with_context(|| {
            format!(
                "buffer {}: read schedule for port {} not affine",
                ub.name, out_ports[k]
            )
        })?;
        sram_read.push(
            PortCtlConfig::new(
                rsh,
                AffineConfig::from_affine(&ra),
                AffineConfig::from_affine(&rt),
            )
            .with_modulus(vecs),
        );
    }

    Ok(MemTileConfig {
        fetch_width: fw,
        capacity: layout.capacity as usize,
        serial_in_agg: vec![0; serial_in.len()],
        serial_in,
        agg_flush,
        sram_read,
        tb_out,
    })
}

/// Build a dual-port fallback bank: word-granular, always affine
/// (address = linear layout mod capacity, schedule = the UB port
/// schedule itself), for ports the wide-fetch path cannot serve.
/// Verifies write/read port conflicts and read-after-write timing.
pub fn build_dp_bank(
    ub: &UnifiedBuffer,
    layout: &Layout,
    in_ports: &[usize],
    out_ports: &[usize],
) -> Result<crate::hw::DpTileConfig> {
    anyhow::ensure!(out_ports.len() <= 1, "dual-port bank has one read port");
    let cap = layout.capacity;

    // Event-level verification.
    let mut wt: HashMap<i64, Vec<(i64, i64)>> = HashMap::new(); // addr -> [(t, flat)]
    let mut wcycles: HashSet<i64> = HashSet::new();
    for &i in in_ports {
        for (t, coords) in ub.inputs[i].events() {
            anyhow::ensure!(
                wcycles.insert(t),
                "buffer {}: two DP writes in cycle {t}",
                ub.name
            );
            let flat = layout.flat(&coords);
            wt.entry(flat.rem_euclid(cap)).or_default().push((t, flat));
        }
    }
    for v in wt.values_mut() {
        v.sort();
    }
    for &o in out_ports {
        let mut rcycles: HashSet<i64> = HashSet::new();
        for (t, coords) in ub.outputs[o].events() {
            anyhow::ensure!(
                rcycles.insert(t - 1),
                "buffer {}: two DP reads in cycle {}",
                ub.name,
                t - 1
            );
            let flat = layout.flat(&coords);
            let chain = wt
                .get(&flat.rem_euclid(cap))
                .with_context(|| format!("buffer {}: read of unwritten {flat}", ub.name))?;
            // Write must commit (end of its cycle) before the read
            // issues at t-1: w <= t-2; and no aliasing overwrite before.
            let w = chain
                .iter()
                .find(|&&(_, f)| f == flat)
                .with_context(|| format!("buffer {}: flat {flat} never written", ub.name))?;
            anyhow::ensure!(
                w.0 <= t - 2,
                "buffer {}: DP read at {t} too soon after write at {}",
                ub.name,
                w.0
            );
            if let Some(ov) = chain.iter().find(|&&(tw, f)| f > flat && tw <= t - 1) {
                bail!(
                    "buffer {}: flat {flat} overwritten at {} before read at {t}",
                    ub.name,
                    ov.0
                );
            }
        }
    }

    let mk = |p: &Port| -> PortCtlConfig {
        let flat = layout.linear.compose(&p.access.outputs);
        PortCtlConfig::new(
            p.domain.dims.iter().map(|d| d.extent).collect(),
            AffineConfig::from_affine(&zero_base(&flat, &p.domain)),
            AffineConfig::from_affine(&zero_base(&p.schedule.expr, &p.domain)),
        )
        .with_modulus(cap)
    };
    Ok(crate::hw::DpTileConfig {
        capacity: cap as usize,
        writes: in_ports.iter().map(|&i| mk(&ub.inputs[i])).collect(),
        reads: out_ports.iter().map(|&o| mk(&ub.outputs[o])).collect(),
    })
}

/// Rebase an affine expression onto the hardware ID's zero-based
/// counters: `new(c) = a(c + mins)`.
fn zero_base(a: &Affine, domain: &BoxSet) -> Affine {
    let mins: Vec<i64> = domain.dims.iter().map(|d| d.min).collect();
    let delta: i64 = a.coeffs.iter().zip(&mins).map(|(c, m)| c * m).sum();
    a.shift(delta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::MemTile;
    use crate::mapping::linearize;
    use crate::poly::{AffineMap, CycleSchedule};
    use crate::ub::PortDir;

    /// 1-D delay buffer: 32 words written densely at t = x, read
    /// identically at t = x + 12.
    fn delay_ub(delay: i64) -> UnifiedBuffer {
        let mut ub = UnifiedBuffer::new("d", BoxSet::from_extents(&[32]));
        ub.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[32]),
            AffineMap::identity(1),
            CycleSchedule::row_major(&[32], 1, 0),
        ));
        ub.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[32]),
            AffineMap::identity(1),
            CycleSchedule::row_major(&[32], 1, delay),
        ));
        ub
    }

    /// Run a configured tile against the UB's port events and check the
    /// output stream bit-exactly.
    fn run_and_check(ub: &UnifiedBuffer, cfg: MemTileConfig, horizon: i64) {
        let mut tile = MemTile::new(cfg);
        // Input data: value = 1000 + flat index, delivered per schedule.
        let mut in_events: HashMap<i64, Vec<Option<i64>>> = HashMap::new();
        let layout = linearize::choose_capacity(ub, 4).unwrap();
        for (i, p) in ub.inputs.iter().enumerate() {
            for (t, coords) in p.events() {
                in_events.entry(t).or_insert_with(|| vec![None; ub.inputs.len()])[i] =
                    Some(1000 + layout.flat(&coords));
            }
        }
        let mut expected: HashMap<(i64, usize), i64> = HashMap::new();
        for (o, p) in ub.outputs.iter().enumerate() {
            for (t, coords) in p.events() {
                expected.insert((t, o), 1000 + layout.flat(&coords));
            }
        }
        let none = vec![None; ub.inputs.len()];
        let mut seen = 0usize;
        for cycle in 0..horizon {
            let ins = in_events.get(&cycle).unwrap_or(&none);
            let outs = tile.tick(cycle, ins).unwrap();
            for (o, w) in outs.iter().enumerate() {
                if let Some(v) = w {
                    let exp = expected
                        .get(&(cycle, o))
                        .unwrap_or_else(|| panic!("unexpected output at {cycle} port {o}"));
                    assert_eq!(v, exp, "wrong word at cycle {cycle} port {o}");
                    seen += 1;
                }
            }
        }
        assert_eq!(seen, expected.len(), "missing output words");
    }

    #[test]
    fn delay_buffer_vectorizes_and_runs() {
        let ub = delay_ub(12);
        let layout = linearize::choose_capacity(&ub, 8).unwrap();
        let cfg = build_bank(&ub, &layout, &[0], &[0], 4).unwrap();
        assert_eq!(cfg.serial_in.len(), 1);
        assert_eq!(cfg.agg_flush.len(), 1);
        run_and_check(&ub, cfg, 60);
    }

    #[test]
    fn line_buffer_with_row_gaps() {
        // 8x8 writes on 9-stride rows (virtual row idling), read one row
        // later: flush/read schedules must fit as (row, group) domains.
        let mut ub = UnifiedBuffer::new("lb", BoxSet::from_extents(&[8, 8]));
        ub.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[8, 8]),
            AffineMap::identity(2),
            CycleSchedule::new(Affine::new(vec![9, 1], 0)),
        ));
        ub.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[8, 8]),
            AffineMap::identity(2),
            CycleSchedule::new(Affine::new(vec![9, 1], 20)),
        ));
        let layout = linearize::choose_capacity(&ub, 8).unwrap();
        let cfg = build_bank(&ub, &layout, &[0], &[0], 4).unwrap();
        run_and_check(&ub, cfg, 120);
    }

    #[test]
    fn offset_read_port_spans_generations() {
        // Read port accesses x+1: its vector uses straddle generation
        // boundaries; the regular-read fallback must still verify.
        let mut ub = UnifiedBuffer::new("off", BoxSet::from_extents(&[33]));
        ub.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[33]),
            AffineMap::identity(1),
            CycleSchedule::row_major(&[33], 1, 0),
        ));
        ub.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[32]),
            AffineMap::new(1, vec![Affine::new(vec![1], 1)]),
            CycleSchedule::row_major(&[32], 1, 12),
        ));
        let layout = linearize::choose_capacity(&ub, 8).unwrap();
        let cfg = build_bank(&ub, &layout, &[0], &[0], 4);
        match cfg {
            Ok(cfg) => run_and_check(&ub, cfg, 80),
            Err(e) => panic!("offset port failed to map: {e:#}"),
        }
    }

    #[test]
    fn circular_capacity_buffer_runs() {
        // Delay 12 over 32 words: capacity 16 (not 32) — circular reuse.
        let ub = delay_ub(12);
        let layout = linearize::choose_capacity(&ub, 8).unwrap();
        assert!(layout.capacity < 32);
        let cfg = build_bank(&ub, &layout, &[0], &[0], 4).unwrap();
        assert_eq!(cfg.capacity as i64, layout.capacity);
        run_and_check(&ub, cfg, 60);
    }
}

