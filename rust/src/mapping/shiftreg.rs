//! Shift-register introduction (§V-C, Fig 8a).
//!
//! An output port can be served by a register chain when there is a
//! constant cycle distance between it and a source whose value stream is
//! a superset of what the port needs. The planner sorts convertible
//! ports by distance and walks the chain greedily: short gaps become
//! registers; a long gap makes the port memory-served, and later ports
//! may chain *off that port's output* — reproducing the paper's
//! "two shift registers and a memory that delays by 64" structure.

use super::{PortImpl, SrSource, SR_MAX_GAP};
use crate::ub::UnifiedBuffer;

/// The shift-register plan for one buffer: a tentative [`PortImpl`] per
/// output port where `Mem.bank/out_idx` are placeholders (banking
/// assigns them later), plus the register word count.
///
/// `dist[k]` records the constant `(input port, cycle distance)` of
/// output port `k` from the write stream, when one exists. Mem-class
/// ports *with* a constant distance are implemented as **delay banks**
/// (a memory replaying the full write stream `d` cycles later — the
/// "memory that delays by 64" of Fig 8a) so that chained taps see every
/// value, including ones the port itself never samples; ports without
/// a constant distance get addressed banks.
#[derive(Clone, Debug)]
pub struct SrPlan {
    pub impls: Vec<PortImpl>,
    pub sr_words: i64,
    pub dist: Vec<Option<(usize, i64)>>,
}

pub fn plan(ub: &UnifiedBuffer) -> SrPlan {
    // Distance of each output port from each input port (if constant).
    // The per-input write map is built once and probed for every output
    // port (§Perf).
    let write_maps: Vec<_> = ub
        .inputs
        .iter()
        .map(|p| ub.event_time_map(p))
        .collect();
    let mut dist: Vec<Option<(usize, i64)>> = Vec::with_capacity(ub.outputs.len());
    for out in &ub.outputs {
        let mut found = None;
        for (i, wt) in write_maps.iter().enumerate() {
            if let Some(d) = ub.distance_against(wt, out) {
                found = Some((i, d));
                break;
            }
        }
        dist.push(found);
    }

    // Sort convertible ports by distance; walk the chain.
    let mut order: Vec<usize> = (0..ub.outputs.len())
        .filter(|&k| dist[k].is_some())
        .collect();
    order.sort_by_key(|&k| dist[k].unwrap());

    let mut impls: Vec<PortImpl> = (0..ub.outputs.len())
        .map(|_| PortImpl::Mem { bank: usize::MAX, out_idx: usize::MAX })
        .collect();
    let mut sr_words = 0i64;

    // Cursor per source input port: (SrSource, depth reached).
    let mut cursors: Vec<(SrSource, i64)> = Vec::new();
    for &k in &order {
        let (src_in, d) = dist[k].unwrap();
        // Find the deepest cursor on this input's chain not past d.
        let cursor = cursors
            .iter()
            .enumerate()
            .filter(|(_, (s, depth))| {
                *depth <= d
                    && match s {
                        SrSource::Input(i) => *i == src_in,
                        SrSource::Output(o) => {
                            matches!(dist[*o], Some((i, _)) if i == src_in)
                        }
                    }
            })
            .max_by_key(|(_, (_, depth))| *depth)
            .map(|(ci, c)| (ci, *c));
        let (base_src, base_depth) = match cursor {
            Some((_, c)) => c,
            None => (SrSource::Input(src_in), 0),
        };
        let gap = d - base_depth;
        if gap <= SR_MAX_GAP {
            impls[k] = PortImpl::Shift { src: base_src, depth: gap };
            sr_words += gap;
            cursors.push((SrSource::Output(k), d));
        } else {
            // Memory-served; later ports can chain off this output.
            impls[k] = PortImpl::Mem { bank: usize::MAX, out_idx: usize::MAX };
            cursors.push((SrSource::Output(k), d));
        }
    }

    SrPlan { impls, sr_words, dist }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::{Affine, AffineMap, BoxSet, CycleSchedule};
    use crate::ub::{Port, PortDir};

    /// The Fig 2/8a brighten buffer: write port + four 2x2-stencil read
    /// ports over 65-wide rows.
    fn brighten() -> UnifiedBuffer {
        let mut ub = UnifiedBuffer::new("brighten", BoxSet::from_extents(&[65, 65]));
        ub.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[65, 65]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[65, 65], 1, 0),
        ));
        for (k, (dy, dx)) in [(1i64, 1i64), (1, 0), (0, 1), (0, 0)].iter().enumerate() {
            ub.add_output(Port::new(
                format!("r{k}"),
                PortDir::Out,
                BoxSet::from_extents(&[64, 64]),
                AffineMap::new(
                    2,
                    vec![Affine::new(vec![1, 0], *dy), Affine::new(vec![0, 1], *dx)],
                ),
                CycleSchedule::new(Affine::new(vec![65, 1], 70)),
            ));
        }
        ub
    }

    #[test]
    fn fig8a_structure() {
        // Distances: port0 (y+1,x+1) newest: d = 70-66 = 4; port1 = 5;
        // port2 = 69; port3 = 70. Expect: SRs at 4 and +1, a memory for
        // the 64-gap, then +1 SR off the memory port.
        let ub = brighten();
        let plan = plan(&ub);
        assert_eq!(
            plan.impls[0],
            PortImpl::Shift { src: SrSource::Input(0), depth: 4 }
        );
        assert_eq!(
            plan.impls[1],
            PortImpl::Shift { src: SrSource::Output(0), depth: 1 }
        );
        // Port 2 (d=69): 64 gap from port1 -> memory.
        assert!(matches!(plan.impls[2], PortImpl::Mem { .. }));
        // Port 3 (d=70): 1 past the memory tap -> SR off output 2.
        assert_eq!(
            plan.impls[3],
            PortImpl::Shift { src: SrSource::Output(2), depth: 1 }
        );
        assert_eq!(plan.sr_words, 6);
    }

    #[test]
    fn non_constant_distance_stays_memory() {
        let mut ub = UnifiedBuffer::new("t", BoxSet::from_extents(&[8, 8]));
        ub.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[8, 8]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[8, 8], 1, 0),
        ));
        // Transposed read: no constant distance.
        ub.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[8, 8]),
            AffineMap::new(2, vec![Affine::var(2, 1), Affine::var(2, 0)]),
            CycleSchedule::row_major(&[8, 8], 1, 64),
        ));
        let plan = plan(&ub);
        assert!(matches!(plan.impls[0], PortImpl::Mem { .. }));
        assert_eq!(plan.sr_words, 0);
    }

    #[test]
    fn tight_wire_is_zero_depth_possible() {
        // Read exactly MEM_READ_MARGIN after write: small SR.
        let mut ub = UnifiedBuffer::new("w", BoxSet::from_extents(&[16]));
        ub.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[16]),
            AffineMap::identity(1),
            CycleSchedule::row_major(&[16], 1, 0),
        ));
        ub.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[16]),
            AffineMap::identity(1),
            CycleSchedule::row_major(&[16], 1, 4),
        ));
        let plan = plan(&ub);
        assert_eq!(
            plan.impls[0],
            PortImpl::Shift { src: SrSource::Input(0), depth: 4 }
        );
    }
}
