//! The top-level mapper: orchestrates shift-register introduction,
//! banking, linearization, vectorization and chaining per buffer, and
//! maps compute kernels onto PE configurations.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

use super::{
    banking, chain, linearize, shiftreg, vectorize, MappedBuffer, MappedDesign, MappedKernel,
    MappedPe, MemBank, OperandSrc, PortImpl, FETCH_WIDTH, TILE_CAPACITY_WORDS,
};
use crate::halide::expr::{eval_binop, BinOp, Expr};
use crate::hw::{PeConfig, PeOp};
use crate::ub::{KernelNode, UbGraph, UnifiedBuffer};

/// Vector-alignment class of a memory-served output port: the flat
/// address of its first event mod fetch width. Banks are built per
/// class so the layout can be shifted to put the port's accesses on
/// generation boundaries.
fn align_class(ub: &UnifiedBuffer, port: usize, fw: i64) -> i64 {
    let lin = linearize::padded_linear(ub, fw);
    ub.outputs[port]
        .events()
        .first()
        .map(|(_, coords)| lin.eval(coords).rem_euclid(fw))
        .unwrap_or(0)
}

/// Map one unified buffer to shift registers + memory banks.
fn map_buffer(ub: &UnifiedBuffer, fw: usize) -> Result<MappedBuffer> {
    let plan = shiftreg::plan(ub);
    let mut impls = plan.impls.clone();
    let mut banks: Vec<MemBank> = Vec::new();

    // Delay-class ports (constant distance, gap too long for registers):
    // build delay banks that replay the full write stream `d` cycles
    // later (Fig 8a's "memory that delays by 64"). Grouped per source
    // input lane and chunked by the bank port budget.
    let mut delay_groups: BTreeMap<usize, Vec<(usize, i64)>> = BTreeMap::new();
    for o in 0..ub.outputs.len() {
        if matches!(plan.impls[o], PortImpl::Mem { .. }) {
            if let Some((i, d)) = plan.dist[o] {
                delay_groups.entry(i).or_default().push((o, d));
            }
        }
    }
    for (src_in, ports) in &delay_groups {
        // Bandwidth budget: `lanes` interleaved write lanes complete a
        // vector every fw/lanes cycles (one flush), and each delayed
        // stream crosses a generation at the same rate (one read). A
        // single-port SRAM sustains fw/lanes - 1 delay ports, but a
        // fully saturated port cannot absorb the phase drift row-pitch
        // gaps introduce — keep one access slot of slack when possible.
        let lanes = ub.inputs.len().max(1);
        anyhow::ensure!(
            fw / lanes >= 2,
            "buffer {}: {lanes} write lanes saturate the fetch-width-{fw} SRAM",
            ub.name
        );
        let per_bank = (fw / lanes - 2).max(1);
        for chunk in ports.chunks(per_bank) {
            let bidx = banks.len();
            let mut view = UnifiedBuffer::new(ub.name.clone(), ub.data_box.clone());
            for p in &ub.inputs {
                view.add_input(p.clone());
            }
            let src = &ub.inputs[*src_in];
            for (k, (o, d)) in chunk.iter().enumerate() {
                view.add_output(crate::ub::Port::new(
                    format!("{}.delay{o}", ub.name),
                    crate::ub::PortDir::Out,
                    src.domain.clone(),
                    src.access.clone(),
                    src.schedule.delayed(*d),
                ));
                impls[*o] = PortImpl::Mem { bank: bidx, out_idx: k };
            }
            let in_idx: Vec<usize> = (0..ub.inputs.len()).collect();
            let out_idx: Vec<usize> = (0..chunk.len()).collect();
            let layout = linearize::choose_capacity(&view, 2 * fw as i64)?;
            match vectorize::build_bank(&view, &layout, &in_idx, &out_idx, fw) {
                Ok(config) => banks.push(MemBank {
                    config: super::BankConfig::Wide(config),
                    in_ports: in_idx,
                    out_ports: chunk.iter().map(|&(o, _)| o).collect(),
                    capacity_words: layout.capacity,
                    tiles: chain::tiles_needed(layout.capacity, TILE_CAPACITY_WORDS),
                }),
                Err(wide_err) => {
                    // Irregular tile widths can leave no conflict-free
                    // static schedule on the saturated single port;
                    // fall back to dual-port tiles, one delay stream
                    // each (Table II row 2 cost).
                    for (k, (o, d)) in chunk.iter().enumerate() {
                        let mut v1 = UnifiedBuffer::new(ub.name.clone(), ub.data_box.clone());
                        for p in &ub.inputs {
                            v1.add_input(p.clone());
                        }
                        let src = &ub.inputs[*src_in];
                        v1.add_output(crate::ub::Port::new(
                            format!("{}.delay{o}", ub.name),
                            crate::ub::PortDir::Out,
                            src.domain.clone(),
                            src.access.clone(),
                            src.schedule.delayed(*d),
                        ));
                        let lay = linearize::choose_capacity(&v1, 1)?;
                        let dp = vectorize::build_dp_bank(&v1, &lay, &in_idx, &[0])
                            .with_context(|| {
                                format!(
                                    "buffer {} delay bank {}: wide failed ({wide_err:#}), DP also failed",
                                    ub.name,
                                    bidx + k
                                )
                            })?;
                        impls[*o] = PortImpl::Mem { bank: banks.len(), out_idx: 0 };
                        banks.push(MemBank {
                            config: super::BankConfig::Dual(dp),
                            in_ports: in_idx.clone(),
                            out_ports: vec![*o],
                            capacity_words: lay.capacity,
                            tiles: chain::tiles_needed(lay.capacity, TILE_CAPACITY_WORDS),
                        });
                    }
                }
            }
        }
    }

    // Addressed-class ports (no constant distance): group by
    // vector-alignment class, then bank within each class.
    let mem_ports: Vec<usize> = (0..ub.outputs.len())
        .filter(|&k| matches!(plan.impls[k], PortImpl::Mem { .. }) && plan.dist[k].is_none())
        .collect();
    let mut classes: BTreeMap<i64, Vec<usize>> = BTreeMap::new();
    for &o in &mem_ports {
        classes.entry(align_class(ub, o, fw as i64)).or_default().push(o);
    }
    for (class, ports) in &classes {
        let groups = banking::assign(ub.inputs.len(), ports, fw)?;
        for group in groups {
            let bidx = banks.len();
            // Bank view: all write ports, this bank's read ports only
            // (storage minimization ignores SR-served reads).
            let mut view = UnifiedBuffer::new(ub.name.clone(), ub.data_box.clone());
            for p in &ub.inputs {
                view.add_input(p.clone());
            }
            for &o in &group {
                view.add_output(ub.outputs[o].clone());
            }
            let in_idx: Vec<usize> = (0..ub.inputs.len()).collect();
            let out_idx: Vec<usize> = (0..group.len()).collect();

            // Try the optimized wide-fetch tile first.
            let layout = linearize::choose_capacity_aligned(&view, 2 * fw as i64, -class)?;
            let wide = vectorize::build_bank(&view, &layout, &in_idx, &out_idx, fw);
            let (config, capacity) = match wide {
                Ok(cfg) => (super::BankConfig::Wide(cfg), layout.capacity),
                Err(wide_err) => {
                    // Fall back to dual-port banks, one read port each.
                    if group.len() > 1 {
                        // Split the group; recurse per port.
                        for &o in &group {
                            let mut v1 = UnifiedBuffer::new(ub.name.clone(), ub.data_box.clone());
                            for p in &ub.inputs {
                                v1.add_input(p.clone());
                            }
                            v1.add_output(ub.outputs[o].clone());
                            let lay = linearize::choose_capacity(&v1, 1)?;
                            let dp = vectorize::build_dp_bank(&v1, &lay, &in_idx, &[0])
                                .with_context(|| {
                                    format!("buffer {}: wide failed ({wide_err:#}), DP also failed", ub.name)
                                })?;
                            impls[o] = PortImpl::Mem { bank: banks.len(), out_idx: 0 };
                            banks.push(MemBank {
                                config: super::BankConfig::Dual(dp),
                                in_ports: in_idx.clone(),
                                out_ports: vec![o],
                                capacity_words: lay.capacity,
                                tiles: chain::tiles_needed(lay.capacity, TILE_CAPACITY_WORDS),
                            });
                        }
                        continue;
                    }
                    let lay = linearize::choose_capacity(&view, 1)?;
                    let dp = vectorize::build_dp_bank(&view, &lay, &in_idx, &out_idx)
                        .with_context(|| {
                            format!("buffer {}: wide failed ({wide_err:#}), DP also failed", ub.name)
                        })?;
                    (super::BankConfig::Dual(dp), lay.capacity)
                }
            };
            for (k, &o) in group.iter().enumerate() {
                impls[o] = PortImpl::Mem { bank: bidx, out_idx: k };
            }
            banks.push(MemBank {
                config,
                in_ports: in_idx,
                out_ports: group,
                capacity_words: capacity,
                tiles: chain::tiles_needed(capacity, TILE_CAPACITY_WORDS),
            });
        }
    }

    Ok(MappedBuffer {
        name: ub.name.clone(),
        banks,
        port_impls: impls,
        sr_words: plan.sr_words,
    })
}

/// Partially-mapped operand during expression mapping.
enum Mapped {
    Const(i32),
    Src(OperandSrc, i64),
}

struct KernelCtx<'a> {
    dims: Vec<String>,
    load_maps: Vec<(String, crate::poly::AffineMap)>,
    self_name: &'a str,
    nodes: Vec<MappedPe>,
}

impl KernelCtx<'_> {
    fn operand(&mut self, m: &Mapped, node_depth: i64, slot: usize, cfg: &mut PeConfig) -> OperandSrc {
        match m {
            Mapped::Const(v) => {
                cfg.consts[slot] = Some(*v);
                OperandSrc::None
            }
            Mapped::Src(src, d) => {
                // Retime shallower operands to arrive with the deepest.
                cfg.delays[slot] = (node_depth - 1 - d) as usize;
                src.clone()
            }
        }
    }

    fn push(&mut self, cfg: PeConfig, srcs: [OperandSrc; 3], depth: i64) -> Mapped {
        self.nodes.push(MappedPe { cfg, srcs, depth });
        Mapped::Src(OperandSrc::Node(self.nodes.len() - 1), depth)
    }

    fn map_expr(&mut self, e: &Expr) -> Result<Mapped> {
        Ok(match e {
            Expr::Const(v) => Mapped::Const(*v),
            Expr::Var(n) => {
                let k = self
                    .dims
                    .iter()
                    .position(|d| d == n)
                    .with_context(|| format!("unknown iterator {n} in kernel"))?;
                Mapped::Src(OperandSrc::Iter(k), 0)
            }
            Expr::Load(buf, idx) => {
                if buf == self.self_name {
                    bail!("accumulator reference outside reduction root");
                }
                let map = Expr::load_affine_map(idx, &self.dims)
                    .context("non-affine load in kernel")?;
                let k = self
                    .load_maps
                    .iter()
                    .position(|(b, m)| b == buf && *m == map)
                    .with_context(|| format!("load of {buf} not among kernel ports"))?;
                Mapped::Src(OperandSrc::Load(k), 0)
            }
            Expr::Binary(op, a, b) => {
                let (ma, mb) = (self.map_expr(a)?, self.map_expr(b)?);
                if let (Mapped::Const(x), Mapped::Const(y)) = (&ma, &mb) {
                    return Ok(Mapped::Const(eval_binop(*op, *x, *y)));
                }
                let depth = 1 + depth_of(&ma).max(depth_of(&mb));
                let mut cfg = PeConfig::bin(*op);
                let s0 = self.operand(&ma, depth, 0, &mut cfg);
                let s1 = self.operand(&mb, depth, 1, &mut cfg);
                self.push(cfg, [s0, s1, OperandSrc::None], depth)
            }
            Expr::Unary(op, a) => {
                let ma = self.map_expr(a)?;
                let depth = 1 + depth_of(&ma);
                let mut cfg = PeConfig { op: PeOp::Un(*op), consts: [None; 3], delays: [0; 3] };
                let s0 = self.operand(&ma, depth, 0, &mut cfg);
                self.push(cfg, [s0, OperandSrc::None, OperandSrc::None], depth)
            }
            Expr::Select(c, t, f) => {
                let (mc, mt, mf) = (self.map_expr(c)?, self.map_expr(t)?, self.map_expr(f)?);
                let depth = 1 + depth_of(&mc).max(depth_of(&mt)).max(depth_of(&mf));
                let mut cfg = PeConfig { op: PeOp::Select, consts: [None; 3], delays: [0; 3] };
                let s0 = self.operand(&mc, depth, 0, &mut cfg);
                let s1 = self.operand(&mt, depth, 1, &mut cfg);
                let s2 = self.operand(&mf, depth, 2, &mut cfg);
                self.push(cfg, [s0, s1, s2], depth)
            }
        })
    }
}

fn depth_of(m: &Mapped) -> i64 {
    match m {
        Mapped::Const(_) => 0,
        Mapped::Src(_, d) => *d,
    }
}

fn is_self_load(e: &Expr, name: &str) -> bool {
    matches!(e, Expr::Load(b, _) if b == name)
}

/// Map one kernel node's expression tree onto PEs.
fn map_kernel(kn: &KernelNode, graph: &UbGraph) -> Result<MappedKernel> {
    let dims: Vec<String> = kn.domain.dims.iter().map(|d| d.name.clone()).collect();
    let load_maps: Vec<(String, crate::poly::AffineMap)> = kn
        .loads
        .iter()
        .map(|(b, p)| (b.clone(), graph.buffers[b].outputs[*p].access.clone()))
        .collect();
    let mut ctx = KernelCtx { dims, load_maps, self_name: &kn.stage, nodes: Vec::new() };

    let acc_period = if kn.is_reduction {
        let pure = &graph.buffers[&kn.store.0].inputs[kn.store.1].domain;
        kn.domain.cardinality() / pure.cardinality()
    } else {
        1
    };

    let root = if kn.is_reduction {
        // The update must be `op(self, term)` (update statements were
        // combined in the frontend, §V-A).
        let Expr::Binary(op, a, b) = &kn.kernel else {
            bail!("reduction kernel {} is not op(self, term)", kn.stage)
        };
        let term = if is_self_load(a, &kn.stage) {
            b
        } else if is_self_load(b, &kn.stage) {
            a
        } else {
            bail!("reduction kernel {} lacks accumulator reference", kn.stage)
        };
        let mt = ctx.map_expr(term)?;
        let depth = 1 + depth_of(&mt);
        let mut cfg =
            PeConfig { op: PeOp::Acc { op: *op, init: 0, period: acc_period }, consts: [None; 3], delays: [0; 3] };
        let s0 = ctx.operand(&mt, depth, 0, &mut cfg);
        ctx.push(cfg, [s0, OperandSrc::None, OperandSrc::None], depth)
    } else {
        let m = ctx.map_expr(&kn.kernel)?;
        match m {
            // A bare load/const/iterator kernel becomes a pass-through
            // add-zero PE (latency 1, matching the scheduler's floor).
            Mapped::Const(v) => {
                let cfg = PeConfig::bin(BinOp::Add).with_const(0, v).with_const(1, 0);
                ctx.push(cfg, [OperandSrc::None, OperandSrc::None, OperandSrc::None], 1)
            }
            Mapped::Src(src, 0) => {
                let cfg = PeConfig::bin(BinOp::Add).with_const(1, 0);
                ctx.push(cfg, [src, OperandSrc::None, OperandSrc::None], 1)
            }
            m => m,
        }
    };

    let depth = depth_of(&root);
    anyhow::ensure!(
        depth == kn.latency,
        "kernel {}: mapped depth {depth} != scheduled latency {}",
        kn.stage,
        kn.latency
    );

    Ok(MappedKernel {
        stage: kn.stage.clone(),
        lane: kn.lane,
        nodes: ctx.nodes,
        loads: kn.loads.clone(),
        store: kn.store.clone(),
        domain: kn.domain.clone(),
        schedule: kn.schedule.clone(),
        latency: kn.latency,
        acc_period,
    })
}

/// Map a whole application graph.
pub fn map_design(graph: &UbGraph) -> Result<MappedDesign> {
    let mut buffers = BTreeMap::new();
    for (name, ub) in &graph.buffers {
        buffers.insert(
            name.clone(),
            map_buffer(ub, FETCH_WIDTH).with_context(|| format!("mapping buffer {name}"))?,
        );
    }
    let kernels: Result<Vec<MappedKernel>> =
        graph.kernels.iter().map(|k| map_kernel(k, graph)).collect();
    Ok(MappedDesign {
        name: graph.name.clone(),
        buffers,
        kernels: kernels?,
        completion: graph.completion,
        coarse_ii: graph.coarse_ii,
        fetch_width: FETCH_WIDTH,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::extraction::extract;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::sched;

    fn brighten_blur(tile: i64) -> UbGraph {
        let brighten = Func::pure_fn(
            "brighten",
            &["y", "x"],
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
        );
        let blur = Func::pure_fn(
            "blur",
            &["y", "x"],
            Expr::shr(
                Expr::sum(vec![
                    Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                    Expr::ld(
                        "brighten",
                        vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(1)),
                            Expr::add(Expr::v("x"), Expr::c(1)),
                        ],
                    ),
                ]),
                2,
            ),
        );
        let p = Program {
            name: "bb".into(),
            inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
            funcs: vec![brighten, blur],
            schedule: HwSchedule::new([tile, tile]).store_at("brighten"),
        };
        let lp = lower(&p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        extract(&lp, &ps).unwrap()
    }

    #[test]
    fn brighten_blur_maps_like_fig8() {
        let g = brighten_blur(63);
        let d = map_design(&g).unwrap();
        // Input buffer: pointwise reads at constant distance -> pure SR,
        // no memory tile (the paper's "input buffer is eliminated").
        assert_eq!(d.buffers["input"].banks.len(), 0);
        assert!(d.buffers["input"].sr_words > 0);
        // Brighten: 2x2 stencil -> some SR taps + one memory bank.
        let b = &d.buffers["brighten"];
        assert_eq!(b.banks.len(), 1);
        let n_sr = b
            .port_impls
            .iter()
            .filter(|i| matches!(i, PortImpl::Shift { .. }))
            .count();
        assert_eq!(n_sr, 3, "three of four stencil ports are SR taps");
        // Capacity is about one row (storage minimization), not 65x65.
        let cap = b.banks[0].capacity_words;
        assert!((64..=96).contains(&cap), "capacity {cap}");
        // Output buffer: drain at distance 1 -> SR only.
        assert_eq!(d.buffers["blur"].banks.len(), 0);
        // One MEM tile total; kernel PEs: brighten 1 op, blur 4 ops.
        assert_eq!(d.mem_tiles(), 1);
        assert_eq!(d.pe_count(), 1 + 4);
    }

    #[test]
    fn kernel_mapping_structure() {
        let g = brighten_blur(31);
        let d = map_design(&g).unwrap();
        let blur = d.kernels.iter().find(|k| k.stage == "blur").unwrap();
        // 3 adds + 1 shr = 4 nodes; depth = scheduled latency.
        assert_eq!(blur.nodes.len(), 4);
        assert_eq!(blur.nodes.last().unwrap().depth, blur.latency);
        // Root consumes the add tree and a constant shift amount.
        let root = blur.nodes.last().unwrap();
        assert!(matches!(root.cfg.op, PeOp::Bin(BinOp::Shr)));
        // Brighten kernel: one mul with constant 2.
        let br = d.kernels.iter().find(|k| k.stage == "brighten").unwrap();
        assert_eq!(br.nodes.len(), 1);
        assert_eq!(br.acc_period, 1);
    }

    #[test]
    fn reduction_kernel_gets_accumulator() {
        let conv = Func::reduce_fn(
            "conv",
            &["y", "x"],
            Expr::c(0),
            &[("ry", 0, 3), ("rx", 0, 3)],
            Expr::add(
                Expr::ld("conv", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld(
                    "in",
                    vec![
                        Expr::add(Expr::v("y"), Expr::v("ry")),
                        Expr::add(Expr::v("x"), Expr::v("rx")),
                    ],
                ),
            ),
        );
        let p = Program {
            name: "boxf".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![conv],
            schedule: HwSchedule::new([6, 6]),
        };
        let lp = lower(&p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        let g = extract(&lp, &ps).unwrap();
        let d = map_design(&g).unwrap();
        let k = &d.kernels[0];
        assert_eq!(k.acc_period, 9);
        assert!(matches!(
            k.nodes.last().unwrap().cfg.op,
            PeOp::Acc { period: 9, .. }
        ));
    }
}
