//! Unified buffer mapping (§V-C): abstract unified buffers → physical
//! unified buffer configurations.
//!
//! The pipeline per buffer (Fig 8):
//!
//! 1. **Shift-register introduction** ([`shiftreg`]) — output ports at a
//!    constant cycle distance from a source are peeled off into register
//!    chains (short gaps) or chained off a memory-served port (Fig 8a).
//! 2. **Banking** ([`banking`]) — remaining memory ports are packed into
//!    banks of at most `fetch_width` total ports (the single-port SRAM's
//!    steady-state bandwidth); reads beyond that duplicate the write
//!    stream into additional banks (read-duplication, a simplified [7]).
//! 3. **Address linearization** ([`linearize`]) — N-d coordinates →
//!    1-d addresses via an offset-vector inner product, wrapped mod a
//!    circular capacity found by collision-checked search (Eq 4).
//! 4. **Vectorization** ([`vectorize`]) — strip-mine port schedules by
//!    the SRAM fetch width into AGG/SRAM/TB controller configurations
//!    (Eq 2/3, Fig 9), fitting exact event lists to affine AG/SG
//!    hardware and resolving single-port access conflicts.
//! 5. **Chaining** ([`chain`]) — capacities beyond one memory tile span
//!    several chained tiles (Eq 5/6, Fig 10).
//!
//! Compute kernels are mapped to PE configurations (one ALU op per PE,
//! operand retiming delays, accumulate mode for reduction loops) by
//! [`mapper`], which also orchestrates the buffer pipeline and emits the
//! final [`MappedDesign`].

pub mod banking;
pub mod chain;
pub mod linearize;
pub mod mapper;
pub mod shiftreg;
pub mod vectorize;

use std::collections::BTreeMap;

use crate::hw::{MemTileConfig, PeConfig};
use crate::poly::{BoxSet, CycleSchedule};

/// Default physical parameters of a memory tile (§VI: 512x64-bit
/// single-port SRAM macro = 2048 16-bit words, fetch width 4).
pub const FETCH_WIDTH: usize = 4;
pub const TILE_CAPACITY_WORDS: usize = 2048;
/// Constant-distance gaps up to this many cycles are implemented as
/// shift registers; larger gaps go through a memory (Fig 8a).
pub const SR_MAX_GAP: i64 = 16;

/// Where a shift-register tap draws its data from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SrSource {
    /// A buffer input port (the write stream).
    Input(usize),
    /// Another output port of the same buffer (chaining off a
    /// memory-served tap, Fig 8a).
    Output(usize),
}

/// How one UB output port is implemented.
#[derive(Clone, Debug, PartialEq)]
pub enum PortImpl {
    /// Register chain: `depth` cycles behind `src`.
    Shift { src: SrSource, depth: i64 },
    /// Served by memory bank `bank`, TB output `out_idx`.
    Mem { bank: usize, out_idx: usize },
}

/// The hardware flavor of a bank: the optimized wide-fetch single-port
/// tile (§IV-B), or the dual-port fallback (Fig 3) for access patterns
/// the vectorizer cannot serve.
#[derive(Clone, Debug)]
pub enum BankConfig {
    Wide(MemTileConfig),
    Dual(crate::hw::DpTileConfig),
}

/// One configured physical-unified-buffer bank.
#[derive(Clone, Debug)]
pub struct MemBank {
    pub config: BankConfig,
    /// UB input port indices, in serial-in order.
    pub in_ports: Vec<usize>,
    /// UB output port indices, in output order.
    pub out_ports: Vec<usize>,
    /// Logical circular capacity in words.
    pub capacity_words: i64,
    /// Physical memory tiles after chaining.
    pub tiles: usize,
}

impl MemBank {
    pub fn is_dual_port(&self) -> bool {
        matches!(self.config, BankConfig::Dual(_))
    }
}

/// A fully mapped unified buffer.
#[derive(Clone, Debug)]
pub struct MappedBuffer {
    pub name: String,
    pub banks: Vec<MemBank>,
    /// Implementation of each UB output port (same indexing).
    pub port_impls: Vec<PortImpl>,
    /// Total shift-register words.
    pub sr_words: i64,
}

impl MappedBuffer {
    pub fn mem_tiles(&self) -> usize {
        self.banks.iter().map(|b| b.tiles).sum()
    }
}

/// Where a PE operand comes from.
#[derive(Clone, Debug, PartialEq)]
pub enum OperandSrc {
    /// Kernel load index (a buffer output port / SR tap).
    Load(usize),
    /// Another PE node of this kernel.
    Node(usize),
    /// The value of iteration dim `k` at issue time (a counter PE).
    Iter(usize),
    /// Constant folded into the PE config.
    None,
}

/// One mapped PE.
#[derive(Clone, Debug)]
pub struct MappedPe {
    pub cfg: PeConfig,
    pub srcs: [OperandSrc; 3],
    /// Result available this many cycles after kernel issue.
    pub depth: i64,
}

/// A compute kernel mapped onto PEs.
#[derive(Clone, Debug)]
pub struct MappedKernel {
    pub stage: String,
    pub lane: usize,
    /// Topological order; the last node is the root (stored value).
    pub nodes: Vec<MappedPe>,
    pub loads: Vec<(String, usize)>,
    pub store: (String, usize),
    pub domain: BoxSet,
    pub schedule: CycleSchedule,
    pub latency: i64,
    /// Reduction accumulator period (1 for pure kernels).
    pub acc_period: i64,
}

impl MappedKernel {
    pub fn pe_count(&self) -> usize {
        self.nodes.len()
    }
}

/// The complete mapped design: the compiler's final output before place
/// and route.
#[derive(Clone, Debug)]
pub struct MappedDesign {
    pub name: String,
    pub buffers: BTreeMap<String, MappedBuffer>,
    pub kernels: Vec<MappedKernel>,
    pub completion: i64,
    pub coarse_ii: i64,
    pub fetch_width: usize,
}

impl MappedDesign {
    /// MEM tile count (Table IV/V column).
    pub fn mem_tiles(&self) -> usize {
        self.buffers.values().map(|b| b.mem_tiles()).sum()
    }

    /// PE count (Table IV/V column).
    pub fn pe_count(&self) -> usize {
        self.kernels.iter().map(|k| k.pe_count()).sum()
    }

    /// Total SRAM words actually allocated (Table VII column).
    pub fn sram_words(&self) -> i64 {
        self.buffers
            .values()
            .flat_map(|b| b.banks.iter().map(|bk| bk.capacity_words))
            .sum()
    }

    /// Total shift-register words.
    pub fn sr_words(&self) -> i64 {
        self.buffers.values().map(|b| b.sr_words).sum()
    }
}

/// Re-exported entry point.
pub use mapper::map_design;
