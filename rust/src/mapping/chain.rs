//! Chaining (§V-C "Chaining", Eq 5/6, Fig 10).
//!
//! A logical buffer whose circular capacity exceeds one memory tile is
//! spread across several chained tiles: logical address `a` lives in
//! tile `floor(a / C)` at physical address `a mod C` (C = per-tile
//! capacity). The behavioral model treats the chain as one larger
//! single-port memory (each tile's mux forwards non-matching accesses,
//! Fig 10), so only the tile *count* and the address split are modeled.

/// Number of physical tiles needed for `capacity_words`.
pub fn tiles_needed(capacity_words: i64, tile_capacity: usize) -> usize {
    let t = tile_capacity as i64;
    (((capacity_words + t - 1) / t).max(1)) as usize
}

/// Eq 5: which tile a logical address lives in.
pub fn tile_id(addr: i64, tile_capacity: usize) -> i64 {
    addr.div_euclid(tile_capacity as i64)
}

/// Eq 6: the physical address within that tile.
pub fn physical_addr(addr: i64, tile_capacity: usize) -> i64 {
    addr.rem_euclid(tile_capacity as i64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example() {
        // §V-C: a 32-word macro, 64-word delay buffer -> 2 tiles;
        // TileID(x) = floor(x/32), phys = x mod 32.
        assert_eq!(tiles_needed(64, 32), 2);
        assert_eq!(tile_id(0, 32), 0);
        assert_eq!(tile_id(33, 32), 1);
        assert_eq!(physical_addr(33, 32), 1);
    }

    #[test]
    fn single_tile_cases() {
        assert_eq!(tiles_needed(1, 2048), 1);
        assert_eq!(tiles_needed(2048, 2048), 1);
        assert_eq!(tiles_needed(2049, 2048), 2);
        assert_eq!(tiles_needed(0, 2048), 1);
    }
}
