//! Address linearization (§V-C "Address Linearization", Eq 4).
//!
//! N-dimensional buffer coordinates are flattened with an offset-vector
//! inner product (row-major strides over the realization box), then
//! wrapped into a circular buffer of capacity `C`: the paper's
//! `{1,64} mod 64 = {1,0}` example is the special case where the mod
//! folds into the offset vector. `C` is the smallest fetch-width
//! multiple ≥ the live-value bound that produces no lifetime collisions,
//! verified exactly against the port event lists.

use anyhow::{bail, Result};
use std::collections::HashMap;

use crate::poly::Affine;
use crate::ub::UnifiedBuffer;

/// A linear, circular memory layout.
#[derive(Clone, Debug)]
pub struct Layout {
    /// Row-major flattening over the data box (absolute coords).
    pub linear: Affine,
    /// Circular capacity in words (`None` while being searched).
    pub capacity: i64,
}

impl Layout {
    /// Flat (pre-wrap) address of a coordinate.
    pub fn flat(&self, coords: &[i64]) -> i64 {
        self.linear.eval(coords)
    }

    /// Physical circular address.
    pub fn address(&self, coords: &[i64]) -> i64 {
        self.flat(coords).rem_euclid(self.capacity)
    }
}

/// Row-major flattening of the buffer's data box.
pub fn row_major_linear(ub: &UnifiedBuffer) -> Affine {
    padded_linear(ub, 1)
}

/// Row-major flattening with the innermost *pitch* rounded up to
/// `row_pad` (the fetch width): rows then start on generation
/// boundaries, so the vectorized flush/read schedules stay affine even
/// when the image width is not a fetch-width multiple. The padded slots
/// are never written or read.
pub fn padded_linear(ub: &UnifiedBuffer, row_pad: i64) -> Affine {
    let dims = &ub.data_box.dims;
    let rank = dims.len();
    let mut coeffs = vec![0i64; rank];
    let mut stride = 1i64;
    for k in (0..rank).rev() {
        coeffs[k] = stride;
        let mut e = dims[k].extent;
        if k == rank - 1 {
            e = (e + row_pad - 1) / row_pad * row_pad;
        }
        stride *= e;
    }
    // Shift so the box minimum maps to flat address 0.
    let mins: Vec<i64> = dims.iter().map(|d| d.min).collect();
    let a = Affine::new(coeffs, 0);
    let off = -a.eval(&mins);
    a.shift(off)
}

/// Find the smallest circular capacity (a `fetch_width` multiple, at
/// least `min_live`) with no lifetime collisions: two values whose flat
/// addresses alias mod `C` must have disjoint live ranges, with the
/// later write landing strictly after the earlier value's last read.
pub fn choose_capacity(ub: &UnifiedBuffer, fetch_width: i64) -> Result<Layout> {
    choose_capacity_aligned(ub, fetch_width, 0)
}

/// [`choose_capacity`] with the flat addresses shifted by `shift`
/// (used by the mapper to vector-align a bank to its primary read
/// port's constant access offset, so stencil taps like `x+1` land on
/// generation boundaries) and the row pitch padded to `row_pad`.
pub fn choose_capacity_aligned(
    ub: &UnifiedBuffer,
    fetch_width: i64,
    shift: i64,
) -> Result<Layout> {
    choose_capacity_padded(ub, fetch_width, shift, fetch_width.max(1) / 2)
}

/// Fully-parameterized capacity search: `quantum` is the capacity
/// rounding (2x fetch width for ping-pong TBs), `row_pad` the pitch
/// alignment (the fetch width; 1 for word-granular dual-port banks).
pub fn choose_capacity_padded(
    ub: &UnifiedBuffer,
    quantum: i64,
    shift: i64,
    row_pad: i64,
) -> Result<Layout> {
    let linear = padded_linear(ub, row_pad.max(1)).shift(shift);
    let fetch_width = quantum;
    let min_live = ub.max_live()?.max(1);
    // Full (non-circular) padded size: the largest flat address + 1.
    let maxs: Vec<i64> = ub.data_box.dims.iter().map(|d| d.max()).collect();
    let full = linear.eval(&maxs) + 1 - shift.min(0);

    // Write time and last-read time per flat address.
    let mut writes: Vec<(i64, i64)> = Vec::new(); // (flat, write cycle)
    for p in &ub.inputs {
        for (t, coords) in p.events() {
            writes.push((linear.eval(&coords), t));
        }
    }
    let mut last_read: HashMap<i64, i64> = HashMap::new();
    for p in &ub.outputs {
        for (t, coords) in p.events() {
            let e = last_read.entry(linear.eval(&coords)).or_insert(t);
            *e = (*e).max(t);
        }
    }

    let round = |v: i64| (v + fetch_width - 1) / fetch_width * fetch_width;
    let mut cap = round(min_live);
    'outer: while cap < full {
        // Check collisions: group by flat mod cap; within each group,
        // sorted by write time, each value must die (last read) before
        // the next aliasing write lands.
        let mut groups: HashMap<i64, Vec<(i64, i64)>> = HashMap::new();
        for &(flat, w) in &writes {
            groups.entry(flat.rem_euclid(cap)).or_default().push((w, flat));
        }
        for g in groups.values_mut() {
            g.sort();
            for w in g.windows(2) {
                let (_, flat_a) = w[0];
                let (wb, _) = w[1];
                if let Some(&r) = last_read.get(&flat_a) {
                    if wb <= r {
                        cap = round(cap + fetch_width);
                        continue 'outer;
                    }
                }
            }
        }
        return Ok(Layout { linear, capacity: cap });
    }
    // Fall back to the full (non-circular) box.
    let cap = round(full.max(1));
    if cap >= full {
        return Ok(Layout { linear, capacity: cap });
    }
    bail!("no collision-free circular capacity for buffer {}", ub.name);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::poly::{AffineMap, BoxSet, CycleSchedule};
    use crate::ub::{Port, PortDir};

    /// Line-buffer-like UB: writes row-major 8x8, one read port delayed
    /// by one row + one pixel (distance 9).
    fn line_buffer(delay: i64) -> UnifiedBuffer {
        let mut ub = UnifiedBuffer::new("lb", BoxSet::from_extents(&[8, 8]));
        ub.add_input(Port::new(
            "w",
            PortDir::In,
            BoxSet::from_extents(&[8, 8]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[8, 8], 1, 0),
        ));
        ub.add_output(Port::new(
            "r",
            PortDir::Out,
            BoxSet::from_extents(&[8, 8]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[8, 8], 1, delay),
        ));
        ub
    }

    #[test]
    fn row_major_flattening() {
        let ub = line_buffer(9);
        let lin = row_major_linear(&ub);
        assert_eq!(lin.eval(&[0, 0]), 0);
        assert_eq!(lin.eval(&[0, 7]), 7);
        assert_eq!(lin.eval(&[1, 0]), 8);
        assert_eq!(lin.eval(&[7, 7]), 63);
    }

    #[test]
    fn capacity_is_live_window_not_full_box() {
        // Delay 9 => ~10 live values => capacity 12 (FW multiple), far
        // below the 64-word box (the paper's storage minimization).
        let ub = line_buffer(9);
        let layout = choose_capacity(&ub, 4).unwrap();
        assert!(layout.capacity >= 10, "capacity {}", layout.capacity);
        assert!(layout.capacity <= 16, "capacity {}", layout.capacity);
        assert_eq!(layout.capacity % 4, 0);
    }

    #[test]
    fn sequential_reads_need_full_box() {
        // Read starts only after all writes: everything live at once.
        let ub = line_buffer(64);
        let layout = choose_capacity(&ub, 4).unwrap();
        assert_eq!(layout.capacity, 64);
    }

    #[test]
    fn addresses_wrap() {
        let ub = line_buffer(9);
        let layout = choose_capacity(&ub, 4).unwrap();
        let c = layout.capacity;
        assert_eq!(layout.address(&[0, 0]), 0);
        // Row 2 wraps around the circular buffer.
        assert_eq!(layout.address(&[2, 0]), 16 % c);
        assert!(layout.address(&[7, 7]) < c);
    }

    #[test]
    fn collision_search_increases_capacity() {
        // Two read ports, the second much later: live window is larger.
        let mut ub = line_buffer(9);
        ub.add_output(Port::new(
            "r2",
            PortDir::Out,
            BoxSet::from_extents(&[8, 8]),
            AffineMap::identity(2),
            CycleSchedule::row_major(&[8, 8], 1, 25),
        ));
        let layout = choose_capacity(&ub, 4).unwrap();
        assert!(layout.capacity >= 26, "capacity {}", layout.capacity);
    }
}
