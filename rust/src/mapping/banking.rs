//! Banking (§V-C "Shift Register Optimization and Banking").
//!
//! A single wide-fetch single-port SRAM sustains `fetch_width` memory
//! operations per cycle in steady state (each serial port consumes one
//! SRAM access per `fetch_width` cycles). Memory-served ports beyond
//! that budget are split across banks; every bank receives a copy of
//! the full write stream (read duplication — the simplified version of
//! the optimal stencil banking of [7], always legal because the write
//! bandwidth is already provisioned).

use anyhow::{ensure, Result};

/// Assign memory-served output ports to banks. Returns one `Vec` of
/// output-port indices per bank.
pub fn assign(
    n_inputs: usize,
    mem_out_ports: &[usize],
    fetch_width: usize,
) -> Result<Vec<Vec<usize>>> {
    ensure!(
        n_inputs < fetch_width,
        "write ports ({n_inputs}) saturate the SRAM bandwidth ({fetch_width})"
    );
    if mem_out_ports.is_empty() {
        return Ok(vec![]);
    }
    let per_bank = fetch_width - n_inputs;
    Ok(mem_out_ports
        .chunks(per_bank)
        .map(|c| c.to_vec())
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_in_one_bank() {
        let banks = assign(1, &[0, 2], 4).unwrap();
        assert_eq!(banks, vec![vec![0, 2]]);
    }

    #[test]
    fn splits_when_over_budget() {
        // 1 write + 5 reads at FW=4: 3 reads per bank -> 2 banks.
        let banks = assign(1, &[0, 1, 2, 3, 4], 4).unwrap();
        assert_eq!(banks.len(), 2);
        assert_eq!(banks[0], vec![0, 1, 2]);
        assert_eq!(banks[1], vec![3, 4]);
    }

    #[test]
    fn no_mem_ports_no_banks() {
        assert!(assign(1, &[], 4).unwrap().is_empty());
    }

    #[test]
    fn saturated_writes_rejected() {
        assert!(assign(4, &[0], 4).is_err());
    }
}
