//! The exact dependence engine shared by all scheduling policies.
//!
//! Given zero-delay issue schedules per stage, compute the minimal
//! per-stage delays such that every load reads a value that is already
//! available, by longest-path over the stage DAG with exact (enumerated)
//! edge weights. Domains here are accelerator tiles (≤ a few thousand
//! points), so enumeration is both exact and cheap.

use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Context, Result};

use super::InputArrival;
use crate::halide::LoweredPipeline;
use crate::poly::CycleSchedule;

/// Availability map: buffer coordinates -> cycle the value becomes
/// available (reads must happen strictly later).
type Avail = HashMap<Vec<i64>, i64>;

/// Minimum cycles between a value landing in a buffer and a dependent
/// read. The physical unified buffer's AGG → single-port SRAM → TB path
/// takes 4 cycles end to end (serial write, flush, wide read, landing);
/// scheduling every load with this margin lets the mapper freely choose
/// between shift registers (which only need 1) and the memory path
/// without feeding back into the schedule.
pub const MEM_READ_MARGIN: i64 = 4;

pub struct SolveResult {
    /// Delay added to each stage's zero-delay schedule (same order as
    /// `lp.stages`).
    pub delays: Vec<i64>,
    /// Cycle after the last output value is readable (tile completion,
    /// including one cycle to drain the final value).
    pub completion: i64,
    /// Per-stage busy span `(first issue, last result)` with delays
    /// applied.
    pub spans: Vec<(i64, i64)>,
}

/// Solve stage delays.
///
/// * `t0`       — zero-delay issue schedule per stage over its full domain.
/// * `latency`  — kernel pipeline latency per stage.
/// * `arrivals` — external input streams (values available at their
///   schedule cycle).
/// * `barrier`  — sequential semantics: every stage additionally waits
///   for all previous stages to finish (Tables VI/VII baseline).
pub fn solve(
    lp: &LoweredPipeline,
    t0: &[CycleSchedule],
    latency: &[i64],
    arrivals: &BTreeMap<String, InputArrival>,
    barrier: bool,
) -> Result<SolveResult> {
    assert_eq!(t0.len(), lp.stages.len());
    assert_eq!(latency.len(), lp.stages.len());

    let mut avail: HashMap<String, Avail> = HashMap::new();
    for (name, arr) in arrivals {
        let map = avail.entry(name.clone()).or_default();
        for p in arr.domain.points() {
            let t = arr.schedule.cycle(&p);
            for lane in &arr.lane_maps {
                let coords = lane.apply(&p);
                if lp.buffers[name].contains(&coords) {
                    map.insert(coords, t);
                }
            }
        }
    }

    let mut delays = Vec::with_capacity(lp.stages.len());
    let mut spans: Vec<(i64, i64)> = Vec::new();
    let mut prev_end = i64::MIN;

    for (si, stage) in lp.stages.iter().enumerate() {
        let full = stage.full_domain();
        if !t0[si].is_injective_on(&full) {
            bail!("stage {}: schedule issues >1 op/cycle", stage.name);
        }
        // Dependence constraints: delay >= avail(load(q)) + 1 - t0(q).
        let mut delay = 0i64;
        for inst in &stage.instances {
            for (buf, map) in &inst.loads {
                let a = avail
                    .get(buf)
                    .with_context(|| format!("stage {} reads unwritten buffer {buf}", stage.name))?;
                for q in full.points() {
                    let coords = map.apply(&q);
                    let av = *a.get(&coords).with_context(|| {
                        format!(
                            "stage {} reads {buf}{coords:?}, never written",
                            stage.name
                        )
                    })?;
                    delay = delay.max(av + MEM_READ_MARGIN - t0[si].cycle(&q));
                }
            }
        }
        if barrier && prev_end > i64::MIN {
            // Sequential: also wait for everything before us to finish.
            let (first, _) = t0[si].span(&full);
            delay = delay.max(prev_end + 1 - first);
        }

        // Register this stage's writes. A reduction stage's value lands
        // when its *last* reduction iteration retires.
        let wmap = avail.entry(stage.name.clone()).or_default();
        let rdom_last: Vec<i64> = stage
            .rdom
            .dims
            .iter()
            .map(|d| d.min + d.extent - 1)
            .collect();
        for p in stage.pure_domain.points() {
            let fp: Vec<i64> = p.iter().cloned().chain(rdom_last.iter().cloned()).collect();
            let t = t0[si].cycle(&fp) + delay + latency[si];
            for inst in &stage.instances {
                let coords = inst.store.apply(&fp);
                if let Some(prev) = wmap.insert(coords.clone(), t) {
                    bail!(
                        "stage {}: coordinate {coords:?} written twice ({prev}, {t})",
                        stage.name
                    );
                }
            }
        }

        let (first, last) = t0[si].span(&full);
        let span = (first + delay, last + delay + latency[si]);
        prev_end = prev_end.max(span.1);
        spans.push(span);
        delays.push(delay);
    }

    // Completion: the output buffer's last value readable, +1 to drain.
    let out_end = spans.last().map(|s| s.1).unwrap_or(0);
    Ok(SolveResult { delays, completion: out_end + 2, spans })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::Expr;
    use crate::poly::AffineMap;

    fn arrivals_for(
        lp: &LoweredPipeline,
        ii: i64,
    ) -> BTreeMap<String, InputArrival> {
        lp.inputs
            .iter()
            .map(|name| {
                let b = lp.buffers[name].clone();
                let extents: Vec<i64> = b.dims.iter().map(|d| d.extent).collect();
                let sched = CycleSchedule::row_major(&extents, ii, 0)
                    .delayed(-offset_of(&b, ii));
                (
                    name.clone(),
                    InputArrival {
                        domain: b.clone(),
                        lane_maps: vec![AffineMap::identity(b.rank())],
                        schedule: sched,
                    },
                )
            })
            .collect()
    }

    /// Row-major cycle of a box's lexicographic first point.
    fn offset_of(b: &crate::poly::BoxSet, ii: i64) -> i64 {
        let extents: Vec<i64> = b.dims.iter().map(|d| d.extent).collect();
        let mins: Vec<i64> = b.dims.iter().map(|d| d.min).collect();
        CycleSchedule::row_major(&extents, ii, 0).cycle(&mins)
    }

    fn two_stage() -> LoweredPipeline {
        let a = Func::pure_fn(
            "a",
            &["y", "x"],
            Expr::add(Expr::ld("in", vec![Expr::v("y"), Expr::v("x")]), Expr::c(1)),
        );
        let b = Func::pure_fn(
            "b",
            &["y", "x"],
            Expr::add(
                Expr::ld("a", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld("a", vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")]),
            ),
        );
        let p = Program {
            name: "p".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![a, b],
            schedule: HwSchedule::new([8, 8]).store_at("a"),
        };
        lower(&p).unwrap()
    }

    #[test]
    fn pipelined_delays_are_line_sized() {
        let lp = two_stage();
        // Both stages share a common 9-wide virtual row (stage a is 9x8).
        let t0: Vec<CycleSchedule> = lp
            .stages
            .iter()
            .map(|s| {
                let mins: Vec<i64> = s.pure_domain.dims.iter().map(|d| d.min).collect();
                CycleSchedule::row_major(&[9, 9], 1, 0)
                    .delayed(-CycleSchedule::row_major(&[9, 9], 1, 0).cycle(&mins))
            })
            .collect();
        let arr = arrivals_for(&lp, 1);
        // Input arrives 9-wide row-major too (its box is 9x8).
        let res = solve(&lp, &t0, &[1, 1], &arr, false).unwrap();
        // Stage b needs a(y+1, x): about one virtual row of delay.
        assert!(res.delays[1] >= 9, "delay {} too small", res.delays[1]);
        assert!(res.delays[1] <= 20, "delay {} not line-sized", res.delays[1]);
        // Pipelined completion is ~one pass over the tile, not two.
        assert!(res.completion < 9 * 9 + 30, "completion {}", res.completion);
    }

    #[test]
    fn barrier_forces_sequential() {
        let lp = two_stage();
        let t0: Vec<CycleSchedule> = lp
            .stages
            .iter()
            .map(|s| {
                let ext: Vec<i64> =
                    s.pure_domain.dims.iter().map(|d| d.extent).collect();
                CycleSchedule::row_major(&ext, 1, 0)
            })
            .collect();
        let arr = arrivals_for(&lp, 1);
        let seq = solve(&lp, &t0, &[1, 1], &arr, true).unwrap();
        let pipe = solve(&lp, &t0, &[1, 1], &arr, false).unwrap();
        assert!(seq.completion > pipe.completion);
        // Barrier start of stage 1 is after stage 0's last result.
        assert!(seq.spans[1].0 > seq.spans[0].1);
    }

    #[test]
    fn missing_producer_is_error() {
        let lp = two_stage();
        let t0: Vec<CycleSchedule> = lp
            .stages
            .iter()
            .map(|s| {
                let ext: Vec<i64> =
                    s.pure_domain.dims.iter().map(|d| d.extent).collect();
                CycleSchedule::row_major(&ext, 1, 0)
            })
            .collect();
        // No arrivals: stage a's input is never written.
        let res = solve(&lp, &t0, &[1, 1], &BTreeMap::new(), false);
        assert!(res.is_err());
    }
}
