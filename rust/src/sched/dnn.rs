//! DNN-pipeline scheduling (§V-B "DNN Pipeline").
//!
//! Used when some reduction loop is not fully unrolled. Each pipeline
//! stage is internally pipelined at II = 1 over its own loop nest (the
//! standard HLS loop schedule of [40]); stages are laid out with the
//! minimal start offsets that respect data dependencies (exact, via the
//! shared dependence engine — producer/consumer orders that cannot be
//! aligned, like resnet's channel-major reuse, naturally degrade to
//! buffer-everything offsets). Successive *tiles* are overlapped by
//! double buffering: the coarse-grained initiation interval is found by
//! binary search, converging on the busy span of the largest stage —
//! 100% utilization of the dominant compute unit.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use super::core;
use super::{InputArrival, PipelineKind, PipelineSchedule, StageSchedule};
use crate::halide::LoweredPipeline;
use crate::poly::{AffineMap, CycleSchedule};

/// Row-major zero-delay schedule over a stage's own full domain, first
/// point at cycle 0.
fn own_t0(domain: &crate::poly::BoxSet, ii: i64) -> CycleSchedule {
    let extents: Vec<i64> = domain.dims.iter().map(|d| d.extent).collect();
    let s = CycleSchedule::row_major(&extents, ii, 0);
    let mins: Vec<i64> = domain.dims.iter().map(|d| d.min).collect();
    let off = s.cycle(&mins);
    s.delayed(-off)
}

/// Binary-search the minimal feasible coarse II for double-buffered tile
/// overlap: tile `n+1`'s stage `s` starts at `start_s + n * II`; this is
/// feasible iff no stage is still busy with the previous tile when its
/// next activation arrives, i.e. `II >= max_s busy_span(s)` (each stage's
/// resources are double-buffered, so only self-overlap constrains II).
fn search_coarse_ii(spans: &[(i64, i64)], completion: i64) -> i64 {
    let feasible = |ii: i64| -> bool {
        spans.iter().all(|&(a, b)| b - a + 1 <= ii)
    };
    let (mut lo, mut hi) = (1i64, completion.max(1));
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if feasible(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

pub fn schedule(lp: &LoweredPipeline) -> Result<PipelineSchedule> {
    ensure!(!lp.stages.is_empty(), "empty pipeline");

    // Inputs stream row-major at full rate (one lane — DNN bandwidth is
    // dominated by the reduction, not the input stream).
    let mut arrivals = BTreeMap::new();
    for name in &lp.inputs {
        let b = lp.buffers[name].clone();
        arrivals.insert(
            name.clone(),
            InputArrival {
                domain: b.clone(),
                lane_maps: vec![AffineMap::identity(b.rank())],
                schedule: own_t0(&b, 1),
            },
        );
    }

    let t0: Vec<CycleSchedule> = lp
        .stages
        .iter()
        .map(|s| own_t0(&s.full_domain(), 1))
        .collect();
    let latency: Vec<i64> = lp
        .stages
        .iter()
        .map(|s| s.instances.iter().map(|i| i.kernel.depth()).max().unwrap_or(0).max(1))
        .collect();

    let solved = core::solve(lp, &t0, &latency, &arrivals, false)?;
    // Input streams are busy too: their span bounds the coarse II.
    let mut spans = solved.spans.clone();
    for arr in arrivals.values() {
        let (a, b) = arr.schedule.span(&arr.domain);
        spans.push((a, b));
    }
    let coarse_ii = search_coarse_ii(&spans, solved.completion);

    let stages = lp
        .stages
        .iter()
        .zip(&t0)
        .zip(&latency)
        .zip(&solved.delays)
        .map(|(((s, t), &lat), &d)| StageSchedule {
            stage: s.name.clone(),
            issue: t.delayed(d),
            latency: lat,
        })
        .collect();

    Ok(PipelineSchedule {
        kind: PipelineKind::Dnn,
        stages,
        arrivals,
        completion: solved.completion,
        coarse_ii,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::Expr;
    use crate::sched::classify;

    /// A small conv layer: 4 output channels, 3x3 window, 4 input
    /// channels, over an 8x8 output — reduction not unrolled.
    fn conv_layer() -> LoweredPipeline {
        let conv = Func::reduce_fn(
            "conv",
            &["co", "y", "x"],
            Expr::c(0),
            &[("ci", 0, 4), ("ry", 0, 3), ("rx", 0, 3)],
            Expr::add(
                Expr::ld("conv", vec![Expr::v("co"), Expr::v("y"), Expr::v("x")]),
                Expr::mul(
                    Expr::ld(
                        "ifmap",
                        vec![
                            Expr::v("ci"),
                            Expr::add(Expr::v("y"), Expr::v("ry")),
                            Expr::add(Expr::v("x"), Expr::v("rx")),
                        ],
                    ),
                    Expr::ld(
                        "weights",
                        vec![Expr::v("co"), Expr::v("ci"), Expr::v("ry"), Expr::v("rx")],
                    ),
                ),
            ),
        );
        let p = Program {
            name: "conv".into(),
            inputs: vec![
                InputDecl { name: "ifmap".into(), rank: 3 },
                InputDecl { name: "weights".into(), rank: 4 },
            ],
            funcs: vec![conv],
            schedule: HwSchedule::new([4, 8, 8]),
        };
        lower(&p).unwrap()
    }

    #[test]
    fn classified_as_dnn() {
        let lp = conv_layer();
        assert_eq!(classify(&lp), PipelineKind::Dnn);
    }

    #[test]
    fn conv_layer_schedules() {
        let lp = conv_layer();
        let ps = schedule(&lp).unwrap();
        assert_eq!(ps.kind, PipelineKind::Dnn);
        // 4*8*8 outputs x 36 MACs each = 9216 issue slots at II=1.
        let conv = ps.stage("conv").unwrap();
        let full = lp.stages[0].full_domain();
        let (a, b) = conv.issue.span(&full);
        assert_eq!(b - a + 1, 9216);
        // Completion covers the whole reduction.
        assert!(ps.completion >= 9216);
        // Double buffering: coarse II is the dominant busy span, less
        // than serial completion (input streaming overlaps compute).
        assert!(ps.coarse_ii <= ps.completion);
        assert!(ps.coarse_ii >= 9216);
    }

    #[test]
    fn coarse_ii_search_converges() {
        assert_eq!(search_coarse_ii(&[(0, 9), (5, 24)], 100), 20);
        assert_eq!(search_coarse_ii(&[(0, 0)], 50), 1);
    }
}
