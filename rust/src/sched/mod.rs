//! Cycle-accurate scheduling (§V-B).
//!
//! The scheduler assigns every stage a one-dimensional affine schedule —
//! cycles after reset — choosing between two policies by the paper's
//! rule: if every reduction loop is fully unrolled the pipeline is a
//! *stencil* pipeline and all loop nests are fused into one aligned,
//! fully-pipelined nest (II=1, line-buffer friendly); otherwise it is a
//! *DNN* pipeline scheduled as a coarse-grained double-buffered pipeline
//! whose coarse II is found by binary search. A third, naïve *sequential*
//! policy (each kernel runs to completion, loops not pipelined) is the
//! baseline of Tables VI and VII.
//!
//! All policies share one exact dependence engine ([`core`]): stage
//! delays are the longest path over the stage DAG where each edge weight
//! is the maximum, over the consumer's iteration domain, of
//! `producer-availability(load(p)) - consumer-issue(p)` — enumerated
//! exactly, which both subsumes the SDF-style constraint problem of
//! Clockwork [12] for stencil pipelines and degrades gracefully (to
//! buffer-everything delays) when access orders cannot be aligned, which
//! is precisely the resnet behaviour in Tables VI/VII.

pub mod core;
pub mod dnn;
pub mod sequential;
pub mod stencil;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::halide::LoweredPipeline;
use crate::poly::{AffineMap, BoxSet, CycleSchedule};

/// Which scheduling policy produced a schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineKind {
    Stencil,
    Dnn,
    Sequential,
}

/// The resolved schedule of one stage.
#[derive(Clone, Debug)]
pub struct StageSchedule {
    pub stage: String,
    /// Issue schedule over the stage's **full** (pure x reduction)
    /// domain, delays already folded in.
    pub issue: CycleSchedule,
    /// Kernel pipeline latency (issue -> result available).
    pub latency: i64,
}

/// How an external input is streamed onto the accelerator
/// (`stream_to_accelerator`): `lanes` values arrive per iteration of
/// `domain`, lane `k` carrying the coordinates `lane_maps[k](p)`.
#[derive(Clone, Debug)]
pub struct InputArrival {
    pub domain: BoxSet,
    pub lane_maps: Vec<AffineMap>,
    pub schedule: CycleSchedule,
}

/// A complete cycle-accurate pipeline schedule.
#[derive(Clone, Debug)]
pub struct PipelineSchedule {
    pub kind: PipelineKind,
    /// Same order as `LoweredPipeline::stages`.
    pub stages: Vec<StageSchedule>,
    pub arrivals: BTreeMap<String, InputArrival>,
    /// Cycles to complete one tile, including draining the output.
    pub completion: i64,
    /// Initiation interval between successive tiles (double buffering
    /// overlaps tiles in DNN pipelines; otherwise = `completion`).
    pub coarse_ii: i64,
}

impl PipelineSchedule {
    pub fn stage(&self, name: &str) -> Option<&StageSchedule> {
        self.stages.iter().find(|s| s.stage == name)
    }
}

/// Classify per the paper's rule (§V-B): stencil iff no remaining
/// (non-unrolled) reduction loops and all stage *and input* ranks align
/// (rate-mismatched pipelines like strip-mined upsamplers cannot fuse
/// into one aligned nest and take the coarse-grained policy instead).
pub fn classify(lp: &LoweredPipeline) -> PipelineKind {
    let rank = lp.stages.last().map(|s| s.pure_domain.rank()).unwrap_or(0);
    let stencil = lp
        .stages
        .iter()
        .all(|s| !s.is_reduction() && s.pure_domain.rank() == rank)
        && lp.inputs.iter().all(|i| lp.buffers[i].rank() == rank);
    if stencil {
        PipelineKind::Stencil
    } else {
        PipelineKind::Dnn
    }
}

/// Schedule with automatic policy selection.
///
/// The full `HwSchedule::validate` runs at the top of lowering (the
/// directives are consumed there and no longer reachable here); this
/// re-checks the one piece the lowered pipeline still carries — the
/// tile — so a hand-built `LoweredPipeline` cannot smuggle in a
/// degenerate extent.
pub fn schedule(lp: &LoweredPipeline) -> Result<PipelineSchedule> {
    anyhow::ensure!(
        !lp.tile.is_empty() && lp.tile.iter().all(|&e| e >= 1),
        "{}: non-positive tile extent in {:?}",
        lp.name,
        lp.tile
    );
    match classify(lp) {
        PipelineKind::Stencil => stencil::schedule(lp),
        PipelineKind::Dnn => dnn::schedule(lp),
        PipelineKind::Sequential => unreachable!(),
    }
}
