//! Stencil-pipeline scheduling (§V-B "Stencil Pipeline").
//!
//! Used when every reduction loop is fully unrolled. All loop nests are
//! fused into one aligned, fully-pipelined iteration (II = 1) in the
//! style of Clockwork [12]: every stage advances through a *common
//! virtual loop nest* whose per-dimension extents are the maxima over
//! all stage domains, so rates match and dependence distances are
//! constant. Per-stage delays then come from the exact dependence engine
//! — the analogue of Clockwork's SDF constraint problem.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use super::core;
use super::{InputArrival, PipelineKind, PipelineSchedule, StageSchedule};
use crate::halide::LoweredPipeline;
use crate::poly::{Affine, AffineMap, BoxSet, CycleSchedule};

/// Zero-delay schedule of a box under common virtual strides: the
/// stage's first point issues at cycle 0, subsequent points advance
/// row-major with the *virtual* strides (which may exceed the stage's
/// own extents, idling the tail of each virtual row).
fn aligned_t0(domain: &BoxSet, strides: &[i64]) -> CycleSchedule {
    assert_eq!(domain.rank(), strides.len());
    let expr = Affine::new(strides.to_vec(), 0);
    let mins: Vec<i64> = domain.dims.iter().map(|d| d.min).collect();
    let offset = -expr.eval(&mins);
    CycleSchedule::new(expr.shift(offset))
}

/// Input lane count: inputs must arrive as fast as the widest stage
/// consumes, so they get one stream lane per unroll instance of the
/// output stage (innermost-dim unrolling, `stream_to_accelerator`).
fn input_lanes(lp: &LoweredPipeline) -> i64 {
    lp.stages.last().map(|s| s.instances.len() as i64).unwrap_or(1)
}

pub fn schedule(lp: &LoweredPipeline) -> Result<PipelineSchedule> {
    let rank = lp
        .stages
        .last()
        .map(|s| s.pure_domain.rank())
        .unwrap_or(0);
    ensure!(rank > 0, "empty pipeline");
    for s in &lp.stages {
        ensure!(
            !s.is_reduction() && s.pure_domain.rank() == rank,
            "stencil scheduling requires fused-rank pure stages; {} violates",
            s.name
        );
    }
    let lanes = input_lanes(lp);

    // Common virtual extents: max per dim over stage domains and
    // (lane-divided) input boxes.
    let mut virt = vec![1i64; rank];
    for s in &lp.stages {
        for (k, d) in s.pure_domain.dims.iter().enumerate() {
            virt[k] = virt[k].max(d.extent);
        }
    }
    for name in &lp.inputs {
        let b = &lp.buffers[name];
        ensure!(b.rank() == rank, "input {name} rank mismatch for stencil fusion");
        for (k, d) in b.dims.iter().enumerate() {
            // Innermost dim is divided across lanes (ceil: a partial
            // final iteration is fine — out-of-box lane coordinates are
            // clipped by the dependence engine and extraction).
            let e = if k == rank - 1 { (d.extent + lanes - 1) / lanes } else { d.extent };
            virt[k] = virt[k].max(e);
        }
    }
    // Row-major strides over the virtual extents (II = 1 innermost).
    let mut strides = vec![1i64; rank];
    for k in (0..rank.saturating_sub(1)).rev() {
        strides[k] = strides[k + 1] * virt[k + 1];
    }

    // Input arrival: `lanes` values per cycle, row-major over the
    // lane-divided box, aligned to the virtual strides.
    let mut arrivals = BTreeMap::new();
    for name in &lp.inputs {
        let b = lp.buffers[name].clone();
        let mut dom = b.clone();
        let last = rank - 1;
        dom.dims[last].extent = (dom.dims[last].extent + lanes - 1) / lanes;
        let lane_maps: Vec<AffineMap> = (0..lanes)
            .map(|k| {
                let mut outs: Vec<Affine> =
                    (0..rank).map(|d| Affine::var(rank, d)).collect();
                // innermost coordinate = lanes * i + k + min adjustment
                outs[last] = Affine::var(rank, last)
                    .scale(lanes)
                    .shift(k - (lanes - 1) * b.dims[last].min);
                AffineMap::new(rank, outs)
            })
            .collect();
        let schedule = aligned_t0(&dom, &strides);
        arrivals.insert(name.clone(), InputArrival { domain: dom, lane_maps, schedule });
    }

    // Zero-delay schedules and kernel latencies.
    let t0: Vec<CycleSchedule> = lp
        .stages
        .iter()
        .map(|s| aligned_t0(&s.pure_domain, &strides))
        .collect();
    let latency: Vec<i64> = lp
        .stages
        .iter()
        .map(|s| s.instances.iter().map(|i| i.kernel.depth()).max().unwrap_or(0).max(1))
        .collect();

    let solved = core::solve(lp, &t0, &latency, &arrivals, false)?;

    let stages = lp
        .stages
        .iter()
        .zip(&t0)
        .zip(&latency)
        .zip(&solved.delays)
        .map(|(((s, t), &lat), &d)| StageSchedule {
            stage: s.name.clone(),
            issue: t.delayed(d),
            latency: lat,
        })
        .collect();

    Ok(PipelineSchedule {
        kind: PipelineKind::Stencil,
        stages,
        arrivals,
        completion: solved.completion,
        coarse_ii: solved.completion,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::Expr;

    fn blur_pipeline(tile: i64, unroll: Option<i64>) -> LoweredPipeline {
        let brighten = Func::pure_fn(
            "brighten",
            &["y", "x"],
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
        );
        let blur = Func::pure_fn(
            "blur",
            &["y", "x"],
            Expr::shr(
                Expr::sum(vec![
                    Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                    Expr::ld(
                        "brighten",
                        vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(1)),
                            Expr::add(Expr::v("x"), Expr::c(1)),
                        ],
                    ),
                ]),
                2,
            ),
        );
        let mut schedule = HwSchedule::new([tile, tile]).store_at("brighten");
        if let Some(u) = unroll {
            schedule = schedule.unroll("brighten", "x", u).unroll("blur", "x", u);
        }
        let p = Program {
            name: "bb".into(),
            inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
            funcs: vec![brighten, blur],
            schedule,
        };
        lower(&p).unwrap()
    }

    #[test]
    fn completion_is_one_tile_pass() {
        // 63x63 output, 64x64 input: completion should be about
        // 64*64 = 4096 cycles plus small startup (the paper's Table VI
        // "optimized" numbers are 4102-4146 for 64x64-input stencils).
        let lp = blur_pipeline(63, None);
        let ps = schedule(&lp).unwrap();
        assert_eq!(ps.kind, PipelineKind::Stencil);
        assert!(
            (4096..4300).contains(&ps.completion),
            "completion {}",
            ps.completion
        );
    }

    #[test]
    fn blur_delay_is_about_one_row() {
        let lp = blur_pipeline(63, None);
        let ps = schedule(&lp).unwrap();
        let b0 = ps.stage("brighten").unwrap().issue.cycle(&[0, 0]);
        let bl = ps.stage("blur").unwrap().issue.cycle(&[0, 0]);
        // blur waits for brighten(1, 1): ~one 64-wide virtual row.
        assert!((64..140).contains(&(bl - b0)), "lead {}", bl - b0);
    }

    #[test]
    fn unrolled_pipeline_halves_completion() {
        let base = schedule(&blur_pipeline(63, None)).unwrap();
        // unroll 63 isn't divisible by 2; use a 62x62 tile for the
        // unrolled variant (input 63x63... still odd) — use 64-tile.
        let lp2 = blur_pipeline(62, Some(2));
        let ps2 = schedule(&lp2).unwrap();
        // Roughly half the cycles (Table V sch4: 4097 -> 2154).
        let ratio = base.completion as f64 / ps2.completion as f64;
        assert!(ratio > 1.6, "ratio {ratio}");
    }

    #[test]
    fn schedules_injective_per_stage() {
        let lp = blur_pipeline(31, None);
        let ps = schedule(&lp).unwrap();
        for (s, ss) in lp.stages.iter().zip(&ps.stages) {
            assert!(ss.issue.is_injective_on(&s.pure_domain), "{}", s.name);
        }
    }
}
