//! The naïve sequential baseline of Tables VI and VII: every loop nest
//! runs to completion before the next starts, and no loop is pipelined —
//! each iteration occupies the kernel for its full latency (II = kernel
//! depth). Inter-stage buffers must therefore hold entire intermediate
//! images, which is what drives the SRAM-capacity column of Table VII.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use super::core;
use super::{InputArrival, PipelineKind, PipelineSchedule, StageSchedule};
use crate::halide::LoweredPipeline;
use crate::poly::{AffineMap, CycleSchedule};

fn own_t0(domain: &crate::poly::BoxSet, ii: i64) -> CycleSchedule {
    let extents: Vec<i64> = domain.dims.iter().map(|d| d.extent).collect();
    let s = CycleSchedule::row_major(&extents, ii, 0);
    let mins: Vec<i64> = domain.dims.iter().map(|d| d.min).collect();
    let off = s.cycle(&mins);
    s.delayed(-off)
}

pub fn schedule(lp: &LoweredPipeline) -> Result<PipelineSchedule> {
    ensure!(!lp.stages.is_empty(), "empty pipeline");

    let mut arrivals = BTreeMap::new();
    for name in &lp.inputs {
        let b = lp.buffers[name].clone();
        arrivals.insert(
            name.clone(),
            InputArrival {
                domain: b.clone(),
                lane_maps: vec![AffineMap::identity(b.rank())],
                schedule: own_t0(&b, 1),
            },
        );
    }

    // No loop pipelining: each iteration waits out the kernel latency.
    let latency: Vec<i64> = lp
        .stages
        .iter()
        .map(|s| s.instances.iter().map(|i| i.kernel.depth()).max().unwrap_or(0).max(1))
        .collect();
    let t0: Vec<CycleSchedule> = lp
        .stages
        .iter()
        .zip(&latency)
        .map(|(s, &lat)| own_t0(&s.full_domain(), lat.max(1)))
        .collect();

    let solved = core::solve(lp, &t0, &latency, &arrivals, true)?;

    let stages = lp
        .stages
        .iter()
        .zip(&t0)
        .zip(&latency)
        .zip(&solved.delays)
        .map(|(((s, t), &lat), &d)| StageSchedule {
            stage: s.name.clone(),
            issue: t.delayed(d),
            latency: lat,
        })
        .collect();

    Ok(PipelineSchedule {
        kind: PipelineKind::Sequential,
        stages,
        arrivals,
        completion: solved.completion,
        coarse_ii: solved.completion,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::Expr;
    use crate::sched::stencil;

    fn gauss_like(tile: i64) -> LoweredPipeline {
        // Two chained 2x2 box filters, fully unrolled: a stencil app.
        let mk = |name: &str, src: &str| {
            Func::pure_fn(
                name,
                &["y", "x"],
                Expr::shr(
                    Expr::sum(vec![
                        Expr::ld(src, vec![Expr::v("y"), Expr::v("x")]),
                        Expr::ld(src, vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))]),
                        Expr::ld(src, vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")]),
                        Expr::ld(
                            src,
                            vec![
                                Expr::add(Expr::v("y"), Expr::c(1)),
                                Expr::add(Expr::v("x"), Expr::c(1)),
                            ],
                        ),
                    ]),
                    2,
                ),
            )
        };
        let p = Program {
            name: "gg".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![mk("a", "in"), mk("b", "a")],
            schedule: HwSchedule::new([tile, tile]).store_at("a"),
        };
        lower(&p).unwrap()
    }

    #[test]
    fn sequential_much_slower_than_pipelined() {
        let lp = gauss_like(30);
        let seq = schedule(&lp).unwrap();
        let opt = stencil::schedule(&lp).unwrap();
        assert_eq!(seq.kind, PipelineKind::Sequential);
        // Table VI shape: multi-x speedup for stencils.
        let speedup = seq.completion as f64 / opt.completion as f64;
        assert!(speedup > 3.0, "speedup {speedup}");
    }

    #[test]
    fn stages_do_not_overlap() {
        let lp = gauss_like(16);
        let ps = schedule(&lp).unwrap();
        let spans: Vec<(i64, i64)> = lp
            .stages
            .iter()
            .zip(&ps.stages)
            .map(|(s, ss)| {
                let (a, b) = ss.issue.span(&s.full_domain());
                (a, b + ss.latency)
            })
            .collect();
        for w in spans.windows(2) {
            assert!(w[1].0 > w[0].1, "stages overlap: {spans:?}");
        }
    }
}
