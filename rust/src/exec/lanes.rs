//! Fixed-width lane arithmetic for the vectorized functional engine
//! (docs/execution.md, "Lanes, threads, and the arena").
//!
//! A lane vector is a plain `[i32; 8]` — no unstable SIMD features,
//! just arrays the optimizer autovectorizes — evaluated element-wise
//! with exactly the wrapping-i32 semantics of
//! [`crate::halide::expr::eval_binop`] and the PE ALU
//! ([`crate::hw::PeOp`]). Every lane op below is the scalar op applied
//! independently per element, so a lane program is bit-identical to
//! eight scalar programs run in lockstep; DESIGN.md §6 makes the
//! argument in full, and `lane_binop_matches_eval_binop` pins each
//! operator against the scalar ALU over an edge-case sweep.
//!
//! The operator `match` in [`lane_binop`] is hoisted outside the lane
//! loop on purpose: the per-element closure is branch-free, which is
//! what lets the compiler emit one 8-wide vector op per operator
//! instead of re-dispatching per element.

use crate::halide::expr::{eval_binop, BinOp};

/// Lane width: eight i32 elements per vector step. Wide enough to
/// keep the host ALU ports busy, narrow enough that the scalar tail
/// (`extent % 8` points) stays cheap at the paper's 60–64-wide tiles.
pub const LANES: usize = 8;

/// One vector of lane values.
pub type Lanes = [i32; LANES];

/// Broadcast a scalar across all lanes.
#[inline]
pub fn splat(v: i32) -> Lanes {
    [v; LANES]
}

#[inline]
fn zipmap(a: &Lanes, b: &Lanes, f: impl Fn(i32, i32) -> i32) -> Lanes {
    let mut r = [0i32; LANES];
    for ((r, &x), &y) in r.iter_mut().zip(a).zip(b) {
        *r = f(x, y);
    }
    r
}

/// Element-wise [`eval_binop`]: each arm mirrors the scalar ALU's
/// wrapping/euclidean semantics exactly (comparisons produce 0/1,
/// division by zero yields 0 — the hardware's defined result).
#[inline]
pub fn lane_binop(op: BinOp, a: &Lanes, b: &Lanes) -> Lanes {
    match op {
        BinOp::Add => zipmap(a, b, i32::wrapping_add),
        BinOp::Sub => zipmap(a, b, i32::wrapping_sub),
        BinOp::Mul => zipmap(a, b, i32::wrapping_mul),
        BinOp::Div => zipmap(a, b, |x, y| if y == 0 { 0 } else { x.div_euclid(y) }),
        BinOp::Mod => zipmap(a, b, |x, y| if y == 0 { 0 } else { x.rem_euclid(y) }),
        BinOp::Min => zipmap(a, b, i32::min),
        BinOp::Max => zipmap(a, b, i32::max),
        BinOp::Shl => zipmap(a, b, |x, y| x.wrapping_shl(y as u32)),
        BinOp::Shr => zipmap(a, b, |x, y| x.wrapping_shr(y as u32)),
        BinOp::And => zipmap(a, b, |x, y| x & y),
        BinOp::Or => zipmap(a, b, |x, y| x | y),
        BinOp::Xor => zipmap(a, b, |x, y| x ^ y),
        BinOp::Lt => zipmap(a, b, |x, y| (x < y) as i32),
        BinOp::Le => zipmap(a, b, |x, y| (x <= y) as i32),
        BinOp::Gt => zipmap(a, b, |x, y| (x > y) as i32),
        BinOp::Ge => zipmap(a, b, |x, y| (x >= y) as i32),
        BinOp::Eq => zipmap(a, b, |x, y| (x == y) as i32),
        BinOp::Ne => zipmap(a, b, |x, y| (x != y) as i32),
    }
}

/// Element-wise wrapping negation ([`crate::halide::expr::UnOp::Neg`]).
#[inline]
pub fn lane_neg(a: &Lanes) -> Lanes {
    let mut r = *a;
    for v in r.iter_mut() {
        *v = v.wrapping_neg();
    }
    r
}

/// Element-wise wrapping absolute value
/// ([`crate::halide::expr::UnOp::Abs`]).
#[inline]
pub fn lane_abs(a: &Lanes) -> Lanes {
    let mut r = *a;
    for v in r.iter_mut() {
        *v = v.wrapping_abs();
    }
    r
}

/// Element-wise select: `c != 0 ? t : e`, the PE's three-operand mux.
#[inline]
pub fn lane_select(c: &Lanes, t: &Lanes, e: &Lanes) -> Lanes {
    let mut r = [0i32; LANES];
    for (l, v) in r.iter_mut().enumerate() {
        *v = if c[l] != 0 { t[l] } else { e[l] };
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_OPS: [BinOp; 18] = [
        BinOp::Add,
        BinOp::Sub,
        BinOp::Mul,
        BinOp::Div,
        BinOp::Mod,
        BinOp::Min,
        BinOp::Max,
        BinOp::Shl,
        BinOp::Shr,
        BinOp::And,
        BinOp::Or,
        BinOp::Xor,
        BinOp::Lt,
        BinOp::Le,
        BinOp::Gt,
        BinOp::Ge,
        BinOp::Eq,
        BinOp::Ne,
    ];

    /// The values where wrapping/euclidean/shift semantics can drift:
    /// extremes, zero divisors, negative operands, shift counts past
    /// the width.
    const EDGES: [i32; 12] = [
        i32::MIN,
        i32::MIN + 1,
        -257,
        -31,
        -1,
        0,
        1,
        2,
        31,
        33,
        12345,
        i32::MAX,
    ];

    /// Every lane operator is element-wise identical to the scalar
    /// ALU (`eval_binop`) — the bit-exactness argument of DESIGN.md §6
    /// reduced to a sweep.
    #[test]
    fn lane_binop_matches_eval_binop() {
        for op in ALL_OPS {
            for &x in &EDGES {
                for chunk in EDGES.chunks(LANES) {
                    let mut b = [0i32; LANES];
                    b[..chunk.len()].copy_from_slice(chunk);
                    let a = splat(x);
                    let got = lane_binop(op, &a, &b);
                    for l in 0..LANES {
                        assert_eq!(
                            got[l],
                            eval_binop(op, x, b[l]),
                            "{op:?}({x}, {})",
                            b[l]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lane_unary_and_select_match_scalar() {
        let mut a = [0i32; LANES];
        let mut c = [0i32; LANES];
        for (l, v) in a.iter_mut().enumerate() {
            *v = EDGES[l];
            c[l] = (l % 2) as i32;
        }
        let neg = lane_neg(&a);
        let abs = lane_abs(&a);
        let sel = lane_select(&c, &a, &splat(-7));
        for l in 0..LANES {
            assert_eq!(neg[l], a[l].wrapping_neg());
            assert_eq!(abs[l], a[l].wrapping_abs());
            assert_eq!(sel[l], if c[l] != 0 { a[l] } else { -7 });
        }
        // The wrapping edge the i16-style ALU relies on.
        assert_eq!(lane_neg(&splat(i32::MIN))[0], i32::MIN);
        assert_eq!(lane_abs(&splat(i32::MIN))[0], i32::MIN);
    }
}
