//! The per-run arena: every buffer the functional engine touches at
//! steady state, allocated once when an [`super::ExecRun`] is built
//! and reset in place between requests — so a warm run (and the
//! large-extent `TileBatch` drains layered on top, docs/tiling.md)
//! performs **zero** steady-state heap allocations.
//!
//! The arena also carries an allocation counter: construction and any
//! later growth event (a feed spill, an output buffer that had to
//! grow) increment it, and the alloc-counter tests assert the count is
//! frozen across repeated warm runs. That turns the zero-allocation
//! contract from a claim in a doc into a property a test can watch.

use super::lanes::{Lanes, LANES};
use super::plan::{ExecKernel, ExecPlan};

/// Reusable per-kernel working buffers: the scalar and lane register
/// files, loaded operand values, odometer counters, and per-stream
/// running addresses. Sized to the widest kernel they will serve.
pub(crate) struct KernelBufs {
    /// Scalar PE register file (one slot per mapped node).
    pub regs: Vec<i32>,
    /// Scalar loaded word per load stream.
    pub load_vals: Vec<i32>,
    /// Lane register file (one vector per mapped node).
    pub lane_regs: Vec<Lanes>,
    /// Lane loaded words per load stream.
    pub lane_loads: Vec<Lanes>,
    /// Outer-loop odometer (dims outside the lane dim).
    pub outer: Vec<i64>,
    /// Reduction-tail odometer (dims inside the lane dim).
    pub tail: Vec<i64>,
    /// Running flat address per load stream.
    pub addr: Vec<i64>,
}

/// How many `Vec`s a [`KernelBufs`] construction allocates.
const KERNEL_BUF_VECS: u64 = 7;

impl KernelBufs {
    fn with(nodes: usize, loads: usize, rank: usize) -> KernelBufs {
        KernelBufs {
            regs: vec![0; nodes],
            load_vals: vec![0; loads],
            lane_regs: vec![[0; LANES]; nodes],
            lane_loads: vec![[0; LANES]; loads],
            outer: vec![0; rank],
            tail: vec![0; rank],
            addr: vec![0; loads],
        }
    }

    /// Buffers sized to the widest kernel of `plan`.
    pub fn for_plan(plan: &ExecPlan) -> KernelBufs {
        let max = |f: fn(&ExecKernel) -> usize| {
            plan.kernels.iter().map(f).max().unwrap_or(0)
        };
        KernelBufs::with(max(|k| k.nodes.len()), max(|k| k.loads.len()), max(|k| k.extents.len()))
    }

    /// Buffers for one kernel — what each helper thread of the
    /// row-parallel path builds for itself.
    pub fn for_kernel(k: &ExecKernel) -> KernelBufs {
        KernelBufs::with(k.nodes.len(), k.loads.len(), k.extents.len())
    }
}

/// The arena one [`super::ExecRun`] owns: intermediate (scratch)
/// buffers plus the kernel working buffers, reset between requests.
pub(crate) struct Arena {
    /// Zero-initialized intermediate buffers, one per plan scratch
    /// spec — the SRAM's reset state.
    pub scratch: Vec<Vec<i32>>,
    pub bufs: KernelBufs,
    allocs: u64,
}

impl Arena {
    pub fn for_plan(plan: &ExecPlan) -> Arena {
        let scratch: Vec<Vec<i32>> =
            plan.scratch.iter().map(|s| vec![0i32; s.len]).collect();
        // Construction cost: the scratch Vecs (plus their container)
        // and the kernel buffers.
        let allocs = scratch.len() as u64 + 1 + KERNEL_BUF_VECS;
        Arena { scratch, bufs: KernelBufs::for_plan(plan), allocs }
    }

    /// Reset the intermediates to the hardware's zeroed state in
    /// place — no frees, no allocations.
    pub fn zero_scratch(&mut self) {
        for s in self.scratch.iter_mut() {
            s.iter_mut().for_each(|v| *v = 0);
        }
    }

    /// Record a heap-allocation event attributed to this run (a
    /// steady-state run must never call this — the alloc-counter
    /// tests assert the count stays frozen across warm runs).
    pub fn count_alloc(&mut self) {
        self.allocs += 1;
    }

    pub fn alloc_count(&self) -> u64 {
        self.allocs
    }
}
