//! `exec` — the functional execution engine: serve and tune without
//! stepping cycles.
//!
//! The unified-buffer abstraction makes every port's address stream a
//! *static affine* function of the iteration domain (PAPER.md §IV), so
//! a compiled design's outputs **and** its cycle/energy counts are
//! computable directly from the polyhedral schedule — no cycle loop:
//!
//! * [`ExecPlan`] compiles a [`crate::mapping::MappedDesign`] into
//!   fused, loop-ordered tensor kernels (the mapped PE node programs
//!   walked over their iteration domains with Fig-5c delta-recurrence
//!   addressing) plus an analytic timing model ([`ExecTiming`]) that
//!   derives every [`crate::cgra::SimStats`] field in closed form.
//! * [`ExecRun`] executes requests against the plan in microseconds,
//!   producing a [`crate::cgra::SimResult`] bit-identical — output
//!   *and* stats — to the cycle-accurate [`crate::cgra::SimRun`].
//!
//! ## Engine selection
//!
//! [`Engine`] names the policies the stack exposes (`pushmem
//! serve/serve-all/tune/report/run --engine {exec,exec-scalar,sim,auto}`):
//! `exec` demands the functional engine (vectorized + parallel on the
//! persistent compute pool, see [`run`] and [`pool`]), `exec-scalar`
//! its original scalar reference walk (the
//! differential-testing escape hatch), `sim` the cycle-accurate
//! simulator, and `auto` (the default) prefers `exec`, falling back to
//! `sim` whenever [`ExecPlan::build`] cannot prove the design's port
//! structure sound for functional replay (non-lockstep load ports,
//! events outside the simulated window, and similar — the simulator
//! also catches designs whose event streams *fall behind* at run time,
//! which a functional replay cannot observe). Full design rationale:
//! docs/execution.md, DESIGN.md §6. `pushmem validate` cross-checks
//! the two engines against each other per app.

mod arena;
pub mod lanes;
pub mod plan;
pub mod pool;
pub mod run;
pub mod timing;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::cgra::{SimResult, SimRun, SimStats};
use crate::tensor::Tensor;

pub use plan::ExecPlan;
pub use run::{execute, ExecRun};
pub use timing::{BufferActivity, ExecTiming};

/// Which execution engine serves a request.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Engine {
    /// Prefer the functional engine; fall back to the cycle-accurate
    /// simulator when the design is outside its proven fragment.
    #[default]
    Auto,
    /// The functional engine ([`ExecRun`]), unconditionally.
    Exec,
    /// The functional engine's scalar reference path
    /// ([`ExecRun::new_scalar`]) — the original one-point-at-a-time
    /// walk, kept selectable as a differential-testing escape hatch.
    ExecScalar,
    /// The cycle-accurate simulator ([`SimRun`]), unconditionally.
    Sim,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Engine> {
        Ok(match s {
            "auto" => Engine::Auto,
            "exec" => Engine::Exec,
            "exec-scalar" => Engine::ExecScalar,
            "sim" => Engine::Sim,
            other => bail!("unknown engine {other:?} (want exec|exec-scalar|sim|auto)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Auto => "auto",
            Engine::Exec => "exec",
            Engine::ExecScalar => "exec-scalar",
            Engine::Sim => "sim",
        }
    }
}

/// A request executor of either engine — what serving, validation,
/// reporting, and the tuner hold per design once the engine is
/// resolved (see [`crate::coordinator::Compiled::runner`]).
pub enum EngineRun {
    Exec(ExecRun),
    Sim(SimRun),
}

impl EngineRun {
    pub fn run(&mut self, inputs: &BTreeMap<String, Tensor>) -> Result<SimResult> {
        match self {
            EngineRun::Exec(r) => r.run(inputs),
            EngineRun::Sim(r) => r.run(inputs),
        }
    }

    /// Execute into a caller-owned output tensor, reusing its buffer
    /// when the layout already matches — the allocation-free variant
    /// the tile path drains through. Returns the stats and whether the
    /// tensor was freshly (re)allocated this call.
    pub fn run_into(
        &mut self,
        inputs: &BTreeMap<String, Tensor>,
        out: &mut Option<Tensor>,
    ) -> Result<(SimStats, bool)> {
        match self {
            EngineRun::Exec(r) => {
                let reuse = out
                    .as_ref()
                    .is_some_and(|t| t.shape.same_layout(&r.plan().out_box));
                if !reuse {
                    *out = Some(Tensor::zeros(r.plan().out_box.clone()));
                }
                let t = out.as_mut().expect("output tensor bound above");
                let stats = r.run_into(inputs, &mut t.data)?;
                Ok((stats, !reuse))
            }
            // The simulator builds its result tensor internally; no
            // reuse to be had (it is not the steady-state tile path).
            EngineRun::Sim(r) => {
                let res = r.run(inputs)?;
                let stats = res.stats;
                *out = Some(res.output);
                Ok((stats, true))
            }
        }
    }

    /// The concrete engine behind this run (`Auto` resolves at
    /// construction, so this is always `Exec`, `ExecScalar`, or `Sim`).
    pub fn engine(&self) -> Engine {
        match self {
            EngineRun::Exec(r) if r.is_scalar() => Engine::ExecScalar,
            EngineRun::Exec(_) => Engine::Exec,
            EngineRun::Sim(_) => Engine::Sim,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_parse_roundtrips() {
        for e in [Engine::Auto, Engine::Exec, Engine::ExecScalar, Engine::Sim] {
            assert_eq!(Engine::parse(e.name()).unwrap(), e);
        }
        assert!(Engine::parse("fast").is_err());
        assert_eq!(Engine::default(), Engine::Auto);
    }
}
