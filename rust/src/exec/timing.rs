//! The analytic timing/energy model: every field of
//! [`crate::cgra::SimStats`] derived in closed form from the
//! polyhedral event counts of a mapped design — no cycle loop.
//!
//! The unified-buffer abstraction makes this possible (PAPER.md §IV):
//! every port's schedule is a static affine function of its iteration
//! domain, so "how many times does this element fire, and when" is a
//! cardinality / interval-bound question, not a simulation question.
//! The derivations mirror the cycle-accurate simulator's accounting
//! exactly (docs/execution.md walks through each one):
//!
//! * `cycles`      — the scheduled completion (what the simulator
//!   reports verbatim).
//! * `words_in/out` — input/output stream event counts (domain
//!   cardinalities).
//! * `sr_shifts`   — shift-register taps free-run every cycle of the
//!   simulated window: `horizon × taps`.
//! * `pe_ops`      — non-accumulator PEs free-run every cycle
//!   (`horizon × count`); gated accumulators fire once per point of
//!   their kernel's full domain.
//! * `sram_reads/writes` — wide-bank flush/read controllers fire once
//!   per point of their (strip-mined) iteration domains.
//!
//! Each closed form is only valid when the corresponding events all
//! land inside the simulator's `[0, horizon)` window; [`build`]
//! verifies the interval bounds and returns `Err` otherwise, which is
//! one of the conditions that makes engine selection fall back to the
//! cycle-accurate simulator (see [`crate::exec::Engine`]).
//!
//! Because the model is purely analytic — a function of the *design*,
//! never of how the functional engine walks it — the stats are
//! identical whether [`crate::exec::ExecRun`] executes scalar,
//! vectorized, or across threads (docs/execution.md, "Lanes, threads,
//! and the arena"); the exec_fuzz suite asserts exactly that.

use anyhow::Result;

use crate::cgra::sim::HORIZON_SLACK;
use crate::cgra::SimStats;
use crate::hw::memtile::PortCtlConfig;
use crate::hw::PeOp;
use crate::mapping::{BankConfig, MappedDesign, PortImpl};
use crate::poly::Affine;
use crate::ub::UbGraph;

/// Event-count activity of one unified buffer over the tile window —
/// the "per-tile activity" view of the analytic model.
#[derive(Clone, Debug)]
pub struct BufferActivity {
    pub buffer: String,
    /// Port events (reads + writes) per tile.
    pub events: u64,
    /// First and last cycle any port of this buffer fires (inclusive).
    pub first: i64,
    pub last: i64,
    /// Events per cycle of the buffer's own active window — 1.0 means
    /// some port fires every cycle the buffer is live.
    pub occupancy: f64,
}

/// The closed-form performance model of one mapped design.
#[derive(Clone, Debug)]
pub struct ExecTiming {
    /// Cycles to complete one tile (the figure `SimStats::cycles`
    /// reports).
    pub completion: i64,
    /// The simulator's accounting window (`completion` plus the flush
    /// slack); the free-running stats below cover exactly this window.
    pub horizon: i64,
    /// Bit-identical to what a cycle-accurate run reports.
    pub stats: SimStats,
    /// Per-buffer event counts and active spans.
    pub activity: Vec<BufferActivity>,
    /// Stall-free output occupancy: output words per completion cycle
    /// (1.0 = one pixel drained every cycle of the tile).
    pub occupancy: f64,
}

/// Total fires of a set of port controllers, verified to land inside
/// `[0, horizon)` (outside it the simulator would stop counting and
/// the closed form would diverge).
fn ctl_fires(ctls: &[PortCtlConfig], horizon: i64, what: &str) -> Result<u64> {
    let mut total = 0u64;
    for c in ctls {
        if c.extents.iter().any(|&e| e <= 0) {
            continue;
        }
        let dims: Vec<(i64, i64)> = c.extents.iter().map(|&e| (0, e - 1)).collect();
        let sched = Affine::new(c.sched.strides.clone(), c.sched.offset);
        let (lo, hi) = sched.bounds(&dims);
        anyhow::ensure!(
            lo >= 0 && hi < horizon,
            "{what} controller fires in [{lo}, {hi}], outside the simulated window [0, {horizon})"
        );
        total += c.extents.iter().product::<i64>() as u64;
    }
    Ok(total)
}

/// Derive the full timing model for `(design, graph)`.
pub fn build(design: &MappedDesign, graph: &UbGraph) -> Result<ExecTiming> {
    let completion = graph.completion;
    let horizon = completion + HORIZON_SLACK;

    // --- Stream event counts ------------------------------------
    let mut words_in = 0u64;
    for ep in &graph.input_streams {
        words_in += graph.buffers[&ep.buffer].inputs[ep.port].domain.cardinality() as u64;
    }
    let mut words_out = 0u64;
    for ep in &graph.output_streams {
        words_out += graph.buffers[&ep.buffer].outputs[ep.port].domain.cardinality() as u64;
    }

    // --- Free-running shift registers ---------------------------
    let taps = design
        .buffers
        .values()
        .flat_map(|b| b.port_impls.iter())
        .filter(|i| matches!(i, PortImpl::Shift { .. }))
        .count() as u64;
    let sr_shifts = horizon as u64 * taps;

    // --- PE operations ------------------------------------------
    // Non-accumulator PEs tick every cycle of the window; a gated
    // accumulator ticks once per full-domain point, provided every
    // gate event lands inside the window.
    let mut free_running_pes = 0u64;
    let mut acc_fires = 0u64;
    for k in &design.kernels {
        for (ni, n) in k.nodes.iter().enumerate() {
            if matches!(n.cfg.op, PeOp::Acc { .. }) {
                anyhow::ensure!(
                    ni + 1 == k.nodes.len(),
                    "kernel {}: accumulator PE at non-root position {ni}",
                    k.stage
                );
                if k.domain.is_empty() {
                    continue;
                }
                let gate = k.schedule.delayed(k.latency - 1);
                let (lo, hi) = gate.expr.bounds(&k.domain.bounds());
                anyhow::ensure!(
                    lo >= 0 && hi < horizon,
                    "kernel {}: accumulator gate fires in [{lo}, {hi}], outside [0, {horizon})",
                    k.stage
                );
                acc_fires += k.domain.cardinality() as u64;
            } else {
                free_running_pes += 1;
            }
        }
    }
    let pe_ops = horizon as u64 * free_running_pes + acc_fires;

    // --- Wide-bank SRAM accesses --------------------------------
    // One write per aggregator flush, one read per SRAM→TB fetch
    // (dual-port fallback banks are excluded, exactly as the
    // simulator's stats collection excludes them).
    let mut sram_reads = 0u64;
    let mut sram_writes = 0u64;
    for mb in design.buffers.values() {
        for bank in &mb.banks {
            if let BankConfig::Wide(cfg) = &bank.config {
                sram_writes += ctl_fires(&cfg.agg_flush, horizon, "AGG flush")?;
                sram_reads += ctl_fires(&cfg.sram_read, horizon, "SRAM read")?;
            }
        }
    }

    // --- Per-buffer activity ------------------------------------
    let mut activity = Vec::with_capacity(graph.buffers.len());
    for (name, ub) in &graph.buffers {
        let mut events = 0u64;
        let mut first = i64::MAX;
        let mut last = i64::MIN;
        for port in ub.inputs.iter().chain(&ub.outputs) {
            if port.domain.is_empty() {
                continue;
            }
            events += port.domain.cardinality() as u64;
            let (lo, hi) = port.active_span();
            first = first.min(lo);
            last = last.max(hi);
        }
        if events == 0 {
            continue;
        }
        let window = (last - first + 1).max(1) as f64;
        activity.push(BufferActivity {
            buffer: name.clone(),
            events,
            first,
            last,
            occupancy: events as f64 / window,
        });
    }

    Ok(ExecTiming {
        completion,
        horizon,
        stats: SimStats {
            cycles: completion,
            sram_reads,
            sram_writes,
            pe_ops,
            sr_shifts,
            words_in,
            words_out,
        },
        activity,
        occupancy: words_out as f64 / completion.max(1) as f64,
    })
}
