//! [`ExecRun`]: execute one request against an [`ExecPlan`] — fused
//! loop nests over the kernels' iteration domains, no cycle loop.
//!
//! Per request the run walks each kernel's domain once in row-major
//! order: load addresses advance by Fig-5c delta recurrences (one add
//! per stream per step), the mapped PE node program evaluates with the
//! same i32 ALU semantics the hardware uses
//! ([`crate::halide::expr::eval_binop`]), and the root value is stored
//! once per reduction group. The reported [`SimStats`] come from the
//! plan's analytic timing model and are bit-identical to what the
//! cycle-accurate simulator would report — the differential suites
//! (`rust/tests/exec_vs_sim.rs`, `rust/tests/exec_fuzz.rs`) enforce it.
//!
//! ## The hot path (docs/execution.md, "Lanes, threads, and the arena")
//!
//! The default engine walks each kernel in three nested layers:
//!
//! - **Lanes** — the innermost *pure* dim runs [`LANES`] points at a
//!   time as plain `[i32; 8]` arrays ([`super::lanes`]), each lane
//!   replaying its pure point's full reduction walk with a per-lane
//!   accumulator register; a scalar tail covers `extent % LANES`.
//! - **Threads** — when the kernel is large enough and some pure
//!   outer dim's store blocks are provably disjoint flat ranges
//!   ([`super::plan::StorePartition`] — row-major rows, strided rows,
//!   and channel-interleaved planes alike), that dim is split into
//!   chunks executed on the persistent compute pool
//!   ([`super::pool`]) over `split_at_mut` destination slices — no
//!   locks, no per-run thread spawns, no `unsafe` in this module.
//!   `PUSHMEM_EXEC_THREADS` caps the fan-out (`0` = auto).
//! - **The arena** ([`super::arena`]) — every scratch tensor and
//!   working buffer is owned by the run and reset in place, so warm
//!   runs (and `TileBatch` drains over them) allocate nothing.
//!
//! [`ExecRun::new_scalar`] (`--engine exec-scalar`) keeps the original
//! one-point-at-a-time walk over [`DeltaImpl`] cursors as an
//! independently-implemented reference for differential testing.
//!
//! Like [`crate::cgra::SimRun`], an `ExecRun` is reused across
//! requests with in-place resets: one run serves one thread.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

use anyhow::{Context, Result};

use crate::cgra::{SimResult, SimStats};
use crate::halide::expr::{eval_binop, UnOp};
use crate::hw::{AffineConfig, AffineHw, DeltaImpl, IterationDomain, PeOp};
use crate::mapping::{MappedDesign, OperandSrc};
use crate::tensor::Tensor;
use crate::ub::UbGraph;

use super::arena::{Arena, KernelBufs};
use super::lanes::{self, Lanes, LANES};
use super::plan::{BufRef, ExecKernel, ExecPlan, StorePartition};

/// Minimum kernel trip count before the partitioned parallel path
/// engages: below this, dispatch overhead beats the win. Per-tile
/// kernels (the paper's 60–64-wide tiles) stay under it, which is also
/// what keeps the steady-state tile path allocation-free — the
/// parallel path builds per-worker [`KernelBufs`].
pub(crate) const PAR_MIN_POINTS: i64 = 1 << 16;

/// Most designs bind a handful of input streams; up to this many are
/// held in a stack array so request binding allocates nothing.
const FEED_CAP: usize = 8;

fn default_threads() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get()).min(8)
}

/// Worker cap for the parallel path: `PUSHMEM_EXEC_THREADS` if set
/// (clamped to `[1, 64]`; `0` means "auto"), else
/// `min(available_parallelism, 8)`. A value that does not parse logs a
/// `warn` through the telemetry logger and falls back to auto — never
/// silently.
fn exec_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    let n = *THREADS.get_or_init(|| match std::env::var("PUSHMEM_EXEC_THREADS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(0) => default_threads(),
            Ok(n) => n.clamp(1, 64),
            Err(_) => {
                crate::telemetry::log::warn(
                    "exec",
                    &format!(
                        "event=bad_env var=PUSHMEM_EXEC_THREADS value={v:?} fallback=auto"
                    ),
                );
                default_threads()
            }
        },
        Err(_) => default_threads(),
    });
    // Surface the configured cap next to `exec_threads_used` so the
    // stats snapshot shows fan-out used vs available. Config-path
    // only (once per ExecRun construction), never per kernel.
    crate::telemetry::metrics().exec_threads_cap.set(n as u64);
    n
}

/// The execution half of the functional engine: mutable per-request
/// state for one [`ExecPlan`].
pub struct ExecRun {
    plan: Arc<ExecPlan>,
    arena: Arena,
    /// Use the original scalar reference walk (`--engine exec-scalar`).
    scalar: bool,
    threads: usize,
}

impl ExecRun {
    pub fn new(plan: Arc<ExecPlan>) -> ExecRun {
        ExecRun::with_threads(plan, exec_threads())
    }

    /// A run with an explicit worker cap (tests pin 1 vs N).
    pub fn with_threads(plan: Arc<ExecPlan>, threads: usize) -> ExecRun {
        let arena = Arena::for_plan(&plan);
        ExecRun { plan, arena, scalar: false, threads: threads.max(1) }
    }

    /// The scalar reference engine: the original one-point-at-a-time
    /// [`DeltaImpl`] walk, kept as an independent implementation for
    /// differential testing (`--engine exec-scalar`).
    pub fn new_scalar(plan: Arc<ExecPlan>) -> ExecRun {
        let arena = Arena::for_plan(&plan);
        ExecRun { plan, arena, scalar: true, threads: 1 }
    }

    pub fn is_scalar(&self) -> bool {
        self.scalar
    }

    pub fn plan(&self) -> &Arc<ExecPlan> {
        &self.plan
    }

    /// Heap allocations attributed to this run so far (construction
    /// plus any later growth). Frozen across warm runs — the
    /// alloc-counter tests assert it.
    pub fn alloc_count(&self) -> u64 {
        self.arena.alloc_count()
    }

    /// Execute one request. Output and stats are bit-identical to a
    /// cycle-accurate [`crate::cgra::SimRun::run`] on the same design
    /// and inputs.
    pub fn run(&mut self, inputs: &BTreeMap<String, Tensor>) -> Result<SimResult> {
        self.execute_all(inputs)?;
        Ok(SimResult {
            output: Tensor::from_data(
                self.plan.out_box.clone(),
                self.arena.scratch[self.plan.out_scratch].clone(),
            ),
            stats: self.plan.timing().stats,
        })
    }

    /// Execute one request into a caller-owned output buffer —
    /// the allocation-free variant the tile path drains through
    /// (`tile/run.rs`). `out` is overwritten with the flat output
    /// words in `out_box` row-major order.
    pub fn run_into(
        &mut self,
        inputs: &BTreeMap<String, Tensor>,
        out: &mut Vec<i32>,
    ) -> Result<SimStats> {
        self.execute_all(inputs)?;
        let need = self.arena.scratch[self.plan.out_scratch].len();
        if out.capacity() < need {
            self.arena.count_alloc();
        }
        out.clear();
        out.extend_from_slice(&self.arena.scratch[self.plan.out_scratch]);
        Ok(self.plan.timing().stats)
    }

    /// The analytic stats the engine reports (identical every request
    /// — activity is input-independent by construction).
    pub fn stats(&self) -> SimStats {
        self.plan.timing().stats
    }

    /// Bind the request, reset the arena, and run every kernel in
    /// dataflow order; the result is left in the output scratch.
    fn execute_all(&mut self, inputs: &BTreeMap<String, Tensor>) -> Result<()> {
        let plan = Arc::clone(&self.plan);

        // Bind request tensors, verifying layout (same rule as the
        // simulator: flat addressing is only valid against the
        // declared boxes). The common case fits the stack array.
        let n = plan.inputs.len();
        let mut feed_arr: [&[i32]; FEED_CAP] = [&[]; FEED_CAP];
        let mut feed_vec: Vec<&[i32]> = Vec::new();
        if n > FEED_CAP {
            feed_vec.reserve(n);
            self.arena.count_alloc();
        }
        for (k, spec) in plan.inputs.iter().enumerate() {
            let t = inputs
                .get(&spec.name)
                .with_context(|| format!("missing input {}", spec.name))?;
            anyhow::ensure!(
                t.shape.same_layout(&spec.shape),
                "input {}: tensor box {} does not match the design's declared box {}",
                spec.name,
                t.shape,
                spec.shape
            );
            if n <= FEED_CAP {
                feed_arr[k] = &t.data;
            } else {
                feed_vec.push(&t.data);
            }
        }
        let feed: &[&[i32]] =
            if n <= FEED_CAP { &feed_arr[..n] } else { &feed_vec };

        // Zero the intermediate buffers (the hardware's reset state).
        self.arena.zero_scratch();

        // --- Fused kernel loops, in dataflow order --------------
        // The destination buffer is taken out of the arena so the
        // remaining scratch can be read shared (including by worker
        // threads). Sound because `build` verified no kernel reads a
        // buffer still being written (`last_writer < ki`) — in
        // particular no kernel reads its own store buffer.
        let scalar = self.scalar;
        let threads = self.threads;
        let arena = &mut self.arena;
        for kp in &plan.kernels {
            let mut dst = std::mem::take(&mut arena.scratch[kp.store.dst]);
            if scalar {
                exec_kernel_scalar(kp, feed, &arena.scratch, &mut dst, &mut arena.bufs);
            } else {
                exec_kernel(kp, feed, &arena.scratch, &mut dst, &mut arena.bufs, threads);
            }
            arena.scratch[kp.store.dst] = dst;
        }
        Ok(())
    }
}

/// Flat address of `cfg` at outer point `outer`, lane-dim coordinate
/// `x`, reduction tail all-zero.
#[inline]
fn addr_at(cfg: &AffineConfig, outer: &[i64], ld: usize, x: i64) -> i64 {
    let mut a = cfg.offset + cfg.strides[ld] * x;
    for (s, o) in cfg.strides[..ld].iter().zip(outer) {
        a += s * o;
    }
    a
}

/// Advance the outer odometer (dims `0..outer.len()`, row-major), with
/// dim `cdim` confined to `[row0, row1)`. Returns false when exhausted
/// — immediately for an empty odometer (lane dim is dim 0).
fn step_outer(outer: &mut [i64], extents: &[i64], cdim: usize, row0: i64, row1: i64) -> bool {
    for k in (0..outer.len()).rev() {
        outer[k] += 1;
        let limit = if k == cdim { row1 } else { extents[k] };
        if outer[k] < limit {
            return true;
        }
        outer[k] = if k == cdim { row0 } else { 0 };
    }
    false
}

/// Advance the reduction-tail odometer one step, updating every load
/// stream's running flat address by its Fig-5c delta (the delta for
/// the owning dim already accounts for every inner dim's wrap —
/// exactly [`DeltaImpl::step`], without the per-step `inc`/`clr`
/// vectors). Returns false when the tail is exhausted.
#[inline]
fn step_tail(extents: &[i64], tail: &mut [i64], deltas: &[Vec<i64>], addr: &mut [i64]) -> bool {
    for k in (0..tail.len()).rev() {
        tail[k] += 1;
        if tail[k] < extents[k] {
            for (a, d) in addr.iter_mut().zip(deltas) {
                *a += d[k];
            }
            return true;
        }
        tail[k] = 0;
    }
    false
}

/// `OperandSrc::Iter(d)` as a lane vector at lane-dim chunk `x0`:
/// consecutive values along the lane dim, a broadcast elsewhere.
#[inline]
fn iter_lanes(kp: &ExecKernel, d: usize, ld: usize, x0: i64, outer: &[i64], tail: &[i64]) -> Lanes {
    use std::cmp::Ordering;
    match d.cmp(&ld) {
        Ordering::Equal => {
            let mut r = [0i32; LANES];
            for (l2, v) in r.iter_mut().enumerate() {
                *v = (kp.mins[d] + x0 + l2 as i64) as i32;
            }
            r
        }
        Ordering::Less => lanes::splat((kp.mins[d] + outer[d]) as i32),
        Ordering::Greater => lanes::splat((kp.mins[d] + tail[d - ld - 1]) as i32),
    }
}

/// Run the full reduction group of ONE pure point, scalar. `prefix(d)`
/// is the zero-based coordinate of pure dim `d`. Returns the root
/// value at group end (the word the store port would latch).
///
/// Accumulator semantics: the PE resets to `init` on the first firing
/// of each group, and `regs[ni]` carries the accumulator between
/// firings (the accumulator is root-only, so nothing else writes that
/// register) — the same gated row-major order the simulator latches.
#[allow(clippy::too_many_arguments)]
fn scalar_group(
    kp: &ExecKernel,
    feed: &[&[i32]],
    scratch: &[Vec<i32>],
    regs: &mut [i32],
    load_vals: &mut [i32],
    tail: &mut [i64],
    addr: &mut [i64],
    prefix: &impl Fn(usize) -> i64,
) -> i32 {
    let pr = kp.pure_rank;
    let tr = kp.extents.len() - pr;
    let tail = &mut tail[..tr];
    let addr = &mut addr[..kp.loads.len()];
    for (li, l) in kp.loads.iter().enumerate() {
        let mut a = l.addr.offset;
        for (d, &s) in l.addr.strides[..pr].iter().enumerate() {
            a += s * prefix(d);
        }
        addr[li] = a;
    }
    tail.iter_mut().for_each(|v| *v = 0);
    let mut first = true;
    loop {
        for (li, l) in kp.loads.iter().enumerate() {
            let a = addr[li] as usize;
            load_vals[li] = match l.src {
                BufRef::Input(i) => feed[i][a],
                BufRef::Scratch(s) => scratch[s][a],
            };
        }
        for (ni, node) in kp.nodes.iter().enumerate() {
            let mut ops = [0i32; 3];
            for (k, s) in node.srcs.iter().enumerate() {
                let routed = match s {
                    OperandSrc::Load(l) => load_vals[*l],
                    OperandSrc::Node(j) => regs[*j],
                    OperandSrc::Iter(d) => {
                        let c = if *d < pr { prefix(*d) } else { tail[*d - pr] };
                        (kp.mins[*d] + c) as i32
                    }
                    OperandSrc::None => 0,
                };
                ops[k] = node.cfg.consts[k].unwrap_or(routed);
            }
            let v = match &node.cfg.op {
                PeOp::Bin(op) => eval_binop(*op, ops[0], ops[1]),
                PeOp::Un(UnOp::Neg) => ops[0].wrapping_neg(),
                PeOp::Un(UnOp::Abs) => ops[0].wrapping_abs(),
                PeOp::Select => {
                    if ops[0] != 0 {
                        ops[1]
                    } else {
                        ops[2]
                    }
                }
                PeOp::Acc { op, init, .. } => {
                    let prev = if first { *init } else { regs[ni] };
                    eval_binop(*op, prev, ops[0])
                }
            };
            regs[ni] = v;
        }
        first = false;
        if !step_tail(&kp.extents[pr..], tail, &kp.lane.load_tail_deltas, addr) {
            break;
        }
    }
    regs[kp.nodes.len() - 1]
}

/// Walk blocks `[row0, row1)` of outer dim `cdim` (every other outer
/// dim runs its full extent; a single pass when the lane dim IS
/// dim 0), running the lane dim in [`LANES`]-wide chunks with a scalar
/// tail. `dst` is the destination slice starting at flat offset
/// `dst_base`. Serial callers pass `cdim = 0` over the full extent;
/// the partitioned path confines whichever dim carries the
/// [`StorePartition`].
#[allow(clippy::too_many_arguments)]
fn run_rows_lanes(
    kp: &ExecKernel,
    ld: usize,
    cdim: usize,
    row0: i64,
    row1: i64,
    feed: &[&[i32]],
    scratch: &[Vec<i32>],
    dst: &mut [i32],
    dst_base: i64,
    bufs: &mut KernelBufs,
) {
    let KernelBufs { regs, load_vals, lane_regs, lane_loads, outer, tail, addr } = bufs;
    let pr = kp.pure_rank; // == ld + 1
    let lane_ext = kp.extents[ld];
    let main = lane_ext - lane_ext % LANES as i64;
    let root = kp.nodes.len() - 1;
    let outer = &mut outer[..ld];
    let tail = &mut tail[..kp.extents.len() - pr];
    let addr = &mut addr[..kp.loads.len()];
    outer.iter_mut().for_each(|v| *v = 0);
    if ld >= 1 {
        if row0 >= row1 {
            return;
        }
        outer[cdim] = row0;
    }
    loop {
        // --- Full LANES-wide chunks of the lane dim -------------
        let mut x0 = 0i64;
        while x0 < main {
            for (li, l) in kp.loads.iter().enumerate() {
                addr[li] = addr_at(&l.addr, outer, ld, x0);
            }
            // Store strides on reduction dims are zero, so the store
            // address is constant across the whole tail walk.
            let store_at = addr_at(&kp.store.addr, outer, ld, x0);
            tail.iter_mut().for_each(|v| *v = 0);
            let mut first = true;
            loop {
                for (li, l) in kp.loads.iter().enumerate() {
                    let src: &[i32] = match l.src {
                        BufRef::Input(i) => feed[i],
                        BufRef::Scratch(s) => &scratch[s],
                    };
                    let base = addr[li];
                    let stride = kp.lane.load_lane_stride[li];
                    for (l2, v) in lane_loads[li].iter_mut().enumerate() {
                        *v = src[(base + l2 as i64 * stride) as usize];
                    }
                }
                for (ni, node) in kp.nodes.iter().enumerate() {
                    let mut ops = [lanes::splat(0); 3];
                    for (k, s) in node.srcs.iter().enumerate() {
                        ops[k] = match node.cfg.consts[k] {
                            Some(c) => lanes::splat(c),
                            None => match s {
                                OperandSrc::Load(l) => lane_loads[*l],
                                OperandSrc::Node(j) => lane_regs[*j],
                                OperandSrc::Iter(d) => {
                                    iter_lanes(kp, *d, ld, x0, outer, tail)
                                }
                                OperandSrc::None => lanes::splat(0),
                            },
                        };
                    }
                    let v = match &node.cfg.op {
                        PeOp::Bin(op) => lanes::lane_binop(*op, &ops[0], &ops[1]),
                        PeOp::Un(UnOp::Neg) => lanes::lane_neg(&ops[0]),
                        PeOp::Un(UnOp::Abs) => lanes::lane_abs(&ops[0]),
                        PeOp::Select => lanes::lane_select(&ops[0], &ops[1], &ops[2]),
                        PeOp::Acc { op, init, .. } => {
                            // Per-lane accumulator: each lane replays
                            // its pure point's group in scalar order.
                            let prev =
                                if first { lanes::splat(*init) } else { lane_regs[ni] };
                            lanes::lane_binop(*op, &prev, &ops[0])
                        }
                    };
                    lane_regs[ni] = v;
                }
                first = false;
                if !step_tail(&kp.extents[pr..], tail, &kp.lane.load_tail_deltas, addr) {
                    break;
                }
            }
            // One store per pure point, at its group's last step.
            let sbase = store_at - dst_base;
            let sstride = kp.lane.store_lane_stride;
            for (l2, &v) in lane_regs[root].iter().enumerate() {
                dst[(sbase + l2 as i64 * sstride) as usize] = v;
            }
            x0 += LANES as i64;
        }
        // --- Scalar tail: the remaining extent % LANES points ---
        for x in main..lane_ext {
            let v = scalar_group(kp, feed, scratch, regs, load_vals, tail, addr, &|d| {
                if d == ld {
                    x
                } else {
                    outer[d]
                }
            });
            let sa = addr_at(&kp.store.addr, outer, ld, x) - dst_base;
            dst[sa as usize] = v;
        }
        if !step_outer(outer, &kp.extents[..ld], cdim, row0, row1) {
            break;
        }
    }
}

/// Split the partition dim into block-range chunks and run them on the
/// persistent compute pool ([`super::pool`]). Sound because
/// [`StorePartition`] proved blocks `[r0, r1)` store exactly into the
/// flat range `[r0·stride + lo, r1·stride + lo)` — so `split_at_mut`
/// at the block boundaries hands each worker a disjoint `&mut` slice,
/// and the borrow checker does the rest. Boundary chunks absorb the
/// `[0, lo)` / `[.., len)` margins.
fn run_partitioned(
    kp: &ExecKernel,
    ld: usize,
    sp: StorePartition,
    feed: &[&[i32]],
    scratch: &[Vec<i32>],
    dst: &mut [i32],
    threads: usize,
) {
    let rows = kp.extents[sp.dim];
    let t = threads.min(rows as usize);
    let len = dst.len() as i64;
    let mut tasks = Vec::with_capacity(t);
    let mut rest: &mut [i32] = dst;
    let mut taken = 0i64;
    for i in 0..t {
        let r0 = rows * i as i64 / t as i64;
        let r1 = rows * (i + 1) as i64 / t as i64;
        let end = if r1 >= rows { len } else { r1 * sp.stride + sp.lo };
        let (chunk, r2) = std::mem::take(&mut rest).split_at_mut((end - taken) as usize);
        rest = r2;
        let dst_base = taken;
        taken = end;
        tasks.push(move || {
            // Per-worker buffers: allocation is fine here — this
            // path only engages at `trip >= PAR_MIN_POINTS`, far
            // above any per-tile kernel.
            let mut bufs = KernelBufs::for_kernel(kp);
            run_rows_lanes(
                kp,
                ld,
                sp.dim,
                r0,
                r1,
                feed,
                scratch,
                &mut *chunk,
                dst_base,
                &mut bufs,
            );
        });
    }
    super::pool::run_tasks(&mut tasks);
}

/// The vectorized engine's per-kernel dispatch: full-reduction
/// fallback, partitioned-parallel when proven safe and big enough,
/// else the serial lane walk.
fn exec_kernel(
    kp: &ExecKernel,
    feed: &[&[i32]],
    scratch: &[Vec<i32>],
    dst: &mut [i32],
    bufs: &mut KernelBufs,
    threads: usize,
) {
    let sampled = crate::telemetry::sampling();
    let Some(ld) = kp.lane.lane_dim else {
        // No pure dims: the whole domain is one reduction group
        // draining to a single point (store strides are all zero).
        if sampled {
            let m = crate::telemetry::metrics();
            m.exec_kernels.inc();
            m.exec_threads_used.inc();
            m.exec_points_scalar.inc();
        }
        let KernelBufs { regs, load_vals, tail, addr, .. } = bufs;
        let v = scalar_group(kp, feed, scratch, regs, load_vals, tail, addr, &|_| 0);
        dst[kp.store.addr.offset as usize] = v;
        return;
    };
    let trip: i64 = kp.extents.iter().product();
    if threads >= 2 && trip >= PAR_MIN_POINTS {
        // The partition proof guarantees `dim < ld` and extent ≥ 2,
        // so a width-2+ run always fans out at least 2 workers here.
        if let Some(sp) = kp.lane.partition {
            if sampled {
                let t = threads.min(kp.extents[sp.dim] as usize);
                record_dispatch(kp, ld, t as u64, true);
            }
            run_partitioned(kp, ld, sp, feed, scratch, dst, threads);
            return;
        }
    }
    if sampled {
        record_dispatch(kp, ld, 1, false);
    }
    let row1 = if ld >= 1 { kp.extents[0] } else { 1 };
    run_rows_lanes(kp, ld, 0, 0, row1, feed, scratch, dst, 0, bufs);
}

/// Telemetry accounting for one vectorized-kernel dispatch: lane
/// engagement (how many output points ran through the 8-wide main
/// loop vs the scalar tail) and thread fan-out. Only called when
/// sampling is on; a few multiplies and atomic adds, no allocation.
fn record_dispatch(kp: &ExecKernel, ld: usize, threads_used: u64, parallel: bool) {
    let m = crate::telemetry::metrics();
    m.exec_kernels.inc();
    if parallel {
        m.exec_kernels_parallel.inc();
    }
    m.exec_threads_used.add(threads_used);
    let lane_ext = kp.extents[ld];
    let main = lane_ext - lane_ext % LANES as i64;
    let outer_trip: i64 = kp.extents[..ld].iter().product();
    m.exec_points_vector.add((outer_trip * main) as u64);
    m.exec_points_scalar.add((outer_trip * (lane_ext - main)) as u64);
}

/// The original scalar reference walk (`--engine exec-scalar`): one
/// point at a time over an [`IterationDomain`] with [`DeltaImpl`]
/// address cursors — a genuinely independent implementation of the
/// same kernel semantics, kept for differential testing. Builds its
/// cursors per call; it is not on anyone's hot path.
fn exec_kernel_scalar(
    kp: &ExecKernel,
    feed: &[&[i32]],
    scratch: &[Vec<i32>],
    dst: &mut [i32],
    bufs: &mut KernelBufs,
) {
    if crate::telemetry::sampling() {
        let m = crate::telemetry::metrics();
        m.exec_kernels.inc();
        m.exec_threads_used.inc();
        let pts: i64 = kp.extents[..kp.pure_rank].iter().product();
        m.exec_points_scalar.add(pts as u64);
    }
    let KernelBufs { regs, load_vals, .. } = bufs;
    let mut id = IterationDomain::new(kp.extents.clone());
    let mut loads: Vec<DeltaImpl> =
        kp.loads.iter().map(|l| DeltaImpl::new(&l.addr, &kp.extents)).collect();
    let mut store = DeltaImpl::new(&kp.store.addr, &kp.extents);
    let root = kp.nodes.len() - 1;
    let period = kp.store.period;
    let mut acc: i32 = 0;
    let mut group: i64 = 0;
    loop {
        let pt = id.point();
        for (li, l) in kp.loads.iter().enumerate() {
            let a = loads[li].value() as usize;
            load_vals[li] = match l.src {
                BufRef::Input(i) => feed[i][a],
                BufRef::Scratch(s) => scratch[s][a],
            };
        }
        for (ni, node) in kp.nodes.iter().enumerate() {
            let mut ops = [0i32; 3];
            for (k, s) in node.srcs.iter().enumerate() {
                let routed = match s {
                    OperandSrc::Load(l) => load_vals[*l],
                    OperandSrc::Node(j) => regs[*j],
                    OperandSrc::Iter(d) => (kp.mins[*d] + pt[*d]) as i32,
                    OperandSrc::None => 0,
                };
                ops[k] = node.cfg.consts[k].unwrap_or(routed);
            }
            regs[ni] = match &node.cfg.op {
                PeOp::Bin(op) => eval_binop(*op, ops[0], ops[1]),
                PeOp::Un(UnOp::Neg) => ops[0].wrapping_neg(),
                PeOp::Un(UnOp::Abs) => ops[0].wrapping_abs(),
                PeOp::Select => {
                    if ops[0] != 0 {
                        ops[1]
                    } else {
                        ops[2]
                    }
                }
                PeOp::Acc { op, init, .. } => {
                    // Same reset-every-`period`-firings rule as the
                    // PE's accumulate mode; firing order is row-major,
                    // exactly the gated order the simulator latches.
                    if group == 0 {
                        acc = *init;
                    }
                    acc = eval_binop(*op, acc, ops[0]);
                    acc
                }
            };
        }
        group += 1;
        if group == period {
            group = 0;
            let a = store.value() as usize;
            dst[a] = regs[root];
        }
        match id.step() {
            Some((inc, clr)) => {
                for d in loads.iter_mut() {
                    d.step(&inc, &clr);
                }
                store.step(&inc, &clr);
            }
            None => break,
        }
    }
}

/// One-shot convenience over [`ExecPlan::build`] + [`ExecRun::run`],
/// mirroring [`crate::cgra::simulate`]. Repeated callers should build
/// the plan once and reuse an `ExecRun`.
pub fn execute(
    design: &MappedDesign,
    graph: &UbGraph,
    inputs: &BTreeMap<String, Tensor>,
) -> Result<SimResult> {
    let plan = Arc::new(ExecPlan::build(design, graph)?);
    ExecRun::new(plan).run(inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::simulate;
    use crate::extraction::extract;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::{Expr, LoweredPipeline};
    use crate::mapping::map_design;
    use crate::sched;

    fn compile(p: &Program) -> (LoweredPipeline, UbGraph, MappedDesign) {
        let lp = lower(p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        let g = extract(&lp, &ps).unwrap();
        let d = map_design(&g).unwrap();
        (lp, g, d)
    }

    fn brighten_blur(tile: i64) -> Program {
        let brighten = Func::pure_fn(
            "brighten",
            &["y", "x"],
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
        );
        let blur = Func::pure_fn(
            "blur",
            &["y", "x"],
            Expr::shr(
                Expr::sum(vec![
                    Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                    Expr::ld(
                        "brighten",
                        vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(1)),
                            Expr::add(Expr::v("x"), Expr::c(1)),
                        ],
                    ),
                ]),
                2,
            ),
        );
        Program {
            name: "bb".into(),
            inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
            funcs: vec![brighten, blur],
            schedule: HwSchedule::new([tile, tile]).store_at("brighten"),
        }
    }

    fn box_filter(tile: i64) -> Program {
        let conv = Func::reduce_fn(
            "conv",
            &["y", "x"],
            Expr::c(0),
            &[("ry", 0, 3), ("rx", 0, 3)],
            Expr::add(
                Expr::ld("conv", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld(
                    "in",
                    vec![
                        Expr::add(Expr::v("y"), Expr::v("ry")),
                        Expr::add(Expr::v("x"), Expr::v("rx")),
                    ],
                ),
            ),
        );
        Program {
            name: "boxf".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![conv],
            schedule: HwSchedule::new([tile, tile]),
        }
    }

    /// A planar RGB generator with the channel dim outermost, unrolled
    /// by 3: each unrolled kernel stores `(3·c₂ + lane, y, x)`, so its
    /// dim-0 extent collapses to 1 and the old dim-0 `RowBlock` proof
    /// could never parallelize it — the `y` dim carries the
    /// [`StorePartition`] instead.
    fn planar_rgb(tile: i64) -> Program {
        let rgb = Func::pure_fn(
            "rgb",
            &["c", "y", "x"],
            Expr::add(
                Expr::mul(
                    Expr::c(3),
                    Expr::ld("input", vec![Expr::v("c"), Expr::v("y"), Expr::v("x")]),
                ),
                Expr::v("c"),
            ),
        );
        Program {
            name: "prgb".into(),
            inputs: vec![InputDecl { name: "input".into(), rank: 3 }],
            funcs: vec![rgb],
            schedule: HwSchedule::new([3, tile, tile]).unroll("rgb", "c", 3),
        }
    }

    fn inputs_for(lp: &LoweredPipeline, salt: i64) -> BTreeMap<String, Tensor> {
        let mut ins = BTreeMap::new();
        for name in &lp.inputs {
            ins.insert(
                name.clone(),
                Tensor::from_fn(lp.buffers[name].clone(), |pt| {
                    let mut h = salt;
                    for &v in pt {
                        h = h.wrapping_mul(31).wrapping_add(v + 7);
                    }
                    (h.rem_euclid(251)) as i32
                }),
            );
        }
        ins
    }

    /// The engine contract on a stencil pipeline: outputs AND stats
    /// bit-identical to the cycle-accurate simulator.
    #[test]
    fn stencil_matches_sim_bit_exact_with_identical_stats() {
        let p = brighten_blur(15);
        let (lp, g, d) = compile(&p);
        let ins = inputs_for(&lp, 17);
        let sim = simulate(&d, &g, &ins).unwrap();
        let ex = execute(&d, &g, &ins).unwrap();
        assert_eq!(ex.output.shape, sim.output.shape);
        assert_eq!(ex.output.data, sim.output.data);
        assert_eq!(ex.stats, sim.stats);
    }

    /// Reduction pipeline (accumulator PE, dual-port fallback): same
    /// contract.
    #[test]
    fn reduction_matches_sim_bit_exact() {
        let p = box_filter(6);
        let (lp, g, d) = compile(&p);
        let ins = inputs_for(&lp, 3);
        let sim = simulate(&d, &g, &ins).unwrap();
        let ex = execute(&d, &g, &ins).unwrap();
        assert_eq!(ex.output.data, sim.output.data);
        assert_eq!(ex.stats, sim.stats);
    }

    /// Unrolled lanes: multiple kernels per stage, multiple drains.
    #[test]
    fn unrolled_matches_sim_bit_exact() {
        let mut p = brighten_blur(14);
        p.schedule = HwSchedule::new([14, 14])
            .store_at("brighten")
            .unroll("brighten", "x", 2)
            .unroll("blur", "x", 2);
        let (lp, g, d) = compile(&p);
        let ins = inputs_for(&lp, 29);
        let sim = simulate(&d, &g, &ins).unwrap();
        let ex = execute(&d, &g, &ins).unwrap();
        assert_eq!(ex.output.data, sim.output.data);
        assert_eq!(ex.stats, sim.stats);
    }

    /// The scalar reference engine is bit-identical to the vectorized
    /// one — on a stencil (pure), a reduction (accumulator), and an
    /// unrolled variant. Tile sizes straddle LANES multiples so the
    /// scalar-tail path runs too.
    #[test]
    fn scalar_engine_matches_simd_engine() {
        let mut unrolled = brighten_blur(14);
        unrolled.schedule = HwSchedule::new([14, 14])
            .store_at("brighten")
            .unroll("brighten", "x", 2)
            .unroll("blur", "x", 2);
        for (p, salt) in [(brighten_blur(16), 5), (box_filter(9), 7), (unrolled, 9)] {
            let (lp, g, d) = compile(&p);
            let ins = inputs_for(&lp, salt);
            let plan = Arc::new(ExecPlan::build(&d, &g).unwrap());
            let simd = ExecRun::new(Arc::clone(&plan)).run(&ins).unwrap();
            let scalar = ExecRun::new_scalar(plan).run(&ins).unwrap();
            assert_eq!(simd.output.data, scalar.output.data, "{}", p.name);
            assert_eq!(simd.stats, scalar.stats, "{}", p.name);
        }
    }

    /// A domain big enough to cross PAR_MIN_POINTS engages the
    /// row-parallel path — its output must be bit-identical to one
    /// worker and to the scalar reference.
    #[test]
    fn threaded_matches_single_thread_bit_exact() {
        let p = brighten_blur(280); // 280^2 points > 2^16
        let (lp, g, d) = compile(&p);
        let plan = Arc::new(ExecPlan::build(&d, &g).unwrap());
        assert!(
            plan.kernels.iter().any(|k| {
                k.extents.iter().product::<i64>() >= PAR_MIN_POINTS
                    && k.lane.partition.is_some()
            }),
            "fixture no longer exercises the parallel path"
        );
        let ins = inputs_for(&lp, 13);
        let par = ExecRun::with_threads(Arc::clone(&plan), 4).run(&ins).unwrap();
        let one = ExecRun::with_threads(Arc::clone(&plan), 1).run(&ins).unwrap();
        let sc = ExecRun::new_scalar(plan).run(&ins).unwrap();
        assert_eq!(par.output.data, one.output.data);
        assert_eq!(par.output.data, sc.output.data);
        assert_eq!(par.stats, one.stats);
    }

    /// A previously-serial interleaved-store shape joins the parallel
    /// path: the channel-unrolled planar RGB kernels have dim-0 extent
    /// 1 (unprovable under the old dim-0 RowBlock rule) but partition
    /// on `y` — and the pooled parallel run stays bit-exact against
    /// one worker and the scalar reference.
    #[test]
    fn channel_unrolled_planar_store_joins_parallel_path() {
        let p = planar_rgb(280); // per-kernel trip 280² > 2^16
        let (lp, g, d) = compile(&p);
        let plan = Arc::new(ExecPlan::build(&d, &g).unwrap());
        for k in &plan.kernels {
            assert_eq!(k.extents[0], 1, "{}: c should collapse under unroll", k.stage);
            let sp = k.lane.partition.expect("planar store must partition");
            assert!(sp.dim >= 1, "{}: partition must ride an inner dim", k.stage);
        }
        assert!(
            plan.parallel_kernel_count() >= 1,
            "fixture no longer exercises the partitioned parallel path"
        );
        let ins = inputs_for(&lp, 41);
        let par = ExecRun::with_threads(Arc::clone(&plan), 8).run(&ins).unwrap();
        let one = ExecRun::with_threads(Arc::clone(&plan), 1).run(&ins).unwrap();
        let sc = ExecRun::new_scalar(plan).run(&ins).unwrap();
        assert_eq!(par.output.data, one.output.data);
        assert_eq!(par.output.data, sc.output.data);
        assert_eq!(par.stats, one.stats);
    }

    /// The zero-spawn half of the warm-path contract: once the pool
    /// has served one parallel run, further runs claim parked workers
    /// instead of spawning threads.
    #[test]
    fn warm_parallel_runs_do_not_spawn_threads() {
        let p = brighten_blur(280);
        let (lp, g, d) = compile(&p);
        let plan = Arc::new(ExecPlan::build(&d, &g).unwrap());
        let mut run = ExecRun::with_threads(plan, 4);
        let ins = inputs_for(&lp, 5);
        run.run(&ins).unwrap(); // warm the pool
        // Concurrent tests may legitimately grow the pool; only a
        // spawn on *every* attempt is a real regression.
        let mut ok = false;
        for _ in 0..5 {
            let before = super::super::pool::spawn_count();
            for _ in 0..4 {
                run.run(&ins).unwrap();
            }
            if super::super::pool::spawn_count() == before {
                ok = true;
                break;
            }
        }
        assert!(ok, "warm parallel runs spawned threads");
    }

    /// A reused ExecRun is bit-identical across interleaved inputs,
    /// like the simulator's plan-reuse contract.
    #[test]
    fn run_reuse_is_bit_identical_across_inputs() {
        let p = brighten_blur(12);
        let (lp, g, d) = compile(&p);
        let plan = Arc::new(ExecPlan::build(&d, &g).unwrap());
        let mut run = ExecRun::new(Arc::clone(&plan));
        let (a, b) = (inputs_for(&lp, 1), inputs_for(&lp, 2));
        for ins in [&a, &b, &a] {
            let reused = run.run(ins).unwrap();
            let fresh = execute(&d, &g, ins).unwrap();
            assert_eq!(reused.output.data, fresh.output.data);
        }
        assert_ne!(
            run.run(&a).unwrap().output.data,
            run.run(&b).unwrap().output.data
        );
    }

    /// The arena's zero-allocation contract: after the first request,
    /// repeated `run_into` calls never allocate — the counter freezes.
    #[test]
    fn warm_runs_do_not_allocate() {
        for p in [brighten_blur(12), box_filter(9)] {
            let (lp, g, d) = compile(&p);
            let plan = Arc::new(ExecPlan::build(&d, &g).unwrap());
            let mut run = ExecRun::new(plan);
            let (a, b) = (inputs_for(&lp, 4), inputs_for(&lp, 6));
            let mut out = Vec::new();
            run.run_into(&a, &mut out).unwrap();
            let warm = run.alloc_count();
            for ins in [&b, &a, &b] {
                run.run_into(ins, &mut out).unwrap();
            }
            assert_eq!(run.alloc_count(), warm, "{}: warm run allocated", p.name);
        }
    }

    /// `run_into` produces the same words `run` returns.
    #[test]
    fn run_into_matches_run() {
        let p = brighten_blur(12);
        let (lp, g, d) = compile(&p);
        let plan = Arc::new(ExecPlan::build(&d, &g).unwrap());
        let mut run = ExecRun::new(plan);
        let ins = inputs_for(&lp, 21);
        let full = run.run(&ins).unwrap();
        let mut out = Vec::new();
        let stats = run.run_into(&ins, &mut out).unwrap();
        assert_eq!(out, full.output.data);
        assert_eq!(stats, full.stats);
    }

    /// Graphs the functional engine cannot prove sound are rejected at
    /// plan build (the engine-selection fallback signal).
    #[test]
    fn no_output_stream_is_an_error() {
        let p = brighten_blur(8);
        let (_, mut g, d) = compile(&p);
        g.output_streams.clear();
        let err = ExecPlan::build(&d, &g).unwrap_err();
        assert!(err.to_string().contains("no output stream"), "{err:#}");
    }

    /// An output write port with no matching drain is rejected: the
    /// simulator would report 0 for its coordinates while this engine
    /// would return the stored values.
    #[test]
    fn undrained_output_write_port_is_rejected() {
        let mut p = brighten_blur(14);
        p.schedule = HwSchedule::new([14, 14])
            .store_at("brighten")
            .unroll("brighten", "x", 2)
            .unroll("blur", "x", 2);
        let (_, mut g, d) = compile(&p);
        assert!(g.output_streams.len() >= 2, "need an unrolled output");
        g.output_streams.pop();
        let err = ExecPlan::build(&d, &g).unwrap_err();
        assert!(err.to_string().contains("never drained"), "{err:#}");
    }

    /// A load port nudged out of lockstep with its kernel must be
    /// rejected — that is precisely the shape the cycle-accurate
    /// fallback exists for.
    #[test]
    fn non_lockstep_load_port_is_rejected() {
        let p = brighten_blur(8);
        let (_, mut g, d) = compile(&p);
        // Delay one read port one cycle: sim would model the skew,
        // the functional engine must refuse.
        let ub = g.buffers.get_mut("brighten").unwrap();
        ub.outputs[0].schedule = ub.outputs[0].schedule.delayed(1);
        let err = ExecPlan::build(&d, &g).unwrap_err();
        assert!(err.to_string().contains("lockstep"), "{err:#}");
    }

    /// Mismatched request layout is rejected up front, same as SimRun.
    #[test]
    fn mismatched_input_box_is_rejected() {
        let p = brighten_blur(8);
        let (_, g, d) = compile(&p);
        let mut ins = BTreeMap::new();
        ins.insert(
            "input".to_string(),
            Tensor::zeros(crate::poly::BoxSet::from_extents(&[3, 3])),
        );
        let err = execute(&d, &g, &ins).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err:#}");
    }
}
