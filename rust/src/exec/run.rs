//! [`ExecRun`]: execute one request against an [`ExecPlan`] — fused
//! loop nests over the kernels' iteration domains, no cycle loop.
//!
//! Per request the run walks each kernel's domain once in row-major
//! order: load addresses advance by Fig-5c delta recurrences
//! ([`crate::hw::DeltaImpl`], one add per stream per step), the mapped
//! PE node program evaluates with the same i32 ALU semantics the
//! hardware uses ([`crate::halide::expr::eval_binop`]), and the root
//! value is stored once per reduction group. The reported
//! [`SimStats`] come from the plan's analytic timing model and are
//! bit-identical to what the cycle-accurate simulator would report —
//! the differential suite (`rust/tests/exec_vs_sim.rs`) enforces it.
//!
//! Like [`crate::cgra::SimRun`], an `ExecRun` is reused across
//! requests with in-place resets: one run serves one thread.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::cgra::{SimResult, SimStats};
use crate::halide::expr::{eval_binop, UnOp};
use crate::hw::{AffineHw, DeltaImpl, IterationDomain, PeOp};
use crate::mapping::{MappedDesign, OperandSrc};
use crate::tensor::Tensor;
use crate::ub::UbGraph;

use super::plan::{BufRef, ExecPlan};

/// Per-kernel iteration state, reset in place between requests.
struct KernelCursors {
    id: IterationDomain,
    loads: Vec<DeltaImpl>,
    store: DeltaImpl,
}

/// The execution half of the functional engine: mutable per-request
/// state for one [`ExecPlan`].
pub struct ExecRun {
    plan: Arc<ExecPlan>,
    scratch: Vec<Vec<i32>>,
    cursors: Vec<KernelCursors>,
    /// PE register file scratch (sized to the widest kernel).
    regs: Vec<i32>,
    load_vals: Vec<i32>,
}

impl ExecRun {
    pub fn new(plan: Arc<ExecPlan>) -> ExecRun {
        let scratch = plan.scratch.iter().map(|s| vec![0i32; s.len]).collect();
        let cursors = plan
            .kernels
            .iter()
            .map(|k| KernelCursors {
                id: IterationDomain::new(k.extents.clone()),
                loads: k
                    .loads
                    .iter()
                    .map(|l| DeltaImpl::new(&l.addr, &k.extents))
                    .collect(),
                store: DeltaImpl::new(&k.store.addr, &k.extents),
            })
            .collect();
        let regs = vec![0; plan.kernels.iter().map(|k| k.nodes.len()).max().unwrap_or(0)];
        let load_vals =
            vec![0; plan.kernels.iter().map(|k| k.loads.len()).max().unwrap_or(0)];
        ExecRun { plan, scratch, cursors, regs, load_vals }
    }

    pub fn plan(&self) -> &Arc<ExecPlan> {
        &self.plan
    }

    /// Execute one request. Output and stats are bit-identical to a
    /// cycle-accurate [`crate::cgra::SimRun::run`] on the same design
    /// and inputs.
    pub fn run(&mut self, inputs: &BTreeMap<String, Tensor>) -> Result<SimResult> {
        let plan = Arc::clone(&self.plan);
        let ExecRun { scratch, cursors, regs, load_vals, .. } = self;

        // Bind request tensors, verifying layout (same rule as the
        // simulator: flat addressing is only valid against the
        // declared boxes).
        let mut feed: Vec<&[i32]> = Vec::with_capacity(plan.inputs.len());
        for spec in &plan.inputs {
            let t = inputs
                .get(&spec.name)
                .with_context(|| format!("missing input {}", spec.name))?;
            anyhow::ensure!(
                t.shape.same_layout(&spec.shape),
                "input {}: tensor box {} does not match the design's declared box {}",
                spec.name,
                t.shape,
                spec.shape
            );
            feed.push(&t.data);
        }

        // Zero the intermediate buffers (the hardware's reset state).
        for s in scratch.iter_mut() {
            s.iter_mut().for_each(|v| *v = 0);
        }

        // --- Fused kernel loops, in dataflow order --------------
        for (ks, kp) in cursors.iter_mut().zip(&plan.kernels) {
            ks.id.reset();
            for d in ks.loads.iter_mut() {
                d.reset();
            }
            ks.store.reset();

            let root = kp.nodes.len() - 1;
            let period = kp.store.period;
            let mut acc: i32 = 0;
            let mut group: i64 = 0;
            loop {
                let pt = ks.id.point();
                for (li, l) in kp.loads.iter().enumerate() {
                    let a = ks.loads[li].value() as usize;
                    load_vals[li] = match l.src {
                        BufRef::Input(i) => feed[i][a],
                        BufRef::Scratch(s) => scratch[s][a],
                    };
                }
                for (ni, node) in kp.nodes.iter().enumerate() {
                    let mut ops = [0i32; 3];
                    for (k, s) in node.srcs.iter().enumerate() {
                        let routed = match s {
                            OperandSrc::Load(l) => load_vals[*l],
                            OperandSrc::Node(j) => regs[*j],
                            OperandSrc::Iter(d) => (kp.mins[*d] + pt[*d]) as i32,
                            OperandSrc::None => 0,
                        };
                        ops[k] = node.cfg.consts[k].unwrap_or(routed);
                    }
                    regs[ni] = match &node.cfg.op {
                        PeOp::Bin(op) => eval_binop(*op, ops[0], ops[1]),
                        PeOp::Un(UnOp::Neg) => ops[0].wrapping_neg(),
                        PeOp::Un(UnOp::Abs) => ops[0].wrapping_abs(),
                        PeOp::Select => {
                            if ops[0] != 0 {
                                ops[1]
                            } else {
                                ops[2]
                            }
                        }
                        PeOp::Acc { op, init, .. } => {
                            // Same reset-every-`period`-firings rule as
                            // the PE's accumulate mode; firing order is
                            // row-major, exactly the gated order the
                            // simulator latches.
                            if group == 0 {
                                acc = *init;
                            }
                            acc = eval_binop(*op, acc, ops[0]);
                            acc
                        }
                    };
                }
                group += 1;
                if group == period {
                    group = 0;
                    let a = ks.store.value() as usize;
                    scratch[kp.store.dst][a] = regs[root];
                }
                match ks.id.step() {
                    Some((inc, clr)) => {
                        for d in ks.loads.iter_mut() {
                            d.step(&inc, &clr);
                        }
                        ks.store.step(&inc, &clr);
                    }
                    None => break,
                }
            }
        }

        Ok(SimResult {
            output: Tensor::from_data(
                plan.out_box.clone(),
                scratch[plan.out_scratch].clone(),
            ),
            stats: plan.timing().stats,
        })
    }

    /// The analytic stats the engine reports (identical every request
    /// — activity is input-independent by construction).
    pub fn stats(&self) -> SimStats {
        self.plan.timing().stats
    }
}

/// One-shot convenience over [`ExecPlan::build`] + [`ExecRun::run`],
/// mirroring [`crate::cgra::simulate`]. Repeated callers should build
/// the plan once and reuse an `ExecRun`.
pub fn execute(
    design: &MappedDesign,
    graph: &UbGraph,
    inputs: &BTreeMap<String, Tensor>,
) -> Result<SimResult> {
    let plan = Arc::new(ExecPlan::build(design, graph)?);
    ExecRun::new(plan).run(inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cgra::simulate;
    use crate::extraction::extract;
    use crate::halide::func::{Func, InputDecl, Program};
    use crate::halide::lower::lower;
    use crate::halide::schedule::HwSchedule;
    use crate::halide::{Expr, LoweredPipeline};
    use crate::mapping::map_design;
    use crate::sched;

    fn compile(p: &Program) -> (LoweredPipeline, UbGraph, MappedDesign) {
        let lp = lower(p).unwrap();
        let ps = sched::schedule(&lp).unwrap();
        let g = extract(&lp, &ps).unwrap();
        let d = map_design(&g).unwrap();
        (lp, g, d)
    }

    fn brighten_blur(tile: i64) -> Program {
        let brighten = Func::pure_fn(
            "brighten",
            &["y", "x"],
            Expr::mul(Expr::c(2), Expr::ld("input", vec![Expr::v("y"), Expr::v("x")])),
        );
        let blur = Func::pure_fn(
            "blur",
            &["y", "x"],
            Expr::shr(
                Expr::sum(vec![
                    Expr::ld("brighten", vec![Expr::v("y"), Expr::v("x")]),
                    Expr::ld(
                        "brighten",
                        vec![Expr::v("y"), Expr::add(Expr::v("x"), Expr::c(1))],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![Expr::add(Expr::v("y"), Expr::c(1)), Expr::v("x")],
                    ),
                    Expr::ld(
                        "brighten",
                        vec![
                            Expr::add(Expr::v("y"), Expr::c(1)),
                            Expr::add(Expr::v("x"), Expr::c(1)),
                        ],
                    ),
                ]),
                2,
            ),
        );
        Program {
            name: "bb".into(),
            inputs: vec![InputDecl { name: "input".into(), rank: 2 }],
            funcs: vec![brighten, blur],
            schedule: HwSchedule::new([tile, tile]).store_at("brighten"),
        }
    }

    fn inputs_for(lp: &LoweredPipeline, salt: i64) -> BTreeMap<String, Tensor> {
        let mut ins = BTreeMap::new();
        for name in &lp.inputs {
            ins.insert(
                name.clone(),
                Tensor::from_fn(lp.buffers[name].clone(), |pt| {
                    let mut h = salt;
                    for &v in pt {
                        h = h.wrapping_mul(31).wrapping_add(v + 7);
                    }
                    (h.rem_euclid(251)) as i32
                }),
            );
        }
        ins
    }

    /// The engine contract on a stencil pipeline: outputs AND stats
    /// bit-identical to the cycle-accurate simulator.
    #[test]
    fn stencil_matches_sim_bit_exact_with_identical_stats() {
        let p = brighten_blur(15);
        let (lp, g, d) = compile(&p);
        let ins = inputs_for(&lp, 17);
        let sim = simulate(&d, &g, &ins).unwrap();
        let ex = execute(&d, &g, &ins).unwrap();
        assert_eq!(ex.output.shape, sim.output.shape);
        assert_eq!(ex.output.data, sim.output.data);
        assert_eq!(ex.stats, sim.stats);
    }

    /// Reduction pipeline (accumulator PE, dual-port fallback): same
    /// contract.
    #[test]
    fn reduction_matches_sim_bit_exact() {
        let conv = Func::reduce_fn(
            "conv",
            &["y", "x"],
            Expr::c(0),
            &[("ry", 0, 3), ("rx", 0, 3)],
            Expr::add(
                Expr::ld("conv", vec![Expr::v("y"), Expr::v("x")]),
                Expr::ld(
                    "in",
                    vec![
                        Expr::add(Expr::v("y"), Expr::v("ry")),
                        Expr::add(Expr::v("x"), Expr::v("rx")),
                    ],
                ),
            ),
        );
        let p = Program {
            name: "boxf".into(),
            inputs: vec![InputDecl { name: "in".into(), rank: 2 }],
            funcs: vec![conv],
            schedule: HwSchedule::new([6, 6]),
        };
        let (lp, g, d) = compile(&p);
        let ins = inputs_for(&lp, 3);
        let sim = simulate(&d, &g, &ins).unwrap();
        let ex = execute(&d, &g, &ins).unwrap();
        assert_eq!(ex.output.data, sim.output.data);
        assert_eq!(ex.stats, sim.stats);
    }

    /// Unrolled lanes: multiple kernels per stage, multiple drains.
    #[test]
    fn unrolled_matches_sim_bit_exact() {
        let mut p = brighten_blur(14);
        p.schedule = HwSchedule::new([14, 14])
            .store_at("brighten")
            .unroll("brighten", "x", 2)
            .unroll("blur", "x", 2);
        let (lp, g, d) = compile(&p);
        let ins = inputs_for(&lp, 29);
        let sim = simulate(&d, &g, &ins).unwrap();
        let ex = execute(&d, &g, &ins).unwrap();
        assert_eq!(ex.output.data, sim.output.data);
        assert_eq!(ex.stats, sim.stats);
    }

    /// A reused ExecRun is bit-identical across interleaved inputs,
    /// like the simulator's plan-reuse contract.
    #[test]
    fn run_reuse_is_bit_identical_across_inputs() {
        let p = brighten_blur(12);
        let (lp, g, d) = compile(&p);
        let plan = Arc::new(ExecPlan::build(&d, &g).unwrap());
        let mut run = ExecRun::new(Arc::clone(&plan));
        let (a, b) = (inputs_for(&lp, 1), inputs_for(&lp, 2));
        for ins in [&a, &b, &a] {
            let reused = run.run(ins).unwrap();
            let fresh = execute(&d, &g, ins).unwrap();
            assert_eq!(reused.output.data, fresh.output.data);
        }
        assert_ne!(
            run.run(&a).unwrap().output.data,
            run.run(&b).unwrap().output.data
        );
    }

    /// Graphs the functional engine cannot prove sound are rejected at
    /// plan build (the engine-selection fallback signal).
    #[test]
    fn no_output_stream_is_an_error() {
        let p = brighten_blur(8);
        let (_, mut g, d) = compile(&p);
        g.output_streams.clear();
        let err = ExecPlan::build(&d, &g).unwrap_err();
        assert!(err.to_string().contains("no output stream"), "{err:#}");
    }

    /// An output write port with no matching drain is rejected: the
    /// simulator would report 0 for its coordinates while this engine
    /// would return the stored values.
    #[test]
    fn undrained_output_write_port_is_rejected() {
        let mut p = brighten_blur(14);
        p.schedule = HwSchedule::new([14, 14])
            .store_at("brighten")
            .unroll("brighten", "x", 2)
            .unroll("blur", "x", 2);
        let (_, mut g, d) = compile(&p);
        assert!(g.output_streams.len() >= 2, "need an unrolled output");
        g.output_streams.pop();
        let err = ExecPlan::build(&d, &g).unwrap_err();
        assert!(err.to_string().contains("never drained"), "{err:#}");
    }

    /// A load port nudged out of lockstep with its kernel must be
    /// rejected — that is precisely the shape the cycle-accurate
    /// fallback exists for.
    #[test]
    fn non_lockstep_load_port_is_rejected() {
        let p = brighten_blur(8);
        let (_, mut g, d) = compile(&p);
        // Delay one read port one cycle: sim would model the skew,
        // the functional engine must refuse.
        let ub = g.buffers.get_mut("brighten").unwrap();
        ub.outputs[0].schedule = ub.outputs[0].schedule.delayed(1);
        let err = ExecPlan::build(&d, &g).unwrap_err();
        assert!(err.to_string().contains("lockstep"), "{err:#}");
    }

    /// Mismatched request layout is rejected up front, same as SimRun.
    #[test]
    fn mismatched_input_box_is_rejected() {
        let p = brighten_blur(8);
        let (_, g, d) = compile(&p);
        let mut ins = BTreeMap::new();
        ins.insert(
            "input".to_string(),
            Tensor::zeros(crate::poly::BoxSet::from_extents(&[3, 3])),
        );
        let err = execute(&d, &g, &ins).unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err:#}");
    }
}
